"""Unified batched ANN search engine (coarse -> fast-scan -> re-rank -> merge).

Public surface:
  - ``SearchEngine``      single-host engine, ``search(queries, k)``
  - ``EngineConfig``      static search knobs (nprobe, rerank_mult, ...)
  - ``QueryStats``        per-query work counters
  - ``SearchResult``      (dists, ids, stats)
  - ``ShardedEngine``     shard-parallel execution + distributed top-k merge
  - ``exact_rerank``      the exact refinement stage, usable standalone
"""
from repro.engine.engine import (  # noqa: F401
    EngineConfig,
    QueryStats,
    SearchEngine,
    SearchResult,
)
from repro.engine.rerank import exact_distances, exact_rerank  # noqa: F401
from repro.engine.sharded import ShardedEngine  # noqa: F401
