"""Unified batched ANN search engine (coarse -> fast-scan -> re-rank -> merge).

Public surface:
  - ``SearchEngine``      single-host engine; ``search`` (staged) and
    ``search_jit`` (whole pipeline fused in one ``jax.jit`` — serving path)
  - ``EngineConfig``      static search knobs (nprobe, rerank_mult, ...),
    validated against the coarse quantizer at construction
  - ``QueryStats``        per-query work counters
  - ``SearchResult``      (dists, ids, stats)
  - ``ShardedEngine``     shard-parallel execution + distributed top-k merge
  - ``exact_rerank``      the exact refinement stage, usable standalone
  - ``fused_cache_size``  compiled-entry count of the shared fused-jit cache
"""
from repro.engine.engine import (  # noqa: F401
    EngineConfig,
    QueryStats,
    SearchEngine,
    SearchResult,
    fused_cache_size,
    validate_config,
)
from repro.engine.rerank import exact_distances, exact_rerank  # noqa: F401
from repro.engine.sharded import ShardedEngine  # noqa: F401
