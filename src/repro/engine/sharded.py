"""Shard-parallel engine execution over a device mesh.

The database's posting lists are partitioned round-robin into S shards
(``core.lists.partition_lists``); every shard runs the same local pipeline —
flat coarse over *its* centroids, grouped 4-bit scan, optional exact re-rank —
and the shard-local top-k results meet in ``core.topk.distributed_topk``:
an all-gather of 2k scalars per device, then one final re-top-k.

Base vectors for the exact re-rank are sharded too (``core.lists.
partition_base``): each shard holds only the (R, D) rows of the lists it
owns, with posting-list ids remapped to shard-local rows; results map back
to global ids via the shard's ``gids`` table just before the merge, so the
2k-scalar merge still needs no re-mapping.

Two drivers over the same per-shard function:
  - ``mesh=None``: ``jax.vmap`` with a named axis — S arbitrary, runs on one
    host; this is also how the merge is unit-tested.
  - ``mesh=...``: ``shard_map`` over a 1-D device mesh (axis ``"shards"``),
    one shard per device — the production layout.

Live mutation (docs/mutability.md) is threaded through the shards:
``upsert`` routes rows with the *global* centroid table (bitwise the same
assignment the single-host engine makes), appends into the owning shard's
spare slots, and maintains the local-id remap (``gids_s``/``norms_s`` grow
in place); ``delete`` tombstones slots; ``compact`` rebuilds every shard's
lists and base slice tombstone-free. All shard arrays live in one
``_ShardState`` snapshot swapped atomically per mutation, mirroring the
single-host ``EngineState``.
"""
from __future__ import annotations

import functools
import threading
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ivf as ivf_mod
from repro.core import topk as topk_mod
from repro.core.kmeans import pairwise_sqdist
from repro.core.lists import (ListStore, filter_pass_sizes, pack_filter_mask,
                              partition_base, partition_filter,
                              partition_lists, round_robin_perm)
from repro.engine import rerank as rerank_mod
from repro.engine.engine import (MARGIN_PROBE_FILL, EngineConfig, QueryStats,
                                 SearchEngine, SearchResult,
                                 combine_filter_bits, scan_candidates)
from repro.kernels import ops as ops_mod

AXIS = "shards"


def _local_search(centroids, lists: ListStore, real, gids, codebook, base,
                  norms, member, q, fbits, live, ns, tau, *, k: int,
                  nprobe: int, r: int, scan_impl: str, rerank_impl: str,
                  remap: bool, probe_policy: str = "fixed",
                  early_exit: bool = False):
    """One shard's pipeline + the cross-shard merge. Runs under a named axis.

    With ``remap=True`` the shard's list ids are *local* rows into its own
    ``base`` slice (see ``partition_base``): the scan and re-rank both work
    on local ids and ``gids`` translates back to global just before the
    distributed merge. With ``remap=False`` (no base held) ids are global
    throughout and ``gids``/``norms`` are unused dummies.

    ``member`` is the shard's (n_ns, L) slice of the namespace table,
    ``fbits`` its (L, W) slice of the per-request filter bitmap, ``live``
    its (L, W) slice of the engine-held live-row bitmap (None while the
    shard set carries no tombstones — docs/mutability.md), ``ns`` the
    replicated (Q,) namespace ids — any may be None (docs/filtering.md).
    A restricted query selects probes with ``masked_topk`` over its own
    lists only; padding lists are member-False everywhere, and with every
    query unrestricted the mask is all-True so the selection is exactly
    ``smallest_k`` — bit-identical to the namespace-free driver.

    Anytime knobs (docs/anytime.md): under ``probe_policy='margin'`` each
    shard prunes against the best centroid among *its own* lists (``tau``
    is the replicated traced margin width) — the margin is shard-local, so
    a shard holding none of the query's near lists prunes almost
    everything, which is exactly the work-skipping the policy wants.
    ``early_exit`` arms the stream kernel's tile pruning per shard; both
    counters are psum'd so the merged stats read as totals across shards,
    like every other counter.
    """
    index = ivf_mod.IVFIndex(centroids=centroids, codebook=codebook, lists=lists)
    nprobe_local = min(nprobe, centroids.shape[0])
    coarse_d = pairwise_sqdist(q, centroids)
    if member is not None and ns is not None:
        allow = (ns < 0)[:, None] | member[jnp.maximum(ns, 0)]
        cvals, probes = topk_mod.masked_topk(coarse_d, allow, nprobe_local)
    else:
        cvals, probes = topk_mod.smallest_k(coarse_d, nprobe_local)
    if probe_policy == "margin":
        probes, lists_pruned = topk_mod.margin_prune_probes(
            cvals, probes, jnp.inf if tau is None else tau)
    else:
        lists_pruned = jnp.zeros((q.shape[0],), jnp.int32)
    # same stage function as the single-host engine, including its stream
    # routing: each shard's local ListStore already has the
    # (nlist_local, cap, M//2) layout the stream kernel scans in place, so a
    # 'stream' (or 'auto'-resolved-to-stream) shard never materializes its
    # gathered code copy either. Tombstones ride the same path as the user
    # filter: ANDed in so the stream kernel's candidate budget skips them
    # before selection.
    eff = combine_filter_bits(fbits, live)
    flat_d, flat_ids, tiles_skipped = scan_candidates(
        index, q, probes, scan_impl=scan_impl, keep=(r * k) if r else k,
        filter_bits=eff, early_exit=early_exit,
        probe_fill=(MARGIN_PROBE_FILL if probe_policy == "margin" else 1.0))
    # re-rank (either impl) runs on the shard-local (R, D) base slice with
    # its precomputed local norms; local candidate ids map back to global
    # through gids only after the top-k, just before the merge
    vals, out_ids, reranked = rerank_mod.finalize_candidates(
        flat_d, flat_ids, base, q, k, r, norms=norms, rerank_impl=rerank_impl)
    if remap:
        out_ids = jnp.where(out_ids >= 0, gids[jnp.maximum(out_ids, 0)], -1)
    mvals, mids = topk_mod.distributed_topk(vals, out_ids, k, AXIS)
    valid = probes >= 0
    safe = jnp.maximum(probes, 0)
    zeros = jnp.zeros((q.shape[0],), jnp.int32)
    live_sizes = (lists.sizes if live is None
                  else filter_pass_sizes(lists, live))
    if fbits is None:
        rows_filtered = zeros
    else:
        dropped = live_sizes - filter_pass_sizes(lists, eff)
        rows_filtered = jnp.sum(jnp.where(valid, dropped[safe], 0), axis=1)
    if live is None:
        rows_tombstoned = zeros
    else:
        tomb = lists.sizes - live_sizes
        rows_tombstoned = jnp.sum(jnp.where(valid, tomb[safe], 0), axis=1)
    stats = QueryStats(
        # count only probes of real lists — a shard with fewer real lists
        # than nprobe inevitably "probes" padding, which is zero work
        lists_probed=jax.lax.psum(
            jnp.sum((real[safe] & valid).astype(jnp.int32), axis=1), AXIS),
        codes_scanned=jax.lax.psum(
            jnp.sum(lists.probed_sizes(probes), axis=1), AXIS),
        reranked=jax.lax.psum(reranked, AXIS),
        rows_filtered=jax.lax.psum(rows_filtered, AXIS),
        rows_tombstoned=jax.lax.psum(rows_tombstoned, AXIS),
        lists_pruned=jax.lax.psum(lists_pruned, AXIS),
        tiles_skipped=jax.lax.psum(tiles_skipped, AXIS),
    )
    return mvals, mids, stats


class _ShardState(NamedTuple):
    """One immutable snapshot of every shard-partitioned array a search
    reads — the sharded twin of ``engine.EngineState`` (docs/mutability.md).
    Mutators build a replacement and install it with a single attribute
    store, so a search never sees lists from one epoch next to base rows or
    live bits from another."""

    centroids_s: jax.Array        # (S, L, D)
    lists_s: ListStore            # leading shard dim S; ids local when base_s
    real_s: jax.Array             # (S, L) bool — False on padding lists
    base_s: jax.Array | None      # (S, R, D) or None
    gids_s: jax.Array             # (S, R) i32 local row -> global id
    norms_s: jax.Array | None     # (S, R) f32
    live_s: jax.Array | None      # (S, L, W) u8 live bitmap; None = no tombs
    rows_used: tuple              # per-shard base rows in use (len S)
    epoch: int
    n_tombstones: int


class ShardedEngine:
    """A ``SearchEngine`` whose lists are partitioned across S shards.

    Note: every shard selects probes with *flat* brute-force coarse over its
    local centroids (each shard holds only nlist/S of them, so the wrapped
    engine's HNSW/tree coarse structure does not partition); the wrapped
    engine's coarse quantizer is intentionally not carried over.

    When the wrapped engine holds base vectors, they are partitioned by
    shard list-membership (``partition_base``): each shard's re-rank reads
    only its own (R, D) slice, R ~= N/S, instead of a replicated (N, D)
    copy — with the per-row ‖x‖² norms (``norms_s``) partitioned alongside
    for the norms+GEMM formulation, so a 'stream' re-rank shard DMAs
    candidate rows straight out of its local slice. Shard-local ListStore
    ids become local row indices; ``gids_s`` maps them back to global ids
    after the per-shard pipeline.

    Mutation (docs/mutability.md): ``upsert``/``delete``/``compact`` mirror
    the single-host engine. Routing uses the retained *global* centroid
    table through the same fixed-shape encoder, so a row lands in the same
    global list (hence the same shard, ``g % S``, local list ``g // S``)
    and gets bitwise-identical codes on both engines.
    """

    def __init__(self, engine: SearchEngine, num_shards: int):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        self.num_shards = int(num_shards)
        self.codebook = engine.index.codebook
        self.config = engine.config
        # retained for mutation routing: identical assignment + codes to the
        # single-host engine by construction (core.ivf.encode_rows)
        self.centroids = engine.index.centroids
        self.nlist_global = engine.index.lists.nlist
        centroids_s, lists_s, real_s = partition_lists(
            engine.index.lists, engine.index.centroids, self.num_shards)
        if engine.base is not None:
            base_s, gids_s, local_ids, norms_s = partition_base(
                lists_s, engine.base)
            lists_s = lists_s._replace(ids=local_ids)
            rows_used = tuple(int(c) for c in
                              np.asarray(jnp.sum(gids_s >= 0, axis=1)))
        else:
            base_s = None
            # unused dummies so both vmap and shard_map see a uniform arity
            gids_s = jnp.full((self.num_shards, 1), -1, jnp.int32)
            norms_s = None
            rows_used = (0,) * self.num_shards
        # a wrapped engine may already carry tombstones — count them so the
        # first sharded search is already exact
        n_tomb = int(np.asarray(jnp.sum(lists_s.sizes))
                     - np.asarray(jnp.sum(lists_s.ids >= 0)))
        self._state = _ShardState(
            centroids_s=centroids_s, lists_s=lists_s, real_s=real_s,
            base_s=base_s, gids_s=gids_s, norms_s=norms_s,
            live_s=pack_filter_mask(lists_s.ids >= 0) if n_tomb else None,
            rows_used=rows_used, epoch=0, n_tombstones=n_tomb)
        self._mutate_lock = threading.RLock()
        self._locator: dict[int, tuple[int, int, int]] | None = None
        # optional persist.WALWriter, mirroring SearchEngine.attach_wal:
        # mutations are logged + fsync'd before the state swap
        self._wal = None
        # namespace membership sharded with the same round-robin permutation
        # as the lists: shard j's (n_ns, L) slice covers exactly its lists;
        # padding lists are member-False for every namespace
        if engine.ns_member is None:
            self.member_s = None
        else:
            member = jnp.asarray(engine.ns_member, bool)
            nlist = member.shape[1]
            s = self.num_shards
            l = -(-nlist // s)
            pad = s * l - nlist
            if pad:
                member = jnp.concatenate(
                    [member, jnp.zeros((member.shape[0], pad), bool)], axis=1)
            perm = jnp.asarray(round_robin_perm(nlist, s))
            self.member_s = (member[:, perm]
                             .reshape(member.shape[0], s, l)
                             .transpose(1, 0, 2))  # (S, n_ns, L)

    # -- state snapshot views (mirror SearchEngine's) -----------------------

    @property
    def centroids_s(self) -> jax.Array:
        return self._state.centroids_s

    @property
    def lists_s(self) -> ListStore:
        return self._state.lists_s

    @property
    def real_s(self) -> jax.Array:
        return self._state.real_s

    @property
    def base_s(self) -> jax.Array | None:
        return self._state.base_s

    @property
    def gids_s(self) -> jax.Array:
        return self._state.gids_s

    @property
    def norms_s(self) -> jax.Array | None:
        return self._state.norms_s

    @property
    def live_s(self) -> jax.Array | None:
        """Sharded live-row bitmap; None while no tombstones are held."""
        return self._state.live_s

    @property
    def cap(self) -> int:
        """Slot capacity of every (shard, list) — NB ``lists_s.cap`` would
        read the wrong axis on the 3-D store."""
        return self._state.lists_s.ids.shape[-1]

    @property
    def base(self) -> jax.Array | None:
        """Sharded base slices (S, R, D), or None when no base is held."""
        return self._state.base_s

    @property
    def epoch(self) -> int:
        return self._state.epoch

    @property
    def n_tombstones(self) -> int:
        return self._state.n_tombstones

    def attach_wal(self, wal) -> None:
        """Attach a ``persist.WALWriter`` (same contract as
        ``SearchEngine.attach_wal``): every later mutation appends a
        checksummed, fsync'd record before its state swap. ``None``
        detaches — replay must not re-log (docs/persistence.md)."""
        with self._mutate_lock:
            self._wal = wal

    def locate(self, gid: int) -> tuple[int, int, int] | None:
        """(shard, local list, slot) of a live row, None if absent."""
        with self._mutate_lock:
            return self._locate(self._state).get(int(gid))

    def _locate(self, st: _ShardState) -> dict[int, tuple[int, int, int]]:
        if self._locator is None:
            lids = np.asarray(st.lists_s.ids)
            if st.base_s is None:
                gid_at = lids                      # ids are global already
            else:
                g = np.asarray(st.gids_s)
                gid_at = np.where(
                    lids >= 0,
                    np.take_along_axis(
                        g, np.maximum(lids, 0).reshape(g.shape[0], -1),
                        axis=1).reshape(lids.shape),
                    -1)
            js, ls, ss = np.nonzero(gid_at >= 0)
            self._locator = {int(gid_at[j, l, s]): (int(j), int(l), int(s))
                             for j, l, s in zip(js, ls, ss)}
        return self._locator

    # -- live mutation (docs/mutability.md) ---------------------------------

    def upsert(self, ids, vecs, *, attrs=None) -> np.ndarray:
        """Shard-local insert/replace. Same contract as
        ``SearchEngine.upsert``; returns the (B,) i32 *global* list per row.

        Routing runs on the retained global centroids through the
        fixed-shape encoder, so assignment and code bytes are bitwise what
        the single-host engine produces; the owning shard is ``g % S``.
        When a target list is out of spare slots the whole shard set grows
        ``cap`` (shard compaction only happens in ``compact``); when a
        shard's base slice is out of rows it grows R — both retire autotune
        signatures, which are invalidated here.
        """
        ids = np.asarray(ids, np.int64)
        vecs = np.asarray(vecs, np.float32)
        if ids.ndim != 1 or vecs.ndim != 2 or ids.shape[0] != vecs.shape[0]:
            raise ValueError(
                f"upsert wants ids (B,) + vecs (B, D), got {ids.shape} and "
                f"{vecs.shape}")
        if ids.size == 0:
            return np.empty((0,), np.int32)
        if (ids < 0).any():
            raise ValueError("upsert ids must be >= 0")
        if np.unique(ids).size != ids.size:
            raise ValueError("duplicate ids within one upsert batch")
        avals = None if attrs is None else np.asarray(attrs, np.int32)
        with self._mutate_lock:
            st = self._state
            assign, packed = ivf_mod.encode_rows(self.centroids,
                                                 self.codebook, vecs)
            shard = assign % self.num_shards
            local = assign // self.num_shards
            loc = dict(self._locate(st))
            lists_s = st.lists_s
            n_tomb = st.n_tombstones
            hit = [int(g) for g in ids if int(g) in loc]
            if hit:
                js = np.array([loc[g][0] for g in hit], np.int32)
                ls = np.array([loc[g][1] for g in hit], np.int32)
                ss = np.array([loc[g][2] for g in hit], np.int32)
                lists_s = lists_s._replace(
                    ids=lists_s.ids.at[js, ls, ss].set(-1),
                    attrs=(None if lists_s.attrs is None
                           else lists_s.attrs.at[js, ls, ss].set(-1)))
                for g in hit:
                    del loc[g]
                n_tomb += len(hit)
            # spare capacity: watermark + incoming per (shard, local list)
            # (NB ListStore.cap reads axis 1, which is L on this 3-D store)
            sizes = np.asarray(lists_s.sizes)
            inc = np.zeros(sizes.shape, np.int64)
            np.add.at(inc, (shard, local), 1)
            if (sizes + inc > lists_s.ids.shape[-1]).any():
                old_cap = lists_s.ids.shape[-1]
                need = int((sizes + inc).max())
                new_cap = -(-need // 8) * 8
                pad = new_cap - old_cap
                s_n = lists_s.ids.shape[0]
                l_n = lists_s.ids.shape[1]
                lists_s = ListStore(
                    codes=jnp.concatenate(
                        [lists_s.codes,
                         jnp.zeros((s_n, l_n, pad, lists_s.codes.shape[-1]),
                                   lists_s.codes.dtype)], axis=2),
                    ids=jnp.concatenate(
                        [lists_s.ids,
                         jnp.full((s_n, l_n, pad), -1, jnp.int32)], axis=2),
                    sizes=lists_s.sizes,
                    attrs=None if lists_s.attrs is None else jnp.concatenate(
                        [lists_s.attrs,
                         jnp.full((s_n, l_n, pad), -1, jnp.int32)], axis=2))
                ops_mod.clear_autotune_cache(nlist=l_n, cap=old_cap)
            # slot per row: list watermark + rank within the batch (same
            # order the single-host append uses — global-list batch order)
            b = ids.shape[0]
            order = np.argsort(assign, kind="stable")
            rank = np.empty(b, np.int64)
            sa = assign[order]
            rank[order] = np.arange(b) - np.searchsorted(sa, sa, side="left")
            slots = sizes[shard, local] + rank
            counts = np.zeros(sizes.shape, np.int32)
            np.add.at(counts, (shard, local), 1)

            base_s, gids_s, norms_s = st.base_s, st.gids_s, st.norms_s
            rows_used = st.rows_used
            if base_s is not None:
                # shard-local base rows: next free row per shard, in batch
                # order within the shard
                order_j = np.argsort(shard, kind="stable")
                rank_j = np.empty(b, np.int64)
                sj = shard[order_j]
                rank_j[order_j] = (np.arange(b)
                                   - np.searchsorted(sj, sj, side="left"))
                used = np.array(rows_used, np.int64)
                rows = used[shard] + rank_j
                r_cap = base_s.shape[1]
                if rows.max() >= r_cap:
                    old_r = r_cap
                    grown = -(-(int(rows.max()) + 1) // 256) * 256
                    pad_r = grown - r_cap
                    base_s = jnp.concatenate(
                        [base_s, jnp.zeros((base_s.shape[0], pad_r,
                                            base_s.shape[2]), base_s.dtype)],
                        axis=1)
                    gids_s = jnp.concatenate(
                        [gids_s, jnp.full((gids_s.shape[0], pad_r), -1,
                                          jnp.int32)], axis=1)
                    norms_s = jnp.concatenate(
                        [norms_s, jnp.zeros((norms_s.shape[0], pad_r),
                                            norms_s.dtype)], axis=1)
                    ops_mod.clear_autotune_cache(kind="rerank", n=old_r)
                vj = jnp.asarray(shard.astype(np.int32))
                vr = jnp.asarray(rows.astype(np.int32))
                vrows = jnp.asarray(vecs)
                base_s = base_s.at[vj, vr].set(vrows)
                gids_s = gids_s.at[vj, vr].set(
                    jnp.asarray(ids.astype(np.int32)))
                # same row-wise mul+sum as core.lists.base_norms
                norms_s = norms_s.at[vj, vr].set(
                    jnp.sum(vrows * vrows, axis=-1))
                np.add.at(used, shard, 1)
                rows_used = tuple(int(c) for c in used)
                slot_ids = rows.astype(np.int32)       # local row indices
            else:
                slot_ids = ids.astype(np.int32)        # global ids directly
            js = jnp.asarray(shard.astype(np.int32))
            ls = jnp.asarray(local.astype(np.int32))
            ks = jnp.asarray(slots.astype(np.int32))
            new_attrs = lists_s.attrs
            if new_attrs is not None:
                aa = (np.full(b, -1, np.int32) if avals is None else avals)
                new_attrs = new_attrs.at[js, ls, ks].set(jnp.asarray(aa))
            elif avals is not None:
                raise ValueError("attrs given but the store holds no attrs "
                                 "column")
            lists_s = ListStore(
                codes=lists_s.codes.at[js, ls, ks].set(jnp.asarray(packed)),
                ids=lists_s.ids.at[js, ls, ks].set(jnp.asarray(slot_ids)),
                sizes=lists_s.sizes + jnp.asarray(counts),
                attrs=new_attrs)
            for g, j, l, s in zip(ids.tolist(), shard.tolist(),
                                  local.tolist(), slots.tolist()):
                loc[int(g)] = (int(j), int(l), int(s))
            if self._wal is not None:
                # durable before visible (docs/persistence.md)
                self._wal.log_upsert(ids, vecs, avals)
            self._locator = loc
            self._state = _ShardState(
                centroids_s=st.centroids_s, lists_s=lists_s,
                real_s=st.real_s, base_s=base_s, gids_s=gids_s,
                norms_s=norms_s,
                live_s=(pack_filter_mask(lists_s.ids >= 0)
                        if n_tomb else None),
                rows_used=rows_used, epoch=st.epoch + 1,
                n_tombstones=n_tomb)
        return assign

    def delete(self, ids) -> int:
        """Tombstone rows by global id across shards; unknown ids ignored.
        Returns the number of rows deleted. Same contract as
        ``SearchEngine.delete``."""
        ids = np.unique(np.asarray(ids, np.int64))
        with self._mutate_lock:
            st = self._state
            loc = dict(self._locate(st))
            found = [int(g) for g in ids if int(g) in loc]
            if not found:
                return 0
            js = np.array([loc[g][0] for g in found], np.int32)
            ls = np.array([loc[g][1] for g in found], np.int32)
            ss = np.array([loc[g][2] for g in found], np.int32)
            lists_s = st.lists_s._replace(
                ids=st.lists_s.ids.at[js, ls, ss].set(-1),
                attrs=(None if st.lists_s.attrs is None
                       else st.lists_s.attrs.at[js, ls, ss].set(-1)))
            for g in found:
                del loc[g]
            if self._wal is not None:
                # no-op deletes returned above unlogged; replay re-derives
                # the same `found` set from the full batch
                self._wal.log_delete(ids)
            self._locator = loc
            self._state = st._replace(
                lists_s=lists_s,
                live_s=pack_filter_mask(lists_s.ids >= 0),
                epoch=st.epoch + 1,
                n_tombstones=st.n_tombstones + len(found))
            return len(found)

    def compact(self, cap: int | None = None) -> int:
        """Rebuild every shard's lists (and base slice) tombstone-free.

        Host-side like ``core.lists.compact_lists``, swapped in atomically.
        Survivors keep their relative slot order per list; when a base is
        held the shard's rows re-pack in order of appearance — exactly the
        ``partition_base`` convention — and R shrinks to the new max.
        Returns the number of tombstoned slots reclaimed.
        """
        with self._mutate_lock:
            st = self._state
            lids = np.asarray(st.lists_s.ids)          # (S, L, cap)
            codes = np.asarray(st.lists_s.codes)
            attrs = (None if st.lists_s.attrs is None
                     else np.asarray(st.lists_s.attrs))
            s_n, l_n, old_cap = lids.shape
            live = lids >= 0
            counts = live.sum(axis=2)                  # (S, L)
            new_cap = int(cap if cap is not None else old_cap)
            if new_cap < int(counts.max(initial=0)):
                raise ValueError(
                    f"compact: cap {new_cap} below the largest live list "
                    f"({int(counts.max(initial=0))})")
            n_codes = np.zeros((s_n, l_n, new_cap, codes.shape[-1]),
                               codes.dtype)
            n_ids = np.full((s_n, l_n, new_cap), -1, np.int32)
            n_attrs = (None if attrs is None
                       else np.full((s_n, l_n, new_cap), -1, np.int32))
            if st.base_s is not None:
                base = np.asarray(st.base_s)
                gids = np.asarray(st.gids_s)
                norms = np.asarray(st.norms_s)
                r_cap = max(1, -(-int(counts.sum(axis=1).max(initial=1))
                                 // 256) * 256)
                n_base = np.zeros((s_n, r_cap, base.shape[-1]), base.dtype)
                n_gids = np.full((s_n, r_cap), -1, np.int32)
                n_norms = np.zeros((s_n, r_cap), norms.dtype)
            rows_used = []
            for j in range(s_n):
                cursor = 0
                for l in range(l_n):
                    m = live[j, l]
                    c = int(counts[j, l])
                    n_codes[j, l, :c] = codes[j, l, m]
                    if attrs is not None:
                        n_attrs[j, l, :c] = attrs[j, l, m]
                    if st.base_s is None:
                        n_ids[j, l, :c] = lids[j, l, m]
                    else:
                        old_rows = lids[j, l, m]       # old local rows
                        new_rows = np.arange(cursor, cursor + c, dtype=np.int32)
                        n_ids[j, l, :c] = new_rows
                        n_base[j, new_rows] = base[j, old_rows]
                        n_gids[j, new_rows] = gids[j, old_rows]
                        n_norms[j, new_rows] = norms[j, old_rows]
                        cursor += c
                rows_used.append(cursor)
            lists_s = ListStore(
                codes=jnp.asarray(n_codes), ids=jnp.asarray(n_ids),
                sizes=jnp.asarray(counts.astype(np.int32)),
                attrs=None if n_attrs is None else jnp.asarray(n_attrs))
            if new_cap != old_cap:
                ops_mod.clear_autotune_cache(nlist=l_n, cap=old_cap)
            if st.base_s is not None and r_cap != np.asarray(st.base_s).shape[1]:
                ops_mod.clear_autotune_cache(kind="rerank",
                                             n=st.base_s.shape[1])
            reclaimed = st.n_tombstones
            if self._wal is not None:
                self._wal.log_compact(cap)
            self._locator = None
            self._state = st._replace(
                lists_s=lists_s,
                base_s=None if st.base_s is None else jnp.asarray(n_base),
                gids_s=st.gids_s if st.base_s is None else jnp.asarray(n_gids),
                norms_s=(None if st.base_s is None
                         else jnp.asarray(n_norms)),
                live_s=None, rows_used=tuple(rows_used),
                epoch=st.epoch + 1, n_tombstones=0)
            return reclaimed

    def search(self, queries: jax.Array, k: int = 10, *,
               nprobe: int | None = None, rerank_mult: int | None = None,
               filter_bits: jax.Array | None = None,
               namespaces: jax.Array | None = None,
               margin_tau: jax.Array | float | None = None,
               mesh: jax.sharding.Mesh | None = None) -> SearchResult:
        """Batched search with the distributed shard merge.

        Semantics note vs the unsharded engine: each shard probes ``nprobe``
        of *its own* lists, so up to S*nprobe lists are scanned in total —
        recall at a given nprobe is >= the single-shard engine's.

        ``filter_bits`` is the (nlist, W) bitmap over *global* list ids —
        it is resharded here per request (``partition_filter``, pure jnp) so
        callers never track the round-robin layout. ``namespaces`` (Q,) i32
        is replicated: each shard masks its own coarse selection with its
        slice of the membership table, so a tenant's query only ever probes
        (and only ever DMAs) the tenant's lists on every shard. See
        docs/filtering.md.

        ``margin_tau`` (scalar or (Q,), replicated) overrides the config's
        margin width for this request — only legal under the wrapped
        engine's ``probe_policy='margin'`` (docs/anytime.md). Each shard
        prunes against its own best centroid distance.
        """
        st = self._state  # ONE snapshot read: the whole search is one epoch
        q = queries[None] if queries.ndim == 1 else queries
        nprobe = self.config.nprobe if nprobe is None else nprobe
        r = self.config.rerank_mult if rerank_mult is None else rerank_mult
        if r and st.base_s is None:
            raise ValueError("exact re-rank requested but engine holds no "
                             "base vectors (build with keep_base=True)")
        if margin_tau is not None and self.config.probe_policy != "margin":
            raise ValueError(
                "margin_tau override given but probe_policy is "
                f"{self.config.probe_policy!r}; build the wrapped engine "
                "with EngineConfig(probe_policy='margin')")
        if self.config.probe_policy == "margin":
            tau = (self.config.margin_tau if margin_tau is None
                   else margin_tau)
            tau = jnp.asarray(tau, jnp.float32)
            if tau.ndim not in (0, 1) or (tau.ndim == 1
                                          and tau.shape != (q.shape[0],)):
                raise ValueError(
                    f"margin_tau must be a scalar or ({q.shape[0]},) per-"
                    f"query widths, got shape {tau.shape}")
        else:
            tau = None
        if namespaces is not None:
            if self.member_s is None:
                raise ValueError(
                    "per-query namespaces given but the wrapped engine was "
                    "built without a namespace table")
            namespaces = jnp.asarray(namespaces, jnp.int32)
        cap = st.lists_s.ids.shape[-1]
        if filter_bits is not None:
            if filter_bits.shape[1] * 8 < cap:
                raise ValueError(
                    f"filter_bits W={filter_bits.shape[1]} too narrow for "
                    f"cap={cap} — a grow may have changed cap; "
                    "re-derive filters from the live store")
            w = -(-cap // 8)
            fbits_s = partition_filter(
                jnp.asarray(filter_bits, jnp.uint8)[:, :w], self.num_shards)
        else:
            fbits_s = None
        member_s = self.member_s if namespaces is not None else None
        fn = functools.partial(_local_search, k=k, nprobe=nprobe, r=r,
                               scan_impl=self.config.scan_impl,
                               rerank_impl=self.config.rerank_impl,
                               remap=st.base_s is not None,
                               probe_policy=self.config.probe_policy,
                               early_exit=self.config.early_exit)
        base_ax = 0 if st.base_s is not None else None

        if mesh is None:
            # None args are empty pytrees: their in_axes entries are inert
            mvals, mids, stats = jax.vmap(
                fn, in_axes=(0, 0, 0, 0, None, base_ax, base_ax, 0, None, 0,
                             0, None, None),
                axis_name=AXIS,
            )(st.centroids_s, st.lists_s, st.real_s, st.gids_s,
              self.codebook, st.base_s, st.norms_s, member_s, q, fbits_s,
              st.live_s, namespaces, tau)
            # merge output is replicated across the shard axis; take shard 0
            return SearchResult(mvals[0], mids[0],
                                QueryStats(*(s[0] for s in stats)))

        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        if mesh.shape[AXIS] != self.num_shards:
            raise ValueError(
                f"mesh axis {AXIS!r} has {mesh.shape[AXIS]} devices but the "
                f"engine holds {self.num_shards} shards")

        def per_device(cen, lists, real, gids, cb, base, norms, mem, qq, fb,
                       lv, nss, tt):
            # each device owns exactly one shard => leading block dim is 1
            out_v, out_i, stt = fn(cen[0], jax.tree.map(lambda x: x[0], lists),
                                   real[0], gids[0], cb,
                                   None if base is None else base[0],
                                   None if norms is None else norms[0],
                                   None if mem is None else mem[0], qq,
                                   None if fb is None else fb[0],
                                   None if lv is None else lv[0], nss, tt)
            return (out_v[None], out_i[None],
                    jax.tree.map(lambda x: x[None], stt))

        base_spec = P() if st.base_s is None else P(AXIS)
        sharded = shard_map(
            per_device, mesh=mesh,
            in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(), base_spec,
                      base_spec, P(AXIS), P(), P(AXIS), P(AXIS), P(), P()),
            out_specs=(P(AXIS), P(AXIS), P(AXIS)),
            # jax has no replication rule for pallas_call (the 'stream'
            # scan/re-rank kernels); the merge replicates results itself via
            # all_gather, so skipping the static replication check is sound
            check_rep=False,
        )
        mvals, mids, stats = sharded(st.centroids_s, st.lists_s,
                                     st.real_s, st.gids_s, self.codebook,
                                     st.base_s, st.norms_s, member_s, q,
                                     fbits_s, st.live_s, namespaces, tau)
        return SearchResult(mvals[0], mids[0], QueryStats(*(s[0] for s in stats)))
