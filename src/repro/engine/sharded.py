"""Shard-parallel engine execution over a device mesh.

The database's posting lists are partitioned round-robin into S shards
(``core.lists.partition_lists``); every shard runs the same local pipeline —
flat coarse over *its* centroids, grouped 4-bit scan, optional exact re-rank —
and the shard-local top-k results meet in ``core.topk.distributed_topk``:
an all-gather of 2k scalars per device, then one final re-top-k. ids are
global throughout, so the merge needs no re-mapping.

Two drivers over the same per-shard function:
  - ``mesh=None``: ``jax.vmap`` with a named axis — S arbitrary, runs on one
    host; this is also how the merge is unit-tested.
  - ``mesh=...``: ``shard_map`` over a 1-D device mesh (axis ``"shards"``),
    one shard per device — the production layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import ivf as ivf_mod
from repro.core import topk as topk_mod
from repro.core.kmeans import pairwise_sqdist
from repro.core.lists import ListStore, partition_lists
from repro.engine import rerank as rerank_mod
from repro.engine.engine import EngineConfig, QueryStats, SearchEngine, SearchResult

AXIS = "shards"


def _local_search(centroids, lists: ListStore, real, codebook, base, q, *,
                  k: int, nprobe: int, r: int, scan_impl: str):
    """One shard's pipeline + the cross-shard merge. Runs under a named axis."""
    index = ivf_mod.IVFIndex(centroids=centroids, codebook=codebook, lists=lists)
    nprobe_local = min(nprobe, centroids.shape[0])
    coarse_d = pairwise_sqdist(q, centroids)
    _, probes = topk_mod.smallest_k(coarse_d, nprobe_local)
    dists, ids = ivf_mod.scan_probes(index, q, probes, impl=scan_impl)
    qq = dists.shape[0]
    vals, out_ids, reranked = rerank_mod.finalize_candidates(
        dists.reshape(qq, -1), ids.reshape(qq, -1), base, q, k, r)
    mvals, mids = topk_mod.distributed_topk(vals, out_ids, k, AXIS)
    stats = QueryStats(
        # count only probes of real lists — a shard with fewer real lists
        # than nprobe inevitably "probes" padding, which is zero work
        lists_probed=jax.lax.psum(
            jnp.sum(real[probes].astype(jnp.int32), axis=1), AXIS),
        codes_scanned=jax.lax.psum(
            jnp.sum(lists.probed_sizes(probes), axis=1), AXIS),
        reranked=jax.lax.psum(reranked, AXIS),
    )
    return mvals, mids, stats


class ShardedEngine:
    """A ``SearchEngine`` whose lists are partitioned across S shards.

    Note: every shard selects probes with *flat* brute-force coarse over its
    local centroids (each shard holds only nlist/S of them, so the wrapped
    engine's HNSW/tree coarse structure does not partition); the wrapped
    engine's coarse quantizer is intentionally not carried over.

    Known limit: ``base`` (for re-rank) is replicated to every shard, so the
    re-rank path is O(N*D) per device. Partitioning base rows by shard
    list-membership is a ROADMAP item; until then, paper-scale sharded
    deployments should re-rank on the caller after the merge or run with
    rerank_mult=0.
    """

    def __init__(self, engine: SearchEngine, num_shards: int):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        self.num_shards = int(num_shards)
        self.codebook = engine.index.codebook
        self.base = engine.base
        self.config = engine.config
        self.centroids_s, self.lists_s, self.real_s = partition_lists(
            engine.index.lists, engine.index.centroids, self.num_shards)

    def search(self, queries: jax.Array, k: int = 10, *,
               nprobe: int | None = None, rerank_mult: int | None = None,
               mesh: jax.sharding.Mesh | None = None) -> SearchResult:
        """Batched search with the distributed shard merge.

        Semantics note vs the unsharded engine: each shard probes ``nprobe``
        of *its own* lists, so up to S*nprobe lists are scanned in total —
        recall at a given nprobe is >= the single-shard engine's.
        """
        q = queries[None] if queries.ndim == 1 else queries
        nprobe = self.config.nprobe if nprobe is None else nprobe
        r = self.config.rerank_mult if rerank_mult is None else rerank_mult
        if r and self.base is None:
            raise ValueError("exact re-rank requested but engine holds no "
                             "base vectors (build with keep_base=True)")
        fn = functools.partial(_local_search, k=k, nprobe=nprobe, r=r,
                               scan_impl=self.config.scan_impl)

        if mesh is None:
            mvals, mids, stats = jax.vmap(
                fn, in_axes=(0, 0, 0, None, None, None), axis_name=AXIS,
            )(self.centroids_s, self.lists_s, self.real_s, self.codebook,
              self.base, q)
            # merge output is replicated across the shard axis; take shard 0
            return SearchResult(mvals[0], mids[0],
                                QueryStats(*(s[0] for s in stats)))

        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        if mesh.shape[AXIS] != self.num_shards:
            raise ValueError(
                f"mesh axis {AXIS!r} has {mesh.shape[AXIS]} devices but the "
                f"engine holds {self.num_shards} shards")

        def per_device(cen, lists, real, cb, base, qq):
            # each device owns exactly one shard => leading block dim is 1
            out_v, out_i, st = fn(cen[0], jax.tree.map(lambda x: x[0], lists),
                                  real[0], cb, base, qq)
            return out_v[None], out_i[None], jax.tree.map(lambda x: x[None], st)

        sharded = shard_map(
            per_device, mesh=mesh,
            in_specs=(P(AXIS), P(AXIS), P(AXIS), P(), P(), P()),
            out_specs=(P(AXIS), P(AXIS), P(AXIS)),
        )
        mvals, mids, stats = sharded(self.centroids_s, self.lists_s,
                                     self.real_s, self.codebook, self.base, q)
        return SearchResult(mvals[0], mids[0], QueryStats(*(s[0] for s in stats)))
