"""Shard-parallel engine execution over a device mesh.

The database's posting lists are partitioned round-robin into S shards
(``core.lists.partition_lists``); every shard runs the same local pipeline —
flat coarse over *its* centroids, grouped 4-bit scan, optional exact re-rank —
and the shard-local top-k results meet in ``core.topk.distributed_topk``:
an all-gather of 2k scalars per device, then one final re-top-k.

Base vectors for the exact re-rank are sharded too (``core.lists.
partition_base``): each shard holds only the (R, D) rows of the lists it
owns, with posting-list ids remapped to shard-local rows; results map back
to global ids via the shard's ``gids`` table just before the merge, so the
2k-scalar merge still needs no re-mapping.

Two drivers over the same per-shard function:
  - ``mesh=None``: ``jax.vmap`` with a named axis — S arbitrary, runs on one
    host; this is also how the merge is unit-tested.
  - ``mesh=...``: ``shard_map`` over a 1-D device mesh (axis ``"shards"``),
    one shard per device — the production layout.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import ivf as ivf_mod
from repro.core import topk as topk_mod
from repro.core.kmeans import pairwise_sqdist
from repro.core.lists import (ListStore, filter_pass_sizes, partition_base,
                              partition_filter, partition_lists,
                              round_robin_perm)
from repro.engine import rerank as rerank_mod
from repro.engine.engine import (EngineConfig, QueryStats, SearchEngine,
                                 SearchResult, scan_candidates)

AXIS = "shards"


def _local_search(centroids, lists: ListStore, real, gids, codebook, base,
                  norms, member, q, fbits, ns, *, k: int, nprobe: int, r: int,
                  scan_impl: str, rerank_impl: str, remap: bool):
    """One shard's pipeline + the cross-shard merge. Runs under a named axis.

    With ``remap=True`` the shard's list ids are *local* rows into its own
    ``base`` slice (see ``partition_base``): the scan and re-rank both work
    on local ids and ``gids`` translates back to global just before the
    distributed merge. With ``remap=False`` (no base held) ids are global
    throughout and ``gids``/``norms`` are unused dummies.

    ``member`` is the shard's (n_ns, L) slice of the namespace table,
    ``fbits`` its (L, W) slice of the per-request filter bitmap, ``ns`` the
    replicated (Q,) namespace ids — any may be None (docs/filtering.md).
    A restricted query selects probes with ``masked_topk`` over its own
    lists only; padding lists are member-False everywhere, and with every
    query unrestricted the mask is all-True so the selection is exactly
    ``smallest_k`` — bit-identical to the namespace-free driver.
    """
    index = ivf_mod.IVFIndex(centroids=centroids, codebook=codebook, lists=lists)
    nprobe_local = min(nprobe, centroids.shape[0])
    coarse_d = pairwise_sqdist(q, centroids)
    if member is not None and ns is not None:
        allow = (ns < 0)[:, None] | member[jnp.maximum(ns, 0)]
        _, probes = topk_mod.masked_topk(coarse_d, allow, nprobe_local)
    else:
        _, probes = topk_mod.smallest_k(coarse_d, nprobe_local)
    # same stage function as the single-host engine, including its stream
    # routing: each shard's local ListStore already has the
    # (nlist_local, cap, M//2) layout the stream kernel scans in place, so a
    # 'stream' (or 'auto'-resolved-to-stream) shard never materializes its
    # gathered code copy either
    flat_d, flat_ids = scan_candidates(index, q, probes, scan_impl=scan_impl,
                                       keep=(r * k) if r else k,
                                       filter_bits=fbits)
    # re-rank (either impl) runs on the shard-local (R, D) base slice with
    # its precomputed local norms; local candidate ids map back to global
    # through gids only after the top-k, just before the merge
    vals, out_ids, reranked = rerank_mod.finalize_candidates(
        flat_d, flat_ids, base, q, k, r, norms=norms, rerank_impl=rerank_impl)
    if remap:
        out_ids = jnp.where(out_ids >= 0, gids[jnp.maximum(out_ids, 0)], -1)
    mvals, mids = topk_mod.distributed_topk(vals, out_ids, k, AXIS)
    valid = probes >= 0
    safe = jnp.maximum(probes, 0)
    if fbits is None:
        rows_filtered = jnp.zeros((q.shape[0],), jnp.int32)
    else:
        dropped = lists.sizes - filter_pass_sizes(lists, fbits)
        rows_filtered = jnp.sum(jnp.where(valid, dropped[safe], 0), axis=1)
    stats = QueryStats(
        # count only probes of real lists — a shard with fewer real lists
        # than nprobe inevitably "probes" padding, which is zero work
        lists_probed=jax.lax.psum(
            jnp.sum((real[safe] & valid).astype(jnp.int32), axis=1), AXIS),
        codes_scanned=jax.lax.psum(
            jnp.sum(lists.probed_sizes(probes), axis=1), AXIS),
        reranked=jax.lax.psum(reranked, AXIS),
        rows_filtered=jax.lax.psum(rows_filtered, AXIS),
    )
    return mvals, mids, stats


class ShardedEngine:
    """A ``SearchEngine`` whose lists are partitioned across S shards.

    Note: every shard selects probes with *flat* brute-force coarse over its
    local centroids (each shard holds only nlist/S of them, so the wrapped
    engine's HNSW/tree coarse structure does not partition); the wrapped
    engine's coarse quantizer is intentionally not carried over.

    When the wrapped engine holds base vectors, they are partitioned by
    shard list-membership (``partition_base``): each shard's re-rank reads
    only its own (R, D) slice, R ~= N/S, instead of a replicated (N, D)
    copy — with the per-row ‖x‖² norms (``norms_s``) partitioned alongside
    for the norms+GEMM formulation, so a 'stream' re-rank shard DMAs
    candidate rows straight out of its local slice. Shard-local ListStore
    ids become local row indices; ``gids_s`` maps them back to global ids
    after the per-shard pipeline.
    """

    def __init__(self, engine: SearchEngine, num_shards: int):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        self.num_shards = int(num_shards)
        self.codebook = engine.index.codebook
        self.config = engine.config
        self.centroids_s, self.lists_s, self.real_s = partition_lists(
            engine.index.lists, engine.index.centroids, self.num_shards)
        if engine.base is not None:
            self.base_s, self.gids_s, local_ids, self.norms_s = partition_base(
                self.lists_s, engine.base)
            self.lists_s = self.lists_s._replace(ids=local_ids)
        else:
            self.base_s = None
            # unused dummies so both vmap and shard_map see a uniform arity
            self.gids_s = jnp.full((self.num_shards, 1), -1, jnp.int32)
            self.norms_s = None
        # namespace membership sharded with the same round-robin permutation
        # as the lists: shard j's (n_ns, L) slice covers exactly its lists;
        # padding lists are member-False for every namespace
        if engine.ns_member is None:
            self.member_s = None
        else:
            member = jnp.asarray(engine.ns_member, bool)
            nlist = member.shape[1]
            s = self.num_shards
            l = -(-nlist // s)
            pad = s * l - nlist
            if pad:
                member = jnp.concatenate(
                    [member, jnp.zeros((member.shape[0], pad), bool)], axis=1)
            perm = jnp.asarray(round_robin_perm(nlist, s))
            self.member_s = (member[:, perm]
                             .reshape(member.shape[0], s, l)
                             .transpose(1, 0, 2))  # (S, n_ns, L)

    @property
    def base(self) -> jax.Array | None:
        """Sharded base slices (S, R, D), or None when no base is held."""
        return self.base_s

    def search(self, queries: jax.Array, k: int = 10, *,
               nprobe: int | None = None, rerank_mult: int | None = None,
               filter_bits: jax.Array | None = None,
               namespaces: jax.Array | None = None,
               mesh: jax.sharding.Mesh | None = None) -> SearchResult:
        """Batched search with the distributed shard merge.

        Semantics note vs the unsharded engine: each shard probes ``nprobe``
        of *its own* lists, so up to S*nprobe lists are scanned in total —
        recall at a given nprobe is >= the single-shard engine's.

        ``filter_bits`` is the (nlist, W) bitmap over *global* list ids —
        it is resharded here per request (``partition_filter``, pure jnp) so
        callers never track the round-robin layout. ``namespaces`` (Q,) i32
        is replicated: each shard masks its own coarse selection with its
        slice of the membership table, so a tenant's query only ever probes
        (and only ever DMAs) the tenant's lists on every shard. See
        docs/filtering.md.
        """
        q = queries[None] if queries.ndim == 1 else queries
        nprobe = self.config.nprobe if nprobe is None else nprobe
        r = self.config.rerank_mult if rerank_mult is None else rerank_mult
        if r and self.base_s is None:
            raise ValueError("exact re-rank requested but engine holds no "
                             "base vectors (build with keep_base=True)")
        if namespaces is not None:
            if self.member_s is None:
                raise ValueError(
                    "per-query namespaces given but the wrapped engine was "
                    "built without a namespace table")
            namespaces = jnp.asarray(namespaces, jnp.int32)
        if filter_bits is not None:
            fbits_s = partition_filter(jnp.asarray(filter_bits, jnp.uint8),
                                       self.num_shards)
        else:
            fbits_s = None
        member_s = self.member_s if namespaces is not None else None
        fn = functools.partial(_local_search, k=k, nprobe=nprobe, r=r,
                               scan_impl=self.config.scan_impl,
                               rerank_impl=self.config.rerank_impl,
                               remap=self.base_s is not None)
        base_ax = 0 if self.base_s is not None else None

        if mesh is None:
            # None args are empty pytrees: their in_axes entries are inert
            mvals, mids, stats = jax.vmap(
                fn, in_axes=(0, 0, 0, 0, None, base_ax, base_ax, 0, None, 0,
                             None),
                axis_name=AXIS,
            )(self.centroids_s, self.lists_s, self.real_s, self.gids_s,
              self.codebook, self.base_s, self.norms_s, member_s, q, fbits_s,
              namespaces)
            # merge output is replicated across the shard axis; take shard 0
            return SearchResult(mvals[0], mids[0],
                                QueryStats(*(s[0] for s in stats)))

        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        if mesh.shape[AXIS] != self.num_shards:
            raise ValueError(
                f"mesh axis {AXIS!r} has {mesh.shape[AXIS]} devices but the "
                f"engine holds {self.num_shards} shards")

        def per_device(cen, lists, real, gids, cb, base, norms, mem, qq, fb,
                       nss):
            # each device owns exactly one shard => leading block dim is 1
            out_v, out_i, st = fn(cen[0], jax.tree.map(lambda x: x[0], lists),
                                  real[0], gids[0], cb,
                                  None if base is None else base[0],
                                  None if norms is None else norms[0],
                                  None if mem is None else mem[0], qq,
                                  None if fb is None else fb[0], nss)
            return out_v[None], out_i[None], jax.tree.map(lambda x: x[None], st)

        base_spec = P() if self.base_s is None else P(AXIS)
        sharded = shard_map(
            per_device, mesh=mesh,
            in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(), base_spec,
                      base_spec, P(AXIS), P(), P(AXIS), P()),
            out_specs=(P(AXIS), P(AXIS), P(AXIS)),
            # jax has no replication rule for pallas_call (the 'stream'
            # scan/re-rank kernels); the merge replicates results itself via
            # all_gather, so skipping the static replication check is sound
            check_rep=False,
        )
        mvals, mids, stats = sharded(self.centroids_s, self.lists_s,
                                     self.real_s, self.gids_s, self.codebook,
                                     self.base_s, self.norms_s, member_s, q,
                                     fbits_s, namespaces)
        return SearchResult(mvals[0], mids[0], QueryStats(*(s[0] for s in stats)))
