"""Exact re-ranking stage: refine quantized-scan candidates with true floats.

Quicker ADC (André et al.) and KScaNN both stack an exact refinement pass on
top of the fast quantized scan: the 4-bit ADC orders candidates *almost*
right, so recomputing true distances for only the top r·k survivors recovers
nearly all the recall lost to quantization at a tiny fraction of brute-force
cost. This module is that pass, batched and jit-friendly (static shapes,
-1-padded candidate sets).

Two implementations, selected by ``rerank_impl`` (registry:
``kernels.ops.RERANK_IMPLS``), bit-identical through every search path:

  'gathered'  gather the candidate rows to a (Q, R, D) copy and compute
              distances with the norms+GEMM formulation
              ``(‖q‖² − 2·q·x) + ‖x‖²`` — no broadcast-subtraction
              intermediate, the dot contracts on the MXU;
  'stream'    gather-free: the Pallas kernel ``kernels.rerank_kernel``
              DMAs only the candidate rows out of the in-place HBM base
              (double-buffered) and reduces to the final top-k in VMEM, so
              only (Q, k) survivors reach HBM;
  'auto'      timed dispatch between the two, cached alongside the scan
              verdicts (``kernels.ops.resolve_rerank_impl``).

Both use precomputed per-row base norms (``core.lists.base_norms``) and the
shared distance helper ``rerank_kernel.norms_gemm_dists``, which is what
keeps them bit-identical (see that module's docstring for the rounding
argument).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import topk as topk_mod
from repro.core.lists import base_norms
from repro.kernels import ops
from repro.kernels.rerank_kernel import norms_gemm_dists


@jax.jit
def exact_distances(base: jax.Array, q: jax.Array, cand_ids: jax.Array,
                    norms: jax.Array | None = None) -> jax.Array:
    """True squared-L2 from each query to its candidates, via norms+GEMM.

    base: (N, D); q: (Q, D); cand_ids: (Q, R) int32, -1 = padding;
    norms: optional precomputed ``base_norms(base)`` (N,) f32 (derived here
    when absent — engines pass their cached copy).
    Returns (Q, R) f32 with +inf at padded slots.

    ``d = (‖q‖² − 2·q·x) + ‖x‖²`` instead of ``Σ(q − x)²``: algebraically
    equal, but the row norms come precomputed, the dot is a GEMM, and no
    (Q, R, D) broadcast-subtraction intermediate is materialized — only the
    row gather itself remains (the 'stream' impl removes that too).
    Guarded by a tolerance-zero parity test against the subtraction form on
    integer-valued data, where f32 arithmetic is exact for both
    (tests/test_stream_rerank.py).
    """
    if norms is None:
        norms = base_norms(base)
    safe = jnp.maximum(cand_ids, 0)
    vecs = base[safe]                                      # (Q, R, D)
    d = norms_gemm_dists(q, vecs, norms[safe])             # (Q, R)
    return jnp.where(cand_ids >= 0, d, jnp.inf)


def finalize_candidates(flat_d: jax.Array, flat_ids: jax.Array,
                        base: jax.Array | None, q: jax.Array, k: int, r: int,
                        *, norms: jax.Array | None = None,
                        rerank_impl: str = "gathered"
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Stages 3+4 for one candidate pool: optional exact re-rank, final top-k.

    flat_d/flat_ids: (Q, C) quantized candidate distances/ids (-1 = padding).
    r > 0 refines the top r*k candidates with true distances from ``base``
    via ``rerank_impl`` ('gathered' | 'stream' | 'auto' — resolved here at
    trace time, like the scan dispatch).
    Returns (dists (Q, k), ids (Q, k), reranked (Q,) i32 work counter).
    Shared by the single-host engine and the per-shard pipeline so the two
    paths cannot drift.
    """
    if r:
        rr = min(r * k, flat_d.shape[1])
        _, pos = topk_mod.masked_topk(flat_d, flat_ids >= 0, rr)
        cand_ids = topk_mod.gather_ids(flat_ids, pos)
        impl, tile_r = ops.resolve_rerank_dispatch(
            rerank_impl, flat_d.shape[0], rr, q.shape[-1], k, base.shape[0])
        if impl == "stream":
            if norms is None:
                norms = base_norms(base)
            vals, out_ids = ops.rerank_stream_topk(base, norms, q, cand_ids,
                                                   k=k, tile_r=tile_r)
        else:
            vals, out_ids = exact_rerank(base, q, cand_ids, k, norms=norms)
        reranked = jnp.sum((cand_ids >= 0).astype(jnp.int32), axis=1)
    else:
        vals, pos = topk_mod.masked_topk(flat_d, flat_ids >= 0, k)
        out_ids = topk_mod.gather_ids(flat_ids, pos)
        reranked = jnp.zeros((flat_d.shape[0],), jnp.int32)
    return vals, out_ids, reranked


@functools.partial(jax.jit, static_argnames=("k",))
def exact_rerank(base: jax.Array, q: jax.Array, cand_ids: jax.Array, k: int,
                 *, norms: jax.Array | None = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Re-rank candidates by true distance, keep the best k (gathered impl).

    Returns (dists (Q, k) f32 ascending, ids (Q, k) i32, -1 past the valid
    candidate count). Candidate ids are unique by construction (each base
    vector lives in exactly one IVF list), so no dedup pass is needed. The
    semantic oracle the streaming kernel is held bit-identical to.
    """
    d = exact_distances(base, q, cand_ids, norms)
    vals, pos = topk_mod.masked_topk(d, cand_ids >= 0, k)
    return vals, topk_mod.gather_ids(cand_ids, pos)
