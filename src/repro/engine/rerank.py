"""Exact re-ranking stage: refine quantized-scan candidates with true floats.

Quicker ADC (André et al.) and KScaNN both stack an exact refinement pass on
top of the fast quantized scan: the 4-bit ADC orders candidates *almost*
right, so recomputing true distances for only the top r·k survivors recovers
nearly all the recall lost to quantization at a tiny fraction of brute-force
cost. This module is that pass, batched and jit-friendly (static shapes,
-1-padded candidate sets).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import topk as topk_mod


@jax.jit
def exact_distances(base: jax.Array, q: jax.Array, cand_ids: jax.Array
                    ) -> jax.Array:
    """True squared-L2 from each query to its candidates.

    base: (N, D); q: (Q, D); cand_ids: (Q, R) int32, -1 = padding.
    Returns (Q, R) f32 with +inf at padded slots.
    """
    vecs = base[jnp.maximum(cand_ids, 0)]                  # (Q, R, D)
    d = jnp.sum((vecs - q[:, None, :]) ** 2, axis=-1)
    return jnp.where(cand_ids >= 0, d, jnp.inf)


def finalize_candidates(flat_d: jax.Array, flat_ids: jax.Array,
                        base: jax.Array | None, q: jax.Array, k: int, r: int
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Stages 3+4 for one candidate pool: optional exact re-rank, final top-k.

    flat_d/flat_ids: (Q, C) quantized candidate distances/ids (-1 = padding).
    r > 0 refines the top r*k candidates with true distances from ``base``.
    Returns (dists (Q, k), ids (Q, k), reranked (Q,) i32 work counter).
    Shared by the single-host engine and the per-shard pipeline so the two
    paths cannot drift.
    """
    if r:
        rr = min(r * k, flat_d.shape[1])
        _, pos = topk_mod.masked_topk(flat_d, flat_ids >= 0, rr)
        cand_ids = topk_mod.gather_ids(flat_ids, pos)
        vals, out_ids = exact_rerank(base, q, cand_ids, k)
        reranked = jnp.sum((cand_ids >= 0).astype(jnp.int32), axis=1)
    else:
        vals, pos = topk_mod.masked_topk(flat_d, flat_ids >= 0, k)
        out_ids = topk_mod.gather_ids(flat_ids, pos)
        reranked = jnp.zeros((flat_d.shape[0],), jnp.int32)
    return vals, out_ids, reranked


@functools.partial(jax.jit, static_argnames=("k",))
def exact_rerank(base: jax.Array, q: jax.Array, cand_ids: jax.Array, k: int
                 ) -> tuple[jax.Array, jax.Array]:
    """Re-rank candidates by true distance, keep the best k.

    Returns (dists (Q, k) f32 ascending, ids (Q, k) i32, -1 past the valid
    candidate count). Candidate ids are unique by construction (each base
    vector lives in exactly one IVF list), so no dedup pass is needed.
    """
    d = exact_distances(base, q, cand_ids)
    vals, pos = topk_mod.masked_topk(d, cand_ids >= 0, k)
    return vals, topk_mod.gather_ids(cand_ids, pos)
