"""Unified batched search engine: coarse -> 4-bit fast-scan -> exact re-rank.

The single query path a server calls, composing the pieces that previously
lived disconnected across ``core``:

  1. coarse: pluggable probe selection over the IVF centroids — flat
     brute-force, HNSW graph routing (paper Table 1), or k-means tree;
  2. scan: the 4-bit fast-scan ADC over the probed posting lists
     (``core.ivf.scan_probes``, grouped Pallas kernel underneath; with
     ``scan_impl='stream'`` the codes are scanned *in place* with fused
     candidate reduction — no gathered copy, no full distance writeback);
  3. re-rank: exact float refinement of the top ``rerank_mult * k``
     quantized candidates (``engine.rerank``), Quicker-ADC style;
  4. merge: final masked top-k (single host) or the distributed 2k-scalar
     shard merge (``engine.sharded`` over ``core.topk.distributed_topk``).

Every stage is a *pure function* of (coarse pytree, index pytree, arrays) —
see ``coarse_probes`` / ``scan_candidates`` / ``make_stats`` — and the engine
offers two compositions of the same stage functions:

  - ``SearchEngine.search``      staged: each stage dispatches on its own
    (stages are individually jit'd); convenient for debugging and for
    composing custom pipelines by hand.
  - ``SearchEngine.search_jit``  fused: the whole pipeline in ONE ``jax.jit``
    with ``(k, nprobe, rerank_mult, scan_impl, ef)`` static. One XLA program,
    one dispatch — the serving path (``repro.serving``). Results are
    bit-identical to the staged path (tested).

Because the fused jit lives at module level, its compile cache is shared by
every engine in the process and keyed only on static knobs + input shapes:
steady-state serving over a fixed set of batch-shape buckets never
recompiles. ``fused_cache_size()`` exposes the cache occupancy so tests and
serving metrics can assert "at most one compile per shape bucket".

A ``QueryStats`` record rides along for observability: how many lists were
probed, codes scanned, candidates re-ranked, rows filtered out — per query.

Filtered & namespaced search (docs/filtering.md): ``search``/``search_jit``
accept an optional packed per-row predicate bitmap (``filter_bits``) that the
stream kernels apply inside their per-tile VMEM reductions, and optional
per-query ``namespaces`` that restrict coarse probe *selection* to the
tenant's own lists. Both are traced arguments — predicate/tenant churn never
recompiles the fused pipeline.
"""
from __future__ import annotations

import functools
import threading
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coarse as coarse_mod
from repro.core import ivf as ivf_mod
from repro.core import lists as lists_mod
from repro.core import topk as topk_mod
from repro.core.kmeans import pairwise_sqdist
from repro.core.lists import (base_norms, filter_pass_sizes, filter_words,
                              unpack_filter_mask)
from repro.engine import rerank as rerank_mod
# single source of truth for both registries (kernels.ops)
from repro.kernels import ops as ops_mod
from repro.kernels.ops import RERANK_IMPLS, SCAN_IMPLS

COARSE_KINDS = ("flat", "hnsw", "tree")


class EngineConfig(NamedTuple):
    """Static search-time knobs (all shapes derive from these => jit-stable)."""

    nprobe: int = 8         # lists scanned per query (the MAX under 'margin')
    rerank_mult: int = 0    # refine rerank_mult*k candidates exactly; 0 = off
    scan_impl: str = "ref"  # grouped ADC impl: 'ref' | 'select' | 'mxu' |
    #                         'stream' (gather-free in-kernel list DMA) |
    #                         'auto' (autotuned; see kernels.ops.SCAN_IMPLS)
    ef: int = 64            # HNSW beam width (hnsw coarse only)
    rerank_impl: str = "gathered"  # exact re-rank impl: 'gathered' |
    #                         'stream' (gather-free in-kernel row DMA) |
    #                         'auto' (see kernels.ops.RERANK_IMPLS)
    probe_policy: str = "fixed"  # 'fixed' (always nprobe lists) | 'margin'
    #                         (adaptive nprobe, docs/anytime.md: drop probes
    #                         whose coarse distance exceeds (1 + tau) x the
    #                         query's best — nprobe becomes a per-query MAX)
    margin_tau: float = float("inf")  # 'margin' width; traced at search time
    #                         (per-request overrides never recompile); +inf
    #                         keeps every probe (bit-identical to 'fixed')
    early_exit: bool = False  # anytime tile pruning inside the stream scan
    #                         kernel (docs/anytime.md); lossless for the
    #                         final top-k, no-op on gathered impls


_EF_DEFAULT = EngineConfig._field_defaults["ef"]
PROBE_POLICIES = ("fixed", "margin")
# valid-probe fraction the autotune sweep assumes under a margin policy: the
# 'auto' verdict for an adaptive workload is timed (and cached) against a
# probe set with this fill instead of a dense one (kernels.ops).
MARGIN_PROBE_FILL = 0.5


class QueryStats(NamedTuple):
    """Per-query work counters threaded through the pipeline."""

    lists_probed: jax.Array   # (Q,) i32  valid probes issued
    codes_scanned: jax.Array  # (Q,) i32  true occupancy of scanned lists
    reranked: jax.Array       # (Q,) i32  candidates refined exactly
    rows_filtered: jax.Array  # (Q,) i32  probed LIVE rows the user filter
    #                           excluded (0 when no filter was supplied;
    #                           namespace-excluded LISTS never appear in any
    #                           counter — their probes are -1, so nothing was
    #                           scanned)
    rows_tombstoned: jax.Array  # (Q,) i32  probed slots inside the watermark
    #                           holding deleted rows (docs/mutability.md);
    #                           always 0 on an unmutated engine
    lists_pruned: jax.Array   # (Q,) i32  coarse probes the margin policy
    #                           dropped (docs/anytime.md); 0 under 'fixed'
    tiles_skipped: jax.Array  # (Q,) i32  valid-probe cap tiles the stream
    #                           kernel's early exit proved irrelevant; 0
    #                           without early_exit or on gathered impls


class SearchResult(NamedTuple):
    dists: jax.Array  # (Q, k) f32 ascending
    ids: jax.Array    # (Q, k) i32 global ids, -1 = no candidate
    stats: QueryStats


def validate_config(config: EngineConfig, *, coarse_kind: str,
                    has_base: bool) -> None:
    """Reject nonsense config/coarse combinations at construction time.

    Raises ``ValueError`` on knobs that would otherwise be silently ignored
    (``ef`` without HNSW coarse) or blow up on the first search
    (``rerank_mult > 0`` without base vectors, unknown ``scan_impl``).
    """
    if config.nprobe < 1:
        raise ValueError(f"EngineConfig.nprobe must be >= 1, got {config.nprobe}")
    if config.rerank_mult < 0:
        raise ValueError(
            f"EngineConfig.rerank_mult must be >= 0, got {config.rerank_mult}")
    if config.scan_impl not in SCAN_IMPLS:
        raise ValueError(f"EngineConfig.scan_impl {config.scan_impl!r} unknown; "
                         f"want one of {SCAN_IMPLS}")
    if config.rerank_impl not in RERANK_IMPLS:
        raise ValueError(
            f"EngineConfig.rerank_impl {config.rerank_impl!r} unknown; "
            f"want one of {RERANK_IMPLS}")
    if config.probe_policy not in PROBE_POLICIES:
        raise ValueError(
            f"EngineConfig.probe_policy {config.probe_policy!r} unknown; "
            f"want one of {PROBE_POLICIES}")
    if config.margin_tau is None or not config.margin_tau >= 0:  # rejects NaN
        raise ValueError(
            f"EngineConfig.margin_tau must be >= 0, got {config.margin_tau}")
    if config.ef < 1:
        raise ValueError(f"EngineConfig.ef must be >= 1, got {config.ef}")
    if config.ef != _EF_DEFAULT and coarse_kind != "hnsw":
        raise ValueError(
            f"EngineConfig.ef={config.ef} is set but coarse={coarse_kind!r}; "
            "ef is the HNSW beam width and is ignored by every other coarse "
            "quantizer — drop it or build with coarse='hnsw'")
    if config.rerank_mult > 0 and not has_base:
        raise ValueError(
            f"EngineConfig.rerank_mult={config.rerank_mult} requires the raw "
            "base vectors for exact re-rank, but the engine holds none "
            "(build with keep_base=True or pass base=...)")


# ---------------------------------------------------------------------------
# stages — pure functions of (coarse/index pytrees, arrays, static ints).
# ``search`` composes them eagerly stage-by-stage; ``_fused_pipeline`` traces
# the very same functions into one XLA program.
# ---------------------------------------------------------------------------

def coarse_probes(coarse, q: jax.Array, *, nprobe: int, ef: int,
                  ns_member: jax.Array | None = None,
                  namespaces: jax.Array | None = None,
                  probe_policy: str = "fixed",
                  margin_tau: jax.Array | float | None = None
                  ) -> tuple[jax.Array, jax.Array]:
    """Stage 1 — coarse: pick the most promising lists, up to nprobe.

    coarse: any of the ``core.coarse`` quantizer pytrees (or a custom object
    with ``.search(q, nprobe)``). q: (Q, D) f32. Returns
    (probes (Q, nprobe) i32 list ids with -1 = no probe,
    lists_pruned (Q,) i32 — probes the adaptive policy dropped).

    Namespacing (docs/filtering.md): ``ns_member`` is the engine-held
    (n_ns, nlist) bool membership table and ``namespaces`` the per-query
    (Q,) i32 namespace ids (-1 = unrestricted). For flat coarse the
    restriction is fused into probe *selection* (``masked_topk`` over the
    allowed lists), so a tenant scan only ever touches its own lists; graph/
    tree coarse post-masks the routed probes to -1 (they may under-fill
    nprobe, never over-reach). With every query unrestricted the flat path
    is exactly ``smallest_k`` — bit-identical to the namespace-free engine.

    Adaptive nprobe (docs/anytime.md): with ``probe_policy='margin'`` the
    coarse distances every quantizer already returns feed
    ``core.topk.margin_prune_probes`` — a probe survives only while its
    centroid distance is within ``(1 + margin_tau) x`` the query's best, so
    ``nprobe`` becomes a per-query *maximum* and easy (large-margin) queries
    scan fewer lists. ``margin_tau`` is traced (scalar or (Q,)):
    per-request budgets never recompile. ``margin_tau=None`` or ``+inf``
    keeps every probe — bit-identical to ``'fixed'``. The probe mask keeps
    its static (Q, nprobe) shape; pruned slots are the ``-1`` sentinel the
    stream kernels skip without touching HBM.
    """
    restrict = ns_member is not None and namespaces is not None
    if restrict:
        # (Q, nlist) bool: True where query may probe the list
        allow = ((namespaces < 0)[:, None]
                 | ns_member[jnp.maximum(namespaces, 0)])
    if isinstance(coarse, coarse_mod.FlatCoarse) and restrict:
        coarse_d = pairwise_sqdist(q, coarse.centroids)
        vals, probes = topk_mod.masked_topk(coarse_d, allow, nprobe)
    else:
        if isinstance(coarse, coarse_mod.HNSWCoarse):
            vals, probes = coarse.search(q, nprobe, ef=max(ef, nprobe))
        else:
            vals, probes = coarse.search(q, nprobe)
        if restrict:
            ok = jnp.take_along_axis(allow, jnp.maximum(probes, 0), axis=1)
            probes = jnp.where(ok & (probes >= 0), probes, -1)
    if probe_policy == "margin":
        tau = jnp.inf if margin_tau is None else margin_tau
        return topk_mod.margin_prune_probes(vals, probes, tau)
    return probes, jnp.zeros((probes.shape[0],), jnp.int32)


def scan_candidates(index: ivf_mod.IVFIndex, q: jax.Array, probes: jax.Array,
                    *, scan_impl: str, keep: int | None = None,
                    filter_bits: jax.Array | None = None,
                    early_exit: bool = False, probe_fill: float = 1.0
                    ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Stage 2 — quantized scan, flattened to one candidate pool per query.

    Returns (dists (Q, C) f32, ids (Q, C) i32 with -1 = pad,
    tiles_skipped (Q,) i32 — early-exit counter, zeros unless the stream
    path ran with ``early_exit=True``). With the gathered impls
    C = nprobe*cap. ``keep`` is the per-query candidate budget the
    downstream selection will take (r*k, or k without re-rank): when the
    resolved impl is 'stream' and ``keep`` is given, the scan runs gather-
    free over the in-place ListStore with fused per-tile reduction
    (``core.ivf.scan_probes_stream``) and C shrinks to
    nprobe*n_tiles*min(keep, tile) — bit-identical through any final
    selection of <= keep candidates. ``keep=None`` always yields the full
    pool (hand-composition back-compat).

    ``filter_bits`` is the (nlist, W) packed per-row predicate bitmap
    (``core.lists.pack_filter_mask``; docs/filtering.md). The stream path
    applies it *inside* the per-tile VMEM reduction (excluded rows hit the
    same sentinel as padding, before candidate selection — so the keep
    budget is spent on passing rows only). The gathered path here is the
    reference post-filter oracle: scan everything, then mask excluded rows
    to (inf, -1). The two are bit-identical through any final selection of
    <= keep candidates (tested at 0/1/50/100% selectivity).

    ``early_exit`` arms the stream kernel's anytime tile pruning
    (docs/anytime.md) — lossless for the final top-``keep``; a no-op (zeros
    counter) whenever the resolved impl is gathered. ``probe_fill`` is the
    valid-probe fraction the 'auto' sweep should assume (< 1 under a margin
    policy, where many probes arrive as -1).
    """
    if keep is not None:
        from repro.kernels import ops
        qq, p = probes.shape
        impl, tile_n = ops.resolve_scan_impl(
            scan_impl, qq * p, index.lists.cap,
            2 * index.lists.codes.shape[-1], nlist=index.lists.nlist,
            probe_fill=probe_fill)
        if impl == "stream":
            out = ivf_mod.scan_probes_stream(index, q, probes, keep=keep,
                                             tile_n=tile_n,
                                             filter_bits=filter_bits,
                                             early_exit=early_exit)
            if early_exit:
                return out
            dists, ids = out
            return dists, ids, jnp.zeros((dists.shape[0],), jnp.int32)
    dists, ids = ivf_mod.scan_probes(index, q, probes, impl=scan_impl)
    if filter_bits is not None:
        # post-filter oracle: (Q, P, cap) bool of rows that pass
        ok = unpack_filter_mask(filter_bits, index.lists.cap)[
            jnp.maximum(probes, 0)]
        ok = ok & (ids >= 0)
        dists = jnp.where(ok, dists, jnp.inf)
        ids = jnp.where(ok, ids, -1)
    qq = dists.shape[0]
    return (dists.reshape(qq, -1), ids.reshape(qq, -1),
            jnp.zeros((qq,), jnp.int32))


def combine_filter_bits(filter_bits: jax.Array | None,
                        live_bits: jax.Array | None) -> jax.Array | None:
    """AND the user predicate bitmap with the engine's live-row bitmap.

    The effective filter the scan stage applies: a row is scannable iff it
    passes the user predicate AND is not tombstoned (docs/mutability.md).
    Either side may be None (no predicate / no tombstones) and simply drops
    out; both None returns None, keeping the unfiltered-unmutated trace
    byte-identical to the pre-mutation engine.
    """
    if live_bits is None:
        return filter_bits
    if filter_bits is None:
        return live_bits
    return filter_bits & live_bits


def _probe_sum(probes: jax.Array, per_list: jax.Array) -> jax.Array:
    """Sum a (nlist,) per-list counter over each query's valid probes."""
    return jnp.sum(jnp.where(probes >= 0, per_list[jnp.maximum(probes, 0)], 0),
                   axis=1)


def count_rows_filtered(index: ivf_mod.IVFIndex, probes: jax.Array,
                        filter_bits: jax.Array | None,
                        live_bits: jax.Array | None = None) -> jax.Array:
    """(Q,) i32: probed LIVE rows the user filter excluded.

    Zero without a filter. Tombstoned slots are counted by
    ``count_rows_tombstoned``, never here — the two partition the probed
    non-passing occupancy. Namespace-excluded lists contribute nothing:
    their probes are already -1, so they were never scanned at all.
    """
    qq = probes.shape[0]
    if filter_bits is None:
        return jnp.zeros((qq,), jnp.int32)
    live = (index.lists.sizes if live_bits is None
            else filter_pass_sizes(index.lists, live_bits))
    eff = combine_filter_bits(filter_bits, live_bits)
    return _probe_sum(probes, live - filter_pass_sizes(index.lists, eff))


def count_rows_tombstoned(index: ivf_mod.IVFIndex, probes: jax.Array,
                          live_bits: jax.Array | None) -> jax.Array:
    """(Q,) i32: probed watermark slots holding tombstones. Zero when the
    engine carries none (``live_bits`` is None)."""
    qq = probes.shape[0]
    if live_bits is None:
        return jnp.zeros((qq,), jnp.int32)
    tomb = index.lists.sizes - filter_pass_sizes(index.lists, live_bits)
    return _probe_sum(probes, tomb)


def make_stats(index: ivf_mod.IVFIndex, probes: jax.Array,
               reranked: jax.Array,
               filter_bits: jax.Array | None = None,
               live_bits: jax.Array | None = None,
               lists_pruned: jax.Array | None = None,
               tiles_skipped: jax.Array | None = None) -> QueryStats:
    """Work counters from the probe set + the re-rank stage's counter.

    ``lists_pruned``/``tiles_skipped`` are the anytime counters
    (docs/anytime.md); None (the hand-composition default) records zeros.
    """
    qq = probes.shape[0]
    return QueryStats(
        lists_probed=jnp.sum((probes >= 0).astype(jnp.int32), axis=1),
        codes_scanned=jnp.sum(index.lists.probed_sizes(probes), axis=1),
        reranked=reranked,
        rows_filtered=count_rows_filtered(index, probes, filter_bits,
                                          live_bits),
        rows_tombstoned=count_rows_tombstoned(index, probes, live_bits),
        lists_pruned=(jnp.zeros((qq,), jnp.int32) if lists_pruned is None
                      else lists_pruned),
        tiles_skipped=(jnp.zeros((qq,), jnp.int32) if tiles_skipped is None
                       else tiles_skipped),
    )


def _pipeline(coarse, index: ivf_mod.IVFIndex, base: jax.Array | None,
              norms: jax.Array | None, ns_member: jax.Array | None,
              q: jax.Array, filter_bits: jax.Array | None,
              namespaces: jax.Array | None,
              live_bits: jax.Array | None = None,
              margin_tau: jax.Array | None = None, *, k: int, nprobe: int,
              r: int, scan_impl: str, rerank_impl: str, ef: int,
              probe_policy: str = "fixed", early_exit: bool = False
              ) -> SearchResult:
    """The whole engine as one pure function (stages 1-4 + stats).

    ``filter_bits``/``namespaces``/``live_bits``/``margin_tau`` are *traced*
    arguments (None simply drops out of the trace): changing the predicate,
    tenant mix, tombstone set, or per-request margin budget between requests
    never recompiles — only presence/absence does, giving a handful of
    compile-cache entries per shape bucket instead of one per predicate.

    ``live_bits`` is the engine-held live-row bitmap
    (``core.lists.live_filter_bits``), present only while the store carries
    tombstones. It is ANDed into the scan's effective filter so the stream
    kernel's per-tile candidate budget skips deleted rows *before*
    selection — the condition for mutated results to stay bit-identical to
    a rebuilt engine's (docs/mutability.md). Gathered impls mask tombstones
    by id anyway; for them the AND only changes the stats, not the math.

    ``probe_policy``/``early_exit`` are the static anytime knobs
    (docs/anytime.md): the policy picks which coarse branch traces and the
    sweep fill the autotuner should time against; early exit changes the
    stream kernel variant. ``margin_tau`` itself stays traced.
    """
    probes, lists_pruned = coarse_probes(
        coarse, q, nprobe=nprobe, ef=ef, ns_member=ns_member,
        namespaces=namespaces, probe_policy=probe_policy,
        margin_tau=margin_tau)
    # the selection budget stage 3+4 will take — under 'stream' this lets
    # the scan kernel reduce candidates in VMEM instead of writing the full
    # (Q, nprobe*cap) pool to HBM
    flat_d, flat_ids, tiles_skipped = scan_candidates(
        index, q, probes, scan_impl=scan_impl, keep=(r * k) if r else k,
        filter_bits=combine_filter_bits(filter_bits, live_bits),
        early_exit=early_exit,
        probe_fill=(MARGIN_PROBE_FILL if probe_policy == "margin" else 1.0))
    vals, out_ids, reranked = rerank_mod.finalize_candidates(
        flat_d, flat_ids, base, q, k, r, norms=norms, rerank_impl=rerank_impl)
    return SearchResult(dists=vals, ids=out_ids,
                        stats=make_stats(index, probes, reranked, filter_bits,
                                         live_bits, lists_pruned,
                                         tiles_skipped))


# ONE process-wide jit: cache is keyed on static knobs + pytree structure +
# leaf shapes/dtypes, so N engines serving the same bucket shapes share
# compiles. This is the serving fast path.
_fused_pipeline = jax.jit(
    _pipeline,
    static_argnames=("k", "nprobe", "r", "scan_impl", "rerank_impl", "ef",
                     "probe_policy", "early_exit"))


def fused_cache_size() -> int:
    """Number of compiled entries in the fused-pipeline jit cache.

    Serving tests assert the delta of this across a request stream: at most
    one new entry per (shape bucket x static-knob combination).
    """
    return _fused_pipeline._cache_size()


class EngineState(NamedTuple):
    """One immutable snapshot of everything a search reads.

    The mutable engine's atomicity primitive (docs/mutability.md): mutation
    never edits what a reader sees — ``upsert``/``delete``/``compact`` build
    a complete replacement snapshot and install it with a single attribute
    store on ``SearchEngine._state`` (atomic under the GIL). A search grabs
    the snapshot exactly once, so an in-flight batch keeps computing on a
    consistent retiring epoch while every later search sees the new one —
    there is no window where a query can mix lists from one epoch with base
    rows or live bits from another.
    """

    index: ivf_mod.IVFIndex
    base: jax.Array | None
    base_norms: jax.Array | None
    live_bits: jax.Array | None  # packed live-row bitmap; None = no tombstones
    epoch: int                   # bumped on every swap (monotonic, starts 0)
    n_tombstones: int            # tombstoned slots currently held across lists


class SearchEngine:
    """IVF + fast-scan + exact re-rank behind one ``search(queries, k)``.

    ``base`` (the raw float vectors) is optional: without it the engine
    degrades gracefully to pure quantized search (re-rank requests are
    rejected loudly rather than silently skipped).

    Config/coarse combinations are validated at construction
    (``validate_config``): a nonsense knob raises here, not on first search.

    The engine is *live-mutable* (docs/mutability.md): ``upsert`` PQ-encodes
    new rows and appends them into spare list slots, ``delete`` tombstones
    rows in place (a mask write — the kernels already treat id -1 as
    padding), and ``compact`` rebuilds the lists tombstone-free into a
    fresh epoch. Everything a search reads lives in one ``EngineState``
    snapshot swapped atomically per mutation, so readers never see a torn
    epoch; ``engine.epoch`` counts the swaps. ``index``/``base``/
    ``base_norms``/``live_bits`` are read-only views of the current
    snapshot.
    """

    def __init__(self, index: ivf_mod.IVFIndex, *, base: jax.Array | None = None,
                 coarse: str | object = "flat",
                 config: EngineConfig | None = None, hnsw_m: int = 16,
                 ef_construction: int = 64,
                 namespaces: jax.Array | None = None):
        # ‖x‖² per base row, computed once: the norms+GEMM re-rank (both
        # impls) reads these instead of re-deriving norms per query.
        # A store built with tombstones already present (unusual, but
        # partition/compact round-trips allow it) derives its live bitmap
        # here so the first search is already exact.
        n_tomb = int(jnp.sum(lists_mod.tombstone_counts(index.lists)))
        self._state = EngineState(
            index=index, base=base,
            base_norms=None if base is None else base_norms(base),
            live_bits=(lists_mod.live_filter_bits(index.lists)
                       if n_tomb else None),
            epoch=0, n_tombstones=n_tomb)
        self._mutate_lock = threading.RLock()
        self._locator: dict[int, tuple[int, int]] | None = None  # lazy
        # optional persist.WALWriter (attach_wal): every mutation is logged
        # and fsync'd BEFORE its state swap, so an acknowledged mutation
        # survives kill-9 (docs/persistence.md)
        self._wal = None
        # retained so a snapshot can record how to rebuild the coarse
        # structure deterministically from the centroids alone
        self.hnsw_m = int(hnsw_m)
        self.ef_construction = int(ef_construction)
        # (n_ns, nlist) bool membership: row t = the lists holding tenant
        # t's vectors. None = engine is namespace-free (docs/filtering.md).
        if namespaces is not None:
            namespaces = jnp.asarray(namespaces, dtype=bool)
            if namespaces.ndim != 2 or namespaces.shape[1] != index.lists.nlist:
                raise ValueError(
                    f"namespaces must be (n_ns, nlist={index.lists.nlist}) "
                    f"bool membership, got shape {namespaces.shape}")
        self.ns_member = namespaces
        self.config = config or EngineConfig()
        if isinstance(coarse, str):
            if coarse == "flat":
                self.coarse = coarse_mod.build_flat(index.centroids)
            elif coarse == "hnsw":
                self.coarse = coarse_mod.build_hnsw_coarse(
                    index.centroids, m=hnsw_m, ef_construction=ef_construction)
            elif coarse == "tree":
                self.coarse = coarse_mod.build_tree(jax.random.PRNGKey(0),
                                                    index.centroids)
            else:
                raise ValueError(
                    f"unknown coarse kind {coarse!r}; want one of {COARSE_KINDS}")
            kind = coarse
        else:
            self.coarse = coarse  # prebuilt object with .search(q, nprobe)
            kind = _coarse_kind_of(coarse)
        self.coarse_kind = kind
        validate_config(self.config, coarse_kind=kind,
                        has_base=base is not None)

    # -- state snapshot views (docs/mutability.md) --------------------------
    # All reads go through the current EngineState so a mutation can never
    # tear what a caller composes by hand; mutators replace the whole tuple.

    @property
    def index(self) -> ivf_mod.IVFIndex:
        return self._state.index

    @property
    def base(self) -> jax.Array | None:
        return self._state.base

    @property
    def base_norms(self) -> jax.Array | None:
        return self._state.base_norms

    @property
    def live_bits(self) -> jax.Array | None:
        """Packed live-row bitmap; None while the store holds no tombstones."""
        return self._state.live_bits

    @property
    def epoch(self) -> int:
        """Monotonic state-swap counter: bumps on every upsert/delete/compact.

        After a mutation call returns with ``epoch == e``, every search
        *started* afterwards reflects at least epoch ``e`` (searches in
        flight during the swap finish on the epoch they snapshotted)."""
        return self._state.epoch

    @property
    def n_tombstones(self) -> int:
        """Tombstoned slots currently held (0 right after ``compact``)."""
        return self._state.n_tombstones

    def attach_wal(self, wal) -> None:
        """Attach a ``persist.WALWriter``: every later ``upsert``/``delete``/
        ``compact`` appends a checksummed, fsync'd record *before* installing
        its state swap, making the mutation durable the moment the call
        returns (docs/persistence.md). Pass ``None`` to detach (replay must
        not re-log)."""
        with self._mutate_lock:
            self._wal = wal

    def locate(self, gid: int) -> tuple[int, int] | None:
        """(list, slot) of a live row by global id, None if absent/deleted."""
        with self._mutate_lock:
            return self._locate(self._state).get(int(gid))

    def _locate(self, st: EngineState) -> dict[int, tuple[int, int]]:
        # callers hold _mutate_lock; the locator tracks st.index.lists
        if self._locator is None:
            self._locator = lists_mod.locate_rows(st.index.lists)
        return self._locator

    # -- live mutation (docs/mutability.md) ---------------------------------

    def upsert(self, ids, vecs, *, attrs=None) -> np.ndarray:
        """Insert or replace rows: PQ-encode, route, append into spare slots.

        ids: (B,) int global ids (>= 0, unique within the batch); vecs:
        (B, D) f32; attrs: optional (B,) i32 filter attributes (requires the
        store to carry an attrs column). Returns the (B,) i32 list each row
        was routed to (its nearest coarse centroid).

        A re-upserted existing id is tombstoned first, then appended like a
        new row — one atomic swap covers both, so no reader ever sees the
        id twice or not at all. Encoding is bitwise batch-independent
        (``core.ivf.encode_rows``), which is what keeps a mutated engine's
        codes identical to a from-scratch rebuild's. When a target list
        lacks spare capacity the store is compacted in place (reclaiming
        tombstones) and, if still short, grown to a larger cap — both under
        the same swap; autotune verdicts keyed to the retired cap are
        dropped. ``base``/``base_norms`` grow and update incrementally
        (zero-padded to 256-row multiples); the engine's namespace table is
        deliberately NOT touched — membership is a list-level property the
        caller owns.
        """
        ids = np.asarray(ids, np.int64)
        vecs = np.asarray(vecs, np.float32)
        if ids.ndim != 1 or vecs.ndim != 2 or ids.shape[0] != vecs.shape[0]:
            raise ValueError(
                f"upsert wants ids (B,) + vecs (B, D), got {ids.shape} and "
                f"{vecs.shape}")
        if ids.size == 0:
            return np.empty((0,), np.int32)
        if (ids < 0).any():
            raise ValueError("upsert ids must be >= 0 (-1 is the padding "
                             "sentinel)")
        if np.unique(ids).size != ids.size:
            raise ValueError("duplicate ids within one upsert batch — the "
                             "per-batch slot order would be ambiguous; "
                             "dedupe to the latest value first")
        avals = None if attrs is None else np.asarray(attrs, np.int32)
        with self._mutate_lock:
            st = self._state
            if vecs.shape[1] != st.index.centroids.shape[1]:
                raise ValueError(
                    f"upsert vecs have D={vecs.shape[1]}, engine expects "
                    f"D={st.index.centroids.shape[1]}")
            assign, packed = ivf_mod.encode_rows(
                st.index.centroids, st.index.codebook, vecs)
            loc = dict(self._locate(st))
            store = st.index.lists
            n_tomb = st.n_tombstones
            hit = [int(g) for g in ids if int(g) in loc]
            if hit:
                store = lists_mod.tombstone_rows(
                    store, np.array([loc[g][0] for g in hit], np.int32),
                    np.array([loc[g][1] for g in hit], np.int32))
                for g in hit:
                    del loc[g]
                n_tomb += len(hit)
            incoming = np.bincount(assign, minlength=store.nlist)
            if (np.asarray(store.sizes) + incoming > store.cap).any():
                # compact-then-grow: reclaiming tombstones may already free
                # enough spare slots; only grow cap when live rows + the
                # batch genuinely exceed it (padded to a multiple of 8 so
                # the filter-bitmap width stays exact)
                live = np.asarray(lists_mod.live_counts(store))
                need = int((live + incoming).max())
                old_cap = store.cap
                new_cap = max(old_cap, -(-need // 8) * 8)
                store = lists_mod.compact_lists(store, cap=new_cap)
                n_tomb = 0
                loc = lists_mod.locate_rows(store)
                if new_cap != old_cap:
                    ops_mod.clear_autotune_cache(nlist=store.nlist,
                                                 cap=old_cap)
            store, slots = lists_mod.append_rows(
                store, assign, packed, ids.astype(np.int32), avals)
            for g, l, s in zip(ids.tolist(), assign.tolist(), slots.tolist()):
                loc[int(g)] = (int(l), int(s))
            base, norms = st.base, st.base_norms
            if base is not None:
                need_rows = int(ids.max()) + 1
                n0 = base.shape[0]
                if need_rows > n0:
                    grown = -(-need_rows // 256) * 256
                    base = jnp.concatenate(
                        [base, jnp.zeros((grown - n0, base.shape[1]),
                                         base.dtype)])
                    norms = jnp.concatenate(
                        [norms, jnp.zeros((grown - n0,), norms.dtype)])
                    ops_mod.clear_autotune_cache(kind="rerank", n=n0)
                rows = jnp.asarray(vecs)
                gidx = jnp.asarray(ids.astype(np.int32))
                base = base.at[gidx].set(rows)
                # same row-wise mul+sum expression as core.lists.base_norms
                # => bitwise equal to a from-scratch norms pass
                norms = norms.at[gidx].set(jnp.sum(rows * rows, axis=-1))
            if self._wal is not None:
                # durable before visible: fsync the record, then swap
                self._wal.log_upsert(ids, vecs, avals)
            self._locator = loc
            self._state = EngineState(
                index=st.index._replace(lists=store), base=base,
                base_norms=norms,
                live_bits=(lists_mod.live_filter_bits(store)
                           if n_tomb else None),
                epoch=st.epoch + 1, n_tombstones=n_tomb)
        return assign

    def delete(self, ids) -> int:
        """Tombstone rows by global id; unknown/already-deleted ids are
        ignored. Returns the number of rows actually deleted.

        A delete is a mask write (ids/attrs at the slot become -1 — the
        padding convention every scan path masks); code bytes and the base
        row stay in place until ``compact``, unreachable because no list
        references them. After this returns, no later-started search can
        return the deleted ids.
        """
        ids = np.unique(np.asarray(ids, np.int64))
        with self._mutate_lock:
            st = self._state
            loc = dict(self._locate(st))
            found = [int(g) for g in ids if int(g) in loc]
            if not found:
                return 0
            store = lists_mod.tombstone_rows(
                st.index.lists,
                np.array([loc[g][0] for g in found], np.int32),
                np.array([loc[g][1] for g in found], np.int32))
            for g in found:
                del loc[g]
            if self._wal is not None:
                # a no-op delete returned above without logging; replaying
                # the full id batch re-derives the same `found` set
                self._wal.log_delete(ids)
            self._locator = loc
            self._state = EngineState(
                index=st.index._replace(lists=store), base=st.base,
                base_norms=st.base_norms,
                live_bits=lists_mod.live_filter_bits(store),
                epoch=st.epoch + 1,
                n_tombstones=st.n_tombstones + len(found))
            return len(found)

    def compact(self, cap: int | None = None) -> int:
        """Rebuild every list tombstone-free into a fresh epoch.

        Survivors keep their relative slot order; ``cap`` may grow (spare
        headroom for upserts) or shrink to fit. The rebuild happens off to
        the side and swaps in atomically — in-flight searches finish on the
        retiring epoch (this is what ``ServingLoop.compact`` runs under its
        dispatch lock). Autotune verdicts keyed to a retired cap are
        dropped so a stale (impl, tile) can't be served or re-persisted.
        Returns the number of tombstoned slots reclaimed.
        """
        with self._mutate_lock:
            st = self._state
            old_cap = st.index.lists.cap
            store = lists_mod.compact_lists(st.index.lists, cap=cap)
            if store.cap != old_cap:
                ops_mod.clear_autotune_cache(nlist=store.nlist, cap=old_cap)
            reclaimed = st.n_tombstones
            if self._wal is not None:
                self._wal.log_compact(cap)
            self._locator = lists_mod.locate_rows(store)
            self._state = EngineState(
                index=st.index._replace(lists=store), base=st.base,
                base_norms=st.base_norms, live_bits=None,
                epoch=st.epoch + 1, n_tombstones=0)
            return reclaimed

    # -- construction -------------------------------------------------------

    @classmethod
    def build(cls, key: jax.Array, train_x: jax.Array, base_x: jax.Array, *,
              m: int, nlist: int, coarse: str = "flat",
              config: EngineConfig | None = None, cap: int | None = None,
              coarse_iters: int = 20, pq_iters: int = 25,
              keep_base: bool = True, **coarse_kw) -> "SearchEngine":
        """Train + bucket + wrap: one call from raw vectors to a live engine."""
        index = ivf_mod.build_ivf(key, train_x, base_x, m=m, nlist=nlist,
                                  cap=cap, coarse_iters=coarse_iters,
                                  pq_iters=pq_iters)
        return cls(index, base=base_x if keep_base else None, coarse=coarse,
                   config=config, **coarse_kw)

    # -- stages (kept as methods for hand-composition; each delegates to the
    #    pure stage functions above) ----------------------------------------

    def select_probes(self, q: jax.Array, nprobe: int) -> jax.Array:
        """Stage 1 — coarse: pick up to nprobe promising lists (-1 = none).

        Applies the config's probe policy; the pruned-count counter is
        dropped here (hand-composition back-compat) — use ``coarse_probes``
        directly to observe it.
        """
        probes, _ = coarse_probes(
            self.coarse, q, nprobe=nprobe, ef=self.config.ef,
            probe_policy=self.config.probe_policy,
            margin_tau=self.config.margin_tau)
        return probes

    def scan(self, q: jax.Array, probe_ids: jax.Array
             ) -> tuple[jax.Array, jax.Array]:
        """Stage 2 — quantized scan: flattened ADC candidates per query."""
        dists, ids, _ = scan_candidates(self.index, q, probe_ids,
                                        scan_impl=self.config.scan_impl)
        return dists, ids

    # -- the unified entry points ------------------------------------------

    def _resolve(self, queries, nprobe, rerank_mult, filter_bits, namespaces,
                 st: EngineState, margin_tau=None):
        q = queries[None] if queries.ndim == 1 else queries
        nprobe = self.config.nprobe if nprobe is None else nprobe
        r = self.config.rerank_mult if rerank_mult is None else rerank_mult
        if margin_tau is not None and self.config.probe_policy != "margin":
            raise ValueError(
                "margin_tau override given but probe_policy is "
                f"{self.config.probe_policy!r}; build the engine with "
                "EngineConfig(probe_policy='margin')")
        if self.config.probe_policy == "margin":
            tau = (self.config.margin_tau if margin_tau is None
                   else margin_tau)
            tau = jnp.asarray(tau, jnp.float32)
            if tau.ndim not in (0, 1) or (tau.ndim == 1
                                          and tau.shape != (q.shape[0],)):
                raise ValueError(
                    f"margin_tau must be a scalar or ({q.shape[0]},) per-"
                    f"query widths, got shape {tau.shape}")
        else:
            tau = None
        if r and st.base is None:
            raise ValueError("exact re-rank requested but engine holds no "
                             "base vectors (build with keep_base=True)")
        if filter_bits is not None:
            nlist, cap = st.index.lists.nlist, st.index.lists.cap
            if (filter_bits.ndim != 2
                    or filter_bits.shape[0] != nlist
                    or filter_bits.shape[1] * 8 < cap):
                raise ValueError(
                    f"filter_bits must be (nlist={nlist}, "
                    f"W>=ceil(cap/8)={filter_words(cap)}) packed "
                    f"u8 (core.lists.pack_filter_mask), got shape "
                    f"{filter_bits.shape} — note a compaction/grow may have "
                    "changed cap; re-derive filters from the live store")
            # normalize to the exact W of this epoch's cap so the bitmap
            # broadcasts against live_bits (extra words carry no slots)
            filter_bits = filter_bits[:, :filter_words(cap)].astype(jnp.uint8)
        if namespaces is not None:
            if self.ns_member is None:
                raise ValueError(
                    "per-query namespaces given but the engine was built "
                    "without a namespace table (pass namespaces=(n_ns, nlist) "
                    "bool membership to SearchEngine)")
            namespaces = jnp.asarray(namespaces, jnp.int32)
            if namespaces.ndim == 0:
                namespaces = namespaces[None]
            if namespaces.shape != (q.shape[0],):
                raise ValueError(
                    f"namespaces must be ({q.shape[0]},) i32 (one per query, "
                    f"-1 = unrestricted), got shape {namespaces.shape}")
        return q, nprobe, r, filter_bits, namespaces, tau

    def search(self, queries: jax.Array, k: int = 10, *,
               nprobe: int | None = None, rerank_mult: int | None = None,
               filter_bits: jax.Array | None = None,
               namespaces: jax.Array | None = None,
               margin_tau: jax.Array | float | None = None) -> SearchResult:
        """Batched ANN search, staged. queries: (Q, D) or (D,).

        ``rerank_mult`` overrides the config: r > 0 refines the top r*k
        quantized candidates with exact float distances before the final
        merge (requires ``base``); 0 returns pure fast-scan results.

        ``filter_bits`` is an optional (nlist, W) packed per-row predicate
        bitmap (``core.lists.pack_filter_mask`` / ``filter_from_attrs``);
        ``namespaces`` an optional (Q,) i32 of per-query namespace ids into
        the engine's membership table, -1 = unrestricted. Both restrict
        which rows can appear in results — see docs/filtering.md for the
        exact contract.

        ``margin_tau`` (scalar or (Q,)) overrides the config's margin width
        for this request — the anytime latency/recall dial
        (docs/anytime.md). Only legal under ``probe_policy='margin'``.
        """
        st = self._state  # ONE snapshot read: the whole search is one epoch
        q, nprobe, r, fb, ns, tau = self._resolve(
            queries, nprobe, rerank_mult, filter_bits, namespaces, st,
            margin_tau)
        return _pipeline(self.coarse, st.index, st.base, st.base_norms,
                         self.ns_member if ns is not None else None,
                         q, fb, ns, st.live_bits, tau, k=k, nprobe=nprobe,
                         r=r, scan_impl=self.config.scan_impl,
                         rerank_impl=self.config.rerank_impl,
                         ef=self.config.ef,
                         probe_policy=self.config.probe_policy,
                         early_exit=self.config.early_exit)

    def search_jit(self, queries: jax.Array, k: int = 10, *,
                   nprobe: int | None = None, rerank_mult: int | None = None,
                   filter_bits: jax.Array | None = None,
                   namespaces: jax.Array | None = None,
                   margin_tau: jax.Array | float | None = None
                   ) -> SearchResult:
        """Batched ANN search, fused: the whole pipeline in one ``jax.jit``.

        Same semantics and bit-identical results to ``search``, but a single
        XLA dispatch with ``(k, nprobe, rerank_mult)`` static — the serving
        path. Steady-state traffic over fixed shape buckets hits the shared
        process-wide compile cache (``fused_cache_size``) and never
        recompiles. Requires the coarse quantizer to be a jax pytree (all of
        ``core.coarse``'s are; a custom non-pytree object falls back to
        ``search``).

        ``filter_bits``/``namespaces``/``margin_tau`` (see ``search``) are
        traced, not static: the predicate/budget VALUES never key the
        compile cache — only their presence does (a None is absent from the
        pytree), so a stream of distinct filters or per-request tau dials
        compiles at most once per presence combination.
        """
        st = self._state  # ONE snapshot read: the whole search is one epoch
        q, nprobe, r, fb, ns, tau = self._resolve(
            queries, nprobe, rerank_mult, filter_bits, namespaces, st,
            margin_tau)
        if self.coarse_kind == "custom":
            # unknown coarse objects may not be jax pytrees => not traceable
            return self.search(queries, k, nprobe=nprobe, rerank_mult=r,
                               filter_bits=fb, namespaces=ns,
                               margin_tau=margin_tau)
        return _fused_pipeline(self.coarse, st.index, st.base,
                               st.base_norms,
                               self.ns_member if ns is not None else None,
                               q, fb, ns, st.live_bits, tau, k=k,
                               nprobe=nprobe, r=r,
                               scan_impl=self.config.scan_impl,
                               rerank_impl=self.config.rerank_impl,
                               ef=self.config.ef,
                               probe_policy=self.config.probe_policy,
                               early_exit=self.config.early_exit)


def _coarse_kind_of(coarse) -> str:
    if isinstance(coarse, coarse_mod.FlatCoarse):
        return "flat"
    if isinstance(coarse, coarse_mod.HNSWCoarse):
        return "hnsw"
    if isinstance(coarse, coarse_mod.TreeCoarse):
        return "tree"
    return "custom"
