"""Unified batched search engine: coarse -> 4-bit fast-scan -> exact re-rank.

The single query path a server calls (``SearchEngine.search``), composing the
pieces that previously lived disconnected across ``core``:

  1. coarse: pluggable probe selection over the IVF centroids — flat
     brute-force, HNSW graph routing (paper Table 1), or k-means tree;
  2. scan: the 4-bit fast-scan ADC over the gathered posting lists
     (``core.ivf.scan_probes``, grouped Pallas kernel underneath);
  3. re-rank: exact float refinement of the top ``rerank_mult * k``
     quantized candidates (``engine.rerank``), Quicker-ADC style;
  4. merge: final masked top-k (single host) or the distributed 2k-scalar
     shard merge (``engine.sharded`` over ``core.topk.distributed_topk``).

Every stage is a jit'd function of static shapes; ``search`` is stage
composition, so its results are *identical* to calling the stages by hand
(tested). A ``QueryStats`` record rides along for observability: how many
lists were probed, codes scanned, candidates re-ranked — per query.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import coarse as coarse_mod
from repro.core import ivf as ivf_mod
from repro.engine import rerank as rerank_mod

COARSE_KINDS = ("flat", "hnsw", "tree")


class EngineConfig(NamedTuple):
    """Static search-time knobs (all shapes derive from these => jit-stable)."""

    nprobe: int = 8         # lists scanned per query
    rerank_mult: int = 0    # refine rerank_mult*k candidates exactly; 0 = off
    scan_impl: str = "ref"  # grouped ADC impl: 'ref' (jnp) | 'select' (Pallas)
    ef: int = 64            # HNSW beam width (hnsw coarse only)


class QueryStats(NamedTuple):
    """Per-query work counters threaded through the pipeline."""

    lists_probed: jax.Array   # (Q,) i32  valid probes issued
    codes_scanned: jax.Array  # (Q,) i32  true occupancy of scanned lists
    reranked: jax.Array       # (Q,) i32  candidates refined exactly


class SearchResult(NamedTuple):
    dists: jax.Array  # (Q, k) f32 ascending
    ids: jax.Array    # (Q, k) i32 global ids, -1 = no candidate
    stats: QueryStats


class SearchEngine:
    """IVF + fast-scan + exact re-rank behind one ``search(queries, k)``.

    ``base`` (the raw float vectors) is optional: without it the engine
    degrades gracefully to pure quantized search (re-rank requests are
    rejected loudly rather than silently skipped).
    """

    def __init__(self, index: ivf_mod.IVFIndex, *, base: jax.Array | None = None,
                 coarse: str | object = "flat",
                 config: EngineConfig | None = None, hnsw_m: int = 16,
                 ef_construction: int = 64):
        self.index = index
        self.base = base
        self.config = config or EngineConfig()
        if isinstance(coarse, str):
            if coarse == "flat":
                self.coarse = coarse_mod.build_flat(index.centroids)
            elif coarse == "hnsw":
                self.coarse = coarse_mod.build_hnsw_coarse(
                    index.centroids, m=hnsw_m, ef_construction=ef_construction)
            elif coarse == "tree":
                self.coarse = coarse_mod.build_tree(jax.random.PRNGKey(0),
                                                    index.centroids)
            else:
                raise ValueError(
                    f"unknown coarse kind {coarse!r}; want one of {COARSE_KINDS}")
        else:
            self.coarse = coarse  # prebuilt object with .search(q, nprobe)

    # -- construction -------------------------------------------------------

    @classmethod
    def build(cls, key: jax.Array, train_x: jax.Array, base_x: jax.Array, *,
              m: int, nlist: int, coarse: str = "flat",
              config: EngineConfig | None = None, cap: int | None = None,
              coarse_iters: int = 20, pq_iters: int = 25,
              keep_base: bool = True, **coarse_kw) -> "SearchEngine":
        """Train + bucket + wrap: one call from raw vectors to a live engine."""
        index = ivf_mod.build_ivf(key, train_x, base_x, m=m, nlist=nlist,
                                  cap=cap, coarse_iters=coarse_iters,
                                  pq_iters=pq_iters)
        return cls(index, base=base_x if keep_base else None, coarse=coarse,
                   config=config, **coarse_kw)

    # -- stages (each individually jit'd; search is their composition) ------

    def select_probes(self, q: jax.Array, nprobe: int) -> jax.Array:
        """Stage 1 — coarse: pick the nprobe most promising lists."""
        if isinstance(self.coarse, coarse_mod.HNSWCoarse):
            _, probes = self.coarse.search(q, nprobe, ef=max(self.config.ef,
                                                             nprobe))
            return probes
        _, probes = self.coarse.search(q, nprobe)
        return probes

    def scan(self, q: jax.Array, probe_ids: jax.Array
             ) -> tuple[jax.Array, jax.Array]:
        """Stage 2 — quantized scan: flattened ADC candidates per query."""
        dists, ids = ivf_mod.scan_probes(self.index, q, probe_ids,
                                         impl=self.config.scan_impl)
        qq = dists.shape[0]
        return dists.reshape(qq, -1), ids.reshape(qq, -1)

    # -- the unified entry point -------------------------------------------

    def search(self, queries: jax.Array, k: int = 10, *,
               nprobe: int | None = None, rerank_mult: int | None = None
               ) -> SearchResult:
        """Batched ANN search. queries: (Q, D) or (D,). Returns SearchResult.

        ``rerank_mult`` overrides the config: r > 0 refines the top r*k
        quantized candidates with exact float distances before the final
        merge (requires ``base``); 0 returns pure fast-scan results.
        """
        q = queries[None] if queries.ndim == 1 else queries
        nprobe = self.config.nprobe if nprobe is None else nprobe
        r = self.config.rerank_mult if rerank_mult is None else rerank_mult
        if r and self.base is None:
            raise ValueError("exact re-rank requested but engine holds no "
                             "base vectors (build with keep_base=True)")

        probes = self.select_probes(q, nprobe)          # (Q, P)
        flat_d, flat_ids = self.scan(q, probes)         # (Q, P*cap)
        vals, out_ids, reranked = rerank_mod.finalize_candidates(
            flat_d, flat_ids, self.base, q, k, r)

        stats = QueryStats(
            lists_probed=jnp.sum((probes >= 0).astype(jnp.int32), axis=1),
            codes_scanned=jnp.sum(self.index.lists.probed_sizes(probes), axis=1),
            reranked=reranked,
        )
        return SearchResult(dists=vals, ids=out_ids, stats=stats)
