"""Deterministic synthetic LM token pipeline.

Production-shaped: host-sharded (each host generates only its slice of the
global batch), deterministic in (step, host) so any host can re-issue any
shard after a failure or for backup-task straggler mitigation, and wrapped
in a double-buffered prefetch iterator.

The token stream is a mixture of Zipfian unigrams and a Markov bigram chain,
which gives a non-degenerate loss curve (pure uniform noise trains to a flat
log(V) immediately and hides optimizer bugs).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class TokenBatch(NamedTuple):
    tokens: jax.Array   # (B, S) int32 inputs
    targets: jax.Array  # (B, S) int32 next-token targets
    mask: jax.Array     # (B, S) float32 loss mask


class TokenPipelineConfig(NamedTuple):
    vocab: int
    seq_len: int
    global_batch: int
    host_count: int = 1
    host_id: int = 0
    seed: int = 0
    zipf_a: float = 1.2


def _host_batch(cfg: TokenPipelineConfig, step: int) -> np.ndarray:
    """Deterministic (step, host)-keyed batch of shape (B/host, S+1)."""
    assert cfg.global_batch % cfg.host_count == 0
    b = cfg.global_batch // cfg.host_count
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
    v = cfg.vocab
    # zipf unigram stream
    uni = rng.zipf(cfg.zipf_a, size=(b, cfg.seq_len + 1)).astype(np.int64)
    uni = (uni - 1) % v
    # markov overlay: with p=0.5, next token = f(prev) for a fixed cheap map
    prev = np.concatenate([uni[:, :1], uni[:, :-1]], axis=1)
    markov = (prev * 2654435761 + 12345) % v
    pick = rng.random((b, cfg.seq_len + 1)) < 0.5
    out = np.where(pick, markov, uni)
    return out.astype(np.int32)


def batch_at_step(cfg: TokenPipelineConfig, step: int) -> TokenBatch:
    raw = _host_batch(cfg, step)
    tokens = jnp.asarray(raw[:, :-1])
    targets = jnp.asarray(raw[:, 1:])
    return TokenBatch(tokens=tokens, targets=targets,
                      mask=jnp.ones(tokens.shape, jnp.float32))


class PrefetchIterator:
    """Background-thread prefetch of the deterministic pipeline (depth 2)."""

    def __init__(self, cfg: TokenPipelineConfig, start_step: int = 0,
                 depth: int = 2):
        self.cfg = cfg
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = batch_at_step(self.cfg, step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[tuple[int, TokenBatch]]:
        return self

    def __next__(self) -> tuple[int, TokenBatch]:
        return self._q.get()

    def close(self):
        self._stop.set()


def input_specs_lm(vocab: int, seq_len: int, global_batch: int
                   ) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    shape = (global_batch, seq_len)
    return {
        "tokens": jax.ShapeDtypeStruct(shape, jnp.int32),
        "targets": jax.ShapeDtypeStruct(shape, jnp.int32),
        "mask": jax.ShapeDtypeStruct(shape, jnp.float32),
    }
