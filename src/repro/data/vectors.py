"""Synthetic ANN datasets with the paper's dataset geometry.

Offline container => no SIFT1M/Deep1B downloads. We generate clustered
Gaussian-mixture data whose dimensionality matches the paper's datasets
(SIFT-like: 128-D non-negative ints; Deep-like: 96-D L2-normalized floats)
and compute exact ground truth by brute force. Cluster structure matters:
PQ recall curves are meaningless on isotropic noise.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import pairwise_sqdist
from repro.core.topk import smallest_k


class ANNDataset(NamedTuple):
    base: jax.Array    # (N, D)
    train: jax.Array   # (Nt, D)
    queries: jax.Array  # (Q, D)
    gt_ids: jax.Array  # (Q, G) exact nearest neighbor ids (ascending)

    @property
    def d(self) -> int:
        return self.base.shape[1]


def _gmm(rng: np.random.Generator, n: int, d: int, ncl: int, spread: float):
    centers = rng.normal(0.0, 1.0, (ncl, d)).astype(np.float32)
    which = rng.integers(0, ncl, n)
    x = centers[which] + spread * rng.normal(0.0, 1.0, (n, d)).astype(np.float32)
    return x.astype(np.float32)


def exact_ground_truth(base: jax.Array, queries: jax.Array, g: int = 10,
                       chunk: int = 512) -> jax.Array:
    outs = []
    for s in range(0, queries.shape[0], chunk):
        d = pairwise_sqdist(queries[s:s + chunk], base)
        _, ids = smallest_k(d, g)
        outs.append(ids)
    return jnp.concatenate(outs, axis=0)


def _make_queries(rng, base: np.ndarray, nq: int, rel_noise: float) -> np.ndarray:
    """Queries = perturbed base vectors (standard synthetic-ANN protocol):
    the true NN is at a controlled margin, so recall curves measure ADC
    fidelity rather than the degenerate geometry of isotropic mixtures."""
    idx = rng.choice(base.shape[0], size=nq, replace=False)
    scale = np.std(base) * rel_noise
    return (base[idx] + scale * rng.normal(0, 1, (nq, base.shape[1]))
            ).astype(np.float32)


def make_sift_like(n: int = 100_000, nt: int = 20_000, nq: int = 256,
                   d: int = 128, ncl: int = 256, seed: int = 0,
                   gt: int = 10, query_noise: float = 0.5) -> ANNDataset:
    """128-D SIFT-like: non-negative, heavy cluster structure (paper Fig. 2a)."""
    rng = np.random.default_rng(seed)
    x = _gmm(rng, n + nt, d, ncl, spread=0.35)
    x = np.abs(x) * 64.0  # SIFT histograms are non-negative with ~[0,218] range
    base, train = x[:n], x[n:]
    queries = _make_queries(rng, base, nq, query_noise)
    base_j, queries_j = jnp.asarray(base), jnp.asarray(queries)
    return ANNDataset(base_j, jnp.asarray(train), queries_j,
                      exact_ground_truth(base_j, queries_j, g=gt))


def make_deep_like(n: int = 100_000, nt: int = 20_000, nq: int = 256,
                   d: int = 96, ncl: int = 256, seed: int = 1,
                   gt: int = 10, query_noise: float = 0.5) -> ANNDataset:
    """96-D Deep1B-like: L2-normalized CNN-ish features (paper Fig. 2b/Table 1)."""
    rng = np.random.default_rng(seed)
    x = _gmm(rng, n + nt, d, ncl, spread=0.25)
    x /= np.maximum(np.linalg.norm(x, axis=1, keepdims=True), 1e-9)
    base, train = x[:n], x[n:]
    queries = _make_queries(rng, base, nq, query_noise)
    queries /= np.maximum(np.linalg.norm(queries, axis=1, keepdims=True), 1e-9)
    base_j, queries_j = jnp.asarray(base), jnp.asarray(queries)
    return ANNDataset(base_j, jnp.asarray(train), queries_j,
                      exact_ground_truth(base_j, queries_j, g=gt))
