"""Input pipelines: synthetic ANN vector datasets + deterministic LM tokens."""
