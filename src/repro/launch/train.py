"""End-to-end training driver: quickstart scale to multi-pod config.

On real hardware this script is launched once per host (jax.distributed
initializes from the cluster env); on the dev box it runs the same code on
the local mesh. The production path is exercised structurally by
`--dry-run`, which builds the full 16x16 (or 2x16x16) pjit train step.

Examples:
  python -m repro.launch.train --arch qwen3-1.7b --smoke --steps 50
  python -m repro.launch.train --arch dbrx-132b --dry-run --multi-pod
"""
from __future__ import annotations

import argparse

import jax

from repro import configs
from repro.train import optimizer as opt_lib
from repro.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile the production-mesh step instead of training")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.dry_run:
        from repro.launch import dryrun
        dryrun.run_cell(args.arch, "train_4k",
                        "multipod" if args.multi_pod else "pod")
        return

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    ocfg = opt_lib.AdamWConfig(lr=args.lr, total_steps=args.steps,
                               warmup_steps=max(1, args.steps // 20))
    state, history = train_loop.train(
        cfg, steps=args.steps, global_batch=args.global_batch,
        seq_len=args.seq_len, ocfg=ocfg, ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every, microbatches=args.microbatches)
    print(f"[train] done: final loss {history[-1]['loss']:.4f} "
          f"over {len(history)} steps")


if __name__ == "__main__":
    main()
