"""Three-term roofline from compiled dry-run artifacts (TPU v5e targets).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = wire_bytes_per_device / link_bw

`cost_analysis()` of the SPMD-partitioned executable is per-device, so the
per-chip division is already done. Collective wire bytes are NOT in
cost_analysis — we parse the partitioned HLO and sum operand/result sizes of
every collective op with the standard ring-algorithm byte factors.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

# TPU v5e-class hardware constants (per the brief)
PEAK_FLOPS = 197e12      # bf16 FLOP/s per chip
HBM_BW = 819e9           # bytes/s per chip
LINK_BW = 50e9           # bytes/s per chip of ICI

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# `%name = <result-type> <op>(`  — result type may be a tuple
_COLL_RE = re.compile(
    r"=\s+(\(?[^=]*?\)?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def shape_bytes(type_str: str) -> int:
    """Sum byte sizes of every array shape in an HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


# ring-algorithm wire-byte factors (large-N limit) applied to the result size
_WIRE_FACTOR = {
    "all-gather": 1.0,        # receives (N-1)/N of the gathered result
    "all-reduce": 2.0,        # reduce-scatter + all-gather
    "reduce-scatter": 1.0,    # sends (N-1)/N of the input
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


@dataclass
class CollectiveStats:
    ops: dict = field(default_factory=dict)       # op -> count
    bytes_by_op: dict = field(default_factory=dict)
    wire_bytes: float = 0.0


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Parse the per-device (partitioned) HLO; returns per-device wire bytes."""
    stats = CollectiveStats()
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        type_str, op = m.group(1), m.group(2)
        if f"{op}-done" in line:
            continue  # counted at -start
        b = shape_bytes(type_str)
        # async pairs: result of -start is a tuple (operand, result, ...);
        # dividing by 2 compensates the doubled tuple type
        if f"{op}-start" in line and type_str.startswith("("):
            b = b / 2
        stats.ops[op] = stats.ops.get(op, 0) + 1
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0) + b
        stats.wire_bytes += b * _WIRE_FACTOR[op]
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_dev: float
    hlo_bytes_per_dev: float
    wire_bytes_per_dev: float
    model_flops_total: float
    collectives: dict

    @property
    def t_compute(self) -> float:
        return self.hlo_flops_per_dev / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes_per_dev / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes_per_dev / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline step time lower bound (no overlap assumption)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips): how much compiled compute is
        'useful' (catches remat/redundancy/causal-mask waste)."""
        denom = self.hlo_flops_per_dev * self.chips
        return self.model_flops_total / denom if denom else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline bound (the perf score)."""
        t = self.t_bound
        if t <= 0:
            return 0.0
        return self.model_flops_total / (self.chips * PEAK_FLOPS * t)

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "hlo_flops_per_dev": self.hlo_flops_per_dev,
            "hlo_bytes_per_dev": self.hlo_bytes_per_dev,
            "wire_bytes_per_dev": self.wire_bytes_per_dev,
            "model_flops_total": self.model_flops_total,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
            "collectives": self.collectives,
        }


def model_flops(cfg, kind: str, batch: int, seq: int) -> float:
    """Analytic MODEL_FLOPS: 6·N_active·D train, 2·N_active·D inference."""
    n_active = cfg.active_param_count()
    tokens = batch * seq if kind in ("train", "prefill") else batch
    mult = 6.0 if kind == "train" else 2.0
    flops = mult * n_active * tokens
    # causal attention term (counted like standard MFU accounting)
    if cfg.n_heads:
        hd = cfg.resolved_head_dim
        if kind in ("train", "prefill"):
            att = 2 * 2 * cfg.n_layers * batch * seq * seq / 2 * cfg.n_heads * hd
            att *= 3.0 if kind == "train" else 1.0
        else:  # decode: one query against `seq` keys
            att = 2 * 2 * cfg.n_layers * batch * seq * cfg.n_heads * hd
        if cfg.block_type == "mamba2" and cfg.shared_attn_every:
            att /= cfg.shared_attn_every
        elif cfg.block_type != "attn":
            att = 0.0
        flops += att
    return flops
