"""Trip-count-aware HLO cost analysis.

XLA's `compiled.cost_analysis()` counts a while-loop body ONCE — for
scan-over-layers models this undercounts FLOPs/bytes by ~n_layers and makes
roofline terms meaningless. This module re-derives the three roofline inputs
by parsing the partitioned HLO text and walking the call graph with
multipliers:

  - `while` ops carry backend_config known_trip_count (jax scans/fori emit
    it) -> body and condition costs are multiplied by the trip count;
  - `fusion`/`call` recurse into the called computation for FLOPs; for HBM
    bytes a fusion counts only its operands+outputs (internals stay in
    registers/VMEM — the same model XLA itself uses);
  - `conditional` takes the max across branches (our causal block-skip);
  - collective ops accumulate wire bytes with ring-algorithm factors.

FLOPs counted: dot (2 * prod(out) * prod(contracted lhs dims)) + a 1-flop/
element charge for elementwise-heavy fusions (captures softmax/norms; <5%
of any matmul-bearing cell). Bytes: operands + outputs of top-level (post-
fusion) instructions, i.e. fusion-boundary traffic.
"""
from __future__ import annotations

import functools
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\(?.*?\)?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\((.*?)\)\s*->")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"(?:branch_computations|true_computation|"
                          r"false_computation)=\{?%?([\w\.\-, %]+)\}?")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_LHS_BDIMS_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")

WIRE_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}
_COLLECTIVE_BASES = tuple(WIRE_FACTOR)


def xla_cost_dict(compiled) -> dict:
    """Normalize ``Compiled.cost_analysis()`` across jax versions.

    Older jax returns one dict; newer versions return a list with one dict
    per partition (often length 1). Always hand back a flat dict ({} when
    the backend reports nothing).
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}


def _shape_elems_bytes(type_str: str) -> tuple[int, int]:
    """(elements, bytes) summed over all arrays in an HLO type string."""
    elems = total = 0
    for dtype, dims in _ARRAY_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        total += n * _DTYPE_BYTES[dtype]
    return elems, total


def _first_array_dims(type_str: str) -> list[int]:
    m = _ARRAY_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str  # operands + attrs tail


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)  # %name -> type string


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        if not line.strip():
            continue
        mc = _COMP_RE.match(line)
        if mc and line.rstrip().endswith("{"):
            cur = Computation(mc.group(1))
            comps[cur.name] = cur
            # parameters from the signature: "name: type, name: type"
            sig = mc.group(2)
            for pm in re.finditer(r"([\w\.\-]+):\s*(\(?[^,()]*(?:\([^)]*\))?"
                                  r"[^,]*\)?)", sig):
                cur.shapes[pm.group(1)] = pm.group(2)
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            name, type_str, op, rest = mi.groups()
            cur.instrs.append(Instr(name, type_str, op, rest))
            cur.shapes[name] = type_str
        elif line.strip() == "}":
            cur = None
    return comps


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    wire_bytes: float = 0.0
    collective_ops: dict = field(default_factory=dict)
    collective_bytes: dict = field(default_factory=dict)


class HloAnalyzer:
    def __init__(self, text: str):
        self.comps = parse_hlo(text)
        m = re.search(r"ENTRY\s+%?([\w\.\-]+)", text)
        self.entry = m.group(1) if m else next(iter(self.comps))

    # -------------------------------------------------------------- flops
    @functools.lru_cache(maxsize=None)
    def flops(self, comp_name: str) -> float:
        comp = self.comps.get(comp_name)
        if comp is None:
            return 0.0
        total = 0.0
        for ins in comp.instrs:
            total += self._instr_flops(comp, ins)
        return total

    def _dot_flops(self, comp: Computation, ins: Instr) -> float:
        out_elems, _ = _shape_elems_bytes(ins.type_str)
        ops = _OPERAND_RE.findall(ins.rest.split("),")[0])
        lhs_shape = _first_array_dims(comp.shapes.get(ops[0], "")) if ops else []
        cdims = _LHS_CDIMS_RE.search(ins.rest)
        contract = 1
        if cdims and lhs_shape:
            for d in cdims.group(1).split(","):
                if d and int(d) < len(lhs_shape):
                    contract *= lhs_shape[int(d)]
        return 2.0 * out_elems * contract

    def _instr_flops(self, comp: Computation, ins: Instr) -> float:
        op = ins.op
        if op == "dot":
            return self._dot_flops(comp, ins)
        if op == "while":
            trip = 1
            mt = _TRIP_RE.search(ins.rest)
            if mt:
                trip = int(mt.group(1))
            body = _BODY_RE.search(ins.rest)
            cond = _COND_RE.search(ins.rest)
            t = 0.0
            if body:
                t += self.flops(body.group(1))
            if cond:
                t += self.flops(cond.group(1))
            return trip * t
        if op in ("fusion", "call", "async-start"):
            mc = _CALLS_RE.search(ins.rest)
            sub = self.flops(mc.group(1)) if mc else 0.0
            if op == "fusion":
                # charge 1 flop/elem for the fused elementwise work
                out_elems, _ = _shape_elems_bytes(ins.type_str)
                sub = max(sub, float(out_elems))
            return sub
        if op == "conditional":
            mb = _BRANCHES_RE.search(ins.rest)
            if mb:
                names = re.findall(r"[\w\.\-]+", mb.group(1))
                return max((self.flops(n) for n in names), default=0.0)
            # true/false form: collect both computations
            names = re.findall(r"(?:true|false)_computation=%?([\w\.\-]+)",
                               ins.rest)
            return max((self.flops(n) for n in names), default=0.0)
        return 0.0

    # -------------------------------------------------------------- bytes
    @functools.lru_cache(maxsize=None)
    def hbm_bytes(self, comp_name: str) -> float:
        comp = self.comps.get(comp_name)
        if comp is None:
            return 0.0
        total = 0.0
        for ins in comp.instrs:
            total += self._instr_bytes(comp, ins)
        return total

    def _operand_bytes(self, comp: Computation, ins: Instr) -> float:
        """Charge only operands that cross the computation boundary
        (parameters / loop-carry reads); values produced by a sibling
        instruction were already charged as that producer's output. This is
        the 'producer-write + boundary-read' traffic model: intermediate
        chains count once, loop-body re-reads count per iteration.
        """
        head = ins.rest.split("),")[0]
        defs = {i.name: i.op for i in comp.instrs}
        total = 0.0
        for name in _OPERAND_RE.findall(head):
            if name not in comp.shapes:
                continue
            producer = defs.get(name)
            if producer is None or producer in ("parameter",
                                                "get-tuple-element"):
                total += _shape_elems_bytes(comp.shapes[name])[1]
        return total

    def _slice_semantics_bytes(self, comp_name: str) -> float | None:
        """If the computation's work is a dynamic-(update-)slice, return the
        actual touched bytes (in-place semantics): 2x the slice/update size.
        None if the computation is not slice-shaped."""
        comp = self.comps.get(comp_name)
        if comp is None:
            return None
        for ins in comp.instrs:
            if ins.op == "dynamic-update-slice":
                ops = _OPERAND_RE.findall(ins.rest.split("),")[0])
                if len(ops) >= 2 and ops[1] in comp.shapes:
                    upd = _shape_elems_bytes(comp.shapes[ops[1]])[1]
                    return 2.0 * upd
        for ins in comp.instrs:
            if ins.op in ("dynamic-slice", "gather"):
                out_b = _shape_elems_bytes(ins.type_str)[1]
                return 2.0 * out_b
        return None

    def _instr_bytes(self, comp: Computation, ins: Instr) -> float:
        op = ins.op
        if op == "while":
            trip = 1
            mt = _TRIP_RE.search(ins.rest)
            if mt:
                trip = int(mt.group(1))
            body = _BODY_RE.search(ins.rest)
            return trip * (self.hbm_bytes(body.group(1)) if body else 0.0)
        if op == "conditional":
            names = re.findall(r"(?:true|false)_computation=%?([\w\.\-]+)",
                               ins.rest)
            mb = _BRANCHES_RE.search(ins.rest)
            if mb:
                names = re.findall(r"[\w\.\-]+", mb.group(1))
            return max((self.hbm_bytes(n) for n in names), default=0.0)
        if op in ("parameter", "constant", "tuple", "get-tuple-element",
                  "bitcast", "copy"):
            # copies of loop carries are buffer aliasing in practice
            return 0.0
        if op == "dynamic-update-slice":
            ops = _OPERAND_RE.findall(ins.rest.split("),")[0])
            if len(ops) >= 2 and ops[1] in comp.shapes:
                return 2.0 * _shape_elems_bytes(comp.shapes[ops[1]])[1]
        if op in ("dynamic-slice", "gather"):
            return 2.0 * _shape_elems_bytes(ins.type_str)[1]
        if op == "fusion":
            mc = _CALLS_RE.search(ins.rest)
            if mc:
                sliced = self._slice_semantics_bytes(mc.group(1))
                if sliced is not None:
                    return sliced
        # fusion-boundary traffic: operands + outputs
        _, out_b = _shape_elems_bytes(ins.type_str)
        return out_b + self._operand_bytes(comp, ins)

    # -------------------------------------------------------- collectives
    def collectives(self, comp_name: str | None = None, mult: float = 1.0,
                    acc: Costs | None = None) -> Costs:
        acc = acc if acc is not None else Costs()
        comp = self.comps.get(comp_name or self.entry)
        if comp is None:
            return acc
        for ins in comp.instrs:
            base = ins.op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVE_BASES and not ins.op.endswith("-done"):
                _, b = _shape_elems_bytes(ins.type_str)
                if ins.op.endswith("-start") and ins.type_str.startswith("("):
                    b = b / 2  # async tuple doubles the type
                acc.collective_ops[base] = acc.collective_ops.get(base, 0) + mult
                acc.collective_bytes[base] = (acc.collective_bytes.get(base, 0)
                                              + mult * b)
                acc.wire_bytes += mult * b * WIRE_FACTOR[base]
            elif ins.op == "while":
                trip = 1
                mt = _TRIP_RE.search(ins.rest)
                if mt:
                    trip = int(mt.group(1))
                body = _BODY_RE.search(ins.rest)
                if body:
                    self.collectives(body.group(1), mult * trip, acc)
            elif ins.op in ("fusion", "call"):
                mc = _CALLS_RE.search(ins.rest)
                if mc:
                    self.collectives(mc.group(1), mult, acc)
            elif ins.op == "conditional":
                names = re.findall(r"(?:true|false)_computation=%?([\w\.\-]+)",
                                   ins.rest)
                mb = _BRANCHES_RE.search(ins.rest)
                if mb:
                    names = re.findall(r"[\w\.\-]+", mb.group(1))
                for n in names:  # upper bound: all branches
                    self.collectives(n, mult, acc)
        return acc

    # ------------------------------------------------------------- public
    def analyze(self) -> Costs:
        c = self.collectives()
        return Costs(flops=self.flops(self.entry),
                     bytes=self.hbm_bytes(self.entry),
                     wire_bytes=c.wire_bytes,
                     collective_ops=c.collective_ops,
                     collective_bytes=c.collective_bytes)


def analyze_hlo(text: str) -> Costs:
    return HloAnalyzer(text).analyze()
