"""Batched serving driver: prefill + decode loop, exact or PQ-KV cache.

Serves the smoke-scale model end-to-end on CPU (greedy decode over batched
requests); the production decode step (128-way batch, 32k context, PQ cache)
is exercised via --dry-run which lowers/compiles it on the 16x16 mesh.

  python -m repro.launch.serve --arch qwen3-1.7b --smoke --tokens 16
  python -m repro.launch.serve --arch qwen1.5-32b --dry-run
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import kvcache as kvc
from repro.models import model as model_lib


def calibrate_pq_cache(key, params, cfg, batch, max_seq, sample_tokens=256):
    """Calibrate PQ codebooks from K/V activations on a random prompt."""
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, sample_tokens), np.int32))
    exact_cfg = cfg.replace(kv_pq=False)
    _, cache = model_lib.prefill(params, toks, exact_cfg, max_seq=sample_tokens)
    m = cfg.resolved_kv_pq_m
    ks, vs = cache.k, cache.v            # (L, B, S, KV, hd)
    l, b, s, kv, hd = ks.shape
    k_cb = jax.vmap(lambda x, k: kvc.calibrate_kv_codebooks(k, x, m))(
        ks.reshape(l, b * s, kv, hd),
        jax.random.split(key, l))
    v_cb = jax.vmap(lambda x, k: kvc.calibrate_kv_codebooks(k, x, m))(
        vs.reshape(l, b * s, kv, hd),
        jax.random.split(jax.random.fold_in(key, 1), l))
    empty = model_lib.init_cache(cfg, batch, max_seq)
    return kvc.PQKVCache(empty.k_codes, empty.v_codes,
                         k_cb.astype(jnp.bfloat16), v_cb.astype(jnp.bfloat16))


def serve_batch(cfg, params, prompts: jax.Array, gen_tokens: int,
                max_seq: int | None = None, key=None):
    """Greedy-decode gen_tokens for a (B, S) batch of prompts."""
    b, s = prompts.shape
    max_seq = max_seq or (s + gen_tokens)
    pq_cache = None
    if cfg.kv_pq and cfg.block_type == "attn":
        pq_cache = calibrate_pq_cache(
            key if key is not None else jax.random.PRNGKey(0),
            params, cfg, b, max_seq)
    logits, cache = model_lib.prefill(params, prompts, cfg, max_seq=max_seq,
                                      pq_cache=pq_cache)
    step = jax.jit(lambda p, c, t, pos: model_lib.decode_step(p, c, t, pos, cfg))
    out = [jnp.argmax(logits[:, :cfg.vocab], axis=-1)]
    for i in range(gen_tokens - 1):
        pos = jnp.full((b,), s + i, jnp.int32)
        logits, cache = step(params, cache, out[-1].astype(jnp.int32), pos)
        out.append(jnp.argmax(logits[:, :cfg.vocab], axis=-1))
    return jnp.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=8)
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--shape", default="decode_32k")
    args = ap.parse_args()

    if args.dry_run:
        from repro.launch import dryrun
        dryrun.run_cell(args.arch, args.shape,
                        "multipod" if args.multi_pod else "pod")
        return

    cfg = (configs.get_smoke_config(args.arch) if args.smoke
           else configs.get_config(args.arch))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len), np.int32))
    params = model_lib.init_lm(jax.random.PRNGKey(0), cfg)
    t0 = time.perf_counter()
    tokens = serve_batch(cfg, params, prompts, args.tokens)
    dt = time.perf_counter() - t0
    print(f"[serve] generated {tokens.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.tokens / dt:.1f} tok/s)")
    print(np.asarray(tokens))


if __name__ == "__main__":
    main()
