import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "REPRO_XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# NOTE: the two lines above MUST run before any jax import (jax locks the
# device count on first init), hence no `from __future__` in this module.
DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
CPU devices stand in for the production mesh; jit(...).lower(SDS).compile()
must succeed for the 16x16 single-pod AND the 2x16x16 multi-pod mesh for
every assigned architecture x input shape. Emits memory_analysis /
cost_analysis / collective-bytes JSON per cell for §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all [--mesh both] [--out experiments/dryrun]
"""

import argparse
import functools
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import mesh as mesh_lib
from repro.launch import roofline as rl
from repro.launch import sharding as shd
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.train import optimizer as opt_lib
from repro.train import train_loop

# (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

SUBQUADRATIC = ("mamba2", "rwkv6")  # block types allowed to run long_500k


def cell_supported(cfg: ModelConfig, shape: str) -> tuple[bool, str]:
    if shape == "long_500k" and cfg.block_type not in SUBQUADRATIC:
        return False, ("SKIP: long_500k needs sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention (see DESIGN.md)")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, batch: int, seq: int) -> tuple[dict, dict]:
    """(ShapeDtypeStructs, logical axes) for a training batch."""
    specs = {
        "tokens": _sds((batch, seq), jnp.int32),
        "targets": _sds((batch, seq), jnp.int32),
        "mask": _sds((batch, seq), jnp.float32),
    }
    axes = {
        "tokens": ("batch", "seq"),
        "targets": ("batch", "seq"),
        "mask": ("batch", "seq"),
    }
    if cfg.frontend != "none":
        specs["frontend_embeds"] = _sds((batch, cfg.frontend_len, cfg.d_model),
                                        jnp.dtype(cfg.dtype))
        axes["frontend_embeds"] = ("batch", None, "embed")
    return specs, axes


def serving_rules(cfg: ModelConfig, mesh) -> dict:
    """Serving shards params TP-only (replicated over data) when they fit:
    FSDP-style weight all-gathers are amortized over 1M tokens in training
    but dominate a single decode step. Falls back to FSDP sharding when
    bf16 params / model-axis exceed the HBM budget (dbrx-132b).

    Archs whose head count does not divide the model axis (qwen1.5: 40H,
    llama4: 40H, internvl2: 14H) would otherwise replicate ALL attention
    weight+compute; for those we shard head_dim instead, and shard the
    PQ-KV codes across sub-quantizers ("pq_m") — sub-space parallelism for
    the paper's ADC: each chip scans its own nibble planes and one small
    int32 partial-accumulation all-reduce merges them."""
    rules = dict(shd.DEFAULT_RULES)
    param_bytes = cfg.param_count() * 2  # bf16
    model_size = mesh.shape.get("model", 1)
    if param_bytes / model_size <= 12e9:
        rules["embed"] = None
    if cfg.n_heads and cfg.n_heads % model_size != 0:
        rules["head_dim"] = "model"
        if cfg.kv_pq:
            rules["pq_m"] = "model"
            rules["kv_seq"] = None
    return rules


def cell_rules(cfg: ModelConfig, shape_name: str, mesh) -> dict:
    kind = SHAPES[shape_name][2]
    return (dict(shd.DEFAULT_RULES) if kind == "train"
            else serving_rules(cfg, mesh))


def build_cell(cfg: ModelConfig, shape_name: str, mesh, rules=None):
    """Returns (fn, arg_specs, in_shardings) ready for jit().lower()."""
    seq, batch, kind = SHAPES[shape_name]
    rules = rules or cell_rules(cfg, shape_name, mesh)
    pspecs = model_lib.lm_shapes(cfg)
    paxes = model_lib.lm_axes(cfg)
    pshard = shd.tree_shardings(pspecs, paxes, mesh, rules)

    if kind == "train":
        ocfg = opt_lib.AdamWConfig(total_steps=1000)
        step = train_loop.make_train_step(cfg, ocfg, microbatches=1)
        ostate = opt_lib.state_shapes(pspecs)
        oshard = shd.tree_shardings(
            ostate, opt_lib.state_axes(paxes), mesh, rules)
        state_sds = train_loop.TrainState(pspecs, ostate, None)
        state_shd = train_loop.TrainState(pshard, oshard, None)
        bspecs, baxes = batch_specs(cfg, batch, seq)
        bshard = shd.tree_shardings(bspecs, baxes, mesh, rules)
        return step, (state_sds, bspecs), (state_shd, bshard)

    if kind == "prefill":
        tok_sds = _sds((batch, seq), jnp.int32)
        tok_shd = shd.named_sharding((batch, seq), ("batch", "seq"), mesh, rules)
        if cfg.kv_pq:
            cache_sds = jax.eval_shape(
                lambda: model_lib.init_cache(cfg, batch, seq))
            cache_shd = shd.tree_shardings(cache_sds, model_lib.cache_axes(cfg),
                                           mesh, rules)
            fn = lambda p, t, c: model_lib.prefill(p, t, cfg, max_seq=seq,
                                                   pq_cache=c)
            return fn, (pspecs, tok_sds, cache_sds), (pshard, tok_shd, cache_shd)
        fn = lambda p, t: model_lib.prefill(p, t, cfg, max_seq=seq)
        return fn, (pspecs, tok_sds), (pshard, tok_shd)

    # decode: one new token against a seq-long cache
    cache_sds = jax.eval_shape(lambda: model_lib.init_cache(cfg, batch, seq))
    cache_shd = shd.tree_shardings(cache_sds, model_lib.cache_axes(cfg),
                                   mesh, rules)
    tok_sds = _sds((batch,), jnp.int32)
    pos_sds = _sds((batch,), jnp.int32)
    tok_shd = shd.named_sharding((batch,), ("batch",), mesh, rules)
    fn = lambda p, c, t, pos: model_lib.decode_step(p, c, t, pos, cfg)
    return fn, (pspecs, cache_sds, tok_sds, pos_sds), \
        (pshard, cache_shd, tok_shd, tok_shd)


def run_cell(arch: str, shape_name: str, mesh_name: str,
             out_dir: str | None = None, kv_override: str = "auto",
             verbose: bool = True) -> dict:
    cfg = configs.get_config(arch)
    if kv_override == "exact":
        cfg = cfg.replace(kv_pq=False)
    elif kv_override == "pq":
        cfg = cfg.replace(kv_pq=True)
    seq, batch, kind = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape_name)
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "kind": kind, "seq": seq, "batch": batch,
              "kv_override": None if kv_override == "auto" else kv_override,
              "kv_pq": cfg.kv_pq and kind in ("decode", "prefill"),
              "params": cfg.param_count(),
              "active_params": cfg.active_param_count()}
    if not ok:
        result["status"] = "skipped"
        result["reason"] = why
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: {why}")
        return result

    mesh = mesh_lib.make_production_mesh(multi_pod=(mesh_name == "multipod"))
    rules = cell_rules(cfg, shape_name, mesh)
    t0 = time.time()
    with shd.use_mesh(mesh, rules):
        fn, arg_specs, in_shardings = build_cell(cfg, shape_name, mesh, rules)
        lowered = jax.jit(fn, in_shardings=in_shardings).lower(*arg_specs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    from repro.launch.hlo_analysis import xla_cost_dict
    cost = xla_cost_dict(compiled)
    result["lower_s"] = round(t_lower, 2)
    result["compile_s"] = round(t_compile, 2)
    result["status"] = "ok"
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            result[attr] = getattr(mem, attr, None)
        args_b = result.get("argument_size_in_bytes") or 0
        temp_b = result.get("temp_size_in_bytes") or 0
        result["bytes_per_device"] = args_b + temp_b
    # trip-count-aware HLO analysis (XLA's cost_analysis counts while bodies
    # once — see launch/hlo_analysis.py); XLA numbers kept for reference
    from repro.launch import hlo_analysis as ha
    costs = ha.analyze_hlo(compiled.as_text())
    result["hlo_flops_per_dev"] = costs.flops
    result["hlo_bytes_per_dev"] = costs.bytes
    result["xla_cost_analysis"] = {
        "flops": float(cost.get("flops", 0.0)) if cost else 0.0,
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)) if cost else 0.0,
    }
    result["collectives"] = {"ops": costs.collective_ops,
                             "bytes_by_op": costs.collective_bytes,
                             "wire_bytes_per_dev": costs.wire_bytes}

    roof = rl.Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name,
        chips=mesh.devices.size,
        hlo_flops_per_dev=costs.flops,
        hlo_bytes_per_dev=costs.bytes,
        wire_bytes_per_dev=costs.wire_bytes,
        model_flops_total=rl.model_flops(cfg, kind, batch, seq),
        collectives=costs.collective_ops,
    )
    result["roofline"] = roof.to_dict()
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
              f"(lower {t_lower:.1f}s, compile {t_compile:.1f}s, "
              f"bottleneck={roof.bottleneck}, "
              f"t_bound={roof.t_bound*1e3:.2f}ms, mfu_bound={roof.mfu_bound:.3f})")

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = "" if kv_override == "auto" else f"_{kv_override}"
        path = os.path.join(out_dir,
                            f"{arch}_{shape_name}_{mesh_name}{suffix}.json")
        with open(path, "w") as f:
            json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--kv", default="auto", choices=["auto", "exact", "pq"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = list(configs.ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_name in meshes:
                try:
                    r = run_cell(arch, shape, mesh_name, out_dir=args.out,
                                 kv_override=args.kv)
                    if r["status"] not in ("ok", "skipped"):
                        failures.append((arch, shape, mesh_name))
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, shape, mesh_name, str(e)[:200]))
    if failures:
        print(f"[dryrun] FAILURES: {failures}")
        sys.exit(1)
    print("[dryrun] all requested cells passed")


if __name__ == "__main__":
    main()
