"""Launchers: mesh construction, sharding rules, dry-run, training, serving."""
