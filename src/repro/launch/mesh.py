"""Production mesh construction (single-pod 16x16 and 2-pod 2x16x16).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / laptop runs)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
