"""Logical-axis sharding: partition rules, divisibility fallback, contexts.

Model code annotates arrays with *logical* axis names ("batch", "embed",
"heads", ...). A rules table maps logical names to mesh axes. The mapping is
applied
  - to parameters when building pjit in_shardings (via the axes pytree), and
  - to activations via `constrain(x, ...)` which becomes
    `with_sharding_constraint` when a mesh context is active and a no-op in
    single-device smoke tests.

Divisibility fallback: if a dimension is not divisible by the product of its
mapped mesh axes (e.g. 14 heads on a 16-wide model axis), the mapping for
that dimension is dropped (replicated) instead of erroring — this is what
lets one rule table serve all ten architectures.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis -> mesh axis (or tuple of mesh axes, or None = replicate).
# "fsdp" style weight sharding rides the data axis; TP rides "model".
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),       # data parallel over pod+data
    # NOTE: "seq" defaults to replicated. A Megatron-SP-style "seq": "model"
    # was the v0 default; the dry-run roofline showed it reshards the
    # residual stream inside the layer/chunk loops (1600+ all-to-alls/step,
    # 370 GB/device wire on qwen3 train_4k) — group remat is the cheaper fix
    # for activation memory. See EXPERIMENTS.md §Perf iteration 1.
    "seq": None,
    # FSDP/ZeRO-3 via one rule: weight matrices shard their "embed" dim over
    # the data axis (activations keep embed replicated because their "batch"
    # dim consumes the data axis first — logical_to_spec never reuses axes).
    # Gradients then reduce-scatter instead of all-reduce, and optimizer
    # state is sharded 256-way. Without this, qwen1.5-32b+ cannot fit
    # params+moments on a 16 GB v5e.
    "embed": "data",
    "heads": "model",               # TP over attention heads
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",                 # TP over FFN hidden
    "vocab": "model",               # TP over vocab (embedding + logits)
    "experts": "model",             # EP over experts
    "expert_mlp": None,
    "fsdp": "data",                 # parameter sharding over the data axis
    "ssm_heads": "model",           # TP over SSM heads
    "ssm_state": None,
    "conv": None,
    "lora": None,
    "kv_seq": "model",              # decode KV cache: shard context over model
    "stack": None,                  # scan-over-layers leading axis
    "pq_m": None,
    None: None,
}

_ctx = threading.local()


def _get_ctx() -> tuple[Mesh | None, Mapping[str, Any] | None]:
    return getattr(_ctx, "mesh", None), getattr(_ctx, "rules", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Mapping[str, Any] | None = None):
    """Activate a mesh + rules for `constrain` and spec helpers."""
    old = _get_ctx()
    _ctx.mesh, _ctx.rules = mesh, dict(rules or DEFAULT_RULES)
    try:
        with mesh:
            yield
    finally:
        _ctx.mesh, _ctx.rules = old


def _axis_size(mesh: Mesh, mesh_axes) -> int:
    if mesh_axes is None:
        return 1
    if isinstance(mesh_axes, str):
        mesh_axes = (mesh_axes,)
    size = 1
    for a in mesh_axes:
        size *= mesh.shape.get(a, 1)
    return size


def _resolve_axis(mesh: Mesh, rules: Mapping[str, Any], logical: str | None):
    """Logical name -> mesh axes entry, dropping axes missing from the mesh."""
    entry = rules.get(logical, None)
    if entry is None:
        return None
    axes = (entry,) if isinstance(entry, str) else tuple(entry)
    axes = tuple(a for a in axes if a in mesh.shape)
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]

def logical_to_spec(shape: Sequence[int], logical_axes: Sequence[str | None],
                    mesh: Mesh, rules: Mapping[str, Any]) -> P:
    """Build a PartitionSpec with divisibility fallback per dimension."""
    assert len(shape) == len(logical_axes), (shape, logical_axes)
    used: set[str] = set()
    out = []
    for dim, name in zip(shape, logical_axes):
        entry = _resolve_axis(mesh, rules, name)
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        axes = tuple(a for a in axes if a not in used)
        size = _axis_size(mesh, axes)
        if size <= 1 or dim % size != 0:
            # try a prefix of the axes tuple before giving up entirely
            while axes and (dim % _axis_size(mesh, axes) != 0):
                axes = axes[:-1]
            if not axes:
                out.append(None)
                continue
        used.update(axes)
        out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def named_sharding(shape: Sequence[int], logical_axes: Sequence[str | None],
                   mesh: Mesh, rules: Mapping[str, Any] | None = None
                   ) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(shape, logical_axes, mesh,
                                               rules or DEFAULT_RULES))


def constrain(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a context."""
    mesh, rules = _get_ctx()
    if mesh is None or x.ndim != len(logical_axes):
        return x
    spec = logical_to_spec(x.shape, logical_axes, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _axes_leaf(x) -> bool:
    """An axes leaf is None or a flat tuple of axis names (not a NamedTuple
    of sub-trees — those have tuple-valued fields and recurse)."""
    return x is None or (
        isinstance(x, tuple)
        and all(e is None or isinstance(e, str) for e in x))


def tree_shardings(shapes_tree: Any, axes_tree: Any, mesh: Mesh,
                   rules: Mapping[str, Any] | None = None) -> Any:
    """Map a pytree of ShapeDtypeStructs + a matching axes pytree to
    NamedShardings (pjit in_shardings for params/opt state)."""
    rules = rules or DEFAULT_RULES
    flat_axes, axes_def = jax.tree.flatten(axes_tree, is_leaf=_axes_leaf)
    flat_shapes = axes_def.flatten_up_to(shapes_tree)

    def one(sds, axes):
        if axes is None:
            return NamedSharding(mesh, P())
        return named_sharding(sds.shape, axes, mesh, rules)

    return jax.tree.unflatten(
        axes_def, [one(s, a) for s, a in zip(flat_shapes, flat_axes)])
