"""Roofline report generator: experiments/dryrun/*.json -> markdown tables."""
from __future__ import annotations

import glob
import json
import os


def load_cells(out_dir: str = "experiments/dryrun") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def _fmt_t(seconds: float) -> str:
    if seconds >= 1.0:
        return f"{seconds:.2f}s"
    return f"{seconds*1e3:.2f}ms"


def roofline_table(cells: list[dict], mesh: str = "pod") -> str:
    rows = ["| arch | shape | status | t_compute | t_memory | t_collective | "
            "bottleneck | useful FLOPs | MFU bound |",
            "|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if c["mesh"] != mesh or c.get("kv_override"):
            continue
        if c["status"] == "skipped":
            rows.append(f"| {c['arch']} | {c['shape']} | SKIP (full attn @500k) "
                        "| — | — | — | — | — | — |")
            continue
        r = c["roofline"]
        rows.append(
            f"| {c['arch']} | {c['shape']} | ok | {_fmt_t(r['t_compute_s'])} "
            f"| {_fmt_t(r['t_memory_s'])} | {_fmt_t(r['t_collective_s'])} "
            f"| {r['bottleneck']} | {r['useful_flops_ratio']:.2f} "
            f"| {r['mfu_bound']:.3f} |")
    return "\n".join(rows)


def dryrun_table(cells: list[dict]) -> str:
    rows = ["| arch | shape | mesh | status | compile | params | "
            "collective ops (trip-weighted) |",
            "|---|---|---|---|---|---|---|"]
    for c in cells:
        if c["status"] == "skipped":
            rows.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | SKIP | "
                        "— | — | — |")
            continue
        ops = c.get("collectives", {}).get("ops", {})
        ops_s = ", ".join(f"{k}:{int(v)}" for k, v in sorted(ops.items()))
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | {c['status']} "
            f"| {c.get('compile_s', 0):.0f}s | {c.get('params', 0)/1e9:.1f}B "
            f"| {ops_s or '-'} |")
    return "\n".join(rows)


def main():
    cells = load_cells()
    print("## Roofline (single-pod 16x16)\n")
    print(roofline_table(cells, "pod"))
    print("\n## Dry-run matrix\n")
    print(dryrun_table(cells))


if __name__ == "__main__":
    main()
