"""Async batched ANN serving on top of ``repro.engine`` (docs/serving.md).

A dynamic micro-batching layer that turns ragged request streams into the
static shape buckets the fused single-jit engine pipeline wants:

  - ``Batcher``       thread-safe queue; groups by ``k``, pads to buckets
  - ``ServingLoop``   dispatch thread; futures + asyncio entry points
  - ``ServeResult``   per-request results + work counters + latency
  - ``StatsRegistry`` / ``TenantStats``  per-caller accounting

Quickstart::

    from repro.serving import ServingLoop
    loop = ServingLoop(engine, rerank_mult=4).start(warmup=True)
    fut = loop.submit(query, k=10, tenant="alice")
    print(fut.result().ids)
    loop.stop()
"""
from repro.serving.batcher import (  # noqa: F401
    DEFAULT_BUCKETS,
    Batcher,
    Request,
    bucket_for,
    pad_to_bucket,
)
from repro.serving.errors import (  # noqa: F401
    DeadlineExceeded,
    LoopClosed,
    NotPrimary,
    Overloaded,
)
from repro.serving.loop import LoopMetrics, ServeResult, ServingLoop  # noqa: F401
from repro.serving.stats import StatsRegistry, TenantStats  # noqa: F401
