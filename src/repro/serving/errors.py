"""Typed serving failures: overload shedding, deadlines, shutdown.

All subclass ``RuntimeError`` so pre-existing callers catching broadly
keep working; new callers branch on the specific type (docs/serving.md,
ops runbook). These are *expected* degraded-mode signals, not bugs: a
bounded queue must refuse work somewhere, and a typed refusal at submit
beats an unbounded queue falling over later.
"""
from __future__ import annotations


class Overloaded(RuntimeError):
    """The batcher's bounded queue (``max_pending``) is full — the request
    was shed at submit time, costing the caller nothing but this error.
    Counted in ``LoopMetrics.rejects`` and per-tenant ``TenantStats.rejects``."""


class DeadlineExceeded(RuntimeError):
    """The request's deadline expired while it was still queued; it was
    failed before burning a batch slot (it never reached ``search_jit``).
    Counted in ``LoopMetrics.deadline_misses``."""


class LoopClosed(RuntimeError):
    """The serving loop (or its batcher) is shut down: submits are refused
    and ``close()`` fails still-pending futures with this instead of
    leaving callers blocked forever."""


class NotPrimary(RuntimeError):
    """A mutation reached a standby loop: standbys replay the primary's
    shipped WAL and serve READS only — accepting a local write would fork
    the replicated history. The caller should route the write to the
    primary (or promote this standby first — docs/serving.md failover
    runbook). Queries keep working throughout."""
