"""Dynamic micro-batching: ragged request streams -> static shape buckets.

The fused engine pipeline (``SearchEngine.search_jit``) compiles one XLA
program per distinct query-batch shape. A serving workload is ragged — one
request here, 40 there — so feeding raw arrival sizes to the engine would
recompile constantly. The ``Batcher`` absorbs the raggedness:

  - requests queue up (thread-safe, FIFO);
  - a dispatcher pulls the oldest request's ``k``-group, waiting up to
    ``max_wait_s`` for the batch to fill (classic latency/throughput knob);
  - the group is padded with zero queries up to the smallest **shape
    bucket** that fits (default Q in (1, 8, 32, 128)).

Only bucket shapes ever reach the engine, so steady-state serving compiles
at most once per (bucket, k) and padding rows are sliced away before any
caller sees results (tested: padded queries cannot leak).

Requests with different ``k`` never share a batch — ``k`` is a static shape
knob of the fused pipeline. Mixed-``k`` streams simply form per-``k`` groups.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import Future

import numpy as np

from repro.serving.errors import DeadlineExceeded, LoopClosed, Overloaded

DEFAULT_BUCKETS = (1, 8, 32, 128)


def bucket_for(n: int, buckets: tuple[int, ...] = DEFAULT_BUCKETS) -> int:
    """Smallest bucket >= n. n must not exceed the largest bucket."""
    for b in buckets:
        if n <= b:
            return b
    raise ValueError(f"batch of {n} exceeds largest bucket {buckets[-1]}")


def pad_to_bucket(queries: np.ndarray, bucket: int) -> np.ndarray:
    """(n, D) -> (bucket, D) f32, zero rows past n (n <= bucket).

    Zero rows are *real* queries as far as the kernel is concerned — they
    cost work but their results are never surfaced; correctness never
    depends on the pad content.
    """
    n, d = queries.shape
    if n > bucket:
        raise ValueError(f"{n} queries do not fit bucket {bucket}")
    out = np.zeros((bucket, d), np.float32)
    out[:n] = queries
    return out


@dataclasses.dataclass
class Request:
    """One queued search request."""

    query: np.ndarray   # (D,) f32
    k: int
    tenant: str
    future: Future
    t_submit: float     # time.monotonic() at enqueue
    namespace: int = -1  # engine namespace id, -1 = unrestricted; namespaces
    #                      are traced per-row, so mixed-namespace batches
    #                      share one dispatch (docs/filtering.md)
    deadline: float | None = None  # absolute time.monotonic() past which the
    #                      request is failed instead of dispatched; None =
    #                      wait forever (docs/serving.md)


class Batcher:
    """Thread-safe request queue + shape-bucket batch former.

    ``submit`` is called from any number of caller threads; ``next_batch``
    from the single serving-loop thread. ``max_wait_s`` bounds how long the
    oldest pending request waits for co-riders: 0 dispatches immediately
    (latency-optimal), larger values trade queueing delay for occupancy.
    """

    def __init__(self, buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 max_wait_s: float = 0.002, max_pending: int | None = None):
        if not buckets or list(buckets) != sorted(set(buckets)):
            raise ValueError(f"buckets must be ascending and unique: {buckets}")
        if buckets[0] < 1:
            raise ValueError(f"buckets must be >= 1: {buckets}")
        if max_pending is not None and max_pending < 1:
            raise ValueError(f"max_pending must be >= 1, got {max_pending}")
        self.buckets = tuple(int(b) for b in buckets)
        self.max_wait_s = float(max_wait_s)
        # bounded admission (docs/serving.md): beyond this many queued
        # requests, submit sheds load with a typed Overloaded instead of
        # letting the queue (and every caller's latency) grow without limit.
        # None = unbounded, the pre-hardening behavior.
        self.max_pending = None if max_pending is None else int(max_pending)
        self._queue: deque[Request] = deque()
        self._cond = threading.Condition()
        self._closed = False
        self.rejects = 0          # submits shed by the max_pending bound
        self.deadline_misses = 0  # queued requests failed past their deadline

    # -- producer side ------------------------------------------------------

    def submit(self, query, k: int = 10, tenant: str = "default",
               namespace: int = -1, deadline_s: float | None = None) -> Future:
        """Enqueue one query; the future resolves to a ``loop.ServeResult``.

        ``deadline_s`` (relative seconds) bounds the total queue wait: a
        request still undispatched past it is failed with
        ``DeadlineExceeded`` before it can burn a batch slot. Raises
        ``Overloaded`` immediately when the queue is at ``max_pending``.
        """
        q = np.asarray(query, np.float32)
        if q.ndim != 1:
            raise ValueError(f"submit takes a single (D,) query, got {q.shape}")
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {deadline_s}")
        now = time.monotonic()
        req = Request(query=q, k=int(k), tenant=str(tenant), future=Future(),
                      t_submit=now, namespace=int(namespace),
                      deadline=None if deadline_s is None else now + deadline_s)
        with self._cond:
            if self._closed:
                raise LoopClosed("batcher is closed")
            if (self.max_pending is not None
                    and len(self._queue) >= self.max_pending):
                self.rejects += 1
                raise Overloaded(
                    f"queue at max_pending={self.max_pending}; request shed")
            self._queue.append(req)
            self._cond.notify_all()
        return req.future

    def close(self) -> None:
        """Reject further submits; pending requests can still be drained."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    def reopen(self) -> None:
        """Accept submits again after ``close`` (loop restart)."""
        with self._cond:
            self._closed = False

    def pending(self) -> int:
        with self._cond:
            return len(self._queue)

    # -- consumer side (serving loop thread) --------------------------------

    def next_batch(self, timeout: float | None = None) -> list[Request] | None:
        """Dequeue the next dispatchable batch, or None on timeout.

        Picks the oldest request, waits up to ``max_wait_s`` (measured from
        that request's submit time) for more same-``k`` requests, then
        returns up to ``max(buckets)`` of them in FIFO order. Different-``k``
        requests stay queued and head the next batch.
        """
        cap = self.buckets[-1]
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while True:
                self._purge_expired_locked()
                if self._queue:
                    break
                if self._closed:
                    return None
                wait = None if deadline is None else deadline - time.monotonic()
                if wait is not None and wait <= 0:
                    return None
                self._cond.wait(wait)

            head = self._queue[0]
            batch_deadline = head.t_submit + self.max_wait_s
            while (self._count_k(head.k) < cap
                   and not self._closed
                   and (remaining := batch_deadline - time.monotonic()) > 0):
                self._cond.wait(remaining)

            # expire again after the co-rider wait: a request whose deadline
            # passed while the batch was filling must not occupy a slot (it
            # would reach search_jit only to have its result thrown away)
            self._purge_expired_locked()
            if not self._queue:
                return None
            head = self._queue[0]
            out: list[Request] = []
            kept: deque[Request] = deque()
            for req in self._queue:
                if req.k == head.k and len(out) < cap:
                    out.append(req)
                else:
                    kept.append(req)
            self._queue = kept
            return out

    def _purge_expired_locked(self) -> None:
        """Fail every queued request past its deadline (caller holds _cond)."""
        now = time.monotonic()
        if not any(r.deadline is not None and r.deadline < now
                   for r in self._queue):
            return
        kept: deque[Request] = deque()
        for req in self._queue:
            if req.deadline is not None and req.deadline < now:
                self.deadline_misses += 1
                if not req.future.done():
                    req.future.set_exception(DeadlineExceeded(
                        f"deadline expired after "
                        f"{now - req.t_submit:.3f}s in queue"))
            else:
                kept.append(req)
        self._queue = kept

    def _count_k(self, k: int) -> int:
        return sum(1 for r in self._queue if r.k == k)

    # -- batch forming -------------------------------------------------------

    def form(self, requests: list[Request]) -> tuple[np.ndarray, int]:
        """Stack + pad a batch: -> ((bucket, D) f32 queries, bucket)."""
        q = np.stack([r.query for r in requests])
        bucket = bucket_for(q.shape[0], self.buckets)
        return pad_to_bucket(q, bucket), bucket
