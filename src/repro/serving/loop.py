"""The serving loop: batcher -> fused engine dispatch -> futures + accounting.

One daemon thread owns the engine: it drains the ``Batcher``, pads each
batch to its shape bucket, runs ``SearchEngine.search_jit`` (the whole
coarse -> scan -> re-rank -> merge pipeline as ONE ``jax.jit`` dispatch),
then splits results/stats back to per-request futures with a single
device->host sync per batch. Padding rows are sliced off before anything
reaches a caller or the ``StatsRegistry``.

Callers interact through futures (``submit``) or asyncio (``asearch``):

    loop = ServingLoop(engine, rerank_mult=4)
    loop.start(warmup=True)        # pre-compile every (bucket, k) pair
    fut = loop.submit(q, k=10, tenant="alice")
    res = fut.result()             # ServeResult: dists, ids, stats, latency

``warmup`` pushes one dummy batch through every shape bucket so steady-state
traffic never sees a compile; ``metrics()`` exposes batch occupancy and the
fused-jit compile count to verify exactly that.

Filtered & namespaced serving (docs/filtering.md): the loop can hold a
process-wide attribute filter bitmap (``filter_bits`` / ``set_filter``) and
each request can carry a ``namespace`` id. Both ride the dispatch as traced
values — mixed-namespace batches share buckets and compiles, and the per-row
``rows_filtered`` counter flows into ``ServeResult`` and ``TenantStats``.

Live mutation (docs/mutability.md): ``upsert`` / ``delete`` / ``compact``
forward to the engine, whose epoch-versioned snapshot swap means in-flight
batches finish on the retiring epoch while the next dispatch reads the new
one — queries and mutations interleave with zero failed futures.
``metrics()`` reports the engine's current ``epoch`` and the cumulative
``rows_tombstoned`` the loop's queries probed past.

Replication (docs/persistence.md#replication): with a ``transport`` the
loop joins a primary/standby pair. ``role='primary'`` ships closed WAL
segments on a background thread and fences its writer against newer
terms; ``role='standby'`` replays the shipped stream into its engine
(serving reads the whole time), sheds writes with ``NotPrimary``, watches
the primary's heartbeat, and on ``promote()`` drains replay, bumps the
fencing term, snapshots, and starts accepting mutations — the failover
runbook in docs/serving.md walks the full drill.
"""
from __future__ import annotations

import asyncio
import threading
import time
from concurrent.futures import Future
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro import persist
from repro.engine import SearchEngine, fused_cache_size
from repro.kernels.ops import (autotune_cache_size, load_autotune_cache,
                               save_autotune_cache)
from repro.serving.batcher import DEFAULT_BUCKETS, Batcher, Request
from repro.serving.errors import LoopClosed, NotPrimary, Overloaded
from repro.serving.stats import StatsRegistry


class ServeResult(NamedTuple):
    """What one request's future resolves to."""

    dists: np.ndarray     # (k,) f32 ascending
    ids: np.ndarray       # (k,) i32 global ids, -1 = no candidate
    lists_probed: int     # this query's QueryStats row
    codes_scanned: int
    reranked: int
    rows_filtered: int    # probed rows the loop's filter excluded (0 if none)
    rows_tombstoned: int  # probed slots holding tombstones (0 if none)
    lists_pruned: int     # coarse probes the margin policy dropped (0 under
    #                       probe_policy='fixed' — docs/anytime.md)
    tiles_skipped: int    # scan tiles the early-exit bound skipped (0
    #                       without early_exit)
    latency_s: float      # submit -> results on host


class LoopMetrics(NamedTuple):
    """Point-in-time serving-loop counters (see ``ServingLoop.metrics``)."""

    batches: int           # dispatches issued
    rows_served: int       # real queries completed
    rows_padded: int       # zero-pad rows dispatched alongside them
    occupancy: float       # rows_served / (rows_served + rows_padded)
    compiles: int          # compiles triggered by THIS loop (incl. warmup)
    bucket_counts: dict    # bucket size -> dispatch count
    autotuned: int         # autotune sweeps THIS loop's dispatches triggered
    #                        (incl. warmup; only grows when scan_impl='auto'
    #                        or rerank_impl='auto' meets a new shape
    #                        signature)
    epoch: int             # the engine's mutation epoch at snapshot time
    rows_tombstoned: int   # probed tombstone slots summed over served rows
    lists_pruned: int      # margin-pruned probes summed over served rows
    tiles_skipped: int     # early-exited scan tiles summed over served rows
    auto_compactions: int  # compactions the loop's tombstone-ratio policy
    #                        triggered itself (0 with compact_at=None)
    rejects: int           # submits shed by the bounded queue (Overloaded;
    #                        0 with max_pending=None — docs/serving.md)
    deadline_misses: int   # queued requests failed past their deadline
    #                        before reaching a dispatch slot
    checkpoints: int       # background snapshots written (0 without
    #                        snapshot_dir — docs/persistence.md)
    role: str = "primary"  # 'primary' | 'standby' (docs/persistence.md
    #                        #replication; standbys shed writes, serve reads)
    term: int = 0          # fencing term this loop writes/replays under
    replication_lag_seqs: int = 0    # standby: acked records not yet applied
    replication_lag_s: float = 0.0   # standby: age of that primary heartbeat
    segments_shipped: int = 0        # primary: WAL segments published
    records_replayed: int = 0        # standby: records applied from the
    #                                  shipped stream


class ServingLoop:
    """Dynamic micro-batching server around one ``SearchEngine``.

    ``nprobe`` / ``rerank_mult`` are fixed per loop (they are static knobs of
    the fused pipeline; run one loop per serving configuration). ``k`` stays
    per-request — the batcher groups requests by ``k``.
    """

    def __init__(self, engine: SearchEngine, *,
                 batcher: Batcher | None = None,
                 buckets: tuple[int, ...] = DEFAULT_BUCKETS,
                 max_wait_s: float = 0.002,
                 nprobe: int | None = None, rerank_mult: int | None = None,
                 stats: StatsRegistry | None = None,
                 warmup_cache: str | None = None,
                 filter_bits=None,
                 margin_tau: float | None = None,
                 compact_at: float | None = None,
                 max_pending: int | None = None,
                 snapshot_dir: str | None = None,
                 snapshot_every: float = 30.0,
                 role: str = "primary",
                 transport=None,
                 ship_every: float = 0.05,
                 poll_every: float = 0.02,
                 heartbeat_timeout: float | None = None,
                 on_failover=None,
                 standby_start_seq: int = 0):
        self.engine = engine
        # durable serving (docs/persistence.md): with snapshot_dir set the
        # loop makes the engine durable into that directory (initial
        # snapshot + WAL attach on a fresh dir; an engine recovered by
        # persist.open_engine is recognized and left as-is) and a
        # background thread checkpoints every snapshot_every seconds while
        # mutations arrive, truncating the WAL chain as it goes.
        if snapshot_every <= 0:
            raise ValueError(f"snapshot_every must be > 0, got {snapshot_every}")
        if role not in ("primary", "standby"):
            raise ValueError(f"role must be 'primary'|'standby', got {role!r}")
        if role == "standby" and transport is None:
            raise ValueError("role='standby' requires a transport to follow")
        if ship_every <= 0 or poll_every <= 0:
            raise ValueError("ship_every/poll_every must be > 0")
        self.snapshot_dir = snapshot_dir
        self.snapshot_every = float(snapshot_every)
        self.role = role
        self.transport = transport
        self.ship_every = float(ship_every)
        self.poll_every = float(poll_every)
        self.heartbeat_timeout = heartbeat_timeout
        self.on_failover = on_failover
        self._last_ckpt_seq = 0
        self._ckpt_thread: threading.Thread | None = None
        self._ckpt_error: Exception | None = None
        self._ship_thread: threading.Thread | None = None
        self._replay_thread: threading.Thread | None = None
        self._stop_replay = threading.Event()
        self._repl_error: Exception | None = None
        self._failover_fired = False
        self._shipper = None
        self._replica = None
        if role == "standby":
            # a standby never attaches a WAL — it replays the primary's
            # shipped records (write shedding below keeps it that way) and
            # only promote() makes it durable in its own right
            self._replica = persist.StandbyReplica(
                engine, transport, start_seq=standby_start_seq)
        elif snapshot_dir is not None:
            persist.ensure_attached(engine, snapshot_dir)
            manifest = persist.read_manifest(snapshot_dir)
            self._last_ckpt_seq = manifest["wal_seq"]
            if transport is not None:
                term = int(manifest.get("term", 0))
                self._shipper = persist.WALShipper(
                    engine, snapshot_dir, transport, term=term)
                # fence the local writer too: once a newer term exists the
                # next append fails, not just the next ship
                engine._wal.guard = persist.make_fence_guard(transport, term)
        elif transport is not None:
            raise ValueError(
                "a primary with a transport needs snapshot_dir (the WAL it "
                "ships lives there)")
        # per-loop margin width override (docs/anytime.md): traced, so two
        # loops over one engine can serve different latency tiers without
        # extra compiles. Only legal when the engine's probe_policy='margin'.
        if margin_tau is not None and engine.config.probe_policy != "margin":
            raise ValueError(
                "margin_tau given but the engine's probe_policy is "
                f"{engine.config.probe_policy!r}; build it with "
                "EngineConfig(probe_policy='margin')")
        self.margin_tau = None if margin_tau is None else float(margin_tau)
        # auto-compaction policy (docs/mutability.md): when the engine's
        # tombstone count reaches this fraction of total occupancy, the
        # dispatch thread runs compact() between batches. None = never
        # (the default — compaction stays an explicit operator action).
        if compact_at is not None and not 0.0 < compact_at <= 1.0:
            raise ValueError(
                f"compact_at must be in (0, 1], got {compact_at}")
        self.compact_at = None if compact_at is None else float(compact_at)
        # loop-level attribute filter: a (nlist, W) packed bitmap applied to
        # every dispatched batch (docs/filtering.md). Swap it atomically with
        # ``set_filter`` on attribute epoch changes — the values are traced,
        # so a swap never recompiles.
        self.filter_bits = (None if filter_bits is None
                            else jnp.asarray(filter_bits, jnp.uint8))
        # path of a persisted autotune table (kernels.ops.save_autotune_cache
        # format): loaded before warmup so a fleet replica skips the timed
        # kernel sweeps its siblings already ran, re-saved after warmup so
        # first boot populates it. None = per-process sweeps only.
        self.warmup_cache = warmup_cache
        self.batcher = batcher or Batcher(buckets=buckets,
                                          max_wait_s=max_wait_s,
                                          max_pending=max_pending)
        self.nprobe = engine.config.nprobe if nprobe is None else int(nprobe)
        self.rerank_mult = (engine.config.rerank_mult if rerank_mult is None
                            else int(rerank_mult))
        if self.rerank_mult and engine.base is None:
            raise ValueError("rerank_mult > 0 but the engine holds no base "
                             "vectors (build with keep_base=True)")
        self.stats = stats or StatsRegistry()
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()
        self._lock = threading.Lock()
        self._batches = 0
        self._rows_served = 0
        self._rows_padded = 0
        self._bucket_counts: dict[int, int] = {}
        self._compiles = 0
        self._autotuned = 0
        self._rows_tombstoned = 0
        self._lists_pruned = 0
        self._tiles_skipped = 0
        self._auto_compactions = 0
        self._checkpoints = 0
        self._dim = int(engine.index.centroids.shape[1])

    # -- lifecycle ----------------------------------------------------------

    def start(self, *, warmup: bool = False, warmup_ks: tuple[int, ...] = (10,)
              ) -> "ServingLoop":
        """Spawn the dispatch thread; optionally pre-compile every bucket.

        With ``warmup_cache`` set, the persisted autotune table is loaded
        before the warmup (so a fleet replica pays zero timed sweeps for
        signatures its siblings already resolved) and re-saved after it.

        A stopped loop can be started again (pending state was cancelled at
        stop; counters keep accumulating).
        """
        if self._thread is not None:
            raise RuntimeError("loop already started")
        self.batcher.reopen()
        if warmup:
            if self.warmup_cache:
                load_autotune_cache(self.warmup_cache)
            self.warmup(ks=warmup_ks)
            if self.warmup_cache:
                try:
                    save_autotune_cache(self.warmup_cache)
                except OSError:
                    # a read-only fleet mount (replicas share the file) or a
                    # missing parent dir must never stop a boot — the cache
                    # only saves re-timing, it is not required state
                    pass
        self._stop.clear()
        self._stop_replay.clear()
        self._thread = threading.Thread(target=self._run, name="repro-serve",
                                        daemon=True)
        self._thread.start()
        if self.role == "primary" and self.snapshot_dir is not None:
            self._ckpt_thread = threading.Thread(
                target=self._ckpt_run, name="repro-checkpoint", daemon=True)
            self._ckpt_thread.start()
        if self._shipper is not None:
            self._ship_thread = threading.Thread(
                target=self._ship_run, name="repro-ship", daemon=True)
            self._ship_thread.start()
        if self.role == "standby":
            self._replay_thread = threading.Thread(
                target=self._replay_run, name="repro-replay", daemon=True)
            self._replay_thread.start()
        return self

    def _shutdown(self, timeout: float) -> None:
        """Common teardown: stop + join EVERY background thread, then make
        durable state quiescent. Idempotent — ``stop``/``close`` in any
        order or repetition never leaves a dangling thread (the historical
        bug: ``close()`` racing a checkpoint skipped the join when the
        dispatch thread was already gone) and always flushes the WAL's
        group-commit tail so every acknowledged record is on disk.
        """
        self.batcher.close()
        self._stop.set()
        self._stop_replay.set()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None
        if self._ckpt_thread is not None:
            self._ckpt_thread.join(timeout)
            self._ckpt_thread = None
        if self._ship_thread is not None:
            self._ship_thread.join(timeout)
            self._ship_thread = None
        if (self._replay_thread is not None
                and self._replay_thread is not threading.current_thread()):
            self._replay_thread.join(timeout)
            self._replay_thread = None
        if self.role == "primary" and self.snapshot_dir is not None:
            self._checkpoint_if_dirty()
        if self._shipper is not None:
            try:  # best-effort final ship so a standby sees the full chain
                self._shipper.ship_once()
            except Exception:
                pass
        wal = getattr(self.engine, "_wal", None)
        if wal is not None:
            wal.flush()

    def stop(self, timeout: float = 5.0) -> None:
        """Stop dispatching; cancel anything still queued.

        With ``snapshot_dir`` set, a final checkpoint runs first so every
        acknowledged mutation is covered by the last snapshot (the WAL
        already covered it — this just shortens replay on the next boot).
        Stops the checkpoint/ship/replay threads too and flushes the WAL;
        idempotent, and safe to interleave with ``close``.
        """
        self._shutdown(timeout)
        while (reqs := self.batcher.next_batch(timeout=0)):
            for r in reqs:
                r.future.cancel()

    def close(self, timeout: float = 5.0) -> None:
        """Shut down and DRAIN: fail every still-pending future.

        Unlike ``stop`` (which cancels, for restart scenarios), ``close``
        resolves each queued request's future with a ``LoopClosed`` error —
        a caller blocked in ``future.result()`` gets a typed failure
        instead of waiting forever on a future nothing will ever run.
        """
        self._shutdown(timeout)
        while (reqs := self.batcher.next_batch(timeout=0)):
            for r in reqs:
                if not r.future.done():
                    r.future.set_exception(
                        LoopClosed("serving loop closed before dispatch"))

    def __enter__(self) -> "ServingLoop":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    def warmup(self, ks: tuple[int, ...] = (10,)) -> None:
        """Compile the fused pipeline for every (bucket, k) pair up front.

        Warmup compiles count toward ``metrics().compiles`` (they are real
        cache entries); steady-state traffic after warmup should add zero.
        When the engine runs ``scan_impl='auto'`` (or ``rerank_impl='auto'``),
        tracing each bucket here also runs the kernel autotune sweep for that
        bucket's scan (G, cap, M, nlist) — and re-rank (Q, R, D, k, N) —
        signature (``kernels.ops.resolve_grouped_impl`` /
        ``resolve_rerank_impl``), so steady-state traffic never pays the
        timed micro-sweep either — ``metrics().autotuned`` should be flat
        after warmup. Both stages' verdicts persist through the same
        ``warmup_cache`` file.
        """
        for b in self.batcher.buckets:
            dummy = jnp.zeros((b, self._dim), jnp.float32)
            for k in ks:
                self._call_engine(dummy, k)

    # -- request entry points ------------------------------------------------

    def submit(self, query, k: int = 10, tenant: str = "default",
               namespace: int = -1, deadline_s: float | None = None) -> Future:
        """Enqueue one (D,) query -> Future[ServeResult].

        ``namespace`` >= 0 restricts the query to that engine namespace's
        lists (-1 = unrestricted). Namespaces are per-row traced values, so
        mixed-namespace requests still share shape buckets and compiles.

        ``deadline_s`` bounds the request's queue wait: still undispatched
        past it, the future fails with ``DeadlineExceeded`` and the request
        never reaches the engine. Raises ``Overloaded`` (counted per
        tenant) when the bounded queue is full — docs/serving.md runbook.
        """
        if self._thread is None:
            raise RuntimeError("loop is not running (call start())")
        q = np.asarray(query, np.float32)
        # reject wrong-D here, where the engine's D is known — a bad query
        # must fail alone, never poison the co-riders in its batch
        if q.shape != (self._dim,):
            raise ValueError(
                f"query shape {q.shape} does not match engine dim "
                f"({self._dim},)")
        if namespace >= 0:
            if self.engine.ns_member is None:
                raise ValueError(
                    f"namespace={namespace} requested but the engine was "
                    "built without a namespace table")
            if namespace >= self.engine.ns_member.shape[0]:
                raise ValueError(
                    f"namespace={namespace} out of range (engine holds "
                    f"{self.engine.ns_member.shape[0]} namespaces)")
        try:
            return self.batcher.submit(q, k=k, tenant=tenant,
                                       namespace=namespace,
                                       deadline_s=deadline_s)
        except Overloaded:
            self.stats.record_reject(tenant)
            raise

    async def asearch(self, query, k: int = 10, tenant: str = "default",
                      namespace: int = -1) -> ServeResult:
        """Asyncio-native entry: await one query's ServeResult."""
        return await asyncio.wrap_future(
            self.submit(query, k=k, tenant=tenant, namespace=namespace))

    # -- live mutation (docs/mutability.md) ---------------------------------

    def upsert(self, ids, vecs, *, attrs=None) -> np.ndarray:
        """Insert/replace rows while serving.

        Delegates to ``SearchEngine.upsert`` under the engine's mutation
        lock; the engine installs the new epoch as ONE snapshot swap, so
        batches already dispatched finish on the retiring epoch and the
        next dispatch reads the new one — no pause, no failed futures.
        Safe to call from any thread, running loop or not.

        On a standby, raises ``NotPrimary`` (graceful degradation: reads
        keep flowing, writes are shed until ``promote()``).
        """
        self._require_primary("upsert")
        return self.engine.upsert(ids, vecs, attrs=attrs)

    def delete(self, ids) -> int:
        """Tombstone rows while serving (see ``upsert`` for the epoch
        contract). Returns the number of rows deleted."""
        self._require_primary("delete")
        return self.engine.delete(ids)

    def compact(self, cap: int | None = None) -> int:
        """Rebuild tombstone-heavy lists into a fresh epoch while serving.

        The rebuild happens off to the side on host arrays; the swap is the
        same single-snapshot install as ``upsert``, so in-flight batches
        finish on the old epoch. A cap change retires the scan kernels'
        autotune signatures (the engine invalidates them); the next dispatch
        pays one re-sweep/compile, subsequent traffic is steady again.
        Returns the number of tombstoned slots reclaimed.
        """
        self._require_primary("compact")
        return self.engine.compact(cap=cap)

    def _require_primary(self, what: str) -> None:
        if self.role != "primary":
            raise NotPrimary(
                f"{what} refused: this loop is a standby replaying the "
                "primary's WAL — route writes to the primary or promote() "
                "this replica first (docs/serving.md)")

    def set_filter(self, filter_bits) -> None:
        """Swap the loop-level filter bitmap (None = unfiltered).

        Safe to call while serving: the reference swap is atomic and each
        dispatch reads it once. Flipping between None and a bitmap changes
        the traced-arg structure and costs one compile per bucket; swapping
        one bitmap for another never recompiles.
        """
        self.filter_bits = (None if filter_bits is None
                            else jnp.asarray(filter_bits, jnp.uint8))

    # -- observability -------------------------------------------------------

    def metrics(self) -> LoopMetrics:
        lag = persist.ReplicationLag(0, 0.0)
        term = 0
        replayed = 0
        shipped = 0
        if self._replica is not None:
            if self.role == "standby":
                # a promoted loop keeps its replica only for the replay
                # counters: lag against its OWN heartbeats is meaningless
                lag = self._replica.lag()
            term = self._replica.max_term
            replayed = self._replica.records_replayed
        if self._shipper is not None:
            term = self._shipper.term
            shipped = self._shipper.segments_shipped
        with self._lock:
            total = self._rows_served + self._rows_padded
            return LoopMetrics(
                role=self.role,
                term=term,
                replication_lag_seqs=lag.seqs,
                replication_lag_s=lag.seconds,
                segments_shipped=shipped,
                records_replayed=replayed,
                batches=self._batches,
                rows_served=self._rows_served,
                rows_padded=self._rows_padded,
                occupancy=self._rows_served / total if total else 0.0,
                compiles=self._compiles,
                bucket_counts=dict(self._bucket_counts),
                autotuned=self._autotuned,
                epoch=self.engine.epoch,
                rows_tombstoned=self._rows_tombstoned,
                lists_pruned=self._lists_pruned,
                tiles_skipped=self._tiles_skipped,
                auto_compactions=self._auto_compactions,
                rejects=self.batcher.rejects,
                deadline_misses=self.batcher.deadline_misses,
                checkpoints=self._checkpoints,
            )

    # -- dispatch thread -----------------------------------------------------

    def _run(self) -> None:
        # BaseException, not Exception: a poisoned batch must fail ONLY its
        # own futures, never wedge or kill the dispatch thread — even on
        # exotic raises (KeyboardInterrupt delivered here, SystemExit from
        # a hook). The loop itself keeps serving subsequent batches.
        while not self._stop.is_set():
            reqs = self.batcher.next_batch(timeout=0.05)
            if not reqs:
                continue
            try:
                self._dispatch(reqs)
            except BaseException as e:  # engine failure -> fail the batch
                for r in reqs:
                    if not r.future.done():
                        r.future.set_exception(e)
                continue
            self._maybe_compact()

    # -- background checkpointing (docs/persistence.md) ---------------------

    def _ckpt_run(self) -> None:
        while not self._stop.wait(self.snapshot_every):
            self._checkpoint_if_dirty()

    def _checkpoint_if_dirty(self) -> None:
        """Snapshot iff mutations arrived since the last checkpoint.

        Runs on the dedicated checkpoint thread (and once at stop/close):
        the capture is atomic under the engine's mutation lock, the
        serialization works on the immutable captured state, so dispatches
        and mutators never stall behind segment I/O. A failed checkpoint is
        recorded (``checkpoint_error``) but must not kill the thread — the
        WAL still holds every acknowledged mutation.
        """
        wal = getattr(self.engine, "_wal", None)
        if wal is None or wal.last_seq == self._last_ckpt_seq:
            return
        try:
            manifest = persist.save_snapshot(self.engine, self.snapshot_dir)
        except Exception as e:
            self._ckpt_error = e
            return
        self._last_ckpt_seq = manifest["wal_seq"]
        with self._lock:
            self._checkpoints += 1

    def checkpoint(self) -> None:
        """Force a snapshot now (if any mutation arrived since the last);
        raises nothing — check ``checkpoint_error`` for the last failure."""
        if self.snapshot_dir is None:
            raise RuntimeError("loop has no snapshot_dir")
        self._checkpoint_if_dirty()

    @property
    def checkpoint_error(self) -> Exception | None:
        """Last background-checkpoint failure, None when healthy."""
        return self._ckpt_error

    # -- replication (docs/persistence.md#replication) -----------------------

    def _ship_run(self) -> None:
        """Primary's shipping thread: rotate + publish closed WAL segments
        every ``ship_every`` seconds. ``FencedError`` means a standby was
        promoted over us — shipping stops for good (the writer guard
        fences appends the same way); transient ``ReplicationError`` is
        recorded and retried next round (already-published segments are
        skipped, so a healed transport catches up exactly)."""
        while not self._stop.wait(self.ship_every):
            try:
                self._shipper.ship_once()
            except persist.FencedError as e:
                self._repl_error = e
                return
            except Exception as e:
                self._repl_error = e

    def _replay_run(self) -> None:
        """Standby's replay thread: poll + apply the shipped stream every
        ``poll_every`` seconds, and watch the primary's heartbeat —
        silence past ``heartbeat_timeout`` fires ``on_failover(self)``
        (the supervisor hook; it may call ``promote()`` directly). A
        primary that never wrote a heartbeat, or whose heartbeat file was
        deleted or damaged, counts as silent too: the silence clock
        starts when this thread does and only a readable heartbeat
        advances it. The hook fires once per silence episode — a fresh
        heartbeat re-arms the detector, so a standby that lost a
        promotion race fails over again when the NEXT primary dies.
        Replay errors are loud-and-stop: a standby that cannot follow the
        chain exactly keeps serving its current prefix, never a diverged
        one."""
        last_signal = time.time()  # no heartbeat ever = silent since start
        while not self._stop_replay.wait(self.poll_every):
            try:
                self._replica.poll_once()
            except Exception as e:
                self._repl_error = e
                return
            if self.heartbeat_timeout is None:
                continue
            hb = self.transport.read_heartbeat("primary")
            if hb is not None:
                last_signal = max(last_signal, float(hb.get("time", 0.0)))
            if time.time() - last_signal <= self.heartbeat_timeout:
                self._failover_fired = False  # fresh signal re-arms
            elif not self._failover_fired:
                self._failover_fired = True
                if self.on_failover is not None:
                    try:
                        self.on_failover(self)
                    except Exception as e:
                        self._repl_error = e

    def promote(self, timeout: float = 5.0) -> int:
        """Fenced failover: turn this standby into the primary; returns the
        new term. Safe to call from the ``on_failover`` hook (which runs
        on the replay thread) or from any other thread:

        1. stop the replay thread (joined unless we ARE it),
        2. drain every segment already shipped, bump the transport term
           (``FencedError`` if a newer promotion won the race — this loop
           then stays a standby: the replay thread is resumed and keeps
           following the winner's stream),
        3. snapshot the drained state into ``snapshot_dir`` under the new
           term and attach a fenced WAL writer,
        4. start accepting mutations, checkpointing, and shipping.

        Standby reads keep flowing throughout — the dispatch thread never
        pauses.
        """
        if self.role != "standby":
            raise RuntimeError("promote() is only valid on a standby loop")
        if self.snapshot_dir is None:
            raise RuntimeError(
                "promote() needs snapshot_dir — the promoted primary's "
                "durable directory")
        self._stop_replay.set()
        replay_thread = self._replay_thread
        if (replay_thread is not None
                and replay_thread is not threading.current_thread()):
            replay_thread.join(timeout)
        self._replay_thread = None
        try:
            new_term = self._replica.promote(self.snapshot_dir)
        except persist.FencedError:
            # Lost the race to a newer promotion: genuinely resume life
            # as a standby — replay must keep following the winner's
            # stream, not silently serve an ever-staler prefix.
            self._stop_replay.clear()
            if replay_thread is threading.current_thread():
                # we ARE the replay thread (the on_failover hook path):
                # its loop continues once the cleared event is seen
                self._replay_thread = replay_thread
            elif self._thread is not None:
                self._replay_thread = threading.Thread(
                    target=self._replay_run, name="repro-replay",
                    daemon=True)
                self._replay_thread.start()
            raise
        self.role = "primary"
        self._last_ckpt_seq = self._replica.applied_seq
        self._shipper = persist.WALShipper(
            self.engine, self.snapshot_dir, self.transport, term=new_term)
        if self._thread is not None:  # loop running: start primary threads
            self._ckpt_thread = threading.Thread(
                target=self._ckpt_run, name="repro-checkpoint", daemon=True)
            self._ckpt_thread.start()
            self._ship_thread = threading.Thread(
                target=self._ship_run, name="repro-ship", daemon=True)
            self._ship_thread.start()
        return new_term

    @property
    def replication_error(self) -> Exception | None:
        """Last ship/replay/failover-hook failure, None when healthy. A
        ``FencedError`` here on an old primary is the EXPECTED signature
        of having been failed over."""
        return self._repl_error

    def replication_lag(self) -> "persist.ReplicationLag":
        """Standby's lag behind the primary (0/0.0 on a primary — a
        promoted loop IS the primary now; comparing its frozen
        ``applied_seq`` against its own heartbeats would only mint an
        ever-growing bogus number)."""
        if self._replica is None or self.role != "standby":
            return persist.ReplicationLag(0, 0.0)
        return self._replica.lag()

    def _maybe_compact(self) -> None:
        """Auto-compaction: runs on the dispatch thread BETWEEN batches.

        With ``compact_at`` set, compact once the tombstone count reaches
        that fraction of the store's total occupancy (watermark slots). The
        check is host-side ints off the engine snapshot — no device sync —
        and the compact itself is the same epoch swap an operator-issued one
        is, so the next dispatch simply reads the fresh epoch. A failed
        compaction is swallowed (and not counted): a compaction hiccup must
        never take the serving thread down with it.
        """
        if self.compact_at is None:
            return
        tomb = self.engine.n_tombstones
        if not tomb:
            return
        occupancy = int(np.asarray(self.engine.index.lists.sizes).sum())
        if tomb / max(1, occupancy) < self.compact_at:
            return
        try:
            self.engine.compact()
        except Exception:
            return  # a compaction hiccup must not kill the dispatch thread
        with self._lock:
            self._auto_compactions += 1

    def _call_engine(self, q, k: int, namespaces=None):
        """search_jit + per-loop compile/autotune attribution (cache deltas
        around the call; warmup runs before the dispatch thread and
        dispatches are single-threaded, so the deltas are this loop's own).

        Trace-shape consistency: when the engine holds a namespace table the
        loop ALWAYS passes a namespaces array (all -1 for warmup and
        unrestricted batches) — so warmup and steady-state traffic share one
        compiled signature per bucket instead of splitting on presence.
        Same for the loop-level filter bitmap.
        """
        if self.engine.ns_member is not None and namespaces is None:
            namespaces = np.full((q.shape[0],), -1, np.int32)
        c0 = fused_cache_size()
        a0 = autotune_cache_size()
        res = self.engine.search_jit(q, k, nprobe=self.nprobe,
                                     rerank_mult=self.rerank_mult,
                                     filter_bits=self.filter_bits,
                                     namespaces=namespaces,
                                     margin_tau=self.margin_tau)
        with self._lock:
            self._compiles += fused_cache_size() - c0
            self._autotuned += autotune_cache_size() - a0
        return res

    def _dispatch(self, reqs: list[Request]) -> None:
        padded, bucket = self.batcher.form(reqs)
        n = len(reqs)
        ns = None
        if self.engine.ns_member is not None:
            # padding rows are unrestricted (-1): their results are dropped,
            # so the cheapest trace-consistent value wins
            ns = np.full((bucket,), -1, np.int32)
            ns[:n] = [r.namespace for r in reqs]
        res = self._call_engine(jnp.asarray(padded), reqs[0].k, namespaces=ns)
        # one device->host sync for the whole batch
        dists = np.asarray(res.dists)
        ids = np.asarray(res.ids)
        lp = np.asarray(res.stats.lists_probed)
        cs = np.asarray(res.stats.codes_scanned)
        rr = np.asarray(res.stats.reranked)
        rf = np.asarray(res.stats.rows_filtered)
        rt = np.asarray(res.stats.rows_tombstoned)
        pr = np.asarray(res.stats.lists_pruned)
        ts = np.asarray(res.stats.tiles_skipped)
        t_done = time.monotonic()
        lats = [t_done - r.t_submit for r in reqs]

        for i, r in enumerate(reqs):
            r.future.set_result(ServeResult(
                dists=dists[i], ids=ids[i], lists_probed=int(lp[i]),
                codes_scanned=int(cs[i]), reranked=int(rr[i]),
                rows_filtered=int(rf[i]), rows_tombstoned=int(rt[i]),
                lists_pruned=int(pr[i]), tiles_skipped=int(ts[i]),
                latency_s=lats[i]))
        # padding rows [n:] are dropped on the floor here — accounting and
        # callers only ever see rows [:n]
        self.stats.record_batch([r.tenant for r in reqs], lp[:n], cs[:n],
                                rr[:n], lats, rf[:n], rt[:n], pr[:n], ts[:n])
        with self._lock:
            self._batches += 1
            self._rows_served += n
            self._rows_padded += bucket - n
            self._rows_tombstoned += int(rt[:n].sum())
            self._lists_pruned += int(pr[:n].sum())
            self._tiles_skipped += int(ts[:n].sum())
            self._bucket_counts[bucket] = self._bucket_counts.get(bucket, 0) + 1
