"""Per-tenant serving accounting on top of the engine's ``QueryStats``.

The engine reports *work* per query (lists probed, codes scanned, candidates
re-ranked); the serving loop knows *who asked* and *how long they waited*.
``TenantStats`` joins the two: one aggregate record per caller id, updated
once per dispatched batch from the batch's ``QueryStats`` rows.

All counters are plain python ints/floats (updated after a single
device->host sync per batch, never per request) and the registry is
thread-safe — the serving loop mutates from its dispatch thread while
callers snapshot from theirs.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Iterable, Mapping

import numpy as np


@dataclasses.dataclass
class TenantStats:
    """Aggregate serving counters for one caller id."""

    tenant: str
    queries: int = 0            # requests completed
    batches: int = 0            # dispatches this tenant had >= 1 row in
    lists_probed: int = 0       # sum of QueryStats.lists_probed
    codes_scanned: int = 0      # sum of QueryStats.codes_scanned
    reranked: int = 0           # sum of QueryStats.reranked
    rows_filtered: int = 0      # sum of QueryStats.rows_filtered (rows the
    #                             attribute filter excluded mid-scan; 0 when
    #                             the loop serves unfiltered)
    rows_tombstoned: int = 0    # sum of QueryStats.rows_tombstoned (probed
    #                             slots holding deleted rows; 0 while the
    #                             index carries no tombstones)
    lists_pruned: int = 0       # sum of QueryStats.lists_pruned (coarse
    #                             probes the margin policy dropped; 0 under
    #                             probe_policy='fixed' — docs/anytime.md)
    tiles_skipped: int = 0      # sum of QueryStats.tiles_skipped (scan tiles
    #                             the early-exit bound proved irrelevant; 0
    #                             without early_exit)
    rejects: int = 0            # submits shed by the bounded queue
    #                             (Overloaded, docs/serving.md); these never
    #                             enqueued, so no other counter moves
    latency_sum_s: float = 0.0  # submit -> result, summed
    latency_max_s: float = 0.0

    @property
    def mean_latency_s(self) -> float:
        return self.latency_sum_s / self.queries if self.queries else 0.0

    @property
    def mean_codes_scanned(self) -> float:
        return self.codes_scanned / self.queries if self.queries else 0.0


class StatsRegistry:
    """Thread-safe map tenant id -> ``TenantStats``.

    The serving loop calls ``record_batch`` once per dispatched bucket with
    the *valid* (non-padding) rows of the batch's ``QueryStats``; padding
    rows never reach accounting.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._stats: dict[str, TenantStats] = {}

    def record_batch(self, tenants: Iterable[str], lists_probed: np.ndarray,
                     codes_scanned: np.ndarray, reranked: np.ndarray,
                     latencies_s: Iterable[float],
                     rows_filtered: np.ndarray | None = None,
                     rows_tombstoned: np.ndarray | None = None,
                     lists_pruned: np.ndarray | None = None,
                     tiles_skipped: np.ndarray | None = None) -> None:
        """Fold one batch's per-row counters into the per-tenant aggregates.

        tenants / latencies_s: one entry per *real* row of the batch, aligned
        with the stat arrays (each (Q_real,)). ``rows_filtered`` /
        ``rows_tombstoned`` / ``lists_pruned`` / ``tiles_skipped`` are
        optional (trailing, defaulted) so pre-filtering / pre-mutability /
        pre-anytime callers keep working.
        """
        with self._lock:
            seen: set[str] = set()
            for i, (tenant, lat) in enumerate(zip(tenants, latencies_s)):
                st = self._stats.get(tenant)
                if st is None:
                    st = self._stats[tenant] = TenantStats(tenant)
                st.queries += 1
                st.lists_probed += int(lists_probed[i])
                st.codes_scanned += int(codes_scanned[i])
                st.reranked += int(reranked[i])
                if rows_filtered is not None:
                    st.rows_filtered += int(rows_filtered[i])
                if rows_tombstoned is not None:
                    st.rows_tombstoned += int(rows_tombstoned[i])
                if lists_pruned is not None:
                    st.lists_pruned += int(lists_pruned[i])
                if tiles_skipped is not None:
                    st.tiles_skipped += int(tiles_skipped[i])
                st.latency_sum_s += float(lat)
                st.latency_max_s = max(st.latency_max_s, float(lat))
                if tenant not in seen:
                    st.batches += 1
                    seen.add(tenant)

    def record_reject(self, tenant: str) -> None:
        """Count one load-shed submit (``Overloaded``) against its tenant."""
        with self._lock:
            st = self._stats.get(tenant)
            if st is None:
                st = self._stats[tenant] = TenantStats(tenant)
            st.rejects += 1

    def snapshot(self) -> Mapping[str, TenantStats]:
        """Point-in-time copy of every tenant's aggregates."""
        with self._lock:
            return {t: dataclasses.replace(s) for t, s in self._stats.items()}

    def get(self, tenant: str) -> TenantStats:
        with self._lock:
            st = self._stats.get(tenant)
            return dataclasses.replace(st) if st is not None else TenantStats(tenant)
