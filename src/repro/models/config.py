"""Model configuration schema covering all assigned architecture families.

One frozen dataclass drives model construction, sharding annotation, the
dry-run input specs, and the roofline's MODEL_FLOPS term.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int                 # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab: int

    # --- attention details
    head_dim: int = 0            # 0 -> d_model // n_heads
    rope_theta: float = 10_000.0
    qk_norm: bool = False        # qwen3
    qkv_bias: bool = False       # qwen1.5
    attn_logit_softcap: float = 0.0

    # --- FFN
    mlp_type: str = "swiglu"     # swiglu | gelu | relu2 (squared ReLU)

    # --- MoE
    n_experts: int = 0
    n_experts_active: int = 0
    moe_every: int = 1           # MoE layer cadence (1 = every layer)
    shared_expert: bool = False  # llama4-style always-on expert
    router_act: str = "softmax"  # softmax | sigmoid
    capacity_factor: float = 1.25
    moe_groups: int = 32         # dispatch groups; align with pod x data

    # --- SSM (Mamba2) / hybrid
    block_type: str = "attn"     # attn | mamba2 | rwkv6
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 128
    shared_attn_every: int = 0   # zamba2: one shared attn block every k ssm layers

    # --- RWKV6
    rwkv_head_dim: int = 64
    rwkv_lora: int = 32
    rwkv_chunk: int = 128

    # --- modality frontend (STUB per brief: precomputed embeddings)
    frontend: str = "none"       # none | patch (vlm) | codec (audio)
    frontend_len: int = 0        # number of prepended frontend embeddings

    # --- numerics / lowering
    dtype: str = "bfloat16"
    loss_chunk: int = 512        # seq-chunked cross-entropy (0 = off)
    norm_eps: float = 1e-5
    remat: str = "layer"         # none | layer | group:<k>
    attn_q_chunk: int = 512
    attn_kv_chunk: int = 1024
    vocab_pad_multiple: int = 2048

    # --- paper technique: PQ-compressed KV cache for decode
    kv_pq: bool = False
    kv_pq_m: int = 0             # sub-quantizers per head (0 -> head_dim // 2)

    # -------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        assert self.n_heads > 0
        return self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab + m - 1) // m) * m

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def rwkv_nheads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def resolved_kv_pq_m(self) -> int:
        return self.kv_pq_m or self.resolved_head_dim // 2

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (embedding included once)."""
        d, v = self.d_model, self.padded_vocab
        total = v * d * 2  # embed + unembed
        hd = self.resolved_head_dim if self.n_heads else 0
        for layer in range(self.n_layers):
            if self.block_type in ("attn",) or (
                    self.block_type == "mamba2" and self.shared_attn_every):
                pass
            if self.block_type == "attn":
                total += self._attn_params(d, hd)
                total += self._ffn_params(layer)
                total += 2 * d  # norms
            elif self.block_type == "mamba2":
                total += self._mamba_params()
                total += d
            elif self.block_type == "rwkv6":
                total += self._rwkv_params()
                total += 2 * d
        if self.block_type == "mamba2" and self.shared_attn_every:
            total += self._attn_params(d, hd) + self._ffn_params(0) + 2 * d
            total += (self.n_layers // self.shared_attn_every) * 2 * d * d  # io projs
        return total

    def _attn_params(self, d: int, hd: int) -> int:
        q = d * self.n_heads * hd
        kv = 2 * d * self.n_kv_heads * hd
        o = self.n_heads * hd * d
        bias = (self.n_heads + 2 * self.n_kv_heads) * hd if self.qkv_bias else 0
        qknorm = 2 * hd if self.qk_norm else 0
        return q + kv + o + bias + qknorm

    def _ffn_params(self, layer: int) -> int:
        d, f = self.d_model, self.d_ff
        dense = 3 * d * f if self.mlp_type == "swiglu" else 2 * d * f
        if self.n_experts and layer % self.moe_every == 0:
            ffn = self.n_experts * dense + d * self.n_experts
            if self.shared_expert:
                ffn += dense
            return ffn
        return dense

    def _mamba_params(self) -> int:
        d, di = self.d_model, self.d_inner
        g, ds, nh = self.ssm_groups, self.ssm_state, self.ssm_nheads
        in_proj = d * (2 * di + 2 * g * ds + nh)
        conv = self.ssm_conv * (di + 2 * g * ds)
        extra = 3 * nh + di  # A_log, D, dt_bias, norm
        return in_proj + conv + extra + di * d

    def _rwkv_params(self) -> int:
        d, f, r = self.d_model, self.d_ff, self.rwkv_lora
        tm = 4 * d * d          # r, k, v, g (square: d_head*nh == d)
        tm += d * d             # output proj
        tm += 6 * d + 5 * (d * r + r * d)  # mus + loras (w + 4 mixes)
        tm += 2 * self.d_model  # u bonus + w bias
        cm = 2 * d * f          # channel mix (k, v)... rwkv6 ffn: wk (d,f), wv (f,d), wr (d,d)
        cm += d * d
        return tm + cm

    def active_param_count(self) -> int:
        """Active params per token (MoE counts only routed-active experts)."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        dense = 3 * d * f if self.mlp_type == "swiglu" else 2 * d * f
        inactive_per_moe_layer = (self.n_experts - self.n_experts_active) * dense
        n_moe_layers = len([l for l in range(self.n_layers) if l % self.moe_every == 0])
        return self.param_count() - n_moe_layers * inactive_per_moe_layer
