"""Mixture-of-Experts FFN with sort-based capacity dispatch (EP-shardable).

Dense-dispatch einsums (GShard style) cost O(T * E*C * D) — quadratic-ish in
sequence and unusable at 1M tokens/step. We instead use the sort-based
dropping dispatch (MaxText-style): top-k route -> stable sort by expert ->
position-in-expert via a cumsum -> scatter into a fixed (E, C, D) buffer ->
batched expert FFN einsum -> combine. Every shape is static, so the whole
thing lowers under pjit; with experts sharded over the "model" axis, GSPMD
inserts the all-to-all-equivalent collectives around the scatter/gather.

Tokens beyond an expert's capacity are dropped (contribute zero); the router
keeps a load-balancing auxiliary loss to make drops rare.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as shd
from repro.launch.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.layers import ParamSpec


def moe_specs(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {"router": ParamSpec((d, e), ("embed", None), scale=0.1)}
    if cfg.mlp_type == "swiglu":
        p.update({
            "wi_gate": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
            "wi_up": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
            "wo": ParamSpec((e, f, d), ("experts", "expert_mlp", "embed")),
        })
    else:
        p.update({
            "wi": ParamSpec((e, d, f), ("experts", "embed", "expert_mlp")),
            "wo": ParamSpec((e, f, d), ("experts", "expert_mlp", "embed")),
        })
    if cfg.shared_expert:
        p.update({
            "shared_gate": ParamSpec((d, f), ("embed", "mlp")),
            "shared_up": ParamSpec((d, f), ("embed", "mlp")),
            "shared_down": ParamSpec((f, d), ("mlp", "embed")),
        })
    return p


def _expert_ffn(p: dict, xb: jax.Array, cfg: ModelConfig) -> jax.Array:
    """xb: (G, E, C, D) -> (G, E, C, D), batched over groups x experts."""
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xb, p["wi_gate"]))
        h = h * jnp.einsum("gecd,edf->gecf", xb, p["wi_up"])
    elif cfg.mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(jnp.einsum("gecd,edf->gecf", xb, p["wi"])))
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xb, p["wi"]))
    h = constrain(h, "batch", "experts", None, "expert_mlp")
    return jnp.einsum("gecf,efd->gecd", h, p["wo"])


def _moe_mesh():
    """Active mesh context if it can shard experts, else None (smoke path)."""
    mesh, _ = shd._get_ctx()
    if mesh is not None and "model" in mesh.shape:
        return mesh
    return None


def _dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _dispatch(xg: jax.Array, tok_for_slot: jax.Array, slot_valid: jax.Array
              ) -> jax.Array:
    """buf[g, e, c] = xg[g, tok_for_slot[g, e, c]] (masked).

    Under a mesh this runs in shard_map so the gather is shard-local
    (xg is replicated over "model"; slots are owned by their expert shard):
    ZERO collectives. The pure-jnp fallback is used in single-device tests.
    """
    g = xg.shape[0]
    gid = jnp.arange(g, dtype=jnp.int32)[:, None, None]

    def local(xg_l, tok_l, valid_l):
        gl = xg_l.shape[0]
        gid_l = jnp.arange(gl, dtype=jnp.int32)[:, None, None]
        buf = xg_l[gid_l, tok_l]
        return jnp.where(valid_l[..., None], buf, 0)

    mesh = _moe_mesh()
    if mesh is None:
        return local(xg, tok_for_slot, slot_valid)
    dp = _dp_axes(mesh)
    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, None, None), P(dp, "model", None), P(dp, "model", None)),
        out_specs=P(dp, "model", None, None),
        check_vma=False,
    )(xg, tok_for_slot, slot_valid)


def _combine(yb: jax.Array, es_tok: jax.Array, ps_tok: jax.Array,
             keep_tok: jax.Array, gates: jax.Array) -> jax.Array:
    """out[g, t] = sum_k gate * yb[g, e_k, c_k] (masked).

    Under a mesh: each "model" shard gathers from its local experts, applies
    gates, sums over k, and ONE psum of the bf16 (G, Tg, D) partial merges
    shards — exactly the row-parallel-TP pattern. The naive GSPMD lowering
    of the global gather all-reduced a k-times-larger f32 tensor instead
    (v2 of this code — 37 TB/step on dbrx; see EXPERIMENTS.md §Perf).
    """
    e = yb.shape[1]

    def local_ref(yb_l, es_l, ps_l, keep_l, gates_l):
        gl = yb_l.shape[0]
        gid_l = jnp.arange(gl, dtype=jnp.int32)[:, None, None]
        ysel = yb_l[gid_l, jnp.minimum(es_l, yb_l.shape[1] - 1), ps_l]
        ysel = jnp.where(keep_l[..., None], ysel * gates_l[..., None], 0)
        return jnp.sum(ysel, axis=2)

    mesh = _moe_mesh()
    if mesh is None:
        return local_ref(yb, es_tok, ps_tok, keep_tok, gates)
    dp = _dp_axes(mesh)
    e_local = e // mesh.shape["model"]

    def local(yb_l, es_l, ps_l, keep_l, gates_l):
        lo = jax.lax.axis_index("model") * e_local
        mine = (es_l >= lo) & (es_l < lo + e_local) & keep_l
        part = local_ref(yb_l, jnp.clip(es_l - lo, 0, e_local - 1), ps_l,
                         mine, gates_l)
        return jax.lax.psum(part, "model")

    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(dp, "model", None, None), P(dp, None, None),
                  P(dp, None, None), P(dp, None, None), P(dp, None, None)),
        out_specs=P(dp, None, None),
        check_vma=False,
    )(yb, es_tok, ps_tok, keep_tok, gates)


def _num_groups(cfg: ModelConfig, t: int) -> int:
    """Dispatch groups (GShard-style). Groups align with the data-parallel
    sharding so the per-group sort/scatter never crosses shards; fall back
    to fewer groups for small token counts (smoke tests)."""
    g = cfg.moe_groups
    while g > 1 and t % g != 0:
        g //= 2
    return max(g, 1)


def moe_ffn(p: dict, x: jax.Array, cfg: ModelConfig
            ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss scalar).

    Grouped sort-based dispatch: tokens are split into G groups (sharded
    over pod x data); each group routes, sorts, and fills a fixed per-group
    capacity buffer *locally*. The v0 implementation used one global sort —
    the dry-run roofline showed GSPMD lowering it to a 2.6 TB/step
    collective-permute sorting network, and the (E, C_global, D) expert
    einsum did not shard over the data axis at all (14x useful-FLOPs
    deficit on dbrx). Groups make both shard-local. See EXPERIMENTS.md §Perf.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.n_experts_active
    t = b * s
    g = _num_groups(cfg, t)
    tg = t // g
    xg = x.reshape(g, tg, d)
    xg = constrain(xg, "batch", None, None)   # groups ride the data axes

    logits = jnp.einsum("gtd,de->gte", xg, p["router"]).astype(jnp.float32)
    if cfg.router_act == "sigmoid":                          # llama4-style
        gates_all = jax.nn.sigmoid(logits)
    else:
        gates_all = jax.nn.softmax(logits, axis=-1)
    gate_k, idx_k = jax.lax.top_k(gates_all, k)              # (G, Tg, k)
    if cfg.router_act != "sigmoid":
        gate_k = gate_k / jnp.maximum(gate_k.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e, group-averaged
    me = jnp.mean(gates_all, axis=1)                         # (G, E)
    ce = jnp.zeros((g, e), jnp.float32).at[
        jnp.arange(g)[:, None], idx_k.reshape(g, -1)].add(1.0) / (tg * k)
    aux = e * jnp.mean(jnp.sum(me * ce, axis=-1))

    # ---- per-group sort-based dispatch (all ops batched over G).
    # Heavy data movement is formulated as GATHERS with data-dependent
    # indices (local under GSPMD: xg/yb are replicated/owned where needed);
    # scatters only ever touch small int32 slot-map buffers. A scatter of
    # the (G, E, C, D) activation buffer itself lowers to replicate +
    # 42 TB/step of all-reduce (v1 of this code; see EXPERIMENTS.md §Perf).
    cap = max(1, int(cfg.capacity_factor * tg * k / e))
    flat_e = idx_k.reshape(g, tg * k)                        # (G, Tg*k)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg, dtype=jnp.int32), k)[None], (g, tg * k))
    order = jnp.argsort(flat_e, axis=1)                      # per-group sort
    se = jnp.take_along_axis(flat_e, order, axis=1)
    stok = jnp.take_along_axis(flat_tok, order, axis=1)
    onehot = jax.nn.one_hot(se, e, dtype=jnp.int32)          # (G, Tg*k, E)
    pos = jnp.cumsum(onehot, axis=1)
    pos = jnp.take_along_axis(pos, se[..., None], axis=2)[..., 0] - 1
    keep = pos < cap
    es = jnp.where(keep, se, e)                              # E = trash row
    ps = jnp.where(keep, pos, 0)
    gid = jnp.arange(g, dtype=jnp.int32)[:, None]

    # slot maps (int32/bool, (G, E+1, C) — a few MB, cheap to scatter)
    tok_for_slot = jnp.zeros((g, e + 1, cap), jnp.int32).at[gid, es, ps].set(stok)
    slot_valid = jnp.zeros((g, e + 1, cap), jnp.bool_).at[gid, es, ps].set(keep)
    tok_for_slot = tok_for_slot[:, :e]
    slot_valid = slot_valid[:, :e]

    # slot coords per (token, k) in original order (invert the sort)
    inv = jnp.argsort(order, axis=1)
    es_tok = jnp.take_along_axis(es, inv, axis=1).reshape(g, tg, k)
    ps_tok = jnp.take_along_axis(ps, inv, axis=1).reshape(g, tg, k)
    keep_tok = jnp.take_along_axis(keep, inv, axis=1).reshape(g, tg, k)
    gates = gate_k.astype(x.dtype)

    buf = _dispatch(xg, tok_for_slot, slot_valid)            # (G, E, C, D)
    buf = constrain(buf, "batch", "experts", None, None)
    yb = _expert_ffn(p, buf, cfg)                            # (G, E, C, D)
    yb = constrain(yb, "batch", "experts", None, None)
    out = _combine(yb, es_tok, ps_tok, keep_tok, gates)      # (G, Tg, D)

    if cfg.shared_expert:
        h = jax.nn.silu(jnp.einsum("gtd,df->gtf", xg, p["shared_gate"]))
        h = h * jnp.einsum("gtd,df->gtf", xg, p["shared_up"])
        h = constrain(h, "batch", None, "mlp")
        out = out + jnp.einsum("gtf,fd->gtd", h, p["shared_down"])
    out = out.reshape(b, s, d)
    return constrain(out, "batch", "seq", "embed"), aux
