"""Stack assembly: scan-over-layers blocks for every architecture family.

Scan-over-layers with stacked params keeps the HLO O(1) in depth, so 64-layer
32B-param configs lower and compile quickly even on the CPU backend with 512
placeholder devices. Remat is applied per layer ("layer") or per group of k
layers ("group:k") — group remat divides saved-residual memory by k at the
cost of one extra in-group forward.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.launch.sharding import constrain
from repro.models import kvcache as kvc
from repro.models import layers as ll
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rwkv_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import ParamSpec


def _remat(cfg: ModelConfig, fn: Callable) -> Callable:
    if cfg.remat == "none":
        return fn
    return jax.checkpoint(fn)


def _group_size(cfg: ModelConfig) -> int:
    if cfg.remat.startswith("group:"):
        gs = int(cfg.remat.split(":")[1])
        if gs <= cfg.n_layers and cfg.n_layers % gs == 0:
            return gs
    return 1  # fall back to per-layer remat (e.g. reduced smoke configs)


# ---------------------------------------------------------------------------
# attention-family block (dense / moe / vlm / audio)
# ---------------------------------------------------------------------------

def attn_block_specs(cfg: ModelConfig) -> dict:
    specs = {
        "ln1": ll.rmsnorm_spec(cfg.d_model),
        "ln2": ll.rmsnorm_spec(cfg.d_model),
        "attn": ll.attn_specs(cfg),
    }
    if cfg.n_experts:
        specs["moe"] = moe_mod.moe_specs(cfg)
    else:
        specs["ffn"] = ll.ffn_specs(cfg)
    return specs


def attn_block(p: dict, h: jax.Array, cfg: ModelConfig, positions: jax.Array
               ) -> tuple[jax.Array, jax.Array]:
    """Pre-norm transformer block. Returns (h, aux_loss)."""
    h = h + ll.attention(p["attn"], ll.rmsnorm(h, p["ln1"], cfg.norm_eps),
                         cfg, positions)
    hn = ll.rmsnorm(h, p["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        out, aux = moe_mod.moe_ffn(p["moe"], hn, cfg)
    else:
        out, aux = ll.ffn(p["ffn"], hn, cfg), jnp.float32(0.0)
    h = constrain(h + out, "batch", "seq", "embed")
    return h, aux


def attn_stack_specs(cfg: ModelConfig) -> dict:
    return {"blocks": ll.stacked(attn_block_specs(cfg), cfg.n_layers)}


def attn_stack(p: dict, h: jax.Array, cfg: ModelConfig, positions: jax.Array
               ) -> tuple[jax.Array, jax.Array]:
    gs = _group_size(cfg)

    def one_layer(carry, lp):
        h, aux = carry
        h, a = attn_block(lp, h, cfg, positions)
        return (h, aux + a), None

    if gs <= 1:
        body = _remat(cfg, lambda c, lp: one_layer(c, lp))
        (h, aux), _ = jax.lax.scan(body, (h, jnp.float32(0.0)), p["blocks"])
        return h, aux

    n_groups = cfg.n_layers // gs
    grouped = jax.tree.map(lambda x: x.reshape(n_groups, gs, *x.shape[1:]),
                           p["blocks"])

    def group_body(carry, gp):
        return jax.lax.scan(lambda c, lp: one_layer(c, lp), carry, gp)[0], None

    body = _remat(cfg, group_body)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.float32(0.0)), grouped)
    return h, aux


def attn_stack_decode(p: dict, h: jax.Array, cfg: ModelConfig,
                      cache: Any, position: jax.Array,
                      ) -> tuple[jax.Array, Any]:
    """One-token decode through the stack; cache is Exact or PQ (paper tech)."""
    b = h.shape[0]

    if isinstance(cache, kvc.PQKVCache):
        def body(hc, xs):
            h = hc
            lp, kcod, vcod, kcb, vcb = xs
            x = ll.rmsnorm(h, lp["ln1"], cfg.norm_eps)
            q, k_new, v_new = ll.qkv_project(lp["attn"], x[:, None], cfg,
                                             position[:, None])
            # write first: the current token attends to itself
            kcod, vcod = kvc.update_pq(kcod, vcod, k_new[:, 0], v_new[:, 0],
                                       kcb, vcb, position[0])
            out = kvc.pq_decode_attention(q[:, 0], kcod, vcod, kcb, vcb,
                                          position, quantize_q8=True)
            h = h + jnp.einsum("bhk,hkd->bd", out, lp["attn"]["wo"])
            hn = ll.rmsnorm(h, lp["ln2"], cfg.norm_eps)
            if cfg.n_experts:
                f, _ = moe_mod.moe_ffn(lp["moe"], hn[:, None], cfg)
                h = h + f[:, 0]
            else:
                h = h + ll.ffn(lp["ffn"], hn[:, None], cfg)[:, 0]
            return h, (kcod, vcod)

        h, (kcods, vcods) = jax.lax.scan(
            body, h, (p["blocks"], cache.k_codes, cache.v_codes,
                      cache.k_cb, cache.v_cb))
        return h, kvc.PQKVCache(kcods, vcods, cache.k_cb, cache.v_cb)

    def body(hc, xs):
        h = hc
        lp, kcache, vcache = xs
        x = ll.rmsnorm(h, lp["ln1"], cfg.norm_eps)
        q, k_new, v_new = ll.qkv_project(lp["attn"], x[:, None], cfg,
                                         position[:, None])
        # write first: the current token attends to itself
        kcache, vcache = kvc.update_exact(kcache, vcache, k_new[:, 0],
                                          v_new[:, 0], position[0])
        out = ll.decode_attention_scores(q[:, 0], kcache, vcache, cfg, position)
        h = h + jnp.einsum("bhk,hkd->bd", out, lp["attn"]["wo"])
        hn = ll.rmsnorm(h, lp["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            f, _ = moe_mod.moe_ffn(lp["moe"], hn[:, None], cfg)
            h = h + f[:, 0]
        else:
            h = h + ll.ffn(lp["ffn"], hn[:, None], cfg)[:, 0]
        return h, (kcache, vcache)

    h, (ks, vs) = jax.lax.scan(body, h, (p["blocks"], cache.k, cache.v))
    return h, kvc.ExactKVCache(ks, vs)


# ---------------------------------------------------------------------------
# mamba2 family (+ zamba2 hybrid: shared attention block every k layers)
# ---------------------------------------------------------------------------

def mamba_stack_specs(cfg: ModelConfig) -> dict:
    specs = {"blocks": ll.stacked({
        "ln": ll.rmsnorm_spec(cfg.d_model),
        "mamba": ssm_mod.mamba_specs(cfg),
    }, cfg.n_layers)}
    if cfg.shared_attn_every:
        n_groups = cfg.n_layers // cfg.shared_attn_every
        d = cfg.d_model
        specs["shared"] = {
            "ln1": ll.rmsnorm_spec(d),
            "ln2": ll.rmsnorm_spec(d),
            "attn": ll.attn_specs(cfg),
            "ffn": ll.ffn_specs(cfg),
        }
        # zamba2-style per-invocation input projection of concat(h, h0)
        specs["group_in"] = ll.stacked(
            {"w": ParamSpec((2 * d, d), ("embed", "embed"))}, n_groups)
    return specs


def _mamba_layer(lp: dict, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    return h + ssm_mod.mamba_block(lp["mamba"],
                                   ll.rmsnorm(h, lp["ln"], cfg.norm_eps), cfg)


def mamba_stack(p: dict, h: jax.Array, cfg: ModelConfig, positions: jax.Array
                ) -> tuple[jax.Array, jax.Array]:
    h0 = h
    if not cfg.shared_attn_every:
        body = _remat(cfg, lambda c, lp: (_mamba_layer(lp, c, cfg), None))
        h, _ = jax.lax.scan(body, h, p["blocks"])
        return h, jnp.float32(0.0)

    k = cfg.shared_attn_every
    n_groups = cfg.n_layers // k
    grouped = jax.tree.map(lambda x: x.reshape(n_groups, k, *x.shape[1:]),
                           p["blocks"])
    shared = p["shared"]

    def group_body(carry, xs):
        h = carry
        gp, gin = xs
        h, _ = jax.lax.scan(lambda c, lp: (_mamba_layer(lp, c, cfg), None), h, gp)
        # shared attention block on concat(h, h0) (weight-tied across groups)
        x = jnp.concatenate([h, h0], axis=-1) @ gin["w"]
        x = x + ll.attention(shared["attn"],
                             ll.rmsnorm(x, shared["ln1"], cfg.norm_eps),
                             cfg, positions)
        x = x + ll.ffn(shared["ffn"], ll.rmsnorm(x, shared["ln2"], cfg.norm_eps), cfg)
        return constrain(h + x, "batch", "seq", "embed"), None

    body = _remat(cfg, group_body)
    h, _ = jax.lax.scan(body, h, (grouped, p["group_in"]))
    return h, jnp.float32(0.0)


def mamba_cache_init(cfg: ModelConfig, batch: int, max_seq: int, dtype,
                     key=None) -> dict:
    nh, hd, ds = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * ds
    cache = {
        "h": jnp.zeros((cfg.n_layers, batch, nh, hd, ds), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }
    if cfg.shared_attn_every:
        n_groups = cfg.n_layers // cfg.shared_attn_every
        kv, ahd = cfg.n_kv_heads, cfg.resolved_head_dim
        if cfg.kv_pq:
            m = cfg.resolved_kv_pq_m
            cbshape = (n_groups, kv, m, 16, ahd // m)
            kk = jax.random.split(key, 2) if key is not None else None
            cache["attn_k_codes"] = jnp.zeros((n_groups, batch, max_seq, kv, m // 2), jnp.uint8)
            cache["attn_v_codes"] = jnp.zeros((n_groups, batch, max_seq, kv, m // 2), jnp.uint8)
            cache["attn_k_cb"] = (jax.random.normal(kk[0], cbshape, jnp.bfloat16)
                                  if key is not None else jnp.zeros(cbshape, jnp.bfloat16))
            cache["attn_v_cb"] = (jax.random.normal(kk[1], cbshape, jnp.bfloat16)
                                  if key is not None else jnp.zeros(cbshape, jnp.bfloat16))
        else:
            cache["attn_k"] = jnp.zeros((n_groups, batch, max_seq, kv, ahd), dtype)
            cache["attn_v"] = jnp.zeros((n_groups, batch, max_seq, kv, ahd), dtype)
    return cache


def mamba_cache_axes(cfg: ModelConfig) -> dict:
    axes = {
        "h": ("stack", "batch", "ssm_heads", None, None),
        "conv": ("stack", "batch", None, "mlp"),
    }
    if cfg.shared_attn_every:
        if cfg.kv_pq:
            axes.update({"attn_k_codes": kvc.PQ_CODE_AXES,
                         "attn_v_codes": kvc.PQ_CODE_AXES,
                         "attn_k_cb": kvc.PQ_CB_AXES,
                         "attn_v_cb": kvc.PQ_CB_AXES})
        else:
            axes.update({"attn_k": kvc.EXACT_KV_AXES, "attn_v": kvc.EXACT_KV_AXES})
    return axes


def mamba_stack_decode(p: dict, h: jax.Array, cfg: ModelConfig, cache: dict,
                       position: jax.Array, h0: jax.Array) -> tuple[jax.Array, dict]:
    """One-token decode. h/h0: (B, D)."""
    def layer_body(carry, xs):
        h = carry
        lp, hstate, cstate = xs
        x = ll.rmsnorm(h, lp["ln"], cfg.norm_eps)
        out, new_state = ssm_mod.mamba_decode_step(
            lp["mamba"], x, {"h": hstate, "conv": cstate}, cfg)
        return h + out, (new_state["h"], new_state["conv"])

    if not cfg.shared_attn_every:
        h, (hs, cs) = jax.lax.scan(layer_body, h,
                                   (p["blocks"], cache["h"], cache["conv"]))
        return h, {**cache, "h": hs, "conv": cs}

    k = cfg.shared_attn_every
    n_groups = cfg.n_layers // k
    grouped = jax.tree.map(lambda x: x.reshape(n_groups, k, *x.shape[1:]),
                           p["blocks"])
    gh = cache["h"].reshape(n_groups, k, *cache["h"].shape[1:])
    gc = cache["conv"].reshape(n_groups, k, *cache["conv"].shape[1:])
    shared = p["shared"]

    def group_body(carry, xs):
        h = carry
        if cfg.kv_pq:
            gp, gin, ghs, gcs, kcod, vcod, kcb, vcb = xs
        else:
            gp, gin, ghs, gcs, kcache, vcache = xs
        h, (hs, cs) = jax.lax.scan(layer_body, h, (gp, ghs, gcs))
        x = jnp.concatenate([h, h0], axis=-1) @ gin["w"]
        xn = ll.rmsnorm(x, shared["ln1"], cfg.norm_eps)
        q, k_new, v_new = ll.qkv_project(shared["attn"], xn[:, None], cfg,
                                         position[:, None])
        if cfg.kv_pq:
            kcod, vcod = kvc.update_pq(kcod, vcod, k_new[:, 0], v_new[:, 0],
                                       kcb, vcb, position[0])
            out = kvc.pq_decode_attention(q[:, 0], kcod, vcod, kcb, vcb, position)
            x = x + jnp.einsum("bhk,hkd->bd", out, shared["attn"]["wo"])
            new_kv = (kcod, vcod, kcb, vcb)
        else:
            kcache, vcache = kvc.update_exact(kcache, vcache, k_new[:, 0],
                                              v_new[:, 0], position[0])
            out = ll.decode_attention_scores(q[:, 0], kcache, vcache, cfg,
                                             position)
            x = x + jnp.einsum("bhk,hkd->bd", out, shared["attn"]["wo"])
            new_kv = (kcache, vcache)
        x = x + ll.ffn(shared["ffn"],
                       ll.rmsnorm(x, shared["ln2"], cfg.norm_eps)[:, None],
                       cfg)[:, 0]
        return h + x, ((hs, cs) + new_kv)

    if cfg.kv_pq:
        xs = (grouped, p["group_in"], gh, gc, cache["attn_k_codes"],
              cache["attn_v_codes"], cache["attn_k_cb"], cache["attn_v_cb"])
    else:
        xs = (grouped, p["group_in"], gh, gc, cache["attn_k"], cache["attn_v"])
    h, ys = jax.lax.scan(group_body, h, xs)
    new_cache = dict(cache)
    new_cache["h"] = ys[0].reshape(cache["h"].shape)
    new_cache["conv"] = ys[1].reshape(cache["conv"].shape)
    if cfg.kv_pq:
        new_cache["attn_k_codes"], new_cache["attn_v_codes"] = ys[2], ys[3]
    else:
        new_cache["attn_k"], new_cache["attn_v"] = ys[2], ys[3]
    return h, new_cache


def mamba_stack_prefill(p: dict, h: jax.Array, cfg: ModelConfig,
                        positions: jax.Array, max_seq: int
                        ) -> tuple[jax.Array, dict]:
    """Full-sequence forward that also emits the decode cache (states + KV)."""
    b, s, _ = h.shape
    h0 = h

    def layer_body(carry, lp):
        h = carry
        out, st = ssm_mod.mamba_block(
            lp["mamba"], ll.rmsnorm(h, lp["ln"], cfg.norm_eps), cfg,
            return_state=True)
        return h + out, (st["h"], st["conv"])

    if not cfg.shared_attn_every:
        h, (hs, convs) = jax.lax.scan(layer_body, h, p["blocks"])
        return h, {"h": hs, "conv": convs}

    k = cfg.shared_attn_every
    n_groups = cfg.n_layers // k
    grouped = jax.tree.map(lambda x: x.reshape(n_groups, k, *x.shape[1:]),
                           p["blocks"])
    shared = p["shared"]

    def group_body(carry, xs):
        h = carry
        gp, gin = xs
        h, (hs, convs) = jax.lax.scan(layer_body, h, gp)
        x = jnp.concatenate([h, h0], axis=-1) @ gin["w"]
        xn = ll.rmsnorm(x, shared["ln1"], cfg.norm_eps)
        q, kk, vv = ll.qkv_project(shared["attn"], xn, cfg, positions)
        out = ll.chunked_causal_attention(q, kk, vv, cfg)
        x = x + jnp.einsum("bshk,hkd->bsd", out, shared["attn"]["wo"])
        x = x + ll.ffn(shared["ffn"], ll.rmsnorm(x, shared["ln2"], cfg.norm_eps), cfg)
        return constrain(h + x, "batch", "seq", "embed"), (hs, convs, kk, vv)

    h, (hs, convs, ks, vs) = jax.lax.scan(group_body, h, (grouped, p["group_in"]))
    cache = {
        "h": hs.reshape(cfg.n_layers, *hs.shape[2:]),
        "conv": convs.reshape(cfg.n_layers, *convs.shape[2:]),
    }
    pad = max_seq - s
    if cfg.kv_pq:
        kcb, vcb = None, None
        raise NotImplementedError(
            "hybrid PQ prefill: encode via examples/serve_lm.py calibration")
    cache["attn_k"] = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache["attn_v"] = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return h, cache


def mamba_stack_prefill_pq(p: dict, h: jax.Array, cfg: ModelConfig,
                           positions: jax.Array, max_seq: int,
                           k_cb: jax.Array, v_cb: jax.Array
                           ) -> tuple[jax.Array, dict]:
    """Hybrid prefill with 4-bit-PQ encoding of the shared-attn KV (paper
    tech): the (G, B, S, KV, hd) cache becomes (G, B, S, KV, M//2) u8 codes."""
    b, s, _ = h.shape
    h0 = h
    k = cfg.shared_attn_every
    n_groups = cfg.n_layers // k
    grouped = jax.tree.map(lambda x: x.reshape(n_groups, k, *x.shape[1:]),
                           p["blocks"])
    shared = p["shared"]

    def layer_body(carry, lp):
        h = carry
        out, st = ssm_mod.mamba_block(
            lp["mamba"], ll.rmsnorm(h, lp["ln"], cfg.norm_eps), cfg,
            return_state=True)
        return h + out, (st["h"], st["conv"])

    def group_body(carry, xs):
        h = carry
        gp, gin, kcb_g, vcb_g = xs
        h, (hs, convs) = jax.lax.scan(layer_body, h, gp)
        x = jnp.concatenate([h, h0], axis=-1) @ gin["w"]
        xn = ll.rmsnorm(x, shared["ln1"], cfg.norm_eps)
        q, kk, vv = ll.qkv_project(shared["attn"], xn, cfg, positions)
        out = ll.chunked_causal_attention(q, kk, vv, cfg)
        x = x + jnp.einsum("bshk,hkd->bsd", out, shared["attn"]["wo"])
        x = x + ll.ffn(shared["ffn"], ll.rmsnorm(x, shared["ln2"], cfg.norm_eps), cfg)
        kcodes = jax.vmap(lambda t: kvc.encode_kv(t, kcb_g), 1, 1)(kk)
        vcodes = jax.vmap(lambda t: kvc.encode_kv(t, vcb_g), 1, 1)(vv)
        return constrain(h + x, "batch", "seq", "embed"), (hs, convs, kcodes, vcodes)

    h, (hs, convs, kcs, vcs) = jax.lax.scan(
        group_body, h, (grouped, p["group_in"], k_cb, v_cb))
    pad = max_seq - s
    cache = {
        "h": hs.reshape(cfg.n_layers, *hs.shape[2:]),
        "conv": convs.reshape(cfg.n_layers, *convs.shape[2:]),
        "attn_k_codes": jnp.pad(kcs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "attn_v_codes": jnp.pad(vcs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
        "attn_k_cb": k_cb,
        "attn_v_cb": v_cb,
    }
    return h, cache


# ---------------------------------------------------------------------------
# rwkv6 family
# ---------------------------------------------------------------------------

def rwkv_stack_specs(cfg: ModelConfig) -> dict:
    return {"blocks": ll.stacked({
        "ln1": ll.rmsnorm_spec(cfg.d_model),
        "ln2": ll.rmsnorm_spec(cfg.d_model),
        "rwkv": rwkv_mod.rwkv_specs(cfg),
    }, cfg.n_layers)}


def rwkv_stack(p: dict, h: jax.Array, cfg: ModelConfig, positions: jax.Array
               ) -> tuple[jax.Array, jax.Array]:
    del positions

    def body(carry, lp):
        h = carry
        tm, _ = rwkv_mod.rwkv_time_mix(lp["rwkv"],
                                       ll.rmsnorm(h, lp["ln1"], cfg.norm_eps), cfg)
        h = h + tm
        h = h + rwkv_mod.rwkv_channel_mix(
            lp["rwkv"], ll.rmsnorm(h, lp["ln2"], cfg.norm_eps))
        return constrain(h, "batch", "seq", "embed"), None

    gs = _group_size(cfg)
    if gs <= 1:
        body_r = _remat(cfg, body)
        h, _ = jax.lax.scan(body_r, h, p["blocks"])
        return h, jnp.float32(0.0)

    n_groups = cfg.n_layers // gs
    grouped = jax.tree.map(lambda x: x.reshape(n_groups, gs, *x.shape[1:]),
                           p["blocks"])

    def group_body(carry, gp):
        return jax.lax.scan(body, carry, gp)[0], None

    h, _ = jax.lax.scan(_remat(cfg, group_body), h, grouped)
    return h, jnp.float32(0.0)


def rwkv_cache_init(cfg: ModelConfig, batch: int, dtype) -> dict:
    nh, hd = cfg.rwkv_nheads, cfg.rwkv_head_dim
    return {
        "s": jnp.zeros((cfg.n_layers, batch, nh, hd, hd), jnp.float32),
        "tm_prev": jnp.zeros((cfg.n_layers, batch, cfg.d_model), dtype),
        "cm_prev": jnp.zeros((cfg.n_layers, batch, cfg.d_model), dtype),
    }


def rwkv_cache_axes() -> dict:
    return {"s": ("stack", "batch", "ssm_heads", None, None),
            "tm_prev": ("stack", "batch", "embed"),
            "cm_prev": ("stack", "batch", "embed")}


def rwkv_stack_prefill(p: dict, h: jax.Array, cfg: ModelConfig
                       ) -> tuple[jax.Array, dict]:
    """Full-sequence forward emitting the O(1) decode state per layer."""
    def body(carry, lp):
        h = carry
        x = ll.rmsnorm(h, lp["ln1"], cfg.norm_eps)
        tm, s_final = rwkv_mod.rwkv_time_mix(lp["rwkv"], x, cfg)
        h = h + tm
        xn = ll.rmsnorm(h, lp["ln2"], cfg.norm_eps)
        h = h + rwkv_mod.rwkv_channel_mix(lp["rwkv"], xn)
        return constrain(h, "batch", "seq", "embed"), (s_final, x[:, -1], xn[:, -1])

    h, (ss, tms, cms) = jax.lax.scan(body, h, p["blocks"])
    return h, {"s": ss, "tm_prev": tms, "cm_prev": cms}


def rwkv_stack_decode(p: dict, h: jax.Array, cfg: ModelConfig, cache: dict,
                      position: jax.Array) -> tuple[jax.Array, dict]:
    del position

    def body(carry, xs):
        h = carry
        lp, s, tm_prev, cm_prev = xs
        x = ll.rmsnorm(h, lp["ln1"], cfg.norm_eps)
        tm, new_state = rwkv_mod.rwkv_decode_step(
            lp["rwkv"], x, {"s": s, "tm_prev": tm_prev, "cm_prev": cm_prev}, cfg)
        h = h + tm
        xn = ll.rmsnorm(h, lp["ln2"], cfg.norm_eps)
        h = h + rwkv_mod.rwkv_channel_mix_step(lp["rwkv"], xn, cm_prev)
        return h, (new_state["s"], x, xn)

    h, (ss, tms, cms) = jax.lax.scan(
        body, h, (p["blocks"], cache["s"], cache["tm_prev"], cache["cm_prev"]))
    return h, {"s": ss, "tm_prev": tms, "cm_prev": cms}
