"""LM substrate: the assigned architectures as composable JAX modules."""
