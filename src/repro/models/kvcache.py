"""KV caches for decode: exact bf16 and 4-bit-PQ-compressed (paper technique).

The PQ-compressed cache is the LM-serving home of the paper's kernel: decode
attention scores q·k_i are computed by ADC against PQ-encoded keys with a
16-entry inner-product LUT per sub-space — the same register-resident
fast-scan machinery as the ANN index (inner-product LUTs instead of L2).
Values are PQ-encoded too and reconstructed on the fly inside an
online-softmax scan over context chunks, so HBM traffic is the 4-bit codes,
not the bf16 tensors: an 8x memory/bandwidth cut at M = head_dim/2
(e.g. qwen1.5-32b decode_32k: 21.4 GB/device exact -> 2.7 GB/device PQ;
exact does NOT fit v5e HBM, PQ does — see EXPERIMENTS.md).

Codebooks are per-(layer, kv-head, sub-space) and are serving-time constants
(calibrated offline on activation samples; `calibrate_kv_codebooks` below).
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import fastscan as fs
from repro.models.config import ModelConfig

# logical axes for cache trees (used by launch/serve for shardings)
EXACT_KV_AXES = ("stack", "batch", "kv_seq", "kv_heads", "head_dim")
PQ_CODE_AXES = ("stack", "batch", "kv_seq", "kv_heads", "pq_m")
PQ_CB_AXES = ("stack", "kv_heads", "pq_m", None, None)


class ExactKVCache(NamedTuple):
    k: jax.Array  # (L, B, Smax, KV, hd)
    v: jax.Array


class PQKVCache(NamedTuple):
    k_codes: jax.Array    # (L, B, Smax, KV, M//2) u8 (nibble-packed)
    v_codes: jax.Array
    k_cb: jax.Array       # (L, KV, M, 16, dsub) codebooks
    v_cb: jax.Array


def init_exact(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> ExactKVCache:
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, max_seq, kv, hd)
    return ExactKVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def init_pq(cfg: ModelConfig, batch: int, max_seq: int, key=None) -> PQKVCache:
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    m = cfg.resolved_kv_pq_m
    dsub = hd // m
    lshape = (cfg.n_layers, batch, max_seq, kv, m // 2)
    cbshape = (cfg.n_layers, kv, m, 16, dsub)
    if key is None:
        cb_k = jnp.zeros(cbshape, jnp.bfloat16)
        cb_v = jnp.zeros(cbshape, jnp.bfloat16)
    else:
        k1, k2 = jax.random.split(key)
        cb_k = jax.random.normal(k1, cbshape, jnp.bfloat16)
        cb_v = jax.random.normal(k2, cbshape, jnp.bfloat16)
    return PQKVCache(jnp.zeros(lshape, jnp.uint8), jnp.zeros(lshape, jnp.uint8),
                     cb_k, cb_v)


def exact_cache_axes() -> ExactKVCache:
    return ExactKVCache(EXACT_KV_AXES, EXACT_KV_AXES)


def pq_cache_axes() -> PQKVCache:
    return PQKVCache(PQ_CODE_AXES, PQ_CODE_AXES, PQ_CB_AXES, PQ_CB_AXES)


# ---------------------------------------------------------------------------
# PQ encode/decode of K/V rows
# ---------------------------------------------------------------------------

def encode_kv(x: jax.Array, cb: jax.Array) -> jax.Array:
    """x: (B, KV, hd); cb: (KV, M, 16, dsub) -> packed codes (B, KV, M//2)."""
    b, kv, hd = x.shape
    m, _, dsub = cb.shape[1], cb.shape[2], cb.shape[3]
    xs = x.reshape(b, kv, m, 1, dsub)
    d = jnp.sum((xs.astype(jnp.float32) - cb[None].astype(jnp.float32)) ** 2, -1)
    codes = jnp.argmin(d, axis=-1).astype(jnp.uint8)          # (B, KV, M)
    lo = codes[..., 0::2]
    hi = codes[..., 1::2]
    return lo | (hi << 4)


def decode_kv(packed: jax.Array, cb: jax.Array) -> jax.Array:
    """packed: (..., KV, M//2) u8; cb: (KV, M, 16, dsub) -> (..., KV, hd)."""
    lo = (packed & 0xF).astype(jnp.int32)
    hi = ((packed >> 4) & 0xF).astype(jnp.int32)
    codes = jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)  # (...,KV,M)
    # gather: cb[kv, m, codes] -> (..., KV, M, dsub)
    gathered = jnp.take_along_axis(
        cb[(None,) * (codes.ndim - 2)],                 # (...,KV,M,16,dsub)
        codes[..., None, None].astype(jnp.int32), axis=-2)[..., 0, :]
    return gathered.reshape(*packed.shape[:-1], -1)


def calibrate_kv_codebooks(key: jax.Array, samples: jax.Array, m: int,
                           iters: int = 15) -> jax.Array:
    """k-means codebooks from activation samples (N, KV, hd) -> (KV, M, 16, dsub)."""
    from repro.core.kmeans import kmeans_multi
    n, kv, hd = samples.shape
    dsub = hd // m
    sub = samples.reshape(n, kv, m, dsub).transpose(1, 2, 0, 3).reshape(kv * m, n, dsub)
    res = kmeans_multi(key, sub.astype(jnp.float32), k=16, iters=iters)
    return res.centroids.reshape(kv, m, 16, dsub)


# ---------------------------------------------------------------------------
# PQ decode attention (one new token vs a PQ-compressed context)
# ---------------------------------------------------------------------------

def _build_ip_lut(q: jax.Array, k_cb: jax.Array) -> jax.Array:
    """Inner-product LUTs. q: (B, KV, g, hd); k_cb: (KV, M, 16, dsub).

    Returns (B, KV, g, M, 16) float32: T[m][c] = q_m . cb[m][c].
    """
    b, kv, g, hd = q.shape
    m, dsub = k_cb.shape[1], k_cb.shape[3]
    qs = q.reshape(b, kv, g, m, dsub)
    return jnp.einsum("bkgmd,kmcd->bkgmc", qs.astype(jnp.float32),
                      k_cb.astype(jnp.float32))


def _adc_scores(lut: jax.Array, packed: jax.Array, quantize_q8: bool) -> jax.Array:
    """lut: (B, KV, g, M, 16); packed: (B, C, KV, M//2) -> scores (B, KV, g, C).

    With quantize_q8 (paper-faithful) the LUT is affine-quantized to u8 and
    accumulated in int32, exactly like the ANN fast-scan; scores are then
    dequantized for the softmax.
    """
    lo = (packed & 0xF).astype(jnp.int32)
    hi = ((packed >> 4) & 0xF).astype(jnp.int32)
    codes = jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)  # (B,C,KV,M)
    codes = jnp.transpose(codes, (0, 2, 3, 1))                   # (B,KV,M,C)
    if quantize_q8:
        qlut = fs.quantize_lut(lut.reshape(-1, *lut.shape[-2:]))  # rows = B*KV*g
        t = qlut.table_q8.reshape(lut.shape).astype(jnp.int32)    # (B,KV,g,M,16)
        gathered = jnp.take_along_axis(t, codes[:, :, None].astype(jnp.int32),
                                       axis=-1)                   # (B,KV,g,M,C)
        acc = jnp.sum(gathered, axis=-2, dtype=jnp.int32)         # (B,KV,g,C)
        scale = qlut.scale.reshape(*lut.shape[:3])                # (B,KV,g)
        bias = qlut.bias.reshape(*lut.shape[:4]).sum(-1)          # (B,KV,g)
        return scale[..., None] * acc.astype(jnp.float32) + bias[..., None]
    gathered = jnp.take_along_axis(lut, codes[:, :, None].astype(jnp.int32), axis=-1)
    return jnp.sum(gathered, axis=-2)


@functools.partial(jax.jit, static_argnames=("chunk", "quantize_q8"))
def pq_decode_attention(q: jax.Array, k_codes: jax.Array, v_codes: jax.Array,
                        k_cb: jax.Array, v_cb: jax.Array, position: jax.Array,
                        *, chunk: int = 2048, quantize_q8: bool = True
                        ) -> jax.Array:
    """One-token attention against the PQ cache, online softmax over chunks.

    q: (B, H, hd); k_codes/v_codes: (B, Smax, KV, M//2) u8;
    k_cb/v_cb: (KV, M, 16, dsub); position: (B,) current positions.
    Returns (B, H, hd).
    """
    b, h, hd = q.shape
    kv = k_codes.shape[2]
    g = h // kv
    smax = k_codes.shape[1]
    chunk = min(chunk, smax)
    assert smax % chunk == 0, (smax, chunk)
    nchunks = smax // chunk
    qg = q.reshape(b, kv, g, hd)
    lut = _build_ip_lut(qg, k_cb) / math.sqrt(hd)    # (B,KV,g,M,16)

    m0 = jnp.full((b, kv, g), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, kv, g), jnp.float32)
    acc0 = jnp.zeros((b, kv, g, hd), jnp.float32)

    def body(i, state):
        m, l, acc = state
        kc = jax.lax.dynamic_slice_in_dim(k_codes, i * chunk, chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(v_codes, i * chunk, chunk, axis=1)
        s = _adc_scores(lut, kc, quantize_q8)         # (B,KV,g,C)
        pos_in_chunk = i * chunk + jnp.arange(chunk)
        valid = pos_in_chunk[None, :] <= position[:, None]       # (B,C)
        s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
        mj = jnp.maximum(m, jnp.max(s, axis=-1))
        mj_safe = jnp.where(jnp.isfinite(mj), mj, 0.0)
        p = jnp.exp(s - mj_safe[..., None])           # (B,KV,g,C)
        corr = jnp.exp(jnp.where(jnp.isfinite(m), m - mj_safe, -jnp.inf))
        lj = l * corr + jnp.sum(p, axis=-1)
        vh = decode_kv(vc, v_cb)                      # (B,C,KV,hd)
        accj = acc * corr[..., None] + jnp.einsum(
            "bkgc,bckp->bkgp", p.astype(vh.dtype), vh).astype(jnp.float32)
        return mj, lj, accj

    m, l, acc = jax.lax.fori_loop(0, nchunks, body, (m0, l0, acc0))
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.reshape(b, h, hd).astype(q.dtype)


def update_exact(k_cache: jax.Array, v_cache: jax.Array, k_new: jax.Array,
                 v_new: jax.Array, pos: jax.Array):
    """Write one token at scalar position `pos`. caches: (B, Smax, KV, hd)."""
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k_new[:, None], pos, 1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v_new[:, None], pos, 1)
    return k_cache, v_cache


def update_pq(k_codes: jax.Array, v_codes: jax.Array, k_new: jax.Array,
              v_new: jax.Array, k_cb: jax.Array, v_cb: jax.Array,
              pos: jax.Array):
    """Encode one token's K/V to 4-bit codes and write at `pos`."""
    kc = encode_kv(k_new, k_cb)[:, None]              # (B,1,KV,M//2)
    vc = encode_kv(v_new, v_cb)[:, None]
    k_codes = jax.lax.dynamic_update_slice_in_dim(k_codes, kc, pos, 1)
    v_codes = jax.lax.dynamic_update_slice_in_dim(v_codes, vc, pos, 1)
    return k_codes, v_codes
