"""Shared NN layers: param-spec system, norms, RoPE, GQA attention, FFNs.

Parameters are declared once as `ParamSpec` trees (shape + logical sharding
axes + initializer); `init_params` / `param_axes` / `param_shapes` derive the
materialized weights, the pjit sharding tree, and the dry-run
ShapeDtypeStructs from the same declaration.
"""
from __future__ import annotations

import functools
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.launch.sharding import constrain
from repro.models.config import ModelConfig


class ParamSpec(NamedTuple):
    shape: tuple
    axes: tuple            # logical axis names, len == ndim
    init: str = "normal"   # normal | zeros | ones
    scale: float = 1.0     # stddev multiplier for "normal"


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(key: jax.Array, specs: Any, dtype=jnp.float32) -> Any:
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))

    def one(k, s: ParamSpec):
        if s.init == "zeros":
            return jnp.zeros(s.shape, dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, dtype)
        fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
        std = s.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(k, s.shape, jnp.float32) * std).astype(dtype)

    return jax.tree.unflatten(treedef, [one(k, s) for k, s in zip(keys, leaves)])


def param_axes(specs: Any) -> Any:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def param_shapes(specs: Any, dtype=jnp.float32) -> Any:
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype), specs,
                        is_leaf=is_spec)


def stacked(specs: Any, n: int) -> Any:
    """Prepend a scan-over-layers axis to every spec in the tree."""
    return jax.tree.map(
        lambda s: ParamSpec((n, *s.shape), ("stack", *s.axes), s.init, s.scale),
        specs, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * w


def rmsnorm_spec(dim: int, axis: str | None = "embed") -> ParamSpec:
    return ParamSpec((dim,), (axis,), "ones")


# ---------------------------------------------------------------------------
# RoPE (on-the-fly from positions — no 500k-long precomputed tables)
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32. Rotates pairs (even, odd)."""
    b, s, h, hd = x.shape
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, :, None] * freq[None, None, :]  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA, qk-norm, qkv-bias, chunked-causal / decode)
# ---------------------------------------------------------------------------

def attn_specs(cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    p = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamSpec((h, hd), ("heads", "head_dim"), "zeros")
        p["bk"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), "zeros")
        p["bv"] = ParamSpec((kv, hd), ("kv_heads", "head_dim"), "zeros")
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_spec(hd, "head_dim")
        p["k_norm"] = rmsnorm_spec(hd, "head_dim")
    return p


def qkv_project(p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,KV,hd) with rope/norm applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", None, "heads", "head_dim")
    k = constrain(k, "batch", None, "kv_heads", "head_dim")
    v = constrain(v, "batch", None, "kv_heads", "head_dim")
    return q, k, v


def _softcap(s: jax.Array, cap: float) -> jax.Array:
    if cap > 0:
        return cap * jnp.tanh(s / cap)
    return s


def full_causal_attention(q, k, v, cfg: ModelConfig) -> jax.Array:
    """Reference O(S^2)-memory path for short sequences / smoke tests."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    scores = _softcap(scores / math.sqrt(hd), cfg.attn_logit_softcap)
    mask = jnp.tril(jnp.ones((s, s), jnp.bool_))
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(b, s, h, hd)


def chunked_causal_attention(q, k, v, cfg: ModelConfig) -> jax.Array:
    """Flash-style online-softmax attention in pure JAX.

    Scans query chunks; for each, an inner scan visits KV chunks with a
    lax.cond that skips blocks past the causal frontier at runtime (cond in a
    sequential scan executes one branch only). This stays reverse-mode
    differentiable (unlike a dynamic-bound fori_loop) while never
    materializing an O(S^2) buffer and skipping ~half the block compute.
    """
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    cq, ckv = cfg.attn_q_chunk, cfg.attn_kv_chunk
    if s % cq or s % ckv or s <= cq:
        return full_causal_attention(q, k, v, cfg)
    nq, nkv = s // cq, s // ckv
    qg = q.reshape(b, nq, cq, kvh, g, hd)
    scale = 1.0 / math.sqrt(hd)

    def q_chunk(qi, i, k, v):
        # (B, cq, KV, g, hd) x full K/V -> (B, KV, g, cq, hd)
        m0 = jnp.full((b, kvh, g, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, cq), jnp.float32)
        acc0 = jnp.zeros((b, kvh, g, cq, hd), jnp.float32)

        def compute_block(args):
            m, l, acc, j = args
            kj = jax.lax.dynamic_slice_in_dim(k, j * ckv, ckv, axis=1)
            vj = jax.lax.dynamic_slice_in_dim(v, j * ckv, ckv, axis=1)
            sij = jnp.einsum("bqkgh,bskh->bkgqs", qi, kj).astype(jnp.float32)
            sij = _softcap(sij * scale, cfg.attn_logit_softcap)
            qpos = i * cq + jnp.arange(cq)
            kpos = j * ckv + jnp.arange(ckv)
            causal = qpos[:, None] >= kpos[None, :]
            sij = jnp.where(causal[None, None, None], sij, -jnp.inf)
            mj = jnp.maximum(m, jnp.max(sij, axis=-1))
            # guard fully-masked rows: mj could still be -inf
            mj_safe = jnp.where(jnp.isfinite(mj), mj, 0.0)
            pij = jnp.exp(sij - mj_safe[..., None])
            corr = jnp.exp(jnp.where(jnp.isfinite(m), m - mj_safe, -jnp.inf))
            lj = l * corr + jnp.sum(pij, axis=-1)
            accj = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", pij.astype(v.dtype), vj).astype(jnp.float32)
            return mj, lj, accj

        def kv_body(state, j):
            m, l, acc = state
            # causal frontier: block j is live iff its first key position
            # is <= the last query position of this q chunk
            live = j * ckv < (i + 1) * cq
            m, l, acc = jax.lax.cond(live, compute_block,
                                     lambda args: args[:3], (m, l, acc, j))
            return (m, l, acc), None

        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, acc0),
                                      jnp.arange(nkv))
        out = acc / jnp.maximum(l, 1e-20)[..., None]      # (B, KV, g, cq, hd)
        out = jnp.transpose(out, (0, 3, 1, 2, 4))          # (B, cq, KV, g, hd)
        return out.astype(q.dtype)

    # flash-attention backward, structurally: checkpoint each q chunk so the
    # O(cq x ckv) score blocks are recomputed in bwd instead of being saved
    # (saving them costs ~4 GB/layer of f32 HBM traffic at S=4k, B=16 — see
    # EXPERIMENTS.md §Perf). Residuals per chunk are just (qi, out).
    q_chunk_ckpt = jax.checkpoint(q_chunk)

    def q_body(carry, inp):
        del carry
        qi, i = inp
        return None, q_chunk_ckpt(qi, i, k, v)

    _, outs = jax.lax.scan(q_body, None,
                           (jnp.moveaxis(qg, 1, 0), jnp.arange(nq)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, hd)
    return out


def attention(p: dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array
              ) -> jax.Array:
    """Training/prefill self-attention over a full sequence."""
    q, k, v = qkv_project(p, x, cfg, positions)
    out = chunked_causal_attention(q, k, v, cfg)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return constrain(out, "batch", "seq", "embed")


def decode_attention_scores(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, cfg: ModelConfig,
                            position: jax.Array) -> jax.Array:
    """One-token attention vs an ALREADY-UPDATED (B, Skv, KV, hd) cache.

    q: (B, H, hd); position: (B,) int32 — the current token's position
    (inclusive: the token attends to itself, so the caller must write the
    new K/V into the cache before scoring). Returns (B, H, hd).
    """
    b, h, hd = q.shape
    kvh = k_cache.shape[2]
    g = h // kvh
    qg = q.reshape(b, 1, kvh, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k_cache).astype(jnp.float32)
    scores = _softcap(scores / math.sqrt(hd), cfg.attn_logit_softcap)
    skv = k_cache.shape[1]
    valid = jnp.arange(skv)[None, :] <= position[:, None]   # (B, Skv)
    scores = jnp.where(valid[:, None, None, None, :], scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v_cache)
    return out.reshape(b, h, hd)


# ---------------------------------------------------------------------------
# FFN variants
# ---------------------------------------------------------------------------

def ffn_specs(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.mlp_type == "swiglu":
        return {
            "wi_gate": ParamSpec((d, f), ("embed", "mlp")),
            "wi_up": ParamSpec((d, f), ("embed", "mlp")),
            "wo": ParamSpec((f, d), ("mlp", "embed")),
        }
    return {
        "wi": ParamSpec((d, f), ("embed", "mlp")),
        "wo": ParamSpec((f, d), ("mlp", "embed")),
    }


def ffn(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["wi_gate"]) * (x @ p["wi_up"])
    elif cfg.mlp_type == "gelu":
        h = jax.nn.gelu(x @ p["wi"])
    elif cfg.mlp_type == "relu2":  # nemotron-4 squared ReLU
        h = jnp.square(jax.nn.relu(x @ p["wi"]))
    else:
        raise ValueError(cfg.mlp_type)
    h = constrain(h, "batch", None, "mlp")
    return constrain(h @ p["wo"], "batch", "seq", "embed")
