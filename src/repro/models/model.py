"""Public model API: specs/init/forward/loss/prefill/decode for every family.

All entry points are pure functions of (params, inputs, cfg) so they compose
directly with pjit, jax.grad, and the dry-run's .lower()/.compile().
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.launch.sharding import constrain
from repro.models import kvcache as kvc
from repro.models import layers as ll
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.models.layers import ParamSpec

AUX_LOSS_WEIGHT = 0.01


# ---------------------------------------------------------------------------
# specs / init
# ---------------------------------------------------------------------------

def lm_specs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.padded_vocab
    specs = {
        "embedding": ParamSpec((v, d), ("vocab", "embed"), scale=1.0),
        "ln_f": ll.rmsnorm_spec(d),
        "lm_head": ParamSpec((d, v), ("embed", "vocab")),
    }
    if cfg.block_type == "attn":
        specs["stack"] = tf.attn_stack_specs(cfg)
    elif cfg.block_type == "mamba2":
        specs["stack"] = tf.mamba_stack_specs(cfg)
    elif cfg.block_type == "rwkv6":
        specs["stack"] = tf.rwkv_stack_specs(cfg)
    else:
        raise ValueError(cfg.block_type)
    return specs


def init_lm(key: jax.Array, cfg: ModelConfig, dtype=None) -> Any:
    dtype = dtype or jnp.dtype(cfg.dtype)
    return ll.init_params(key, lm_specs(cfg), dtype)


def lm_axes(cfg: ModelConfig) -> Any:
    return ll.param_axes(lm_specs(cfg))


def lm_shapes(cfg: ModelConfig, dtype=None) -> Any:
    dtype = dtype or jnp.dtype(cfg.dtype)
    return ll.param_shapes(lm_specs(cfg), dtype)


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def forward(params: Any, tokens: jax.Array, cfg: ModelConfig,
            frontend_embeds: jax.Array | None = None,
            positions: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """tokens: (B, S) -> (logits (B, S, Vpad), aux_loss scalar).

    The modality frontend is a STUB (per brief): precomputed frame/patch
    embeddings occupy the first frontend_len sequence positions.
    """
    del positions  # positions are always 0..S-1 for full-sequence forward
    h, aux = _hidden_states(params, tokens, cfg, frontend_embeds)
    logits = h @ params["lm_head"]
    return constrain(logits, "batch", "seq", "vocab"), aux


def _ce_from_logits(logits: jax.Array, targets: jax.Array, mask: jax.Array,
                    cfg: ModelConfig) -> jax.Array:
    """Masked summed NLL for one (B, s, Vpad) logits block."""
    logits = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab:
        pad = jnp.arange(cfg.padded_vocab) >= cfg.vocab
        logits = jnp.where(pad[None, None], -1e30, logits)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    return jnp.sum((lse - gold) * mask)


def loss_fn(params: Any, batch: dict, cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """batch: {tokens, targets, mask, [frontend_embeds]} -> (loss, metrics).

    Cross-entropy is computed in sequence chunks over the final hidden
    states (jax.checkpoint'd), so the full (B, S, Vpad) f32 logits tensor is
    never materialized nor saved for backward — it was the dominant memory
    term for small-d_model/large-vocab archs (musicgen: 77.8s -> see
    EXPERIMENTS.md §Perf cell D; internvl2 vocab 153k likewise).
    """
    b, s = batch["tokens"].shape
    chunk = cfg.loss_chunk
    if chunk <= 0 or s % chunk or s <= chunk:
        logits, aux = forward(params, batch["tokens"], cfg,
                              frontend_embeds=batch.get("frontend_embeds"))
        nll = _ce_from_logits(logits, batch["targets"], batch["mask"], cfg)
    else:
        # forward WITHOUT the lm_head, then scan the head+CE over seq chunks
        h, aux = _hidden_states(params, batch["tokens"], cfg,
                                batch.get("frontend_embeds"))
        nc = s // chunk

        def rs(t):
            return t.reshape(b, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

        def body(acc, xs):
            hc, tc, mc = xs
            logits = hc @ params["lm_head"]
            return acc + _ce_from_logits(logits, tc, mc, cfg), None

        nll, _ = jax.lax.scan(
            jax.checkpoint(body),
            jnp.float32(0.0),
            (rs(h), rs(batch["targets"]), rs(batch["mask"])))
    denom = jnp.maximum(jnp.sum(batch["mask"]), 1.0)
    ce = nll / denom
    loss = ce + AUX_LOSS_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux, "tokens": denom}


def _hidden_states(params: Any, tokens: jax.Array, cfg: ModelConfig,
                   frontend_embeds: jax.Array | None) -> tuple[jax.Array, jax.Array]:
    """forward() minus the lm_head: final-norm hidden states (B, S, D)."""
    b, s = tokens.shape
    h = params["embedding"][tokens]
    h = constrain(h, "batch", "seq", "embed")
    if cfg.frontend != "none" and frontend_embeds is not None:
        h = jax.lax.dynamic_update_slice(h, frontend_embeds.astype(h.dtype),
                                         (0, 0, 0))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.block_type == "attn":
        h, aux = tf.attn_stack(params["stack"], h, cfg, positions)
    elif cfg.block_type == "mamba2":
        h, aux = tf.mamba_stack(params["stack"], h, cfg, positions)
    else:
        h, aux = tf.rwkv_stack(params["stack"], h, cfg, positions)
    return ll.rmsnorm(h, params["ln_f"], cfg.norm_eps), aux


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None,
               key=None) -> Any:
    dtype = dtype or jnp.dtype(cfg.dtype)
    if cfg.block_type == "attn":
        if cfg.kv_pq:
            return kvc.init_pq(cfg, batch, max_seq, key=key)
        return kvc.init_exact(cfg, batch, max_seq, dtype)
    if cfg.block_type == "mamba2":
        return tf.mamba_cache_init(cfg, batch, max_seq, dtype, key=key)
    return tf.rwkv_cache_init(cfg, batch, dtype)


def cache_axes(cfg: ModelConfig) -> Any:
    if cfg.block_type == "attn":
        return kvc.pq_cache_axes() if cfg.kv_pq else kvc.exact_cache_axes()
    if cfg.block_type == "mamba2":
        return tf.mamba_cache_axes(cfg)
    return tf.rwkv_cache_axes()


def decode_step(params: Any, cache: Any, tokens: jax.Array,
                position: jax.Array, cfg: ModelConfig
                ) -> tuple[jax.Array, Any]:
    """One decode step. tokens: (B,) int32; position: (B,) int32.

    Returns (logits (B, Vpad), updated cache). This is the `serve_step`
    lowered by the decode_32k / long_500k dry-run cells.
    """
    h = params["embedding"][tokens]                     # (B, D)
    h = constrain(h, "batch", "embed")
    if cfg.block_type == "attn":
        h, cache = tf.attn_stack_decode(params["stack"], h, cfg, cache, position)
    elif cfg.block_type == "mamba2":
        h0 = h
        h, cache = tf.mamba_stack_decode(params["stack"], h, cfg, cache,
                                         position, h0)
    else:
        h, cache = tf.rwkv_stack_decode(params["stack"], h, cfg, cache, position)
    h = ll.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    logits = h @ params["lm_head"]
    return constrain(logits, "batch", "vocab"), cache


def prefill(params: Any, tokens: jax.Array, cfg: ModelConfig,
            max_seq: int | None = None,
            frontend_embeds: jax.Array | None = None,
            pq_cache: Any | None = None) -> tuple[jax.Array, Any]:
    """Prefill a prompt, returning (last-position logits, filled cache).

    Attention family: one stack scan that also captures per-layer K/V (or
    their 4-bit PQ codes when cfg.kv_pq, via `pq_cache` carrying calibrated
    codebooks). SSM/RWKV: the chunked scans natively emit their O(1) states.
    """
    b, s = tokens.shape
    max_seq = max_seq or s
    if cfg.block_type != "attn":
        h = params["embedding"][tokens]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
        if cfg.block_type == "mamba2":
            if cfg.kv_pq and cfg.shared_attn_every:
                assert pq_cache is not None, "PQ prefill needs calibrated codebooks"
                h, cache = tf.mamba_stack_prefill_pq(
                    params["stack"], h, cfg, positions, max_seq,
                    pq_cache["attn_k_cb"], pq_cache["attn_v_cb"])
            else:
                h, cache = tf.mamba_stack_prefill(params["stack"], h, cfg,
                                                  positions, max_seq)
        else:
            h, cache = tf.rwkv_stack_prefill(params["stack"], h, cfg)
        h = ll.rmsnorm(h, params["ln_f"], cfg.norm_eps)
        logits = h[:, -1] @ params["lm_head"]
        return logits, cache

    if cfg.kv_pq:  # paper tech: encode K/V straight to 4-bit codes
        assert pq_cache is not None, "PQ prefill needs calibrated codebooks"
        return encode_pq_cache(params, tokens, cfg, pq_cache)

    # attention family: capture per-layer K/V during the stack scan
    h = params["embedding"][tokens]
    if cfg.frontend != "none" and frontend_embeds is not None:
        h = jax.lax.dynamic_update_slice(h, frontend_embeds.astype(h.dtype),
                                         (0, 0, 0))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(carry, lp):
        h = carry
        x = ll.rmsnorm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = ll.qkv_project(lp["attn"], x, cfg, positions)
        out = ll.chunked_causal_attention(q, k, v, cfg)
        h = h + jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"])
        hn = ll.rmsnorm(h, lp["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            from repro.models import moe as moe_mod
            f, _ = moe_mod.moe_ffn(lp["moe"], hn, cfg)
            h = h + f
        else:
            h = h + ll.ffn(lp["ffn"], hn, cfg)
        return h, (k, v)

    h, (ks, vs) = jax.lax.scan(body, h, params["stack"]["blocks"])
    h = ll.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    logits = h[:, -1] @ params["lm_head"]

    pad = max_seq - s
    ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return logits, kvc.ExactKVCache(ks, vs)


def encode_pq_cache(params: Any, tokens: jax.Array, cfg: ModelConfig,
                    cache: kvc.PQKVCache) -> tuple[jax.Array, kvc.PQKVCache]:
    """Prefill into a PQ cache whose codebooks are already calibrated."""
    b, s = tokens.shape
    h = params["embedding"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(carry, xs):
        h = carry
        lp, kcb, vcb = xs
        x = ll.rmsnorm(h, lp["ln1"], cfg.norm_eps)
        q, k, v = ll.qkv_project(lp["attn"], x, cfg, positions)
        out = ll.chunked_causal_attention(q, k, v, cfg)
        h = h + jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"])
        hn = ll.rmsnorm(h, lp["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            from repro.models import moe as moe_mod
            f, _ = moe_mod.moe_ffn(lp["moe"], hn, cfg)
            h = h + f
        else:
            h = h + ll.ffn(lp["ffn"], hn, cfg)
        # encode K/V rows to 4-bit codes (vectorized over sequence)
        kc = jax.vmap(lambda kk: kvc.encode_kv(kk, kcb), in_axes=1, out_axes=1)(k)
        vc = jax.vmap(lambda vv: kvc.encode_kv(vv, vcb), in_axes=1, out_axes=1)(v)
        return h, (kc, vc)

    h, (kcs, vcs) = jax.lax.scan(body, h,
                                 (params["stack"]["blocks"], cache.k_cb, cache.v_cb))
    h = ll.rmsnorm(h, params["ln_f"], cfg.norm_eps)
    logits = h[:, -1] @ params["lm_head"]
    smax = cache.k_codes.shape[2]
    pad = smax - s
    kcs = jnp.pad(kcs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    vcs = jnp.pad(vcs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return logits, kvc.PQKVCache(kcs, vcs, cache.k_cb, cache.v_cb)
