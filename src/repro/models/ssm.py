"""Mamba2 (SSD) block: chunked state-space scan, train + decode paths.

The SSD ("state-space dual") chunked algorithm: within a chunk of length L
the recurrence h_t = a_t h_{t-1} + B_t (dt_t x_t) is unrolled into an L x L
decay-weighted attention-like matmul (MXU-friendly); across chunks a short
lax.scan carries the (nh, hd, ds) state. Complexity O(S·L·hd + S·hd·ds),
sub-quadratic in S — this is why the hybrid/ssm archs run the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.layers import ParamSpec, rmsnorm


def mamba_specs(cfg: ModelConfig) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    g, ds, nh, w = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_conv
    conv_dim = di + 2 * g * ds
    return {
        "in_proj": ParamSpec((d, 2 * di + 2 * g * ds + nh), ("embed", "mlp")),
        "conv_w": ParamSpec((w, conv_dim), ("conv", "mlp"), scale=0.5),
        "conv_b": ParamSpec((conv_dim,), ("mlp",), "zeros"),
        "a_log": ParamSpec((nh,), ("ssm_heads",), "ones"),
        "d_skip": ParamSpec((nh,), ("ssm_heads",), "ones"),
        "dt_bias": ParamSpec((nh,), ("ssm_heads",), "zeros"),
        "norm": ParamSpec((di,), ("mlp",), "ones"),
        "out_proj": ParamSpec((di, d), ("mlp", "embed")),
    }


def _split_in_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, g, ds, nh = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_nheads
    z, xc, b, c, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + g * ds, 2 * di + 2 * g * ds], axis=-1)
    return z, xc, b, c, dt


def _causal_conv(xin: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d: xin (B, S, C), w (W, C) -> (B, S, C)."""
    width = w.shape[0]
    pad = jnp.pad(xin, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xin)
    for i in range(width):  # static unroll: width is 4
        out = out + pad[:, i:i + xin.shape[1], :] * w[i]
    return out + b


def ssd_chunked(xh: jax.Array, log_a: jax.Array, bmat: jax.Array,
                cmat: jax.Array, chunk: int,
                h0: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    xh:    (B, S, nh, hd)  dt-weighted inputs
    log_a: (B, S, nh)      per-step log decay (<= 0)
    bmat:  (B, S, g, ds)   input maps (groups broadcast over heads)
    cmat:  (B, S, g, ds)   output maps
    Returns (y (B, S, nh, hd), final state (B, nh, hd, ds)).
    """
    b, s, nh, hd = xh.shape
    g, ds = bmat.shape[2], bmat.shape[3]
    pad = (-s) % chunk
    if pad:  # pad with identity steps (decay 1, zero input): state-neutral
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s_orig, s = s, s + pad
    nc, l = s // chunk, chunk
    hpg = nh // g  # heads per group

    def rs(t, extra):  # (B, S, ...) -> (B, nc, L, ...)
        return t.reshape(b, nc, l, *extra)

    xh_c = rs(xh, (nh, hd))
    la_c = jnp.cumsum(rs(log_a, (nh,)).astype(jnp.float32), axis=2)  # (B,nc,L,nh)
    bh = jnp.repeat(rs(bmat, (g, ds)), hpg, axis=3)   # (B,nc,L,nh,ds)
    ch = jnp.repeat(rs(cmat, (g, ds)), hpg, axis=3)

    # ---- intra-chunk: attention-like L x L matmul per (chunk, head)
    gmat = jnp.einsum("bclhn,bcshn->bchls", ch, bh)   # (B,nc,nh,L,L)
    diff = la_c[:, :, :, None, :] - la_c[:, :, None, :, :]   # (B,nc,L,S?,nh)
    decay = jnp.exp(jnp.transpose(diff, (0, 1, 4, 2, 3)))    # (B,nc,nh,L,L)
    mask = jnp.tril(jnp.ones((l, l), jnp.bool_))
    m = jnp.where(mask, gmat * decay, 0.0).astype(xh.dtype)
    y_intra = jnp.einsum("bchls,bcshp->bclhp", m, xh_c)

    # ---- chunk states: S_c = sum_s exp(la_last - la_s) B_s x_s
    seg = jnp.exp(la_c[:, :, -1:, :] - la_c)          # (B,nc,L,nh)
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn", bh, seg.astype(xh.dtype), xh_c)

    # ---- inter-chunk scan over the carried state
    total = jnp.exp(la_c[:, :, -1, :])                # (B,nc,nh)
    h_init = (jnp.zeros((b, nh, hd, ds), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))

    def body(h, inp):
        st, tot = inp  # (B,nh,hd,ds), (B,nh)
        h_prev = h
        h = h * tot[:, :, None, None] + st.astype(jnp.float32)
        return h, h_prev

    hs, h_prevs = jax.lax.scan(
        body, h_init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(total, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)             # (B,nc,nh,hd,ds)

    # ---- inter-chunk contribution: C_t . (decay_t * H_prev)
    y_inter = jnp.einsum("bclhn,bclh,bchpn->bclhp",
                         ch, jnp.exp(la_c).astype(xh.dtype),
                         h_prevs.astype(xh.dtype))
    y = (y_intra + y_inter).reshape(b, s, nh, hd)
    return y[:, :s_orig], hs


def mamba_block(p: dict, x: jax.Array, cfg: ModelConfig,
                return_state: bool = False):
    """Training/prefill forward. x: (B, S, D) -> (B, S, D) [, final state]."""
    b, s, d = x.shape
    nh, hd = cfg.ssm_nheads, cfg.ssm_head_dim
    g, ds = cfg.ssm_groups, cfg.ssm_state

    zxbcdt = x @ p["in_proj"]
    z, xc, bm, cm, dt = _split_in_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xc, bm, cm], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"], p["conv_b"]))
    xc, bm, cm = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + g * ds], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))      # (nh,) negative
    log_a = (a[None, None, :] * dt)                   # (B,S,nh) <= 0
    xh = xc.reshape(b, s, nh, hd) * dt[..., None].astype(x.dtype)
    bmat = bm.reshape(b, s, g, ds)
    cmat = cm.reshape(b, s, g, ds)

    y, h_final = ssd_chunked(xh, log_a, bmat, cmat, cfg.ssm_chunk)
    y = y + xc.reshape(b, s, nh, hd) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    y = constrain(y, "batch", None, "mlp")
    out = constrain(y @ p["out_proj"], "batch", "seq", "embed")
    if return_state:
        w = p["conv_w"].shape[0]
        state = {"h": h_final, "conv": conv_in[:, s - (w - 1):, :]}
        return out, state
    return out


# ---------------------------------------------------------------------------
# decode path: O(1) per token
# ---------------------------------------------------------------------------

def mamba_state_init(cfg: ModelConfig, batch: int, dtype) -> dict:
    nh, hd, ds = cfg.ssm_nheads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * ds
    return {
        "h": jnp.zeros((batch, nh, hd, ds), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }


def mamba_decode_step(p: dict, x: jax.Array, state: dict, cfg: ModelConfig
                      ) -> tuple[jax.Array, dict]:
    """x: (B, D) one token -> (out (B, D), new state)."""
    b, d = x.shape
    nh, hd = cfg.ssm_nheads, cfg.ssm_head_dim
    g, ds = cfg.ssm_groups, cfg.ssm_state

    zxbcdt = x @ p["in_proj"]
    z, xc, bm, cm, dt = _split_in_proj(cfg, zxbcdt[:, None, :])
    conv_in = jnp.concatenate([xc, bm, cm], axis=-1)  # (B,1,conv_dim)
    window = jnp.concatenate([state["conv"], conv_in], axis=1)  # (B, W, conv)
    conv_out = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
    conv_out = jax.nn.silu(conv_out)
    xc, bm, cm = jnp.split(conv_out, [cfg.d_inner, cfg.d_inner + g * ds], axis=-1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(a[None] * dt)                      # (B,nh)
    xh = xc.reshape(b, nh, hd) * dt[..., None].astype(x.dtype)
    bmat = jnp.repeat(bm.reshape(b, g, ds), nh // g, axis=1)   # (B,nh,ds)
    cmat = jnp.repeat(cm.reshape(b, g, ds), nh // g, axis=1)

    h = state["h"] * decay[:, :, None, None] + jnp.einsum(
        "bhp,bhn->bhpn", xh, bmat).astype(jnp.float32)
    y = jnp.einsum("bhpn,bhn->bhp", h.astype(x.dtype), cmat)
    y = y + xc.reshape(b, nh, hd) * p["d_skip"][None, :, None]
    y = y.reshape(b, cfg.d_inner)
    y = rmsnorm(y * jax.nn.silu(z[:, 0]), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"]
    return out, {"h": h, "conv": window[:, 1:]}
