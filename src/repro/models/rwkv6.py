"""RWKV-6 "Finch" block: linear attention with data-dependent decay.

Attention-free: the per-head state is a fixed (hd x hd) matrix, so decode is
O(1)/token and training uses the same chunked decay-matmul trick as SSD —
intra-chunk L x L matrices on the MXU, inter-chunk state carried by lax.scan.

Recurrence (per head, key channel i, value channel j):
    o_t = r_t . S_{t-1} + (r_t . (u ⊙ k_t)) v_t
    S_t = diag(w_t) S_{t-1} + k_t ⊗ v_t
with w_t = exp(-exp(loglog-decay)) data-dependent per channel (the paper's
"Finch" delta over RWKV-5), r/k/v/g produced from data-dependent token-shift
(DDLerp with a small LoRA).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.launch.sharding import constrain
from repro.models.config import ModelConfig
from repro.models.layers import ParamSpec, rmsnorm

MIXES = ("w", "k", "v", "r", "g")


def rwkv_specs(cfg: ModelConfig) -> dict:
    d, r = cfg.d_model, cfg.rwkv_lora
    nh, hd = cfg.rwkv_nheads, cfg.rwkv_head_dim
    f = cfg.d_ff
    return {
        # time-mix (attention analogue)
        "mu_base": ParamSpec((d,), ("embed",), "zeros"),
        "mu": ParamSpec((5, d), (None, "embed"), "zeros"),
        "lora_a": ParamSpec((d, 5 * r), ("embed", "lora"), scale=0.1),
        "lora_b": ParamSpec((5, r, d), (None, "lora", "embed"), scale=0.1),
        "decay_base": ParamSpec((d,), ("embed",), "zeros"),
        "decay_a": ParamSpec((d, r), ("embed", "lora"), scale=0.1),
        "decay_b": ParamSpec((r, d), ("lora", "embed"), scale=0.1),
        "wr": ParamSpec((d, d), ("embed", "heads")),
        "wk": ParamSpec((d, d), ("embed", "heads")),
        "wv": ParamSpec((d, d), ("embed", "heads")),
        "wg": ParamSpec((d, d), ("embed", "heads")),
        "wo": ParamSpec((d, d), ("heads", "embed")),
        "u": ParamSpec((nh, hd), ("ssm_heads", None), scale=0.5),
        "ln_x": ParamSpec((d,), ("embed",), "ones"),
        # channel-mix (FFN analogue)
        "cm_mu_k": ParamSpec((d,), ("embed",), "zeros"),
        "cm_mu_r": ParamSpec((d,), ("embed",), "zeros"),
        "cm_wk": ParamSpec((d, f), ("embed", "mlp")),
        "cm_wv": ParamSpec((f, d), ("mlp", "embed")),
        "cm_wr": ParamSpec((d, d), ("embed", None)),
    }


def _shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """Token shift: x_{t-1} (zero/carry-padded). x: (B, S, D)."""
    first = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None, :]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _ddlerp(p: dict, x: jax.Array, sx: jax.Array):
    """Data-dependent token-shift mixes for w/k/v/r/g. Returns dict of (B,S,D)."""
    base = x + sx * p["mu_base"]
    r = p["lora_a"].shape[1] // 5
    lora = jnp.tanh(base @ p["lora_a"])                   # (B,S,5r)
    lora = lora.reshape(*lora.shape[:-1], 5, r)           # (B,S,5,r)
    adj = jnp.einsum("bsmr,mrd->bsmd", lora, p["lora_b"])  # (B,S,5,D)
    out = {}
    for i, name in enumerate(MIXES):
        out[name] = x + sx * (p["mu"][i] + adj[:, :, i])
    return out


def wkv6_chunked(r: jax.Array, k: jax.Array, v: jax.Array, log_w: jax.Array,
                 u: jax.Array, chunk: int, s0: jax.Array | None = None
                 ) -> tuple[jax.Array, jax.Array]:
    """Chunked WKV6.

    r/k/v: (B, S, nh, hd); log_w: (B, S, nh, hd) (<= 0); u: (nh, hd).
    Returns (o (B, S, nh, hd), final state (B, nh, hd, hd) [key, value]).
    """
    b, s, nh, hd = r.shape
    pad = (-s) % chunk
    if pad:  # identity pad steps: decay 1, zero k/v -> state-neutral
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_w = jnp.pad(log_w, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s_orig, s = s, s + pad
    nc, l = s // chunk, chunk

    rs_ = lambda t: t.reshape(b, nc, l, nh, hd)
    rc, kc, vc = rs_(r), rs_(k), rs_(v)
    a = jnp.cumsum(rs_(log_w).astype(jnp.float32), axis=2)    # (B,nc,L,nh,hd) inclusive
    bexp = a - rs_(log_w).astype(jnp.float32)                 # exclusive cumsum (a_{t-1})

    # intra-chunk: M[t,s] = (r_t ⊙ exp(b_t - a_s)) · k_s  for s < t; diag via u
    ri = rc.astype(jnp.float32) * jnp.exp(bexp)               # (B,nc,L,nh,hd)
    ki = kc.astype(jnp.float32) * jnp.exp(-a)
    m = jnp.einsum("bclhi,bcshi->bchls", ri, ki)              # (B,nc,nh,L,L)
    mask = jnp.tril(jnp.ones((l, l), jnp.bool_), k=-1)
    m = jnp.where(mask, m, 0.0)
    diag = jnp.einsum("bclhi,hi,bclhi->bclh", rc.astype(jnp.float32),
                      u.astype(jnp.float32), kc.astype(jnp.float32))
    y_intra = (jnp.einsum("bchls,bcshj->bclhj", m.astype(r.dtype), vc)
               + diag[..., None].astype(r.dtype) * vc)

    # chunk states: S_c = sum_s exp(a_L - a_s)[i] k_s[i] v_s[j]
    seg = jnp.exp(a[:, :, -1:, :, :] - a)                     # (B,nc,L,nh,hd)
    states = jnp.einsum("bclhi,bclhj->bchij",
                        (kc.astype(jnp.float32) * seg), vc.astype(jnp.float32))
    total = jnp.exp(a[:, :, -1])                              # (B,nc,nh,hd)

    h_init = (jnp.zeros((b, nh, hd, hd), jnp.float32) if s0 is None
              else s0.astype(jnp.float32))

    def body(h, inp):
        st, tot = inp
        h_prev = h
        h = h * tot[..., None] + st
        return h, h_prev

    hs, h_prevs = jax.lax.scan(body, h_init,
                               (jnp.moveaxis(states, 1, 0), jnp.moveaxis(total, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                     # (B,nc,nh,hd,hd)
    y_inter = jnp.einsum("bclhi,bchij->bclhj", ri.astype(r.dtype),
                         h_prevs.astype(r.dtype))
    y = (y_intra + y_inter).reshape(b, s, nh, hd)
    return y[:, :s_orig], hs


def rwkv_time_mix(p: dict, x: jax.Array, cfg: ModelConfig,
                  prev_tok: jax.Array | None = None,
                  s0: jax.Array | None = None):
    """(B, S, D) -> (out, final_state). Training/prefill path."""
    b, s, d = x.shape
    nh, hd = cfg.rwkv_nheads, cfg.rwkv_head_dim
    sx = _shift(x, prev_tok) - x
    mixes = _ddlerp(p, x, sx)
    r = (mixes["r"] @ p["wr"]).reshape(b, s, nh, hd)
    k = (mixes["k"] @ p["wk"]).reshape(b, s, nh, hd)
    v = (mixes["v"] @ p["wv"]).reshape(b, s, nh, hd)
    g = mixes["g"] @ p["wg"]
    r = constrain(r, "batch", None, "ssm_heads", None)
    k = constrain(k, "batch", None, "ssm_heads", None)
    v = constrain(v, "batch", None, "ssm_heads", None)
    # data-dependent decay (Finch): w = exp(-exp(dd)) in (0, 1)
    dd = p["decay_base"] + jnp.tanh(mixes["w"] @ p["decay_a"]) @ p["decay_b"]
    log_w = -jnp.exp(dd.astype(jnp.float32)).reshape(b, s, nh, hd)

    y, hs = wkv6_chunked(r, k, v, log_w, p["u"], cfg.rwkv_chunk, s0)
    y = y.reshape(b, s, d)
    y = rmsnorm(y, p["ln_x"], cfg.norm_eps) * jax.nn.silu(g)
    out = y @ p["wo"]
    return constrain(out, "batch", "seq", "embed"), hs


def rwkv_channel_mix(p: dict, x: jax.Array,
                     prev_tok: jax.Array | None = None) -> jax.Array:
    sx = _shift(x, prev_tok) - x
    xk = x + sx * p["cm_mu_k"]
    xr = x + sx * p["cm_mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    k = constrain(k, "batch", None, "mlp")
    return jax.nn.sigmoid(xr @ p["cm_wr"]) * (k @ p["cm_wv"])


# ---------------------------------------------------------------------------
# decode path: O(1) per token
# ---------------------------------------------------------------------------

def rwkv_state_init(cfg: ModelConfig, batch: int, dtype) -> dict:
    nh, hd = cfg.rwkv_nheads, cfg.rwkv_head_dim
    return {
        "s": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "tm_prev": jnp.zeros((batch, cfg.d_model), dtype),
        "cm_prev": jnp.zeros((batch, cfg.d_model), dtype),
    }


def rwkv_decode_step(p: dict, x: jax.Array, state: dict, cfg: ModelConfig
                     ) -> tuple[jax.Array, dict]:
    """Single-token time-mix + channel-mix. x: (B, D)."""
    b, d = x.shape
    nh, hd = cfg.rwkv_nheads, cfg.rwkv_head_dim
    # --- time mix
    xs = x[:, None, :]
    sx = (state["tm_prev"] - x)[:, None, :]
    mixes = _ddlerp(p, xs, sx)
    r = (mixes["r"][:, 0] @ p["wr"]).reshape(b, nh, hd)
    k = (mixes["k"][:, 0] @ p["wk"]).reshape(b, nh, hd)
    v = (mixes["v"][:, 0] @ p["wv"]).reshape(b, nh, hd)
    g = mixes["g"][:, 0] @ p["wg"]
    dd = p["decay_base"] + jnp.tanh(mixes["w"][:, 0] @ p["decay_a"]) @ p["decay_b"]
    w = jnp.exp(-jnp.exp(dd.astype(jnp.float32))).reshape(b, nh, hd)

    s = state["s"]                                     # (B,nh,hd,hd)
    kv = jnp.einsum("bhi,bhj->bhij", k.astype(jnp.float32), v.astype(jnp.float32))
    o = jnp.einsum("bhi,bhij->bhj", r.astype(jnp.float32),
                   s + p["u"].astype(jnp.float32)[None, :, :, None] * kv)
    s_new = w[..., None] * s + kv
    y = o.reshape(b, d).astype(x.dtype)
    y = rmsnorm(y, p["ln_x"], cfg.norm_eps) * jax.nn.silu(g)
    tm_out = y @ p["wo"]

    # --- channel mix (note: operates on the post-time-mix residual stream in
    # the block wrapper; here we only expose the primitive)
    return tm_out, {"s": s_new, "tm_prev": x, "cm_prev": state["cm_prev"]}


def rwkv_channel_mix_step(p: dict, x: jax.Array, prev: jax.Array) -> jax.Array:
    sx = prev - x
    xk = x + sx * p["cm_mu_k"]
    xr = x + sx * p["cm_mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    return jax.nn.sigmoid(xr @ p["cm_wr"]) * (k @ p["cm_wv"])
