"""Pallas TPU kernels for the paper's hot spot: 4-bit PQ fast-scan ADC."""
from repro.kernels import ops, ref
from repro.kernels.ops import fastscan_blockmin, fastscan_distances

__all__ = ["ops", "ref", "fastscan_distances", "fastscan_blockmin"]
