"""Pallas TPU kernels for the paper's hot spot: 4-bit PQ fast-scan ADC."""
from repro.kernels import ops, ref
from repro.kernels.ops import (
    GROUPED_IMPLS,
    IMPLS,
    SCAN_IMPLS,
    autotune_cache,
    autotune_cache_size,
    clear_autotune_cache,
    fastscan_blockmin,
    fastscan_distances,
    fastscan_grouped,
    resolve_grouped_impl,
)

__all__ = [
    "ops", "ref", "fastscan_distances", "fastscan_blockmin",
    "fastscan_grouped", "resolve_grouped_impl", "autotune_cache",
    "autotune_cache_size", "clear_autotune_cache",
    "GROUPED_IMPLS", "IMPLS", "SCAN_IMPLS",
]
