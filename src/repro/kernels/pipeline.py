"""Shared double-buffered DMA pipeline for the streaming Pallas kernels.

Every gather-free kernel in this repo has the same inner shape: a sequence of
grid (or loop) steps, each of which DMAs one tile of in-place HBM data into
VMEM scratch and then computes on it. Issuing the copy and immediately
waiting on it (one DMA per step) leaves the DMA engine idle during compute
and the compute units idle during the copy. The classic fix is a two-slot
pipeline: while step ``t`` computes out of scratch slot ``t % 2``, step
``t+1``'s copy is already in flight into slot ``(t+1) % 2`` — two scratch
buffers, two DMA semaphores, copy latency hidden behind compute.

``double_buffered_dma`` is that pipeline as a step-local helper: kernels call
it once per sequential step with callbacks that start/wait the step's
transfer(s), and it schedules

    step 0:  start(0) ; start(1) ; wait(0) ; <compute on slot 0>
    step t:  start(t+1)          ; wait(t) ; <compute on slot t % 2>

Correctness of the slot rotation relies only on steps executing in order
(TPU grid dims are sequential unless declared parallel; ``fori_loop`` bodies
trivially so) and on the caller computing on slot ``t % 2`` after the call:
slot ``(t+1) % 2`` was last read by step ``t-1``, whose compute finished
before step ``t`` began, so overwriting it is race-free.

Interpret mode executes copies synchronously, so the pipeline degenerates to
the one-DMA-per-step schedule with identical results — bit-identity of the
refactor is asserted in ``tests/test_stream_rerank.py``.
"""
from __future__ import annotations

from jax.experimental import pallas as pl


def double_buffered_dma(step, total: int, start, wait, valid) -> None:
    """Run one step of a two-slot DMA pipeline over ``total`` sequential steps.

    step:  traced i32 — this step's position in the global sequential order
           (for a 2-D grid: ``gi * n_inner + ni``).
    total: static int — number of steps in the sequence.
    start: ``start(s, slot)`` issues the copy/copies for step ``s`` into
           scratch slot ``slot`` (0 or 1). Called under ``pl.when``, at most
           once per step across the whole pipeline.
    wait:  ``wait(s, slot)`` blocks until step ``s``'s copy/copies into
           ``slot`` have landed. Must mirror ``start`` transfer-for-transfer
           (each DMA wait consumes exactly one start's semaphore signals).
    valid: ``valid(s)`` — traced bool, False for steps whose transfer is
           skipped entirely (e.g. a ``-1`` probe). Evaluated for ``s`` up to
           ``total`` (non-short-circuiting ``&``), so implementations must
           clamp any indexing on ``s``.

    After this returns, step ``step``'s data is resident in slot
    ``step % 2`` (when valid) and step ``step + 1``'s transfer is in flight.
    """
    nxt = step + 1

    @pl.when((step == 0) & valid(step))
    def _prime():  # first step of the sequence: nothing is in flight yet
        start(step, 0)

    @pl.when((nxt < total) & valid(nxt))
    def _prefetch():  # overlap the next tile's copy with this tile's compute
        start(nxt, nxt % 2)

    @pl.when(valid(step))
    def _land():
        wait(step, step % 2)


def double_buffered_dma_gated(step, total: int, start, wait, want,
                              latch) -> None:
    """Two-slot pipeline whose skip predicate may change between steps.

    ``double_buffered_dma`` evaluates ``valid(s)`` independently at the
    start-issue site (step ``s - 1``) and the wait site (step ``s``). That is
    only sound when the predicate is a pure function of ``s``. An early-exit
    kernel's skip decision also reads a *mutable* threshold (the running
    k-th-best distance), which can tighten between those two evaluations —
    the wait would then see ``False`` for a copy that was actually issued,
    leaking an unconsumed DMA semaphore signal into the next step that reuses
    the slot.

    This variant evaluates ``want(s)`` exactly once, at the moment step
    ``s``'s copy would be issued, and records the verdict in ``latch`` (SMEM
    scratch, shape (2,), i32, indexed by ``s % 2``). The wait site consults
    the latch, never the predicate, so every started copy is waited and every
    skipped copy stays skipped — the slots cannot desync no matter how the
    threshold moves. ``want`` must still clamp indexing on ``s`` (evaluated
    for ``s`` up to ``total``). Skips based on a stale-but-monotone threshold
    are conservative: the threshold only tightens, so a copy issued under an
    older looser threshold is merely wasted bandwidth, never a correctness
    hazard; the caller re-checks the fresh bound before computing.

    Returns nothing; after it, ``latch[step % 2] != 0`` iff step ``step``'s
    data is resident in slot ``step % 2``.
    """
    nxt = step + 1

    @pl.when(step == 0)
    def _prime():  # decide + issue (or latch the skip of) the first copy
        w = want(step)
        latch[0] = w.astype(latch.dtype)

        @pl.when(w)
        def _go():
            start(step, 0)

    @pl.when(nxt < total)
    def _prefetch():
        w = want(nxt)
        latch[nxt % 2] = w.astype(latch.dtype)

        @pl.when(w)
        def _go():
            start(nxt, nxt % 2)

    @pl.when(latch[step % 2] != 0)
    def _land():
        wait(step, step % 2)
