"""Shared double-buffered DMA pipeline for the streaming Pallas kernels.

Every gather-free kernel in this repo has the same inner shape: a sequence of
grid (or loop) steps, each of which DMAs one tile of in-place HBM data into
VMEM scratch and then computes on it. Issuing the copy and immediately
waiting on it (one DMA per step) leaves the DMA engine idle during compute
and the compute units idle during the copy. The classic fix is a two-slot
pipeline: while step ``t`` computes out of scratch slot ``t % 2``, step
``t+1``'s copy is already in flight into slot ``(t+1) % 2`` — two scratch
buffers, two DMA semaphores, copy latency hidden behind compute.

``double_buffered_dma`` is that pipeline as a step-local helper: kernels call
it once per sequential step with callbacks that start/wait the step's
transfer(s), and it schedules

    step 0:  start(0) ; start(1) ; wait(0) ; <compute on slot 0>
    step t:  start(t+1)          ; wait(t) ; <compute on slot t % 2>

Correctness of the slot rotation relies only on steps executing in order
(TPU grid dims are sequential unless declared parallel; ``fori_loop`` bodies
trivially so) and on the caller computing on slot ``t % 2`` after the call:
slot ``(t+1) % 2`` was last read by step ``t-1``, whose compute finished
before step ``t`` began, so overwriting it is race-free.

Interpret mode executes copies synchronously, so the pipeline degenerates to
the one-DMA-per-step schedule with identical results — bit-identity of the
refactor is asserted in ``tests/test_stream_rerank.py``.
"""
from __future__ import annotations

from jax.experimental import pallas as pl


def double_buffered_dma(step, total: int, start, wait, valid) -> None:
    """Run one step of a two-slot DMA pipeline over ``total`` sequential steps.

    step:  traced i32 — this step's position in the global sequential order
           (for a 2-D grid: ``gi * n_inner + ni``).
    total: static int — number of steps in the sequence.
    start: ``start(s, slot)`` issues the copy/copies for step ``s`` into
           scratch slot ``slot`` (0 or 1). Called under ``pl.when``, at most
           once per step across the whole pipeline.
    wait:  ``wait(s, slot)`` blocks until step ``s``'s copy/copies into
           ``slot`` have landed. Must mirror ``start`` transfer-for-transfer
           (each DMA wait consumes exactly one start's semaphore signals).
    valid: ``valid(s)`` — traced bool, False for steps whose transfer is
           skipped entirely (e.g. a ``-1`` probe). Evaluated for ``s`` up to
           ``total`` (non-short-circuiting ``&``), so implementations must
           clamp any indexing on ``s``.

    After this returns, step ``step``'s data is resident in slot
    ``step % 2`` (when valid) and step ``step + 1``'s transfer is in flight.
    """
    nxt = step + 1

    @pl.when((step == 0) & valid(step))
    def _prime():  # first step of the sequence: nothing is in flight yet
        start(step, 0)

    @pl.when((nxt < total) & valid(nxt))
    def _prefetch():  # overlap the next tile's copy with this tile's compute
        start(nxt, nxt % 2)

    @pl.when(valid(step))
    def _land():
        wait(step, step % 2)
