"""Pure-jnp oracle for the fast-scan ADC kernels.

This is the semantic ground truth: int32 accumulation of u8 LUT entries
gathered by 4-bit codes. Every Pallas kernel variant must match this bit-exactly
(integer arithmetic — no tolerance needed).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def unpack_nibbles(packed: jax.Array) -> jax.Array:
    """(N, M//2) uint8 -> (N, M) int32, lo nibble = even m."""
    lo = (packed & 0xF).astype(jnp.int32)
    hi = ((packed >> 4) & 0xF).astype(jnp.int32)
    n, mh = packed.shape
    out = jnp.zeros((n, 2 * mh), jnp.int32)
    out = out.at[:, 0::2].set(lo)
    out = out.at[:, 1::2].set(hi)
    return out


def fastscan_distances_ref(table_q8: jax.Array, packed_codes: jax.Array) -> jax.Array:
    """ADC accumulation oracle.

    table_q8: (Q, M, 16) uint8; packed_codes: (N, M//2) uint8.
    Returns (Q, N) int32: acc[q, n] = sum_m table_q8[q, m, codes[n, m]].
    """
    codes = unpack_nibbles(packed_codes)  # (N, M)
    t = table_q8.astype(jnp.int32)  # (Q, M, 16)

    def per_query(tq):  # tq: (M, 16)
        g = jax.vmap(lambda t_m, k_m: t_m[k_m], in_axes=(0, 1))(tq, codes)  # (M, N)
        return jnp.sum(g, axis=0)

    return jax.vmap(per_query)(t)


def fastscan_grouped_ref(table_q8: jax.Array, packed_codes: jax.Array) -> jax.Array:
    """Grouped ADC oracle: each group has its own LUT and its own codes.

    table_q8: (G, M, 16) uint8; packed_codes: (G, N, M//2) uint8.
    Returns (G, N) int32: acc[g, n] = sum_m table_q8[g, m, codes[g, n, m]].
    """
    g, n, mh = packed_codes.shape
    codes = unpack_nibbles(packed_codes.reshape(g * n, mh)).reshape(g, n, 2 * mh)
    t = table_q8.astype(jnp.int32)  # (G, M, 16)
    gathered = jnp.take_along_axis(
        t[:, None, :, :],          # (G, 1, M, 16)
        codes[..., None],          # (G, N, M, 1)
        axis=-1,
    )[..., 0]                      # (G, N, M)
    return jnp.sum(gathered, axis=-1, dtype=jnp.int32)


def fastscan_block_min_ref(table_q8: jax.Array, packed_codes: jax.Array,
                           block: int) -> tuple[jax.Array, jax.Array]:
    """Fused scan + per-block argmin oracle.

    Returns (min_dist (Q, N//block) int32, argmin (Q, N//block) int32 global ids).
    """
    q, n = table_q8.shape[0], packed_codes.shape[0]
    assert n % block == 0
    d = fastscan_distances_ref(table_q8, packed_codes)  # (Q, N)
    d = d.reshape(q, n // block, block)
    amin = jnp.argmin(d, axis=-1).astype(jnp.int32)
    base = (jnp.arange(n // block, dtype=jnp.int32) * block)[None, :]
    return jnp.min(d, axis=-1), amin + base
