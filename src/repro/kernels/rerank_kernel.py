"""Gather-free streaming exact re-rank: Pallas kernel for stage 3.

The gathered re-rank (``engine/rerank.py exact_distances``) materializes a
``(Q, R, D)`` f32 copy of every candidate base row plus a full ``(Q, R)``
distance tensor before top-k — after PR 4 made the scan stage gather-free,
that copy is the dominant memory-traffic term of the pipeline. This kernel
is the same move applied to stage 3: candidate ids are scalar-prefetched,
each grid step DMAs only its candidate rows out of the in-place HBM base
into VMEM scratch (double-buffered, two slots + two semaphores, so chunk
t+1's rows stream in while chunk t's distances compute), distances use the
norms+GEMM formulation

    ``d(q, x) = (‖q‖² − 2·q·x) + ‖x‖²``

with per-row base norms precomputed once at index build
(``core.lists.base_norms``), and a running top-k folds each chunk in VMEM —
only the ``(Q, k)`` survivors ever reach HBM.

Exactness. The kernel must be *bit-identical* to the gathered
``exact_rerank``, so both paths compute the distance through the same
``norms_gemm_dists`` helper below: an elementwise multiply + ``axis=-1``
sum contraction, whose per-row reduction order XLA keeps identical across
the two batching shapes (asserted in ``tests/test_stream_rerank.py``; a
``dot_general`` here would round differently from the gathered ``einsum``
at the last ulp). The running top-k reproduces ``masked_topk``'s
lowest-flat-index tie-break: the running candidates (all from earlier
chunks, i.e. lower flat positions) are merged *ahead of* the current
chunk's entries and min-extraction takes the first occurrence, so an equal
value always resolves to the lowest candidate position; non-finite
distances get position -1 exactly like ``masked_topk``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pipeline import double_buffered_dma

# Default candidate-chunk size: r*k candidate rows per query are typically a
# few dozen, so one or two chunks cover a query while keeping the (2, tile_r,
# D) f32 scratch small.
TILE_R = 64


def norms_gemm_dists(qv: jax.Array, vecs: jax.Array, xn: jax.Array
                     ) -> jax.Array:
    """Squared-L2 via norms+GEMM: ``(‖q‖² − 2·q·x) + ‖x‖²``.

    qv (..., D) against vecs (..., R, D) row blocks with precomputed row
    norms xn (..., R); returns (..., R) f32. The ONE distance expression
    both re-rank impls share: the dot and both norms are elementwise
    multiply + ``axis=-1`` sum contractions, so the gathered fallback
    (Q-batched) and the stream kernel (per-query chunks) round identically
    per row and stay bit-identical (see module docstring). XLA contracts
    the mul+sum on the MXU where profitable; no ``(..., R, D)`` subtraction
    intermediate ever exists.
    """
    qn = jnp.sum(qv * qv, axis=-1)                       # (...,)
    dots = jnp.sum(qv[..., None, :] * vecs, axis=-1)     # (..., R)
    # clamp: unlike Σ(q−x)², this form can cancel to a slightly negative
    # value when ‖q−x‖² ≪ ‖q‖² (near-duplicate vectors); squared distances
    # are ≥ 0 by contract, and clamping identically in both impls keeps
    # them bit-identical (it is a no-op wherever f32 is exact)
    return jnp.maximum((qn[..., None] - 2.0 * dots) + xn, 0.0)


def _merge_topk(run_vals, run_pos, chunk_vals, chunk_pos, k: int):
    """Fold one chunk into the running top-k by iterative min-extraction.

    run_vals/run_pos: (1, k) f32/i32 running selection (+inf / -1 absent),
    ascending, equal values ordered by position. chunk_vals/chunk_pos:
    (1, tn). Returns the updated (1, k) pair with the same invariants.
    Running entries are concatenated FIRST: they hold strictly lower flat
    positions than any current-chunk entry, so first-occurrence argmin
    reproduces ``masked_topk``'s lowest-flat-index tie-break.
    """
    vals = jnp.concatenate([run_vals, chunk_vals], axis=1)   # (1, k + tn)
    pos = jnp.concatenate([run_pos, chunk_pos], axis=1)
    width = vals.shape[1]
    iota_w = jax.lax.broadcasted_iota(jnp.int32, (1, width), 1)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, k), 1)

    def body(j, carry):
        v, out_v, out_p = carry
        mn = jnp.min(v, axis=-1, keepdims=True)                   # (1, 1)
        am = jnp.argmin(v, axis=-1).astype(jnp.int32)[:, None]    # (1, 1)
        sel = jnp.where(iota_w == am, True, False)
        out_v = jnp.where(iota_k == j, mn, out_v)
        out_p = jnp.where(iota_k == j,
                          jnp.sum(jnp.where(sel, pos, 0), axis=-1,
                                  keepdims=True), out_p)
        v = jnp.where(sel, jnp.inf, v)
        return v, out_v, out_p

    init = (vals,
            jnp.full((1, k), jnp.inf, jnp.float32),
            jnp.full((1, k), -1, jnp.int32))
    _, out_v, out_p = jax.lax.fori_loop(0, k, body, init)
    # masked_topk marks non-finite selections with position -1
    out_p = jnp.where(jnp.isfinite(out_v), out_p, -1)
    return out_v, out_p


def _rerank_kernel(cand_ref, q_ref, xn_ref, cids_ref, base_hbm,
                   vals_ref, pos_ref, scratch, sem, *,
                   tile_r: int, k: int, n_chunks: int, q: int, d: int):
    """One query x one candidate chunk; base rows DMA'd from HBM in place.

    cand_ref: (Q*Rp,) i32 scalar-prefetched flat candidate ids (-1 = pad)
    q_ref:    (1, D) f32 block — this query's row
    xn_ref:   (1, tile_r) f32 block — precomputed ‖x‖² of this chunk's rows
    cids_ref: (1, tile_r) i32 block — the same candidate ids, vector-readable
              (validity mask; the scalar copy drives the DMA)
    base_hbm: (N, D) f32, memory space ANY — the base, untouched in place
    vals_ref/pos_ref: (1, k) output blocks, revisited across the chunk grid
              (index map ignores the chunk dim) — the running top-k lives in
              VMEM and is written back once per query
    scratch:  (2, tile_r, D) f32 — double-buffered row landing pads
    sem:      (2,) DMA semaphores, one per slot

    Each chunk issues ``tile_r`` single-row copies (a true gather has no
    contiguous HBM slice to DMA); invalid ids skip their copy, and the
    whole next chunk streams into the other slot while this one computes.
    """
    qi = pl.program_id(0)
    ci = pl.program_id(1)
    step = qi * n_chunks + ci
    total = q * n_chunks
    rp = n_chunks * tile_r

    def row_dma(s, slot, j):
        sq, sc = s // n_chunks, s % n_chunks
        cid = cand_ref[sq * rp + sc * tile_r + j]
        return cid, lambda: pltpu.make_async_copy(
            base_hbm.at[cid], scratch.at[slot, j], sem.at[slot])

    def start(s, slot):
        def body(j, _):
            cid, dma = row_dma(s, slot, j)
            jax.lax.cond(cid >= 0, lambda: dma().start(), lambda: None)
            return 0
        jax.lax.fori_loop(0, tile_r, body, 0)

    def wait(s, slot):
        def body(j, _):
            cid, dma = row_dma(s, slot, j)
            jax.lax.cond(cid >= 0, lambda: dma().wait(), lambda: None)
            return 0
        jax.lax.fori_loop(0, tile_r, body, 0)

    double_buffered_dma(step, total, start, wait, lambda s: True)

    @pl.when(ci == 0)
    def _init():  # fresh query: empty running selection
        vals_ref[...] = jnp.full_like(vals_ref, jnp.inf)
        pos_ref[...] = jnp.full_like(pos_ref, -1)

    cids = cids_ref[...]                               # (1, tile_r)
    rows = scratch[step % 2]                           # (tile_r, D)
    dists = norms_gemm_dists(q_ref[0], rows, xn_ref[0])[None, :]
    dists = jnp.where(cids >= 0, dists, jnp.inf)       # pad/-1 -> absent
    chunk_pos = (jax.lax.broadcasted_iota(jnp.int32, (1, tile_r), 1)
                 + ci * tile_r)
    vals_ref[...], pos_ref[...] = _merge_topk(
        vals_ref[...], pos_ref[...], dists, chunk_pos, k)


def rerank_stream_topk(base: jax.Array, q: jax.Array, cand_ids: jax.Array,
                       xn: jax.Array, *, k: int, tile_r: int = TILE_R,
                       interpret: bool = True
                       ) -> tuple[jax.Array, jax.Array]:
    """Gather-free exact re-rank: (N, D) f32 base *in place* + (Q, Rp) i32
    candidate ids -> (vals (Q, k) f32 ascending, pos (Q, k) i32).

    ``xn`` (Q, Rp) f32 carries the precomputed ‖x‖² of each candidate row
    (gathered from ``core.lists.base_norms`` output — D× smaller than the
    row gather this kernel eliminates). Rp must be a ``tile_r`` multiple
    (pad with -1; padded slots come back +inf / -1). ``pos`` indexes into
    ``cand_ids`` exactly like ``masked_topk``'s positions: the caller maps
    positions to ids with ``topk.gather_ids``. Bit-identical to the
    gathered ``engine.rerank.exact_rerank`` (same ``norms_gemm_dists``
    expression, same tie-breaks).
    """
    n, d = base.shape
    qq, rp = cand_ids.shape
    assert rp % tile_r == 0, (rp, tile_r)
    assert xn.shape == (qq, rp) and q.shape == (qq, d)
    n_chunks = rp // tile_r
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(qq, n_chunks),
        in_specs=[
            pl.BlockSpec((1, d), lambda qi, ci, cd: (qi, 0)),
            pl.BlockSpec((1, tile_r), lambda qi, ci, cd: (qi, ci)),
            pl.BlockSpec((1, tile_r), lambda qi, ci, cd: (qi, ci)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda qi, ci, cd: (qi, 0)),
            pl.BlockSpec((1, k), lambda qi, ci, cd: (qi, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((2, tile_r, d), jnp.float32),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    kernel = functools.partial(_rerank_kernel, tile_r=tile_r, k=k,
                               n_chunks=n_chunks, q=qq, d=d)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qq, k), jnp.float32),
            jax.ShapeDtypeStruct((qq, k), jnp.int32),
        ],
        interpret=interpret,
    )(cand_ids.reshape(-1), q, xn, cand_ids, base)
