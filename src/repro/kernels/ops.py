"""jit'd dispatch wrappers around the fast-scan kernels.

Handles padding (queries to the Q tile, database to the N tile), backend
selection (compiled Pallas on TPU, interpret mode elsewhere), and the
pure-jnp reference fallback. All variants are bit-identical; see ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fastscan_kernel as fk
from repro.kernels import ref as ref_mod

IMPLS = ("ref", "select", "mxu")


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _auto_tile(size: int, cap: int) -> int:
    """Largest power-of-two tile <= cap covering size (min 8, VREG sublane)."""
    pow2 = 1 << max(size - 1, 1).bit_length()
    return max(8, min(cap, pow2))


def _pad_to(x: jax.Array, axis: int, mult: int, value: int = 0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("impl", "tile_n", "tile_q", "interpret"))
def fastscan_distances(table_q8: jax.Array, packed_codes: jax.Array, *,
                       impl: str = "mxu", tile_n: int = 0, tile_q: int = 0,
                       interpret: bool | None = None) -> jax.Array:
    """ADC accumulation: (Q, M, 16) u8 x (N, M//2) u8 -> (Q, N) i32.

    impl: 'ref' (pure jnp oracle) | 'select' (VPU select-tree, paper-faithful)
          | 'mxu' (one-hot GEMM, beyond-paper). All bit-identical.
    """
    if table_q8.ndim == 2:
        table_q8 = table_q8[None]
    q, m, k = table_q8.shape
    n = packed_codes.shape[0]
    assert k == 16, f"4-bit PQ requires K=16, got {k}"
    if impl == "ref":
        return ref_mod.fastscan_distances_ref(table_q8, packed_codes)

    interp = _default_interpret() if interpret is None else interpret
    tn = tile_n or _auto_tile(n, fk.TILE_N)
    codes_p = _pad_to(packed_codes, 0, tn)

    if impl == "select":
        acc = fk.fastscan_select_tree(table_q8, codes_p, tile_n=tn, interpret=interp)
    elif impl == "mxu":
        tq = tile_q or _auto_tile(q, fk.TILE_Q)
        table_p = _pad_to(table_q8, 0, tq)
        acc = fk.fastscan_onehot_mxu(table_p, codes_p, tile_n=tn, tile_q=tq,
                                     interpret=interp)
    else:
        raise ValueError(f"unknown impl {impl!r}; want one of {IMPLS}")
    return acc[:q, :n]


@functools.partial(jax.jit, static_argnames=("impl", "tile_n", "interpret"))
def fastscan_grouped(table_q8: jax.Array, packed_codes: jax.Array, *,
                     impl: str = "ref", tile_n: int = 0,
                     interpret: bool | None = None) -> jax.Array:
    """Grouped ADC for gathered IVF lists: (G, M, 16) u8 x (G, cap, M//2) u8
    -> (G, cap) i32. Group g = one (query, probed-list) pair.

    impl: 'ref' (vectorized jnp gather — fastest off-TPU) | 'select'
    (register-resident Pallas select-tree). Bit-identical.
    """
    g, m, k = table_q8.shape
    cap = packed_codes.shape[1]
    assert k == 16, f"4-bit PQ requires K=16, got {k}"
    if impl == "ref":
        return ref_mod.fastscan_grouped_ref(table_q8, packed_codes)
    if impl != "select":
        raise ValueError(f"unknown grouped impl {impl!r}; want 'ref' or 'select'")
    interp = _default_interpret() if interpret is None else interpret
    tn = tile_n or _auto_tile(cap, fk.TILE_N)
    codes_p = _pad_to(packed_codes, 1, tn)
    acc = fk.fastscan_select_tree_grouped(table_q8, codes_p, tile_n=tn,
                                          interpret=interp)
    return acc[:, :cap]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fastscan_blockmin(table_q8: jax.Array, packed_codes: jax.Array, *,
                      block: int = 1024, interpret: bool | None = None
                      ) -> tuple[jax.Array, jax.Array]:
    """Fused ADC + per-block min/argmin. Pads N with +inf-like sentinel codes.

    Returns (min_dists (Q, ceil(N/block)) i32, global argmin ids).
    Padded tail rows use code 15 in every sub-space; callers who need exact
    semantics on ragged N should mask via the returned ids (< N check).
    """
    if table_q8.ndim == 2:
        table_q8 = table_q8[None]
    q, m, k = table_q8.shape
    n = packed_codes.shape[0]
    assert k == 16
    interp = _default_interpret() if interpret is None else interpret
    tq = _auto_tile(q, fk.TILE_Q)
    table_p = _pad_to(table_q8, 0, tq)
    codes_p = _pad_to(packed_codes, 0, block, value=0xFF)
    mins, args = fk.fastscan_blockmin(table_p, codes_p, tile_n=block, tile_q=tq,
                                      interpret=interp)
    nb = -(-n // block)
    return mins[:q, :nb], args[:q, :nb]
