"""jit'd dispatch wrappers around the fast-scan kernels, plus autotuning.

Handles padding (queries to the Q tile, database to the N tile), backend
selection (compiled Pallas on TPU, interpret mode elsewhere), and the
pure-jnp reference fallback. All variants are bit-identical; see ref.py.

Impl registries — ONE source of truth, everything else derives from it:

  ``GROUPED_IMPLS``  concrete grouped-scan formulations ('ref' jnp gather /
                     'select' VPU select-tree / 'mxu' one-hot GEMM /
                     'stream' gather-free in-kernel list DMA);
  ``IMPLS``          the flat (shared-database) scan: the gathered subset
                     (no probe indirection exists in the flat layout);
  ``SCAN_IMPLS``     what callers may request: GROUPED_IMPLS + 'auto'.

``impl='auto'`` resolves to a concrete (impl, tile_n) via a one-time timed
micro-sweep per ``(backend, interpret, G, cap, M)`` signature
(``resolve_grouped_impl``),
cached process-wide — the analogue of the paper picking the widest SIMD unit
per target CPU, done empirically per shape instead of hard-coded per arch.
``autotune_cache()`` / ``autotune_cache_size()`` expose the cache for
inspection, mirroring ``engine.fused_cache_size``;
``save_autotune_cache()`` / ``load_autotune_cache()`` persist the resolved
table to JSON so a serving fleet stops re-timing identical signatures on
every boot (``ServingLoop(warmup_cache=...)``).
"""
from __future__ import annotations

import concurrent.futures
import functools
import json
import os
import threading
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import fastscan_kernel as fk
from repro.kernels import ref as ref_mod

# Concrete grouped-scan kernel formulations. The flat scan supports the
# gathered three; the engine additionally accepts 'auto' (autotuned dispatch
# below).
GROUPED_IMPLS = ("ref", "select", "mxu", "stream")
IMPLS = ("ref", "select", "mxu")
SCAN_IMPLS = GROUPED_IMPLS + ("auto",)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _auto_tile(size: int, cap: int) -> int:
    """Largest power-of-two tile <= cap covering size (min 8, VREG sublane)."""
    pow2 = 1 << max(size - 1, 1).bit_length()
    return max(8, min(cap, pow2))


def _stream_tile(cap: int, tile_n: int = 0) -> int:
    """A cap tile for the in-place stream kernels: must DIVIDE cap (the
    ListStore is scanned where it lives — there is nothing to pad). Honors
    ``tile_n`` when it divides cap, otherwise falls back to the largest
    power-of-two divisor <= TILE_N, then to cap itself (one tile per list).
    """
    if tile_n and cap % tile_n == 0:
        return tile_n
    t = fk.TILE_N
    while t >= 8:
        if cap % t == 0:
            return t
        t //= 2
    return cap


def _pad_to(x: jax.Array, axis: int, mult: int, value: int = 0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("impl", "tile_n", "tile_q", "interpret"))
def fastscan_distances(table_q8: jax.Array, packed_codes: jax.Array, *,
                       impl: str = "mxu", tile_n: int = 0, tile_q: int = 0,
                       interpret: bool | None = None) -> jax.Array:
    """ADC accumulation: (Q, M, 16) u8 x (N, M//2) u8 -> (Q, N) i32.

    impl: 'ref' (pure jnp oracle) | 'select' (VPU select-tree, paper-faithful)
          | 'mxu' (one-hot GEMM, beyond-paper). All bit-identical.
    """
    if table_q8.ndim == 2:
        table_q8 = table_q8[None]
    q, m, k = table_q8.shape
    n = packed_codes.shape[0]
    assert k == 16, f"4-bit PQ requires K=16, got {k}"
    if impl == "ref":
        return ref_mod.fastscan_distances_ref(table_q8, packed_codes)

    interp = _default_interpret() if interpret is None else interpret
    tn = tile_n or _auto_tile(n, fk.TILE_N)
    codes_p = _pad_to(packed_codes, 0, tn)

    if impl == "select":
        acc = fk.fastscan_select_tree(table_q8, codes_p, tile_n=tn, interpret=interp)
    elif impl == "mxu":
        tq = tile_q or _auto_tile(q, fk.TILE_Q)
        table_p = _pad_to(table_q8, 0, tq)
        acc = fk.fastscan_onehot_mxu(table_p, codes_p, tile_n=tn, tile_q=tq,
                                     interpret=interp)
    else:
        raise ValueError(f"unknown impl {impl!r}; want one of {IMPLS}")
    return acc[:q, :n]


# ---------------------------------------------------------------------------
# grouped scan (the IVF hot path) + autotuned dispatch
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("impl", "tile_n", "interpret"))
def _fastscan_grouped_pallas(table_q8: jax.Array, packed_codes: jax.Array, *,
                             impl: str, tile_n: int,
                             interpret: bool | None) -> jax.Array:
    """Pallas half of the grouped dispatch ('select' | 'mxu'), pre-validated."""
    cap = packed_codes.shape[1]
    interp = _default_interpret() if interpret is None else interpret
    tn = tile_n or _auto_tile(cap, fk.TILE_N)
    codes_p = _pad_to(packed_codes, 1, tn)
    if impl == "select":
        acc = fk.fastscan_select_tree_grouped(table_q8, codes_p, tile_n=tn,
                                              interpret=interp)
    else:
        acc = fk.fastscan_onehot_mxu_grouped(table_q8, codes_p, tile_n=tn,
                                             interpret=interp)
    return acc[:, :cap]


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def _fastscan_grouped_stream(table_q8: jax.Array, packed_codes: jax.Array, *,
                             tile_n: int, interpret: bool | None) -> jax.Array:
    """Stream impl under the *gathered* calling convention: treat the
    (G, cap, M//2) codes as an in-place store of G lists probed by
    arange(G). Exists so 'stream' slots into the same registry/sweep as the
    gathered impls; the gather-free payoff comes from calling
    ``fastscan_stream_grouped`` on the real ListStore instead."""
    g, cap = packed_codes.shape[0], packed_codes.shape[1]
    interp = _default_interpret() if interpret is None else interpret
    # padding a copy is fine here (this is the parity/sweep path, not the
    # in-place hot path), so any tile works — pad cap up to it
    tn = tile_n if (tile_n and cap % tile_n == 0) else _auto_tile(cap, fk.TILE_N)
    codes_p = _pad_to(packed_codes, 1, tn)
    probes = jnp.arange(g, dtype=jnp.int32)
    acc = fk.fastscan_stream_grouped(table_q8, codes_p, probes, tile_n=tn,
                                     interpret=interp)
    return acc[:, :cap]


def fastscan_grouped(table_q8: jax.Array, packed_codes: jax.Array, *,
                     impl: str = "ref", tile_n: int = 0,
                     interpret: bool | None = None) -> jax.Array:
    """Grouped ADC for gathered IVF lists: (G, M, 16) u8 x (G, cap, M//2) u8
    -> (G, cap) i32. Group g = one (query, probed-list) pair.

    impl: 'ref' (vectorized jnp gather — fastest off-TPU) | 'select'
    (register-resident Pallas select-tree) | 'mxu' (per-group one-hot GEMM on
    the MXU) | 'stream' (in-kernel DMA of one cap tile per grid step; under
    this gathered signature it scans the codes as a G-list store probed by
    arange — see ``fastscan_stream_grouped`` for the true in-place entry) |
    'auto' (timed micro-sweep picks the (impl, tile_n) pair per
    (backend, interpret, G, cap, M) signature, cached process-wide; an
    explicit ``tile_n`` is ignored under 'auto' since the sweep timed pairs).
    Bit-identical.

    Shapes are static under jit, so 'auto' resolves at trace time: the sweep
    runs once per signature and the chosen concrete impl is what gets staged
    into the XLA program.
    """
    g, m, k = table_q8.shape
    cap = packed_codes.shape[1]
    assert k == 16, f"4-bit PQ requires K=16, got {k}"
    if impl not in SCAN_IMPLS:
        raise ValueError(f"unknown grouped impl {impl!r}; "
                         f"want one of {SCAN_IMPLS}")
    if impl == "auto":
        tuned = resolve_grouped_impl(g, cap, m, interpret=interpret)
        # the sweep timed (impl, tile) PAIRS — honoring a caller tile_n here
        # could pair the winning impl with a tile it never won with, so an
        # explicit tile_n is ignored under 'auto' (pass a concrete impl to
        # control tiling by hand)
        impl, tile_n = tuned.impl, tuned.tile_n
    if impl == "ref":
        return _fastscan_grouped_ref_jit(table_q8, packed_codes)
    if impl == "stream":
        return _fastscan_grouped_stream(table_q8, packed_codes, tile_n=tile_n,
                                        interpret=interpret)
    return _fastscan_grouped_pallas(table_q8, packed_codes, impl=impl,
                                    tile_n=tile_n, interpret=interpret)


_fastscan_grouped_ref_jit = jax.jit(ref_mod.fastscan_grouped_ref)


def resolve_scan_impl(impl: str, g: int, cap: int, m: int, *,
                      interpret: bool | None = None) -> tuple[str, int]:
    """Resolve a requested scan impl to a concrete ``(impl, tile_n)``.

    Concrete impls pass through with tile 0 (shape-fit default); ``'auto'``
    consults the autotune table (``resolve_grouped_impl``) — which may pick
    ``'stream'``, letting callers that hold the codes in place
    (``core.ivf.scan_probes``) route to the gather-free path. Shared by the
    single-host and sharded pipelines so dispatch cannot drift.
    """
    if impl not in SCAN_IMPLS:
        raise ValueError(f"unknown grouped impl {impl!r}; "
                         f"want one of {SCAN_IMPLS}")
    if impl != "auto":
        return impl, 0
    tuned = resolve_grouped_impl(g, cap, m, interpret=interpret)
    return tuned.impl, tuned.tile_n


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def fastscan_stream_grouped(table_q8: jax.Array, list_codes: jax.Array,
                            probe_ids: jax.Array, *, tile_n: int = 0,
                            interpret: bool | None = None) -> jax.Array:
    """Gather-free grouped ADC over an in-place ListStore.

    table_q8: (G, M, 16) u8 per-group LUTs; list_codes: (nlist, cap, M//2)
    u8 — ``ListStore.codes``, scanned where it lives (no gathered copy);
    probe_ids: (G,) i32, -1 = no probe (DMA skipped, zeros emitted).
    Returns (G, cap) i32, identical at every real slot to
    ``fastscan_grouped(table, list_codes[probe_ids])``.
    """
    g, m, k = table_q8.shape
    cap = list_codes.shape[1]
    assert k == 16, f"4-bit PQ requires K=16, got {k}"
    assert probe_ids.shape == (g,), (probe_ids.shape, g)
    interp = _default_interpret() if interpret is None else interpret
    tn = _stream_tile(cap, tile_n)
    return fk.fastscan_stream_grouped(table_q8, list_codes,
                                      probe_ids.astype(jnp.int32),
                                      tile_n=tn, interpret=interp)


@functools.partial(jax.jit,
                   static_argnames=("keep", "tile_n", "interpret"))
def fastscan_stream_topk(table_q8: jax.Array, list_codes: jax.Array,
                         probe_ids: jax.Array, sizes: jax.Array, *,
                         keep: int, tile_n: int = 0,
                         interpret: bool | None = None
                         ) -> tuple[jax.Array, jax.Array]:
    """Gather-free scan + fused candidate reduction over an in-place store.

    Like ``fastscan_stream_grouped`` but the full (G, cap) accumulation
    never reaches HBM: each cap tile keeps only its ``kc = min(keep,
    tile_n)`` smallest entries, so any final selection of <= ``keep``
    candidates per query is exact (see the kernel docstring for the
    tie-break argument). ``sizes`` (nlist,) i32 masks slots past each
    list's true occupancy before selection. Returns
    (vals (G, n_tiles, kc) i32, slots (G, n_tiles, kc) i32, -1 = absent).
    """
    g, m, k = table_q8.shape
    cap = list_codes.shape[1]
    assert k == 16, f"4-bit PQ requires K=16, got {k}"
    assert probe_ids.shape == (g,), (probe_ids.shape, g)
    interp = _default_interpret() if interpret is None else interpret
    tn = _stream_tile(cap, tile_n)
    kc = max(1, min(keep, tn))
    return fk.fastscan_stream_topk_grouped(
        table_q8, list_codes, probe_ids.astype(jnp.int32),
        sizes.astype(jnp.int32), kc=kc, tile_n=tn, interpret=interp)


class TunedScan(NamedTuple):
    """Autotune verdict for one (backend, interpret, G, cap, M) signature."""

    impl: str          # winning concrete impl (in GROUPED_IMPLS)
    tile_n: int        # winning cap tile (0 = impl has no tiling knob)
    timings_us: tuple  # ((f"{impl}@{tile}", median_us), ...) — full sweep


_AUTOTUNE_CACHE: dict[tuple, TunedScan] = {}
# serializes first resolutions: without it, two threads racing on the same
# signature would pay the sweep twice and could cache divergent verdicts
_AUTOTUNE_LOCK = threading.Lock()


class _TraceEscapeError(RuntimeError):
    """The autotune sweep was staged into an ambient trace instead of run."""


def _grouped_tile_candidates(cap: int) -> tuple[int, ...]:
    """Cap-tile sizes worth timing: the shape-fit auto tile plus smaller
    power-of-two tiles (more grid parallelism / smaller VMEM blocks)."""
    fit = _auto_tile(cap, fk.TILE_N)
    cands = {fit}
    for t in (128, 512):
        if t < fit:
            cands.add(t)
    return tuple(sorted(cands))


def _median_time_us(fn, iters: int = 3) -> float:
    out = fn()  # warmup: compile (or first interpret pass)
    if isinstance(out, jax.core.Tracer):
        # Staged into an ambient trace instead of executed — the "timing"
        # would measure tracing overhead, not the kernel. resolve_grouped_impl
        # escapes to a worker thread precisely to prevent this.
        raise _TraceEscapeError("autotune sweep ran under an ambient jax trace")
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def resolve_grouped_impl(g: int, cap: int, m: int, *,
                         interpret: bool | None = None) -> TunedScan:
    """Resolve ``impl='auto'`` for the grouped scan at one shape signature.

    Times every concrete impl (x its tile candidates) on synthetic data of
    the exact workload shape and caches the winner per
    ``(backend, interpret, G, cap, M)`` — one sweep per signature per
    process (interpret mode is part of the key: a verdict timed on the
    Pallas interpreter must never be reused for compiled execution, or vice
    versa). The fixed-seed synthetic data makes the sweep reproducible; the
    cache makes resolution deterministic for the life of the process
    (asserted in tests/test_kernels.py). A candidate that fails to build at
    this shape (e.g. an MXU tile blowing VMEM) is dropped, not fatal —
    'ref' always survives.
    """
    interp = _default_interpret() if interpret is None else interpret
    sig = (jax.default_backend(), interp, int(g), int(cap), int(m))
    hit = _AUTOTUNE_CACHE.get(sig)
    if hit is not None:
        return hit
    with _AUTOTUNE_LOCK:
        hit = _AUTOTUNE_CACHE.get(sig)  # racing thread may have resolved it
        if hit is not None:
            return hit
        # The sweep must EXECUTE even when resolution happens at trace time
        # (scan_probes and the fused pipeline are jit'd, so that is the
        # normal case): under an ambient trace every jax call made here
        # would be staged into the caller's jaxpr instead of run, and the
        # "timings" would measure tracing overhead. JAX trace state is
        # thread-local, so a worker thread is a clean escape hatch —
        # everything it runs dispatches eagerly on concrete arrays.
        with concurrent.futures.ThreadPoolExecutor(max_workers=1) as ex:
            tuned = ex.submit(_run_grouped_sweep, int(g), int(cap), int(m),
                              interp).result()
        _AUTOTUNE_CACHE[sig] = tuned
    return tuned


def _run_grouped_sweep(g: int, cap: int, m: int, interp: bool) -> TunedScan:
    rng = np.random.default_rng(0)
    # plain numpy on purpose: jnp.asarray under an ambient trace would make
    # these tracers; as numpy they only become device arrays inside the
    # worker thread's eager calls
    table = rng.integers(0, 256, (g, m, 16), dtype=np.uint8)
    codes = rng.integers(0, 256, (g, cap, m // 2), dtype=np.uint8)
    sweep = []
    for impl in GROUPED_IMPLS:
        if impl == "ref":
            tiles = (0,)
        elif impl == "stream":
            # stream scans the store in place, so only cap-dividing tiles
            # are realizable — map each candidate to its realizable tile so
            # the verdict's (impl, tile) pair is exactly what executes
            tiles = tuple(sorted({_stream_tile(cap, t)
                                  for t in _grouped_tile_candidates(cap)}))
        else:
            tiles = _grouped_tile_candidates(cap)
        for tn in tiles:
            try:
                us = _median_time_us(functools.partial(
                    fastscan_grouped, table, codes, impl=impl, tile_n=tn,
                    interpret=interp))
            except _TraceEscapeError:
                raise  # a trace-escape regression, not a bad candidate
            except Exception:  # candidate unbuildable at this shape: skip it
                continue
            sweep.append((impl, tn, us))
    if not sweep:
        raise RuntimeError(
            f"autotune sweep produced no working candidate at "
            f"(G={g}, cap={cap}, M={m}) — 'ref' should never fail")
    best = min(sweep, key=lambda r: r[2])
    tuned = TunedScan(
        impl=best[0], tile_n=best[1],
        timings_us=tuple((f"{i}@{tn}", us) for i, tn, us in sweep))
    return tuned


def autotune_cache() -> dict[tuple, TunedScan]:
    """Snapshot of the process-wide autotune cache, keyed by
    (backend, interpret, G, cap, M). For inspection/metrics — mutations
    don't stick."""
    return dict(_AUTOTUNE_CACHE)


def autotune_cache_size() -> int:
    """Number of resolved signatures (mirrors ``engine.fused_cache_size``)."""
    return len(_AUTOTUNE_CACHE)


def clear_autotune_cache() -> None:
    """Drop all resolutions (tests; a backend change mid-process)."""
    _AUTOTUNE_CACHE.clear()


_AUTOTUNE_SCHEMA = "repro.autotune/v1"


def save_autotune_cache(path: str) -> int:
    """Serialize the resolved TunedScan table to JSON at ``path``.

    Returns the number of entries written. The key quintuple
    (backend, interpret, G, cap, M) is stored per entry, so one file can
    hold verdicts for several backends; ``load_autotune_cache`` re-keys
    them verbatim and lookups still only ever hit the running backend's
    signatures. A serving fleet saves after its first warmup and ships the
    file to every replica (``ServingLoop(warmup_cache=...)``).
    """
    with _AUTOTUNE_LOCK:  # a concurrent sweep may be inserting its verdict
        snapshot = dict(_AUTOTUNE_CACHE)
    entries = [
        {"backend": b, "interpret": bool(i), "g": g, "cap": c, "m": m,
         "impl": t.impl, "tile_n": t.tile_n,
         "timings_us": [[name, us] for name, us in t.timings_us]}
        for (b, i, g, c, m), t in snapshot.items()
    ]
    with open(path, "w") as f:
        json.dump({"schema": _AUTOTUNE_SCHEMA, "entries": entries}, f,
                  indent=2)
    return len(entries)


def load_autotune_cache(path: str) -> int:
    """Merge a ``save_autotune_cache`` file into the process-wide table.

    Returns the number of entries adopted. Missing file, wrong schema, or
    malformed JSON load nothing (0) — a stale or absent warmup cache must
    never stop a boot, it just means the sweeps run again. Entries naming
    an impl that no longer exists in ``GROUPED_IMPLS`` are skipped (stale
    file from an older build); entries already resolved in this process
    keep their in-process verdict.
    """
    if not os.path.exists(path):
        return 0
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError):
        return 0
    if not isinstance(data, dict) or data.get("schema") != _AUTOTUNE_SCHEMA:
        return 0
    loaded = 0
    with _AUTOTUNE_LOCK:
        for e in data.get("entries", ()):
            try:
                key = (str(e["backend"]), bool(e["interpret"]), int(e["g"]),
                       int(e["cap"]), int(e["m"]))
                tuned = TunedScan(
                    impl=str(e["impl"]), tile_n=int(e["tile_n"]),
                    timings_us=tuple((str(n), float(us))
                                     for n, us in e["timings_us"]))
            except (KeyError, TypeError, ValueError):
                continue
            if tuned.impl not in GROUPED_IMPLS or key in _AUTOTUNE_CACHE:
                continue
            _AUTOTUNE_CACHE[key] = tuned
            loaded += 1
    return loaded


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fastscan_blockmin(table_q8: jax.Array, packed_codes: jax.Array, *,
                      block: int = 1024, interpret: bool | None = None
                      ) -> tuple[jax.Array, jax.Array]:
    """Fused ADC + per-block min/argmin. Pads N with +inf-like sentinel codes.

    Returns (min_dists (Q, ceil(N/block)) i32, global argmin ids).
    Padded tail rows use code 15 in every sub-space; callers who need exact
    semantics on ragged N should mask via the returned ids (< N check).
    """
    if table_q8.ndim == 2:
        table_q8 = table_q8[None]
    q, m, k = table_q8.shape
    n = packed_codes.shape[0]
    assert k == 16
    interp = _default_interpret() if interpret is None else interpret
    tq = _auto_tile(q, fk.TILE_Q)
    table_p = _pad_to(table_q8, 0, tq)
    codes_p = _pad_to(packed_codes, 0, block, value=0xFF)
    mins, args = fk.fastscan_blockmin(table_p, codes_p, tile_n=block, tile_q=tq,
                                      interpret=interp)
    nb = -(-n // block)
    return mins[:q, :nb], args[:q, :nb]
