"""jit'd dispatch wrappers around the fast-scan kernels, plus autotuning.

Handles padding (queries to the Q tile, database to the N tile), backend
selection (compiled Pallas on TPU, interpret mode elsewhere), and the
pure-jnp reference fallback. All variants are bit-identical; see ref.py.

Impl registries — ONE source of truth, everything else derives from it:

  ``GROUPED_IMPLS``  concrete grouped-scan formulations ('ref' jnp gather /
                     'select' VPU select-tree / 'mxu' one-hot GEMM /
                     'stream' gather-free in-kernel list DMA);
  ``IMPLS``          the flat (shared-database) scan: the gathered subset
                     (no probe indirection exists in the flat layout);
  ``SCAN_IMPLS``     what callers may request: GROUPED_IMPLS + 'auto'.

``impl='auto'`` resolves to a concrete (impl, tile_n) via a one-time timed
micro-sweep per ``('scan', backend, interpret, G, cap, M, nlist,
probe_fill)`` signature (``resolve_grouped_impl``; ``nlist`` is in the key
because the 'stream' candidate is timed against a real nlist-sized
ListStore — its HBM strides, not an arange-probed G-list stand-in;
``probe_fill`` because an adaptive-nprobe workload presents sparse probe
sets whose skipped DMAs change the verdict), cached process-wide — the analogue of
the paper picking the widest SIMD unit per target CPU, done empirically per
shape instead of hard-coded per arch. The exact re-rank stage has the same
dispatch problem and shares the machinery: ``RERANK_IMPLS`` ('gathered' |
'stream' | 'auto'), ``rerank_stream_topk`` (the gather-free Pallas re-rank),
and ``resolve_rerank_impl`` (verdicts keyed ``('rerank', backend,
interpret, Q, R, D, k, N)`` in the same cache).
``autotune_cache()`` / ``autotune_cache_size()`` expose the cache for
inspection, mirroring ``engine.fused_cache_size``;
``save_autotune_cache()`` / ``load_autotune_cache()`` persist the resolved
table to JSON so a serving fleet stops re-timing identical signatures on
every boot (``ServingLoop(warmup_cache=...)``) — schema v3; v1/v2 files
load with their scan verdicts re-keyed to the store / probe density they
actually timed (v1: nlist=g; v1+v2: probe_fill=1.0).
"""
from __future__ import annotations

import concurrent.futures
import functools
import json
import os
import threading
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import topk as topk_mod
from repro.kernels import fastscan_kernel as fk
from repro.kernels import ref as ref_mod
from repro.kernels import rerank_kernel as rk

# Concrete grouped-scan kernel formulations. The flat scan supports the
# gathered three; the engine additionally accepts 'auto' (autotuned dispatch
# below).
GROUPED_IMPLS = ("ref", "select", "mxu", "stream")
IMPLS = ("ref", "select", "mxu")
SCAN_IMPLS = GROUPED_IMPLS + ("auto",)
# Exact re-rank (stage 3) formulations: 'gathered' (jnp norms+GEMM over a
# gathered (Q, R, D) row copy), 'stream' (gather-free in-kernel row DMA +
# fused top-k, kernels/rerank_kernel.py), 'auto' (timed dispatch, below).
RERANK_CONCRETE = ("gathered", "stream")
RERANK_IMPLS = RERANK_CONCRETE + ("auto",)


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _auto_tile(size: int, cap: int) -> int:
    """Largest power-of-two tile <= cap covering size (min 8, VREG sublane)."""
    pow2 = 1 << max(size - 1, 1).bit_length()
    return max(8, min(cap, pow2))


def _stream_tile(cap: int, tile_n: int = 0) -> int:
    """A cap tile for the in-place stream kernels: must DIVIDE cap (the
    ListStore is scanned where it lives — there is nothing to pad). Honors
    ``tile_n`` when it divides cap, otherwise falls back to the largest
    power-of-two divisor <= TILE_N, then to cap itself (one tile per list).
    """
    if tile_n and cap % tile_n == 0:
        return tile_n
    t = fk.TILE_N
    while t >= 8:
        if cap % t == 0:
            return t
        t //= 2
    return cap


def _pad_to(x: jax.Array, axis: int, mult: int, value: int = 0) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("impl", "tile_n", "tile_q", "interpret"))
def fastscan_distances(table_q8: jax.Array, packed_codes: jax.Array, *,
                       impl: str = "mxu", tile_n: int = 0, tile_q: int = 0,
                       interpret: bool | None = None) -> jax.Array:
    """ADC accumulation: (Q, M, 16) u8 x (N, M//2) u8 -> (Q, N) i32.

    impl: 'ref' (pure jnp oracle) | 'select' (VPU select-tree, paper-faithful)
          | 'mxu' (one-hot GEMM, beyond-paper). All bit-identical.
    """
    if table_q8.ndim == 2:
        table_q8 = table_q8[None]
    q, m, k = table_q8.shape
    n = packed_codes.shape[0]
    assert k == 16, f"4-bit PQ requires K=16, got {k}"
    if impl == "ref":
        return ref_mod.fastscan_distances_ref(table_q8, packed_codes)

    interp = _default_interpret() if interpret is None else interpret
    tn = tile_n or _auto_tile(n, fk.TILE_N)
    codes_p = _pad_to(packed_codes, 0, tn)

    if impl == "select":
        acc = fk.fastscan_select_tree(table_q8, codes_p, tile_n=tn, interpret=interp)
    elif impl == "mxu":
        tq = tile_q or _auto_tile(q, fk.TILE_Q)
        table_p = _pad_to(table_q8, 0, tq)
        acc = fk.fastscan_onehot_mxu(table_p, codes_p, tile_n=tn, tile_q=tq,
                                     interpret=interp)
    else:
        raise ValueError(f"unknown impl {impl!r}; want one of {IMPLS}")
    return acc[:q, :n]


# ---------------------------------------------------------------------------
# grouped scan (the IVF hot path) + autotuned dispatch
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("impl", "tile_n", "interpret"))
def _fastscan_grouped_pallas(table_q8: jax.Array, packed_codes: jax.Array, *,
                             impl: str, tile_n: int,
                             interpret: bool | None) -> jax.Array:
    """Pallas half of the grouped dispatch ('select' | 'mxu'), pre-validated."""
    cap = packed_codes.shape[1]
    interp = _default_interpret() if interpret is None else interpret
    tn = tile_n or _auto_tile(cap, fk.TILE_N)
    codes_p = _pad_to(packed_codes, 1, tn)
    if impl == "select":
        acc = fk.fastscan_select_tree_grouped(table_q8, codes_p, tile_n=tn,
                                              interpret=interp)
    else:
        acc = fk.fastscan_onehot_mxu_grouped(table_q8, codes_p, tile_n=tn,
                                             interpret=interp)
    return acc[:, :cap]


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def _fastscan_grouped_stream(table_q8: jax.Array, packed_codes: jax.Array, *,
                             tile_n: int, interpret: bool | None) -> jax.Array:
    """Stream impl under the *gathered* calling convention: treat the
    (G, cap, M//2) codes as an in-place store of G lists probed by
    arange(G). Exists so 'stream' slots into the same registry/sweep as the
    gathered impls; the gather-free payoff comes from calling
    ``fastscan_stream_grouped`` on the real ListStore instead."""
    g, cap = packed_codes.shape[0], packed_codes.shape[1]
    interp = _default_interpret() if interpret is None else interpret
    # padding a copy is fine here (this is the parity/sweep path, not the
    # in-place hot path), so any tile works — pad cap up to it
    tn = tile_n if (tile_n and cap % tile_n == 0) else _auto_tile(cap, fk.TILE_N)
    codes_p = _pad_to(packed_codes, 1, tn)
    probes = jnp.arange(g, dtype=jnp.int32)
    acc = fk.fastscan_stream_grouped(table_q8, codes_p, probes, tile_n=tn,
                                     interpret=interp)
    return acc[:, :cap]


def fastscan_grouped(table_q8: jax.Array, packed_codes: jax.Array, *,
                     impl: str = "ref", tile_n: int = 0,
                     interpret: bool | None = None) -> jax.Array:
    """Grouped ADC for gathered IVF lists: (G, M, 16) u8 x (G, cap, M//2) u8
    -> (G, cap) i32. Group g = one (query, probed-list) pair.

    impl: 'ref' (vectorized jnp gather — fastest off-TPU) | 'select'
    (register-resident Pallas select-tree) | 'mxu' (per-group one-hot GEMM on
    the MXU) | 'stream' (in-kernel DMA of one cap tile per grid step; under
    this gathered signature it scans the codes as a G-list store probed by
    arange — see ``fastscan_stream_grouped`` for the true in-place entry) |
    'auto' (timed micro-sweep picks the (impl, tile_n) pair per
    (backend, interpret, G, cap, M) signature, cached process-wide; an
    explicit ``tile_n`` is ignored under 'auto' since the sweep timed pairs).
    Bit-identical.

    Shapes are static under jit, so 'auto' resolves at trace time: the sweep
    runs once per signature and the chosen concrete impl is what gets staged
    into the XLA program.
    """
    g, m, k = table_q8.shape
    cap = packed_codes.shape[1]
    assert k == 16, f"4-bit PQ requires K=16, got {k}"
    if impl not in SCAN_IMPLS:
        raise ValueError(f"unknown grouped impl {impl!r}; "
                         f"want one of {SCAN_IMPLS}")
    if impl == "auto":
        tuned = resolve_grouped_impl(g, cap, m, interpret=interpret)
        # the sweep timed (impl, tile) PAIRS — honoring a caller tile_n here
        # could pair the winning impl with a tile it never won with, so an
        # explicit tile_n is ignored under 'auto' (pass a concrete impl to
        # control tiling by hand)
        impl, tile_n = tuned.impl, tuned.tile_n
    if impl == "ref":
        return _fastscan_grouped_ref_jit(table_q8, packed_codes)
    if impl == "stream":
        return _fastscan_grouped_stream(table_q8, packed_codes, tile_n=tile_n,
                                        interpret=interpret)
    return _fastscan_grouped_pallas(table_q8, packed_codes, impl=impl,
                                    tile_n=tile_n, interpret=interpret)


_fastscan_grouped_ref_jit = jax.jit(ref_mod.fastscan_grouped_ref)


def resolve_scan_impl(impl: str, g: int, cap: int, m: int, *,
                      nlist: int | None = None,
                      interpret: bool | None = None,
                      probe_fill: float = 1.0) -> tuple[str, int]:
    """Resolve a requested scan impl to a concrete ``(impl, tile_n)``.

    Concrete impls pass through with tile 0 (shape-fit default); ``'auto'``
    consults the autotune table (``resolve_grouped_impl``) — which may pick
    ``'stream'``, letting callers that hold the codes in place
    (``core.ivf.scan_probes``) route to the gather-free path; such callers
    pass their store's ``nlist`` so the stream candidate is timed against
    the strides it will really see. ``probe_fill`` is the expected fraction
    of *valid* probe slots: under adaptive pruning (docs/anytime.md) a
    margin policy leaves many ``-1`` slots whose DMA the stream kernel
    skips outright, so a sweep timed on dense probes would overstate the
    stream cost — the sweep masks ``1 - probe_fill`` of its probes and the
    verdict is keyed by the fill. Shared by the single-host and sharded
    pipelines so dispatch cannot drift.
    """
    if impl not in SCAN_IMPLS:
        raise ValueError(f"unknown grouped impl {impl!r}; "
                         f"want one of {SCAN_IMPLS}")
    if impl != "auto":
        return impl, 0
    tuned = resolve_grouped_impl(g, cap, m, nlist=nlist, interpret=interpret,
                                 probe_fill=probe_fill)
    return tuned.impl, tuned.tile_n


def resolve_rerank_dispatch(impl: str, q: int, r: int, d: int, k: int,
                            n: int, *,
                            interpret: bool | None = None) -> tuple[str, int]:
    """Resolve a requested re-rank impl to a concrete ``(impl, tile_r)``.

    The re-rank twin of ``resolve_scan_impl``: concrete impls pass through
    with tile 0 (shape-fit default), ``'auto'`` consults the autotune table
    (``resolve_rerank_impl``). Shared by ``rerank.finalize_candidates`` on
    the single-host and sharded pipelines.
    """
    if impl not in RERANK_IMPLS:
        raise ValueError(f"unknown rerank impl {impl!r}; "
                         f"want one of {RERANK_IMPLS}")
    if impl != "auto":
        return impl, 0
    tuned = resolve_rerank_impl(q, r, d, k, n, interpret=interpret)
    return tuned.impl, tuned.tile_n


@functools.partial(jax.jit, static_argnames=("tile_n", "interpret"))
def fastscan_stream_grouped(table_q8: jax.Array, list_codes: jax.Array,
                            probe_ids: jax.Array, *, tile_n: int = 0,
                            interpret: bool | None = None) -> jax.Array:
    """Gather-free grouped ADC over an in-place ListStore.

    table_q8: (G, M, 16) u8 per-group LUTs; list_codes: (nlist, cap, M//2)
    u8 — ``ListStore.codes``, scanned where it lives (no gathered copy);
    probe_ids: (G,) i32, -1 = no probe (DMA skipped, zeros emitted).
    Returns (G, cap) i32, identical at every real slot to
    ``fastscan_grouped(table, list_codes[probe_ids])``.
    """
    g, m, k = table_q8.shape
    cap = list_codes.shape[1]
    assert k == 16, f"4-bit PQ requires K=16, got {k}"
    assert probe_ids.shape == (g,), (probe_ids.shape, g)
    interp = _default_interpret() if interpret is None else interpret
    tn = _stream_tile(cap, tile_n)
    return fk.fastscan_stream_grouped(table_q8, list_codes,
                                      probe_ids.astype(jnp.int32),
                                      tile_n=tn, interpret=interp)


@functools.partial(jax.jit,
                   static_argnames=("keep", "tile_n", "interpret",
                                    "early_exit", "groups_per_query"))
def fastscan_stream_topk(table_q8: jax.Array, list_codes: jax.Array,
                         probe_ids: jax.Array, sizes: jax.Array, *,
                         keep: int, tile_n: int = 0,
                         filter_bits: jax.Array | None = None,
                         interpret: bool | None = None,
                         early_exit: bool = False,
                         groups_per_query: int = 0,
                         scales: jax.Array | None = None,
                         biases: jax.Array | None = None
                         ) -> tuple[jax.Array, ...]:
    """Gather-free scan + fused candidate reduction over an in-place store.

    Like ``fastscan_stream_grouped`` but the full (G, cap) accumulation
    never reaches HBM: each cap tile keeps only its ``kc = min(keep,
    tile_n)`` smallest entries, so any final selection of <= ``keep``
    candidates per query is exact (see the kernel docstring for the
    tie-break argument). ``sizes`` (nlist,) i32 masks slots past each
    list's true occupancy before selection. ``filter_bits`` — optional
    (nlist, W) u8 packed filter bitmap (``core.lists.pack_filter_mask``
    layout) — masks rows whose bit is 0 through the same pre-selection
    path; only the probed groups' rows (a (G, W) u8 gather, ~1.5% of the
    code bytes at M=16) ever reach the kernel. Returns
    (vals (G, n_tiles, kc) i32, slots (G, n_tiles, kc) i32, -1 = absent —
    padding, filtered-out, or invalid probe).

    With ``early_exit`` (plus ``groups_per_query`` > 0 dividing G and the
    per-group dequantization affine ``scales``/``biases``, both (G,) f32)
    the kernel additionally prunes tiles whose lower bound can't beat the
    query's running kc-th best, and a third ``skipped`` (G, n_tiles) i32
    array is returned (docs/anytime.md). Pruning is only armed when the
    per-tile candidate width covers the full selection (``kc == keep``,
    i.e. ``keep <= tile_n``) — otherwise the running kc-th best would be
    tighter than the keep-th best the caller selects and the skip would
    stop being lossless, so the kernel silently falls back to the unpruned
    path (``skipped`` all zeros).
    """
    g, m, k = table_q8.shape
    cap = list_codes.shape[1]
    assert k == 16, f"4-bit PQ requires K=16, got {k}"
    assert probe_ids.shape == (g,), (probe_ids.shape, g)
    interp = _default_interpret() if interpret is None else interpret
    tn = _stream_tile(cap, tile_n)
    kc = max(1, min(keep, tn))
    probes = probe_ids.astype(jnp.int32)
    fb = None
    if filter_bits is not None:
        assert filter_bits.shape[0] == list_codes.shape[0], (
            filter_bits.shape, list_codes.shape)
        # pre-gather each group's bitmap row; invalid probes (-1) clamp to
        # row 0 but their whole group is skipped inside the kernel anyway
        fb = filter_bits.astype(jnp.uint8)[jnp.maximum(probes, 0)]
    if early_exit:
        assert scales is not None and biases is not None, (
            "early_exit requires the per-group dequantization affine")
        if kc == keep and groups_per_query > 0 and g % groups_per_query == 0:
            vals, slots, skipped = fk.fastscan_stream_topk_grouped(
                table_q8, list_codes, probes, sizes.astype(jnp.int32), kc=kc,
                tile_n=tn, filter_bits=fb, interpret=interp, early_exit=True,
                groups_per_query=groups_per_query, scales=scales,
                biases=biases)
            return vals, slots, skipped
        vals, slots = fk.fastscan_stream_topk_grouped(
            table_q8, list_codes, probes, sizes.astype(jnp.int32), kc=kc,
            tile_n=tn, filter_bits=fb, interpret=interp)
        return vals, slots, jnp.zeros(vals.shape[:2], jnp.int32)
    return fk.fastscan_stream_topk_grouped(
        table_q8, list_codes, probes, sizes.astype(jnp.int32), kc=kc,
        tile_n=tn, filter_bits=fb, interpret=interp)


def _rerank_tile(r: int, tile_r: int = 0) -> int:
    """Candidate-chunk size for the stream re-rank: honor an explicit
    ``tile_r``, else the smallest power-of-two >= min(r, TILE_R) (floor 8) —
    candidate ids are padded with -1, so any tile is realizable."""
    if tile_r:
        return tile_r
    return max(8, min(rk.TILE_R, 1 << max(r - 1, 1).bit_length()))


@functools.partial(jax.jit, static_argnames=("k", "tile_r", "interpret"))
def rerank_stream_topk(base: jax.Array, norms: jax.Array, q: jax.Array,
                       cand_ids: jax.Array, *, k: int, tile_r: int = 0,
                       interpret: bool | None = None
                       ) -> tuple[jax.Array, jax.Array]:
    """Gather-free exact re-rank over the in-place base (stage 3 hot path).

    base (N, D) f32 stays in HBM — the kernel DMAs only each query's
    candidate rows; norms (N,) f32 = ``core.lists.base_norms(base)``;
    q (Q, D) f32; cand_ids (Q, R) i32, -1 = padding. Returns
    (vals (Q, k) f32 ascending, ids (Q, k) i32, -1 = absent), bit-identical
    to ``engine.rerank.exact_rerank`` (same norms+GEMM expression, same
    ``masked_topk`` tie-breaks — see kernels/rerank_kernel.py).
    """
    qq, r = cand_ids.shape
    interp = _default_interpret() if interpret is None else interpret
    tr = _rerank_tile(r, tile_r)
    cand_p = _pad_to(cand_ids.astype(jnp.int32), 1, tr, value=-1)
    # only the survivors' norms are gathered up front: (Q, Rp) f32, a D×
    # smaller gather than the (Q, R, D) row copy this path eliminates
    xn = norms[jnp.maximum(cand_p, 0)]
    vals, pos = rk.rerank_stream_topk(base, q, cand_p, xn, k=k, tile_r=tr,
                                      interpret=interp)
    # pos follows masked_topk's position contract, so the shared sentinel-
    # preserving mapper applies as-is
    return vals, topk_mod.gather_ids(cand_p, pos)


class TunedScan(NamedTuple):
    """Autotune verdict for one scan/re-rank shape signature."""

    impl: str          # winning concrete impl (GROUPED_IMPLS / RERANK_CONCRETE)
    tile_n: int        # winning tile (0 = impl has no tiling knob)
    timings_us: tuple  # ((f"{impl}@{tile}", median_us), ...) — full sweep


_AUTOTUNE_CACHE: dict[tuple, TunedScan] = {}
# serializes first resolutions: without it, two threads racing on the same
# signature would pay the sweep twice and could cache divergent verdicts
_AUTOTUNE_LOCK = threading.Lock()


class _TraceEscapeError(RuntimeError):
    """The autotune sweep was staged into an ambient trace instead of run."""


def _grouped_tile_candidates(cap: int) -> tuple[int, ...]:
    """Cap-tile sizes worth timing: the shape-fit auto tile plus smaller
    power-of-two tiles (more grid parallelism / smaller VMEM blocks)."""
    fit = _auto_tile(cap, fk.TILE_N)
    cands = {fit}
    for t in (128, 512):
        if t < fit:
            cands.add(t)
    return tuple(sorted(cands))


def _median_time_us(fn, iters: int = 3) -> float:
    out = fn()  # warmup: compile (or first interpret pass)
    if isinstance(out, jax.core.Tracer):
        # Staged into an ambient trace instead of executed — the "timing"
        # would measure tracing overhead, not the kernel. resolve_grouped_impl
        # escapes to a worker thread precisely to prevent this.
        raise _TraceEscapeError("autotune sweep ran under an ambient jax trace")
    jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def _resolve_cached(sig: tuple, sweep_fn, *args) -> TunedScan:
    """Shared resolve-or-sweep path for the scan and re-rank autotuners.

    One sweep per signature per process. The sweep must EXECUTE even when
    resolution happens at trace time (scan_probes, finalize_candidates and
    the fused pipeline are jit'd, so that is the normal case): under an
    ambient trace every jax call made here would be staged into the
    caller's jaxpr instead of run, and the "timings" would measure tracing
    overhead. JAX trace state is thread-local, so a worker thread is a
    clean escape hatch — everything it runs dispatches eagerly on concrete
    arrays.
    """
    hit = _AUTOTUNE_CACHE.get(sig)
    if hit is not None:
        return hit
    with _AUTOTUNE_LOCK:
        hit = _AUTOTUNE_CACHE.get(sig)  # racing thread may have resolved it
        if hit is not None:
            return hit
        with concurrent.futures.ThreadPoolExecutor(max_workers=1) as ex:
            tuned = ex.submit(sweep_fn, *args).result()
        _AUTOTUNE_CACHE[sig] = tuned
    return tuned


def resolve_grouped_impl(g: int, cap: int, m: int, *, nlist: int | None = None,
                         interpret: bool | None = None,
                         probe_fill: float = 1.0) -> TunedScan:
    """Resolve ``impl='auto'`` for the grouped scan at one shape signature.

    Times every concrete impl (x its tile candidates) on synthetic data of
    the exact workload shape and caches the winner per
    ``('scan', backend, interpret, G, cap, M, nlist, probe_fill)`` — one
    sweep per signature per process (interpret mode is part of the key: a
    verdict timed on the Pallas interpreter must never be reused for
    compiled execution, or vice versa). ``nlist`` is the size of the
    in-place ListStore the 'stream' candidate would scan: the sweep times
    it against a store of that many lists with random probes, so the
    verdict reflects real list-store strides rather than the arange-probed
    G-list stand-in (``nlist=None`` keeps the gathered calling convention's
    G-list store — what ``fastscan_grouped`` itself executes).
    ``probe_fill`` in (0, 1] is the expected valid-probe fraction: the
    sweep masks ``1 - probe_fill`` of its probes to ``-1`` (evenly across
    the sweep's queries), the workload an adaptive-nprobe policy actually
    presents — the stream kernel skips those groups' DMAs while the
    gathered impls still pay full freight, so a dense-probe sweep would
    overstate the stream advantage's denominator. The fixed-seed synthetic
    data makes the sweep reproducible; the cache makes resolution
    deterministic for the life of the process (asserted in
    tests/test_kernels.py). A candidate that fails to build at this shape
    (e.g. an MXU tile blowing VMEM) is dropped, not fatal — 'ref' always
    survives.
    """
    interp = _default_interpret() if interpret is None else interpret
    nl = int(g if nlist is None else nlist)
    fill = round(float(probe_fill), 4)
    if not 0.0 < fill <= 1.0:
        raise ValueError(f"probe_fill must be in (0, 1], got {probe_fill}")
    sig = ("scan", jax.default_backend(), interp, int(g), int(cap), int(m),
           nl, fill)
    return _resolve_cached(sig, _run_grouped_sweep, int(g), int(cap), int(m),
                           nl, fill, interp)


def _run_grouped_sweep(g: int, cap: int, m: int, nlist: int, fill: float,
                       interp: bool) -> TunedScan:
    rng = np.random.default_rng(0)
    # plain numpy on purpose: jnp.asarray under an ambient trace would make
    # these tracers; as numpy they only become device arrays inside the
    # worker thread's eager calls
    table = rng.integers(0, 256, (g, m, 16), dtype=np.uint8)
    codes = rng.integers(0, 256, (g, cap, m // 2), dtype=np.uint8)
    # the stream impl's real operand: an nlist-sized in-place store with
    # random probes — the strides scan_probes actually drives it with
    store = rng.integers(0, 256, (nlist, cap, m // 2), dtype=np.uint8)
    probes = rng.integers(0, nlist, (g,), dtype=np.int32)
    if fill < 1.0:
        # representative adaptive-probe mix: prune a deterministic
        # 1-fill fraction of slots to the -1 sentinel, spread evenly so
        # the stream kernel's skip pattern matches a margin policy's
        # (some groups per query dropped) rather than a dead prefix
        n_prune = min(g - 1, int(round(g * (1.0 - fill))))
        if n_prune > 0:
            pruned_idx = np.linspace(0, g - 1, n_prune).astype(np.int64)
            probes[pruned_idx] = -1
    sweep = []
    for impl in GROUPED_IMPLS:
        if impl == "ref":
            tiles = (0,)
        elif impl == "stream":
            # stream scans the store in place, so only cap-dividing tiles
            # are realizable — map each candidate to its realizable tile so
            # the verdict's (impl, tile) pair is exactly what executes
            tiles = tuple(sorted({_stream_tile(cap, t)
                                  for t in _grouped_tile_candidates(cap)}))
        else:
            tiles = _grouped_tile_candidates(cap)
        for tn in tiles:
            if impl == "stream":
                fn = functools.partial(fastscan_stream_grouped, table, store,
                                       probes, tile_n=tn, interpret=interp)
            else:
                fn = functools.partial(fastscan_grouped, table, codes,
                                       impl=impl, tile_n=tn, interpret=interp)
            try:
                us = _median_time_us(fn)
            except _TraceEscapeError:
                raise  # a trace-escape regression, not a bad candidate
            except Exception:  # candidate unbuildable at this shape: skip it
                continue
            sweep.append((impl, tn, us))
    if not sweep:
        raise RuntimeError(
            f"autotune sweep produced no working candidate at "
            f"(G={g}, cap={cap}, M={m}) — 'ref' should never fail")
    best = min(sweep, key=lambda r: r[2])
    tuned = TunedScan(
        impl=best[0], tile_n=best[1],
        timings_us=tuple((f"{i}@{tn}", us) for i, tn, us in sweep))
    return tuned


# Default cap on the synthetic base built for the re-rank sweep. The real N
# stays in the verdict KEY (two engines with identical (Q, R, D, k) but
# different base sizes must never share a verdict), but building a
# multi-million-row synthetic copy would cost more than the sweep measures,
# so beyond the cap the timing runs on a 64k-row stand-in. What actually
# varies with N for fixed R is row-gather cache locality, and at 64k x 128
# f32 (~32 MB) the stand-in already misses on-chip caches like a large table
# does. Real-TPU deployments that want the sweep to touch genuine multi-
# million-row strides raise the cap via the REPRO_RERANK_SWEEP_N_CAP env var
# or the ``sweep_n_cap`` kwarg (docs/kernels.md).
_RERANK_SWEEP_N_CAP = 65536


def _rerank_sweep_n_cap() -> int:
    """Effective sweep cap: ``REPRO_RERANK_SWEEP_N_CAP`` env override (>= 1)
    falling back to ``_RERANK_SWEEP_N_CAP``. Read at resolve time, so tests
    and long-lived servers can retarget without a restart."""
    raw = os.environ.get("REPRO_RERANK_SWEEP_N_CAP", "")
    try:
        cap = int(raw)
    except ValueError:
        return _RERANK_SWEEP_N_CAP
    return cap if cap >= 1 else _RERANK_SWEEP_N_CAP


def resolve_rerank_impl(q: int, r: int, d: int, k: int, n: int, *,
                        interpret: bool | None = None,
                        sweep_n_cap: int | None = None) -> TunedScan:
    """Resolve ``rerank_impl='auto'`` at one (Q, R, D, k, N) re-rank
    signature (N = base-row count).

    Times the gathered norms+GEMM fallback against the streaming kernel
    (x its chunk-tile candidates) on synthetic data of the workload shape
    (base rows capped at ``sweep_n_cap``, defaulting to the
    ``REPRO_RERANK_SWEEP_N_CAP`` env var then ``_RERANK_SWEEP_N_CAP``) and
    caches the verdict per ``('rerank', backend, interpret, Q, R, D, k, N)``
    in the same process-wide table (and the same persisted JSON) as the
    scan verdicts. The cap shapes only the synthetic stand-in's size, never
    the key, so re-resolving with a bigger cap requires clearing the cached
    verdict first (``clear_autotune_cache(n=...)``). Both candidates are
    bit-identical, so the verdict is purely a performance choice —
    'gathered' always survives as the fallback.
    """
    interp = _default_interpret() if interpret is None else interpret
    cap = _rerank_sweep_n_cap() if sweep_n_cap is None else max(1, int(sweep_n_cap))
    sig = ("rerank", jax.default_backend(), interp, int(q), int(r), int(d),
           int(k), int(n))
    return _resolve_cached(sig, _run_rerank_sweep, int(q), int(r), int(d),
                           int(k), int(n), cap, interp)


def _rerank_tile_candidates(r: int) -> tuple[int, ...]:
    """Chunk sizes worth timing: the shape-fit default plus smaller
    power-of-two chunks (more DMA overlap, smaller scratch)."""
    fit = _rerank_tile(r)
    return tuple(sorted({fit} | {t for t in (16, 32) if t < fit}))


def _run_rerank_sweep(q: int, r: int, d: int, k: int, n: int, n_cap: int,
                      interp: bool) -> TunedScan:
    from repro.engine import rerank as rerank_mod  # lazy: engine -> ops

    rng = np.random.default_rng(0)
    n_sweep = max(r, min(n, n_cap))
    base = rng.standard_normal((n_sweep, d), dtype=np.float32)
    norms = np.sum(base * base, axis=-1)
    queries = rng.standard_normal((q, d), dtype=np.float32)
    cand = rng.integers(0, n_sweep, (q, r), dtype=np.int32)
    sweep = []
    for impl in RERANK_CONCRETE:
        tiles = (0,) if impl == "gathered" else _rerank_tile_candidates(r)
        for tr in tiles:
            if impl == "gathered":
                fn = functools.partial(rerank_mod.exact_rerank, base, queries,
                                       cand, k, norms=norms)
            else:
                fn = functools.partial(rerank_stream_topk, base, norms,
                                       queries, cand, k=k, tile_r=tr,
                                       interpret=interp)
            try:
                us = _median_time_us(fn)
            except _TraceEscapeError:
                raise
            except Exception:  # unbuildable candidate (scratch too big): skip
                continue
            sweep.append((impl, tr, us))
    if not sweep:
        raise RuntimeError(
            f"re-rank autotune sweep produced no working candidate at "
            f"(Q={q}, R={r}, D={d}, k={k}) — 'gathered' should never fail")
    best = min(sweep, key=lambda rec: rec[2])
    return TunedScan(
        impl=best[0], tile_n=best[1],
        timings_us=tuple((f"{i}@{tn}", us) for i, tn, us in sweep))


def autotune_cache() -> dict[tuple, TunedScan]:
    """Snapshot of the process-wide autotune cache, keyed by
    ('scan', backend, interpret, G, cap, M, nlist, probe_fill) and
    ('rerank', backend, interpret, Q, R, D, k, N). For inspection/metrics —
    mutations don't stick."""
    return dict(_AUTOTUNE_CACHE)


def autotune_cache_size() -> int:
    """Number of resolved signatures (mirrors ``engine.fused_cache_size``)."""
    return len(_AUTOTUNE_CACHE)


def clear_autotune_cache(kind: str | None = None, *, nlist: int | None = None,
                         cap: int | None = None, n: int | None = None) -> int:
    """Drop resolved verdicts; with no arguments, all of them.

    Selective form (the mutation path, docs/mutability.md): ``kind``
    restricts to 'scan' or 'rerank' keys; ``nlist``/``cap`` match scan keys
    on the ListStore dimensions a compaction can change; ``n`` matches
    rerank keys on the base-row count an upsert can grow. The mutable
    engine calls this when an epoch swap retires a shape signature, so the
    retired epoch's verdicts can neither serve a lookup (the new shape
    re-keys anyway) nor be re-persisted by ``save_autotune_cache`` into a
    warmup file that outlives them. Returns the number of entries dropped.

    ``nlist``/``cap`` only ever match scan keys and ``n`` only rerank keys,
    so e.g. ``clear_autotune_cache(cap=1024)`` leaves every rerank verdict
    alone without needing ``kind='scan'`` spelled out.
    """
    with _AUTOTUNE_LOCK:
        if kind is None and nlist is None and cap is None and n is None:
            dropped = len(_AUTOTUNE_CACHE)
            _AUTOTUNE_CACHE.clear()
            return dropped
        doomed = []
        for key in _AUTOTUNE_CACHE:
            if kind is not None and key[0] != kind:
                continue
            if key[0] == "scan":
                # ('scan', backend, interpret, G, cap, M, nlist, probe_fill)
                if n is not None:
                    continue
                if nlist is not None and key[6] != nlist:
                    continue
                if cap is not None and key[4] != cap:
                    continue
            else:
                # ('rerank', backend, interpret, Q, R, D, k, N)
                if nlist is not None or cap is not None:
                    continue
                if n is not None and key[7] != n:
                    continue
            doomed.append(key)
        for key in doomed:
            del _AUTOTUNE_CACHE[key]
        return len(doomed)


_AUTOTUNE_SCHEMA = "repro.autotune/v3"
_AUTOTUNE_SCHEMA_V2 = "repro.autotune/v2"
_AUTOTUNE_SCHEMA_V1 = "repro.autotune/v1"


def save_autotune_cache(path: str) -> int:
    """Serialize the resolved TunedScan table to JSON at ``path``.

    Returns the number of entries written. Schema v3: each entry carries a
    ``kind`` ('scan' | 'rerank') plus its kind's full key dims (scan:
    backend/interpret/g/cap/m/nlist/probe_fill; rerank:
    backend/interpret/q/r/d/k/n), so one file can hold both stages'
    verdicts for several backends; ``load_autotune_cache`` re-keys them
    verbatim and lookups still only ever hit the running backend's
    signatures. A serving fleet saves after its first warmup and ships the
    file to every replica (``ServingLoop(warmup_cache=...)``).
    """
    with _AUTOTUNE_LOCK:  # a concurrent sweep may be inserting its verdict
        snapshot = dict(_AUTOTUNE_CACHE)
    entries = []
    for key, t in snapshot.items():
        timings = [[name, us] for name, us in t.timings_us]
        if key[0] == "scan":
            _, b, i, g, c, m, nl, fill = key
            entries.append({"kind": "scan", "backend": b, "interpret": bool(i),
                            "g": g, "cap": c, "m": m, "nlist": nl,
                            "probe_fill": fill,
                            "impl": t.impl, "tile_n": t.tile_n,
                            "timings_us": timings})
        else:
            _, b, i, q, r, d, k, n = key
            entries.append({"kind": "rerank", "backend": b,
                            "interpret": bool(i), "q": q, "r": r, "d": d,
                            "k": k, "n": n, "impl": t.impl,
                            "tile_n": t.tile_n, "timings_us": timings})
    with open(path, "w") as f:
        json.dump({"schema": _AUTOTUNE_SCHEMA, "entries": entries}, f,
                  indent=2)
    return len(entries)


def load_autotune_cache(path: str) -> int:
    """Merge a ``save_autotune_cache`` file into the process-wide table.

    Returns the number of entries adopted. Missing file, wrong schema, or
    malformed JSON load nothing (0) — a stale or absent warmup cache must
    never stop a boot, it just means the sweeps run again. Older schemas
    migrate gracefully: v1 files (no ``kind``, no ``nlist``) re-key their
    scan verdicts to ``nlist=g`` — the arange-probed G-list store that
    sweep actually timed — and both v1 and v2 files (no ``probe_fill``)
    re-key to ``probe_fill=1.0``, the dense-probe sweep they ran, so they
    only ever satisfy lookups for the workloads they measured. Entries
    naming an impl that no longer exists are skipped (stale file from an
    older build); entries already resolved in this process keep their
    in-process verdict.
    """
    if not os.path.exists(path):
        return 0
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError):
        return 0
    if not isinstance(data, dict) or data.get("schema") not in (
            _AUTOTUNE_SCHEMA, _AUTOTUNE_SCHEMA_V2, _AUTOTUNE_SCHEMA_V1):
        return 0
    loaded = 0
    with _AUTOTUNE_LOCK:
        for e in data.get("entries", ()):
            try:
                kind = str(e.get("kind", "scan"))
                if kind == "scan":
                    g = int(e["g"])
                    key = ("scan", str(e["backend"]), bool(e["interpret"]),
                           g, int(e["cap"]), int(e["m"]),
                           int(e.get("nlist", g)),  # v1: the G-list store
                           round(float(e.get("probe_fill", 1.0)), 4))
                    known = GROUPED_IMPLS
                elif kind == "rerank":
                    key = ("rerank", str(e["backend"]), bool(e["interpret"]),
                           int(e["q"]), int(e["r"]), int(e["d"]),
                           int(e["k"]), int(e["n"]))
                    known = RERANK_CONCRETE
                else:
                    continue
                tuned = TunedScan(
                    impl=str(e["impl"]), tile_n=int(e["tile_n"]),
                    timings_us=tuple((str(n), float(us))
                                     for n, us in e["timings_us"]))
            except (KeyError, TypeError, ValueError):
                continue
            if tuned.impl not in known or key in _AUTOTUNE_CACHE:
                continue
            _AUTOTUNE_CACHE[key] = tuned
            loaded += 1
    return loaded


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def fastscan_blockmin(table_q8: jax.Array, packed_codes: jax.Array, *,
                      block: int = 1024, interpret: bool | None = None
                      ) -> tuple[jax.Array, jax.Array]:
    """Fused ADC + per-block min/argmin. Pads N with +inf-like sentinel codes.

    Returns (min_dists (Q, ceil(N/block)) i32, global argmin ids).
    Padded tail rows use code 15 in every sub-space; callers who need exact
    semantics on ragged N should mask via the returned ids (< N check).
    """
    if table_q8.ndim == 2:
        table_q8 = table_q8[None]
    q, m, k = table_q8.shape
    n = packed_codes.shape[0]
    assert k == 16
    interp = _default_interpret() if interpret is None else interpret
    tq = _auto_tile(q, fk.TILE_Q)
    table_p = _pad_to(table_q8, 0, tq)
    codes_p = _pad_to(packed_codes, 0, block, value=0xFF)
    mins, args = fk.fastscan_blockmin(table_p, codes_p, tile_n=block, tile_q=tq,
                                      interpret=interp)
    nb = -(-n // block)
    return mins[:q, :nb], args[:q, :nb]
