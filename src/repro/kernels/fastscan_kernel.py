"""Pallas TPU kernels for the 4-bit PQ fast-scan ADC (paper §3, TPU-adapted).

The paper emulates AVX2's 256-bit in-register shuffle with two NEON 128-bit
``vqtbl1q_u8`` table lookups. A TPU has no cross-lane shuffle at all, so we
re-express the register-resident 16-entry LUT gather in the units the TPU
*does* have, keeping the LUT pinned in VMEM/VREGs (the TPU analogue of the
SIMD register file):

Variant A — ``select-tree`` (VPU, paper-faithful analogue):
    A 16-way LUT lookup is decomposed into log2(16) = 4 levels of 2-way
    vector selects over statically-sliced halves of the LUT, exactly as the
    paper decomposes one 256-bit shuffle into two 128-bit shuffles. All
    operands live in vector registers; the only memory traffic is the code
    tile stream.

Variant B — ``one-hot MXU`` (beyond-paper):
    The ADC gather for a *batch* of queries is algebraically a matmul:
    ``acc[q, n] = T_flat[q] . onehot(codes[n])`` with ``T_flat`` the stacked
    (M*16) LUT. On TPU the systolic MXU is the throughput unit, so we convert
    the gather into a dense bf16 GEMM. Exactness: all u8 LUT entries (0..255)
    and one-hot 0/1 are exactly representable in bf16; products and the f32
    accumulation of <= M*16 terms (<= 32640 for M <= 128) are exact in f32,
    so the result is still bit-identical to the int oracle.

    Both the flat (shared database, ``fastscan_onehot_mxu``) and the grouped
    (gathered IVF lists, ``fastscan_onehot_mxu_grouped``) scans have MXU
    forms; the grouped one is the serving hot path (``core.ivf.scan_probes``)
    where each (query, probe) pair owns its own residual LUT.

Variant C — ``fused block-min``: variant B plus an in-kernel per-tile
    min/argmin reduction, the TPU stand-in for faiss' SIMD top-k candidate
    filtering via ``_mm256_movemask_epi8`` (which has no Pallas equivalent).

Variant D — ``stream`` (gather-free probe streaming):
    The grouped variants above consume a *gathered* ``(G, cap, M//2)`` copy
    of every probed list — an O(G·cap) HBM round trip that exists only to
    feed the kernel. The stream kernels instead take ``ListStore.codes``
    **in place** (``(nlist, cap, M//2)`` u8, memory space ANY) plus
    scalar-prefetched probe ids, and each grid step DMAs only the probed
    list's ``(tile_n, M//2)`` tile into VMEM — the gathered copy never
    exists, and invalid probes (id -1) skip the DMA entirely.
    ``fastscan_stream_topk_grouped`` additionally fuses the candidate
    reduction: instead of writing the full ``(G, cap)`` accumulation back to
    HBM it keeps a per-tile partial selection in VMEM and emits only
    ``(G, n_tiles, kc)`` (quantized dist, slot) candidate pairs — shrinking
    the scan-stage writeback by ~cap/kc. Both stream kernels drive their
    copies through the shared two-slot double-buffered pipeline
    (``kernels/pipeline.py``): tile t+1 streams into one scratch buffer
    while tile t is scanned out of the other, hiding the DMA latency on
    real hardware.

All kernels are tiled with explicit BlockSpecs. Codes arrive nibble-packed
``(N, M//2) u8`` — one VMEM tile feeds every variant with lane-contiguous
access (the TPU adaptation of the paper's interleaved register layout).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pipeline import double_buffered_dma, double_buffered_dma_gated

# Default tile sizes. Lane dim multiples of 128, sublane multiples of 8
# (f32/i32 VREG tile is 8x128). N tile of 1024 keeps the code tile
# (1024 x M/2 u8) well under VMEM while amortizing LUT residency.
TILE_N = 1024
TILE_Q = 128


def _unpack_nibbles_i32(packed_u8: jax.Array) -> jax.Array:
    """(tn, M//2) u8 -> (tn, M) i32; lo nibble = even sub-space."""
    p = packed_u8.astype(jnp.int32)
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    # interleave: out[:, 0::2] = lo, out[:, 1::2] = hi, without scatter
    # (tn, mh) -> (tn, mh, 2) -> (tn, m)
    return jnp.stack([lo, hi], axis=-1).reshape(p.shape[0], -1)


# ---------------------------------------------------------------------------
# Variant A: select-tree (VPU)
# ---------------------------------------------------------------------------

def _select_tree_acc(t: jax.Array, codes: jax.Array) -> jax.Array:
    """Select-tree ADC accumulation: t (M, 16) i32 LUT x codes (tn, M) i32
    -> (tn,) i32 sums.

    A 16-way LUT lookup decomposed into log2(16) = 4 levels of 2-way vector
    selects (the paper's 256-bit shuffle via 2x128-bit shuffles, one level
    deeper on TPU)."""
    b0 = (codes & 1).astype(jnp.bool_)
    b1 = (codes & 2).astype(jnp.bool_)
    b2 = (codes & 4).astype(jnp.bool_)
    b3 = (codes & 8).astype(jnp.bool_)

    lo8 = t[None, :, 0:8]   # (1, M, 8) broadcast over the N tile
    hi8 = t[None, :, 8:16]
    s3 = jnp.where(b3[:, :, None], hi8, lo8)          # (tn, M, 8)
    s2 = jnp.where(b2[:, :, None], s3[..., 4:8], s3[..., 0:4])  # (tn, M, 4)
    s1 = jnp.where(b1[:, :, None], s2[..., 2:4], s2[..., 0:2])  # (tn, M, 2)
    s0 = jnp.where(b0, s1[..., 1], s1[..., 0])        # (tn, M)
    return jnp.sum(s0, axis=-1, dtype=jnp.int32)


def _select_tree_kernel(table_ref, codes_ref, out_ref):
    """One query row x one N tile.

    table_ref: (1, M, 16) u8 block  — the register-resident LUT
    codes_ref: (tn, M//2) u8 block  — nibble-packed codes
    out_ref:   (1, tn) i32 block
    """
    codes = _unpack_nibbles_i32(codes_ref[...])  # (tn, M)
    t = table_ref[0].astype(jnp.int32)  # (M, 16)
    out_ref[...] = _select_tree_acc(t, codes)[None, :]


def fastscan_select_tree(table_q8: jax.Array, packed_codes: jax.Array, *,
                         tile_n: int = TILE_N, interpret: bool = True) -> jax.Array:
    """(Q, M, 16) u8 x (N, M//2) u8 -> (Q, N) i32. Q and N pre-padded."""
    q, m, k = table_q8.shape
    n, mh = packed_codes.shape
    assert k == 16 and mh * 2 == m and n % tile_n == 0
    grid = (q, n // tile_n)
    return pl.pallas_call(
        _select_tree_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m, 16), lambda qi, ni: (qi, 0, 0)),
            pl.BlockSpec((tile_n, mh), lambda qi, ni: (ni, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda qi, ni: (qi, ni)),
        out_shape=jax.ShapeDtypeStruct((q, n), jnp.int32),
        interpret=interpret,
    )(table_q8, packed_codes)


def _select_tree_grouped_kernel(table_ref, codes_ref, out_ref):
    """One (query, probe) group x one N tile — each group has its OWN LUT
    *and* its own code tile (gathered IVF lists), unlike the shared-database
    variant above.

    table_ref: (1, M, 16) u8 block; codes_ref: (1, tn, M//2) u8 block;
    out_ref: (1, tn) i32 block.
    """
    codes = _unpack_nibbles_i32(codes_ref[0])  # (tn, M)
    t = table_ref[0].astype(jnp.int32)  # (M, 16)
    out_ref[...] = _select_tree_acc(t, codes)[None, :]


def fastscan_select_tree_grouped(table_q8: jax.Array, packed_codes: jax.Array, *,
                                 tile_n: int = TILE_N, interpret: bool = True
                                 ) -> jax.Array:
    """Grouped ADC: (G, M, 16) u8 x (G, N, M//2) u8 -> (G, N) i32.

    The IVF 'memory path' made register-resident: group g = one
    (query, probed-list) pair whose residual LUT scans only that list's code
    tile. N (the padded list capacity) must be a tile_n multiple.
    """
    g, m, k = table_q8.shape
    gc, n, mh = packed_codes.shape
    assert k == 16 and mh * 2 == m and gc == g and n % tile_n == 0
    grid = (g, n // tile_n)
    return pl.pallas_call(
        _select_tree_grouped_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m, 16), lambda gi, ni: (gi, 0, 0)),
            pl.BlockSpec((1, tile_n, mh), lambda gi, ni: (gi, ni, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda gi, ni: (gi, ni)),
        out_shape=jax.ShapeDtypeStruct((g, n), jnp.int32),
        interpret=interpret,
    )(table_q8, packed_codes)


# ---------------------------------------------------------------------------
# Variant B: one-hot MXU
# ---------------------------------------------------------------------------

def _onehot_mxu_kernel(table_ref, codes_ref, out_ref):
    """table_ref: (tq, M*16) u8; codes_ref: (tn, M//2) u8; out_ref: (tq, tn) i32."""
    codes = _unpack_nibbles_i32(codes_ref[...])  # (tn, M)
    tn, m = codes.shape
    # one-hot on the VPU: (tn, M, 16) -> (tn, M*16), bf16 so the MXU eats it
    iota = jax.lax.broadcasted_iota(jnp.int32, (tn, m, 16), dimension=2)
    onehot = (codes[:, :, None] == iota).astype(jnp.bfloat16).reshape(tn, m * 16)
    t = table_ref[...].astype(jnp.bfloat16)  # (tq, M*16)
    # (tq, M16) x (M16, tn) -> (tq, tn) on the MXU, f32 accumulation (exact)
    acc = jax.lax.dot_general(
        t, onehot,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] = acc.astype(jnp.int32)


def fastscan_onehot_mxu(table_q8: jax.Array, packed_codes: jax.Array, *,
                        tile_n: int = TILE_N, tile_q: int = TILE_Q,
                        interpret: bool = True) -> jax.Array:
    """(Q, M, 16) u8 x (N, M//2) u8 -> (Q, N) i32. Q, N pre-padded to tiles."""
    q, m, k = table_q8.shape
    n, mh = packed_codes.shape
    assert k == 16 and mh * 2 == m
    assert q % tile_q == 0 and n % tile_n == 0, (q, tile_q, n, tile_n)
    t_flat = table_q8.reshape(q, m * 16)
    grid = (q // tile_q, n // tile_n)
    return pl.pallas_call(
        _onehot_mxu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, m * 16), lambda qi, ni: (qi, 0)),
            pl.BlockSpec((tile_n, mh), lambda qi, ni: (ni, 0)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_n), lambda qi, ni: (qi, ni)),
        out_shape=jax.ShapeDtypeStruct((q, n), jnp.int32),
        interpret=interpret,
    )(t_flat, packed_codes)


def _onehot_mxu_grouped_kernel(table_ref, codes_ref, out_ref):
    """One (query, probe) group x one cap tile, on the MXU.

    table_ref: (1, M*16) u8 block — this group's flattened LUT
    codes_ref: (1, tn, M//2) u8 block — this group's gathered code tile
    out_ref:   (1, tn) i32 block

    The grouped ADC gather is a per-group matvec: unpack the nibble codes to
    one-hot (tn, M, 16) planes, flatten to (tn, M*16) bf16, and contract
    against the group's own (1, M*16) LUT row on the MXU with f32
    accumulation. Exactness argument is identical to the flat variant above
    (u8 and 0/1 exact in bf16; <= M*16 f32 summands exact).
    """
    codes = _unpack_nibbles_i32(codes_ref[0])  # (tn, M)
    tn, m = codes.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (tn, m, 16), dimension=2)
    onehot = (codes[:, :, None] == iota).astype(jnp.bfloat16).reshape(tn, m * 16)
    t = table_ref[...].astype(jnp.bfloat16)  # (1, M*16)
    acc = jax.lax.dot_general(
        t, onehot,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (1, tn)
    out_ref[...] = acc.astype(jnp.int32)


def fastscan_onehot_mxu_grouped(table_q8: jax.Array, packed_codes: jax.Array, *,
                                tile_n: int = TILE_N, interpret: bool = True
                                ) -> jax.Array:
    """Grouped one-hot MXU ADC: (G, M, 16) u8 x (G, cap, M//2) u8 -> (G, cap) i32.

    The MXU formulation of the gathered-list scan — the path every real IVF
    search takes (``scan_probes``). Group g = one (query, probed-list) pair
    with its OWN residual LUT and its own gathered code tile; the grid runs
    over (group, cap tile) and each program does one LUT-row x one-hot-codes
    contraction on the MXU. cap must be a tile_n multiple (pre-padded).
    Bit-identical to the ref/select formulations.
    """
    g, m, k = table_q8.shape
    gc, n, mh = packed_codes.shape
    assert k == 16 and mh * 2 == m and gc == g and n % tile_n == 0
    t_flat = table_q8.reshape(g, m * 16)
    grid = (g, n // tile_n)
    return pl.pallas_call(
        _onehot_mxu_grouped_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m * 16), lambda gi, ni: (gi, 0)),
            pl.BlockSpec((1, tile_n, mh), lambda gi, ni: (gi, ni, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda gi, ni: (gi, ni)),
        out_shape=jax.ShapeDtypeStruct((g, n), jnp.int32),
        interpret=interpret,
    )(t_flat, packed_codes)


# ---------------------------------------------------------------------------
# Variant C: fused scan + per-tile min/argmin (top-1 candidate filter)
# ---------------------------------------------------------------------------

def _blockmin_kernel(table_ref, codes_ref, min_ref, arg_ref, *, tile_n: int):
    codes = _unpack_nibbles_i32(codes_ref[...])  # (tn, M)
    tn, m = codes.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (tn, m, 16), dimension=2)
    onehot = (codes[:, :, None] == iota).astype(jnp.bfloat16).reshape(tn, m * 16)
    t = table_ref[...].astype(jnp.bfloat16)
    acc = jax.lax.dot_general(
        t, onehot, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32).astype(jnp.int32)  # (tq, tn)
    ni = pl.program_id(1)
    # in-register reduction: the movemask/top-k filter analogue
    min_ref[...] = jnp.min(acc, axis=-1, keepdims=True)
    local_arg = jnp.argmin(acc, axis=-1).astype(jnp.int32)
    arg_ref[...] = (local_arg + ni * tile_n)[:, None]


def fastscan_blockmin(table_q8: jax.Array, packed_codes: jax.Array, *,
                      tile_n: int = TILE_N, tile_q: int = TILE_Q,
                      interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused ADC + per-N-tile min: (Q, N/tile_n) i32 mins and global argmin ids."""
    q, m, k = table_q8.shape
    n, mh = packed_codes.shape
    assert k == 16 and mh * 2 == m
    assert q % tile_q == 0 and n % tile_n == 0
    t_flat = table_q8.reshape(q, m * 16)
    grid = (q // tile_q, n // tile_n)
    kernel = functools.partial(_blockmin_kernel, tile_n=tile_n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, m * 16), lambda qi, ni: (qi, 0)),
            pl.BlockSpec((tile_n, mh), lambda qi, ni: (ni, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q, 1), lambda qi, ni: (qi, ni)),
            pl.BlockSpec((tile_q, 1), lambda qi, ni: (qi, ni)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, n // tile_n), jnp.int32),
            jax.ShapeDtypeStruct((q, n // tile_n), jnp.int32),
        ],
        interpret=interpret,
    )(t_flat, packed_codes)


# ---------------------------------------------------------------------------
# Variant D: gather-free probe streaming (in-kernel list DMA)
# ---------------------------------------------------------------------------

# Larger than any reachable ADC sum (<= 128 sub-spaces * 255 = 32640), used
# to mark padded/invalid candidate slots inside the fused selection.
ACC_SENTINEL = jnp.iinfo(jnp.int32).max


def _stream_dma_plan(probe_ref, codes_hbm, scratch, sem, *,
                     tile_n: int, n_tiles: int, total: int):
    """The (make_dma, valid) pair shared by both stream scan kernels.

    A global sequential step ``s`` (grid order is group-major) maps to group
    ``s // n_tiles``, cap tile ``s % n_tiles``; its transfer is one
    ``(tile_n, M//2)`` slice of the probed list, landed in scratch slot
    ``s % 2`` with semaphore ``s % 2`` — the two-buffer pipeline's rotation.
    ``valid`` clamps ``s`` (the pipeline probes one step past the end).
    """
    def start(s, slot):
        lid = probe_ref[s // n_tiles]
        pltpu.make_async_copy(
            codes_hbm.at[lid, pl.ds((s % n_tiles) * tile_n, tile_n), :],
            scratch.at[slot], sem.at[slot]).start()

    def wait(s, slot):
        lid = probe_ref[s // n_tiles]
        pltpu.make_async_copy(
            codes_hbm.at[lid, pl.ds((s % n_tiles) * tile_n, tile_n), :],
            scratch.at[slot], sem.at[slot]).wait()

    def valid(s):
        return probe_ref[jnp.minimum(s, total - 1) // n_tiles] >= 0

    return start, wait, valid


def _stream_grouped_kernel(probe_ref, table_ref, codes_hbm, out_ref,
                           scratch, sem, *, tile_n: int, n_tiles: int,
                           g: int):
    """One (query, probe) group x one cap tile, codes DMA'd from HBM in place.

    probe_ref: (G,) i32 scalar-prefetched flat probe ids (-1 = no probe)
    table_ref: (1, M, 16) u8 block — this group's LUT (VMEM)
    codes_hbm: (nlist, cap, M//2) u8, memory space ANY — the ListStore,
               untouched; only the probed tile ever crosses into VMEM
    out_ref:   (1, tile_n) i32 block
    scratch:   (2, tile_n, M//2) u8 VMEM — double-buffered DMA landing pads
    sem:       (2,) DMA semaphores, one per scratch slot

    Grid steps run group-major and sequentially; ``double_buffered_dma``
    keeps tile t+1's copy in flight (possibly for the *next* group) while
    tile t is scanned, hiding the HBM latency the one-DMA-per-step version
    exposed.
    """
    gi = pl.program_id(0)
    ni = pl.program_id(1)
    step = gi * n_tiles + ni
    lid = probe_ref[gi]

    start, wait, valid = _stream_dma_plan(
        probe_ref, codes_hbm, scratch, sem,
        tile_n=tile_n, n_tiles=n_tiles, total=g * n_tiles)
    double_buffered_dma(step, g * n_tiles, start, wait, valid)

    @pl.when(lid >= 0)
    def _scan():
        codes = _unpack_nibbles_i32(scratch[step % 2])  # (tn, M)
        t = table_ref[0].astype(jnp.int32)              # (M, 16)
        out_ref[...] = _select_tree_acc(t, codes)[None, :]

    @pl.when(lid < 0)
    def _skip():  # no DMA, no scan: invalid probes cost nothing
        out_ref[...] = jnp.zeros_like(out_ref)


def fastscan_stream_grouped(table_q8: jax.Array, list_codes: jax.Array,
                            probe_ids: jax.Array, *, tile_n: int = TILE_N,
                            interpret: bool = True) -> jax.Array:
    """Gather-free grouped ADC: (G, M, 16) u8 LUTs x (nlist, cap, M//2) u8
    codes *in place* + (G,) i32 probe ids -> (G, cap) i32.

    Semantically ``fastscan_select_tree_grouped(table, codes[probe_ids])``
    without the gathered copy ever existing: a PrefetchScalarGridSpec makes
    ``probe_ids`` available before the grid runs, and each (group, cap-tile)
    step DMAs only that probed list's tile from HBM into a VMEM scratch.
    Invalid probes (id -1) skip the DMA entirely and emit zeros (their
    output is id-masked downstream, like gathered padding). cap must be a
    ``tile_n`` multiple — the store is scanned in place, never padded.
    """
    g, m, k = table_q8.shape
    nlist, cap, mh = list_codes.shape
    assert k == 16 and mh * 2 == m and probe_ids.shape == (g,)
    assert cap % tile_n == 0, (cap, tile_n)
    n_tiles = cap // tile_n
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(g, n_tiles),
        in_specs=[
            pl.BlockSpec((1, m, 16), lambda gi, ni, pr: (gi, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda gi, ni, pr: (gi, ni)),
        scratch_shapes=[
            pltpu.VMEM((2, tile_n, mh), jnp.uint8),
            pltpu.SemaphoreType.DMA((2,)),
        ],
    )
    kernel = functools.partial(_stream_grouped_kernel, tile_n=tile_n,
                               n_tiles=n_tiles, g=g)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, cap), jnp.int32),
        interpret=interpret,
    )(probe_ids, table_q8, list_codes)


def _tile_topk(acc: jax.Array, slot_base: jax.Array, kc: int
               ) -> tuple[jax.Array, jax.Array]:
    """Smallest kc of acc (1, tn) i32 by iterative min-extraction, in VMEM.

    Entries equal to ACC_SENTINEL are treated as absent. Returns
    (vals (1, kc) i32 ascending, slots (1, kc) i32 global slot ids, -1 where
    fewer than kc real entries exist). Ties resolve to the lowest slot
    (argmin takes the first occurrence), matching ``masked_topk``'s
    lowest-flat-index tie-break on the full array.
    """
    tn = acc.shape[-1]
    iota_n = jax.lax.broadcasted_iota(jnp.int32, (1, tn), 1)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, kc), 1)

    def body(j, carry):
        a, vals, slots = carry
        mn = jnp.min(a, axis=-1, keepdims=True)                    # (1, 1)
        am = jnp.argmin(a, axis=-1).astype(jnp.int32)[:, None]     # (1, 1)
        vals = jnp.where(iota_k == j, mn, vals)
        slots = jnp.where(iota_k == j, am, slots)
        a = jnp.where(iota_n == am, ACC_SENTINEL, a)
        return a, vals, slots

    init = (acc,
            jnp.full((1, kc), ACC_SENTINEL, jnp.int32),
            jnp.zeros((1, kc), jnp.int32))
    _, vals, slots = jax.lax.fori_loop(0, kc, body, init)
    slots = jnp.where(vals == ACC_SENTINEL, -1, slots + slot_base)
    return vals, slots


def _stream_topk_kernel(probe_ref, sizes_ref, table_ref, *rest,
                        tile_n: int, kc: int, n_tiles: int, g: int,
                        has_filter: bool):
    """Stream kernel + fused per-tile candidate selection (+ optional
    per-row predicate mask).

    Outputs per (group, cap-tile): the kc smallest quantized dists and their
    global slot ids within the list (-1 = absent). Slots past the list's
    true occupancy (``sizes_ref``) are masked to ACC_SENTINEL *before* the
    selection, so padding can never displace a real candidate. With
    ``has_filter`` the group's packed filter-bitmap row (``fbits_ref``,
    (1, W) u8, LSB-first — see core/lists.py) rides into VMEM next to the
    LUT; the tile's bits are unpacked in registers and rows whose bit is 0
    are masked to ACC_SENTINEL through the *same* pre-selection path as the
    occupancy mask — a filtered row is indistinguishable from padding, so
    the fused selection stays bit-identical to a post-filtered oracle. Same
    double-buffered DMA pipeline as ``_stream_grouped_kernel``: tile t+1's
    copy overlaps tile t's scan+selection.
    """
    if has_filter:
        fbits_ref, codes_hbm, vals_ref, slots_ref, scratch, sem = rest
    else:
        codes_hbm, vals_ref, slots_ref, scratch, sem = rest
        fbits_ref = None
    gi = pl.program_id(0)
    ni = pl.program_id(1)
    step = gi * n_tiles + ni
    lid = probe_ref[gi]

    start, wait, valid = _stream_dma_plan(
        probe_ref, codes_hbm, scratch, sem,
        tile_n=tile_n, n_tiles=n_tiles, total=g * n_tiles)
    double_buffered_dma(step, g * n_tiles, start, wait, valid)

    @pl.when(lid >= 0)
    def _scan():
        codes = _unpack_nibbles_i32(scratch[step % 2])  # (tn, M)
        t = table_ref[0].astype(jnp.int32)
        acc = _select_tree_acc(t, codes)[None, :]  # (1, tn)
        slot = (jax.lax.broadcasted_iota(jnp.int32, (1, tile_n), 1)
                + ni * tile_n)
        acc = jnp.where(slot < sizes_ref[lid], acc, ACC_SENTINEL)
        if fbits_ref is not None:
            # unpack this group's bitmap row (1, W) -> (1, W*8) bits with
            # the same stack+reshape idiom as the nibble unpack (LSB-first
            # bit j of word w = slot w*8 + j), then slice this tile's span.
            # W*8 >= cap >= (ni+1)*tile_n, so the slice never runs off the
            # end; excluded rows join the occupancy padding at ACC_SENTINEL.
            fb = fbits_ref[...].astype(jnp.int32)  # (1, W)
            bits = jnp.stack([(fb >> j) & 1 for j in range(8)],
                             axis=-1).reshape(1, -1)
            tile_bits = jax.lax.dynamic_slice(
                bits, (0, ni * tile_n), (1, tile_n))
            acc = jnp.where(tile_bits > 0, acc, ACC_SENTINEL)
        vals, slots = _tile_topk(acc, ni * tile_n, kc)
        vals_ref[...] = vals[:, None, :]
        slots_ref[...] = slots[:, None, :]

    @pl.when(lid < 0)
    def _skip():
        vals_ref[...] = jnp.full_like(vals_ref, ACC_SENTINEL)
        slots_ref[...] = jnp.full_like(slots_ref, -1)


def _merge_smallest(cat: jax.Array, kc: int) -> jax.Array:
    """Smallest kc of cat (1, W) f32 ascending, +inf = absent. Same iterative
    min-extraction as ``_tile_topk`` but in the dequantized f32 domain the
    early-exit threshold lives in."""
    w = cat.shape[-1]
    iota_n = jax.lax.broadcasted_iota(jnp.int32, (1, w), 1)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (1, kc), 1)

    def body(j, carry):
        a, vals = carry
        mn = jnp.min(a, axis=-1, keepdims=True)
        am = jnp.argmin(a, axis=-1).astype(jnp.int32)[:, None]
        vals = jnp.where(iota_k == j, mn, vals)
        a = jnp.where(iota_n == am, jnp.float32(jnp.inf), a)
        return a, vals

    init = (cat, jnp.full((1, kc), jnp.inf, jnp.float32))
    _, vals = jax.lax.fori_loop(0, kc, body, init)
    return vals


def _stream_topk_prune_kernel(probe_ref, sizes_ref, table_ref, bounds_ref,
                              scales_ref, biases_ref, *rest, tile_n: int,
                              kc: int, n_tiles: int, g: int, gpq: int,
                              has_filter: bool):
    """Early-exit variant of ``_stream_topk_kernel``: anytime tile pruning.

    Extra operands (all (G,), SMEM — read as scalars, never tiled):
      bounds_ref  f32 — per-group lower bound on any candidate's dequantized
                  distance (``scale * sum_m min_j LUT[m, j] + bias``), the
                  min possible ADC sum made comparable across a query's
                  probes. Admissible by construction: the per-subquantizer
                  minimum undercuts every real code, and ``a*x + b`` with
                  ``a >= 0`` is monotone under f32 rounding.
      scales_ref / biases_ref — the group's dequantization affine, the SAME
                  expression downstream selection applies to the emitted
                  quantized vals, so in-kernel threshold comparisons agree
                  bitwise with the host-side ordering.

    Extra scratch: ``run_ref`` (1, kc) f32 VMEM — running top-kc dequantized
    distances of the *current query* (groups arrive query-major, ``gpq``
    groups per query); ``thr_ref`` (1,) f32 SMEM — mirror of the running
    kc-th best for scalar reads inside the DMA gate; ``latch_ref`` (2,) i32
    SMEM — per-slot copy-issued flags for ``double_buffered_dma_gated``.

    A tile is skipped when its group's bound can't beat the running kc-th
    best: every candidate it could emit is >= bound >= threshold, and the
    running set already holds kc candidates from earlier flat positions, so
    with downstream's lowest-index tie-break the final top-kc is unchanged
    (bit-identical for kc == keep). The decision is taken twice: once at
    DMA-issue time through the latched gate (saving the copy itself — the
    threshold only tightens afterwards, so a stale verdict is conservative),
    and once fresh at compute time (saving the scan for tiles whose copy was
    issued under a looser threshold). Tiles of the *next* query are always
    copied — their query's threshold doesn't exist yet.

    Third output ``skip_ref`` (1, 1) i32: 1 iff this (group, tile) held a
    valid probe but was pruned (its emitted candidates are sentinels).
    """
    if has_filter:
        (fbits_ref, codes_hbm, vals_ref, slots_ref, skip_ref,
         scratch, sem, run_ref, thr_ref, latch_ref) = rest
    else:
        (codes_hbm, vals_ref, slots_ref, skip_ref,
         scratch, sem, run_ref, thr_ref, latch_ref) = rest
        fbits_ref = None
    gi = pl.program_id(0)
    ni = pl.program_id(1)
    step = gi * n_tiles + ni
    lid = probe_ref[gi]
    total = g * n_tiles
    qspan = gpq * n_tiles  # sequential steps belonging to one query

    @pl.when(step % qspan == 0)
    def _reset():  # first tile of a new query: no candidates seen yet
        run_ref[...] = jnp.full_like(run_ref, jnp.inf)
        thr_ref[0] = jnp.float32(jnp.inf)

    start, wait, _ = _stream_dma_plan(
        probe_ref, codes_hbm, scratch, sem,
        tile_n=tile_n, n_tiles=n_tiles, total=total)

    def want(s):
        sc = jnp.minimum(s, total - 1)
        gq = sc // n_tiles
        ok = probe_ref[gq] >= 0
        same_q = (gq // gpq) == (gi // gpq)
        survives = bounds_ref[gq] < thr_ref[0]
        return ok & (survives | ~same_q)

    double_buffered_dma_gated(step, total, start, wait, want, latch_ref)

    landed = latch_ref[step % 2] != 0
    do_scan = landed & (bounds_ref[gi] < thr_ref[0])  # fresh re-check

    @pl.when(do_scan)
    def _scan():
        codes = _unpack_nibbles_i32(scratch[step % 2])  # (tn, M)
        t = table_ref[0].astype(jnp.int32)
        acc = _select_tree_acc(t, codes)[None, :]  # (1, tn)
        slot = (jax.lax.broadcasted_iota(jnp.int32, (1, tile_n), 1)
                + ni * tile_n)
        acc = jnp.where(slot < sizes_ref[lid], acc, ACC_SENTINEL)
        if fbits_ref is not None:
            fb = fbits_ref[...].astype(jnp.int32)  # (1, W)
            bits = jnp.stack([(fb >> j) & 1 for j in range(8)],
                             axis=-1).reshape(1, -1)
            tile_bits = jax.lax.dynamic_slice(
                bits, (0, ni * tile_n), (1, tile_n))
            acc = jnp.where(tile_bits > 0, acc, ACC_SENTINEL)
        vals, slots = _tile_topk(acc, ni * tile_n, kc)
        vals_ref[...] = vals[:, None, :]
        slots_ref[...] = slots[:, None, :]
        skip_ref[...] = jnp.zeros_like(skip_ref)
        # fold this tile's candidates into the query's running top-kc and
        # tighten the threshold (the same affine downstream applies)
        d = scales_ref[gi] * vals.astype(jnp.float32) + biases_ref[gi]
        d = jnp.where(slots < 0, jnp.float32(jnp.inf), d)
        merged = _merge_smallest(
            jnp.concatenate([run_ref[...], d], axis=-1), kc)
        run_ref[...] = merged
        thr_ref[0] = merged[0, kc - 1]

    @pl.when(~do_scan)
    def _skip():  # invalid probe, or a tile the bound proved irrelevant
        vals_ref[...] = jnp.full_like(vals_ref, ACC_SENTINEL)
        slots_ref[...] = jnp.full_like(slots_ref, -1)
        skip_ref[...] = jnp.full_like(skip_ref, (lid >= 0).astype(jnp.int32))


def fastscan_stream_topk_grouped(table_q8: jax.Array, list_codes: jax.Array,
                                 probe_ids: jax.Array, sizes: jax.Array, *,
                                 kc: int, tile_n: int = TILE_N,
                                 filter_bits: jax.Array | None = None,
                                 interpret: bool = True,
                                 early_exit: bool = False,
                                 groups_per_query: int = 0,
                                 scales: jax.Array | None = None,
                                 biases: jax.Array | None = None
                                 ) -> tuple[jax.Array, ...]:
    """Gather-free grouped ADC with fused candidate reduction + filtering.

    table_q8 (G, M, 16) u8; list_codes (nlist, cap, M//2) u8 in place;
    probe_ids (G,) i32 (-1 = no probe); sizes (nlist,) i32 true occupancy;
    filter_bits optional (G, W) u8 — each group's *pre-gathered* packed
    filter-bitmap row (W = ceil(cap/8), LSB-first; callers gather
    ``bitmap[max(probe_ids, 0)]`` — ~W bytes/group next to cap*M//2 code
    bytes, so the extra VMEM traffic is ~1.5% at M=16).
    Returns (vals (G, n_tiles, kc) i32, slots (G, n_tiles, kc) i32): per
    (group, cap-tile) the kc smallest quantized distances and their slot
    position inside the probed list, -1 slot = absent (padding past the
    list's occupancy, a filtered-out row, or an invalid probe — whose DMA
    is skipped outright).

    The full (G, cap) accumulation never reaches HBM: selection happens in
    VMEM on the tile the DMA just landed, so scan-stage writeback shrinks
    by ~cap/kc. Keeping the per-tile top-kc is exact for any final
    selection of <= kc candidates (every survivor is within its own tile's
    top-kc), with ties resolved identically to ``masked_topk`` over the
    full array (lowest slot wins) — and the predicate mask joins the
    occupancy mask *before* selection, so the filtered result is
    bit-identical to filtering the full accumulation after the fact.

    With ``early_exit`` (anytime search, docs/anytime.md) the kernel also
    prunes tiles whose group-level lower bound on any dequantized distance
    can't beat the query's running kc-th best — skipping the tile's scan
    and, when the verdict lands before the copy is issued, its DMA. Requires
    ``groups_per_query`` (consecutive groups per query, > 0, dividing G) and
    the per-group dequantization affine ``scales``/``biases`` ((G,) f32,
    exactly what downstream selection applies). Returns a third array
    ``skipped`` (G, n_tiles) i32, 1 per pruned valid-probe tile. The final
    top-kc per query is bit-identical to the unpruned kernel; the raw
    candidate pool is not (pruned tiles emit sentinels).
    """
    g, m, k = table_q8.shape
    nlist, cap, mh = list_codes.shape
    assert k == 16 and mh * 2 == m and probe_ids.shape == (g,)
    assert sizes.shape == (nlist,)
    assert cap % tile_n == 0, (cap, tile_n)
    assert 1 <= kc <= tile_n, (kc, tile_n)
    n_tiles = cap // tile_n
    in_specs = [
        pl.BlockSpec((1, m, 16), lambda gi, ni, pr, sz: (gi, 0, 0)),
    ]
    operands = [probe_ids, sizes, table_q8]
    if early_exit:
        assert groups_per_query > 0 and g % groups_per_query == 0, (
            g, groups_per_query)
        assert scales is not None and biases is not None
        assert scales.shape == (g,) and biases.shape == (g,), (
            scales.shape, biases.shape, g)
        scales = scales.astype(jnp.float32)
        biases = biases.astype(jnp.float32)
        # Admissible per-group lower bound: the min possible ADC sum (each
        # subquantizer contributes its smallest LUT entry), dequantized with
        # the group's own affine so it is comparable across a query's probes.
        acc_min = jnp.sum(jnp.min(table_q8.astype(jnp.int32), axis=-1),
                          axis=-1)  # (G,)
        bounds = scales * acc_min.astype(jnp.float32) + biases
        for arr in (bounds, scales, biases):
            in_specs.append(pl.BlockSpec(memory_space=pltpu.SMEM))
            operands.append(arr)
    if filter_bits is not None:
        w = filter_bits.shape[-1]
        assert filter_bits.shape == (g, w) and w * 8 >= cap, (
            filter_bits.shape, g, cap)
        in_specs.append(pl.BlockSpec((1, w), lambda gi, ni, pr, sz: (gi, 0)))
        operands.append(filter_bits)
    in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))
    operands.append(list_codes)
    out_specs = [
        pl.BlockSpec((1, 1, kc), lambda gi, ni, pr, sz: (gi, ni, 0)),
        pl.BlockSpec((1, 1, kc), lambda gi, ni, pr, sz: (gi, ni, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((g, n_tiles, kc), jnp.int32),
        jax.ShapeDtypeStruct((g, n_tiles, kc), jnp.int32),
    ]
    scratch_shapes = [
        pltpu.VMEM((2, tile_n, mh), jnp.uint8),
        pltpu.SemaphoreType.DMA((2,)),
    ]
    if early_exit:
        out_specs.append(pl.BlockSpec((1, 1), lambda gi, ni, pr, sz: (gi, ni)))
        out_shape.append(jax.ShapeDtypeStruct((g, n_tiles), jnp.int32))
        scratch_shapes += [
            pltpu.VMEM((1, kc), jnp.float32),   # running top-kc (dequant)
            pltpu.SMEM((1,), jnp.float32),      # threshold mirror
            pltpu.SMEM((2,), jnp.int32),        # DMA-issued latches
        ]
        kernel = functools.partial(
            _stream_topk_prune_kernel, tile_n=tile_n, kc=kc,
            n_tiles=n_tiles, g=g, gpq=groups_per_query,
            has_filter=filter_bits is not None)
    else:
        kernel = functools.partial(_stream_topk_kernel, tile_n=tile_n, kc=kc,
                                   n_tiles=n_tiles, g=g,
                                   has_filter=filter_bits is not None)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(g, n_tiles),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch_shapes,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
