"""Pallas TPU kernels for the 4-bit PQ fast-scan ADC (paper §3, TPU-adapted).

The paper emulates AVX2's 256-bit in-register shuffle with two NEON 128-bit
``vqtbl1q_u8`` table lookups. A TPU has no cross-lane shuffle at all, so we
re-express the register-resident 16-entry LUT gather in the units the TPU
*does* have, keeping the LUT pinned in VMEM/VREGs (the TPU analogue of the
SIMD register file):

Variant A — ``select-tree`` (VPU, paper-faithful analogue):
    A 16-way LUT lookup is decomposed into log2(16) = 4 levels of 2-way
    vector selects over statically-sliced halves of the LUT, exactly as the
    paper decomposes one 256-bit shuffle into two 128-bit shuffles. All
    operands live in vector registers; the only memory traffic is the code
    tile stream.

Variant B — ``one-hot MXU`` (beyond-paper):
    The ADC gather for a *batch* of queries is algebraically a matmul:
    ``acc[q, n] = T_flat[q] . onehot(codes[n])`` with ``T_flat`` the stacked
    (M*16) LUT. On TPU the systolic MXU is the throughput unit, so we convert
    the gather into a dense bf16 GEMM. Exactness: all u8 LUT entries (0..255)
    and one-hot 0/1 are exactly representable in bf16; products and the f32
    accumulation of <= M*16 terms (<= 32640 for M <= 128) are exact in f32,
    so the result is still bit-identical to the int oracle.

    Both the flat (shared database, ``fastscan_onehot_mxu``) and the grouped
    (gathered IVF lists, ``fastscan_onehot_mxu_grouped``) scans have MXU
    forms; the grouped one is the serving hot path (``core.ivf.scan_probes``)
    where each (query, probe) pair owns its own residual LUT.

Variant C — ``fused block-min``: variant B plus an in-kernel per-tile
    min/argmin reduction, the TPU stand-in for faiss' SIMD top-k candidate
    filtering via ``_mm256_movemask_epi8`` (which has no Pallas equivalent).

All kernels are tiled with explicit BlockSpecs. Codes arrive nibble-packed
``(N, M//2) u8`` — one VMEM tile feeds every variant with lane-contiguous
access (the TPU adaptation of the paper's interleaved register layout).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. Lane dim multiples of 128, sublane multiples of 8
# (f32/i32 VREG tile is 8x128). N tile of 1024 keeps the code tile
# (1024 x M/2 u8) well under VMEM while amortizing LUT residency.
TILE_N = 1024
TILE_Q = 128


def _unpack_nibbles_i32(packed_u8: jax.Array) -> jax.Array:
    """(tn, M//2) u8 -> (tn, M) i32; lo nibble = even sub-space."""
    p = packed_u8.astype(jnp.int32)
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    # interleave: out[:, 0::2] = lo, out[:, 1::2] = hi, without scatter
    # (tn, mh) -> (tn, mh, 2) -> (tn, m)
    return jnp.stack([lo, hi], axis=-1).reshape(p.shape[0], -1)


# ---------------------------------------------------------------------------
# Variant A: select-tree (VPU)
# ---------------------------------------------------------------------------

def _select_tree_acc(t: jax.Array, codes: jax.Array) -> jax.Array:
    """Select-tree ADC accumulation: t (M, 16) i32 LUT x codes (tn, M) i32
    -> (tn,) i32 sums.

    A 16-way LUT lookup decomposed into log2(16) = 4 levels of 2-way vector
    selects (the paper's 256-bit shuffle via 2x128-bit shuffles, one level
    deeper on TPU)."""
    b0 = (codes & 1).astype(jnp.bool_)
    b1 = (codes & 2).astype(jnp.bool_)
    b2 = (codes & 4).astype(jnp.bool_)
    b3 = (codes & 8).astype(jnp.bool_)

    lo8 = t[None, :, 0:8]   # (1, M, 8) broadcast over the N tile
    hi8 = t[None, :, 8:16]
    s3 = jnp.where(b3[:, :, None], hi8, lo8)          # (tn, M, 8)
    s2 = jnp.where(b2[:, :, None], s3[..., 4:8], s3[..., 0:4])  # (tn, M, 4)
    s1 = jnp.where(b1[:, :, None], s2[..., 2:4], s2[..., 0:2])  # (tn, M, 2)
    s0 = jnp.where(b0, s1[..., 1], s1[..., 0])        # (tn, M)
    return jnp.sum(s0, axis=-1, dtype=jnp.int32)


def _select_tree_kernel(table_ref, codes_ref, out_ref):
    """One query row x one N tile.

    table_ref: (1, M, 16) u8 block  — the register-resident LUT
    codes_ref: (tn, M//2) u8 block  — nibble-packed codes
    out_ref:   (1, tn) i32 block
    """
    codes = _unpack_nibbles_i32(codes_ref[...])  # (tn, M)
    t = table_ref[0].astype(jnp.int32)  # (M, 16)
    out_ref[...] = _select_tree_acc(t, codes)[None, :]


def fastscan_select_tree(table_q8: jax.Array, packed_codes: jax.Array, *,
                         tile_n: int = TILE_N, interpret: bool = True) -> jax.Array:
    """(Q, M, 16) u8 x (N, M//2) u8 -> (Q, N) i32. Q and N pre-padded."""
    q, m, k = table_q8.shape
    n, mh = packed_codes.shape
    assert k == 16 and mh * 2 == m and n % tile_n == 0
    grid = (q, n // tile_n)
    return pl.pallas_call(
        _select_tree_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m, 16), lambda qi, ni: (qi, 0, 0)),
            pl.BlockSpec((tile_n, mh), lambda qi, ni: (ni, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda qi, ni: (qi, ni)),
        out_shape=jax.ShapeDtypeStruct((q, n), jnp.int32),
        interpret=interpret,
    )(table_q8, packed_codes)


def _select_tree_grouped_kernel(table_ref, codes_ref, out_ref):
    """One (query, probe) group x one N tile — each group has its OWN LUT
    *and* its own code tile (gathered IVF lists), unlike the shared-database
    variant above.

    table_ref: (1, M, 16) u8 block; codes_ref: (1, tn, M//2) u8 block;
    out_ref: (1, tn) i32 block.
    """
    codes = _unpack_nibbles_i32(codes_ref[0])  # (tn, M)
    t = table_ref[0].astype(jnp.int32)  # (M, 16)
    out_ref[...] = _select_tree_acc(t, codes)[None, :]


def fastscan_select_tree_grouped(table_q8: jax.Array, packed_codes: jax.Array, *,
                                 tile_n: int = TILE_N, interpret: bool = True
                                 ) -> jax.Array:
    """Grouped ADC: (G, M, 16) u8 x (G, N, M//2) u8 -> (G, N) i32.

    The IVF 'memory path' made register-resident: group g = one
    (query, probed-list) pair whose residual LUT scans only that list's code
    tile. N (the padded list capacity) must be a tile_n multiple.
    """
    g, m, k = table_q8.shape
    gc, n, mh = packed_codes.shape
    assert k == 16 and mh * 2 == m and gc == g and n % tile_n == 0
    grid = (g, n // tile_n)
    return pl.pallas_call(
        _select_tree_grouped_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m, 16), lambda gi, ni: (gi, 0, 0)),
            pl.BlockSpec((1, tile_n, mh), lambda gi, ni: (gi, ni, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda gi, ni: (gi, ni)),
        out_shape=jax.ShapeDtypeStruct((g, n), jnp.int32),
        interpret=interpret,
    )(table_q8, packed_codes)


# ---------------------------------------------------------------------------
# Variant B: one-hot MXU
# ---------------------------------------------------------------------------

def _onehot_mxu_kernel(table_ref, codes_ref, out_ref):
    """table_ref: (tq, M*16) u8; codes_ref: (tn, M//2) u8; out_ref: (tq, tn) i32."""
    codes = _unpack_nibbles_i32(codes_ref[...])  # (tn, M)
    tn, m = codes.shape
    # one-hot on the VPU: (tn, M, 16) -> (tn, M*16), bf16 so the MXU eats it
    iota = jax.lax.broadcasted_iota(jnp.int32, (tn, m, 16), dimension=2)
    onehot = (codes[:, :, None] == iota).astype(jnp.bfloat16).reshape(tn, m * 16)
    t = table_ref[...].astype(jnp.bfloat16)  # (tq, M*16)
    # (tq, M16) x (M16, tn) -> (tq, tn) on the MXU, f32 accumulation (exact)
    acc = jax.lax.dot_general(
        t, onehot,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] = acc.astype(jnp.int32)


def fastscan_onehot_mxu(table_q8: jax.Array, packed_codes: jax.Array, *,
                        tile_n: int = TILE_N, tile_q: int = TILE_Q,
                        interpret: bool = True) -> jax.Array:
    """(Q, M, 16) u8 x (N, M//2) u8 -> (Q, N) i32. Q, N pre-padded to tiles."""
    q, m, k = table_q8.shape
    n, mh = packed_codes.shape
    assert k == 16 and mh * 2 == m
    assert q % tile_q == 0 and n % tile_n == 0, (q, tile_q, n, tile_n)
    t_flat = table_q8.reshape(q, m * 16)
    grid = (q // tile_q, n // tile_n)
    return pl.pallas_call(
        _onehot_mxu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, m * 16), lambda qi, ni: (qi, 0)),
            pl.BlockSpec((tile_n, mh), lambda qi, ni: (ni, 0)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_n), lambda qi, ni: (qi, ni)),
        out_shape=jax.ShapeDtypeStruct((q, n), jnp.int32),
        interpret=interpret,
    )(t_flat, packed_codes)


def _onehot_mxu_grouped_kernel(table_ref, codes_ref, out_ref):
    """One (query, probe) group x one cap tile, on the MXU.

    table_ref: (1, M*16) u8 block — this group's flattened LUT
    codes_ref: (1, tn, M//2) u8 block — this group's gathered code tile
    out_ref:   (1, tn) i32 block

    The grouped ADC gather is a per-group matvec: unpack the nibble codes to
    one-hot (tn, M, 16) planes, flatten to (tn, M*16) bf16, and contract
    against the group's own (1, M*16) LUT row on the MXU with f32
    accumulation. Exactness argument is identical to the flat variant above
    (u8 and 0/1 exact in bf16; <= M*16 f32 summands exact).
    """
    codes = _unpack_nibbles_i32(codes_ref[0])  # (tn, M)
    tn, m = codes.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (tn, m, 16), dimension=2)
    onehot = (codes[:, :, None] == iota).astype(jnp.bfloat16).reshape(tn, m * 16)
    t = table_ref[...].astype(jnp.bfloat16)  # (1, M*16)
    acc = jax.lax.dot_general(
        t, onehot,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (1, tn)
    out_ref[...] = acc.astype(jnp.int32)


def fastscan_onehot_mxu_grouped(table_q8: jax.Array, packed_codes: jax.Array, *,
                                tile_n: int = TILE_N, interpret: bool = True
                                ) -> jax.Array:
    """Grouped one-hot MXU ADC: (G, M, 16) u8 x (G, cap, M//2) u8 -> (G, cap) i32.

    The MXU formulation of the gathered-list scan — the path every real IVF
    search takes (``scan_probes``). Group g = one (query, probed-list) pair
    with its OWN residual LUT and its own gathered code tile; the grid runs
    over (group, cap tile) and each program does one LUT-row x one-hot-codes
    contraction on the MXU. cap must be a tile_n multiple (pre-padded).
    Bit-identical to the ref/select formulations.
    """
    g, m, k = table_q8.shape
    gc, n, mh = packed_codes.shape
    assert k == 16 and mh * 2 == m and gc == g and n % tile_n == 0
    t_flat = table_q8.reshape(g, m * 16)
    grid = (g, n // tile_n)
    return pl.pallas_call(
        _onehot_mxu_grouped_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m * 16), lambda gi, ni: (gi, 0)),
            pl.BlockSpec((1, tile_n, mh), lambda gi, ni: (gi, ni, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_n), lambda gi, ni: (gi, ni)),
        out_shape=jax.ShapeDtypeStruct((g, n), jnp.int32),
        interpret=interpret,
    )(t_flat, packed_codes)


# ---------------------------------------------------------------------------
# Variant C: fused scan + per-tile min/argmin (top-1 candidate filter)
# ---------------------------------------------------------------------------

def _blockmin_kernel(table_ref, codes_ref, min_ref, arg_ref, *, tile_n: int):
    codes = _unpack_nibbles_i32(codes_ref[...])  # (tn, M)
    tn, m = codes.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (tn, m, 16), dimension=2)
    onehot = (codes[:, :, None] == iota).astype(jnp.bfloat16).reshape(tn, m * 16)
    t = table_ref[...].astype(jnp.bfloat16)
    acc = jax.lax.dot_general(
        t, onehot, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32).astype(jnp.int32)  # (tq, tn)
    ni = pl.program_id(1)
    # in-register reduction: the movemask/top-k filter analogue
    min_ref[...] = jnp.min(acc, axis=-1, keepdims=True)
    local_arg = jnp.argmin(acc, axis=-1).astype(jnp.int32)
    arg_ref[...] = (local_arg + ni * tile_n)[:, None]


def fastscan_blockmin(table_q8: jax.Array, packed_codes: jax.Array, *,
                      tile_n: int = TILE_N, tile_q: int = TILE_Q,
                      interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused ADC + per-N-tile min: (Q, N/tile_n) i32 mins and global argmin ids."""
    q, m, k = table_q8.shape
    n, mh = packed_codes.shape
    assert k == 16 and mh * 2 == m
    assert q % tile_q == 0 and n % tile_n == 0
    t_flat = table_q8.reshape(q, m * 16)
    grid = (q // tile_q, n // tile_n)
    kernel = functools.partial(_blockmin_kernel, tile_n=tile_n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, m * 16), lambda qi, ni: (qi, 0)),
            pl.BlockSpec((tile_n, mh), lambda qi, ni: (ni, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q, 1), lambda qi, ni: (qi, ni)),
            pl.BlockSpec((tile_q, 1), lambda qi, ni: (qi, ni)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, n // tile_n), jnp.int32),
            jax.ShapeDtypeStruct((q, n // tile_n), jnp.int32),
        ],
        interpret=interpret,
    )(t_flat, packed_codes)
