"""Padded inverted-list storage: the reusable half of the IVF index.

Extracted from ``core/ivf.py`` so that any component — the IVF index, the
unified ``repro.engine`` search pipeline, shard-parallel serving — can own,
gather, and slice posting lists without going through IVF-specific code.

Layout (TPU rule: every shape static, no raggedness):
  codes: (nlist, cap, M//2) uint8   nibble-packed PQ codes, zero-padded
  ids:   (nlist, cap)       int32   global vector ids, -1 = padding
  sizes: (nlist,)           int32   true occupancy per list (<= cap)
  attrs: (nlist, cap)       int32   optional per-row metadata attribute,
                                    -1 = padding (None when unused)

Bucketing is host-side numpy (index build is offline); ``gather`` is pure
jnp and lowers under jit/pjit.

Filter bitmaps (docs/filtering.md): a predicate over the rows is carried as
a *packed* bitmap ``(nlist, W) u8`` with ``W = ceil(cap / 8)``, bit ``j`` of
word ``w`` = slot ``w*8 + j`` (LSB-first), 1 = the row passes. Packing keeps
the filter at ~1.5% of the code bytes at M=16, so streaming it next to the
codes costs almost nothing (``pack_filter_mask`` / ``unpack_filter_mask`` /
``filter_from_attrs`` / ``filter_pass_sizes`` below). The bitmap is sliced
and permuted exactly like the codes by ``partition_lists`` /
``partition_filter``, so it stays epoch-consistent with the codes on every
shard.

Conventions (shared across ``repro.core``, see docs/architecture.md):
  shapes  all static — every list padded to ``cap``; gathers preserve the
          leading probe-set shape; filter bitmaps padded to W words
  dtypes  packed codes uint8; ids/sizes/attrs int32; filter bitmaps uint8
          (LSB-first within each word)
  -1 id   sentinel — a padded list slot or an invalid (negative) probe id
          gathers to id -1; code bytes at padded slots are zero and must be
          masked by the id, never interpreted; attrs at padded slots are -1
  filter  bit 0 = row excluded (scans treat the slot exactly like padding:
          id -1, distance +inf / ACC_SENTINEL before selection); bits at
          padded slots must be 0 (``filter_from_attrs`` guarantees it)
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class ListStore(NamedTuple):
    codes: jax.Array  # (nlist, cap, M//2) uint8
    ids: jax.Array    # (nlist, cap) int32, -1 = padding
    sizes: jax.Array  # (nlist,) int32
    # optional per-row metadata column (filtering contract, docs/filtering.md):
    # one i32 attribute per slot, -1 at padding. None = no attributes — the
    # field vanishes from the pytree, so vmap/shard_map arities are unchanged.
    attrs: jax.Array | None = None

    @property
    def nlist(self) -> int:
        return self.ids.shape[0]

    @property
    def cap(self) -> int:
        return self.ids.shape[1]

    def gather(self, probe_ids: jax.Array) -> tuple[jax.Array, jax.Array]:
        """Gather probed lists: probe_ids (..., P) -> codes (..., P, cap, M//2),
        ids (..., P, cap). Negative probe ids yield fully-padded lists: ids
        all -1 AND codes all zero (the early mask) — without it an invalid
        probe hands list 0's real codes to the scan, which then does work
        that ``probed_sizes`` (and ``QueryStats.codes_scanned``) never
        counted, and which the gather-free stream kernel — skipping the DMA
        outright — would disagree with."""
        valid = probe_ids >= 0
        safe = jnp.maximum(probe_ids, 0)
        codes = jnp.where(valid[..., None, None], self.codes[safe], 0)
        ids = jnp.where(valid[..., None], self.ids[safe], -1)
        return codes, ids

    def gather_ids(self, probe_ids: jax.Array) -> jax.Array:
        """ids of ``gather`` alone: probe_ids (..., P) -> (..., P, cap) i32.

        The gather-free scan path (``core.ivf.scan_probes`` with
        impl='stream') reads codes in place and only needs this — the
        (..., P, cap, M//2) code copy never exists."""
        return jnp.where((probe_ids >= 0)[..., None],
                         self.ids[jnp.maximum(probe_ids, 0)], -1)

    def probed_sizes(self, probe_ids: jax.Array) -> jax.Array:
        """True occupancy of each probed list (0 for invalid probes)."""
        return jnp.where(probe_ids >= 0, self.sizes[jnp.maximum(probe_ids, 0)], 0)


@jax.jit
def base_norms(base: jax.Array) -> jax.Array:
    """Per-row squared norms ``‖x‖²`` of the base vectors: (N, D) -> (N,) f32.

    Precomputed once at engine construction (and per shard by
    ``partition_base``) so the exact re-rank stage can use the norms+GEMM
    distance formulation ``(‖q‖² − 2·q·x) + ‖x‖²`` without touching the
    rows twice — the streaming re-rank kernel gathers only these scalars
    up front and DMAs the rows themselves in place. The mul + ``axis=-1``
    sum here is the exact expression ``rerank_kernel.norms_gemm_dists``
    uses for ``‖q‖²``, keeping every path's rounding identical.
    """
    return jnp.sum(base * base, axis=-1)


# ---------------------------------------------------------------------------
# packed filter bitmaps (the filtering contract — docs/filtering.md)
# ---------------------------------------------------------------------------

def filter_words(cap: int) -> int:
    """Words per list of a packed filter bitmap: W = ceil(cap / 8)."""
    return -(-int(cap) // 8)


@jax.jit
def pack_filter_mask(mask: jax.Array) -> jax.Array:
    """Pack a per-slot boolean mask into the filter bitmap layout.

    mask: (..., cap) bool (1 = row passes). Returns (..., W) u8 with
    W = ceil(cap/8); bit j of word w = slot w*8 + j (LSB-first). Bits past
    ``cap`` in the last word are 0.
    """
    cap = mask.shape[-1]
    pad = (-cap) % 8
    m = mask.astype(jnp.int32)
    if pad:
        widths = [(0, 0)] * (m.ndim - 1) + [(0, pad)]
        m = jnp.pad(m, widths)
    m = m.reshape(*mask.shape[:-1], -1, 8)
    weights = (1 << jnp.arange(8, dtype=jnp.int32))
    return jnp.sum(m * weights, axis=-1).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("cap",))
def unpack_filter_mask(bits: jax.Array, cap: int) -> jax.Array:
    """Inverse of ``pack_filter_mask``: (..., W) u8 -> (..., cap) bool."""
    b = bits.astype(jnp.int32)
    u = ((b[..., None] >> jnp.arange(8, dtype=jnp.int32)) & 1)
    return u.reshape(*bits.shape[:-1], -1)[..., :cap].astype(jnp.bool_)


def filter_from_attrs(store: ListStore, predicate) -> jax.Array:
    """Evaluate a per-row predicate over ``store.attrs`` into a packed bitmap.

    predicate: elementwise fn (nlist, cap) i32 attrs -> bool (pure jnp, so
    the whole thing jits). Returns (nlist, W) u8. Padded slots (id -1) are
    forced to 0 regardless of what the predicate says about the -1 attr
    sentinel — a filter bit may only ever be set on a real row.
    """
    if store.attrs is None:
        raise ValueError("ListStore holds no attrs column; build with "
                         "build_lists(..., attrs=...)")
    return pack_filter_mask(predicate(store.attrs) & (store.ids >= 0))


@jax.jit
def filter_pass_sizes(store: ListStore, filter_bits: jax.Array) -> jax.Array:
    """Rows per list that pass the filter: (nlist, W) u8 -> (nlist,) i32.

    Occupancy-aware: bits at slots past ``sizes`` never count even if a
    stale bitmap left them set. ``sizes - filter_pass_sizes`` is the
    per-list row count a filtered scan excludes (``QueryStats.rows_filtered``
    sums this over the probed lists).
    """
    cap = store.cap
    m = unpack_filter_mask(filter_bits, cap)
    slot = jnp.arange(cap, dtype=jnp.int32)[None, :]
    return jnp.sum((m & (slot < store.sizes[:, None])).astype(jnp.int32),
                   axis=-1)


def build_lists(assign: np.ndarray, packed_codes: np.ndarray, *, nlist: int,
                cap: int | None = None, ids: np.ndarray | None = None,
                attrs: np.ndarray | None = None) -> ListStore:
    """Bucket packed codes into padded lists (host-side, offline).

    assign: (n,) list assignment per vector; packed_codes: (n, M//2) uint8;
    ids: optional global id per vector (defaults to arange — shards pass
    their own offsets); attrs: optional (n,) i32 per-vector metadata
    attribute, bucketed alongside the codes (-1 at padded slots) so filter
    bitmaps derived from it stay epoch-consistent with the codes. Overflow
    beyond ``cap`` is dropped, reflected in ``sizes`` (same semantics the
    IVF build always had).
    """
    assign = np.asarray(assign, np.int64)
    packed = np.asarray(packed_codes, np.uint8)
    n, mh = packed.shape
    gids = np.arange(n, dtype=np.int32) if ids is None else np.asarray(ids, np.int32)
    avals = None if attrs is None else np.asarray(attrs, np.int32)
    counts = np.bincount(assign, minlength=nlist)
    cap_ = int(cap or max(1, counts.max()))
    list_codes = np.zeros((nlist, cap_, mh), np.uint8)
    list_ids = np.full((nlist, cap_), -1, np.int32)
    list_attrs = None if avals is None else np.full((nlist, cap_), -1, np.int32)
    cursor = np.zeros((nlist,), np.int64)
    order = np.argsort(assign, kind="stable")
    for i in order:
        li = assign[i]
        c = cursor[li]
        if c < cap_:
            list_codes[li, c] = packed[i]
            list_ids[li, c] = gids[i]
            if list_attrs is not None:
                list_attrs[li, c] = avals[i]
            cursor[li] += 1
    return ListStore(
        codes=jnp.asarray(list_codes),
        ids=jnp.asarray(list_ids),
        sizes=jnp.asarray(np.minimum(counts, cap_).astype(np.int32)),
        attrs=None if list_attrs is None else jnp.asarray(list_attrs),
    )


# ---------------------------------------------------------------------------
# live mutation primitives (docs/mutability.md)
#
# A ListStore mutates under three invariants:
#   watermark   ``sizes[l]`` counts slots EVER written this epoch (appends go
#               at the watermark; it only moves on append or compaction)
#   tombstone   a deleted row keeps its slot: ``ids[l, s] = -1`` (and attrs
#               -1) while its stale code bytes stay in place — exactly the
#               padding convention every scan path already masks
#   live bits   ``live_filter_bits`` = the packed bitmap of rows with
#               ``id >= 0``; engines AND it into the per-request filter so
#               the stream kernels' candidate budget is spent on live rows
#               only (a tombstone inside the watermark would otherwise pass
#               the occupancy mask with its stale distance)
#
# All three are derivable from (ids, sizes) alone, so mutation helpers return
# plain new ListStores — no parallel bookkeeping structure to desync.
# ---------------------------------------------------------------------------

def locate_rows(store: ListStore) -> dict[int, tuple[int, int]]:
    """Host-side id -> (list, slot) map of every live row.

    The mutable engine's locator: built once (one device->host sync), then
    maintained incrementally by upsert/delete/compact.
    """
    ids = np.asarray(store.ids)
    ls, ss = np.nonzero(ids >= 0)
    return {int(ids[l, s]): (int(l), int(s)) for l, s in zip(ls, ss)}


def live_counts(store: ListStore) -> jax.Array:
    """(nlist,) i32 rows per list that are live (id >= 0, inside watermark)."""
    return jnp.sum((store.ids >= 0).astype(jnp.int32), axis=1)


def tombstone_counts(store: ListStore) -> jax.Array:
    """(nlist,) i32 tombstoned slots per list: watermark minus live rows."""
    return store.sizes - live_counts(store)


def live_filter_bits(store: ListStore) -> jax.Array:
    """Packed (nlist, W) u8 bitmap of live rows (``pack_filter_mask`` layout).

    Bit 1 exactly where ``ids >= 0`` — padding beyond the watermark and
    tombstones inside it are both 0, so ANDing this into any per-request
    filter makes the stream kernels treat tombstones like padding *before*
    candidate selection (the exactness condition for the mutation oracle:
    a deleted row must never occupy a per-tile candidate slot).
    """
    return pack_filter_mask(store.ids >= 0)


def grow_cap(store: ListStore, new_cap: int) -> ListStore:
    """Pad every list with spare slots: cap -> ``new_cap`` (ids -1, codes 0,
    attrs -1). Watermarks are untouched; gathers/scans behave identically
    (the new slots are past every watermark). Shape change — compiled
    pipelines re-key, and scan autotune verdicts for the old cap are stale
    (``kernels.ops.clear_autotune_cache(cap=...)``)."""
    cap = store.cap
    if new_cap < cap:
        raise ValueError(f"grow_cap: new_cap {new_cap} < current cap {cap}")
    if new_cap == cap:
        return store
    pad = new_cap - cap
    nlist = store.nlist
    return ListStore(
        codes=jnp.concatenate(
            [store.codes,
             jnp.zeros((nlist, pad, store.codes.shape[-1]), store.codes.dtype)],
            axis=1),
        ids=jnp.concatenate(
            [store.ids, jnp.full((nlist, pad), -1, store.ids.dtype)], axis=1),
        sizes=store.sizes,
        attrs=None if store.attrs is None else jnp.concatenate(
            [store.attrs, jnp.full((nlist, pad), -1, store.attrs.dtype)],
            axis=1),
    )


def tombstone_rows(store: ListStore, list_ids: np.ndarray,
                   slots: np.ndarray) -> ListStore:
    """Delete rows in place: ids/attrs at each (list, slot) become -1.

    Codes stay (masked by the id like padding); watermarks stay (the slot is
    not reusable until compaction). A pure functional update — callers swap
    the returned store atomically.
    """
    l = jnp.asarray(list_ids, jnp.int32)
    s = jnp.asarray(slots, jnp.int32)
    return store._replace(
        ids=store.ids.at[l, s].set(-1),
        attrs=None if store.attrs is None else store.attrs.at[l, s].set(-1),
    )


def append_rows(store: ListStore, list_ids: np.ndarray, packed: np.ndarray,
                gids: np.ndarray, attrs: np.ndarray | None = None
                ) -> tuple[ListStore, np.ndarray]:
    """Append rows into spare slots at each target list's watermark.

    list_ids (B,) target list per row; packed (B, M//2) u8 PQ codes;
    gids (B,) i32 global ids; attrs optional (B,) i32 (required -1-free when
    the store carries an attrs column — pass -1 explicitly to mean "no
    attribute" at your own risk: -1 is the padding sentinel).

    Returns (new store, slots (B,) the rows landed in). Raises when any
    target list lacks spare capacity (callers compact/grow first — this
    helper never drops rows the way ``build_lists`` overflow does).
    Slot assignment is deterministic: batch order within each list.
    """
    list_ids = np.asarray(list_ids, np.int64)
    packed = np.asarray(packed, np.uint8)
    gids = np.asarray(gids, np.int32)
    b = list_ids.shape[0]
    sizes = np.asarray(store.sizes, np.int64)
    # slot = watermark + rank of the row among batch rows targeting its list
    order = np.argsort(list_ids, kind="stable")
    rank = np.empty(b, np.int64)
    sorted_lists = list_ids[order]
    rank[order] = np.arange(b) - np.searchsorted(sorted_lists, sorted_lists,
                                                 side="left")
    slots = sizes[list_ids] + rank
    if b and slots.max() >= store.cap:
        full = int(list_ids[slots.argmax()])
        raise ValueError(
            f"append_rows: list {full} is out of spare capacity "
            f"(cap={store.cap}); compact or grow_cap first")
    l = jnp.asarray(list_ids, jnp.int32)
    s = jnp.asarray(slots, jnp.int32)
    counts = np.bincount(list_ids, minlength=store.nlist).astype(np.int32)
    new_attrs = store.attrs
    if new_attrs is not None:
        avals = (np.full(b, -1, np.int32) if attrs is None
                 else np.asarray(attrs, np.int32))
        new_attrs = new_attrs.at[l, s].set(jnp.asarray(avals))
    elif attrs is not None:
        raise ValueError("append_rows: attrs given but the store holds no "
                         "attrs column (build with attrs=...)")
    return store._replace(
        codes=store.codes.at[l, s].set(jnp.asarray(packed)),
        ids=store.ids.at[l, s].set(jnp.asarray(gids)),
        sizes=store.sizes + jnp.asarray(counts),
        attrs=new_attrs,
    ), slots.astype(np.int32)


def compact_lists(store: ListStore, cap: int | None = None) -> ListStore:
    """Rebuild every list without tombstones: the fresh-epoch store.

    Survivors keep their relative slot order (stable shift-down), watermarks
    become live counts, and ``cap`` may change (grow for headroom, shrink to
    fit — must cover the largest live list). Host-side numpy like
    ``build_lists`` — compaction is the offline half of mutation; the swap
    into a serving engine is what must be atomic, not the rebuild.
    """
    ids = np.asarray(store.ids)
    codes = np.asarray(store.codes)
    attrs = None if store.attrs is None else np.asarray(store.attrs)
    nlist, old_cap = ids.shape
    live = ids >= 0
    counts = live.sum(axis=1)
    new_cap = int(cap if cap is not None else old_cap)
    if new_cap < int(counts.max(initial=0)):
        raise ValueError(
            f"compact_lists: cap {new_cap} below the largest live list "
            f"({int(counts.max(initial=0))} rows)")
    new_codes = np.zeros((nlist, new_cap, codes.shape[-1]), codes.dtype)
    new_ids = np.full((nlist, new_cap), -1, ids.dtype)
    new_attrs = None if attrs is None else np.full((nlist, new_cap), -1,
                                                   attrs.dtype)
    for li in range(nlist):
        m = live[li]
        c = int(counts[li])
        new_codes[li, :c] = codes[li, m]
        new_ids[li, :c] = ids[li, m]
        if new_attrs is not None:
            new_attrs[li, :c] = attrs[li, m]
    return ListStore(
        codes=jnp.asarray(new_codes),
        ids=jnp.asarray(new_ids),
        sizes=jnp.asarray(counts.astype(np.int32)),
        attrs=None if new_attrs is None else jnp.asarray(new_attrs),
    )


def store_arrays(store: ListStore) -> dict[str, np.ndarray]:
    """The store as plain host arrays — the persistence wire format.

    Works on 2-D (single-host) and 3-D (shard-stacked) stores alike; the
    ``attrs`` key is simply absent when the store carries no attribute
    column, so ``store_from_arrays(store_arrays(s))`` round-trips the
    pytree arity exactly (docs/persistence.md)."""
    out = {"codes": np.asarray(store.codes),
           "ids": np.asarray(store.ids),
           "sizes": np.asarray(store.sizes)}
    if store.attrs is not None:
        out["attrs"] = np.asarray(store.attrs)
    return out


def store_from_arrays(arrays: dict[str, np.ndarray]) -> ListStore:
    """Inverse of ``store_arrays``: rebuild the ListStore pytree."""
    return ListStore(
        codes=jnp.asarray(arrays["codes"]),
        ids=jnp.asarray(arrays["ids"]),
        sizes=jnp.asarray(arrays["sizes"]),
        attrs=jnp.asarray(arrays["attrs"]) if "attrs" in arrays else None,
    )


def round_robin_perm(nlist: int, num_shards: int) -> np.ndarray:
    """The list permutation ``partition_lists`` applies: shard j owns lists
    j, j+S, j+2S, ... of the (padded to S*L) id space. Exposed so per-request
    sidecars — filter bitmaps (``partition_filter``), namespace membership
    rows — can be sharded consistently with a store partitioned earlier."""
    s = int(num_shards)
    l = -(-int(nlist) // s)
    return np.arange(s * l).reshape(l, s).T.reshape(-1)


def partition_lists(store: ListStore, centroids: jax.Array, num_shards: int
                    ) -> tuple[jax.Array, ListStore, jax.Array]:
    """Round-robin partition of lists into shards for shard-parallel search.

    Returns (centroids (S, L, D), ListStore with leading shard dim S,
    real (S, L) bool), where L = ceil(nlist / S). Padding lists — marked
    False in ``real`` — get a far-away centroid (probed only when a shard
    holds fewer real lists than nprobe), size 0, and all-(-1) ids, so every
    shard sees identical static shapes. ids stay *global* — the distributed
    top-k merge needs no re-mapping.
    """
    nlist = store.nlist
    s = int(num_shards)
    l = -(-nlist // s)
    pad = s * l - nlist
    cen = np.asarray(centroids, np.float32)
    codes = np.asarray(store.codes)
    ids = np.asarray(store.ids)
    sizes = np.asarray(store.sizes)
    attrs = None if store.attrs is None else np.asarray(store.attrs)
    if pad:
        far = np.full((pad, cen.shape[1]), 1e30, np.float32)
        cen = np.concatenate([cen, far], axis=0)
        codes = np.concatenate(
            [codes, np.zeros((pad,) + codes.shape[1:], codes.dtype)], axis=0)
        ids = np.concatenate([ids, np.full((pad,) + ids.shape[1:], -1, ids.dtype)],
                             axis=0)
        sizes = np.concatenate([sizes, np.zeros((pad,), sizes.dtype)], axis=0)
        if attrs is not None:
            attrs = np.concatenate(
                [attrs, np.full((pad,) + attrs.shape[1:], -1, attrs.dtype)],
                axis=0)
    # round-robin: shard j owns lists j, j+S, j+2S, ... — balances sizes when
    # k-means produces a long tail of small clusters
    perm = round_robin_perm(nlist, s)
    real = (perm < nlist).reshape(s, l)
    return (
        jnp.asarray(cen[perm].reshape(s, l, -1)),
        ListStore(
            codes=jnp.asarray(codes[perm].reshape((s, l) + codes.shape[1:])),
            ids=jnp.asarray(ids[perm].reshape(s, l, -1)),
            sizes=jnp.asarray(sizes[perm].reshape(s, l)),
            attrs=None if attrs is None else jnp.asarray(
                attrs[perm].reshape(s, l, -1)),
        ),
        jnp.asarray(real),
    )


def partition_filter(filter_bits: jax.Array, num_shards: int) -> jax.Array:
    """Shard a packed filter bitmap like ``partition_lists`` shards the codes.

    filter_bits: (nlist, W) u8 over the *global* list ids. Returns
    (S, L, W) u8 aligned with the partitioned store — shard j's row i is the
    bitmap of the list ``partition_lists`` placed at (j, i); padding lists
    get all-zero words (nothing passes — they hold no rows anyway). Pure jnp
    (the permutation is a compile-time constant), so it composes under jit;
    per-request filters go through here on every sharded search.
    """
    nlist, w = filter_bits.shape
    s = int(num_shards)
    l = -(-nlist // s)
    pad = s * l - nlist
    bits = filter_bits
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros((pad, w), filter_bits.dtype)], axis=0)
    perm = jnp.asarray(round_robin_perm(nlist, s))
    return bits[perm].reshape(s, l, w)


def partition_base(lists_s: ListStore, base: jax.Array
                   ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Per-shard base-vector slices + the id->row remap for sharded re-rank.

    Each base vector lives in exactly one posting list, hence on exactly one
    shard — so the exact-re-rank stage only ever needs the rows whose lists
    that shard owns. This builds those slices (host-side, offline, like
    ``build_lists``) so ``ShardedEngine`` stops replicating the full (N, D)
    base to every device.

    lists_s: ListStore with leading shard dim S (from ``partition_lists``,
    ids still global); base: (N, D) f32.

    Returns:
      base_s    (S, R, D) f32 — shard-local base rows, zero-padded;
      gids_s    (S, R)    i32 — global id of each local row (-1 = padding);
      local_ids (S, L, cap) i32 — ``lists_s.ids`` remapped to shard-local
                row indices into ``base_s`` (-1 where ids was -1);
      norms_s   (S, R)    f32 — ``base_norms`` of each local row (0 at
                padding), stored alongside the partitioned base so the
                norms+GEMM re-rank never recomputes them per query.

    R = max over shards of the shard's vector count (static shapes — the
    round-robin list partition keeps shards balanced, so the padding slack
    is small). Search runs on local ids end-to-end and maps back to global
    via ``gids_s`` just before the distributed merge.
    """
    ids = np.asarray(lists_s.ids)              # (S, L, cap) global ids
    s = ids.shape[0]
    base_np = np.asarray(base, np.float32)
    flat = ids.reshape(s, -1)
    mask = flat >= 0
    r_cap = max(1, int(mask.sum(axis=1).max()))
    base_s = np.zeros((s, r_cap, base_np.shape[1]), np.float32)
    gids_s = np.full((s, r_cap), -1, np.int32)
    local_flat = np.full(flat.shape, -1, np.int32)
    for j in range(s):
        g = flat[j][mask[j]]                   # globals in order of appearance
        base_s[j, :g.size] = base_np[g]
        gids_s[j, :g.size] = g
        local_flat[j][mask[j]] = np.arange(g.size, dtype=np.int32)
    base_s = jnp.asarray(base_s)
    # slice the precomputed norms per shard instead of re-deriving from the
    # sliced rows: gathering from one (N,) base_norms output keeps every
    # shard's values bitwise identical to the single-host engine's
    norms = np.asarray(base_norms(jnp.asarray(base_np)))
    norms_s = np.where(gids_s >= 0, norms[np.maximum(gids_s, 0)],
                       0.0).astype(np.float32)
    return (base_s, jnp.asarray(gids_s),
            jnp.asarray(local_flat.reshape(ids.shape)), jnp.asarray(norms_s))
