"""4-bit PQ fast-scan: u8-quantized LUTs + nibble-packed codes (paper §2-§3).

The paper's fast path needs three ingredients:
  1. K = 16 so each PQ code is 4 bits,
  2. the per-query float LUT scalar-quantized to uint8 so a whole sub-space
     table (16 x u8 = 128 bit) fits in the fastest memory tier,
  3. a register-resident gather (NEON vqtbl1q_u8 x2 in the paper; on TPU our
     Pallas kernels in ``repro.kernels`` — select-tree on the VPU or one-hot
     matmul on the MXU).

This module owns (1) and (2) plus the code layout, and exposes the search API
that dispatches to the kernels.

Conventions (shared across ``repro.core``, see docs/architecture.md):
  shapes  all static — codes padded to fixed N, tables fixed (M, 16);
          queries (Q, D) or (D,) auto-promoted to (1, D)
  dtypes  queries/tables/distances float32; quantized LUT entries uint8;
          packed codes uint8 (two 4-bit codes per byte, lo nibble = even m);
          int accumulations int32
  -1 id   not produced here (full-database scan has no padding); the IVF
          layer introduces -1 sentinel ids and masks on ``id >= 0``
  filter  not applied here either — per-row predicate bitmaps (packed u8
          words, bit 1 = row passes; core.lists / docs/filtering.md) are a
          posting-list concept: the stream kernels sentinel excluded rows'
          i32 ADC scores (ACC_SENTINEL) before candidate selection, exactly
          like occupancy padding, so the LUT quantization here never sees
          or affects filtering
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import pq as pq_mod
from repro.core.pq import PQCodebook


class QuantizedLUT(NamedTuple):
    """Affine-quantized ADC tables for a batch of queries.

    table_q8: (Q, M, 16) uint8   quantized entries
    scale:    (Q,)       float32 global scale per query (faiss-style)
    bias:     (Q, M)     float32 per-sub-space bias (the per-row minimum)

    Reconstruction: dist(q, n) ~= scale[q] * acc[q, n] + sum_m bias[q, m]
    where acc is the int accumulation of table_q8 entries.
    """

    table_q8: jax.Array
    scale: jax.Array
    bias: jax.Array


def quantize_lut(table: jax.Array) -> QuantizedLUT:
    """Scalar-quantize float LUTs (Q, M, K) -> u8, faiss PQFastScan style.

    Per-row (sub-space) bias = row min; one global scale per query chosen so
    the *largest single entry* maps to 255. Accumulation is exact in int32
    (the paper saturates u16 on ARM; int32 is the TPU-native accumulator and
    strictly more accurate — documented deviation).
    """
    squeeze = table.ndim == 2
    if squeeze:
        table = table[None]
    bias = jnp.min(table, axis=-1)  # (Q, M)
    shifted = table - bias[..., None]
    maxval = jnp.max(shifted, axis=(-2, -1))  # (Q,)
    scale = jnp.maximum(maxval, 1e-20) / 255.0
    q8 = jnp.clip(jnp.round(shifted / scale[..., None, None]), 0, 255).astype(jnp.uint8)
    out = QuantizedLUT(q8, scale.astype(jnp.float32), bias.astype(jnp.float32))
    if squeeze:
        out = QuantizedLUT(out.table_q8[0], out.scale[0], out.bias[0])
    return out


def dequantize_acc(qlut: QuantizedLUT, acc: jax.Array) -> jax.Array:
    """int32 accumulations (Q, N) -> approximate float distances (Q, N)."""
    if qlut.table_q8.ndim == 3:
        return qlut.scale[:, None] * acc.astype(jnp.float32) + jnp.sum(qlut.bias, axis=-1)[:, None]
    return qlut.scale * acc.astype(jnp.float32) + jnp.sum(qlut.bias)


# ---------------------------------------------------------------------------
# code layout: nibble packing
# ---------------------------------------------------------------------------

def pack_codes(codes: jax.Array) -> jax.Array:
    """(N, M) int codes in [0,16) -> (N, M//2) uint8, lo nibble = even m.

    M must be even (callers pad the codebook with a zero sub-space if not).
    This is the TPU adaptation of the paper's interleaved register layout: a
    (N_tile, M/2) u8 VMEM tile feeds the kernel with lane-contiguous access.
    """
    n, m = codes.shape
    assert m % 2 == 0, f"M={m} must be even for nibble packing"
    c = codes.astype(jnp.uint8)
    lo = c[:, 0::2]
    hi = c[:, 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_codes(packed: jax.Array) -> jax.Array:
    """(N, M//2) uint8 -> (N, M) int32."""
    lo = (packed & 0xF).astype(jnp.int32)
    hi = ((packed >> 4) & 0xF).astype(jnp.int32)
    n, mh = packed.shape
    out = jnp.zeros((n, 2 * mh), jnp.int32)
    out = out.at[:, 0::2].set(lo)
    out = out.at[:, 1::2].set(hi)
    return out


# ---------------------------------------------------------------------------
# index object + search API
# ---------------------------------------------------------------------------

class FastScanIndex(NamedTuple):
    codebook: PQCodebook  # K must be 16
    packed_codes: jax.Array  # (N, M//2) uint8
    n: int


def build_index(key: jax.Array, train_x: jax.Array, base_x: jax.Array, m: int,
                iters: int = 25) -> FastScanIndex:
    cb = pq_mod.train_pq(key, train_x, m=m, k=16, iters=iters)
    codes = pq_mod.encode(cb, base_x)
    return FastScanIndex(cb, pack_codes(codes), base_x.shape[0])


@functools.partial(jax.jit, static_argnames=("impl", "metric"))
def compute_distances(index: FastScanIndex, q: jax.Array, impl: str = "mxu",
                      metric: str = "l2") -> jax.Array:
    """Approximate distances (Q, N) via the 4-bit fast-scan pipeline."""
    from repro.kernels import ops  # local import: kernels depend on nothing here

    if q.ndim == 1:
        q = q[None]
    table = pq_mod.adc_table(index.codebook, q, metric=metric)  # (Q, M, 16)
    qlut = quantize_lut(table)
    acc = ops.fastscan_distances(qlut.table_q8, index.packed_codes, impl=impl)
    return dequantize_acc(qlut, acc)


@functools.partial(jax.jit, static_argnames=("topk", "impl", "metric"))
def search(index: FastScanIndex, q: jax.Array, topk: int = 10, impl: str = "mxu",
           metric: str = "l2") -> tuple[jax.Array, jax.Array]:
    """Top-k search: returns (dists (Q, topk), ids (Q, topk))."""
    d = compute_distances(index, q, impl=impl, metric=metric)
    neg, idx = jax.lax.top_k(-d, topk)
    return -neg, idx
