"""Batched Lloyd's k-means in pure JAX.

Used for PQ codebook training (vmapped over sub-spaces) and for IVF coarse
centroids. Fully jit-able: fixed iteration count, dead clusters re-seeded
deterministically from the data.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class KMeansResult(NamedTuple):
    centroids: jax.Array  # (k, d)
    assignments: jax.Array  # (n,) int32
    inertia: jax.Array  # () float32 — sum of squared distances


def pairwise_sqdist(x: jax.Array, c: jax.Array) -> jax.Array:
    """Squared L2 distances (n, k) between rows of x (n, d) and c (k, d).

    Uses the ||x||^2 - 2 x.c + ||c||^2 expansion so the inner term is a
    single matmul (MXU-friendly on TPU).
    """
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)  # (n, 1)
    c2 = jnp.sum(c * c, axis=-1)  # (k,)
    # clamp: the expansion can go slightly negative in float32
    d = x2 - 2.0 * (x @ c.T) + c2[None, :]
    return jnp.maximum(d, 0.0)


def _assign(x: jax.Array, c: jax.Array) -> tuple[jax.Array, jax.Array]:
    d = pairwise_sqdist(x, c)
    a = jnp.argmin(d, axis=-1).astype(jnp.int32)
    return a, jnp.min(d, axis=-1)


def _update(x: jax.Array, a: jax.Array, k: int, key: jax.Array) -> jax.Array:
    """Mean per cluster; empty clusters re-seeded from random data points."""
    n = x.shape[0]
    counts = jax.ops.segment_sum(jnp.ones((n,), x.dtype), a, num_segments=k)
    sums = jax.ops.segment_sum(x, a, num_segments=k)
    means = sums / jnp.maximum(counts, 1.0)[:, None]
    # deterministic re-seed for empty clusters
    reseed_idx = jax.random.randint(key, (k,), 0, n)
    reseed = x[reseed_idx]
    return jnp.where(counts[:, None] > 0, means, reseed)


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(key: jax.Array, x: jax.Array, k: int, iters: int = 25) -> KMeansResult:
    """Lloyd's algorithm. x: (n, d) float32. Returns KMeansResult."""
    n = x.shape[0]
    init_key, *iter_keys = jax.random.split(key, iters + 1)
    # k-means|| style cheap init: random distinct-ish sample
    perm = jax.random.permutation(init_key, n)[:k]
    c0 = x[perm]

    def body(c, it_key):
        a, _ = _assign(x, c)
        c = _update(x, a, k, it_key)
        return c, None

    c, _ = jax.lax.scan(body, c0, jnp.stack(iter_keys))
    a, dmin = _assign(x, c)
    return KMeansResult(centroids=c, assignments=a, inertia=jnp.sum(dmin))


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def kmeans_multi(key: jax.Array, x: jax.Array, k: int, iters: int = 25) -> KMeansResult:
    """vmapped k-means over a leading batch axis: x (m, n, d) -> (m, k, d).

    This is the PQ training primitive: one independent k-means per sub-space.
    """
    m = x.shape[0]
    keys = jax.random.split(key, m)
    return jax.vmap(lambda kk, xx: kmeans(kk, xx, k=k, iters=iters))(keys, x)
