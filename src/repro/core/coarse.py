"""Coarse quantizer zoo for the IVF pipeline (paper §4).

Three interchangeable coarse quantizers over the IVF centroids:
  - FlatL2: brute-force distance matrix (a single MXU matmul) + top-k.
  - HNSW:   graph search (paper's Table 1 choice for nlist=30k).
  - KMeansTree: two-level tree — search sqrt(nlist) super-clusters, then
    only their children; sub-linear and fully dense/jit-able.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hnsw as hnsw_mod
from repro.core import topk as topk_mod
from repro.core.kmeans import kmeans, pairwise_sqdist


class FlatCoarse(NamedTuple):
    centroids: jax.Array  # (nlist, D)

    def search(self, q: jax.Array, nprobe: int) -> tuple[jax.Array, jax.Array]:
        d = pairwise_sqdist(q, self.centroids)
        return topk_mod.smallest_k(d, nprobe)


class HNSWCoarse(NamedTuple):
    graph: hnsw_mod.HNSWGraph

    def search(self, q: jax.Array, nprobe: int, ef: int = 64
               ) -> tuple[jax.Array, jax.Array]:
        return hnsw_mod.search_hnsw(self.graph, q, ef=max(ef, nprobe), topk=nprobe)


class TreeCoarse(NamedTuple):
    roots: jax.Array        # (R, D) super-cluster centers
    children: jax.Array     # (R, C) int32 child centroid ids, -1 padded
    centroids: jax.Array    # (nlist, D)

    def search(self, q: jax.Array, nprobe: int, nroots: int = 4
               ) -> tuple[jax.Array, jax.Array]:
        dr = pairwise_sqdist(q, self.roots)
        _, rid = topk_mod.smallest_k(dr, nroots)              # (Q, nroots)
        cand = self.children[rid].reshape(q.shape[0], -1)     # (Q, nroots*C)
        cvec = self.centroids[jnp.maximum(cand, 0)]
        dc = jnp.sum((cvec - q[:, None, :]) ** 2, axis=-1)
        dc = jnp.where(cand >= 0, dc, jnp.inf)
        vals, pos = topk_mod.smallest_k(dc, nprobe)
        return vals, jnp.take_along_axis(cand, pos, axis=1)


def build_flat(centroids: jax.Array) -> FlatCoarse:
    return FlatCoarse(centroids=centroids)


def build_hnsw_coarse(centroids: jax.Array, m: int = 16,
                      ef_construction: int = 64, seed: int = 0) -> HNSWCoarse:
    g = hnsw_mod.build_hnsw(np.asarray(centroids, np.float32), m=m,
                            ef_construction=ef_construction, seed=seed)
    return HNSWCoarse(graph=g)


def build_tree(key: jax.Array, centroids: jax.Array, *, nroots: int | None = None,
               iters: int = 15) -> TreeCoarse:
    nlist = centroids.shape[0]
    r = int(nroots or max(2, int(np.sqrt(nlist))))
    res = kmeans(key, centroids, k=r, iters=iters)
    assign = np.asarray(res.assignments)
    counts = np.bincount(assign, minlength=r)
    cap = int(counts.max())
    children = np.full((r, cap), -1, np.int32)
    cursor = np.zeros((r,), np.int64)
    for i, a in enumerate(assign):
        children[a, cursor[a]] = i
        cursor[a] += 1
    return TreeCoarse(roots=res.centroids, children=jnp.asarray(children),
                      centroids=centroids)
