"""Inverted-file index (IVF) with 4-bit PQ fast-scan distance estimation.

Paper §4: split the database into n_list subsets around k-means centroids;
at query time scan only the n_probe nearest subsets with the 4-bit ADC.

TPU adaptation of the data structure: lists are *padded* to a fixed capacity
so every shape is static and the whole probe+scan+merge pipeline lowers under
jit/pjit on a 512-device mesh (no dynamic shapes anywhere — the brief's rule).
Encoding is by-residual (faiss IVFPQ default): codes quantize x - centroid.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fastscan as fs
from repro.core import pq as pq_mod
from repro.core import topk as topk_mod
from repro.core.kmeans import kmeans, pairwise_sqdist
from repro.core.pq import PQCodebook


class IVFIndex(NamedTuple):
    centroids: jax.Array     # (nlist, D) coarse quantizer
    codebook: PQCodebook     # residual PQ codebooks, K=16
    list_codes: jax.Array    # (nlist, cap, M//2) uint8, nibble-packed
    list_ids: jax.Array      # (nlist, cap) int32, -1 = padding
    list_sizes: jax.Array    # (nlist,) int32

    @property
    def nlist(self) -> int:
        return self.centroids.shape[0]

    @property
    def cap(self) -> int:
        return self.list_ids.shape[1]


def build_ivf(key: jax.Array, train_x: jax.Array, base_x: jax.Array, *,
              m: int, nlist: int, cap: int | None = None,
              coarse_iters: int = 20, pq_iters: int = 25) -> IVFIndex:
    """Train coarse centroids + residual PQ, bucket base into padded lists.

    Host-side bucketing (numpy) — index build is offline; search is jit'd.
    """
    k_coarse, k_pq, k_assign = jax.random.split(key, 3)
    res = kmeans(k_coarse, train_x, k=nlist, iters=coarse_iters)
    centroids = res.centroids

    # assign base vectors to lists, in chunks to bound memory
    n = base_x.shape[0]
    assign = np.empty((n,), np.int32)
    chunk = 65536
    for s in range(0, n, chunk):
        d = pairwise_sqdist(base_x[s:s + chunk], centroids)
        assign[s:s + chunk] = np.asarray(jnp.argmin(d, axis=-1), np.int32)

    # residual PQ training on train residuals
    d_train = pairwise_sqdist(train_x, centroids)
    train_assign = jnp.argmin(d_train, axis=-1)
    train_res = train_x - centroids[train_assign]
    cb = pq_mod.train_pq(k_pq, train_res, m=m, k=16, iters=pq_iters)

    # encode base residuals
    base_res = base_x - centroids[assign]
    codes = np.asarray(pq_mod.encode(cb, base_res), np.int32)  # (n, M)
    packed = np.asarray(fs.pack_codes(jnp.asarray(codes)), np.uint8)

    counts = np.bincount(assign, minlength=nlist)
    cap_ = int(cap or counts.max())
    mh = packed.shape[1]
    list_codes = np.zeros((nlist, cap_, mh), np.uint8)
    list_ids = np.full((nlist, cap_), -1, np.int32)
    cursor = np.zeros((nlist,), np.int64)
    order = np.argsort(assign, kind="stable")
    for i in order:
        li = assign[i]
        c = cursor[li]
        if c < cap_:  # overflow beyond capacity is dropped (counted below)
            list_codes[li, c] = packed[i]
            list_ids[li, c] = i
            cursor[li] += 1
    return IVFIndex(
        centroids=centroids,
        codebook=cb,
        list_codes=jnp.asarray(list_codes),
        list_ids=jnp.asarray(list_ids),
        list_sizes=jnp.asarray(np.minimum(counts, cap_).astype(np.int32)),
    )


def _probe_tables(index: IVFIndex, q: jax.Array, probe_ids: jax.Array
                  ) -> fs.QuantizedLUT:
    """Residual ADC LUTs for each (query, probe): (Q, P, M, 16) u8."""
    mu = index.centroids[probe_ids]            # (Q, P, D)
    resid = q[:, None, :] - mu                 # (Q, P, D)
    qq, p, d = resid.shape
    t = pq_mod.adc_table(index.codebook, resid.reshape(qq * p, d))  # (QP, M, 16)
    qlut = fs.quantize_lut(t)
    return fs.QuantizedLUT(
        table_q8=qlut.table_q8.reshape(qq, p, *qlut.table_q8.shape[1:]),
        scale=qlut.scale.reshape(qq, p),
        bias=qlut.bias.reshape(qq, p, -1),
    )


def _adc_scan_lists(table_q8: jax.Array, codes: jax.Array) -> jax.Array:
    """Batched per-list ADC: (Q, P, M, 16) u8 x (Q, P, cap, M//2) -> (Q, P, cap) i32.

    Each (query, probe) cell has its own LUT and its own codes, so this is the
    'memory path' formulation (vectorized gather); the shared-database kernel
    path lives in repro.kernels and is used by the flat fast-scan index.
    """
    unpacked = fs.unpack_codes(codes.reshape(-1, codes.shape[-1]))  # (QPc, M)
    qq, p, cap, _ = codes.shape
    m = unpacked.shape[-1]
    unpacked = unpacked.reshape(qq, p, cap, m)
    t = table_q8.astype(jnp.int32)  # (Q, P, M, 16)
    gathered = jnp.take_along_axis(
        t[:, :, None, :, :],                                  # (Q,P,1,M,16)
        unpacked[..., None],                                  # (Q,P,cap,M,1)
        axis=-1,
    )[..., 0]                                                 # (Q,P,cap,M)
    return jnp.sum(gathered, axis=-1, dtype=jnp.int32)


@functools.partial(jax.jit, static_argnames=("nprobe", "topk"))
def search_ivf(index: IVFIndex, q: jax.Array, *, nprobe: int = 8,
               topk: int = 10) -> tuple[jax.Array, jax.Array]:
    """IVF + 4-bit fast-scan search.

    q: (Q, D). Returns (dists (Q, topk) f32, ids (Q, topk) i32, -1 padding).
    """
    if q.ndim == 1:
        q = q[None]
    coarse_d = pairwise_sqdist(q, index.centroids)            # (Q, nlist)
    _, probe_ids = topk_mod.smallest_k(coarse_d, nprobe)      # (Q, P)

    qlut = _probe_tables(index, q, probe_ids)                 # (Q, P, M, 16)
    codes = index.list_codes[probe_ids]                       # (Q, P, cap, M//2)
    ids = index.list_ids[probe_ids]                           # (Q, P, cap)
    acc = _adc_scan_lists(qlut.table_q8, codes)               # (Q, P, cap) i32
    dists = (qlut.scale[..., None] * acc.astype(jnp.float32)
             + jnp.sum(qlut.bias, axis=-1)[..., None])        # (Q, P, cap)

    qq = dists.shape[0]
    flat_d = dists.reshape(qq, -1)
    flat_ids = ids.reshape(qq, -1)
    vals, pos = topk_mod.masked_topk(flat_d, flat_ids >= 0, topk)
    out_ids = jnp.where(pos >= 0, jnp.take_along_axis(flat_ids, jnp.maximum(pos, 0), axis=1), -1)
    return vals, out_ids


@functools.partial(jax.jit, static_argnames=("nprobe", "topk"))
def search_ivf_precomputed_probes(index: IVFIndex, q: jax.Array,
                                  probe_ids: jax.Array, *, nprobe: int = 8,
                                  topk: int = 10) -> tuple[jax.Array, jax.Array]:
    """Fine stage only — probes come from an external coarse quantizer (HNSW).

    This is the paper's Table 1 pipeline: HNSW for coarse, fast-scan for fine.
    """
    if q.ndim == 1:
        q = q[None]
    probe_ids = probe_ids[:, :nprobe]
    qlut = _probe_tables(index, q, probe_ids)
    codes = index.list_codes[probe_ids]
    ids = index.list_ids[probe_ids]
    acc = _adc_scan_lists(qlut.table_q8, codes)
    dists = (qlut.scale[..., None] * acc.astype(jnp.float32)
             + jnp.sum(qlut.bias, axis=-1)[..., None])
    qq = dists.shape[0]
    flat_d = dists.reshape(qq, -1)
    flat_ids = ids.reshape(qq, -1)
    vals, pos = topk_mod.masked_topk(flat_d, flat_ids >= 0, topk)
    out_ids = jnp.where(pos >= 0, jnp.take_along_axis(flat_ids, jnp.maximum(pos, 0), axis=1), -1)
    return vals, out_ids
