"""Inverted-file index (IVF) with 4-bit PQ fast-scan distance estimation.

Paper §4: split the database into n_list subsets around k-means centroids;
at query time scan only the n_probe nearest subsets with the 4-bit ADC.

TPU adaptation of the data structure: lists are *padded* to a fixed capacity
so every shape is static and the whole probe+scan+merge pipeline lowers under
jit/pjit on a 512-device mesh (no dynamic shapes anywhere — the brief's rule).
Encoding is by-residual (faiss IVFPQ default): codes quantize x - centroid.

List storage/gather lives in ``repro.core.lists.ListStore`` — a reusable
component shared with the unified engine (``repro.engine``) and the
shard-parallel path. ``scan_probes`` is the quantized-scan stage on its own:
(query, probe_ids) -> per-candidate ADC distances, reused verbatim by the
engine so ``SearchEngine.search`` and hand-composition are identical.

Conventions (shared across ``repro.core``, see docs/architecture.md):
  shapes  all static — lists padded to ``cap``, probe sets to ``nprobe``;
          queries (Q, D) or (D,) auto-promoted to (1, D)
  dtypes  queries/centroids/distances float32; packed codes uint8;
          ids and probe ids int32
  -1 id   sentinel everywhere — probe_ids entry -1 = no probe (yields a
          fully-padded list), candidate/result id -1 = padding/no candidate
          (distance +inf); consumers mask on ``id >= 0``
  filter  optional packed per-row bitmap (nlist, W) u8 (layout:
          ``core.lists.pack_filter_mask``, docs/filtering.md); bit 0 = the
          row is excluded from the scan exactly as if it were padding (id
          -1, distance +inf). ``scan_probes_stream`` applies it inside the
          kernel's pre-selection mask; gathered paths post-mask the full
          pool — both bit-identical
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fastscan as fs
from repro.core import pq as pq_mod
from repro.core import topk as topk_mod
from repro.core.kmeans import kmeans, pairwise_sqdist
from repro.core.lists import ListStore, build_lists
from repro.core.pq import PQCodebook


class IVFIndex(NamedTuple):
    centroids: jax.Array  # (nlist, D) coarse quantizer
    codebook: PQCodebook  # residual PQ codebooks, K=16
    lists: ListStore      # padded posting lists (codes/ids/sizes)

    @property
    def nlist(self) -> int:
        return self.centroids.shape[0]

    @property
    def cap(self) -> int:
        return self.lists.cap

    # back-compat accessors for the pre-ListStore field layout
    @property
    def list_codes(self) -> jax.Array:
        return self.lists.codes

    @property
    def list_ids(self) -> jax.Array:
        return self.lists.ids

    @property
    def list_sizes(self) -> jax.Array:
        return self.lists.sizes


def build_ivf(key: jax.Array, train_x: jax.Array, base_x: jax.Array, *,
              m: int, nlist: int, cap: int | None = None,
              coarse_iters: int = 20, pq_iters: int = 25) -> IVFIndex:
    """Train coarse centroids + residual PQ, bucket base into padded lists.

    Host-side bucketing (numpy) — index build is offline; search is jit'd.
    """
    k_coarse, k_pq, k_assign = jax.random.split(key, 3)
    res = kmeans(k_coarse, train_x, k=nlist, iters=coarse_iters)
    centroids = res.centroids

    # assign base vectors to lists, in chunks to bound memory
    n = base_x.shape[0]
    assign = np.empty((n,), np.int32)
    chunk = 65536
    for s in range(0, n, chunk):
        d = pairwise_sqdist(base_x[s:s + chunk], centroids)
        assign[s:s + chunk] = np.asarray(jnp.argmin(d, axis=-1), np.int32)

    # residual PQ training on train residuals
    d_train = pairwise_sqdist(train_x, centroids)
    train_assign = jnp.argmin(d_train, axis=-1)
    train_res = train_x - centroids[train_assign]
    cb = pq_mod.train_pq(k_pq, train_res, m=m, k=16, iters=pq_iters)

    # encode base residuals
    base_res = base_x - centroids[assign]
    codes = np.asarray(pq_mod.encode(cb, base_res), np.int32)  # (n, M)
    packed = np.asarray(fs.pack_codes(jnp.asarray(codes)), np.uint8)

    return IVFIndex(
        centroids=centroids,
        codebook=cb,
        lists=build_lists(assign, packed, nlist=nlist, cap=cap),
    )


# fixed encode batch shape for the mutation path (docs/mutability.md): a
# row's assignment + code bytes must be bitwise independent of who shares
# its upsert batch, so every encode runs at this exact padded shape
_ENCODE_CHUNK = 256


@jax.jit
def _encode_chunk(centroids: jax.Array, cb: PQCodebook, chunk: jax.Array
                  ) -> tuple[jax.Array, jax.Array]:
    d = pairwise_sqdist(chunk, centroids)
    assign = jnp.argmin(d, axis=-1).astype(jnp.int32)
    codes = pq_mod.encode(cb, chunk - centroids[assign])
    return assign, fs.pack_codes(codes)


def encode_rows(centroids: jax.Array, cb: PQCodebook, vecs: np.ndarray
                ) -> tuple[np.ndarray, np.ndarray]:
    """Deterministic list assignment + residual PQ encode of raw rows.

    vecs: (B, D) f32. Returns (assign (B,) i32, packed (B, M//2) u8) — the
    nearest coarse centroid per row and the nibble-packed 4-bit PQ codes of
    the residual, exactly what ``build_ivf`` computes for the initial build.

    Every call runs the same jitted program at a FIXED zero-padded batch
    shape (``_ENCODE_CHUNK``), so a given row encodes to bitwise-identical
    bytes no matter how it is batched. That is the property the mutation
    oracle rests on (docs/mutability.md): an upserted row's codes equal the
    codes a from-scratch rebuild assigns it, so a mutated engine and a
    rebuilt one score it identically.
    """
    vecs = np.asarray(vecs, np.float32)
    b, d = vecs.shape
    assign = np.empty((b,), np.int32)
    packed = np.empty((b, cb.m // 2), np.uint8)
    for s in range(0, b, _ENCODE_CHUNK):
        chunk = vecs[s:s + _ENCODE_CHUNK]
        c = chunk.shape[0]
        if c < _ENCODE_CHUNK:
            chunk = np.concatenate(
                [chunk, np.zeros((_ENCODE_CHUNK - c, d), np.float32)])
        a, p = _encode_chunk(centroids, cb, jnp.asarray(chunk))
        assign[s:s + c] = np.asarray(a)[:c]
        packed[s:s + c] = np.asarray(p)[:c]
    return assign, packed


def _probe_tables(index: IVFIndex, q: jax.Array, probe_ids: jax.Array
                  ) -> fs.QuantizedLUT:
    """Residual ADC LUTs for each (query, probe): (Q, P, M, 16) u8."""
    mu = index.centroids[jnp.maximum(probe_ids, 0)]  # (Q, P, D)
    resid = q[:, None, :] - mu                 # (Q, P, D)
    qq, p, d = resid.shape
    t = pq_mod.adc_table(index.codebook, resid.reshape(qq * p, d))  # (QP, M, 16)
    qlut = fs.quantize_lut(t)
    return fs.QuantizedLUT(
        table_q8=qlut.table_q8.reshape(qq, p, *qlut.table_q8.shape[1:]),
        scale=qlut.scale.reshape(qq, p),
        bias=qlut.bias.reshape(qq, p, -1),
    )


@functools.partial(jax.jit, static_argnames=("impl",))
def scan_probes(index: IVFIndex, q: jax.Array, probe_ids: jax.Array, *,
                impl: str = "ref") -> tuple[jax.Array, jax.Array]:
    """Quantized fine-scan stage: 4-bit ADC over the probed lists.

    q: (Q, D); probe_ids: (Q, P) (-1 = no probe). Returns
    (dists (Q, P, cap) f32, ids (Q, P, cap) i32, -1 = padding).

    Each (query, probe) pair gets its own residual u8 LUT, so the scan is the
    *grouped* kernel formulation: impl 'ref' is the vectorized jnp gather,
    'select' the register-resident Pallas select-tree, 'mxu' the per-group
    one-hot GEMM on the MXU, 'stream' the gather-free in-kernel list DMA
    (codes scanned in place in ``index.lists`` — the (Q, P, cap, M//2)
    gathered copy never exists), and 'auto' the autotuned dispatch
    (``kernels.ops.SCAN_IMPLS``; resolution happens at trace time since all
    shapes here are static, and may itself pick 'stream'). All bit-identical
    on every real candidate (invalid probes yield unmasked garbage distances
    under any impl; consumers mask on ``ids >= 0``).
    """
    from repro.kernels import ops  # local import: kernels depend on nothing here

    qlut = _probe_tables(index, q, probe_ids)          # (Q, P, M, 16)
    qq, p = probe_ids.shape
    cap = index.lists.cap
    m = qlut.table_q8.shape[-2]
    impl, tile_n = ops.resolve_scan_impl(impl, qq * p, cap, m,
                                         nlist=index.lists.nlist)
    tables = qlut.table_q8.reshape(qq * p, *qlut.table_q8.shape[2:])
    if impl == "stream":
        # in-place calling convention: the ListStore never gets copied —
        # only the probed tiles cross into VMEM, and only the ids (needed
        # downstream for masking/re-rank) are gathered
        acc = ops.fastscan_stream_grouped(
            tables, index.lists.codes, probe_ids.reshape(-1),
            tile_n=tile_n).reshape(qq, p, cap)
        ids = index.lists.gather_ids(probe_ids)        # (Q, P, cap)
    else:
        codes, ids = index.lists.gather(probe_ids)     # (Q,P,cap,Mh), (Q,P,cap)
        acc = ops.fastscan_grouped(
            tables, codes.reshape(qq * p, cap, -1),
            impl=impl, tile_n=tile_n).reshape(qq, p, cap)
    dists = (qlut.scale[..., None] * acc.astype(jnp.float32)
             + jnp.sum(qlut.bias, axis=-1)[..., None])  # (Q, P, cap)
    return dists, ids


@functools.partial(jax.jit, static_argnames=("keep", "tile_n", "early_exit"))
def scan_probes_stream(index: IVFIndex, q: jax.Array, probe_ids: jax.Array, *,
                       keep: int, tile_n: int = 0,
                       filter_bits: jax.Array | None = None,
                       early_exit: bool = False
                       ) -> tuple[jax.Array, ...]:
    """Gather-free fine scan with fused candidate reduction (+ filtering).

    The ``impl='stream'`` serving hot path: ADC runs over ``index.lists``
    *in place* and the kernel reduces each cap tile to its ``kc =
    min(keep, tile)`` best candidates in VMEM, so neither the gathered
    (Q, P, cap, M//2) code copy nor the full (Q, P, cap) distance tensor
    ever reaches HBM. ``filter_bits`` — optional (nlist, W) u8 packed
    per-row bitmap (docs/filtering.md) — excludes rows whose bit is 0
    inside the kernel's pre-selection mask, so filtering costs no recall
    at fixed ``keep``: excluded rows free their candidate slots instead of
    occupying them the way a post-filter would. Returns a *reduced*
    candidate pool (dists (Q, C') f32, ids (Q, C') i32, -1 = absent) with
    C' = P * n_tiles * kc.

    Exactness: any final selection of <= ``keep`` candidates per query over
    (dists, ids) — e.g. ``rerank.finalize_candidates`` with
    ``r*k <= keep`` — is bit-identical to the same selection over the full
    ``scan_probes`` pool (post-masked by the same filter): every true
    survivor is within its own tile's top-kc (i32 ADC scores are exact),
    the pool preserves (probe, tile, slot) order, and in-tile ties resolve
    lowest-slot-first, matching ``masked_topk``'s lowest-flat-index
    tie-break.

    ``early_exit`` arms the kernel's anytime tile pruning (docs/anytime.md)
    and changes the return to (dists, ids, tiles_skipped (Q,) i32) — the
    per-query count of valid-probe tiles whose scan (and usually DMA) the
    lower bound proved irrelevant. The final <= ``keep`` selection stays
    bit-identical; the raw pool does not (pruned tiles surface as absent
    candidates).
    """
    from repro.kernels import ops

    qlut = _probe_tables(index, q, probe_ids)          # (Q, P, M, 16)
    qq, p = probe_ids.shape
    bias_sum = jnp.sum(qlut.bias, axis=-1)             # (Q, P)
    tiles_skipped = None
    if early_exit:
        vals, slots, skipped = ops.fastscan_stream_topk(
            qlut.table_q8.reshape(qq * p, *qlut.table_q8.shape[2:]),
            index.lists.codes, probe_ids.reshape(-1), index.lists.sizes,
            keep=keep, tile_n=tile_n, filter_bits=filter_bits,
            early_exit=True, groups_per_query=p,
            scales=qlut.scale.reshape(-1),
            biases=bias_sum.reshape(-1))               # + (G, n_tiles)
        tiles_skipped = jnp.sum(skipped.reshape(qq, -1), axis=1)
    else:
        vals, slots = ops.fastscan_stream_topk(
            qlut.table_q8.reshape(qq * p, *qlut.table_q8.shape[2:]),
            index.lists.codes, probe_ids.reshape(-1), index.lists.sizes,
            keep=keep, tile_n=tile_n,
            filter_bits=filter_bits)                   # (G, n_tiles, kc) x2
    n_tiles, kc = vals.shape[1], vals.shape[2]
    vals = vals.reshape(qq, p, n_tiles * kc)
    slots = slots.reshape(qq, p, n_tiles * kc)
    valid = slots >= 0
    # same affine dequantization expression as scan_probes -> f32-identical
    # (and the same expression the early-exit kernel thresholds with)
    dists = (qlut.scale[..., None] * vals.astype(jnp.float32)
             + bias_sum[..., None])
    dists = jnp.where(valid, dists, jnp.inf)
    # ids only for the kept candidates: a (Q, P, n_tiles*kc) gather instead
    # of the full (Q, P, cap) one
    lids = jnp.maximum(probe_ids, 0)[..., None]
    ids = index.lists.ids[lids, jnp.maximum(slots, 0)]
    ids = jnp.where(valid & (probe_ids >= 0)[..., None], ids, -1)
    if early_exit:
        return dists.reshape(qq, -1), ids.reshape(qq, -1), tiles_skipped
    return dists.reshape(qq, -1), ids.reshape(qq, -1)


@functools.partial(jax.jit, static_argnames=("nprobe", "topk"))
def search_ivf(index: IVFIndex, q: jax.Array, *, nprobe: int = 8,
               topk: int = 10) -> tuple[jax.Array, jax.Array]:
    """IVF + 4-bit fast-scan search.

    q: (Q, D). Returns (dists (Q, topk) f32, ids (Q, topk) i32, -1 padding).
    """
    if q.ndim == 1:
        q = q[None]
    coarse_d = pairwise_sqdist(q, index.centroids)            # (Q, nlist)
    _, probe_ids = topk_mod.smallest_k(coarse_d, nprobe)      # (Q, P)
    dists, ids = scan_probes(index, q, probe_ids)             # (Q, P, cap)
    qq = dists.shape[0]
    flat_d = dists.reshape(qq, -1)
    flat_ids = ids.reshape(qq, -1)
    vals, pos = topk_mod.masked_topk(flat_d, flat_ids >= 0, topk)
    return vals, topk_mod.gather_ids(flat_ids, pos)


@functools.partial(jax.jit, static_argnames=("nprobe", "topk"))
def search_ivf_precomputed_probes(index: IVFIndex, q: jax.Array,
                                  probe_ids: jax.Array, *, nprobe: int = 8,
                                  topk: int = 10) -> tuple[jax.Array, jax.Array]:
    """Fine stage only — probes come from an external coarse quantizer (HNSW).

    This is the paper's Table 1 pipeline: HNSW for coarse, fast-scan for fine.
    """
    if q.ndim == 1:
        q = q[None]
    probe_ids = probe_ids[:, :nprobe]
    dists, ids = scan_probes(index, q, probe_ids)
    qq = dists.shape[0]
    flat_d = dists.reshape(qq, -1)
    flat_ids = ids.reshape(qq, -1)
    vals, pos = topk_mod.masked_topk(flat_d, flat_ids >= 0, topk)
    return vals, topk_mod.gather_ids(flat_ids, pos)
