"""Search-quality metrics: Recall@R and distance-error statistics."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def recall_at_r(pred_ids: jax.Array, gt_ids: jax.Array, r: int | None = None) -> jax.Array:
    """Recall@R as in the paper's Fig. 2 / Table 1.

    pred_ids: (Q, R') predicted neighbor ids (ascending by distance).
    gt_ids:   (Q,) or (Q, G) ground-truth nearest ids; recall@R counts a hit
              if the true *first* NN appears in the top R predictions.
    """
    if gt_ids.ndim == 2:
        gt = gt_ids[:, 0]
    else:
        gt = gt_ids
    if r is not None:
        pred_ids = pred_ids[:, :r]
    hits = jnp.any(pred_ids == gt[:, None], axis=1)
    return jnp.mean(hits.astype(jnp.float32))


def intersection_recall(pred_ids: jax.Array, gt_ids: jax.Array) -> jax.Array:
    """|pred ∩ gt| / |gt| per query, averaged (the 'k-recall@k' variant)."""
    inter = (pred_ids[:, :, None] == gt_ids[:, None, :]).any(axis=1)
    return jnp.mean(jnp.mean(inter.astype(jnp.float32), axis=1))


def distance_error_stats(approx: jax.Array, exact: jax.Array) -> dict:
    """Relative distance-estimation error of the quantized ADC pipeline."""
    rel = jnp.abs(approx - exact) / jnp.maximum(jnp.abs(exact), 1e-12)
    return {
        "mean_rel_err": float(jnp.mean(rel)),
        "p95_rel_err": float(jnp.percentile(rel, 95)),
        "max_abs_err": float(jnp.max(jnp.abs(approx - exact))),
    }
