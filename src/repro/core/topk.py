"""Top-k selection: blocked tournament, masked, and distributed merge.

The distributed variant is how a 1000+-node deployment merges shard-local
fast-scan results: each device scans its own code shard, keeps k candidates,
and only 2k scalars per device cross the wire (all-gather + re-top-k).

Conventions (shared across ``repro.core``, see docs/architecture.md):
  shapes  all static — results always exactly k wide, padded when fewer
          candidates exist
  dtypes  distances float32 (ascending on return); ids/positions int32
  -1 id   sentinel — ``masked_topk`` emits position -1 (distance +inf) past
          the valid candidates and ``gather_ids`` propagates it, so -1 ids
          survive every merge layer unchanged
  filter  ``masked_topk``'s validity mask is also how filtering reaches
          selection: a filtered/namespaced candidate is masked invalid
          (distance +inf) *before* the top-k, never deleted — shapes stay
          static (docs/filtering.md). With an all-valid mask ``masked_topk``
          computes exactly ``smallest_k`` (the +inf substitution is the
          identity), which is why namespace-unrestricted queries are
          bit-identical to namespace-free ones
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

INF = jnp.float32(jnp.inf)


@functools.partial(jax.jit, static_argnames=("k",))
def smallest_k(dists: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """(..., N) -> (vals (..., k), ids (..., k)) ascending by distance."""
    neg, idx = jax.lax.top_k(-dists, k)
    return -neg, idx


@functools.partial(jax.jit, static_argnames=("k", "block"))
def tournament_topk(dists: jax.Array, k: int, block: int = 1024
                    ) -> tuple[jax.Array, jax.Array]:
    """Blocked top-k: per-block top-k then merge. O(N log k) instead of a
    full sort of N; mirrors the in-register candidate filtering of fast-scan.

    dists: (Q, N). Returns (vals (Q, k), ids (Q, k)) ascending.
    """
    q, n = dists.shape
    if n <= max(block, 2 * k):
        return smallest_k(dists, k)
    pad = (-n) % block
    if pad:
        dists = jnp.pad(dists, ((0, 0), (0, pad)), constant_values=INF)
    nb = dists.shape[1] // block
    d = dists.reshape(q, nb, block)
    kb = min(k, block)
    vals, idx = smallest_k(d, kb)  # (Q, nb, kb)
    gidx = idx + (jnp.arange(nb, dtype=idx.dtype) * block)[None, :, None]
    vals = vals.reshape(q, nb * kb)
    gidx = gidx.reshape(q, nb * kb)
    mvals, midx = smallest_k(vals, k)
    return mvals, jnp.take_along_axis(gidx, midx, axis=1)


@functools.partial(jax.jit, static_argnames=("k",))
def masked_topk(dists: jax.Array, valid: jax.Array, k: int
                ) -> tuple[jax.Array, jax.Array]:
    """Top-k over entries where valid; invalid slots return inf/-1."""
    d = jnp.where(valid, dists, INF)
    vals, idx = smallest_k(d, k)
    idx = jnp.where(jnp.isfinite(vals), idx, -1)
    return vals, idx


@jax.jit
def margin_prune_probes(vals: jax.Array, probes: jax.Array, tau: jax.Array
                        ) -> tuple[jax.Array, jax.Array]:
    """Adaptive-nprobe mask: drop probes outside the per-query margin.

    vals: (Q, P) coarse centroid distances aligned with probes (Q, P); slots
    already -1 must carry +inf vals. A probe survives iff its distance is
    within ``(1 + tau) * d0`` of the query's best probed centroid ``d0``.
    ``tau`` is traced (scalar or (Q,)), so per-query budgets recompile
    nothing; ``tau = +inf`` keeps every probe (bit-identical to fixed
    nprobe) — guarded explicitly so ``d0 == 0`` never turns ``0 * inf``
    into NaN — and the best probe always survives regardless of tau.

    Returns (probes with pruned slots set to -1, per-query pruned count).
    """
    tau = jnp.asarray(tau, jnp.float32)
    if tau.ndim == 1:
        tau = tau[:, None]
    present = probes >= 0
    d = jnp.where(present, vals, INF)
    d0 = jnp.min(d, axis=1, keepdims=True)
    keep = (d <= d0 * (1.0 + tau)) | jnp.isposinf(tau) | (d <= d0)
    pruned = jnp.sum((present & ~keep).astype(jnp.int32), axis=1)
    return jnp.where(keep, probes, -1), pruned


@jax.jit
def gather_ids(ids: jax.Array, pos: jax.Array) -> jax.Array:
    """Map masked_topk positions back to ids, preserving the -1 sentinel.

    ids: (Q, N); pos: (Q, k) from masked_topk (-1 = no candidate).
    """
    return jnp.where(
        pos >= 0, jnp.take_along_axis(ids, jnp.maximum(pos, 0), axis=1), -1)


def distributed_topk(local_dists: jax.Array, local_ids: jax.Array, k: int,
                     axis_name: str) -> tuple[jax.Array, jax.Array]:
    """Merge shard-local top-k across a mesh axis (call under shard_map/pmap).

    local_dists/local_ids: (Q, >=k) per shard, ids already global.
    Returns replicated (Q, k) merged results. Wire cost: 2k per device.
    """
    vals, idx = smallest_k(local_dists, min(k, local_dists.shape[-1]))
    ids = jnp.take_along_axis(local_ids, idx, axis=-1)
    all_vals = jax.lax.all_gather(vals, axis_name, axis=1)  # (Q, S, k)
    all_ids = jax.lax.all_gather(ids, axis_name, axis=1)
    q = all_vals.shape[0]
    flat_vals = all_vals.reshape(q, -1)
    flat_ids = all_ids.reshape(q, -1)
    mvals, midx = smallest_k(flat_vals, k)
    return mvals, jnp.take_along_axis(flat_ids, midx, axis=1)
