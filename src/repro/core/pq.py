"""Product quantization: codebook training, encoding, and float-LUT ADC.

This module is the **"original PQ" baseline** of the paper (Fig. 2's comparison
point): distances are estimated with a per-query float lookup table T[m][k] and
a memory-gather accumulation — exactly Eq. (2)/(3) of the paper.

The 4-bit fast-scan path (register-resident u8 LUTs) lives in
``repro.core.fastscan`` and ``repro.kernels``.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.kmeans import kmeans_multi, pairwise_sqdist


class PQCodebook(NamedTuple):
    """M sub-space codebooks. codewords: (M, K, dsub) with M*dsub == D."""

    codewords: jax.Array

    @property
    def m(self) -> int:
        return self.codewords.shape[0]

    @property
    def k(self) -> int:
        return self.codewords.shape[1]

    @property
    def dsub(self) -> int:
        return self.codewords.shape[2]

    @property
    def d(self) -> int:
        return self.m * self.dsub


def split_subvectors(x: jax.Array, m: int) -> jax.Array:
    """(n, D) -> (m, n, D/m)."""
    n, d = x.shape
    assert d % m == 0, f"D={d} not divisible by M={m}"
    return jnp.transpose(x.reshape(n, m, d // m), (1, 0, 2))


def train_pq(key: jax.Array, x: jax.Array, m: int, k: int = 16, iters: int = 25) -> PQCodebook:
    """Train M independent K-entry codebooks on training vectors x (n, D)."""
    sub = split_subvectors(x, m)  # (m, n, dsub)
    res = kmeans_multi(key, sub, k=k, iters=iters)
    return PQCodebook(codewords=res.centroids)


@jax.jit
def encode(cb: PQCodebook, x: jax.Array) -> jax.Array:
    """Quantize x (n, D) -> codes (n, M) int32 in [0, K)."""
    sub = split_subvectors(x, cb.m)  # (m, n, dsub)

    def enc_one(c_m, x_m):
        return jnp.argmin(pairwise_sqdist(x_m, c_m), axis=-1).astype(jnp.int32)

    codes = jax.vmap(enc_one)(cb.codewords, sub)  # (m, n)
    return codes.T  # (n, m)


@jax.jit
def decode(cb: PQCodebook, codes: jax.Array) -> jax.Array:
    """Lossy reconstruction: codes (n, M) -> (n, D)."""

    def dec_one(c_m, k_m):
        return c_m[k_m]  # (n, dsub)

    sub = jax.vmap(dec_one)(cb.codewords, codes.T)  # (m, n, dsub)
    return jnp.transpose(sub, (1, 0, 2)).reshape(codes.shape[0], -1)


@functools.partial(jax.jit, static_argnames=("metric",))
def adc_table(cb: PQCodebook, q: jax.Array, metric: str = "l2") -> jax.Array:
    """Per-query lookup table T (..., M, K).

    q: (D,) or (Q, D). metric 'l2' -> squared L2 per sub-space (paper Eq. (2));
    'ip' -> negated inner product (so that smaller is better for both metrics).
    """
    squeeze = q.ndim == 1
    if squeeze:
        q = q[None]
    qsub = split_subvectors(q, cb.m)  # (m, Q, dsub)
    if metric == "l2":
        t = jax.vmap(lambda c_m, q_m: pairwise_sqdist(q_m, c_m))(cb.codewords, qsub)
    elif metric == "ip":
        t = jax.vmap(lambda c_m, q_m: -(q_m @ c_m.T))(cb.codewords, qsub)
    else:
        raise ValueError(metric)
    t = jnp.transpose(t, (1, 0, 2))  # (Q, m, K)
    return t[0] if squeeze else t


@jax.jit
def adc_lookup(table: jax.Array, codes: jax.Array) -> jax.Array:
    """Naive PQ ADC (the paper's baseline): memory-gather + sum.

    table: (M, K) float or (Q, M, K); codes: (n, M) -> distances (n,) or (Q, n).
    """
    if table.ndim == 2:
        g = jax.vmap(lambda t_m, k_m: t_m[k_m], in_axes=(0, 1))(table, codes)  # (m, n)
        return jnp.sum(g, axis=0)
    return jax.vmap(lambda t: adc_lookup(t, codes))(table)


@functools.partial(jax.jit, static_argnames=("topk",))
def search(cb: PQCodebook, codes: jax.Array, q: jax.Array, topk: int = 10) -> tuple[jax.Array, jax.Array]:
    """Naive-PQ top-k search. q: (Q, D) -> (dists (Q, topk), ids (Q, topk))."""
    t = adc_table(cb, q)  # (Q, m, K)
    d = adc_lookup(t, codes)  # (Q, n)
    neg, idx = jax.lax.top_k(-d, topk)
    return -neg, idx
