"""HNSW graph: host-side (numpy) construction + jit'd batched beam search.

Used as the coarse quantizer of the paper's Table 1 pipeline
(IVF + HNSW + 4-bit PQ). Graph construction is pointer-chasing and therefore
host-side by design (it is an offline, one-time cost); the *search* — the
latency-critical part — is a fixed-shape JAX beam search that lowers under
jit/pjit (visited set as a dense bool mask, fixed-degree padded adjacency,
fixed iteration count).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class HNSWGraph(NamedTuple):
    vectors: jax.Array        # (N, D) float32 — the indexed points
    level0: jax.Array         # (N, 2M) int32 adjacency, -1 padded
    uppers: tuple             # tuple of (ids (n_l,), adj (n_l, M)) per level>0,
                              # ids sorted ascending; adj entries are global ids
    entry: int                # entry point id (top level)

    @property
    def n(self) -> int:
        return self.vectors.shape[0]


# ---------------------------------------------------------------------------
# construction (numpy, offline)
# ---------------------------------------------------------------------------

def _search_layer_np(vecs, adj, q, entry, ef):
    """Classic single-layer beam search (numpy, used only during build)."""
    import heapq
    visited = {entry}
    d0 = float(np.sum((vecs[entry] - q) ** 2))
    cand = [(d0, entry)]           # min-heap of candidates to expand
    best = [(-d0, entry)]          # max-heap (neg) of current best ef
    while cand:
        d, u = heapq.heappop(cand)
        if d > -best[0][0] and len(best) >= ef:
            break
        for v in adj[u]:
            if v < 0 or v in visited:
                continue
            visited.add(v)
            dv = float(np.sum((vecs[v] - q) ** 2))
            if len(best) < ef or dv < -best[0][0]:
                heapq.heappush(cand, (dv, v))
                heapq.heappush(best, (-dv, v))
                if len(best) > ef:
                    heapq.heappop(best)
    out = sorted((-nd, v) for nd, v in best)
    return [v for _, v in out], [d for d, _ in out]


def build_hnsw(vectors: np.ndarray, m: int = 16, ef_construction: int = 64,
               seed: int = 0) -> HNSWGraph:
    """Insert-based HNSW build. vectors: (N, D) float32."""
    rng = np.random.default_rng(seed)
    n, d = vectors.shape
    ml = 1.0 / np.log(m)
    levels = np.minimum((-np.log(rng.uniform(1e-12, 1.0, n)) * ml).astype(np.int64), 8)
    max_level = int(levels.max())
    deg0, degu = 2 * m, m
    adj = [np.full((n, deg0 if l == 0 else degu), -1, np.int64)
           for l in range(max_level + 1)]

    def connect(l, u, neighbors):
        cap = adj[l].shape[1]
        sel = neighbors[:cap]
        adj[l][u, :len(sel)] = sel
        for v in sel:  # back-links with pruning by distance
            row = adj[l][v]
            free = np.where(row < 0)[0]
            if len(free):
                row[free[0]] = u
            else:  # replace the farthest back-link if u is closer
                dists = np.sum((vectors[row] - vectors[v]) ** 2, axis=1)
                du = np.sum((vectors[u] - vectors[v]) ** 2)
                worst = int(np.argmax(dists))
                if du < dists[worst]:
                    row[worst] = u

    entry = 0
    entry_level = int(levels[0])
    for i in range(1, n):
        li = int(levels[i])
        ep = entry
        # greedy descent through levels above li
        for l in range(entry_level, li, -1):
            if l > max_level:
                continue
            changed = True
            while changed:
                changed = False
                neigh = adj[l][ep]
                neigh = neigh[neigh >= 0]
                if len(neigh):
                    dn = np.sum((vectors[neigh] - vectors[i]) ** 2, axis=1)
                    j = int(np.argmin(dn))
                    if dn[j] < np.sum((vectors[ep] - vectors[i]) ** 2):
                        ep = int(neigh[j])
                        changed = True
        # insert at levels min(li, entry_level) .. 0
        for l in range(min(li, entry_level), -1, -1):
            cands, _ = _search_layer_np(vectors, adj[l], vectors[i], ep, ef_construction)
            connect(l, i, np.asarray(cands, np.int64))
            ep = cands[0]
        if li > entry_level:
            entry, entry_level = i, li

    # pack upper levels as (ids, adj) pairs
    uppers = []
    for l in range(1, max_level + 1):
        ids = np.where(levels >= l)[0].astype(np.int32)
        uppers.append((jnp.asarray(ids), jnp.asarray(adj[l][ids].astype(np.int32))))
    return HNSWGraph(
        vectors=jnp.asarray(vectors.astype(np.float32)),
        level0=jnp.asarray(adj[0].astype(np.int32)),
        uppers=tuple(uppers),
        entry=int(entry),
    )


# ---------------------------------------------------------------------------
# search (JAX, jit'd, batched)
# ---------------------------------------------------------------------------

def _sqd(a: jax.Array, b: jax.Array) -> jax.Array:
    diff = a - b
    return jnp.sum(diff * diff, axis=-1)


@functools.partial(jax.jit, static_argnames=("ef", "topk", "iters"))
def search_hnsw(g: HNSWGraph, q: jax.Array, *, ef: int = 64, topk: int = 10,
                iters: int = 0) -> tuple[jax.Array, jax.Array]:
    """Batched HNSW search. q: (Q, D) -> (dists (Q, topk), ids (Q, topk)).

    Fixed-shape beam search at level 0 (beam = ef), greedy descent above.
    `iters` bounds the level-0 expansion count (default ~ 2*ef), making the
    whole search a static-length lax.while-free fori_loop — pjit-friendly.
    """
    if q.ndim == 1:
        q = q[None]
    nq = q.shape[0]
    n = g.n
    iters = iters or 2 * ef

    # --- greedy descent through upper layers (vectorized over queries)
    ep = jnp.full((nq,), g.entry, jnp.int32)
    for ids, adj in reversed(g.uppers):  # static python loop over levels
        # one hop per level is enough for coarse entry (standard practice:
        # repeat a few fixed hops for robustness)
        for _ in range(3):
            pos = jnp.searchsorted(ids, ep)  # position of ep rows in this level
            pos = jnp.clip(pos, 0, ids.shape[0] - 1)
            valid_row = ids[pos] == ep
            neigh = jnp.where(valid_row[:, None], adj[pos], -1)  # (Q, M)
            nv = jnp.maximum(neigh, 0)
            dn = _sqd(g.vectors[nv], q[:, None, :])
            dn = jnp.where(neigh >= 0, dn, jnp.inf)
            best = jnp.argmin(dn, axis=-1)
            bd = jnp.take_along_axis(dn, best[:, None], axis=1)[:, 0]
            cur = _sqd(g.vectors[ep], q)
            better = bd < cur
            ep = jnp.where(better, jnp.take_along_axis(neigh, best[:, None], axis=1)[:, 0], ep)

    # --- level-0 beam search with dense visited mask
    deg = g.level0.shape[1]
    beam_ids = jnp.full((nq, ef), -1, jnp.int32).at[:, 0].set(ep)
    beam_d = jnp.full((nq, ef), jnp.inf, jnp.float32).at[:, 0].set(_sqd(g.vectors[ep], q))
    expanded = jnp.zeros((nq, ef), jnp.bool_)
    visited = jnp.zeros((nq, n), jnp.bool_).at[jnp.arange(nq), ep].set(True)

    def body(_, state):
        beam_ids, beam_d, expanded, visited = state
        # pick nearest unexpanded beam entry
        cand_d = jnp.where(expanded | (beam_ids < 0), jnp.inf, beam_d)
        sel = jnp.argmin(cand_d, axis=-1)                      # (Q,)
        sel_id = jnp.take_along_axis(beam_ids, sel[:, None], axis=1)[:, 0]
        has = jnp.isfinite(jnp.take_along_axis(cand_d, sel[:, None], axis=1)[:, 0])
        expanded = expanded.at[jnp.arange(nq), sel].set(True)
        neigh = g.level0[jnp.maximum(sel_id, 0)]               # (Q, deg)
        neigh = jnp.where((neigh >= 0) & has[:, None], neigh, -1)
        seen = jnp.take_along_axis(visited, jnp.maximum(neigh, 0), axis=1)
        fresh = (neigh >= 0) & (~seen)
        visited = visited.at[jnp.arange(nq)[:, None], jnp.maximum(neigh, 0)].set(
            jnp.take_along_axis(visited, jnp.maximum(neigh, 0), axis=1) | (neigh >= 0))
        dn = _sqd(g.vectors[jnp.maximum(neigh, 0)], q[:, None, :])
        dn = jnp.where(fresh, dn, jnp.inf)
        # merge (beam, new) -> best ef
        all_d = jnp.concatenate([beam_d, dn], axis=1)          # (Q, ef+deg)
        all_ids = jnp.concatenate([beam_ids, neigh], axis=1)
        all_exp = jnp.concatenate([expanded, jnp.zeros_like(fresh)], axis=1)
        neg, pos = jax.lax.top_k(-all_d, ef)
        beam_d = -neg
        beam_ids = jnp.take_along_axis(all_ids, pos, axis=1)
        expanded = jnp.take_along_axis(all_exp, pos, axis=1)
        return beam_ids, beam_d, expanded, visited

    beam_ids, beam_d, expanded, visited = jax.lax.fori_loop(
        0, iters, body, (beam_ids, beam_d, expanded, visited))
    neg, pos = jax.lax.top_k(-beam_d[:, :ef], topk)
    return -neg, jnp.take_along_axis(beam_ids, pos, axis=1)
