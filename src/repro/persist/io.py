"""Durable-I/O primitives: the single seam every persistence byte crosses.

All snapshot and WAL bytes go through the four module-level functions below
(``write_bytes`` / ``read_bytes`` / ``append_record`` / ``fsync_dir``), so
the fault-injection harness (``tests/faults.py``) can deterministically
inject torn writes, bit flips, and short reads by wrapping exactly these —
no fault path exists that the harness cannot reach.

Durability contract (docs/persistence.md):

  - ``atomic_write_bytes`` is the only way a *named* snapshot/manifest file
    comes into existence: full bytes to a temp file, ``fsync``, then
    ``os.replace`` + directory fsync. A crash at any step leaves either the
    old file or the new file, never a torn one under its real name.
  - ``append_record`` fsyncs before returning — a WAL append that returned
    is on disk; the caller may acknowledge the mutation.
"""
from __future__ import annotations

import os
import zlib


def crc32(data: bytes) -> int:
    """Unsigned CRC-32 of ``data`` (zlib polynomial, masked to 32 bits)."""
    return zlib.crc32(data) & 0xFFFFFFFF


def write_bytes(path: str, data: bytes) -> None:
    """Write ``data`` to ``path`` and fsync the file. Patchable primitive."""
    with open(path, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())


def read_bytes(path: str) -> bytes:
    """Read the whole file at ``path``. Patchable primitive."""
    with open(path, "rb") as f:
        return f.read()


def append_record(f, data: bytes) -> None:
    """Append ``data`` to the open binary file ``f`` and fsync.

    The WAL's acknowledge point: when this returns, the record survives
    kill-9. Patchable primitive.
    """
    f.write(data)
    f.flush()
    os.fsync(f.fileno())


def append_bytes(f, data: bytes) -> None:
    """Append ``data`` WITHOUT fsync — the group-commit half of the WAL
    write path (``WALWriter(fsync_interval=...)``): bytes reach the OS, the
    durability point is the next ``fsync_file``. Patchable primitive.
    """
    f.write(data)
    f.flush()


def fsync_file(f) -> None:
    """fsync an open file — the deferred half of a group commit. Patchable
    primitive."""
    os.fsync(f.fileno())


def fsync_dir(path: str) -> None:
    """fsync a directory so a rename/create inside it is durable.

    Best-effort: some filesystems refuse O_RDONLY fsync on directories;
    the rename itself is still atomic there.
    """
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def atomic_write_bytes(path: str, data: bytes) -> None:
    """Publish ``data`` at ``path`` via temp file + fsync + atomic rename.

    The temp file lives next to the target (same filesystem, so the rename
    is atomic) and carries the pid so concurrent writers never collide.
    """
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        write_bytes(tmp, data)
        os.replace(tmp, path)
    finally:
        # a failed (torn) write must not leave the temp file behind — it is
        # unnamed garbage either way, but tests assert clean directories
        if os.path.exists(tmp):
            try:
                os.remove(tmp)
            except OSError:
                pass
    fsync_dir(os.path.dirname(path) or ".")
