"""Write-ahead mutation log: fixed-format, checksummed, fsync'd records.

Every mutation (``upsert`` / ``delete`` / ``compact``) appends ONE record —
fsync'd *before* the engine installs the new in-memory epoch — so recovery
is always "last snapshot + replay" and a crash can lose at most the
unacknowledged tail (docs/persistence.md).

On-disk record format (little-endian, 28-byte preamble + payload):

    u32  magic       0x4C415752 ("RWAL")
    u8   op          1=upsert 2=delete 3=compact
    u8   flags       0 (reserved)
    u16  reserved    0
    u64  seq         global record number, contiguous from 1
    u32  payload_len
    u32  payload_crc CRC-32 of the payload bytes
    u32  header_crc  CRC-32 of the preceding 24 header bytes
    ...  payload     ``np.savez`` archive of the mutation's arrays

Torn-tail vs corruption: a record cut short by EOF (crash mid-append) is a
*clean* stop — ``scan_wal`` returns the valid prefix and flags the tail.
A record whose bytes are all present but whose CRC fails (bit flip), whose
magic is wrong, or that is torn with more data after it, raises
``CorruptWALError``: acknowledged mutations may be missing and silently
replaying the rest would build a wrong index.

WAL files are named ``wal-<start_seq:012d>.log`` so a directory's files
chain in seq order; ``rotate`` (the checkpoint path) closes the current
file and opens the next, and GC deletes files whose records a durable
snapshot fully covers.
"""
from __future__ import annotations

import io as _io
import os
import re
import struct
import threading
from typing import Iterator, NamedTuple

import numpy as np

from repro.persist import io as pio
from repro.persist.errors import CorruptWALError

_MAGIC = 0x4C415752  # "RWAL" little-endian
_HEADER = struct.Struct("<IBBHQII")   # magic, op, flags, reserved, seq, len, crc
_HEADER_CRC = struct.Struct("<I")
PREAMBLE = _HEADER.size + _HEADER_CRC.size  # 28 bytes

OP_UPSERT = 1
OP_DELETE = 2
OP_COMPACT = 3
_OP_NAMES = {OP_UPSERT: "upsert", OP_DELETE: "delete", OP_COMPACT: "compact"}
_OP_CODES = {v: k for k, v in _OP_NAMES.items()}

_WAL_RE = re.compile(r"^wal-(\d{12})\.log$")


def wal_name(start_seq: int) -> str:
    """File name of the WAL segment whose first record is ``start_seq``."""
    return f"wal-{start_seq:012d}.log"


def wal_files(directory: str) -> list[tuple[int, str]]:
    """(start_seq, path) of every WAL file in ``directory``, seq-ascending."""
    out = []
    for name in os.listdir(directory):
        m = _WAL_RE.match(name)
        if m:
            out.append((int(m.group(1)), os.path.join(directory, name)))
    out.sort()
    return out


class WALRecord(NamedTuple):
    seq: int
    op: str                        # 'upsert' | 'delete' | 'compact'
    arrays: dict[str, np.ndarray]  # the mutation's payload arrays


def encode_record(seq: int, op: str, arrays: dict[str, np.ndarray]) -> bytes:
    """One record's bytes: checksummed preamble + npz payload."""
    bio = _io.BytesIO()
    np.savez(bio, **arrays)
    payload = bio.getvalue()
    head = _HEADER.pack(_MAGIC, _OP_CODES[op], 0, 0, int(seq), len(payload),
                        pio.crc32(payload))
    return head + _HEADER_CRC.pack(pio.crc32(head)) + payload


def _decode_payload(payload: bytes) -> dict[str, np.ndarray]:
    with np.load(_io.BytesIO(payload), allow_pickle=False) as z:
        return {k: z[k] for k in z.files}


def scan_wal(path: str) -> tuple[list[WALRecord], int, bool]:
    """Parse one WAL file: (records, valid_byte_length, clean).

    ``clean=False`` means the file ends in a torn record (crash mid-append):
    the returned records are the trustworthy prefix and ``valid_byte_length``
    is where it ends — the caller may truncate there before appending.
    Anything that is NOT a clean torn tail — bad magic, failed header or
    payload CRC on fully-present bytes — raises ``CorruptWALError``.
    """
    data = pio.read_bytes(path)
    records: list[WALRecord] = []
    off = 0
    n = len(data)
    while off < n:
        if n - off < PREAMBLE:
            return records, off, False          # torn header at EOF
        head = data[off:off + _HEADER.size]
        (magic, op_code, _flags, _rsvd, seq, plen,
         pcrc) = _HEADER.unpack(head)
        (hcrc,) = _HEADER_CRC.unpack(
            data[off + _HEADER.size:off + PREAMBLE])
        if hcrc != pio.crc32(head):
            raise CorruptWALError(
                f"{path}: header CRC mismatch at offset {off}")
        if magic != _MAGIC or op_code not in _OP_NAMES:
            raise CorruptWALError(
                f"{path}: bad record magic/op at offset {off}")
        if n - off - PREAMBLE < plen:
            return records, off, False          # torn payload at EOF
        payload = data[off + PREAMBLE:off + PREAMBLE + plen]
        if pio.crc32(payload) != pcrc:
            raise CorruptWALError(
                f"{path}: payload CRC mismatch at offset {off} (seq {seq})")
        try:
            arrays = _decode_payload(payload)
        except Exception as e:  # zipfile/np.load damage the CRC missed
            raise CorruptWALError(
                f"{path}: undecodable payload at offset {off}: {e}") from e
        records.append(WALRecord(int(seq), _OP_NAMES[op_code], arrays))
        off += PREAMBLE + plen
    return records, off, True


def iter_wal(directory: str, after_seq: int = 0) -> Iterator[WALRecord]:
    """Replay-ordered records with seq > ``after_seq`` across the file chain.

    Enforces the recovery contract: records must be contiguous from
    ``after_seq + 1`` (a gap means a missing WAL file — acknowledged
    mutations lost in the *middle*, so ``CorruptWALError``), and only the
    FINAL file may end torn (a torn earlier file likewise hides
    acknowledged mutations that later files prove existed).
    """
    files = wal_files(directory)
    expect = int(after_seq) + 1
    for i, (_start, path) in enumerate(files):
        records, _valid, clean = scan_wal(path)
        if not clean and i != len(files) - 1:
            raise CorruptWALError(
                f"{path}: torn record in a non-final WAL file")
        for rec in records:
            if rec.seq <= after_seq:
                continue
            if rec.seq != expect:
                raise CorruptWALError(
                    f"{path}: sequence gap — expected seq {expect}, found "
                    f"{rec.seq} (a WAL file is missing or out of order)")
            yield rec
            expect += 1


class WALWriter:
    """Append-side of the log: one open file, fsync per record.

    Thread-safe (the engines call ``log_*`` under their own mutation lock,
    but the checkpointer rotates from another thread). ``seq`` is global
    and survives rotation — the next record after ``rotate`` lands in the
    new file with the next contiguous number.
    """

    def __init__(self, path: str, next_seq: int):
        self.path = path
        self._f = open(path, "ab")
        self._next = int(next_seq)
        self._written_here = 0  # records appended to the CURRENT file
        self._lock = threading.Lock()

    @property
    def last_seq(self) -> int:
        """Seq of the last appended record (0 before the first)."""
        with self._lock:
            return self._next - 1

    def append(self, op: str, arrays: dict[str, np.ndarray]) -> int:
        """Encode + append + fsync one record; returns its seq."""
        with self._lock:
            seq = self._next
            pio.append_record(self._f, encode_record(seq, op, arrays))
            self._next += 1
            self._written_here += 1
            return seq

    # -- the engine-facing hooks (docs/persistence.md) ----------------------

    def log_upsert(self, ids: np.ndarray, vecs: np.ndarray,
                   attrs: np.ndarray | None = None) -> int:
        arrays = {"ids": np.asarray(ids, np.int64),
                  "vecs": np.asarray(vecs, np.float32)}
        if attrs is not None:
            arrays["attrs"] = np.asarray(attrs, np.int32)
        return self.append("upsert", arrays)

    def log_delete(self, ids: np.ndarray) -> int:
        return self.append("delete", {"ids": np.asarray(ids, np.int64)})

    def log_compact(self, cap: int | None) -> int:
        return self.append(
            "compact", {"cap": np.asarray(-1 if cap is None else cap,
                                          np.int64)})

    # -- checkpoint-side ----------------------------------------------------

    def rotate(self, directory: str) -> str:
        """Close the current file and start ``wal-<next_seq>.log``.

        No-op when the current file holds no records yet (back-to-back
        checkpoints with no intervening mutations would otherwise mint a
        same-named file). Returns the active path.
        """
        with self._lock:
            if self._written_here == 0:
                return self.path
            self._f.close()
            self.path = os.path.join(directory, wal_name(self._next))
            self._f = open(self.path, "ab")
            self._written_here = 0
            pio.fsync_dir(directory)
            return self.path

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._f.close()


def apply_record(engine, rec: WALRecord) -> None:
    """Apply one replayed record through the engine's own mutators.

    The mutators are deterministic functions of (state, arguments) — the
    exactness spine of docs/mutability.md — so replaying the logged
    arguments reproduces bit-identical state. The caller must have the
    engine's WAL detached (or never attached): replay must not re-log.
    """
    a = rec.arrays
    if rec.op == "upsert":
        engine.upsert(a["ids"], a["vecs"], attrs=a.get("attrs"))
    elif rec.op == "delete":
        engine.delete(a["ids"])
    elif rec.op == "compact":
        cap = int(a["cap"])
        engine.compact(cap=None if cap < 0 else cap)
    else:  # pragma: no cover - scan_wal already rejects unknown ops
        raise CorruptWALError(f"unknown WAL op {rec.op!r}")
