"""Write-ahead mutation log: fixed-format, checksummed, fsync'd records.

Every mutation (``upsert`` / ``delete`` / ``compact``) appends ONE record —
fsync'd *before* the engine installs the new in-memory epoch — so recovery
is always "last snapshot + replay" and a crash can lose at most the
unacknowledged tail (docs/persistence.md).

On-disk record format (little-endian, 28-byte preamble + payload):

    u32  magic       0x4C415752 ("RWAL")
    u8   op          1=upsert 2=delete 3=compact
    u8   flags       0 (reserved)
    u16  reserved    0
    u64  seq         global record number, contiguous from 1
    u32  payload_len
    u32  payload_crc CRC-32 of the payload bytes
    u32  header_crc  CRC-32 of the preceding 24 header bytes
    ...  payload     ``np.savez`` archive of the mutation's arrays

Torn-tail vs corruption: a record cut short by EOF (crash mid-append) is a
*clean* stop — ``scan_wal`` returns the valid prefix and flags the tail.
A record whose bytes are all present but whose CRC fails (bit flip), whose
magic is wrong, or that is torn with more data after it, raises
``CorruptWALError``: acknowledged mutations may be missing and silently
replaying the rest would build a wrong index.

WAL files are named ``wal-<start_seq:012d>.log`` so a directory's files
chain in seq order; ``rotate`` (the checkpoint path) closes the current
file and opens the next, and GC deletes files whose records a durable
snapshot fully covers.

Since the replication tier (docs/persistence.md, ``persist.replicate``)
every NEW WAL file opens with a 24-byte file header carrying the writer's
**term** — the fencing token a promotion bumps — and the file's start seq,
so a shipped or recovered segment always knows which leadership era wrote
it. Headerless files (pre-replication format) still parse; they read as
term 0.
"""
from __future__ import annotations

import io as _io
import os
import re
import struct
import threading
import time
from typing import Callable, Iterator, NamedTuple

import numpy as np

from repro.persist import io as pio
from repro.persist.errors import CorruptWALError

_MAGIC = 0x4C415752  # "RWAL" little-endian
_HEADER = struct.Struct("<IBBHQII")   # magic, op, flags, reserved, seq, len, crc
_HEADER_CRC = struct.Struct("<I")
PREAMBLE = _HEADER.size + _HEADER_CRC.size  # 28 bytes

_FILE_MAGIC = 0x484C5752  # "RWLH" little-endian — per-file header, not a record
_FILE_HEADER = struct.Struct("<IQQI")  # magic, term, start_seq, crc of first 20
FILE_HEADER_SIZE = _FILE_HEADER.size   # 24 bytes

OP_UPSERT = 1
OP_DELETE = 2
OP_COMPACT = 3
_OP_NAMES = {OP_UPSERT: "upsert", OP_DELETE: "delete", OP_COMPACT: "compact"}
_OP_CODES = {v: k for k, v in _OP_NAMES.items()}

_WAL_RE = re.compile(r"^wal-(\d{12})\.log$")


def wal_name(start_seq: int) -> str:
    """File name of the WAL segment whose first record is ``start_seq``."""
    return f"wal-{start_seq:012d}.log"


def wal_files(directory: str) -> list[tuple[int, str]]:
    """(start_seq, path) of every WAL file in ``directory``, seq-ascending."""
    out = []
    for name in os.listdir(directory):
        m = _WAL_RE.match(name)
        if m:
            out.append((int(m.group(1)), os.path.join(directory, name)))
    out.sort()
    return out


class WALRecord(NamedTuple):
    seq: int
    op: str                        # 'upsert' | 'delete' | 'compact'
    arrays: dict[str, np.ndarray]  # the mutation's payload arrays


def encode_file_header(term: int, start_seq: int) -> bytes:
    """24-byte per-file header: term + start seq, self-CRC'd."""
    head = _FILE_HEADER.pack(_FILE_MAGIC, int(term), int(start_seq), 0)[:20]
    return head + struct.pack("<I", pio.crc32(head))


def read_file_header(data: bytes) -> tuple[int, int] | None:
    """(term, start_seq) if ``data`` opens with a complete, valid file
    header; None for the pre-replication headerless format (or for data
    shorter than a header — a crash between header write and first append
    leaves such a prefix, which the record scan then reports as an empty
    torn file). A COMPLETE header whose CRC fails is a bit flip, not a
    tear, and raises ``CorruptWALError``."""
    if len(data) < FILE_HEADER_SIZE:
        return None
    magic, term, start_seq, crc = _FILE_HEADER.unpack(
        data[:FILE_HEADER_SIZE])
    if magic != _FILE_MAGIC:
        return None
    if crc != pio.crc32(data[:20]):
        raise CorruptWALError("WAL file header failed its CRC check")
    return int(term), int(start_seq)


def wal_term(path: str) -> int:
    """Term recorded in the file's header (0 for headerless legacy files)."""
    with open(path, "rb") as f:
        head = f.read(FILE_HEADER_SIZE)
    if len(head) < FILE_HEADER_SIZE:
        return 0
    try:
        parsed = read_file_header(head)
    except CorruptWALError:
        return 0
    return 0 if parsed is None else parsed[0]


def encode_record(seq: int, op: str, arrays: dict[str, np.ndarray]) -> bytes:
    """One record's bytes: checksummed preamble + npz payload."""
    bio = _io.BytesIO()
    np.savez(bio, **arrays)
    payload = bio.getvalue()
    head = _HEADER.pack(_MAGIC, _OP_CODES[op], 0, 0, int(seq), len(payload),
                        pio.crc32(payload))
    return head + _HEADER_CRC.pack(pio.crc32(head)) + payload


def _decode_payload(payload: bytes) -> dict[str, np.ndarray]:
    with np.load(_io.BytesIO(payload), allow_pickle=False) as z:
        return {k: z[k] for k in z.files}


def scan_wal(path: str) -> tuple[list[WALRecord], int, bool]:
    """Parse one WAL file: (records, valid_byte_length, clean).

    ``clean=False`` means the file ends in a torn record (crash mid-append):
    the returned records are the trustworthy prefix and ``valid_byte_length``
    is where it ends — the caller may truncate there before appending.
    Anything that is NOT a clean torn tail — bad magic, failed header or
    payload CRC on fully-present bytes — raises ``CorruptWALError``.
    """
    return scan_wal_bytes(pio.read_bytes(path), origin=path)


def scan_wal_bytes(data: bytes, origin: str = "<bytes>"
                   ) -> tuple[list[WALRecord], int, bool]:
    """``scan_wal`` over in-memory segment bytes (the shipped-segment path:
    a standby verifies and replays segments it never writes to disk). A
    leading file header, if present, is CRC-checked and skipped."""
    records: list[WALRecord] = []
    off = 0
    n = len(data)
    try:
        header = read_file_header(data)
    except CorruptWALError as e:
        raise CorruptWALError(f"{origin}: {e}") from None
    if header is not None:
        off = FILE_HEADER_SIZE
    path = origin
    while off < n:
        if n - off < PREAMBLE:
            return records, off, False          # torn header at EOF
        head = data[off:off + _HEADER.size]
        (magic, op_code, _flags, _rsvd, seq, plen,
         pcrc) = _HEADER.unpack(head)
        (hcrc,) = _HEADER_CRC.unpack(
            data[off + _HEADER.size:off + PREAMBLE])
        if hcrc != pio.crc32(head):
            raise CorruptWALError(
                f"{path}: header CRC mismatch at offset {off}")
        if magic != _MAGIC or op_code not in _OP_NAMES:
            raise CorruptWALError(
                f"{path}: bad record magic/op at offset {off}")
        if n - off - PREAMBLE < plen:
            return records, off, False          # torn payload at EOF
        payload = data[off + PREAMBLE:off + PREAMBLE + plen]
        if pio.crc32(payload) != pcrc:
            raise CorruptWALError(
                f"{path}: payload CRC mismatch at offset {off} (seq {seq})")
        try:
            arrays = _decode_payload(payload)
        except Exception as e:  # zipfile/np.load damage the CRC missed
            raise CorruptWALError(
                f"{path}: undecodable payload at offset {off}: {e}") from e
        records.append(WALRecord(int(seq), _OP_NAMES[op_code], arrays))
        off += PREAMBLE + plen
    return records, off, True


def iter_wal(directory: str, after_seq: int = 0) -> Iterator[WALRecord]:
    """Replay-ordered records with seq > ``after_seq`` across the file chain.

    Enforces the recovery contract: records must be contiguous from
    ``after_seq + 1`` (a gap means a missing WAL file — acknowledged
    mutations lost in the *middle*, so ``CorruptWALError``), and only the
    FINAL file may end torn (a torn earlier file likewise hides
    acknowledged mutations that later files prove existed).
    """
    files = wal_files(directory)
    expect = int(after_seq) + 1
    for i, (_start, path) in enumerate(files):
        records, _valid, clean = scan_wal(path)
        if not clean and i != len(files) - 1:
            raise CorruptWALError(
                f"{path}: torn record in a non-final WAL file")
        for rec in records:
            if rec.seq <= after_seq:
                continue
            if rec.seq != expect:
                raise CorruptWALError(
                    f"{path}: sequence gap — expected seq {expect}, found "
                    f"{rec.seq} (a WAL file is missing or out of order)")
            yield rec
            expect += 1


class WALWriter:
    """Append-side of the log: one open file, fsync per record.

    Thread-safe (the engines call ``log_*`` under their own mutation lock,
    but the checkpointer rotates from another thread). ``seq`` is global
    and survives rotation — the next record after ``rotate`` lands in the
    new file with the next contiguous number.

    ``term`` is the fencing token of docs/persistence.md: it is stamped
    into every file header this writer creates, and an optional ``guard``
    callable runs before every append — the replication tier installs one
    that raises ``FencedError`` once a newer term exists, so a deposed
    primary cannot extend its log even by one record.

    ``fsync_interval`` enables **group commit**: appends write to the OS
    immediately but the fsync is deferred until the interval elapses (or
    an explicit ``flush``/``rotate``/``close``). Throughput per mutation
    burst rises by the batched-fsync factor; the durability point of an
    individual record widens to at most one interval — choose per
    deployment (docs/persistence.md#group-commit).
    """

    def __init__(self, path: str, next_seq: int, *, term: int = 0,
                 fsync_interval: float | None = None,
                 guard: Callable[[], None] | None = None):
        self.path = path
        self.term = int(term)
        self.guard = guard
        if fsync_interval is not None and fsync_interval < 0:
            raise ValueError(
                f"fsync_interval must be >= 0, got {fsync_interval}")
        self.fsync_interval = fsync_interval
        self._f = open(path, "ab")
        self._next = int(next_seq)
        self._written_here = 0  # records appended to the CURRENT file
        self._pending_fsync = 0  # group-commit records not yet fsync'd
        self._last_fsync = time.monotonic()
        self._lock = threading.Lock()
        self._write_header_if_new()

    def _write_header_if_new(self) -> None:
        # a brand-new file opens with the term header; a reopened file
        # (recovery attaching at an existing path) keeps whatever it has
        self._f.seek(0, os.SEEK_END)
        if self._f.tell() == 0:
            pio.append_record(self._f, encode_file_header(self.term,
                                                          self._next))

    @property
    def last_seq(self) -> int:
        """Seq of the last appended record (0 before the first)."""
        with self._lock:
            return self._next - 1

    def append(self, op: str, arrays: dict[str, np.ndarray]) -> int:
        """Encode + append one record; returns its seq.

        Without ``fsync_interval`` the record is fsync'd before this
        returns (the classic acknowledge point). With it, the fsync may be
        deferred up to one interval (group commit). Either way the bytes
        are written in seq order, so a crash still tears only the tail.
        """
        with self._lock:
            if self.guard is not None:
                self.guard()
            seq = self._next
            data = encode_record(seq, op, arrays)
            if self.fsync_interval is None:
                pio.append_record(self._f, data)
            else:
                pio.append_bytes(self._f, data)
                self._pending_fsync += 1
                now = time.monotonic()
                if now - self._last_fsync >= self.fsync_interval:
                    pio.fsync_file(self._f)
                    self._pending_fsync = 0
                    self._last_fsync = now
            self._next += 1
            self._written_here += 1
            return seq

    def flush(self) -> None:
        """Force the group-commit tail to disk (no-op when nothing is
        pending or every append already fsync'd). After this returns every
        appended record survives kill-9."""
        with self._lock:
            self._flush_locked()

    def _flush_locked(self) -> None:
        if self._pending_fsync and not self._f.closed:
            pio.fsync_file(self._f)
            self._pending_fsync = 0
            self._last_fsync = time.monotonic()

    # -- the engine-facing hooks (docs/persistence.md) ----------------------

    def log_upsert(self, ids: np.ndarray, vecs: np.ndarray,
                   attrs: np.ndarray | None = None) -> int:
        arrays = {"ids": np.asarray(ids, np.int64),
                  "vecs": np.asarray(vecs, np.float32)}
        if attrs is not None:
            arrays["attrs"] = np.asarray(attrs, np.int32)
        return self.append("upsert", arrays)

    def log_delete(self, ids: np.ndarray) -> int:
        return self.append("delete", {"ids": np.asarray(ids, np.int64)})

    def log_compact(self, cap: int | None) -> int:
        return self.append(
            "compact", {"cap": np.asarray(-1 if cap is None else cap,
                                          np.int64)})

    # -- checkpoint-side ----------------------------------------------------

    def rotate(self, directory: str) -> str:
        """Close the current file and start ``wal-<next_seq>.log``.

        No-op when the current file holds no records yet (back-to-back
        checkpoints with no intervening mutations would otherwise mint a
        same-named file). Flushes any group-commit tail first — a closed
        (shippable) segment is always fully durable. Returns the active
        path.
        """
        with self._lock:
            if self._written_here == 0:
                return self.path
            self._flush_locked()
            self._f.close()
            self.path = os.path.join(directory, wal_name(self._next))
            self._f = open(self.path, "ab")
            self._written_here = 0
            self._write_header_if_new()
            pio.fsync_dir(directory)
            return self.path

    def close(self) -> None:
        with self._lock:
            if not self._f.closed:
                self._flush_locked()
                self._f.close()


def apply_record(engine, rec: WALRecord) -> None:
    """Apply one replayed record through the engine's own mutators.

    The mutators are deterministic functions of (state, arguments) — the
    exactness spine of docs/mutability.md — so replaying the logged
    arguments reproduces bit-identical state. The caller must have the
    engine's WAL detached (or never attached): replay must not re-log.
    """
    a = rec.arrays
    if rec.op == "upsert":
        engine.upsert(a["ids"], a["vecs"], attrs=a.get("attrs"))
    elif rec.op == "delete":
        engine.delete(a["ids"])
    elif rec.op == "compact":
        cap = int(a["cap"])
        engine.compact(cap=None if cap < 0 else cap)
    else:  # pragma: no cover - scan_wal already rejects unknown ops
        raise CorruptWALError(f"unknown WAL op {rec.op!r}")
