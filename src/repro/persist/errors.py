"""Typed failure modes of the durable-index subsystem (docs/persistence.md).

The recovery contract is *prefix-or-loud*: opening a directory either yields
an engine bit-identical to the never-crashed engine over some prefix of the
acknowledged mutations, or raises one of these — never a silently wrong
index. Checksums turn every byte-level fault (bit flip, short read, torn
segment) into one of the typed errors below; the only faults that do NOT
raise are the ones that by construction lose nothing but an unacknowledged
tail (a torn final WAL record, a crash before the manifest rename).
"""
from __future__ import annotations


class PersistError(RuntimeError):
    """Base class of every durable-index failure."""


class NoSnapshotError(PersistError):
    """The directory holds no manifest — nothing was ever checkpointed
    there (or the manifest itself was deleted). Distinct from corruption so
    boot logic can branch on fresh-dir vs damaged-dir."""


class CorruptSnapshotError(PersistError):
    """A manifest-named segment is missing, truncated, or fails its CRC —
    the snapshot cannot be trusted and is refused wholesale."""


class CorruptWALError(PersistError):
    """A write-ahead-log record that *should* be intact is not: bad magic,
    a failed header/payload CRC with the full record present, a torn record
    that is not the final one, or a sequence gap (a missing WAL file).
    A torn tail on the FINAL file is not an error — it is the expected
    signature of a crash mid-append and recovery keeps the valid prefix."""


class FencedError(PersistError):
    """A write from a superseded term was rejected: the cluster promoted a
    new primary (its term is higher than the writer's), so the old primary
    must stop appending and shipping IMMEDIATELY. This is the split-brain
    guard of docs/persistence.md — the fenced process keeps its local
    state (useful for forensics) but no byte of it reaches the replication
    stream or the shared term authority again."""


class ReplicationError(PersistError):
    """The shipped-WAL chain cannot be followed safely: a gap in the
    shipped sequence (a dropped segment), a transport that kept failing
    past the bounded retry budget, or an undecodable ship frame. The
    standby must stop replaying and resync from a snapshot rather than
    serve a silently diverged index."""
