"""Replication tier: WAL shipping, warm standbys, fenced failover.

Layered directly on the durable-index contract (docs/persistence.md): the
primary's WAL is already a totally-ordered, checksummed, prefix-or-loud
record of every acknowledged mutation, so replication is *shipping that
log* — no second serialization format, no divergent code path.

    primary                     transport                  standby
    -------                     ---------                  -------
    WALShipper.ship_once  --->  publish(seg)   --->  StandbyReplica.poll_once
      rotate + read closed        fenced by TERM         verify frame CRC
      wal-*.log segments,         (atomic files or       scan_wal_bytes,
      wrap in ship frames         in-process pipe)       apply_record past
                                                         applied_seq

**Fencing** makes split-brain structurally impossible: the transport holds
a monotonically increasing *term* — the leadership token. Every shipped
frame and every WAL file header carries the term it was written under;
``promote()`` bumps the transport term atomically, and from that instant
the old primary's next append (via the writer ``guard``) or ship (via the
``read_term`` check and the transport's own publish-side check) raises
``FencedError``. Those checks are best-effort (check-then-act), so a
deposed primary's in-flight publish can still *land* — which is why the
stream is also fenced structurally: segment names are **term-scoped**
(``t<term>-wal-<seq>.log``), so a stale publish can never overwrite or
sort after a newer term's segment, and the transport keeps a **term
chart** — for every term ever promoted, the first sequence number of its
chain. A record from term ``t`` at seq ``s`` is a fenced leftover exactly
when some newer term's chain starts at or before ``s``; standbys skip
such records (counting them in ``records_stale``) instead of replaying
them, so even a publish that slips past the fence is inert.

**Lag** is tracked in both units that matter operationally: sequence
numbers behind the primary's last heartbeat, and seconds since that
heartbeat was minted (``ReplicationLag``).

Failure handling is *bounded-retry, then loud*: transient transport
errors are retried with exponential backoff inside a per-segment time
budget; a gap in the shipped chain, an undecodable frame, or a torn
shipped segment raises ``ReplicationError`` — a standby must resync from
a snapshot rather than serve a silently diverged index.
"""
from __future__ import annotations

import json
import os
import re
import struct
import threading
import time
from typing import Callable, NamedTuple

from repro.persist import io as pio
from repro.persist import wal as wal_mod
from repro.persist.errors import FencedError, ReplicationError

_SHIP_MAGIC = 0x50485352  # "RSHP" little-endian
_SHIP_HEADER = struct.Struct("<IQQII")  # magic, term, start_seq, len, crc
SHIP_HEADER_SIZE = _SHIP_HEADER.size + 4  # + u32 header CRC = 32 bytes

_SEG_PREFIX = "seg-"
_TERM_NAME = "TERM"
_SHIP_NAME_RE = re.compile(r"^t(\d{12})-(.+)$")


def ship_segment_name(term: int, wal_name: str) -> str:
    """Term-scoped transport name for one WAL segment.

    The zero-padded term prefix makes the published namespace term-scoped:
    lexicographic order is exactly (term, seq) replay order, and a deposed
    primary's late publish can never collide with — or sort after — a
    segment the new term published, no matter how the publish-side fence
    races.
    """
    return f"t{int(term):012d}-{wal_name}"


def parse_ship_name(name: str) -> tuple[int | None, str]:
    """(term, wal_name) from a published segment name; term is None for a
    legacy un-prefixed name (the frame header stays authoritative — the
    name's term is for namespacing and ordering only)."""
    m = _SHIP_NAME_RE.match(name)
    if m is None:
        return None, name
    return int(m.group(1)), m.group(2)


def _stale_record(chart: list[tuple[int, int]], term: int, seq: int) -> bool:
    """True when the term chart proves ``(term, seq)`` is a fenced
    primary's leftover: some newer term's chain starts at or before
    ``seq``, i.e. that suffix of history was rewritten under new
    leadership and this record can never be part of the acked prefix."""
    for t, start_seq in chart:
        if t > term and seq >= start_seq:
            return True
    return False


def encode_ship_frame(term: int, start_seq: int, payload: bytes) -> bytes:
    """Wrap one WAL segment's raw bytes for transport.

    The frame CRCs both its header and the payload, so a dropped byte in
    flight is loud at the standby before any record is parsed — the WAL's
    own per-record checksums then guard the contents a second time.
    """
    head = _SHIP_HEADER.pack(_SHIP_MAGIC, int(term), int(start_seq),
                             len(payload), pio.crc32(payload))
    return head + struct.pack("<I", pio.crc32(head)) + payload


def decode_ship_frame(data: bytes, origin: str = "<frame>"
                      ) -> tuple[int, int, bytes]:
    """(term, start_seq, payload) or ``ReplicationError`` — never a torn
    or bit-flipped frame silently accepted."""
    if len(data) < SHIP_HEADER_SIZE:
        raise ReplicationError(
            f"{origin}: ship frame truncated ({len(data)} bytes)")
    head = data[:_SHIP_HEADER.size]
    magic, term, start_seq, plen, pcrc = _SHIP_HEADER.unpack(head)
    (hcrc,) = struct.unpack(
        "<I", data[_SHIP_HEADER.size:SHIP_HEADER_SIZE])
    if magic != _SHIP_MAGIC:
        raise ReplicationError(f"{origin}: bad ship-frame magic")
    if hcrc != pio.crc32(head):
        raise ReplicationError(f"{origin}: ship-frame header CRC mismatch")
    payload = data[SHIP_HEADER_SIZE:]
    if len(payload) != plen:
        raise ReplicationError(
            f"{origin}: ship-frame payload truncated "
            f"({len(payload)} of {plen} bytes)")
    if pio.crc32(payload) != pcrc:
        raise ReplicationError(f"{origin}: ship-frame payload CRC mismatch")
    return int(term), int(start_seq), payload


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------

class DirTransport:
    """Directory-backed transport: segments, term, and heartbeats as files.

    Every byte crosses ``persist.io`` primitives, so the fault-injection
    harness reaches shipped segments exactly like local ones; segment and
    term writes are atomic-rename publishes, so a reader never sees a torn
    file under its real name. Works across processes sharing a filesystem
    (the crash-drill and CI path) as well as across threads.
    """

    def __init__(self, directory: str):
        self.directory = directory
        os.makedirs(directory, exist_ok=True)

    # -- term authority -----------------------------------------------------

    def _read_term_doc(self) -> dict:
        try:
            raw = pio.read_bytes(os.path.join(self.directory, _TERM_NAME))
        except FileNotFoundError:
            return {"term": 0, "chart": []}
        try:
            doc = json.loads(raw.decode("ascii"))
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise ReplicationError(f"unreadable TERM file: {e}") from e
        if isinstance(doc, int):  # legacy bare-int TERM file
            return {"term": doc, "chart": []}
        return doc

    def read_term(self) -> int:
        return int(self._read_term_doc()["term"])

    def term_chart(self) -> list[tuple[int, int]]:
        """(term, start_seq) for every promoted term, ascending — the
        authoritative record of where each leadership era's chain begins
        (term 0, the genesis era, has no entry)."""
        return sorted((int(t), int(s))
                      for t, s in self._read_term_doc()["chart"])

    def bump_term(self, new_term: int, *, start_seq: int) -> int:
        """Install a strictly higher term whose chain starts at
        ``start_seq``; ``FencedError`` otherwise — a promotion racing a
        newer promotion must lose loudly."""
        doc = self._read_term_doc()
        current = int(doc["term"])
        if new_term <= current:
            raise FencedError(
                f"term {new_term} is not newer than current {current}")
        doc["term"] = int(new_term)
        doc["chart"] = sorted(
            [[int(t), int(s)] for t, s in doc["chart"]]
            + [[int(new_term), int(start_seq)]])
        pio.atomic_write_bytes(os.path.join(self.directory, _TERM_NAME),
                               json.dumps(doc).encode("ascii"))
        return int(new_term)

    # -- segments -----------------------------------------------------------

    def publish(self, name: str, data: bytes, *, term: int) -> None:
        """Atomically publish one framed segment; the transport itself
        rejects stale-term publishes so even a shipper that skipped its
        ``read_term`` check cannot extend the stream after a promotion."""
        if term < self.read_term():
            raise FencedError(
                f"publish from term {term} rejected: transport term is "
                f"{self.read_term()}")
        pio.atomic_write_bytes(
            os.path.join(self.directory, _SEG_PREFIX + name), data)

    def list_segments(self) -> list[str]:
        out = [n[len(_SEG_PREFIX):] for n in os.listdir(self.directory)
               if n.startswith(_SEG_PREFIX)]
        out.sort()  # wal-<seq:012d>.log names sort in seq order
        return out

    def fetch(self, name: str) -> bytes:
        try:
            return pio.read_bytes(
                os.path.join(self.directory, _SEG_PREFIX + name))
        except OSError as e:
            raise ReplicationError(f"segment {name} unfetchable: {e}") from e

    # -- heartbeats ---------------------------------------------------------

    def write_heartbeat(self, role: str, info: dict) -> None:
        pio.atomic_write_bytes(
            os.path.join(self.directory, f"HEARTBEAT-{role}.json"),
            json.dumps(info).encode("utf-8"))

    def read_heartbeat(self, role: str) -> dict | None:
        try:
            data = pio.read_bytes(
                os.path.join(self.directory, f"HEARTBEAT-{role}.json"))
            return json.loads(data.decode("utf-8"))
        except (OSError, UnicodeDecodeError, json.JSONDecodeError):
            return None  # absent or mid-damage heartbeat = no signal


class PipeTransport:
    """In-process transport for the threaded harness: one shared object,
    segments and term under a lock. Same duck type as ``DirTransport``;
    tests wrap ``publish``/``fetch`` to inject drops, duplicates, and
    transient failures without touching a filesystem."""

    def __init__(self):
        self._lock = threading.Lock()
        self._segments: dict[str, bytes] = {}
        self._term = 0
        self._chart: list[tuple[int, int]] = []
        self._heartbeats: dict[str, dict] = {}

    def read_term(self) -> int:
        with self._lock:
            return self._term

    def term_chart(self) -> list[tuple[int, int]]:
        with self._lock:
            return sorted(self._chart)

    def bump_term(self, new_term: int, *, start_seq: int) -> int:
        with self._lock:
            if new_term <= self._term:
                raise FencedError(
                    f"term {new_term} is not newer than current {self._term}")
            self._term = int(new_term)
            self._chart.append((int(new_term), int(start_seq)))
            return self._term

    def publish(self, name: str, data: bytes, *, term: int) -> None:
        with self._lock:
            if term < self._term:
                raise FencedError(
                    f"publish from term {term} rejected: transport term "
                    f"is {self._term}")
            self._segments[name] = bytes(data)

    def list_segments(self) -> list[str]:
        with self._lock:
            return sorted(self._segments)

    def fetch(self, name: str) -> bytes:
        with self._lock:
            try:
                return self._segments[name]
            except KeyError:
                raise ReplicationError(
                    f"segment {name} not in transport") from None

    def write_heartbeat(self, role: str, info: dict) -> None:
        with self._lock:
            self._heartbeats[role] = dict(info)

    def read_heartbeat(self, role: str) -> dict | None:
        with self._lock:
            hb = self._heartbeats.get(role)
            return None if hb is None else dict(hb)


def make_fence_guard(transport, term: int) -> Callable[[], None]:
    """A ``WALWriter`` guard: raise ``FencedError`` the moment the
    transport knows a term newer than ``term`` — the deposed primary
    cannot extend its local log past the promotion point, so no
    acknowledged-but-unshippable suffix can ever exist."""
    def guard() -> None:
        current = transport.read_term()
        if current > term:
            raise FencedError(
                f"append from term {term} rejected: a newer primary holds "
                f"term {current}")
    return guard


# ---------------------------------------------------------------------------
# primary side: the shipper
# ---------------------------------------------------------------------------

class WALShipper:
    """Streams the primary's closed WAL segments through a transport.

    ``ship_once`` is the whole protocol: check the fence, rotate the live
    WAL file (so the records accumulated since the last ship become a
    closed, fully-fsync'd segment), then publish every not-yet-shipped
    closed segment in seq order, each wrapped in a checksummed ship frame
    stamped with this shipper's term.

    Transient transport failures are retried with exponential backoff —
    at most ``max_retries`` extra attempts per segment AND within
    ``send_timeout_s`` wall-clock per segment; past either budget,
    ``ReplicationError``. ``FencedError`` is never retried: a newer term
    exists and this primary is done.

    Idempotent across restarts: WAL files already published under THIS
    term (from ``transport.list_segments``) are skipped, and a
    re-published segment carries byte-identical records anyway (closed
    WAL files never change). Another term's publishes don't count — a
    same-named WAL file from a different leadership era is a different
    chain (the term-scoped namespace keeps them apart).
    """

    def __init__(self, engine, directory: str, transport, *, term: int = 0,
                 max_retries: int = 4, backoff_s: float = 0.01,
                 send_timeout_s: float | None = None):
        self.engine = engine
        self.directory = directory
        self.transport = transport
        self.term = int(term)
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self.send_timeout_s = send_timeout_s
        self.segments_shipped = 0
        self._published = {
            wal for t, wal in map(parse_ship_name,
                                  transport.list_segments())
            if t is None or t == self.term}
        self._lock = threading.Lock()

    def ship_once(self) -> int:
        """One shipping round; returns segments published this round."""
        with self._lock:
            current = self.transport.read_term()
            if current > self.term:
                raise FencedError(
                    f"shipper at term {self.term} fenced: transport term "
                    f"is {current}")
            wal = getattr(self.engine, "_wal", None)
            if wal is None:
                raise ReplicationError(
                    "primary engine has no WAL attached — nothing to ship")
            wal.rotate(self.directory)
            shipped = 0
            for start_seq, path in wal_mod.wal_files(self.directory):
                name = os.path.basename(path)
                if name in self._published:
                    continue
                # Re-read wal.path for EVERY candidate rather than
                # capturing it once: the checkpoint thread rotates this
                # WAL concurrently (save_snapshot), so a file that did
                # not exist at our rotate() above may be the live file
                # now. A file that stops being wal.path can never become
                # live again (rotation only moves forward through seq
                # names), so candidate != wal.path at this instant proves
                # the candidate is closed and immutable — only then is it
                # safe to read it and mark it published. The live file is
                # simply picked up on a later round, after its rotation.
                if path == wal.path:
                    continue
                frame = encode_ship_frame(self.term, start_seq,
                                          pio.read_bytes(path))
                self._publish_with_retry(ship_segment_name(self.term, name),
                                         frame)
                self._published.add(name)
                shipped += 1
            self.segments_shipped += shipped
            self.transport.write_heartbeat("primary", {
                "term": self.term, "last_seq": int(wal.last_seq),
                "time": time.time()})
            return shipped

    def _publish_with_retry(self, name: str, frame: bytes) -> None:
        deadline = (None if self.send_timeout_s is None
                    else time.monotonic() + self.send_timeout_s)
        last_err: Exception | None = None
        for attempt in range(self.max_retries + 1):
            try:
                self.transport.publish(name, frame, term=self.term)
                return
            except FencedError:
                raise
            except Exception as e:
                last_err = e
                if attempt == self.max_retries:
                    break
                sleep = self.backoff_s * (2 ** attempt)
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        break
                    sleep = min(sleep, remaining)
                time.sleep(sleep)
        raise ReplicationError(
            f"publishing segment {name} failed after "
            f"{self.max_retries + 1} attempts: {last_err}") from last_err


# ---------------------------------------------------------------------------
# standby side: the replica
# ---------------------------------------------------------------------------

class ReplicationLag(NamedTuple):
    """How far a standby trails its primary, in both operational units."""

    seqs: int       # records the primary acknowledged that we've not applied
    seconds: float  # age of the primary heartbeat those seqs came from
    #                 (0.0 when fully caught up or no heartbeat exists yet)


class StandbyReplica:
    """Warm follower: replays shipped WAL segments into a live engine.

    The engine must have NO WAL writer attached — replay goes through
    ``apply_record`` (the same deterministic mutators recovery uses), so
    the standby's state is bit-identical to the primary's over the
    applied prefix and read-only queries are served from it at any moment.

    Replay is *idempotent and gap-loud*: records at or below
    ``applied_seq`` are skipped exactly (re-shipped or duplicated
    segments are harmless), the first record above it must be
    ``applied_seq + 1`` (a dropped segment raises ``ReplicationError``),
    and records the transport's term chart proves are a fenced primary's
    leftovers — minted under term ``t`` at a seq a newer term's chain has
    rewritten — are skipped (counted in ``records_stale``), never
    replayed and never an excuse to stop following the live chain.
    """

    def __init__(self, engine, transport, *, start_seq: int = 0,
                 max_retries: int = 4, backoff_s: float = 0.01):
        if getattr(engine, "_wal", None) is not None:
            raise ValueError(
                "standby engine must not have a WAL attached — replay "
                "must not re-log (promotion attaches one)")
        self.engine = engine
        self.transport = transport
        self.applied_seq = int(start_seq)
        self.records_replayed = 0
        self.records_stale = 0
        self.max_term = 0
        self.max_retries = int(max_retries)
        self.backoff_s = float(backoff_s)
        self._seen: set[str] = set()
        self._lock = threading.RLock()  # promote() drains via poll_once()

    def poll_once(self) -> int:
        """Fetch + replay every new shipped segment; returns records applied."""
        with self._lock:
            applied = 0
            chart = self.transport.term_chart()
            for name in self.transport.list_segments():
                if name in self._seen:
                    continue
                frame = self._fetch_with_retry(name)
                term, _start_seq, payload = decode_ship_frame(frame, name)
                self.max_term = max(self.max_term, term)
                records, _valid, clean = wal_mod.scan_wal_bytes(payload, name)
                if not clean:
                    raise ReplicationError(
                        f"shipped segment {name} ends torn — closed "
                        "segments are always complete; refusing to replay")
                for rec in records:
                    if _stale_record(chart, term, rec.seq):
                        self.records_stale += 1
                        continue  # a fenced primary's leftover: inert
                    if rec.seq <= self.applied_seq:
                        continue  # duplicate delivery: already applied
                    if rec.seq != self.applied_seq + 1:
                        raise ReplicationError(
                            f"sequence gap in shipped chain: expected "
                            f"{self.applied_seq + 1}, segment {name} holds "
                            f"{rec.seq} — a segment was dropped")
                    wal_mod.apply_record(self.engine, rec)
                    self.applied_seq = rec.seq
                    self.records_replayed += 1
                    applied += 1
                self._seen.add(name)
            self.transport.write_heartbeat("standby", {
                "term": self.max_term, "applied_seq": self.applied_seq,
                "time": time.time()})
            return applied

    def _fetch_with_retry(self, name: str) -> bytes:
        last_err: Exception | None = None
        for attempt in range(self.max_retries + 1):
            try:
                return self.transport.fetch(name)
            except ReplicationError:
                raise  # typed = permanent (missing segment), don't spin
            except Exception as e:
                last_err = e
                if attempt < self.max_retries:
                    time.sleep(self.backoff_s * (2 ** attempt))
        raise ReplicationError(
            f"fetching segment {name} failed after "
            f"{self.max_retries + 1} attempts: {last_err}") from last_err

    def lag(self) -> ReplicationLag:
        """Current lag vs the primary's last heartbeat (0/0.0 when caught
        up, or before any heartbeat arrives — absence of a primary is a
        liveness question for the failure detector, not a lag number)."""
        hb = self.transport.read_heartbeat("primary")
        if hb is None:
            return ReplicationLag(0, 0.0)
        seqs = max(0, int(hb.get("last_seq", 0)) - self.applied_seq)
        if seqs == 0:
            return ReplicationLag(0, 0.0)
        return ReplicationLag(
            seqs, max(0.0, time.time() - float(hb.get("time", 0.0))))

    def promote(self, directory: str, *, term: int | None = None) -> int:
        """Fenced failover: drain, bump the term, become writable.

        1. Drain: replay every segment already in the transport, so no
           shipped record is left behind.
        2. Bump: install ``max(transport, seen) + 1`` (or the explicit
           ``term``) as the new transport term — atomically, recording
           ``applied_seq + 1`` as the new term's chain start in the term
           chart (so the deposed primary's unshipped suffix is provably
           stale to every follower); losing a race to an even newer term
           raises ``FencedError`` and changes nothing locally.
        3. Snapshot: checkpoint the drained state into ``directory`` with
           the new term and ``wal_seq = applied_seq`` (the replica applied
           records without logging them, so the manifest must pin the
           exact prefix the state folds in).
        4. Attach: a fresh ``WALWriter`` at ``applied_seq + 1`` carrying
           the new term and a fence guard.

        Returns the new term. From the transport's perspective the old
        primary is fenced the instant step 2 lands.
        """
        from repro.persist.snapshot import save_snapshot  # cycle-free import
        with self._lock:
            while self.poll_once():  # drain what the transport already holds
                pass
            current = self.transport.read_term()
            new_term = (max(current, self.max_term) + 1 if term is None
                        else int(term))
            self.transport.bump_term(  # FencedError if stale
                new_term, start_seq=self.applied_seq + 1)
            self.max_term = new_term
            os.makedirs(directory, exist_ok=True)
            save_snapshot(self.engine, directory, term=new_term,
                          wal_seq=self.applied_seq)
            writer = wal_mod.WALWriter(
                os.path.join(directory,
                             wal_mod.wal_name(self.applied_seq + 1)),
                self.applied_seq + 1, term=new_term,
                guard=make_fence_guard(self.transport, new_term))
            self.engine.attach_wal(writer)
            return new_term
