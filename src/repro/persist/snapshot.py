"""Checksummed engine snapshots + recovery (docs/persistence.md).

A durable-index directory holds three kinds of entry:

    MANIFEST.json            atomic pointer to the last COMPLETE snapshot
    snap-NNNNNN/*.npy        per-segment CRC-verified array files
    wal-############.log     the write-ahead mutation log chain

``save_snapshot`` captures (WAL position, engine state) atomically under
the engine's own mutation lock, serializes every segment to a *new*
``snap-`` directory, and only then atomically replaces the manifest — so a
crash mid-snapshot leaves the previous manifest pointing at the previous,
still-complete snapshot, and a torn segment is never loadable (the
manifest that would have named it was never written). After the manifest
is durable, older snapshots and WAL files it fully covers are garbage
collected (this is the WAL truncation story).

Recovery (``open_engine``) = load the manifest's snapshot (every segment
CRC-checked), replay WAL records past the snapshot's ``wal_seq`` through
the engine's own deterministic mutators, and attach a fresh WAL writer at
the next sequence number. The result is asserted bit-identical to the
never-crashed engine across every query path (tests/test_persist.py).

Sharded engines persist one sub-manifest per shard (``shard-NN/
manifest.json``, itself CRC'd by the top manifest) so each shard's
segment set is independently verifiable.
"""
from __future__ import annotations

import io as _io
import json
import os
import shutil
import threading
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import ivf as ivf_mod
from repro.core import lists as lists_mod
from repro.core.lists import pack_filter_mask
from repro.core.pq import PQCodebook
from repro.engine.engine import EngineConfig, SearchEngine
from repro.engine.sharded import ShardedEngine, _ShardState
from repro.kernels import ops as ops_mod
from repro.persist import io as pio
from repro.persist import wal as wal_mod
from repro.persist.errors import CorruptSnapshotError, NoSnapshotError

MANIFEST_NAME = "MANIFEST.json"
SCHEMA = 2
_KNOWN_SCHEMAS = (1, 2)  # 1 = pre-replication (no term/parent/delta fields)
_SNAPSHOT_KINDS = ("single", "sharded")


class RecoveryInfo(NamedTuple):
    """What ``open_engine`` reconstructed, for assertions and ops logs."""

    snapshot: str        # snap-NNNNNN directory the manifest named
    wal_seq: int         # mutations already folded into that snapshot
    replayed: int        # WAL records replayed on top of it
    last_seq: int        # wal_seq + replayed == total acknowledged mutations
    truncated_bytes: int # torn tail dropped from the final WAL file (crash
    #                      mid-append; 0 on a clean shutdown)
    term: int = 0        # fencing term the manifest recorded (replication)


# ---------------------------------------------------------------------------
# segment primitives
# ---------------------------------------------------------------------------

def _npy_bytes(arr: np.ndarray) -> bytes:
    bio = _io.BytesIO()
    np.save(bio, np.asarray(arr), allow_pickle=False)
    return bio.getvalue()


class _DeltaStats:
    """Per-checkpoint byte accounting: what was rewritten vs referenced."""

    def __init__(self):
        self.bytes_written = 0
        self.bytes_reused = 0
        self.segments_written = 0
        self.segments_reused = 0

    def as_meta(self) -> dict:
        return {"bytes_written": self.bytes_written,
                "bytes_reused": self.bytes_reused,
                "segments_written": self.segments_written,
                "segments_reused": self.segments_reused}


def _write_segments(directory: str, seg_dir: str,
                    arrays: dict[str, np.ndarray],
                    parent: dict | None = None,
                    stats: _DeltaStats | None = None) -> dict:
    """Write each array as ``<seg_dir>/<name>.npy``; return manifest entries
    (file paths relative to the root ``directory``).

    **Delta snapshots**: when ``parent`` holds the previous manifest's
    entries for the same segment set, any array whose serialized bytes
    CRC+size-match the parent entry is NOT rewritten — the new manifest
    references the parent's file in place (``_gc`` keeps every referenced
    snapshot directory alive). A delete-only interval thus rewrites only
    ids/sizes/live_bits, never the code or base payloads.
    """
    entries = {}
    for name, arr in arrays.items():
        data = _npy_bytes(arr)
        crc = pio.crc32(data)
        old = None if parent is None else parent.get(name)
        if (old is not None and old.get("crc") == crc
                and old.get("size") == len(data)
                and os.path.exists(os.path.join(directory, old["file"]))):
            entries[name] = {"file": old["file"], "crc": crc,
                             "size": len(data)}
            if stats is not None:
                stats.bytes_reused += len(data)
                stats.segments_reused += 1
            continue
        rel = os.path.join(os.path.relpath(seg_dir, directory),
                           f"{name}.npy")
        pio.write_bytes(os.path.join(directory, rel), data)
        entries[name] = {"file": rel, "crc": crc, "size": len(data)}
        if stats is not None:
            stats.bytes_written += len(data)
            stats.segments_written += 1
    return entries


def _read_verified(directory: str, entry: dict, what: str) -> bytes:
    path = os.path.join(directory, entry["file"])
    try:
        data = pio.read_bytes(path)
    except OSError as e:
        raise CorruptSnapshotError(
            f"{what} segment {entry['file']} unreadable: {e}") from e
    if len(data) != entry["size"]:
        raise CorruptSnapshotError(
            f"{what} segment {entry['file']} truncated: "
            f"{len(data)} bytes, manifest says {entry['size']}")
    if pio.crc32(data) != entry["crc"]:
        raise CorruptSnapshotError(
            f"{what} segment {entry['file']} failed its CRC check")
    return data


def _load_array(directory: str, entry: dict, what: str) -> np.ndarray:
    data = _read_verified(directory, entry, what)
    try:
        return np.load(_io.BytesIO(data), allow_pickle=False)
    except Exception as e:
        raise CorruptSnapshotError(
            f"{what} segment {entry['file']} undecodable: {e}") from e


# ---------------------------------------------------------------------------
# manifest
# ---------------------------------------------------------------------------

def _manifest_crc(manifest: dict) -> int:
    """CRC of the manifest body over a canonical serialization, so the
    manifest protects its own fields (``wal_seq`` above all — a flipped
    digit there would replay the wrong WAL suffix undetected)."""
    body = {k: v for k, v in manifest.items() if k != "manifest_crc"}
    return pio.crc32(json.dumps(body, sort_keys=True,
                                separators=(",", ":")).encode("utf-8"))

def read_manifest(directory: str) -> dict:
    """The directory's manifest, or ``NoSnapshotError`` if none exists.

    A present-but-unparseable manifest is ``CorruptSnapshotError`` — the
    distinction lets boot logic initialize a fresh directory while never
    silently reinitializing a damaged one."""
    path = os.path.join(directory, MANIFEST_NAME)
    try:
        data = pio.read_bytes(path)
    except FileNotFoundError:
        raise NoSnapshotError(
            f"no {MANIFEST_NAME} in {directory} — nothing was ever "
            "checkpointed here") from None
    try:
        manifest = json.loads(data.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise CorruptSnapshotError(
            f"{path} is not valid manifest JSON: {e}") from e
    if (manifest.get("schema") not in _KNOWN_SCHEMAS
            or manifest.get("kind") not in _SNAPSHOT_KINDS):
        raise CorruptSnapshotError(
            f"{path}: unknown schema/kind "
            f"{manifest.get('schema')!r}/{manifest.get('kind')!r}")
    if manifest.get("manifest_crc") != _manifest_crc(manifest):
        raise CorruptSnapshotError(f"{path} failed its self-CRC check")
    # graceful migration: schema-1 manifests predate replication — they are
    # full (non-delta) snapshots written by term 0 with no parent chain
    manifest.setdefault("term", 0)
    manifest.setdefault("parent", None)
    return manifest


def _next_snap_name(directory: str) -> str:
    nums = [0]
    for name in os.listdir(directory):
        if name.startswith("snap-") and name[5:].isdigit():
            nums.append(int(name[5:]))
    return f"snap-{max(nums) + 1:06d}"


# ---------------------------------------------------------------------------
# save
# ---------------------------------------------------------------------------

def _config_meta(config: EngineConfig) -> dict:
    return dict(config._asdict())


def _serialize_single(engine: SearchEngine, st, directory: str,
                      snap_dir: str, parent: dict | None,
                      stats: _DeltaStats) -> tuple[dict, dict, None]:
    if engine.coarse_kind not in ("flat", "hnsw", "tree"):
        raise ValueError(
            f"cannot snapshot an engine with a custom coarse quantizer "
            f"({engine.coarse_kind!r}) — only flat/hnsw/tree rebuild "
            "deterministically from the centroids")
    arrays = dict(lists_mod.store_arrays(st.index.lists))
    arrays["centroids"] = np.asarray(st.index.centroids)
    arrays["codebook"] = np.asarray(st.index.codebook.codewords)
    if st.base is not None:
        arrays["base"] = np.asarray(st.base)
        arrays["base_norms"] = np.asarray(st.base_norms)
    if st.live_bits is not None:
        arrays["live_bits"] = np.asarray(st.live_bits)
    if engine.ns_member is not None:
        arrays["ns_member"] = np.asarray(engine.ns_member)
    meta = {"config": _config_meta(engine.config),
            "coarse_kind": engine.coarse_kind,
            "hnsw_m": engine.hnsw_m,
            "ef_construction": engine.ef_construction,
            "epoch": int(st.epoch),
            "n_tombstones": int(st.n_tombstones)}
    parent_segs = None if parent is None else parent.get("segments")
    return (_write_segments(directory, snap_dir, arrays, parent_segs, stats),
            meta, None)


def _parent_shard_segments(directory: str, parent: dict | None,
                           num_shards: int) -> list[dict | None]:
    """Per-shard segment tables of the parent manifest (for delta reuse);
    a shard whose sub-manifest cannot be verified simply gets no reuse."""
    out: list[dict | None] = [None] * num_shards
    if parent is None or len(parent.get("shards", ())) != num_shards:
        return out
    for j, entry in enumerate(parent["shards"]):
        try:
            sub = json.loads(_read_verified(
                directory, {"file": entry["manifest"], "crc": entry["crc"],
                            "size": entry["size"]},
                "parent shard manifest").decode("utf-8"))
            out[j] = sub["segments"]
        except (CorruptSnapshotError, KeyError, UnicodeDecodeError,
                json.JSONDecodeError):
            out[j] = None
    return out


def _serialize_sharded(engine: ShardedEngine, st: _ShardState,
                       directory: str, snap_dir: str, parent: dict | None,
                       stats: _DeltaStats) -> tuple[dict, dict, list]:
    arrays = {"centroids": np.asarray(engine.centroids),
              "codebook": np.asarray(engine.codebook.codewords)}
    if engine.member_s is not None:
        arrays["member_s"] = np.asarray(engine.member_s)
    parent_segs = None if parent is None else parent.get("segments")
    segments = _write_segments(directory, snap_dir, arrays, parent_segs,
                               stats)
    store = lists_mod.store_arrays(st.lists_s)  # 3-D, leading shard dim
    parent_sh = _parent_shard_segments(directory, parent, engine.num_shards)
    shards = []
    for j in range(engine.num_shards):
        shard_dir = os.path.join(snap_dir, f"shard-{j:02d}")
        os.makedirs(shard_dir, exist_ok=True)
        sh = {k: v[j] for k, v in store.items()}
        sh["centroids"] = np.asarray(st.centroids_s[j])
        sh["real"] = np.asarray(st.real_s[j])
        sh["gids"] = np.asarray(st.gids_s[j])
        if st.base_s is not None:
            sh["base"] = np.asarray(st.base_s[j])
            sh["norms"] = np.asarray(st.norms_s[j])
        entries = _write_segments(directory, shard_dir, sh, parent_sh[j],
                                  stats)
        sub = json.dumps({"shard": j, "segments": entries},
                         indent=1).encode("utf-8")
        rel = os.path.join(os.path.relpath(shard_dir, directory),
                           "manifest.json")
        pio.write_bytes(os.path.join(directory, rel), sub)
        shards.append({"manifest": rel, "crc": pio.crc32(sub),
                       "size": len(sub)})
    meta = {"config": _config_meta(engine.config),
            "num_shards": engine.num_shards,
            "nlist_global": int(engine.nlist_global),
            "rows_used": [int(r) for r in st.rows_used],
            "epoch": int(st.epoch),
            "n_tombstones": int(st.n_tombstones)}
    return segments, meta, shards


def save_snapshot(engine, directory: str, *, term: int | None = None,
                  wal_seq: int | None = None) -> dict:
    """Checkpoint ``engine`` into ``directory``; returns the new manifest.

    The (WAL position, state) pair is captured atomically under the
    engine's mutation lock — rotating the WAL first, so every record the
    snapshot folds in lives in files that GC may then delete. All segment
    bytes are written and fsync'd BEFORE the manifest atomically flips to
    the new snapshot; a crash anywhere in between recovers from the old
    manifest plus the intact WAL chain. Works on ``SearchEngine`` and
    ``ShardedEngine`` (per-shard manifests).

    Checkpoints are **delta snapshots**: segments whose bytes match the
    parent manifest's CRC+size are referenced from the parent instead of
    rewritten (the manifest records the ``parent`` name and per-checkpoint
    byte accounting under ``delta``; ``_gc`` keeps every snapshot
    directory the new manifest still references).

    ``term`` stamps the manifest with the replication fencing term
    (default: carry the previous manifest's term forward, 0 on a fresh
    directory). ``wal_seq`` overrides the recorded WAL position — only
    for engines WITHOUT an attached writer whose state is known to fold
    in exactly that prefix (the standby-promotion path, where the replica
    applied shipped records without logging them locally).
    """
    os.makedirs(directory, exist_ok=True)
    try:
        parent = read_manifest(directory)
    except (NoSnapshotError, CorruptSnapshotError):
        parent = None  # fresh (or unreadable) parent -> full snapshot
    with engine._mutate_lock:
        wal = getattr(engine, "_wal", None)
        if wal is not None:
            wal.rotate(directory)
            wal_seq = wal.last_seq
        elif wal_seq is None:
            wal_seq = 0
        st = engine._state  # immutable — safe to serialize outside the lock
    if term is None:
        term = 0 if parent is None else int(parent.get("term", 0))
    snap_name = _next_snap_name(directory)
    snap_dir = os.path.join(directory, snap_name)
    os.makedirs(snap_dir, exist_ok=True)
    stats = _DeltaStats()
    if isinstance(engine, ShardedEngine):
        segments, meta, shards = _serialize_sharded(
            engine, st, directory, snap_dir, parent, stats)
        kind = "sharded"
    else:
        segments, meta, shards = _serialize_single(
            engine, st, directory, snap_dir, parent, stats)
        kind = "single"
    # autotune verdicts ride along so a restored replica serves warm
    tmp = os.path.join(snap_dir, "autotune.tmp")
    ops_mod.save_autotune_cache(tmp)
    with open(tmp, "rb") as f:
        tune = f.read()
    os.remove(tmp)
    tune_crc = pio.crc32(tune)
    old_tune = None if parent is None else parent["segments"].get("autotune")
    if (old_tune is not None and old_tune.get("crc") == tune_crc
            and old_tune.get("size") == len(tune)
            and os.path.exists(os.path.join(directory, old_tune["file"]))):
        segments["autotune"] = {"file": old_tune["file"], "crc": tune_crc,
                                "size": len(tune)}
        stats.bytes_reused += len(tune)
        stats.segments_reused += 1
    else:
        rel = os.path.join(snap_name, "autotune.json")
        pio.write_bytes(os.path.join(directory, rel), tune)
        segments["autotune"] = {"file": rel, "crc": tune_crc,
                                "size": len(tune)}
        stats.bytes_written += len(tune)
        stats.segments_written += 1
    pio.fsync_dir(snap_dir)
    manifest = {"schema": SCHEMA, "kind": kind, "snapshot": snap_name,
                "term": int(term),
                "parent": None if parent is None else parent["snapshot"],
                "delta": stats.as_meta(),
                "wal_seq": int(wal_seq), "meta": meta, "segments": segments}
    if shards is not None:
        manifest["shards"] = shards
    manifest["manifest_crc"] = _manifest_crc(manifest)
    pio.atomic_write_bytes(os.path.join(directory, MANIFEST_NAME),
                           json.dumps(manifest, indent=1).encode("utf-8"))
    _gc(directory, manifest, wal_seq,
        keep=None if wal is None else wal.path)
    return manifest


def _reachable_snaps(manifest: dict) -> set[str]:
    """Snapshot directories the manifest still references — its own plus
    any parent dirs that delta entries point into (the live parent chain)."""
    rels = [e["file"] for e in manifest["segments"].values()]
    rels += [sh["manifest"] for sh in manifest.get("shards", ())]
    keep = {manifest["snapshot"]}
    for rel in rels:
        head = rel.replace(os.sep, "/").split("/", 1)[0]
        if head.startswith("snap-"):
            keep.add(head)
    return keep


def _gc(directory: str, manifest: dict, wal_seq: int,
        keep: str | None) -> None:
    """Drop snapshots and WAL files the new manifest supersedes.

    Runs only after the manifest is durable. A snapshot directory survives
    while ANY current segment references into it (the delta parent chain);
    note the per-shard sub-manifests live inside their snapshot directory,
    so a kept directory keeps its shard segment tables too — and those
    tables' own entries always point within the same directory set the top
    manifest references. A WAL file is deletable when a LATER file exists
    and every record it could hold is <= ``wal_seq`` (the final file's
    extent is unknown without a scan, so it always stays); the active
    writer's file is never touched.
    """
    reachable = _reachable_snaps(manifest)
    # shard sub-manifests referenced by the top manifest may in turn
    # reference parent shard directories: walk them too
    for sh in manifest.get("shards", ()):
        try:
            with open(os.path.join(directory, sh["manifest"])) as f:
                sub = json.load(f)
            for e in sub.get("segments", {}).values():
                head = e["file"].replace(os.sep, "/").split("/", 1)[0]
                if head.startswith("snap-"):
                    reachable.add(head)
        except (OSError, json.JSONDecodeError, KeyError):
            continue  # unreadable sub-manifest: keep GC conservative below
    for name in os.listdir(directory):
        if (name.startswith("snap-") and name not in reachable
                and os.path.isdir(os.path.join(directory, name))):
            shutil.rmtree(os.path.join(directory, name), ignore_errors=True)
    files = wal_mod.wal_files(directory)
    for i, (_start, path) in enumerate(files[:-1]):
        covered = files[i + 1][0] <= wal_seq + 1
        if covered and path != keep:
            try:
                os.remove(path)
            except OSError:
                pass


# ---------------------------------------------------------------------------
# load + recovery
# ---------------------------------------------------------------------------

def _deserialize_single(directory: str, manifest: dict) -> SearchEngine:
    segs = manifest["segments"]
    meta = manifest["meta"]
    get = lambda name: _load_array(directory, segs[name], "snapshot")
    store_arrays = {k: get(k) for k in ("codes", "ids", "sizes")}
    if "attrs" in segs:
        store_arrays["attrs"] = get("attrs")
    index = ivf_mod.IVFIndex(
        centroids=jnp.asarray(get("centroids")),
        codebook=PQCodebook(jnp.asarray(get("codebook"))),
        lists=lists_mod.store_from_arrays(store_arrays))
    engine = SearchEngine(
        index,
        base=jnp.asarray(get("base")) if "base" in segs else None,
        coarse=meta["coarse_kind"],
        config=EngineConfig(**meta["config"]),
        hnsw_m=int(meta["hnsw_m"]),
        ef_construction=int(meta["ef_construction"]),
        namespaces=get("ns_member") if "ns_member" in segs else None)
    # the constructor recomputes norms/live bits (bitwise-equal by
    # construction); install the snapshotted ones + epoch verbatim anyway
    engine._state = engine._state._replace(
        base_norms=(jnp.asarray(get("base_norms"))
                    if "base_norms" in segs else None),
        live_bits=(jnp.asarray(get("live_bits"))
                   if "live_bits" in segs else None),
        epoch=int(meta["epoch"]),
        n_tombstones=int(meta["n_tombstones"]))
    return engine


def _deserialize_sharded(directory: str, manifest: dict) -> ShardedEngine:
    segs = manifest["segments"]
    meta = manifest["meta"]
    num_shards = int(meta["num_shards"])
    if len(manifest.get("shards", ())) != num_shards:
        raise CorruptSnapshotError(
            f"manifest lists {len(manifest.get('shards', ()))} shard "
            f"manifests but meta says num_shards={num_shards}")
    per_shard: list[dict[str, np.ndarray]] = []
    for entry in manifest["shards"]:
        sub_bytes = _read_verified(directory, {"file": entry["manifest"],
                                               "crc": entry["crc"],
                                               "size": entry["size"]},
                                   "shard manifest")
        try:
            sub = json.loads(sub_bytes.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise CorruptSnapshotError(
                f"shard manifest {entry['manifest']} unparseable: {e}") from e
        per_shard.append({k: _load_array(directory, v, "shard snapshot")
                          for k, v in sub["segments"].items()})
    stack = lambda name: jnp.asarray(
        np.stack([sh[name] for sh in per_shard]))
    store_arrays = {k: np.stack([sh[k] for sh in per_shard])
                    for k in ("codes", "ids", "sizes")}
    if "attrs" in per_shard[0]:
        store_arrays["attrs"] = np.stack([sh["attrs"] for sh in per_shard])
    lists_s = lists_mod.store_from_arrays(store_arrays)
    has_base = "base" in per_shard[0]
    n_tomb = int(meta["n_tombstones"])
    engine = object.__new__(ShardedEngine)
    engine.num_shards = num_shards
    engine.codebook = PQCodebook(
        jnp.asarray(_load_array(directory, segs["codebook"], "snapshot")))
    engine.config = EngineConfig(**meta["config"])
    engine.centroids = jnp.asarray(
        _load_array(directory, segs["centroids"], "snapshot"))
    engine.nlist_global = int(meta["nlist_global"])
    engine.member_s = (
        jnp.asarray(_load_array(directory, segs["member_s"], "snapshot"),
                    bool) if "member_s" in segs else None)
    engine._state = _ShardState(
        centroids_s=stack("centroids"), lists_s=lists_s,
        real_s=stack("real").astype(bool),
        base_s=stack("base") if has_base else None,
        gids_s=stack("gids"),
        norms_s=stack("norms") if has_base else None,
        live_s=pack_filter_mask(lists_s.ids >= 0) if n_tomb else None,
        rows_used=tuple(int(r) for r in meta["rows_used"]),
        epoch=int(meta["epoch"]), n_tombstones=n_tomb)
    engine._mutate_lock = threading.RLock()
    engine._locator = None
    engine._wal = None
    return engine


def load_snapshot(directory: str):
    """(engine, manifest) from the last complete snapshot — NO WAL replay.

    The raw snapshot restore, exposed for tools and tests; serving boots
    through ``open_engine`` so acknowledged mutations past the snapshot
    are replayed too.
    """
    manifest = read_manifest(directory)
    if manifest["kind"] == "sharded":
        engine = _deserialize_sharded(directory, manifest)
    else:
        engine = _deserialize_single(directory, manifest)
    if "autotune" in manifest["segments"]:
        tune = _read_verified(directory, manifest["segments"]["autotune"],
                              "autotune")
        tmp = os.path.join(directory, ".autotune.load.tmp")
        with open(tmp, "wb") as f:
            f.write(tune)
        try:
            ops_mod.load_autotune_cache(tmp)
        finally:
            os.remove(tmp)
    return engine, manifest


def open_engine(directory: str, *, attach: bool = True):
    """Recover: last snapshot + WAL replay; returns (engine, RecoveryInfo).

    The recovered engine is bit-identical to the never-crashed engine over
    the acknowledged-mutation prefix the directory holds. A torn record at
    the tail of the FINAL WAL file — the signature of a crash mid-append —
    is truncated away (that mutation never acknowledged); any other damage
    raises ``CorruptSnapshotError``/``CorruptWALError`` instead of serving
    a silently wrong index. With ``attach=True`` (default) a fresh WAL
    writer is attached at the next sequence number, so the engine is
    immediately durable again.
    """
    engine, manifest = load_snapshot(directory)
    wal_seq = int(manifest["wal_seq"])
    truncated = 0
    files = wal_mod.wal_files(directory)
    if files:
        last_path = files[-1][1]
        _, valid, clean = wal_mod.scan_wal(last_path)
        if not clean:
            truncated = os.path.getsize(last_path) - valid
            with open(last_path, "r+b") as f:
                f.truncate(valid)
                f.flush()
                os.fsync(f.fileno())
    replayed = 0
    for rec in wal_mod.iter_wal(directory, after_seq=wal_seq):
        wal_mod.apply_record(engine, rec)
        replayed += 1
    last_seq = wal_seq + replayed
    term = int(manifest.get("term", 0))
    if attach:
        writer = wal_mod.WALWriter(
            os.path.join(directory, wal_mod.wal_name(last_seq + 1)),
            last_seq + 1, term=term)
        engine.attach_wal(writer)
    return engine, RecoveryInfo(snapshot=manifest["snapshot"],
                                wal_seq=wal_seq, replayed=replayed,
                                last_seq=last_seq,
                                truncated_bytes=truncated,
                                term=term)


def ensure_attached(engine, directory: str) -> None:
    """Boot contract for serving: make ``engine`` durable into ``directory``.

    Fresh directory -> write the initial snapshot and attach a WAL writer
    at seq 1. Already attached to this directory (the ``open_engine``
    path) -> no-op. A directory that already holds a manifest the engine
    did NOT come from is refused: silently re-initializing would fork the
    history and orphan acknowledged mutations.
    """
    os.makedirs(directory, exist_ok=True)
    wal = getattr(engine, "_wal", None)
    if wal is not None and (os.path.dirname(os.path.abspath(wal.path))
                            == os.path.abspath(directory)):
        return
    try:
        read_manifest(directory)
    except NoSnapshotError:
        manifest = save_snapshot(engine, directory)
        writer = wal_mod.WALWriter(
            os.path.join(directory, wal_mod.wal_name(1)), 1,
            term=int(manifest.get("term", 0)))
        engine.attach_wal(writer)
        return
    raise ValueError(
        f"{directory} already holds a durable index this engine did not "
        "come from — boot it with persist.open_engine(directory) so the "
        "WAL resumes where it left off")
