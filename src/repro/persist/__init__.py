"""Crash-safe durable index: checksummed snapshots + WAL recovery.

``save_snapshot``/``load_snapshot`` serialize an engine (single-host or
sharded) as versioned, per-segment CRC-verified files behind an atomically
renamed manifest; ``WALWriter`` (attached via ``engine.attach_wal``) makes
every mutation durable before it becomes visible; ``open_engine`` recovers
snapshot + replay, bit-identical to the never-crashed engine over the
acknowledged prefix — or fails loudly with a typed error. See
docs/persistence.md.
"""
from repro.persist.errors import (CorruptSnapshotError, CorruptWALError,
                                  NoSnapshotError, PersistError)
from repro.persist.snapshot import (MANIFEST_NAME, RecoveryInfo,
                                    ensure_attached, load_snapshot,
                                    open_engine, read_manifest,
                                    save_snapshot)
from repro.persist.wal import (WALRecord, WALWriter, apply_record, iter_wal,
                               scan_wal, wal_files, wal_name)

__all__ = [
    "PersistError", "NoSnapshotError", "CorruptSnapshotError",
    "CorruptWALError", "MANIFEST_NAME", "RecoveryInfo", "save_snapshot",
    "load_snapshot", "open_engine", "read_manifest", "ensure_attached",
    "WALRecord", "WALWriter", "apply_record", "iter_wal", "scan_wal",
    "wal_files", "wal_name",
]
