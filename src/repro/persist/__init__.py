"""Crash-safe durable index: checksummed snapshots + WAL recovery.

``save_snapshot``/``load_snapshot`` serialize an engine (single-host or
sharded) as versioned, per-segment CRC-verified files behind an atomically
renamed manifest; ``WALWriter`` (attached via ``engine.attach_wal``) makes
every mutation durable before it becomes visible; ``open_engine`` recovers
snapshot + replay, bit-identical to the never-crashed engine over the
acknowledged prefix — or fails loudly with a typed error. See
docs/persistence.md.

The replication tier (``repro.persist.replicate``) ships the same WAL to
warm standbys: ``WALShipper`` publishes closed segments over a pluggable
transport, ``StandbyReplica`` replays them into a read-serving follower,
and fenced failover (``promote`` + term tokens) makes split-brain
structurally impossible (``FencedError`` / ``ReplicationError``).
"""
from repro.persist.errors import (CorruptSnapshotError, CorruptWALError,
                                  FencedError, NoSnapshotError, PersistError,
                                  ReplicationError)
from repro.persist.replicate import (DirTransport, PipeTransport,
                                     ReplicationLag, StandbyReplica,
                                     WALShipper, decode_ship_frame,
                                     encode_ship_frame, make_fence_guard,
                                     parse_ship_name, ship_segment_name)
from repro.persist.snapshot import (MANIFEST_NAME, RecoveryInfo,
                                    ensure_attached, load_snapshot,
                                    open_engine, read_manifest,
                                    save_snapshot)
from repro.persist.wal import (WALRecord, WALWriter, apply_record, iter_wal,
                               scan_wal, scan_wal_bytes, wal_files, wal_name,
                               wal_term)

__all__ = [
    "PersistError", "NoSnapshotError", "CorruptSnapshotError",
    "CorruptWALError", "FencedError", "ReplicationError", "MANIFEST_NAME",
    "RecoveryInfo", "save_snapshot", "load_snapshot", "open_engine",
    "read_manifest", "ensure_attached", "WALRecord", "WALWriter",
    "apply_record", "iter_wal", "scan_wal", "scan_wal_bytes", "wal_files",
    "wal_name", "wal_term", "DirTransport", "PipeTransport", "WALShipper",
    "StandbyReplica", "ReplicationLag", "encode_ship_frame",
    "decode_ship_frame", "make_fence_guard", "ship_segment_name",
    "parse_ship_name",
]
