"""musicgen-medium [audio]: 48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048,
decoder-only over EnCodec tokens. EnCodec frontend is a STUB (precomputed
frame embeddings per brief). [arXiv:2306.05284; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    mlp_type="gelu",
    rope_theta=10_000.0,
    frontend="codec",
    frontend_len=128,        # precomputed EnCodec frame embeddings
    vocab_pad_multiple=256,
    remat="group:8",
)

SMOKE = CONFIG.replace(
    name="musicgen-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab=128, frontend_len=8, dtype="float32",
    attn_q_chunk=32, attn_kv_chunk=32, vocab_pad_multiple=8,
)
