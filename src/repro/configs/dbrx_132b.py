"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16 experts top-4, fine-grained. [hf:databricks/dbrx-base; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    n_experts=16,
    n_experts_active=4,
    mlp_type="swiglu",
    rope_theta=500_000.0,
    remat="group:8",
)

SMOKE = CONFIG.replace(
    name="dbrx-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab=256, n_experts=4, n_experts_active=2, dtype="float32",
    attn_q_chunk=32, attn_kv_chunk=32, vocab_pad_multiple=8,
)
