"""starcoder2-15b [dense]: 40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152, GQA + RoPE. [arXiv:2402.19173; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    mlp_type="gelu",
    rope_theta=100_000.0,
    remat="group:8",
)

SMOKE = CONFIG.replace(
    name="starcoder2-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, dtype="float32",
    attn_q_chunk=32, attn_kv_chunk=32, vocab_pad_multiple=8,
)
