"""nemotron-4-15b [dense]: 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000, squared-ReLU FFN. [arXiv:2402.16819; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab=256000,
    mlp_type="relu2",
    rope_theta=10_000.0,
    remat="group:8",
)

SMOKE = CONFIG.replace(
    name="nemotron-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, dtype="float32",
    attn_q_chunk=32, attn_kv_chunk=32, vocab_pad_multiple=8,
)
