"""qwen1.5-32b [dense]: 64L d_model=5120 40H (GQA kv=40) d_ff=27392
vocab=152064, QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]

This is the PQ-KV showcase arch: decode_32k exact KV does not fit v5e HBM
(21.4 GB/device on a 256-chip pod); the paper's 4-bit PQ cache does (2.7 GB).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab=152064,
    qkv_bias=True,
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    remat="group:8",
    kv_pq=True,          # paper technique: 4-bit PQ KV cache for decode
)

SMOKE = CONFIG.replace(
    name="qwen1.5-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=160, vocab=256, dtype="float32",
    attn_q_chunk=32, attn_kv_chunk=32, vocab_pad_multiple=8,
)
