"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64, Mamba2 backbone + shared attention block every 6 layers.
[arXiv:2411.15242; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    block_type="mamba2",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_expand=2,
    ssm_chunk=128,
    shared_attn_every=6,     # 9 shared-attn invocations over 54 mamba layers
    mlp_type="swiglu",
    rope_theta=10_000.0,
    remat="layer",
    kv_pq=True,              # paper tech on the shared-attn KV at long context
)

SMOKE = CONFIG.replace(
    name="zamba2-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=96, vocab=256, ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
    shared_attn_every=2, dtype="float32",
    attn_q_chunk=32, attn_kv_chunk=32, vocab_pad_multiple=8,
)
