"""rwkv6-3b "Finch" [ssm]: 32L d_model=2560 (attention-free) d_ff=8960
vocab=65536, data-dependent decay. [arXiv:2404.05892; hf]

The paper's PQ-KV technique is INAPPLICABLE here (no KV cache exists; the
state is a fixed (hd x hd) matrix per head) — see DESIGN.md
§Arch-applicability. Implemented without the technique, per the brief.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    block_type="rwkv6",
    n_layers=32,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=8960,
    vocab=65536,
    rwkv_head_dim=64,
    rwkv_lora=64,
    rwkv_chunk=128,
    remat="group:8",
)

SMOKE = CONFIG.replace(
    name="rwkv6-smoke", n_layers=2, d_model=64, d_ff=96, vocab=256,
    rwkv_head_dim=16, rwkv_lora=8, rwkv_chunk=8, dtype="float32",
    vocab_pad_multiple=8,
)
