"""qwen3-1.7b [dense]: 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936, qk_norm. [hf:Qwen/Qwen3-8B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=6144,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    remat="group:7",
)

SMOKE = CONFIG.replace(
    name="qwen3-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    head_dim=16, d_ff=128, vocab=256, dtype="float32",
    attn_q_chunk=32, attn_kv_chunk=32, vocab_pad_multiple=8,
)
