"""internvl2-1b [vlm]: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
InternViT frontend is a STUB (precomputed patch embeddings per brief);
backbone is the Qwen2-0.5B-style decoder. [arXiv:2404.16821; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151655,            # padded to 153600 internally (vocab_pad_multiple)
    qkv_bias=True,
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    frontend="patch",
    frontend_len=256,        # 256 precomputed patch embeddings
    remat="layer",
)

SMOKE = CONFIG.replace(
    name="internvl2-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab=256, frontend_len=8, dtype="float32",
    attn_q_chunk=32, attn_kv_chunk=32, vocab_pad_multiple=8,
)
