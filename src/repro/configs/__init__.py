"""Assigned-architecture configs (exact per the brief) + reduced smoke configs.

`get_config(name)` returns the full production config; `get_smoke_config(name)`
returns a reduced same-family config for CPU smoke tests (small layers/width,
few experts, tiny vocab). The full configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCHS = (
    "dbrx_132b",
    "llama4_scout_17b_a16e",
    "qwen3_1p7b",
    "qwen1p5_32b",
    "nemotron_4_15b",
    "starcoder2_15b",
    "internvl2_1b",
    "musicgen_medium",
    "zamba2_2p7b",
    "rwkv6_3b",
)

# canonical ids from the brief -> module names
ALIASES = {
    "dbrx-132b": "dbrx_132b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen3-1.7b": "qwen3_1p7b",
    "qwen1.5-32b": "qwen1p5_32b",
    "nemotron-4-15b": "nemotron_4_15b",
    "starcoder2-15b": "starcoder2_15b",
    "internvl2-1b": "internvl2_1b",
    "musicgen-medium": "musicgen_medium",
    "zamba2-2.7b": "zamba2_2p7b",
    "rwkv6-3b": "rwkv6_3b",
}


def _module(name: str):
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    return importlib.import_module(f"repro.configs.{mod_name}")


def get_config(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    return _module(name).SMOKE


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
