"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16e top-1, shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    n_experts=16,
    n_experts_active=1,
    shared_expert=True,
    router_act="sigmoid",
    mlp_type="swiglu",
    rope_theta=500_000.0,
    remat="group:8",
)

SMOKE = CONFIG.replace(
    name="llama4-scout-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab=256, n_experts=4, n_experts_active=1, dtype="float32",
    attn_q_chunk=32, attn_kv_chunk=32, vocab_pad_multiple=8,
)
