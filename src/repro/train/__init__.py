"""Training substrate: optimizer, checkpointing, compression, train loop."""
