"""Sharded checkpointing: atomic commit, keep-k, elastic restore.

Production-shaped without orbax (offline container): the state pytree is
flattened to named arrays, written as one .npz per host shard plus a JSON
manifest, committed by atomic directory rename. Checkpoints are *logical*
(named arrays, full shapes) so a restart on a different topology or a
resharded mesh restores transparently — elasticity is a property of the
format, not a special code path.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

MANIFEST = "manifest.json"


def _flatten_with_names(tree: Any) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        out[name] = np.asarray(leaf)
    return out


def save(ckpt_dir: str, step: int, state: Any, *, host_id: int = 0,
         keep: int = 3) -> str:
    """Write state atomically as <ckpt_dir>/step_<n>/. Returns the path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=f".tmp_step_{step}_", dir=ckpt_dir)
    arrays = _flatten_with_names(state)
    np.savez(os.path.join(tmp, f"shard_{host_id:05d}.npz"), **arrays)
    manifest = {
        "step": step,
        "names": sorted(arrays.keys()),
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "host_count": 1,
        "format_version": 1,
    }
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):  # idempotent re-save at same step
        shutil.rmtree(final)
    os.rename(tmp, final)      # atomic commit
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
    # clean stale tmp dirs from crashed writers
    for d in os.listdir(ckpt_dir):
        if d.startswith(".tmp_"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and os.path.exists(
                 os.path.join(ckpt_dir, d, MANIFEST))]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Any, step: int | None = None,
            sharding_fn: Callable[[str, np.ndarray], Any] | None = None) -> tuple[int, Any]:
    """Restore into the structure of `like` (a pytree of arrays or SDS).

    `sharding_fn(name, np_array) -> jax.Array` lets the caller place each
    array with its target sharding (elastic restore onto any mesh); default
    is plain device_put.
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, MANIFEST)) as f:
        manifest = json.load(f)
    data: dict[str, np.ndarray] = {}
    for fn in sorted(os.listdir(d)):
        if fn.endswith(".npz"):
            with np.load(os.path.join(d, fn)) as z:
                data.update({k: z[k] for k in z.files})
    missing = set(manifest["names"]) - set(data)
    if missing:
        raise ValueError(f"checkpoint incomplete, missing arrays: {sorted(missing)[:5]}")

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        if name not in data:
            raise KeyError(f"array {name!r} not in checkpoint")
        arr = data[name]
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{name}: checkpoint shape {arr.shape} != {want_shape}")
        if sharding_fn is not None:
            out.append(sharding_fn(name, arr))
        else:
            dtype = leaf.dtype if hasattr(leaf, "dtype") else arr.dtype
            out.append(jnp.asarray(arr, dtype=dtype))
    return step, jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Overlaps checkpoint writes with training (single in-flight write)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, state: Any) -> None:
        self.wait()
        host_state = jax.tree.map(np.asarray, state)  # snapshot before async

        def run():
            try:
                save(self.ckpt_dir, step, host_state, keep=self.keep)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
