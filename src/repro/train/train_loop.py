"""Jitted train step + fault-tolerant training loop.

Features required at 1000+-node scale, exercised here at laptop scale:
  - microbatch gradient accumulation (scan) inside one jit step,
  - checkpoint/restart (atomic, keep-k, async) — resume is bitwise-exact,
  - straggler watchdog: per-step wall-time EMA; steps slower than
    `straggler_factor` x EMA fire a callback (at scale: re-issue the shard
    to a backup host — the deterministic (step, host)-keyed data pipeline in
    repro.data.tokens is what makes any host able to recompute any shard),
  - optional PQ gradient compression with error feedback (cross-pod trick).
"""
from __future__ import annotations

import functools
import time
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.data import tokens as tok
from repro.models import model as model_lib
from repro.models.config import ModelConfig
from repro.train import checkpoint as ckpt_lib
from repro.train import grad_compress as gc_lib
from repro.train import optimizer as opt_lib


class TrainState(NamedTuple):
    params: Any
    opt: opt_lib.AdamWState
    ef_error: Any | None = None   # error-feedback state (grad compression)


def make_train_step(cfg: ModelConfig, ocfg: opt_lib.AdamWConfig,
                    microbatches: int = 1) -> Callable:
    """Build the jitted (state, batch) -> (state, metrics) step.

    With microbatches > 1, the global batch is split on axis 0 and gradients
    are accumulated in f32 by a lax.scan before one optimizer update.
    """

    def loss_fn(params, batch):
        return model_lib.loss_fn(params, batch, cfg)

    def step(state: TrainState, batch: dict) -> tuple[TrainState, dict]:
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])

            mb = jax.tree.map(split, batch)
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

            def acc_body(carry, mbatch):
                g_acc, loss_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mbatch)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / microbatches,
                    g_acc, g)
                return (g_acc, loss_acc + l / microbatches), m

            (grads, loss), metrics = jax.lax.scan(
                acc_body, (zero, jnp.float32(0.0)), mb)
            metrics = jax.tree.map(lambda x: x[-1], metrics)

        new_params, new_opt, opt_metrics = opt_lib.apply_updates(
            state.params, grads, state.opt, ocfg)
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return TrainState(new_params, new_opt, state.ef_error), metrics

    return step


class StragglerWatchdog:
    """Step-time EMA; flags steps slower than factor x EMA (backup-task hook)."""

    def __init__(self, factor: float = 3.0, alpha: float = 0.2,
                 on_straggler: Callable[[int, float, float], None] | None = None):
        self.factor = factor
        self.alpha = alpha
        self.ema: float | None = None
        self.events: list[tuple[int, float, float]] = []
        self.on_straggler = on_straggler

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = False
        if self.ema is not None and dt > self.factor * self.ema:
            is_straggler = True
            self.events.append((step, dt, self.ema))
            if self.on_straggler:
                self.on_straggler(step, dt, self.ema)
            # do not poison the EMA with the outlier
        else:
            self.ema = dt if self.ema is None else (
                (1 - self.alpha) * self.ema + self.alpha * dt)
        return is_straggler


def train(cfg: ModelConfig, *, steps: int, global_batch: int, seq_len: int,
          ocfg: opt_lib.AdamWConfig | None = None, ckpt_dir: str | None = None,
          ckpt_every: int = 0, microbatches: int = 1, seed: int = 0,
          grad_compress: bool = False,
          codec: gc_lib.PQGradCodec | None = None,
          log: Callable[[str], None] = print) -> tuple[TrainState, list[dict]]:
    """Single-process training driver with checkpoint/restart."""
    ocfg = ocfg or opt_lib.AdamWConfig(total_steps=steps)
    pipe_cfg = tok.TokenPipelineConfig(vocab=cfg.vocab, seq_len=seq_len,
                                       global_batch=global_batch, seed=seed)

    params = model_lib.init_lm(jax.random.PRNGKey(seed), cfg)
    state = TrainState(params, opt_lib.init_state(params),
                       gc_lib.init_error(params) if grad_compress else None)
    start_step = 0
    checkpointer = None
    if ckpt_dir:
        checkpointer = ckpt_lib.AsyncCheckpointer(ckpt_dir)
        last = ckpt_lib.latest_step(ckpt_dir)
        if last is not None:
            start_step, state = ckpt_lib.restore(ckpt_dir, state, step=last)
            log(f"[train] resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(cfg, ocfg, microbatches))
    watchdog = StragglerWatchdog()
    codec = codec or gc_lib.PQGradCodec()
    history: list[dict] = []

    for step in range(start_step, steps):
        batch = tok.batch_at_step(pipe_cfg, step)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, dict(batch._asdict()))
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        watchdog.observe(step, dt)

        if grad_compress and state.ef_error is not None:
            pass  # compression is applied inside examples/dist_opt flows

        rec = {k: float(v) for k, v in metrics.items()}
        rec.update(step=step, dt=dt)
        history.append(rec)
        if step % max(1, steps // 10) == 0:
            log(f"[train] step {step}: loss={rec['loss']:.4f} "
                f"gnorm={rec['grad_norm']:.3f} dt={dt*1e3:.0f}ms")
        if checkpointer and ckpt_every and (step + 1) % ckpt_every == 0:
            checkpointer.save(step + 1, state)
    if checkpointer:
        checkpointer.wait()
        if ckpt_every:
            checkpointer.save(steps, state)
            checkpointer.wait()
    return state, history
