"""AdamW with cosine schedule and global-norm clipping, implemented in-repo.

Optimizer state mirrors the param tree (f32 moments regardless of param
dtype — bf16 params with f32 moments is the production-standard mix). The
update is a pure function usable inside pjit; state shapes/axes derive from
the param specs so the dry-run can shard them without allocation.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array   # () int32
    mu: Any           # f32 tree like params
    nu: Any           # f32 tree like params


def init_state(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def state_shapes(param_shapes: Any) -> AdamWState:
    f32 = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
                       param_shapes)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32), mu=f32, nu=f32)


def state_axes(param_axes: Any) -> AdamWState:
    return AdamWState(step=None, mu=param_axes, nu=param_axes)


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    floor = cfg.min_lr_ratio
    return cfg.lr * warm * (floor + (1.0 - floor) * cos)


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(params: Any, grads: Any, state: AdamWState,
                  cfg: AdamWConfig) -> tuple[Any, AdamWState, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
