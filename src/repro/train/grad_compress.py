"""PQ gradient compression with error feedback — the paper's encode/ADC
machinery reused as a distributed-optimization trick.

For the slow cross-pod links, gradients are 4-bit-PQ encoded before the
exchange: each gradient tensor is reshaped to (N, dsub) rows, quantized
against a per-tensor 16-entry codebook (k-means on a sample of rows), and
only the 4-bit codes + the tiny codebook cross the wire (7.9x compression at
dsub=4 vs f32). The residual (g - decode(encode(g))) is carried into the
next step's gradient (error feedback), which keeps SGD convergence.

This module implements the *compression codec* + error-feedback state; the
cross-pod exchange itself is a standard psum of the decoded tensors (the
codes being exchanged is what a custom collective would ship — on a dry-run
mesh we account bytes in the roofline instead).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.kmeans import kmeans


class PQGradCodec(NamedTuple):
    dsub: int = 4          # gradient sub-vector length
    k: int = 16            # 4-bit codebooks
    iters: int = 5         # k-means refinement per step (cheap, on samples)
    sample: int = 4096     # rows sampled for codebook training


class CompressedGrad(NamedTuple):
    codes: jax.Array       # (N,) uint8 — two 4-bit codes per byte
    codebook: jax.Array    # (16, dsub) f32
    shape: tuple           # original shape
    nrows: int


def _rows(g: jax.Array, dsub: int) -> jax.Array:
    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % dsub
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, dsub)


def compress(key: jax.Array, g: jax.Array, codec: PQGradCodec) -> CompressedGrad:
    rows = _rows(g, codec.dsub)
    n = rows.shape[0]
    idx = jax.random.randint(key, (min(codec.sample, n),), 0, n)
    res = kmeans(key, rows[idx], k=codec.k, iters=codec.iters)
    cb = res.centroids                                   # (16, dsub)
    d = (jnp.sum(rows * rows, -1, keepdims=True)
         - 2.0 * rows @ cb.T + jnp.sum(cb * cb, -1)[None])
    codes = jnp.argmin(d, axis=-1).astype(jnp.uint8)     # (N,)
    pad = (-codes.shape[0]) % 2
    if pad:
        codes = jnp.pad(codes, (0, pad))
    packed = codes[0::2] | (codes[1::2] << 4)
    return CompressedGrad(packed, cb, tuple(g.shape), n)


def decompress(c: CompressedGrad) -> jax.Array:
    lo = (c.codes & 0xF).astype(jnp.int32)
    hi = ((c.codes >> 4) & 0xF).astype(jnp.int32)
    codes = jnp.stack([lo, hi], -1).reshape(-1)[:c.nrows]
    rows = c.codebook[codes]                             # (N, dsub)
    flat = rows.reshape(-1)
    size = 1
    for s in c.shape:
        size *= s
    return flat[:size].reshape(c.shape)


def compressed_bytes(c: CompressedGrad) -> int:
    return int(c.codes.size) + int(c.codebook.size) * 4


def ef_step(key: jax.Array, grads: Any, error: Any, codec: PQGradCodec
            ) -> tuple[Any, Any, dict]:
    """Error-feedback compression of a gradient pytree.

    Returns (decoded grads to feed the optimizer, new error state, stats).
    Semantics: send = compress(g + e); e' = (g + e) - decode(send).
    """
    leaves, treedef = jax.tree.flatten(grads)
    err_leaves = jax.tree.leaves(error)
    keys = jax.random.split(key, len(leaves))
    out, new_err = [], []
    raw_bytes = comp_bytes = 0
    for k, g, e in zip(keys, leaves, err_leaves):
        target = g.astype(jnp.float32) + e
        c = compress(k, target, codec)
        dec = decompress(c).astype(jnp.float32)
        out.append(dec.astype(g.dtype))
        new_err.append(target - dec)
        raw_bytes += g.size * 4
        comp_bytes += compressed_bytes(c)
    stats = {"raw_bytes": raw_bytes, "compressed_bytes": comp_bytes,
             "ratio": raw_bytes / max(comp_bytes, 1)}
    return (jax.tree.unflatten(treedef, out),
            jax.tree.unflatten(treedef, new_err), stats)


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
