"""Crash-safe durable index: snapshot + WAL recovery under fault injection.

The contract under test is docs/persistence.md's *prefix-or-loud* recovery:
for ANY injected fault — torn write, bit flip, short read, missing file,
crash at I/O step N — reopening a durable directory yields either an engine
whose results are bit-identical to the never-crashed engine over a prefix
of the acknowledged mutations, or a typed ``CorruptSnapshotError`` /
``CorruptWALError`` / ``NoSnapshotError``. Never a silently wrong index.

Bit-identity is asserted with ``assert_array_equal`` (integer-exact ADC,
deterministic encoder) across staged/fused paths, scan/rerank impls, the
filtered and namespaced paths, and both ShardedEngine drivers.
"""
import functools
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as hst

import faults
from repro.core import ivf
from repro.core.lists import filter_from_attrs, store_arrays, store_from_arrays
from repro.data import vectors
from repro.engine import EngineConfig, SearchEngine, ShardedEngine
from repro import persist
from repro.persist import (CorruptSnapshotError, CorruptWALError,
                           NoSnapshotError, WALWriter)
from repro.persist import wal as wal_mod

NLIST = 16
D = 32
M = 8


@functools.lru_cache(maxsize=None)
def _built():
    ds = vectors.make_sift_like(n=2000, nt=1000, nq=6, d=D, ncl=16, seed=5)
    index = ivf.build_ivf(jax.random.PRNGKey(0), jnp.asarray(ds.train),
                          jnp.asarray(ds.base), m=M, nlist=NLIST,
                          coarse_iters=4, pq_iters=4)
    return ds, index


def _attr_of(gids):
    return (np.asarray(gids, np.int64) % 5).astype(np.int32)


def mk_engine(cfg: EngineConfig | None = None, *, attrs=False,
              namespaces=None) -> SearchEngine:
    ds, index = _built()
    store = index.lists
    if attrs:
        ids = np.asarray(store.ids)
        store = store._replace(attrs=jnp.asarray(
            np.where(ids >= 0, _attr_of(np.maximum(ids, 0)), -1)
            .astype(np.int32)))
    return SearchEngine(index._replace(lists=store),
                        base=jnp.asarray(ds.base),
                        config=cfg or EngineConfig(nprobe=6, rerank_mult=2),
                        namespaces=namespaces)


def _queries():
    ds, _ = _built()
    return jnp.asarray(ds.queries)


# every op appends exactly ONE WAL record (delete slabs are disjoint and
# always find live rows), so acknowledged-prefix j == ops[:j] applied
def scripted_ops(n=6, seed=11):
    rng = np.random.default_rng(seed)
    ops = []
    for i in range(n):
        r = i % 3
        if r == 0 or r == 1:
            ids = np.arange(2000 + 40 * i, 2000 + 40 * i + 25)
            ops.append(("upsert", ids,
                        rng.normal(size=(25, D)).astype(np.float32)))
        else:
            ops.append(("delete", np.arange(100 * i, 100 * i + 40)))
    ops.append(("compact",))
    return ops[:n]


def apply_ops(eng, ops):
    for op in ops:
        if op[0] == "upsert":
            eng.upsert(op[1], op[2])
        elif op[0] == "delete":
            eng.delete(op[1])
        else:
            eng.compact()


def assert_same_results(a, b, q, *, k=8, calls=("search", "search_jit"),
                        **kw):
    for call in calls:
        ra = getattr(a, call)(q, k, **kw)
        rb = getattr(b, call)(q, k, **kw)
        np.testing.assert_array_equal(np.asarray(ra.dists),
                                      np.asarray(rb.dists), err_msg=call)
        np.testing.assert_array_equal(np.asarray(ra.ids),
                                      np.asarray(rb.ids), err_msg=call)


# ---------------------------------------------------------------------------
# store serialization + WAL record format
# ---------------------------------------------------------------------------

def test_store_arrays_roundtrip():
    _, index = _built()
    rt = store_from_arrays(store_arrays(index.lists))
    np.testing.assert_array_equal(np.asarray(rt.codes),
                                  np.asarray(index.lists.codes))
    np.testing.assert_array_equal(np.asarray(rt.ids),
                                  np.asarray(index.lists.ids))
    assert rt.attrs is None


def test_wal_roundtrip_and_torn_tail(tmp_path):
    p = str(tmp_path / "wal-000000000001.log")
    w = WALWriter(p, 1)
    w.log_upsert(np.array([1, 2]), np.ones((2, 4), np.float32))
    w.log_delete(np.array([7]))
    w.log_compact(None)
    w.close()
    recs, valid, clean = wal_mod.scan_wal(p)
    assert clean and [r.op for r in recs] == ["upsert", "delete", "compact"]
    assert recs[1].seq == 2
    np.testing.assert_array_equal(recs[0].arrays["ids"], [1, 2])
    # torn tail: cut the last record mid-payload -> clean prefix, no error
    faults.truncate_file(p, fraction=0.9)
    recs2, valid2, clean2 = wal_mod.scan_wal(p)
    assert not clean2 and [r.op for r in recs2] == ["upsert", "delete"]
    # a fully-present record with a flipped byte must be LOUD, not a prefix
    w2path = str(tmp_path / "wal-000000000010.log")
    w2 = WALWriter(w2path, 10)
    w2.log_delete(np.array([1]))
    w2.log_delete(np.array([2]))
    w2.close()
    faults.flip_byte_in(w2path, offset=10)  # inside record 1's preamble
    with pytest.raises(CorruptWALError):
        wal_mod.scan_wal(w2path)


def test_wal_chain_gap_and_torn_middle_are_loud(tmp_path):
    d = str(tmp_path)
    w = WALWriter(os.path.join(d, persist.wal_name(1)), 1)
    w.log_delete(np.array([1]))
    w.log_delete(np.array([2]))
    w.close()
    w = WALWriter(os.path.join(d, persist.wal_name(3)), 3)
    w.log_delete(np.array([3]))
    w.close()
    assert [r.seq for r in persist.iter_wal(d)] == [1, 2, 3]
    # tear the FIRST (non-final) file: later files prove records are missing
    faults.truncate_file(os.path.join(d, persist.wal_name(1)), 0.5)
    with pytest.raises(CorruptWALError):
        list(persist.iter_wal(d))
    # missing middle file -> sequence gap
    os.remove(os.path.join(d, persist.wal_name(1)))
    with pytest.raises(CorruptWALError):
        list(persist.iter_wal(d))


# the replication seam (persist.replicate) resumes replay at exact seqs
# across segment boundaries — these edges must be surgically precise

def test_iter_wal_resume_at_rotation_seam(tmp_path):
    """after_seq landing exactly on a segment boundary yields precisely
    the later file's records — no duplicate, no skip."""
    d = str(tmp_path)
    w = WALWriter(os.path.join(d, persist.wal_name(1)), 1)
    w.log_delete(np.array([1]))
    w.log_delete(np.array([2]))
    w.rotate(d)  # seam: file 1 holds seqs 1-2, file 2 starts at 3
    w.log_delete(np.array([3]))
    w.log_delete(np.array([4]))
    w.close()
    assert [r.seq for r in persist.iter_wal(d, after_seq=0)] == [1, 2, 3, 4]
    assert [r.seq for r in persist.iter_wal(d, after_seq=2)] == [3, 4]  # seam
    assert [r.seq for r in persist.iter_wal(d, after_seq=3)] == [4]
    assert [r.seq for r in persist.iter_wal(d, after_seq=4)] == []
    assert [r.seq for r in persist.iter_wal(d, after_seq=99)] == []


def test_iter_wal_duplicate_seqs_across_files(tmp_path):
    """Duplicates at or below after_seq are skipped exactly; a duplicate
    ABOVE it is a forked history and must be loud (contiguity check)."""
    d = str(tmp_path)
    w = WALWriter(os.path.join(d, persist.wal_name(1)), 1)
    w.log_delete(np.array([1]))
    w.log_delete(np.array([2]))
    w.close()
    # a re-shipped/re-created file whose records OVERLAP the previous one
    with open(os.path.join(d, persist.wal_name(2)), "wb") as f:
        for seq in (2, 3):
            f.write(wal_mod.encode_record(
                seq, "delete", {"ids": np.array([seq], np.int64)}))
    # resuming past the duplicate: seq 2 copies are both skipped, 3 plays
    assert [r.seq for r in persist.iter_wal(d, after_seq=2)] == [3]
    # replaying from scratch meets seq 2 twice above after_seq: loud
    with pytest.raises(CorruptWALError, match="gap|order"):
        list(persist.iter_wal(d, after_seq=0))


def test_iter_wal_empty_trailing_file(tmp_path):
    """A trailing segment holding only its file header (rotation raced a
    crash before the first append) contributes nothing and breaks nothing."""
    d = str(tmp_path)
    w = WALWriter(os.path.join(d, persist.wal_name(1)), 1)
    w.log_delete(np.array([1]))
    w.close()
    w2 = WALWriter(os.path.join(d, persist.wal_name(2)), 2)  # header only
    w2.close()
    assert [r.seq for r in persist.iter_wal(d, after_seq=0)] == [1]
    assert [r.seq for r in persist.iter_wal(d, after_seq=1)] == []
    # and a zero-byte trailing file (legacy crash signature) too
    open(os.path.join(d, persist.wal_name(2)), "w").close()
    assert [r.seq for r in persist.iter_wal(d, after_seq=0)] == [1]


# ---------------------------------------------------------------------------
# recovery bit-identity across every query path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scan_impl,rerank_impl", [
    ("ref", "gathered"), ("select", "gathered"),
    ("mxu", "stream"), ("stream", "stream")])
def test_recovery_bit_identity_impls(tmp_path, scan_impl, rerank_impl):
    cfg = EngineConfig(nprobe=6, rerank_mult=2, scan_impl=scan_impl,
                       rerank_impl=rerank_impl)
    eng = mk_engine(cfg)
    persist.ensure_attached(eng, str(tmp_path))
    apply_ops(eng, scripted_ops())
    rec, info = persist.open_engine(str(tmp_path), attach=False)
    assert info.replayed == len(scripted_ops()) and info.truncated_bytes == 0
    assert rec.epoch == eng.epoch and rec.n_tombstones == eng.n_tombstones
    assert_same_results(eng, rec, _queries())


def test_recovery_bit_identity_filtered_and_namespaced(tmp_path):
    ns = jnp.ones((3, NLIST), bool)
    eng = mk_engine(EngineConfig(nprobe=6, rerank_mult=2), attrs=True,
                    namespaces=ns)
    persist.ensure_attached(eng, str(tmp_path))
    ops = scripted_ops()
    for op in ops:  # attrs column requires attr values on upsert
        if op[0] == "upsert":
            eng.upsert(op[1], op[2], attrs=_attr_of(op[1]))
        elif op[0] == "delete":
            eng.delete(op[1])
        else:
            eng.compact()
    rec, _ = persist.open_engine(str(tmp_path), attach=False)
    assert rec.ns_member is not None
    fb_live = filter_from_attrs(eng.index.lists, lambda a: a % 5 != 1)
    fb_rec = filter_from_attrs(rec.index.lists, lambda a: a % 5 != 1)
    np.testing.assert_array_equal(np.asarray(fb_live), np.asarray(fb_rec))
    q = _queries()
    nsq = np.array([0, 1, 2, 0, 1, -1], np.int32)[:q.shape[0]]
    for call in ("search", "search_jit"):
        ra = getattr(eng, call)(q, 8, filter_bits=fb_live, namespaces=nsq)
        rb = getattr(rec, call)(q, 8, filter_bits=fb_rec, namespaces=nsq)
        np.testing.assert_array_equal(np.asarray(ra.ids),
                                      np.asarray(rb.ids), err_msg=call)
        np.testing.assert_array_equal(np.asarray(ra.dists),
                                      np.asarray(rb.dists), err_msg=call)


def test_sharded_recovery_both_drivers(tmp_path):
    eng = mk_engine(EngineConfig(nprobe=6, rerank_mult=2))
    sh = ShardedEngine(eng, 2)
    persist.ensure_attached(sh, str(tmp_path))
    apply_ops(sh, scripted_ops(5))
    rec, info = persist.open_engine(str(tmp_path), attach=False)
    assert isinstance(rec, ShardedEngine) and info.replayed == 5
    assert rec.epoch == sh.epoch
    q = _queries()
    assert_same_results(sh, rec, q, calls=("search",))      # vmap driver
    # shard_map driver needs mesh size == num_shards: use a 1-shard engine
    d2 = str(tmp_path / "mesh")
    sh1 = ShardedEngine(mk_engine(EngineConfig(nprobe=6, rerank_mult=2)), 1)
    persist.ensure_attached(sh1, d2)
    apply_ops(sh1, scripted_ops(3))
    rec1, _ = persist.open_engine(d2, attach=False)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("shards",))
    ra = sh1.search(q, 8, mesh=mesh)
    rb = rec1.search(q, 8, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(ra.ids), np.asarray(rb.ids))
    np.testing.assert_array_equal(np.asarray(ra.dists), np.asarray(rb.dists))


def test_recovered_engine_stays_durable(tmp_path):
    """open_engine attaches a positioned writer: mutations after recovery
    land at the next contiguous seq and survive another recovery."""
    eng = mk_engine()
    persist.ensure_attached(eng, str(tmp_path))
    apply_ops(eng, scripted_ops(3))
    rec, info = persist.open_engine(str(tmp_path))
    assert rec._wal is not None and rec._wal.last_seq == info.last_seq
    apply_ops(rec, scripted_ops(2, seed=23))
    rec2, info2 = persist.open_engine(str(tmp_path), attach=False)
    assert info2.last_seq == info.last_seq + 2
    assert_same_results(rec, rec2, _queries())


# ---------------------------------------------------------------------------
# fault sweeps: prefix-or-loud
# ---------------------------------------------------------------------------

def _fresh_durable(tmp_path, name):
    d = str(tmp_path / name)
    eng = mk_engine()
    persist.ensure_attached(eng, d)
    return eng, d


def _prefix_references(ops, q, k=8):
    """Never-crashed search results after each ops prefix [0..n]."""
    eng = mk_engine()
    refs = []
    for i in range(len(ops) + 1):
        r = eng.search(q, k)
        refs.append((np.asarray(r.dists).copy(), np.asarray(r.ids).copy()))
        if i < len(ops):
            apply_ops(eng, ops[i:i + 1])
    return refs


def _matches_some_prefix(engine, refs, q, k=8):
    r = engine.search(q, k)
    d, i = np.asarray(r.dists), np.asarray(r.ids)
    return any((d == rd).all() and (i == ri).all() for rd, ri in refs)


def test_kill_at_every_mutation_step_recovers_prefix(tmp_path):
    """Crash inside the k-th WAL append: the torn record was never
    acknowledged, recovery yields exactly ops[:k-1]."""
    ops = scripted_ops(5)
    q = _queries()
    refs = _prefix_references(ops, q)
    for k in range(1, len(ops) + 1):
        eng, d = _fresh_durable(tmp_path, f"mut{k}")
        with faults.FaultInjector(crash_at_write=k, torn_fraction=0.6):
            with pytest.raises(faults.SimulatedCrash):
                apply_ops(eng, ops)
        rec, info = persist.open_engine(d, attach=False)
        assert info.last_seq == k - 1, f"crash at append {k}"
        assert info.truncated_bytes > 0  # the torn record was dropped
        r = rec.search(q, 8)
        np.testing.assert_array_equal(np.asarray(r.dists), refs[k - 1][0])
        np.testing.assert_array_equal(np.asarray(r.ids), refs[k - 1][1])


def test_crash_at_every_checkpoint_step_keeps_old_state(tmp_path):
    """Crash at the N-th write inside save_snapshot: the manifest still
    names the previous complete snapshot and the intact WAL chain replays
    to the FULL pre-crash state — nothing acknowledged is lost."""
    ops = scripted_ops(4)
    # count the writes one checkpoint performs
    eng, d = _fresh_durable(tmp_path, "count")
    apply_ops(eng, ops)
    with faults.FaultInjector() as counter:
        persist.save_snapshot(eng, d)
    n_writes = counter.writes
    assert n_writes >= 5
    q = _queries()
    want = eng.search(q, 8)
    for n in range(1, n_writes + 1):
        eng_n, d_n = _fresh_durable(tmp_path, f"ck{n}")
        apply_ops(eng_n, ops)
        with faults.FaultInjector(crash_at_write=n):
            with pytest.raises(faults.SimulatedCrash):
                persist.save_snapshot(eng_n, d_n)
        rec, info = persist.open_engine(d_n, attach=False)
        assert info.last_seq == len(ops), f"crash at write {n}"
        r = rec.search(q, 8)
        np.testing.assert_array_equal(np.asarray(r.dists),
                                      np.asarray(want.dists))
        np.testing.assert_array_equal(np.asarray(r.ids),
                                      np.asarray(want.ids))


def test_bitflip_in_every_snapshot_file_is_loud(tmp_path):
    eng, d = _fresh_durable(tmp_path, "flip")
    apply_ops(eng, scripted_ops(3))
    persist.save_snapshot(eng, d)
    targets = faults.snapshot_files(d) + [os.path.join(d, persist.MANIFEST_NAME)]
    for i, path in enumerate(targets):
        pristine = path + ".orig"
        shutil.copyfile(path, pristine)
        faults.flip_byte_in(path, seed=i)
        with pytest.raises(CorruptSnapshotError):
            persist.open_engine(d, attach=False)
        os.replace(pristine, path)
    # repaired directory loads again
    persist.open_engine(d, attach=False)


def test_bitflip_in_wal_is_loud(tmp_path):
    eng, d = _fresh_durable(tmp_path, "walflip")
    apply_ops(eng, scripted_ops(4))
    for path in faults.wal_paths(d):
        pristine = path + ".orig"
        shutil.copyfile(path, pristine)
        # flip inside the FIRST record so the damage is not a torn tail
        faults.flip_byte_in(path, offset=40)
        with pytest.raises(CorruptWALError):
            persist.open_engine(d, attach=False)
        os.replace(pristine, path)
    persist.open_engine(d, attach=False)


def test_missing_files_are_typed(tmp_path):
    eng, d = _fresh_durable(tmp_path, "missing")
    apply_ops(eng, scripted_ops(3))
    persist.save_snapshot(eng, d)
    seg = faults.snapshot_files(d)[0]
    pristine = seg + ".orig"
    shutil.copyfile(seg, pristine)
    os.remove(seg)
    with pytest.raises(CorruptSnapshotError):
        persist.open_engine(d, attach=False)
    os.replace(pristine, seg)
    # a deleted manifest is NoSnapshotError (fresh-vs-damaged distinction)
    man = os.path.join(d, persist.MANIFEST_NAME)
    shutil.copyfile(man, man + ".orig")
    os.remove(man)
    with pytest.raises(NoSnapshotError):
        persist.open_engine(d, attach=False)
    os.replace(man + ".orig", man)
    persist.open_engine(d, attach=False)


def test_short_read_prefix_or_loud(tmp_path):
    """Truncate the N-th read during recovery, for every N: recovery must
    either land on SOME acknowledged prefix or raise a typed error."""
    ops = scripted_ops(4)
    eng, d = _fresh_durable(tmp_path, "short")
    apply_ops(eng, ops[:2])
    persist.save_snapshot(eng, d)
    apply_ops(eng, ops[2:])
    q = _queries()
    refs = _prefix_references(ops, q)
    with faults.FaultInjector() as counter:
        persist.open_engine(d, attach=False)
    outcomes = {"ok": 0, "loud": 0}
    for n in range(1, counter.reads + 1):
        with faults.FaultInjector(short_read_at=n):
            try:
                rec, _ = persist.open_engine(d, attach=False)
            except (CorruptSnapshotError, CorruptWALError,
                    NoSnapshotError):
                outcomes["loud"] += 1
                continue
        assert _matches_some_prefix(rec, refs, q), \
            f"short read {n}: silently wrong state"
        outcomes["ok"] += 1
    assert outcomes["loud"] > 0  # snapshot segments cannot shrink silently


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large,
                                 HealthCheck.function_scoped_fixture])
@given(crash=hst.integers(min_value=1, max_value=8),
       flip=hst.booleans(), seed=hst.integers(min_value=0, max_value=99))
def test_fault_sweep_never_silently_wrong(tmp_path_factory, crash, flip,
                                          seed):
    """Hypothesis sweep: random crash step x optional bit flip x rng seed.
    Every outcome is a typed error or a bit-identical acknowledged prefix."""
    ops = scripted_ops(4, seed=seed)
    q = _queries()
    refs = _prefix_references(ops, q)
    d = str(tmp_path_factory.mktemp("sweep"))
    eng = mk_engine()
    persist.ensure_attached(eng, d)
    # when flipping, crash two writes LATER so the rotted write completes
    # and is acknowledged — recovery must then be loud, never lossy-silent
    inj = faults.FaultInjector(crash_at_write=crash + 2 if flip else crash,
                               flip_write_byte=crash if flip else None,
                               seed=seed)
    with inj:
        try:
            apply_ops(eng, ops[:2])
            persist.save_snapshot(eng, d)
            apply_ops(eng, ops[2:])
        except faults.SimulatedCrash:
            pass
    try:
        rec, _ = persist.open_engine(d, attach=False)
    except (CorruptSnapshotError, CorruptWALError, NoSnapshotError):
        return  # loud is a correct outcome
    assert _matches_some_prefix(rec, refs, q), "silently wrong recovery"


def test_reinit_of_foreign_directory_refused(tmp_path):
    eng, d = _fresh_durable(tmp_path, "own")
    other = mk_engine()
    with pytest.raises(ValueError, match="open_engine"):
        persist.ensure_attached(other, d)


def test_custom_coarse_refused_at_save(tmp_path):
    ds, index = _built()

    class Custom:
        def search(self, q, nprobe):
            raise NotImplementedError

    eng = SearchEngine(index, coarse=Custom())
    with pytest.raises(ValueError, match="custom"):
        persist.save_snapshot(eng, str(tmp_path))
