"""Anytime search: margin probe pruning + in-kernel early-exit tile pruning.

Two claims are load-bearing (docs/anytime.md) and everything here drives
at them with exact oracles, never allclose:

  1. ``margin_prune_probes`` with ``tau=inf`` is the identity, the best
     probe always survives, and the pruned counter is exact — so
     ``probe_policy='margin'`` at ``tau=inf`` is bit-identical to 'fixed'
     through the whole engine (staged, fused, sharded, serving).
  2. The stream kernel's early-exit bound is admissible: the final
     top-``keep`` selection over the pruned pool is bit-identical to the
     unpruned kernel's for every shape/occupancy/filter combination, even
     when the skewed-data path genuinely skips tiles.

Hypothesis drives the probe-pruning property (gracefully skipped when the
package is absent — see conftest); deterministic seed-swept twins cover
the same oracles in the tier-1 container.
"""
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as hst

from repro.core import ivf
from repro.core.lists import ListStore, pack_filter_mask
from repro.core.pq import PQCodebook
from repro.core.topk import gather_ids, margin_prune_probes, masked_topk
from repro.data import vectors
from repro.engine import EngineConfig, SearchEngine, ShardedEngine
from repro.engine.engine import coarse_probes, scan_candidates
from repro.kernels import ops
from repro.serving.loop import ServingLoop

_SETTINGS = dict(max_examples=25, deadline=None,
                 suppress_health_check=[HealthCheck.too_slow,
                                        HealthCheck.data_too_large])


# ---------------------------------------------------------------------------
# margin_prune_probes: unit + property
# ---------------------------------------------------------------------------

def _check_margin_invariants(vals, probes, tau):
    out, pruned = margin_prune_probes(jnp.asarray(vals), jnp.asarray(probes),
                                      tau)
    out = np.asarray(out)
    pruned = np.asarray(pruned)
    vals = np.asarray(vals)
    probes = np.asarray(probes)
    present = probes >= 0
    taus = np.broadcast_to(np.asarray(tau, np.float32).reshape(-1, 1)
                           if np.ndim(tau) == 1 else np.float32(tau),
                           probes.shape)
    for qi in range(probes.shape[0]):
        kept = out[qi] >= 0
        # pruning only ever clears slots, never invents them, and a kept
        # slot keeps its probe id
        assert not (kept & ~present[qi]).any()
        np.testing.assert_array_equal(out[qi][kept], probes[qi][kept])
        if present[qi].any():
            d0 = vals[qi][present[qi]].min()
            # the best probe always survives (ties included)
            best = present[qi] & (vals[qi] <= d0)
            assert kept[best].all(), "a best-distance probe was pruned"
            # the margin rule, slot by slot
            want = present[qi] & (
                (vals[qi] <= d0 * (1.0 + taus[qi]))
                | np.isposinf(taus[qi]) | (vals[qi] <= d0))
            np.testing.assert_array_equal(kept, want)
        assert pruned[qi] == int((present[qi] & ~kept).sum())
    return out, pruned


@given(qn=hst.integers(1, 5), pn=hst.integers(1, 8),
       tau=hst.floats(0.0, 4.0), frac=hst.floats(0.0, 1.0),
       seed=hst.integers(0, 2**31 - 1))
@settings(**_SETTINGS)
def test_margin_prune_property(qn, pn, tau, frac, seed):
    rng = np.random.default_rng(seed)
    vals = rng.random((qn, pn)).astype(np.float32) * 10
    probes = np.where(rng.random((qn, pn)) < frac,
                      rng.integers(0, 64, (qn, pn)), -1).astype(np.int32)
    _check_margin_invariants(vals, probes, tau)


@pytest.mark.parametrize("seed", range(8))
def test_margin_prune_seeds(seed):
    rng = np.random.default_rng(seed)
    qn, pn = int(rng.integers(1, 5)), int(rng.integers(1, 8))
    vals = rng.random((qn, pn)).astype(np.float32) * 10
    probes = np.where(rng.random((qn, pn)) < rng.random(),
                      rng.integers(0, 64, (qn, pn)), -1).astype(np.int32)
    for tau in (0.0, 0.3, float(seed), np.inf):
        _check_margin_invariants(vals, probes, tau)


def test_margin_prune_tau_inf_is_identity():
    rng = np.random.default_rng(0)
    vals = rng.random((3, 6)).astype(np.float32)
    probes = rng.integers(-1, 20, (3, 6)).astype(np.int32)
    out, pruned = margin_prune_probes(jnp.asarray(vals), jnp.asarray(probes),
                                      np.inf)
    np.testing.assert_array_equal(np.asarray(out), probes)
    np.testing.assert_array_equal(np.asarray(pruned), 0)


def test_margin_prune_all_absent_row_stays_absent():
    vals = jnp.full((2, 4), jnp.inf, jnp.float32)
    probes = jnp.full((2, 4), -1, jnp.int32)
    out, pruned = margin_prune_probes(vals, probes, 0.0)
    np.testing.assert_array_equal(np.asarray(out), -1)
    np.testing.assert_array_equal(np.asarray(pruned), 0)


def test_margin_prune_per_query_tau_and_monotonicity():
    rng = np.random.default_rng(3)
    vals = rng.random((4, 8)).astype(np.float32)
    probes = rng.integers(0, 32, (4, 8)).astype(np.int32)
    taus = np.array([0.0, 0.2, 1.0, np.inf], np.float32)
    out_vec, pruned_vec = _check_margin_invariants(vals, probes, taus)
    # each row of the vector call == the scalar call at that row's tau
    for qi, t in enumerate(taus):
        out_s, pruned_s = margin_prune_probes(
            jnp.asarray(vals[qi:qi + 1]), jnp.asarray(probes[qi:qi + 1]),
            float(t))
        np.testing.assert_array_equal(out_vec[qi], np.asarray(out_s)[0])
        assert pruned_vec[qi] == int(np.asarray(pruned_s)[0])
    # widening tau never prunes more
    prev = None
    for t in (0.0, 0.1, 0.5, 2.0, np.inf):
        _, pruned = margin_prune_probes(jnp.asarray(vals),
                                        jnp.asarray(probes), float(t))
        tot = int(np.asarray(pruned).sum())
        assert prev is None or tot <= prev
        prev = tot


# ---------------------------------------------------------------------------
# early-exit stream scan vs the unpruned oracle (kernel grid)
# ---------------------------------------------------------------------------

def _synth_index(nlist, cap, m, *, d=None, seed=0, occupancy="ragged"):
    """IVFIndex from raw random arrays — no k-means, instant to build."""
    d = d or 4 * m
    rng = np.random.default_rng(seed)
    if isinstance(occupancy, str):
        sizes = (np.full(nlist, cap) if occupancy == "full"
                 else rng.integers(0, cap + 1, nlist))
    else:
        sizes = np.asarray(occupancy)
    codes = np.zeros((nlist, cap, m // 2), np.uint8)
    ids = np.full((nlist, cap), -1, np.int32)
    nxt = 0
    for li in range(nlist):
        s = int(sizes[li])
        codes[li, :s] = rng.integers(0, 256, (s, m // 2), np.uint8)
        ids[li, :s] = np.arange(nxt, nxt + s, dtype=np.int32)
        nxt += s
    return ivf.IVFIndex(
        centroids=jnp.asarray(rng.normal(size=(nlist, d)).astype(np.float32)),
        codebook=PQCodebook(jnp.asarray(
            rng.normal(size=(m, 16, d // m)).astype(np.float32))),
        lists=ListStore(codes=jnp.asarray(codes), ids=jnp.asarray(ids),
                        sizes=jnp.asarray(sizes.astype(np.int32))),
    )


def _skewed_index(nlist, cap, m, *, d=None, seed=0):
    """An index whose later lists sit far from the origin: queries near the
    origin get a huge ADC bias on those probes, so the early-exit bound can
    genuinely beat the running threshold and skip their tiles."""
    idx = _synth_index(nlist, cap, m, d=d, seed=seed, occupancy="full")
    cen = np.array(idx.centroids)
    cen[nlist // 2:] += 200.0  # push half the lists far away
    return idx._replace(centroids=jnp.asarray(cen))


def _topk_oracle(dists, ids, keep):
    v, pos = masked_topk(dists, ids >= 0, keep)
    return np.asarray(v), np.asarray(gather_ids(ids, pos))


def _assert_early_exit_lossless(index, q, probes, keep, tile_n,
                                filter_bits=None):
    base_d, base_i = ivf.scan_probes_stream(index, q, probes, keep=keep,
                                            tile_n=tile_n,
                                            filter_bits=filter_bits)
    ee_d, ee_i, skipped = ivf.scan_probes_stream(index, q, probes, keep=keep,
                                                 tile_n=tile_n,
                                                 filter_bits=filter_bits,
                                                 early_exit=True)
    want_v, want_i = _topk_oracle(base_d, base_i, keep)
    got_v, got_i = _topk_oracle(ee_d, ee_i, keep)
    np.testing.assert_array_equal(got_i, want_i)
    np.testing.assert_array_equal(got_v, want_v)
    return np.asarray(skipped)


EE_GRID = [
    # (nlist, cap, m, tile_n, keep, p, occupancy)
    (6, 64, 4, 32, 8, 3, "ragged"),     # multi-tile, ragged
    (6, 64, 4, 64, 8, 3, "full"),       # single tile per probe
    (4, 100, 8, 32, 5, 4, "ragged"),    # non-pow2 cap, p == nlist
    (8, 48, 4, 16, 16, 2, "ragged"),    # keep == tile_n (kc == keep, armed)
    (5, 32, 2, 8, 1, 5, "full"),        # keep=1, many tiny tiles
    (3, 64, 4, 16, 32, 3, "full"),      # keep > tile_n -> prune DISARMED
]


@pytest.mark.parametrize("nlist,cap,m,tile_n,keep,p,occ", EE_GRID)
def test_early_exit_scan_lossless_grid(nlist, cap, m, tile_n, keep, p, occ):
    rng = np.random.default_rng(nlist * 7 + cap + keep)
    index = _synth_index(nlist, cap, m, seed=nlist + cap, occupancy=occ)
    q = jnp.asarray(rng.normal(size=(3, 4 * m)).astype(np.float32))
    probes = np.where(rng.random((3, p)) < 0.8,
                      rng.integers(0, nlist, (3, p)), -1).astype(np.int32)
    probes[1, :] = -1  # one fully-pruned query (all-sentinel probe row)
    probes[2, :2] = probes[2, 0]  # duplicate probes
    skipped = _assert_early_exit_lossless(index, q, jnp.asarray(probes),
                                          keep, tile_n)
    assert (skipped >= 0).all()
    assert skipped[1] == 0  # no valid probes -> nothing to count as skipped


def test_early_exit_actually_skips_on_skewed_data():
    """The lossless grid can pass with zero pruning; this construction makes
    the bound genuinely fire so the skip path itself is exercised."""
    nlist, cap, m, tile_n, keep = 8, 64, 8, 16, 4
    index = _skewed_index(nlist, cap, m, seed=11)
    rng = np.random.default_rng(12)
    q = jnp.asarray(rng.normal(size=(2, 4 * m)).astype(np.float32))
    probes = jnp.asarray(np.tile(np.arange(nlist, dtype=np.int32), (2, 1)))
    skipped = _assert_early_exit_lossless(index, q, probes, keep, tile_n)
    assert skipped.sum() > 0, "skewed construction never pruned a tile"


def test_early_exit_lossless_with_filters_and_tombstones():
    """Filter bits (and the tombstone bitmap that rides the same path) must
    compose with the bound: the pre-selection mask shrinks candidates, the
    bound only ever skips tiles that cannot matter."""
    nlist, cap, m, tile_n, keep = 6, 64, 4, 16, 6
    index = _skewed_index(nlist, cap, m, seed=21)
    rng = np.random.default_rng(22)
    q = jnp.asarray(rng.normal(size=(2, 4 * m)).astype(np.float32))
    probes = jnp.asarray(np.tile(np.arange(nlist, dtype=np.int32), (2, 1)))
    for selectivity in (0.0, 0.5, 1.0):
        mask = rng.random((nlist, cap)) < selectivity
        fb = pack_filter_mask(jnp.asarray(mask))
        _assert_early_exit_lossless(index, q, probes, keep, tile_n,
                                    filter_bits=fb)


def test_early_exit_disarmed_keep_exceeds_tile_reports_zero():
    """keep > tile_n means the kernel cannot hold a full top-keep per tile,
    so pruning silently disarms: results identical, counter all zeros."""
    index = _synth_index(4, 64, 4, seed=31, occupancy="full")
    rng = np.random.default_rng(32)
    q = jnp.asarray(rng.normal(size=(2, 16)).astype(np.float32))
    probes = jnp.asarray(rng.integers(0, 4, (2, 3)).astype(np.int32))
    skipped = _assert_early_exit_lossless(index, q, probes, keep=40,
                                          tile_n=16)
    np.testing.assert_array_equal(skipped, 0)


# ---------------------------------------------------------------------------
# engine end-to-end: margin policy + early exit
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _dataset():
    return vectors.make_sift_like(n=4000, nt=1500, nq=8, d=32, ncl=16, seed=5)


@functools.lru_cache(maxsize=None)
def _engines(probe_policy, early_exit, rerank_mult=0):
    ds = _dataset()
    cfg = EngineConfig(nprobe=8, scan_impl="stream", rerank_mult=rerank_mult,
                       probe_policy=probe_policy, early_exit=early_exit)
    return ds, SearchEngine.build(jax.random.PRNGKey(0), ds.train, ds.base,
                                  m=8, nlist=16, config=cfg,
                                  coarse_iters=5, pq_iters=5)


@pytest.mark.parametrize("rerank_mult", [0, 2])
def test_margin_tau_inf_bit_identical_to_fixed(rerank_mult):
    ds, e_fix = _engines("fixed", False, rerank_mult)
    _, e_adp = _engines("margin", True, rerank_mult)
    q = jnp.asarray(ds.queries)
    rf = e_fix.search_jit(q, 10)
    ra = e_adp.search_jit(q, 10, margin_tau=float("inf"))
    np.testing.assert_array_equal(np.asarray(ra.ids), np.asarray(rf.ids))
    np.testing.assert_array_equal(np.asarray(ra.dists), np.asarray(rf.dists))
    np.testing.assert_array_equal(np.asarray(ra.stats.lists_pruned), 0)
    # staged == fused under the adaptive config too
    rs = e_adp.search(q, 10, margin_tau=float("inf"))
    np.testing.assert_array_equal(np.asarray(ra.ids), np.asarray(rs.ids))
    np.testing.assert_array_equal(np.asarray(ra.dists), np.asarray(rs.dists))


def test_margin_tau_prunes_and_early_exit_stays_lossless_at_fixed_probes():
    """At any tau the adaptive engine must equal a fixed engine given the
    SAME pruned probe set — early exit never costs recall at fixed probes.
    (Smaller tau may change the probe set and hence results; that recall
    trade is the point of the dial, measured in serve_bench.)"""
    ds, e_adp = _engines("margin", True)
    _, e_noee = _engines("margin", False)
    q = jnp.asarray(ds.queries)
    for tau in (0.0, 0.25, 1.0):
        ra = e_adp.search_jit(q, 10, margin_tau=tau)
        rb = e_noee.search_jit(q, 10, margin_tau=tau)
        np.testing.assert_array_equal(np.asarray(ra.ids), np.asarray(rb.ids))
        np.testing.assert_array_equal(np.asarray(ra.dists),
                                      np.asarray(rb.dists))
        np.testing.assert_array_equal(np.asarray(ra.stats.lists_pruned),
                                      np.asarray(rb.stats.lists_pruned))
    r0 = e_adp.search_jit(q, 10, margin_tau=0.0)
    assert (np.asarray(r0.stats.lists_pruned) > 0).any()
    # probes shrink with tau: lists_probed + lists_pruned == nprobe-selected
    probed = np.asarray(r0.stats.lists_probed)
    pruned = np.asarray(r0.stats.lists_pruned)
    full = np.asarray(e_adp.search_jit(
        q, 10, margin_tau=float("inf")).stats.lists_probed)
    np.testing.assert_array_equal(probed + pruned, full)


def test_margin_policy_with_tombstones_and_filters():
    ds, _ = _engines("margin", True)
    cfg = EngineConfig(nprobe=8, scan_impl="stream", probe_policy="margin",
                       early_exit=True)
    eng = SearchEngine.build(jax.random.PRNGKey(0), ds.train, ds.base,
                             m=8, nlist=16, config=cfg,
                             coarse_iters=5, pq_iters=5)
    cfg_f = EngineConfig(nprobe=8, scan_impl="stream")
    eng_f = SearchEngine.build(jax.random.PRNGKey(0), ds.train, ds.base,
                               m=8, nlist=16, config=cfg_f,
                               coarse_iters=5, pq_iters=5)
    dead = np.arange(0, 400)
    assert eng.delete(dead) == 400
    assert eng_f.delete(dead) == 400
    q = jnp.asarray(ds.queries)
    fb = pack_filter_mask(
        jnp.asarray(np.random.default_rng(7).random(
            (16, eng.index.lists.cap)) < 0.6))
    ra = eng.search_jit(q, 10, margin_tau=float("inf"), filter_bits=fb)
    rf = eng_f.search_jit(q, 10, filter_bits=fb)
    np.testing.assert_array_equal(np.asarray(ra.ids), np.asarray(rf.ids))
    np.testing.assert_array_equal(np.asarray(ra.dists), np.asarray(rf.dists))
    assert not np.isin(np.asarray(ra.ids), dead).any()
    # tight tau still never returns a tombstoned or filtered-out row
    rt = eng.search_jit(q, 10, margin_tau=0.0, filter_bits=fb)
    assert not np.isin(np.asarray(rt.ids), dead).any()


def test_margin_tau_rejected_under_fixed_policy():
    ds, e_fix = _engines("fixed", False)
    with pytest.raises(ValueError, match="probe_policy"):
        e_fix.search_jit(jnp.asarray(ds.queries), 10, margin_tau=0.5)
    from repro.engine.engine import validate_config
    with pytest.raises(ValueError, match="margin_tau"):
        validate_config(EngineConfig(probe_policy="margin", margin_tau=-1.0),
                        coarse_kind="flat", has_base=False)
    with pytest.raises(ValueError, match="probe_policy"):
        validate_config(EngineConfig(probe_policy="bogus"),
                        coarse_kind="flat", has_base=False)


def test_coarse_probes_policy_with_namespaces():
    """The flat+restricted branch (masked_topk) must feed the margin prune
    the same distances it selected by — a tenant's pruned set is a subset
    of its own lists and the best allowed probe survives."""
    ds, eng = _engines("margin", True)
    member = np.zeros((2, 16), bool)
    member[0, :8] = True
    member[1, 8:] = True
    q = jnp.asarray(ds.queries[:4])
    ns = jnp.asarray(np.array([0, 1, 0, -1], np.int32))
    probes, pruned = coarse_probes(
        eng.coarse, q, nprobe=8, ef=64, ns_member=jnp.asarray(member),
        namespaces=ns, probe_policy="margin", margin_tau=0.3)
    probes = np.asarray(probes)
    assert (probes[0][probes[0] >= 0] < 8).all()
    assert (probes[1][probes[1] >= 0] >= 8).all()
    assert (probes[0] >= 0).any() and (probes[1] >= 0).any()
    assert (np.asarray(pruned) >= 0).all()


# ---------------------------------------------------------------------------
# sharded driver (vmap; the 8-device shard_map twin lives in
# tests/_multidevice_harness.py)
# ---------------------------------------------------------------------------

def test_sharded_margin_tau_inf_matches_fixed_and_counters_psum():
    ds, e_adp = _engines("margin", True)
    _, e_fix = _engines("fixed", False)
    sh_a = ShardedEngine(e_adp, 4)
    sh_f = ShardedEngine(e_fix, 4)
    q = jnp.asarray(ds.queries)
    ra = sh_a.search(q, 10, margin_tau=float("inf"))
    rf = sh_f.search(q, 10)
    np.testing.assert_array_equal(np.asarray(ra.ids), np.asarray(rf.ids))
    np.testing.assert_array_equal(np.asarray(ra.dists), np.asarray(rf.dists))
    np.testing.assert_array_equal(np.asarray(ra.stats.lists_pruned), 0)
    rt = sh_a.search(q, 10, margin_tau=0.0)
    assert (np.asarray(rt.stats.lists_pruned) > 0).any()
    # per-shard prune: probed + pruned == tau=inf probed (psum'd totals)
    np.testing.assert_array_equal(
        np.asarray(rt.stats.lists_probed) + np.asarray(rt.stats.lists_pruned),
        np.asarray(ra.stats.lists_probed))
    with pytest.raises(ValueError, match="probe_policy"):
        sh_f.search(q, 10, margin_tau=0.5)


# ---------------------------------------------------------------------------
# serving loop: margin_tau plumb-through + auto-compaction satellite
# ---------------------------------------------------------------------------

def test_serving_loop_margin_counters_and_auto_compaction():
    ds, _ = _engines("margin", True)
    cfg = EngineConfig(nprobe=8, scan_impl="stream", probe_policy="margin",
                       early_exit=True)
    eng = SearchEngine.build(jax.random.PRNGKey(0), ds.train, ds.base,
                             m=8, nlist=16, config=cfg,
                             coarse_iters=5, pq_iters=5)
    loop = ServingLoop(eng, margin_tau=0.0, compact_at=0.001)
    with loop:
        res = loop.submit(np.asarray(ds.queries[0]), k=5,
                          tenant="t0").result(timeout=60)
        assert res.lists_pruned > 0
        assert res.tiles_skipped >= 0
        # push tombstones over the ratio; the NEXT dispatch auto-compacts
        assert loop.delete(np.asarray(res.ids[res.ids >= 0])) > 0
        assert eng.n_tombstones > 0
        loop.submit(np.asarray(ds.queries[1]), k=5,
                    tenant="t0").result(timeout=60)
        # compaction runs on the dispatch thread between batches; give it a
        # generous deadline — a full-suite run can starve this thread for
        # seconds on a loaded CPU
        deadline = 600
        while (eng.n_tombstones or not loop.metrics().auto_compactions) \
                and deadline:
            import time
            time.sleep(0.05)
            deadline -= 1
        m = loop.metrics()
        assert m.auto_compactions >= 1
        assert eng.n_tombstones == 0
        assert m.lists_pruned > 0
        st = loop.stats.get("t0")
        assert st.lists_pruned > 0
        assert st.tiles_skipped >= 0


def test_serving_loop_auto_compaction_default_off():
    ds, _ = _engines("margin", True)
    cfg = EngineConfig(nprobe=4, scan_impl="stream")
    eng = SearchEngine.build(jax.random.PRNGKey(0), ds.train, ds.base,
                             m=8, nlist=16, config=cfg,
                             coarse_iters=5, pq_iters=5)
    loop = ServingLoop(eng)
    assert loop.compact_at is None
    with loop:
        res = loop.submit(np.asarray(ds.queries[0]), k=5).result(timeout=60)
        loop.delete(np.asarray(res.ids[res.ids >= 0]))
        n_tomb = eng.n_tombstones
        assert n_tomb > 0
        loop.submit(np.asarray(ds.queries[1]), k=5).result(timeout=60)
        assert loop.metrics().auto_compactions == 0
        assert eng.n_tombstones == n_tomb  # nothing compacted behind our back


def test_serving_loop_rejects_bad_anytime_config():
    ds, _ = _engines("margin", True)
    cfg = EngineConfig(nprobe=4, scan_impl="stream")
    eng = SearchEngine.build(jax.random.PRNGKey(0), ds.train, ds.base,
                             m=8, nlist=16, config=cfg,
                             coarse_iters=5, pq_iters=5)
    with pytest.raises(ValueError, match="probe_policy"):
        ServingLoop(eng, margin_tau=0.1)
    with pytest.raises(ValueError, match="compact_at"):
        ServingLoop(eng, compact_at=1.5)
    with pytest.raises(ValueError, match="compact_at"):
        ServingLoop(eng, compact_at=0.0)


# ---------------------------------------------------------------------------
# autotune: probe_fill keys + schema migration + re-rank sweep cap
# ---------------------------------------------------------------------------

def test_autotune_probe_fill_keys_distinct_entries():
    ops.clear_autotune_cache()
    try:
        t_dense = ops.resolve_grouped_impl(8, 32, 8, nlist=16)
        t_half = ops.resolve_grouped_impl(8, 32, 8, nlist=16, probe_fill=0.5)
        assert ops.autotune_cache_size() == 2  # distinct keys, both cached
        # cached on repeat: no third entry
        ops.resolve_grouped_impl(8, 32, 8, nlist=16, probe_fill=0.5)
        assert ops.autotune_cache_size() == 2
        assert t_dense.impl in ("ref", "select", "mxu", "stream")
        assert t_half.impl in ("ref", "select", "mxu", "stream")
        with pytest.raises(ValueError, match="probe_fill"):
            ops.resolve_grouped_impl(8, 32, 8, nlist=16, probe_fill=0.0)
        with pytest.raises(ValueError, match="probe_fill"):
            ops.resolve_grouped_impl(8, 32, 8, nlist=16, probe_fill=1.5)
    finally:
        ops.clear_autotune_cache()


def test_autotune_cache_v3_roundtrip_and_v2_v1_migration(tmp_path):
    ops.clear_autotune_cache()
    try:
        ops.resolve_grouped_impl(8, 32, 8, nlist=16, probe_fill=0.5)
        path = str(tmp_path / "tuned.json")
        assert ops.save_autotune_cache(path) == 1
        data = json.loads(open(path).read())
        assert data["schema"].endswith("/v3")
        assert data["entries"][0]["probe_fill"] == 0.5
        ops.clear_autotune_cache()
        assert ops.load_autotune_cache(path) == 1
        # the reloaded verdict satisfies the same fill-keyed lookup with no
        # re-sweep (cache size stays 1)
        ops.resolve_grouped_impl(8, 32, 8, nlist=16, probe_fill=0.5)
        assert ops.autotune_cache_size() == 1

        # v2 file (no probe_fill): migrates to fill=1.0
        e2 = dict(data["entries"][0])
        e2.pop("probe_fill")
        v2 = {"schema": "repro.autotune/v2", "entries": [e2]}
        p2 = str(tmp_path / "v2.json")
        open(p2, "w").write(json.dumps(v2))
        ops.clear_autotune_cache()
        assert ops.load_autotune_cache(p2) == 1
        ops.resolve_grouped_impl(8, 32, 8, nlist=16)  # fill=1.0 lookup hits
        assert ops.autotune_cache_size() == 1

        # v1 file (no kind/nlist/probe_fill): re-keys to nlist=g, fill=1.0
        e1 = {k: e2[k] for k in ("backend", "interpret", "g", "cap", "m",
                                 "impl", "tile_n", "timings_us")}
        v1 = {"schema": "repro.autotune/v1", "entries": [e1]}
        p1 = str(tmp_path / "v1.json")
        open(p1, "w").write(json.dumps(v1))
        ops.clear_autotune_cache()
        assert ops.load_autotune_cache(p1) == 1
        ops.resolve_grouped_impl(8, 32, 8, nlist=8)  # nlist=g=8 lookup hits
        assert ops.autotune_cache_size() == 1
    finally:
        ops.clear_autotune_cache()


def test_rerank_sweep_cap_env_and_kwarg(monkeypatch):
    from repro.kernels.ops import _RERANK_SWEEP_N_CAP, _rerank_sweep_n_cap
    monkeypatch.delenv("REPRO_RERANK_SWEEP_N_CAP", raising=False)
    assert _rerank_sweep_n_cap() == _RERANK_SWEEP_N_CAP
    monkeypatch.setenv("REPRO_RERANK_SWEEP_N_CAP", "2048")
    assert _rerank_sweep_n_cap() == 2048
    monkeypatch.setenv("REPRO_RERANK_SWEEP_N_CAP", "not-a-number")
    assert _rerank_sweep_n_cap() == _RERANK_SWEEP_N_CAP
    monkeypatch.setenv("REPRO_RERANK_SWEEP_N_CAP", "0")
    assert _rerank_sweep_n_cap() == _RERANK_SWEEP_N_CAP
    # the kwarg shapes the sweep without touching the cache key
    ops.clear_autotune_cache()
    try:
        t = ops.resolve_rerank_impl(2, 4, 16, 2, 512, sweep_n_cap=64)
        assert t.impl in ("gathered", "stream")
        assert ops.autotune_cache_size() == 1
        # same signature, different cap: the cached verdict is returned
        # (documented: clear first to re-time at a new cap)
        ops.resolve_rerank_impl(2, 4, 16, 2, 512, sweep_n_cap=128)
        assert ops.autotune_cache_size() == 1
    finally:
        ops.clear_autotune_cache()


# ---------------------------------------------------------------------------
# scan_candidates: gathered impls ignore early_exit (zeros counter)
# ---------------------------------------------------------------------------

def test_scan_candidates_gathered_early_exit_is_noop():
    index = _synth_index(5, 64, 8, seed=9)
    rng = np.random.default_rng(10)
    q = jnp.asarray(rng.normal(size=(2, 32)).astype(np.float32))
    probes = jnp.asarray(np.array([[0, 2], [4, 1]], np.int32))
    d_ref, i_ref, ts_ref = scan_candidates(index, q, probes, scan_impl="ref",
                                           keep=5, early_exit=True)
    np.testing.assert_array_equal(np.asarray(ts_ref), 0)
    d_st, i_st, ts_st = scan_candidates(index, q, probes, scan_impl="stream",
                                        keep=5, early_exit=True)
    assert np.asarray(ts_st).shape == (2,)
    want_v, want_i = _topk_oracle(d_ref, i_ref, 5)
    got_v, got_i = _topk_oracle(d_st, i_st, 5)
    np.testing.assert_array_equal(got_i, want_i)
    np.testing.assert_array_equal(got_v, want_v)
