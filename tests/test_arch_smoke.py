"""Per-architecture smoke tests: reduced config, one forward + one grad step
on CPU, asserting output shapes and no NaNs (the brief's required smokes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as model_lib

ARCHS = list(configs.ARCHS)


def _batch(cfg, b=2, s=64):
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s), np.int32))
    batch = {
        "tokens": tokens,
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (b, s), np.int32)),
        "mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.frontend != "none":
        batch["frontend_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.frontend_len, cfg.d_model)).astype(np.float32))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nans(arch):
    cfg = configs.get_smoke_config(arch)
    params = model_lib.init_lm(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, aux = model_lib.forward(params, batch["tokens"], cfg,
                                    frontend_embeds=batch.get("frontend_embeds"))
    assert logits.shape == (2, 64, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_one_grad_step_reduces_loss_structurally(arch):
    """One SGD step on the smoke config: loss finite, grads finite, params move."""
    cfg = configs.get_smoke_config(arch)
    params = model_lib.init_lm(jax.random.PRNGKey(1), cfg)
    batch = _batch(cfg)

    def loss(p):
        l, _ = model_lib.loss_fn(p, batch, cfg)
        return l

    l0, grads = jax.value_and_grad(loss)(params)
    assert bool(jnp.isfinite(l0)), f"{arch}: loss not finite"
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    new_params = jax.tree.map(lambda p, g: p - 1e-2 * g.astype(p.dtype), params, grads)
    l1 = loss(new_params)
    assert bool(jnp.isfinite(l1))
    # loss should typically drop after one step at this scale; allow slack
    assert float(l1) < float(l0) + 0.5


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = configs.get_smoke_config(arch)
    b, prompt_len, max_seq = 2, 8, 32
    params = model_lib.init_lm(jax.random.PRNGKey(2), cfg)
    cache = model_lib.init_cache(cfg, b, max_seq, key=jax.random.PRNGKey(3))
    tokens = jnp.asarray(np.random.default_rng(1).integers(0, cfg.vocab, (b,), np.int32))
    position = jnp.full((b,), prompt_len, jnp.int32)
    logits, new_cache = model_lib.decode_step(params, cache, tokens, position, cfg)
    assert logits.shape == (b, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite decode logits"
    # cache must actually change
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(bb))
        for a, bb in zip(jax.tree.leaves(cache), jax.tree.leaves(new_cache)))
    assert changed
