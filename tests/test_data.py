"""Data pipeline tests: determinism, host sharding, prefetch."""
import numpy as np

from repro.data import tokens, vectors


def test_token_pipeline_deterministic_and_host_sharded():
    cfg = tokens.TokenPipelineConfig(vocab=1000, seq_len=32, global_batch=8,
                                     host_count=2, host_id=0, seed=7)
    b1 = tokens.batch_at_step(cfg, step=5)
    b2 = tokens.batch_at_step(cfg, step=5)
    np.testing.assert_array_equal(np.asarray(b1.tokens), np.asarray(b2.tokens))
    assert b1.tokens.shape == (4, 32)  # global 8 / 2 hosts
    # next-token alignment
    cfg1 = cfg._replace(host_id=1)
    other = tokens.batch_at_step(cfg1, step=5)
    assert not np.array_equal(np.asarray(b1.tokens), np.asarray(other.tokens))
    # different steps differ
    b3 = tokens.batch_at_step(cfg, step=6)
    assert not np.array_equal(np.asarray(b1.tokens), np.asarray(b3.tokens))
    assert int(b1.tokens.max()) < 1000


def test_prefetch_iterator_orders_steps():
    cfg = tokens.TokenPipelineConfig(vocab=100, seq_len=8, global_batch=2)
    it = tokens.PrefetchIterator(cfg, start_step=3)
    s0, batch0 = next(it)
    s1, _ = next(it)
    it.close()
    assert (s0, s1) == (3, 4)
    want = tokens.batch_at_step(cfg, 3)
    np.testing.assert_array_equal(np.asarray(batch0.tokens), np.asarray(want.tokens))


def test_vector_datasets_shapes_and_gt():
    ds = vectors.make_deep_like(n=2000, nt=500, nq=16, d=24, ncl=16)
    assert ds.base.shape == (2000, 24) and ds.gt_ids.shape == (16, 10)
    # gt really is the argmin
    import jax.numpy as jnp
    from repro.core.kmeans import pairwise_sqdist
    d = pairwise_sqdist(ds.queries, ds.base)
    np.testing.assert_array_equal(np.asarray(jnp.argmin(d, 1)), np.asarray(ds.gt_ids[:, 0]))
    # deep-like is unit-norm
    norms = np.linalg.norm(np.asarray(ds.base), axis=1)
    np.testing.assert_allclose(norms, 1.0, atol=1e-5)
