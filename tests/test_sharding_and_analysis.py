"""Unit tests: logical sharding rules, divisibility fallback, HLO analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import hlo_analysis as ha
from repro.launch import sharding as shd


def _mesh22():
    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    return jax.make_mesh((1, 1), ("data", "model"))


def test_logical_to_spec_basic():
    mesh = _mesh22()
    spec = shd.logical_to_spec((8, 16), ("batch", "mlp"), mesh,
                               shd.DEFAULT_RULES)
    # data/model axes of size 1 divide everything
    assert spec == P(("data",), "model") or spec == P("data", "model")


def test_divisibility_fallback_replicates():
    mesh = jax.make_mesh((1,), ("model",))
    rules = {"heads": "model", None: None}
    # 14 % 1 == 0 -> sharded; emulate non-divisible via size-1 axis trick:
    spec = shd.logical_to_spec((14,), ("heads",), mesh, rules)
    assert spec == P("model")


def test_axis_never_reused_across_dims():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = {"batch": ("data",), "embed": "data", None: None}
    spec = shd.logical_to_spec((4, 8), ("batch", "embed"), mesh, rules)
    # embed wanted "data" but batch already consumed it
    assert spec == P(("data",), None) or spec == P("data", None)


def test_tree_shardings_handles_namedtuples_and_none():
    from repro.train import optimizer as opt
    mesh = _mesh22()
    pshapes = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    paxes = {"w": ("embed", "mlp")}
    st = opt.state_shapes(pshapes)
    sax = opt.state_axes(paxes)
    out = shd.tree_shardings(st, sax, mesh)
    assert out.step.spec == P()
    assert out.mu["w"].spec is not None


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = shd.constrain(x, "batch", "embed")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# HLO analyzer
# ---------------------------------------------------------------------------

def test_analyzer_scan_trip_count():
    def f(x, w):
        def body(c, wl):
            return jnp.tanh(c @ wl), None
        return jax.lax.scan(body, x, w)[0]

    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 32), jnp.float32),
        jax.ShapeDtypeStruct((10, 32, 32), jnp.float32)).compile()
    costs = ha.analyze_hlo(comp.as_text())
    want = 2 * 10 * 64 * 32 * 32
    assert want <= costs.flops <= want * 1.2, costs.flops
    # XLA's own analysis undercounts the while body (the bug we fix)
    xla = ha.xla_cost_dict(comp)["flops"]
    assert xla < want / 2


def test_analyzer_shape_bytes():
    assert ha._shape_elems_bytes("bf16[8,128]{1,0}") == (1024, 2048)
    assert ha._shape_elems_bytes("(f32[2,2], u8[16])") == (20, 32)
    assert ha._shape_elems_bytes("pred[]") == (1, 1)   # scalars: 1 element
    assert ha._shape_elems_bytes("s32[]") == (1, 4)


def test_analyzer_remat_counts_recompute():
    """jax.checkpoint doubles forward flops in the bwd pass."""
    def loss(w, x):
        f = jax.checkpoint(lambda w, x: jnp.tanh(x @ w).sum())
        return f(w, x)

    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    comp_g = jax.jit(jax.grad(loss)).lower(w, x).compile()
    costs_g = ha.analyze_hlo(comp_g.as_text())
    one_fwd = 2 * 64 * 64 * 64
    # recomputed fwd matmul + dw matmul (fwd value itself is DCE'd by grad)
    assert costs_g.flops >= 1.9 * one_fwd
    # and our count agrees with XLA's within 5% on a while-free program
    assert abs(costs_g.flops - ha.xla_cost_dict(comp_g)["flops"]) < 0.05 * costs_g.flops


def test_analyzer_collective_wire_factors():
    mesh = jax.make_mesh((1,), ("m",))
    from jax.sharding import NamedSharding

    def f(a, b):
        return a @ b

    # 1-device mesh: no collectives emitted
    comp = jax.jit(f).lower(
        jax.ShapeDtypeStruct((32, 32), jnp.float32),
        jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    costs = ha.analyze_hlo(comp.as_text())
    assert costs.wire_bytes == 0
