"""Gather-free streaming exact re-rank + double-buffered DMA pipeline.

The 'stream' re-rank impl must be *bit-identical* to the gathered
``exact_rerank`` — through the raw kernel wrapper, ``finalize_candidates``,
and the whole engine (``search`` / ``search_jit`` / ``ShardedEngine`` on
both top-k drivers). Both impls share one distance expression
(``rerank_kernel.norms_gemm_dists``), so every comparison here is
``assert_array_equal``, never allclose. Also covers: the norms+GEMM rewrite
of the gathered fallback (tolerance-zero parity against the subtraction
form on integer-valued data, where f32 is exact for both), the
double-buffered DMA refactor of the stream *scan* kernels (bit-identity
across multi-tile grids that exercise the two-slot rotation), re-rank
autotune dispatch, the v2 persistence schema + v1 migration, and the
memory-traffic acceptance (rerank-stage bytes >= 4x below gathered).
"""
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import topk as topk_mod
from repro.core.lists import base_norms
from repro.data import vectors
from repro.engine import EngineConfig, SearchEngine, ShardedEngine
from repro.engine import rerank as rerank_mod
from repro.kernels import ops, ref
from repro.launch.hlo_analysis import xla_cost_dict


def _case(n=300, d=16, q=4, r=24, seed=0, ties=False):
    """(base, norms, queries, cand) — ``ties=True`` draws base rows from a
    tiny integer lattice so duplicate rows (hence exactly-equal distances)
    genuinely occur and the lowest-position tie-break is exercised."""
    rng = np.random.default_rng(seed)
    if ties:
        base = rng.integers(-2, 3, (n, d)).astype(np.float32)
    else:
        base = rng.normal(size=(n, d)).astype(np.float32)
    base = jnp.asarray(base)
    queries = jnp.asarray(rng.normal(size=(q, d)).astype(np.float32))
    cand = rng.integers(0, n, (q, r)).astype(np.int32)
    return base, base_norms(base), queries, cand


def _assert_rerank_parity(base, norms, queries, cand, k, **kw):
    want_v, want_i = rerank_mod.exact_rerank(base, queries, jnp.asarray(cand),
                                             k, norms=norms)
    got_v, got_i = ops.rerank_stream_topk(base, norms, queries,
                                          jnp.asarray(cand), k=k, **kw)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))


# ---------------------------------------------------------------------------
# kernel-level parity vs the gathered exact_rerank
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tile_r", [0, 8, 16])
def test_stream_rerank_matches_gathered(tile_r):
    base, norms, q, cand = _case()
    _assert_rerank_parity(base, norms, q, cand, 10, tile_r=tile_r)


def test_stream_rerank_ragged_and_all_invalid_rows():
    """-1 padding mid-pool, a fully-invalid query, and R < r*k raggedness:
    absent slots come back (+inf, -1) exactly like masked_topk's."""
    base, norms, q, cand = _case(q=4, r=24)
    cand[0, 5:] = -1          # ragged: only 5 live candidates (< k)
    cand[1, :] = -1           # all invalid -> whole row absent
    cand[2, ::2] = -1         # interleaved padding
    _assert_rerank_parity(base, norms, q, cand, 10, tile_r=8)
    vals, ids = ops.rerank_stream_topk(base, norms, q, jnp.asarray(cand),
                                       k=10, tile_r=8)
    assert (np.asarray(ids)[1] == -1).all()
    assert np.isinf(np.asarray(vals)[1]).all()
    assert (np.asarray(ids)[0, 5:] == -1).all()


def test_stream_rerank_single_candidate_and_single_query():
    # k == R == 1: the smallest legal selection (k > R is rejected by the
    # gathered oracle's lax.top_k, so the contract floor is k <= R)
    base, norms, q, cand = _case(q=1, r=1)
    _assert_rerank_parity(base, norms, q, cand, 1)


def test_stream_rerank_ties_resolve_like_masked_topk():
    """Duplicate base rows => exactly equal f32 distances; the kernel's
    running-merge must pick the lowest candidate position, byte-for-byte
    like masked_topk — across chunk boundaries too (tile_r=4 splits the
    pool into 6 chunks)."""
    base, norms, q, cand = _case(n=40, d=4, q=5, r=24, ties=True)
    _assert_rerank_parity(base, norms, q, cand, 8, tile_r=4)


def test_stream_rerank_multi_chunk_shapes():
    """R >> tile_r drives many double-buffered chunks per query."""
    base, norms, q, cand = _case(n=800, d=24, q=3, r=160)
    _assert_rerank_parity(base, norms, q, cand, 10, tile_r=16)


def test_stream_rerank_duplicate_candidates_behave_like_gathered():
    """Candidate ids are unique by construction in the engine (each base
    vector lives in exactly one IVF list), so neither impl dedups — but a
    hand-composed pool CAN contain duplicates, and the two impls must then
    misbehave identically (the duplicate id may appear twice in the top-k,
    positions still lowest-first)."""
    base, norms, q, cand = _case(q=3, r=16)
    cand[:, 8:] = cand[:, :8]          # every candidate duplicated
    _assert_rerank_parity(base, norms, q, cand, 10, tile_r=8)


def test_stream_rerank_k_exceeds_live_candidates():
    """k > live candidates: exactly the live ones come back, then -1s."""
    base, norms, q, cand = _case(q=2, r=6)
    cand[:, 3:] = -1
    _assert_rerank_parity(base, norms, q, cand, 6, tile_r=8)


# ---------------------------------------------------------------------------
# the norms+GEMM rewrite of the gathered fallback
# ---------------------------------------------------------------------------

def test_exact_distances_norms_gemm_equals_subtraction_form_exactly():
    """Tolerance-ZERO parity of the rewritten gathered ``exact_distances``
    against the subtraction form it replaced — on integer-valued f32 data,
    where every product/sum in both formulations is an exactly-representable
    integer (all magnitudes << 2^24), so the algebraic identity
    ``Σ(q−x)² == (‖q‖² − 2q·x) + ‖x‖²`` must hold bit-for-bit. (On generic
    float data the two round differently by design; the f64-anchored
    accuracy test lives in tests/test_engine.py.)"""
    rng = np.random.default_rng(7)
    n, d, q, r = 200, 16, 6, 30
    base = jnp.asarray(rng.integers(-9, 10, (n, d)).astype(np.float32))
    queries = jnp.asarray(rng.integers(-9, 10, (q, d)).astype(np.float32))
    cand = rng.integers(0, n, (q, r)).astype(np.int32)
    cand[0, 10:] = -1
    cand = jnp.asarray(cand)
    got = rerank_mod.exact_distances(base, queries, cand)
    want = jax.jit(lambda b, qq, c: jnp.where(
        c >= 0,
        jnp.sum((b[jnp.maximum(c, 0)] - qq[:, None, :]) ** 2, axis=-1),
        jnp.inf))(base, queries, cand)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_finalize_candidates_routes_impls_identically():
    """finalize_candidates under 'gathered' vs 'stream' (and an unknown
    impl raising) — same (vals, ids, reranked) bit-for-bit."""
    base, norms, q, cand = _case(q=3, r=20)
    rng = np.random.default_rng(3)
    flat_d = jnp.asarray(rng.normal(size=(3, 64)).astype(np.float32)) ** 2
    flat_ids = jnp.asarray(rng.permutation(300)[:64][None, :].repeat(3, 0)
                           .astype(np.int32))
    out = {}
    for impl in ("gathered", "stream"):
        out[impl] = rerank_mod.finalize_candidates(
            flat_d, flat_ids, base, q, 10, 3, norms=norms, rerank_impl=impl)
    for a, b in zip(out["gathered"], out["stream"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    with pytest.raises(ValueError, match="unknown rerank impl"):
        rerank_mod.finalize_candidates(flat_d, flat_ids, base, q, 10, 3,
                                       norms=norms, rerank_impl="simd")


# ---------------------------------------------------------------------------
# engine end-to-end: search / search_jit / sharded (both drivers)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def trained_engine():
    ds = vectors.make_sift_like(n=5000, nt=2000, nq=16, d=32, ncl=32, seed=5)
    eng = SearchEngine.build(jax.random.PRNGKey(0), ds.train, ds.base,
                             m=8, nlist=32, coarse_iters=6, pq_iters=6)
    return ds, eng


@pytest.mark.parametrize("scan_impl", ["ref", "stream"])
def test_search_stream_rerank_bitidentical(scan_impl):
    ds, eng = trained_engine()
    eng_s = SearchEngine(eng.index, base=ds.base,
                         config=EngineConfig(scan_impl=scan_impl,
                                             rerank_impl="stream"))
    q = ds.queries[:6]
    res_ref = eng.search(q, 10, nprobe=6, rerank_mult=4)
    for res in (eng_s.search(q, 10, nprobe=6, rerank_mult=4),
                eng_s.search_jit(q, 10, nprobe=6, rerank_mult=4)):
        np.testing.assert_array_equal(np.asarray(res.ids),
                                      np.asarray(res_ref.ids))
        np.testing.assert_array_equal(np.asarray(res.dists),
                                      np.asarray(res_ref.dists))
        for a, b in zip(res.stats, res_ref.stats):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sharded_stream_rerank_matches_gathered_vmap_driver():
    """Stream re-rank on shard-local base partitions (local candidate ids,
    gids remap before the merge) == gathered, on the vmap named-axis
    driver."""
    ds, eng = trained_engine()
    eng_s = SearchEngine(eng.index, base=ds.base,
                         config=EngineConfig(rerank_impl="stream"))
    q = ds.queries[:4]
    res_g = ShardedEngine(eng, 3).search(q, 10, nprobe=4, rerank_mult=2)
    res_s = ShardedEngine(eng_s, 3).search(q, 10, nprobe=4, rerank_mult=2)
    np.testing.assert_array_equal(np.asarray(res_s.ids), np.asarray(res_g.ids))
    np.testing.assert_array_equal(np.asarray(res_s.dists),
                                  np.asarray(res_g.dists))
    for a, b in zip(res_s.stats, res_g.stats):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sharded_stream_rerank_matches_on_shard_map_mesh_driver():
    ds, eng = trained_engine()
    eng_s = SearchEngine(eng.index, base=ds.base,
                         config=EngineConfig(rerank_impl="stream"))
    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("shards",))
    q = ds.queries[:4]
    res_g = ShardedEngine(eng, n_dev).search(q, 10, nprobe=4, rerank_mult=2,
                                             mesh=mesh)
    res_s = ShardedEngine(eng_s, n_dev).search(q, 10, nprobe=4, rerank_mult=2,
                                               mesh=mesh)
    np.testing.assert_array_equal(np.asarray(res_s.ids), np.asarray(res_g.ids))
    np.testing.assert_array_equal(np.asarray(res_s.dists),
                                  np.asarray(res_g.dists))


def test_engine_validates_rerank_impl():
    ds, eng = trained_engine()
    with pytest.raises(ValueError, match="rerank_impl"):
        SearchEngine(eng.index, base=ds.base,
                     config=EngineConfig(rerank_impl="simd"))


# ---------------------------------------------------------------------------
# double-buffered DMA pipeline: stream scan kernels stay bit-identical
# ---------------------------------------------------------------------------

def test_double_buffered_stream_scan_bitidentical_to_ref():
    """The two-slot pipeline refactor must not change a single bit of the
    stream scan outputs: multi-tile grids (>= 3 tiles per group, exercising
    both slot reuses), invalid probes interleaved mid-sequence (their
    skipped DMA must not desync the rotation), and duplicate probes."""
    rng = np.random.default_rng(11)
    nlist, cap, mh, tile = 6, 128, 4, 32   # 4 tiles/group
    store = jnp.asarray(rng.integers(0, 256, (nlist, cap, mh), np.uint8))
    probes = jnp.asarray(np.array([2, -1, 2, 5, -1, 0, 3, -1], np.int32))
    g = probes.shape[0]
    table = jnp.asarray(rng.integers(0, 256, (g, 2 * mh, 16), np.uint8))
    got = np.asarray(ops.fastscan_stream_grouped(table, store, probes,
                                                 tile_n=tile))
    want = np.asarray(ref.fastscan_grouped_ref(
        table, store[jnp.maximum(probes, 0)]))
    valid = np.asarray(probes) >= 0
    np.testing.assert_array_equal(got[valid], want[valid])
    assert (got[~valid] == 0).all()
    # and the result is tile-size invariant (different pipeline depths)
    got_1tile = np.asarray(ops.fastscan_stream_grouped(table, store, probes,
                                                       tile_n=cap))
    np.testing.assert_array_equal(got[valid], got_1tile[valid])


def test_double_buffered_stream_topk_bitidentical():
    """Same refactor check for the fused-reduction kernel: per-tile top-kc
    against the numpy stable-sort oracle across a multi-tile pipeline."""
    rng = np.random.default_rng(13)
    nlist, cap, mh, tile, kc = 4, 96, 2, 32, 5
    store = jnp.asarray(rng.integers(0, 4, (nlist, cap, mh), np.uint8))
    sizes = jnp.asarray(np.array([96, 50, 0, 33], np.int32))
    probes = jnp.asarray(np.array([0, -1, 1, 3, 2], np.int32))
    g = probes.shape[0]
    table = jnp.asarray(rng.integers(0, 3, (g, 2 * mh, 16), np.uint8))
    vals, slots = ops.fastscan_stream_topk(table, store, probes, sizes,
                                           keep=kc, tile_n=tile)
    vals, slots = np.asarray(vals), np.asarray(slots)
    acc = np.asarray(ref.fastscan_grouped_ref(
        table, store[jnp.maximum(probes, 0)]))
    for gi in range(g):
        lid = int(probes[gi])
        if lid < 0:
            assert (slots[gi] == -1).all()
            continue
        for ti in range(cap // tile):
            lo = ti * tile
            n_valid = int(np.clip(int(sizes[lid]) - lo, 0, tile))
            seg = acc[gi, lo:lo + n_valid]
            order = np.argsort(seg, kind="stable")[:kc]
            k_real = min(kc, n_valid)
            np.testing.assert_array_equal(vals[gi, ti, :k_real], seg[order])
            np.testing.assert_array_equal(slots[gi, ti, :k_real], order + lo)
            assert (slots[gi, ti, k_real:] == -1).all()


# ---------------------------------------------------------------------------
# autotune: re-rank dispatch + v2 persistence + v1 migration
# ---------------------------------------------------------------------------

def test_rerank_impls_registered():
    assert ops.RERANK_IMPLS == ("gathered", "stream", "auto")
    from repro.engine import engine as engine_mod
    assert engine_mod.RERANK_IMPLS is ops.RERANK_IMPLS


def test_resolve_rerank_impl_sweeps_both_and_caches():
    ops.clear_autotune_cache()
    try:
        tuned = ops.resolve_rerank_impl(2, 12, 16, 5, 300)
        assert tuned.impl in ops.RERANK_CONCRETE
        swept = {name.split("@")[0] for name, _ in tuned.timings_us}
        assert swept == set(ops.RERANK_CONCRETE)
        assert ops.resolve_rerank_impl(2, 12, 16, 5, 300) is tuned  # cache hit
        assert ops.autotune_cache_size() == 1
        (key,) = ops.autotune_cache().keys()
        assert key[0] == "rerank" and key[3:] == (2, 12, 16, 5, 300)
        # N is part of the key: the gathered path's gather cost scales with
        # the table, so a verdict must never be shared across base sizes
        ops.resolve_rerank_impl(2, 12, 16, 5, 5000)
        assert ops.autotune_cache_size() == 2
        # 'auto' through the engine path is bit-identical to both concretes
        base, norms, q, cand = _case(q=2, r=12, d=16)
        want = rerank_mod.finalize_candidates(
            jnp.abs(jnp.asarray(np.random.default_rng(0).normal(
                size=(2, 40)).astype(np.float32))),
            jnp.asarray(np.arange(80, dtype=np.int32).reshape(2, 40)),
            base, q, 5, 2, norms=norms, rerank_impl="gathered")
        got = rerank_mod.finalize_candidates(
            jnp.abs(jnp.asarray(np.random.default_rng(0).normal(
                size=(2, 40)).astype(np.float32))),
            jnp.asarray(np.arange(80, dtype=np.int32).reshape(2, 40)),
            base, q, 5, 2, norms=norms, rerank_impl="auto")
        for a, b in zip(want, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        ops.clear_autotune_cache()


def test_autotune_roundtrips_scan_and_rerank_entries(tmp_path):
    path = str(tmp_path / "tuned.json")
    ops.clear_autotune_cache()
    try:
        t_scan = ops.resolve_grouped_impl(2, 32, 4, nlist=10)
        t_rr = ops.resolve_rerank_impl(2, 8, 8, 4, 100)
        assert ops.save_autotune_cache(path) == 2
        with open(path) as f:
            data = json.load(f)
        assert data["schema"] == "repro.autotune/v3"
        kinds = {e["kind"] for e in data["entries"]}
        assert kinds == {"scan", "rerank"}
        assert all("nlist" in e for e in data["entries"]
                   if e["kind"] == "scan")
        ops.clear_autotune_cache()
        assert ops.load_autotune_cache(path) == 2
        assert ops.resolve_grouped_impl(2, 32, 4, nlist=10) == t_scan
        assert ops.resolve_rerank_impl(2, 8, 8, 4, 100) == t_rr
        assert ops.autotune_cache_size() == 2  # both were cache hits
    finally:
        ops.clear_autotune_cache()


def test_autotune_v1_files_migrate_gracefully(tmp_path):
    """A v1 file (no kind/nlist) still loads: its scan verdicts re-key to
    nlist=g — the G-list store that sweep actually timed — and satisfy
    exactly those lookups; unknown impls are still skipped."""
    v1 = tmp_path / "v1.json"
    v1.write_text(json.dumps({
        "schema": "repro.autotune/v1",
        "entries": [
            {"backend": jax.default_backend(), "interpret": True, "g": 3,
             "cap": 64, "m": 4, "impl": "ref", "tile_n": 0,
             "timings_us": [["ref@0", 12.5]]},
            {"backend": "cpu", "interpret": True, "g": 1, "cap": 8, "m": 2,
             "impl": "gone-impl", "tile_n": 0, "timings_us": []},
        ]}))
    ops.clear_autotune_cache()
    try:
        assert ops.load_autotune_cache(str(v1)) == 1
        (key,) = ops.autotune_cache().keys()
        assert key == ("scan", jax.default_backend(), True, 3, 64, 4, 3, 1.0)
        # the migrated verdict is a hit for the shape it measured...
        tuned = ops.resolve_grouped_impl(3, 64, 4, interpret=True)
        assert tuned.impl == "ref" and ops.autotune_cache_size() == 1
        # ...but NOT for the same (G, cap, M) against a different store size
        ops.resolve_grouped_impl(3, 64, 4, nlist=20, interpret=True)
        assert ops.autotune_cache_size() == 2
    finally:
        ops.clear_autotune_cache()


# ---------------------------------------------------------------------------
# memory traffic: the point of the whole exercise
# ---------------------------------------------------------------------------

def test_stream_rerank_stage_bytes_accessed_4x_below_gathered():
    """cost_analysis bytes-accessed of the re-rank stage: the gather-free
    kernel must come in at least 4x under the gathered path at the
    acceptance shape (Q=32, k=10, r=4, D=128)."""
    rng = np.random.default_rng(17)
    n, d, q, k, r = 4096, 128, 32, 10, 4
    base = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
    norms = base_norms(base)
    queries = jnp.asarray(rng.normal(size=(q, d)).astype(np.float32))
    cand = jnp.asarray(rng.integers(0, n, (q, r * k)).astype(np.int32))
    gathered = jax.jit(functools.partial(rerank_mod.exact_rerank, k=k))
    streamed = jax.jit(functools.partial(ops.rerank_stream_topk, k=k))
    b_gather = xla_cost_dict(gathered.lower(
        base, queries, cand, norms=norms).compile()).get("bytes accessed", 0.0)
    b_stream = xla_cost_dict(streamed.lower(
        base, norms, queries, cand).compile()).get("bytes accessed", 0.0)
    assert b_gather > 0 and b_stream > 0
    assert b_stream * 4 <= b_gather, (b_stream, b_gather)
