"""PQ-KV cache correctness: codec roundtrip, ADC-vs-exact attention parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models import kvcache as kvc


def _perfect_codebook(key, kv, m, dsub):
    """Codebook whose entries are distinct; K/V drawn FROM the codebook so
    encoding is lossless -> PQ attention must match exact attention."""
    return jax.random.normal(key, (kv, m, 16, dsub), jnp.float32)


def _draw_from_codebook(key, cb, b, s):
    kv, m, _, dsub = cb.shape
    codes = jax.random.randint(key, (b, s, kv, m), 0, 16)
    gathered = jnp.take_along_axis(
        cb[None, None], codes[..., None, None], axis=-2)[..., 0, :]
    return gathered.reshape(b, s, kv, m * dsub), codes


def test_encode_decode_roundtrip_lossless_on_codebook_points():
    key = jax.random.PRNGKey(0)
    kv, m, dsub, b, s = 2, 4, 8, 3, 16
    cb = _perfect_codebook(key, kv, m, dsub)
    x, codes = _draw_from_codebook(jax.random.PRNGKey(1), cb, b, s)
    packed = jax.vmap(lambda t: kvc.encode_kv(t, cb), 1, 1)(x)  # (B,S,KV,M/2)
    dec = kvc.decode_kv(packed, cb)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(x), atol=1e-5)


def test_pq_decode_attention_matches_exact_with_lossless_codebooks():
    """With codebooks that reconstruct K/V exactly and quantize_q8=False,
    PQ ADC attention == exact attention (up to float assoc)."""
    key = jax.random.PRNGKey(2)
    b, s, kv, g, m, dsub = 2, 64, 2, 2, 8, 16
    hd = m * dsub
    h = kv * g
    k_cb = _perfect_codebook(jax.random.fold_in(key, 0), kv, m, dsub) * 0.2
    v_cb = _perfect_codebook(jax.random.fold_in(key, 1), kv, m, dsub) * 0.2
    kx, _ = _draw_from_codebook(jax.random.fold_in(key, 2), k_cb, b, s)
    vx, _ = _draw_from_codebook(jax.random.fold_in(key, 3), v_cb, b, s)
    q = jax.random.normal(jax.random.fold_in(key, 4), (b, h, hd)) * 0.5
    position = jnp.full((b,), s - 1, jnp.int32)

    k_codes = jax.vmap(lambda t: kvc.encode_kv(t, k_cb), 1, 1)(kx)
    v_codes = jax.vmap(lambda t: kvc.encode_kv(t, v_cb), 1, 1)(vx)
    out_pq = kvc.pq_decode_attention(q, k_codes, v_codes, k_cb, v_cb, position,
                                     chunk=32, quantize_q8=False)

    # exact reference
    qg = q.reshape(b, kv, g, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, kx) / np.sqrt(hd)
    w = jax.nn.softmax(scores, axis=-1)
    out_ref = jnp.einsum("bkgs,bskh->bkgh", w, vx).reshape(b, h, hd)
    np.testing.assert_allclose(np.asarray(out_pq), np.asarray(out_ref),
                               atol=2e-3, rtol=2e-3)


def test_pq_decode_attention_q8_close_to_float_lut():
    """The paper-faithful u8 LUT quantization stays close to the float LUT."""
    key = jax.random.PRNGKey(3)
    b, s, kv, g, m, dsub = 2, 128, 2, 2, 16, 8
    hd = m * dsub
    k_cb = _perfect_codebook(jax.random.fold_in(key, 0), kv, m, dsub) * 0.1
    v_cb = _perfect_codebook(jax.random.fold_in(key, 1), kv, m, dsub) * 0.1
    kx, _ = _draw_from_codebook(jax.random.fold_in(key, 2), k_cb, b, s)
    vx, _ = _draw_from_codebook(jax.random.fold_in(key, 3), v_cb, b, s)
    q = jax.random.normal(jax.random.fold_in(key, 4), (b, kv * g, hd)) * 0.3
    position = jnp.full((b,), s - 1, jnp.int32)
    k_codes = jax.vmap(lambda t: kvc.encode_kv(t, k_cb), 1, 1)(kx)
    v_codes = jax.vmap(lambda t: kvc.encode_kv(t, v_cb), 1, 1)(vx)

    out_f = kvc.pq_decode_attention(q, k_codes, v_codes, k_cb, v_cb, position,
                                    chunk=64, quantize_q8=False)
    out_q8 = kvc.pq_decode_attention(q, k_codes, v_codes, k_cb, v_cb, position,
                                     chunk=64, quantize_q8=True)
    err = float(jnp.max(jnp.abs(out_f - out_q8)))
    scale = float(jnp.max(jnp.abs(out_f))) + 1e-6
    assert err / scale < 0.15, f"u8 LUT error too large: {err/scale}"


def test_position_masking():
    """Entries past `position` must not contribute."""
    key = jax.random.PRNGKey(4)
    b, s, kv, g, m, dsub = 1, 32, 1, 1, 4, 4
    hd = m * dsub
    cb = _perfect_codebook(key, kv, m, dsub)
    kx, _ = _draw_from_codebook(jax.random.fold_in(key, 1), cb, b, s)
    vx, _ = _draw_from_codebook(jax.random.fold_in(key, 2), cb, b, s)
    q = jax.random.normal(jax.random.fold_in(key, 3), (b, kv * g, hd))
    k_codes = jax.vmap(lambda t: kvc.encode_kv(t, cb), 1, 1)(kx)
    v_codes = jax.vmap(lambda t: kvc.encode_kv(t, cb), 1, 1)(vx)
    pos = jnp.asarray([7], jnp.int32)
    out1 = kvc.pq_decode_attention(q, k_codes, v_codes, cb, cb, pos, chunk=8,
                                   quantize_q8=False)
    # scramble the masked tail: result must be identical
    k2 = k_codes.at[:, 20:].set(255)
    v2 = v_codes.at[:, 20:].set(255)
    out2 = kvc.pq_decode_attention(q, k2, v2, cb, cb, pos, chunk=8,
                                   quantize_q8=False)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-6)


def test_update_pq_writes_at_position():
    key = jax.random.PRNGKey(5)
    kv, m, dsub, b, smax = 2, 4, 4, 2, 16
    cb = _perfect_codebook(key, kv, m, dsub)
    k_codes = jnp.zeros((b, smax, kv, m // 2), jnp.uint8)
    v_codes = jnp.zeros((b, smax, kv, m // 2), jnp.uint8)
    k_new, _ = _draw_from_codebook(jax.random.fold_in(key, 1), cb, b, 1)
    v_new, _ = _draw_from_codebook(jax.random.fold_in(key, 2), cb, b, 1)
    k2, v2 = kvc.update_pq(k_codes, v_codes, k_new[:, 0], v_new[:, 0], cb, cb,
                           jnp.int32(5))
    changed = np.asarray(k2 != k_codes).any(axis=(0, 2, 3))
    assert changed[5] or np.asarray(v2 != v_codes).any(axis=(0, 2, 3))[5]
    assert not changed[[0, 1, 2, 3, 4, 6]].any()


def test_calibrated_codebooks_reduce_reconstruction_error():
    key = jax.random.PRNGKey(6)
    n, kv, hd, m = 512, 2, 32, 8
    # clustered samples (realistic activation structure)
    centers = jax.random.normal(key, (8, kv, hd))
    which = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, 8)
    x = centers[which] + 0.05 * jax.random.normal(jax.random.fold_in(key, 2),
                                                  (n, kv, hd))
    cb = kvc.calibrate_kv_codebooks(jax.random.fold_in(key, 3), x, m=m)
    codes = kvc.encode_kv(x, cb)
    rec = kvc.decode_kv(codes, cb)
    rel = float(jnp.linalg.norm(rec - x) / jnp.linalg.norm(x))
    assert rel < 0.2, f"calibrated PQ reconstruction too lossy: {rel}"
