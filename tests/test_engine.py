"""Unified engine tests: stage-composition parity, exact re-rank, QueryStats,
grouped-kernel agreement, and the shard-parallel merge."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import coarse, ivf, metrics
from repro.core import topk as topk_mod
from repro.core.kmeans import pairwise_sqdist
from repro.core.lists import ListStore, partition_lists
from repro.data import vectors
from repro.engine import (EngineConfig, SearchEngine, ShardedEngine,
                          exact_distances, exact_rerank)


@functools.lru_cache(maxsize=None)
def small_ds():
    return vectors.make_sift_like(n=5000, nt=2000, nq=16, d=32, ncl=32, seed=3)


@functools.lru_cache(maxsize=None)
def small_engine():
    ds = small_ds()
    return SearchEngine.build(jax.random.PRNGKey(0), ds.train, ds.base,
                              m=8, nlist=32, coarse_iters=6, pq_iters=6)


@functools.lru_cache(maxsize=None)
def hard_ds():
    """Coarse PQ (M=4) + noisy queries: quantization visibly costs recall,
    so the exact re-rank stage has something to win back."""
    return vectors.make_deep_like(n=12000, nt=4000, nq=64, d=32, ncl=256,
                                  seed=5, query_noise=1.0)


@functools.lru_cache(maxsize=None)
def hard_engine():
    ds = hard_ds()
    return SearchEngine.build(jax.random.PRNGKey(0), ds.train, ds.base,
                              m=4, nlist=64, coarse_iters=8, pq_iters=8)


# ---------------------------------------------------------------------------
# stage-composition parity (the engine is exactly its stages)
# ---------------------------------------------------------------------------

def test_search_matches_hand_composed_flat_pipeline():
    ds, eng = small_ds(), small_engine()
    res = eng.search(ds.queries, 10, nprobe=8, rerank_mult=0)
    _, ids_hand = ivf.search_ivf(eng.index, ds.queries, nprobe=8, topk=10)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ids_hand))


def test_search_matches_hand_composed_hnsw_pipeline():
    ds, eng = small_ds(), small_engine()
    eng_h = SearchEngine(eng.index, base=ds.base, coarse="hnsw",
                         hnsw_m=8, ef_construction=32)
    res = eng_h.search(ds.queries, 10, nprobe=8, rerank_mult=0)
    _, probes = eng_h.coarse.search(ds.queries, 8, ef=max(eng_h.config.ef, 8))
    _, ids_hand = ivf.search_ivf_precomputed_probes(
        eng.index, ds.queries, probes, nprobe=8, topk=10)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(ids_hand))


@pytest.mark.parametrize("impl", ["select", "mxu", "stream", "auto"])
def test_scan_impl_matches_ref_through_engine(impl):
    """Every grouped kernel formulation — select-tree VPU, one-hot MXU, the
    gather-free stream DMA, and the autotuned dispatch — produces results
    identical to the jnp gather end-to-end, through both the staged and the
    fused pipeline."""
    ds, eng = small_ds(), small_engine()
    eng_i = SearchEngine(eng.index, base=ds.base,
                         config=EngineConfig(scan_impl=impl))
    q = ds.queries[:4]
    res_ref = eng.search(q, 10, nprobe=4, rerank_mult=2)
    res_i = eng_i.search(q, 10, nprobe=4, rerank_mult=2)
    np.testing.assert_array_equal(np.asarray(res_ref.ids), np.asarray(res_i.ids))
    np.testing.assert_array_equal(np.asarray(res_ref.dists),
                                  np.asarray(res_i.dists))
    res_j = eng_i.search_jit(q, 10, nprobe=4, rerank_mult=2)
    np.testing.assert_array_equal(np.asarray(res_ref.ids), np.asarray(res_j.ids))
    np.testing.assert_array_equal(np.asarray(res_ref.dists),
                                  np.asarray(res_j.dists))


# ---------------------------------------------------------------------------
# exact re-rank
# ---------------------------------------------------------------------------

def test_rerank_bitmatches_brute_force_on_candidate_set():
    """Stage 3 distances == brute-force float distances, bit-for-bit."""
    ds, eng = small_ds(), small_engine()
    q = ds.queries[:8]
    probes = eng.select_probes(q, 8)
    flat_d, flat_ids = eng.scan(q, probes)
    _, pos = topk_mod.masked_topk(flat_d, flat_ids >= 0, 40)
    cand = jnp.where(pos >= 0,
                     jnp.take_along_axis(flat_ids, jnp.maximum(pos, 0), axis=1),
                     -1)
    got = exact_distances(ds.base, q, cand)

    # candidate-restricted brute force, written independently in the same
    # norms+GEMM formulation exact_distances now uses ((‖q‖² − 2q·x) + ‖x‖²,
    # mul+sum contractions): same math, same shapes -> must agree
    # bit-for-bit (the subtraction form drifts by ~1 ulp and is guarded
    # separately on integer data in tests/test_stream_rerank.py). jit'd so
    # both sides get XLA's fused reduction order.
    def bf(b, qq, c):
        x = b[jnp.maximum(c, 0)]
        return jnp.maximum((jnp.sum(qq * qq, -1)[:, None]
                            - 2.0 * jnp.sum(qq[:, None, :] * x, -1))
                           + jnp.sum(b * b, -1)[jnp.maximum(c, 0)], 0.0)
    want = jax.jit(bf)(ds.base, q, cand)
    valid = np.asarray(cand >= 0)
    np.testing.assert_array_equal(np.asarray(got)[valid], np.asarray(want)[valid])
    assert np.all(np.isinf(np.asarray(got)[~valid]))

    # and anchor against float64 numpy ground truth (f32 rounding only)
    base64 = np.asarray(ds.base, np.float64)
    q64 = np.asarray(q, np.float64)
    want64 = ((base64[np.maximum(np.asarray(cand), 0)]
               - q64[:, None, :]) ** 2).sum(-1)
    np.testing.assert_allclose(np.asarray(got)[valid], want64[valid], rtol=1e-5)

    # and the re-ranked top-k is the brute-force order on that set
    vals, ids = exact_rerank(ds.base, q, cand, 10)
    masked = jnp.where(cand >= 0, want, jnp.inf)
    bf_vals, bf_pos = topk_mod.smallest_k(masked, 10)
    bf_ids = jnp.take_along_axis(cand, bf_pos, axis=1)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(bf_vals))
    np.testing.assert_array_equal(np.asarray(ids), np.asarray(bf_ids))


def test_rerank_improves_recall_over_pure_fastscan():
    """Acceptance: re-rank strictly improves (or ties) recall@10 — here it
    improves by a wide margin because M=4 quantization is deliberately lossy."""
    ds, eng = hard_ds(), hard_engine()
    r_pure = float(metrics.recall_at_r(
        eng.search(ds.queries, 10, nprobe=8, rerank_mult=0).ids, ds.gt_ids, r=10))
    r_rr = float(metrics.recall_at_r(
        eng.search(ds.queries, 10, nprobe=8, rerank_mult=4).ids, ds.gt_ids, r=10))
    assert r_rr >= r_pure
    assert r_rr > r_pure + 0.05, (r_pure, r_rr)


def test_full_pipeline_recall_not_below_raw_ivf_fastscan():
    """Acceptance: engine recall@k >= raw IVF fast-scan recall@k."""
    ds, eng = hard_ds(), hard_engine()
    _, ids_raw = ivf.search_ivf(eng.index, ds.queries, nprobe=8, topk=10)
    r_raw = float(metrics.recall_at_r(ids_raw, ds.gt_ids, r=10))
    res = eng.search(ds.queries, 10, nprobe=8, rerank_mult=4)
    r_eng = float(metrics.recall_at_r(res.ids, ds.gt_ids, r=10))
    assert r_eng >= r_raw, (r_raw, r_eng)


def test_rerank_without_base_raises():
    ds, eng = small_ds(), small_engine()
    bare = SearchEngine(eng.index, base=None)
    with pytest.raises(ValueError, match="re-rank"):
        bare.search(ds.queries, 10, rerank_mult=2)


# ---------------------------------------------------------------------------
# QueryStats
# ---------------------------------------------------------------------------

def test_query_stats_match_nprobe_and_list_sizes():
    ds, eng = small_ds(), small_engine()
    k, nprobe, r = 10, 6, 3
    res = eng.search(ds.queries, k, nprobe=nprobe, rerank_mult=r)

    np.testing.assert_array_equal(np.asarray(res.stats.lists_probed),
                                  np.full((ds.queries.shape[0],), nprobe))
    # recompute the probe set by hand and sum true occupancies
    d = pairwise_sqdist(ds.queries, eng.index.centroids)
    _, probes = topk_mod.smallest_k(d, nprobe)
    want_scanned = np.asarray(eng.index.lists.sizes)[np.asarray(probes)].sum(axis=1)
    np.testing.assert_array_equal(np.asarray(res.stats.codes_scanned),
                                  want_scanned)
    # every candidate in a probed list is valid, so the re-rank pool is
    # min(r*k, codes actually scanned)
    np.testing.assert_array_equal(np.asarray(res.stats.reranked),
                                  np.minimum(r * k, want_scanned))


def test_query_stats_zero_rerank_when_disabled():
    ds, eng = small_ds(), small_engine()
    res = eng.search(ds.queries, 10, nprobe=4, rerank_mult=0)
    assert int(np.asarray(res.stats.reranked).sum()) == 0


# ---------------------------------------------------------------------------
# list store
# ---------------------------------------------------------------------------

def test_liststore_gather_masks_invalid_probes():
    eng = small_engine()
    store = eng.index.lists
    probes = jnp.asarray([[0, -1], [2, 3]], jnp.int32)
    codes, ids = store.gather(probes)
    assert codes.shape == (2, 2, store.cap, store.codes.shape[-1])
    assert int((np.asarray(ids[0, 1]) != -1).sum()) == 0  # invalid probe
    sizes = store.probed_sizes(probes)
    assert int(sizes[0, 1]) == 0
    assert int(sizes[0, 0]) == int(store.sizes[0])


def test_partition_lists_preserves_every_vector_once():
    eng = small_engine()
    cen_s, lists_s, real_s = partition_lists(eng.index.lists,
                                             eng.index.centroids, 3)
    all_ids = np.asarray(lists_s.ids).reshape(-1)
    valid = np.sort(all_ids[all_ids >= 0])
    orig = np.asarray(eng.index.lists.ids).reshape(-1)
    np.testing.assert_array_equal(valid, np.sort(orig[orig >= 0]))
    assert cen_s.shape[0] == 3 and lists_s.ids.shape[0] == 3
    # real mask covers exactly the original lists; padding is marked False
    assert int(np.asarray(real_s).sum()) == eng.index.nlist


def test_partition_base_covers_every_row_once_without_replication():
    """The sharded re-rank base: every base row lands on exactly one shard,
    shard slices are ~N/S (not a replicated full copy), and the local-id
    remap round-trips through gids back to the global posting-list ids."""
    from repro.core.lists import partition_base
    ds, eng = small_ds(), small_engine()
    s = 4
    cen_s, lists_s, real_s = partition_lists(eng.index.lists,
                                             eng.index.centroids, s)
    base_s, gids_s, local_ids, norms_s = partition_base(lists_s, ds.base)
    n, d = ds.base.shape
    # each global id appears exactly once across all shards' gids
    g = np.asarray(gids_s).reshape(-1)
    np.testing.assert_array_equal(np.sort(g[g >= 0]), np.arange(n))
    # per-shard slices are a partition, not replicas: R < N for S > 1
    assert base_s.shape[0] == s and base_s.shape[2] == d
    assert base_s.shape[1] < n
    # local ids point at the right rows: base_s[shard, local] == base[global]
    li = np.asarray(local_ids)
    gi = np.asarray(lists_s.ids)
    bs = np.asarray(base_s)
    b = np.asarray(ds.base)
    valid = gi >= 0
    np.testing.assert_array_equal(valid, li >= 0)
    for j in range(s):
        np.testing.assert_array_equal(bs[j][li[j][valid[j]]], b[gi[j][valid[j]]])
        np.testing.assert_array_equal(np.asarray(gids_s)[j][li[j][valid[j]]],
                                      gi[j][valid[j]])
    # norms ride along: norms_s[shard, local] == base_norms(base)[global]
    # bitwise (sliced from ONE full-base computation, not re-derived), 0 at
    # padding
    from repro.core.lists import base_norms
    nrm = np.asarray(base_norms(ds.base))
    ns = np.asarray(norms_s)
    gv = g >= 0
    np.testing.assert_array_equal(ns.reshape(-1)[gv], nrm[g[gv]])
    assert (ns.reshape(-1)[~gv] == 0).all()


def test_sharded_rerank_on_local_base_matches_replicated_semantics():
    """Single shard + re-rank: local-base slicing and the id remap must be
    invisible — results identical to the unsharded engine's."""
    ds, eng = small_ds(), small_engine()
    sh = ShardedEngine(eng, 1)
    res = eng.search(ds.queries, 10, nprobe=8, rerank_mult=3)
    res_s = sh.search(ds.queries, 10, nprobe=8, rerank_mult=3)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(res_s.ids))
    np.testing.assert_array_equal(np.asarray(res.dists), np.asarray(res_s.dists))


def test_sharded_rerank_multi_shard_returns_global_ids():
    """S > 1 with re-rank: result ids are global (valid range, deduped) and
    the re-ranked distances are the true float distances to those ids."""
    ds, eng = small_ds(), small_engine()
    sh = ShardedEngine(eng, 3)
    res = sh.search(ds.queries, 10, nprobe=4, rerank_mult=2)
    ids = np.asarray(res.ids)
    d = np.asarray(res.dists)
    b = np.asarray(ds.base)
    q = np.asarray(ds.queries)
    assert ids.max() < b.shape[0]
    for qi in range(ids.shape[0]):
        row = ids[qi][ids[qi] >= 0]
        assert len(row) == len(set(row.tolist()))
        want = ((b[row] - q[qi][None, :]) ** 2).sum(-1)
        np.testing.assert_allclose(d[qi][ids[qi] >= 0], want, rtol=1e-5)


def test_sharded_stats_exclude_padding_lists():
    """nlist=32, S=5 -> L=7 with 3 padding lists; probing all 7 local lists
    per shard must report exactly the 32 real lists, not 35."""
    ds, eng = small_ds(), small_engine()
    sh = ShardedEngine(eng, 5)
    res = sh.search(ds.queries, 10, nprobe=7, rerank_mult=0)
    np.testing.assert_array_equal(np.asarray(res.stats.lists_probed),
                                  np.full((ds.queries.shape[0],), 32))


# ---------------------------------------------------------------------------
# shard-parallel execution
# ---------------------------------------------------------------------------

def test_sharded_single_shard_matches_unsharded():
    ds, eng = small_ds(), small_engine()
    res = eng.search(ds.queries, 10, nprobe=8, rerank_mult=0)
    sh = ShardedEngine(eng, 1)
    res_s = sh.search(ds.queries, 10, nprobe=8, rerank_mult=0)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(res_s.ids))
    np.testing.assert_array_equal(np.asarray(res.dists), np.asarray(res_s.dists))


def test_sharded_recall_and_stats():
    """Each of S shards probes nprobe of its own lists => >= recall of the
    single-shard engine at the same nprobe, and stats aggregate via psum."""
    ds, eng = hard_ds(), hard_engine()
    nprobe = 4
    r_single = float(metrics.recall_at_r(
        eng.search(ds.queries, 10, nprobe=nprobe, rerank_mult=4).ids,
        ds.gt_ids, r=10))
    sh = ShardedEngine(eng, 4)
    res = sh.search(ds.queries, 10, nprobe=nprobe, rerank_mult=4)
    r_sharded = float(metrics.recall_at_r(res.ids, ds.gt_ids, r=10))
    assert r_sharded >= r_single - 1e-6, (r_single, r_sharded)
    np.testing.assert_array_equal(np.asarray(res.stats.lists_probed),
                                  np.full((ds.queries.shape[0],), 4 * nprobe))


def test_sharded_results_are_sorted_and_deduped():
    ds, eng = small_ds(), small_engine()
    sh = ShardedEngine(eng, 4)
    res = sh.search(ds.queries, 10, nprobe=4, rerank_mult=0)
    d = np.asarray(res.dists)
    assert np.all(np.diff(d, axis=1) >= 0)
    ids = np.asarray(res.ids)
    for row in ids:
        row = row[row >= 0]
        assert len(row) == len(set(row.tolist()))


def test_sharded_shard_map_on_device_mesh():
    """The shard_map driver (one shard per device) agrees with the vmap one."""
    ds, eng = small_ds(), small_engine()
    n_dev = jax.device_count()
    sh = ShardedEngine(eng, n_dev)
    mesh = jax.make_mesh((n_dev,), ("shards",))
    res_m = sh.search(ds.queries, 10, nprobe=4, rerank_mult=2, mesh=mesh)
    res_v = sh.search(ds.queries, 10, nprobe=4, rerank_mult=2)
    np.testing.assert_array_equal(np.asarray(res_m.ids), np.asarray(res_v.ids))
    np.testing.assert_array_equal(np.asarray(res_m.dists), np.asarray(res_v.dists))
