"""Fault-injection harness for the durable-index subsystem.

Every persistence byte crosses the primitives in ``repro.persist.io``
(``write_bytes`` / ``read_bytes`` / ``append_record`` and the group-commit
pair ``append_bytes`` / ``fsync_file``) — see that module's docstring.
``FaultInjector`` monkey-wraps exactly those, so the harness can
deterministically produce:

  - **torn writes**: a snapshot segment / WAL append persists only a prefix
    of its bytes (crash mid-write);
  - **bit flips**: one byte of a written or read file is corrupted
    (storage rot the CRCs must catch);
  - **short reads**: ``read_bytes`` returns a prefix (truncated file, torn
    download);
  - **crash-at-step-N**: the N-th I/O call raises ``SimulatedCrash`` after
    optionally persisting a prefix, aborting whatever multi-file operation
    was in flight (the in-process analogue of the kill-9 subprocess driver
    in ``tools/crash_test.py``).

Plus filesystem-level corruptors (``flip_byte_in`` / ``truncate_file`` /
``delete_file``) for damaging completed directories. The recovery contract
under every fault is *prefix-or-loud* (repro.persist.errors): reopening
yields either a bit-identical engine over a prefix of the acknowledged
mutations, or a typed ``CorruptSnapshotError``/``CorruptWALError`` —
asserted by tests/test_persist.py.

Importable from tests and tools (lives in ``tests/`` but has no pytest
dependency).
"""
from __future__ import annotations

import os
import random

from repro.persist import io as pio


class SimulatedCrash(BaseException):
    """Raised by the injector at the chosen I/O step. Deliberately NOT an
    Exception subclass so production code cannot accidentally swallow it —
    only the test harness catches it (like a real kill-9 would not be
    caught)."""


class FaultInjector:
    """Context manager wrapping the persistence I/O seam.

    Counts write-side calls (``write_bytes`` + ``append_record``); when the
    count hits ``crash_at_write`` the call persists only ``torn_fraction``
    of its payload and raises ``SimulatedCrash``. Independently,
    ``flip_write_byte``/``flip_read_byte`` corrupt one byte of the N-th
    written/read buffer (no crash — silent rot), and ``short_read_at``
    truncates the N-th read to half. All counters are 1-based.
    """

    def __init__(self, *, crash_at_write: int | None = None,
                 torn_fraction: float = 0.5,
                 flip_write_byte: int | None = None,
                 flip_read_byte: int | None = None,
                 short_read_at: int | None = None,
                 seed: int = 0):
        self.crash_at_write = crash_at_write
        self.torn_fraction = torn_fraction
        self.flip_write_byte = flip_write_byte
        self.flip_read_byte = flip_read_byte
        self.short_read_at = short_read_at
        self.rng = random.Random(seed)
        self.writes = 0
        self.reads = 0
        self._saved: dict[str, object] = {}

    # -- byte corruption -----------------------------------------------------

    def _flip(self, data: bytes) -> bytes:
        if not data:
            return data
        i = self.rng.randrange(len(data))
        return data[:i] + bytes([data[i] ^ (1 << self.rng.randrange(8))]) \
            + data[i + 1:]

    def _on_write(self, data: bytes) -> bytes:
        self.writes += 1
        if self.writes == self.flip_write_byte:
            data = self._flip(data)
        if self.writes == self.crash_at_write:
            return None  # sentinel: crash, persisting a torn prefix
        return data

    # -- wrapped primitives --------------------------------------------------

    def _write_bytes(self, path: str, data: bytes) -> None:
        out = self._on_write(data)
        if out is None:
            torn = data[:int(len(data) * self.torn_fraction)]
            self._orig_write(path, torn)
            raise SimulatedCrash(f"write_bytes({path}) at step {self.writes}")
        self._orig_write(path, out)

    def _append_record(self, f, data: bytes) -> None:
        out = self._on_write(data)
        if out is None:
            self._orig_append(f, data[:int(len(data) * self.torn_fraction)])
            raise SimulatedCrash(f"append_record at step {self.writes}")
        self._orig_append(f, out)

    def _append_bytes(self, f, data: bytes) -> None:
        # the group-commit write half: same write-side counter, so a crash
        # sweep covers deferred-fsync appends exactly like fsync'd ones
        out = self._on_write(data)
        if out is None:
            self._orig_append_b(f, data[:int(len(data) * self.torn_fraction)])
            raise SimulatedCrash(f"append_bytes at step {self.writes}")
        self._orig_append_b(f, out)

    def _read_bytes(self, path: str) -> bytes:
        data = self._orig_read(path)
        self.reads += 1
        if self.reads == self.flip_read_byte:
            data = self._flip(data)
        if self.reads == self.short_read_at:
            data = data[:len(data) // 2]
        return data

    # -- install / restore ---------------------------------------------------

    def __enter__(self) -> "FaultInjector":
        self._orig_write = pio.write_bytes
        self._orig_append = pio.append_record
        self._orig_append_b = pio.append_bytes
        self._orig_read = pio.read_bytes
        pio.write_bytes = self._write_bytes
        pio.append_record = self._append_record
        pio.append_bytes = self._append_bytes
        pio.read_bytes = self._read_bytes
        return self

    def __exit__(self, *exc) -> None:
        pio.write_bytes = self._orig_write
        pio.append_record = self._orig_append
        pio.append_bytes = self._orig_append_b
        pio.read_bytes = self._orig_read


# ---------------------------------------------------------------------------
# filesystem-level corruptors for completed directories
# ---------------------------------------------------------------------------

def flip_byte_in(path: str, offset: int | None = None, seed: int = 0) -> None:
    """Flip one bit of one byte of the file at ``path`` in place."""
    rng = random.Random(seed)
    with open(path, "r+b") as f:
        f.seek(0, os.SEEK_END)
        size = f.tell()
        if size == 0:
            return
        i = rng.randrange(size) if offset is None else offset
        f.seek(i)
        b = f.read(1)[0]
        f.seek(i)
        f.write(bytes([b ^ (1 << rng.randrange(8))]))


def truncate_file(path: str, fraction: float = 0.5) -> None:
    """Cut the file to a prefix (torn write / lost tail)."""
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(int(size * fraction))


def delete_file(path: str) -> None:
    os.remove(path)


def snapshot_files(directory: str) -> list[str]:
    """Every file of the CURRENT snapshot (segments + shard manifests),
    paths absolute, sorted for determinism."""
    import json
    with open(os.path.join(directory, "MANIFEST.json")) as f:
        manifest = json.load(f)
    rels = [e["file"] for e in manifest["segments"].values()]
    for sh in manifest.get("shards", ()):
        rels.append(sh["manifest"])
        with open(os.path.join(directory, sh["manifest"])) as f:
            rels.extend(e["file"]
                        for e in json.load(f)["segments"].values())
    return sorted(os.path.join(directory, r) for r in rels)


def wal_paths(directory: str) -> list[str]:
    from repro.persist import wal_files
    return [p for _s, p in wal_files(directory)]
