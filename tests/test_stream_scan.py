"""Gather-free probe-streaming scan: parity, DMA-skip semantics, fused
reduction exactness, memory traffic, and autotune-cache persistence.

The 'stream' impl must be bit-identical to 'ref' on every real candidate —
through the raw kernels, ``scan_probes``, the reduced-pool
``scan_probes_stream``, and the whole engine (``search`` / ``search_jit``).
Integer ADC accumulation is exact, so every comparison here is
``assert_array_equal``, never allclose.
"""
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ivf
from repro.core.lists import ListStore
from repro.core.pq import PQCodebook
from repro.data import vectors
from repro.engine import EngineConfig, SearchEngine, ShardedEngine
from repro.engine.engine import scan_candidates
from repro.kernels import ops, ref
from repro.launch.hlo_analysis import xla_cost_dict


def _synth_index(nlist, cap, m, *, d=None, seed=0, occupancy="ragged"):
    """An IVFIndex from raw random arrays — no k-means, instant to build.

    occupancy: 'ragged' (random sizes incl. empty lists), 'full', or an
    explicit (nlist,) array of sizes.
    """
    d = d or 4 * m
    assert d % m == 0
    rng = np.random.default_rng(seed)
    if isinstance(occupancy, str):
        sizes = (np.full(nlist, cap) if occupancy == "full"
                 else rng.integers(0, cap + 1, nlist))
    else:
        sizes = np.asarray(occupancy)
    codes = np.zeros((nlist, cap, m // 2), np.uint8)
    ids = np.full((nlist, cap), -1, np.int32)
    nxt = 0
    for li in range(nlist):
        s = int(sizes[li])
        codes[li, :s] = rng.integers(0, 256, (s, m // 2), np.uint8)
        ids[li, :s] = np.arange(nxt, nxt + s, dtype=np.int32)
        nxt += s
    index = ivf.IVFIndex(
        centroids=jnp.asarray(rng.normal(size=(nlist, d)).astype(np.float32)),
        codebook=PQCodebook(jnp.asarray(
            rng.normal(size=(m, 16, d // m)).astype(np.float32))),
        lists=ListStore(codes=jnp.asarray(codes), ids=jnp.asarray(ids),
                        sizes=jnp.asarray(sizes.astype(np.int32))),
    )
    base = rng.normal(size=(max(nxt, 1), d)).astype(np.float32)
    return index, jnp.asarray(base)


def _queries(index, q, seed=1):
    rng = np.random.default_rng(seed)
    d = index.centroids.shape[1]
    return jnp.asarray(rng.normal(size=(q, d)).astype(np.float32))


# ---------------------------------------------------------------------------
# kernel-level parity (gathered calling convention)
# ---------------------------------------------------------------------------

STREAM_SHAPES = [
    (1, 64, 4),     # G=1 (single query x single probe)
    (3, 100, 4),    # cap with no pow2 divisor >= 8 -> padded-copy path
    (4, 129, 3),    # ragged cap AND odd M//2
    (2, 300, 1),    # minimal M
    (5, 1024, 8),   # exact tile
]


@pytest.mark.parametrize("g,cap,mh", STREAM_SHAPES)
def test_stream_gathered_signature_matches_ref(g, cap, mh):
    rng = np.random.default_rng(g * 31 + cap + mh)
    table = jnp.asarray(rng.integers(0, 256, (g, 2 * mh, 16), np.uint8))
    codes = jnp.asarray(rng.integers(0, 256, (g, cap, mh), np.uint8))
    want = ref.fastscan_grouped_ref(table, codes)
    got = ops.fastscan_grouped(table, codes, impl="stream")
    assert got.dtype == jnp.int32 and got.shape == (g, cap)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_stream_multi_tile_grid():
    """tile_n < cap drives >1 DMA per group; results must be seamless."""
    rng = np.random.default_rng(5)
    table = jnp.asarray(rng.integers(0, 256, (3, 8, 16), np.uint8))
    codes = jnp.asarray(rng.integers(0, 256, (3, 256, 4), np.uint8))
    want = np.asarray(ref.fastscan_grouped_ref(table, codes))
    got = ops.fastscan_grouped(table, codes, impl="stream", tile_n=64)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_stream_inplace_skips_invalid_and_handles_duplicates():
    """In-place entry: duplicate probes scan the same list twice; invalid
    probes (-1) skip the DMA and emit zeros."""
    rng = np.random.default_rng(7)
    nlist, cap, mh = 6, 96, 4
    store = jnp.asarray(rng.integers(0, 256, (nlist, cap, mh), np.uint8))
    probes = jnp.asarray(np.array([2, 2, -1, 5, 0, -1], np.int32))
    table = jnp.asarray(rng.integers(0, 256, (6, 2 * mh, 16), np.uint8))
    got = np.asarray(ops.fastscan_stream_grouped(table, store, probes,
                                                 tile_n=32))
    want = np.asarray(ref.fastscan_grouped_ref(
        table, store[jnp.maximum(probes, 0)]))
    valid = np.asarray(probes) >= 0
    np.testing.assert_array_equal(got[valid], want[valid])
    assert (got[~valid] == 0).all()


def test_stream_topk_exact_with_occupancy_and_ties():
    """Fused per-tile selection == numpy stable-sort oracle, including
    occupancy masking and lowest-slot tie-breaks (u8 codes collide often
    at these sizes, so ties genuinely occur)."""
    rng = np.random.default_rng(11)
    nlist, cap, mh, tile, kc = 5, 64, 2, 32, 6
    store = jnp.asarray(rng.integers(0, 4, (nlist, cap, mh), np.uint8))
    sizes = jnp.asarray(np.array([64, 40, 0, 33, 1], np.int32))
    probes = jnp.asarray(np.array([0, 1, 2, 3, 4, -1], np.int32))
    g = probes.shape[0]
    table = jnp.asarray(rng.integers(0, 3, (g, 2 * mh, 16), np.uint8))
    vals, slots = ops.fastscan_stream_topk(table, store, probes, sizes,
                                           keep=kc, tile_n=tile)
    assert vals.shape == (g, cap // tile, kc)
    vals, slots = np.asarray(vals), np.asarray(slots)
    acc = np.asarray(ref.fastscan_grouped_ref(
        table, store[jnp.maximum(probes, 0)]))
    for gi in range(g):
        lid = int(probes[gi])
        if lid < 0:
            assert (slots[gi] == -1).all()
            continue
        for ti in range(cap // tile):
            lo = ti * tile
            n_valid = int(np.clip(int(sizes[lid]) - lo, 0, tile))
            seg = acc[gi, lo:lo + n_valid]
            order = np.argsort(seg, kind="stable")[:kc]  # ties: lowest slot
            k_real = min(kc, n_valid)
            np.testing.assert_array_equal(vals[gi, ti, :k_real], seg[order])
            np.testing.assert_array_equal(slots[gi, ti, :k_real], order + lo)
            assert (slots[gi, ti, k_real:] == -1).all()


def test_stream_registered_in_impl_registries():
    assert "stream" in ops.GROUPED_IMPLS
    assert "stream" in ops.SCAN_IMPLS
    assert "stream" not in ops.IMPLS  # flat scan has no probe indirection


def test_autotune_sweep_times_stream():
    ops.clear_autotune_cache()
    try:
        tuned = ops.resolve_grouped_impl(2, 64, 8)
        swept = {name.split("@")[0] for name, _ in tuned.timings_us}
        assert "stream" in swept
        # stream tiles in the sweep must divide cap (in-place constraint),
        # so the verdict's pair is exactly what scan_probes will execute
        for name, _ in tuned.timings_us:
            impl, tile = name.split("@")
            if impl == "stream":
                assert 64 % int(tile) == 0
    finally:
        ops.clear_autotune_cache()


# ---------------------------------------------------------------------------
# gather early-mask bugfix
# ---------------------------------------------------------------------------

def test_gather_masks_codes_for_invalid_probes():
    """An invalid probe must gather ZERO codes, not list 0's real codes —
    otherwise the gathered impls scan work that QueryStats.codes_scanned
    never counted and that the stream kernel (which skips the DMA) never
    does."""
    index, _ = _synth_index(4, 32, 8, occupancy="full")
    probes = jnp.asarray(np.array([[0, -1], [-1, 3]], np.int32))
    codes, ids = index.lists.gather(probes)
    codes, ids = np.asarray(codes), np.asarray(ids)
    assert (codes[0, 1] == 0).all() and (codes[1, 0] == 0).all()
    assert (ids[0, 1] == -1).all() and (ids[1, 0] == -1).all()
    np.testing.assert_array_equal(codes[0, 0], np.asarray(index.lists.codes[0]))
    np.testing.assert_array_equal(
        np.asarray(index.lists.gather_ids(probes)), ids)


# ---------------------------------------------------------------------------
# scan_probes / scan_probes_stream parity
# ---------------------------------------------------------------------------

def _assert_scan_parity(index, q, probes):
    d_ref, i_ref = ivf.scan_probes(index, q, probes, impl="ref")
    d_s, i_s = ivf.scan_probes(index, q, probes, impl="stream")
    i_ref, i_s = np.asarray(i_ref), np.asarray(i_s)
    np.testing.assert_array_equal(i_s, i_ref)
    valid = i_ref >= 0
    np.testing.assert_array_equal(np.asarray(d_s)[valid],
                                  np.asarray(d_ref)[valid])
    return d_ref, i_ref


def test_scan_probes_stream_impl_parity_ragged():
    index, _ = _synth_index(6, 100, 8, occupancy="ragged")
    q = _queries(index, 3)
    probes = jnp.asarray(np.array([[0, 1], [5, 5], [2, 4]], np.int32))
    _assert_scan_parity(index, q, probes)  # incl. duplicate probes (row 1)


def test_scan_probes_stream_impl_parity_invalid_rows():
    index, _ = _synth_index(4, 64, 4)
    q = _queries(index, 3)
    probes = jnp.asarray(np.array([[-1, -1], [0, -1], [3, 1]], np.int32))
    _assert_scan_parity(index, q, probes)  # incl. an all-invalid row


def test_scan_probes_stream_reduced_pool_selection_parity():
    """The reduced (P*n_tiles*kc) pool must yield the exact same top-keep
    selection as the full (P*cap) pool — multi-tile, ragged occupancy,
    duplicate + invalid probes all at once."""
    from repro.core import topk as topk_mod
    index, _ = _synth_index(6, 128, 8, occupancy="ragged", seed=3)
    q = _queries(index, 4)
    probes = jnp.asarray(np.array(
        [[0, 1, 2], [3, 3, -1], [-1, -1, -1], [5, 4, 0]], np.int32))
    keep = 10
    d_full, i_full = ivf.scan_probes(index, q, probes, impl="ref")
    qq = d_full.shape[0]
    fd, fi = d_full.reshape(qq, -1), i_full.reshape(qq, -1)
    want_v, want_pos = topk_mod.masked_topk(fd, fi >= 0, keep)
    want_i = topk_mod.gather_ids(fi, want_pos)

    rd, ri = ivf.scan_probes_stream(index, q, probes, keep=keep, tile_n=32)
    assert rd.shape[1] < fd.shape[1]  # the pool genuinely shrank
    got_v, got_pos = topk_mod.masked_topk(rd, ri >= 0, keep)
    got_i = topk_mod.gather_ids(ri, got_pos)
    np.testing.assert_array_equal(np.asarray(got_i), np.asarray(want_i))
    np.testing.assert_array_equal(np.asarray(got_v), np.asarray(want_v))


def test_scan_candidates_keep_routes_stream_to_reduced_pool():
    index, _ = _synth_index(5, 64, 8, seed=9)
    q = _queries(index, 2)
    probes = jnp.asarray(np.array([[0, 2], [4, 1]], np.int32))
    full_d, full_i, full_ts = scan_candidates(index, q, probes,
                                              scan_impl="ref", keep=5)
    red_d, red_i, _ = scan_candidates(index, q, probes, scan_impl="stream",
                                      keep=5)
    assert full_d.shape[1] == 2 * 64
    assert red_d.shape[1] < full_d.shape[1]
    # the tiles-skipped counter is all zeros without early_exit
    np.testing.assert_array_equal(np.asarray(full_ts), 0)
    # both pools contain the same top-5 (checked end-to-end elsewhere);
    # keep=None falls back to the full pool under every impl
    s_d, s_i, _ = scan_candidates(index, q, probes, scan_impl="stream")
    assert s_d.shape == full_d.shape
    valid = np.asarray(full_i) >= 0
    np.testing.assert_array_equal(np.asarray(s_i), np.asarray(full_i))
    np.testing.assert_array_equal(np.asarray(s_d)[valid],
                                  np.asarray(full_d)[valid])


# ---------------------------------------------------------------------------
# engine end-to-end: bit-identical search/search_jit, multi-tile cap
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def trained_engine():
    ds = vectors.make_sift_like(n=5000, nt=2000, nq=16, d=32, ncl=32, seed=3)
    eng = SearchEngine.build(jax.random.PRNGKey(0), ds.train, ds.base,
                             m=8, nlist=32, coarse_iters=6, pq_iters=6)
    return ds, eng


@pytest.mark.parametrize("rerank_mult", [0, 2])
def test_search_stream_bitidentical_to_ref(rerank_mult):
    ds, eng = trained_engine()
    eng_s = SearchEngine(eng.index, base=ds.base,
                         config=EngineConfig(scan_impl="stream"))
    q = ds.queries[:6]
    res_ref = eng.search(q, 10, nprobe=6, rerank_mult=rerank_mult)
    for res in (eng_s.search(q, 10, nprobe=6, rerank_mult=rerank_mult),
                eng_s.search_jit(q, 10, nprobe=6, rerank_mult=rerank_mult)):
        np.testing.assert_array_equal(np.asarray(res.ids),
                                      np.asarray(res_ref.ids))
        np.testing.assert_array_equal(np.asarray(res.dists),
                                      np.asarray(res_ref.dists))
        for a, b in zip(res.stats, res_ref.stats):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_search_stream_multi_tile_cap():
    """cap > TILE_N forces a multi-tile stream grid through the engine."""
    index, base = _synth_index(4, 2048, 8, occupancy="ragged", seed=13)
    q = _queries(index, 3)
    eng_r = SearchEngine(index, base=base, config=EngineConfig(scan_impl="ref"))
    eng_s = SearchEngine(index, base=base,
                         config=EngineConfig(scan_impl="stream"))
    res_r = eng_r.search(q, 5, nprobe=3, rerank_mult=2)
    res_s = eng_s.search(q, 5, nprobe=3, rerank_mult=2)
    np.testing.assert_array_equal(np.asarray(res_s.ids), np.asarray(res_r.ids))
    np.testing.assert_array_equal(np.asarray(res_s.dists),
                                  np.asarray(res_r.dists))


def test_sharded_stream_matches_sharded_ref():
    ds, eng = trained_engine()
    eng_s = SearchEngine(eng.index, base=ds.base,
                         config=EngineConfig(scan_impl="stream"))
    q = ds.queries[:4]
    res_r = ShardedEngine(eng, 3).search(q, 10, nprobe=4, rerank_mult=2)
    res_s = ShardedEngine(eng_s, 3).search(q, 10, nprobe=4, rerank_mult=2)
    np.testing.assert_array_equal(np.asarray(res_s.ids), np.asarray(res_r.ids))
    np.testing.assert_array_equal(np.asarray(res_s.dists),
                                  np.asarray(res_r.dists))
    for a, b in zip(res_s.stats, res_r.stats):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# memory traffic: the point of the whole exercise
# ---------------------------------------------------------------------------

def test_stream_scan_stage_bytes_accessed_4x_below_gathered():
    """cost_analysis bytes-accessed of the scan stage: the gather-free path
    must come in at least 4x under the gathered path at the acceptance
    shape (Q=32, P=16, cap=1024, M=16)."""
    index, _ = _synth_index(64, 1024, 16, d=32, occupancy="full", seed=17)
    q = _queries(index, 32)
    probes = jnp.asarray(
        np.random.default_rng(0).integers(0, 64, (32, 16), np.int32))

    gathered = jax.jit(lambda i, qq, p: ivf.scan_probes(i, qq, p, impl="ref"))
    streamed = jax.jit(functools.partial(ivf.scan_probes_stream, keep=40))
    b_gather = xla_cost_dict(
        gathered.lower(index, q, probes).compile()).get("bytes accessed", 0.0)
    b_stream = xla_cost_dict(
        streamed.lower(index, q, probes).compile()).get("bytes accessed", 0.0)
    assert b_gather > 0 and b_stream > 0
    assert b_stream * 4 <= b_gather, (b_stream, b_gather)


# ---------------------------------------------------------------------------
# autotune-cache persistence
# ---------------------------------------------------------------------------

def test_autotune_cache_save_load_roundtrip(tmp_path):
    path = str(tmp_path / "tuned.json")
    ops.clear_autotune_cache()
    try:
        tuned = ops.resolve_grouped_impl(2, 32, 4)
        assert ops.save_autotune_cache(path) == 1
        ops.clear_autotune_cache()
        assert ops.autotune_cache_size() == 0
        assert ops.load_autotune_cache(path) == 1
        (got,) = ops.autotune_cache().values()
        assert got == tuned
        # a loaded verdict is a cache hit: resolving again runs no sweep
        # (it would append a new entry only on a miss)
        assert ops.resolve_grouped_impl(2, 32, 4) == tuned
        assert ops.autotune_cache_size() == 1
        # loading again is idempotent (in-process verdicts win)
        assert ops.load_autotune_cache(path) == 0
    finally:
        ops.clear_autotune_cache()


def test_autotune_cache_load_tolerates_garbage(tmp_path):
    assert ops.load_autotune_cache(str(tmp_path / "missing.json")) == 0
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert ops.load_autotune_cache(str(bad)) == 0
    stale = tmp_path / "stale.json"
    stale.write_text(json.dumps({
        "schema": "repro.autotune/v1",
        "entries": [{"backend": "cpu", "interpret": True, "g": 1, "cap": 8,
                     "m": 2, "impl": "gone-impl", "tile_n": 0,
                     "timings_us": []}]}))
    assert ops.load_autotune_cache(str(stale)) == 0  # unknown impl skipped
    assert ops.autotune_cache_size() == 0


def test_serving_loop_warmup_cache_skips_resweep(tmp_path):
    from repro.serving import ServingLoop
    path = str(tmp_path / "fleet.json")
    # an index shape no other test uses, so the process-wide fused-jit cache
    # cannot already hold this signature and the first warmup MUST trace
    # (and therefore sweep)
    index, base = _synth_index(10, 48, 6, d=24, seed=23)
    eng_a = SearchEngine(index, base=base,
                         config=EngineConfig(scan_impl="auto"))
    ops.clear_autotune_cache()
    try:
        loop = ServingLoop(eng_a, rerank_mult=2, buckets=(2,),
                           warmup_cache=path)
        loop.start(warmup=True, warmup_ks=(7,))
        loop.stop()
        assert loop.metrics().autotuned >= 1  # first boot paid the sweep
        with open(path) as f:
            assert len(json.load(f)["entries"]) >= 1
        ops.clear_autotune_cache()  # "new replica"
        loop2 = ServingLoop(eng_a, rerank_mult=2, buckets=(2,),
                            warmup_cache=path)
        loop2.start(warmup=True, warmup_ks=(7,))
        loop2.stop()
        # the hook re-populated the table from the fleet file (the roundtrip
        # test proves a loaded verdict short-circuits the sweep) and no new
        # sweeps ran during warmup
        assert ops.autotune_cache_size() >= 1
        assert loop2.metrics().autotuned == 0
    finally:
        ops.clear_autotune_cache()
