"""Cross-path consistency: chunked-train vs decode-recurrence parity.

These are the strongest correctness tests in the repo: the chunked SSD /
WKV6 / flash-attention formulations (training path) and the O(1)-state
decode recurrences are independent implementations of the same math, so
teacher-forced logits must agree position by position.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import model as model_lib
from repro.models import layers as ll
from repro.models import ssm as ssm_mod
from repro.models import rwkv6 as rwkv_mod

ATTN_ARCHS = ["qwen3_1p7b", "starcoder2_15b"]
REC_ARCHS = ["zamba2_2p7b", "rwkv6_3b"]


def _tokens(cfg, b, s, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, cfg.vocab, (b, s), np.int32))


@pytest.mark.parametrize("arch", ATTN_ARCHS + REC_ARCHS)
def test_decode_matches_teacher_forced_forward(arch):
    cfg = configs.get_smoke_config(arch).replace(kv_pq=False)
    b, s = 2, 32
    params = model_lib.init_lm(jax.random.PRNGKey(0), cfg)
    tokens = _tokens(cfg, b, s)
    full_logits, _ = model_lib.forward(params, tokens, cfg)  # (B,S,V)

    cache = model_lib.init_cache(cfg, b, s)
    step = jax.jit(lambda c, t, pos: model_lib.decode_step(params, c, t, pos, cfg))
    errs = []
    for i in range(s - 1):
        pos = jnp.full((b,), i, jnp.int32)
        logits, cache = step(cache, tokens[:, i], pos)
        diff = jnp.max(jnp.abs(logits - full_logits[:, i]))
        errs.append(float(diff))
    scale = float(jnp.max(jnp.abs(full_logits))) + 1e-9
    assert max(errs) / scale < 5e-2, \
        f"{arch}: decode diverges from forward, max rel err {max(errs)/scale}"


def test_chunked_attention_matches_full():
    cfg = configs.get_smoke_config("qwen3_1p7b").replace(
        attn_q_chunk=8, attn_kv_chunk=16)
    key = jax.random.PRNGKey(0)
    b, s, h, kv, hd = 2, 64, 4, 2, 16
    q = jax.random.normal(key, (b, s, h, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, kv, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, kv, hd))
    out_c = ll.chunked_causal_attention(q, k, v, cfg)
    out_f = ll.full_causal_attention(q, k, v, cfg)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_f),
                               atol=2e-5, rtol=2e-4)


def test_ssd_chunked_matches_naive_recurrence():
    """SSD chunked scan == token-by-token linear recurrence."""
    key = jax.random.PRNGKey(1)
    b, s, nh, hd, g, ds, chunk = 2, 32, 4, 8, 1, 16, 8
    xh = jax.random.normal(key, (b, s, nh, hd)) * 0.5
    log_a = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (b, s, nh))) * 0.3
    bm = jax.random.normal(jax.random.fold_in(key, 2), (b, s, g, ds)) * 0.5
    cm = jax.random.normal(jax.random.fold_in(key, 3), (b, s, g, ds)) * 0.5
    y_chunked, h_final = ssm_mod.ssd_chunked(xh, log_a, bm, cm, chunk)

    # naive recurrence
    h = np.zeros((b, nh, hd, ds))
    a_np = np.exp(np.asarray(log_a, np.float64))
    bh = np.repeat(np.asarray(bm, np.float64), nh // g, axis=2)
    ch = np.repeat(np.asarray(cm, np.float64), nh // g, axis=2)
    x_np = np.asarray(xh, np.float64)
    ys = []
    for t in range(s):
        h = h * a_np[:, t][:, :, None, None] + np.einsum(
            "bhp,bhn->bhpn", x_np[:, t], bh[:, t])
        ys.append(np.einsum("bhpn,bhn->bhp", h, ch[:, t]))
    y_ref = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked, np.float64), y_ref,
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h_final, np.float64), h,
                               atol=1e-3, rtol=1e-3)


def test_wkv6_chunked_matches_naive_recurrence():
    """WKV6 chunked == per-token recurrence (incl. the u-bonus diagonal)."""
    key = jax.random.PRNGKey(2)
    b, s, nh, hd, chunk = 2, 24, 2, 8, 8
    r = jax.random.normal(key, (b, s, nh, hd)) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, nh, hd)) * 0.5
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, nh, hd)) * 0.5
    log_w = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 3),
                                       (b, s, nh, hd))) * 0.3
    u = jax.random.normal(jax.random.fold_in(key, 4), (nh, hd)) * 0.5
    y_chunked, s_final = rwkv_mod.wkv6_chunked(r, k, v, log_w, u, chunk)

    S = np.zeros((b, nh, hd, hd))
    w_np = np.exp(np.asarray(log_w, np.float64))
    r_np, k_np, v_np = (np.asarray(t, np.float64) for t in (r, k, v))
    u_np = np.asarray(u, np.float64)
    ys = []
    for t in range(s):
        kv = np.einsum("bhi,bhj->bhij", k_np[:, t], v_np[:, t])
        o = np.einsum("bhi,bhij->bhj", r_np[:, t], S + u_np[None, :, :, None] * kv)
        S = S * w_np[:, t][..., None] + kv
        ys.append(o)
    y_ref = np.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked, np.float64), y_ref,
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_final, np.float64), S,
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("arch", ["qwen3_1p7b", "zamba2_2p7b", "rwkv6_3b"])
def test_prefill_then_decode_continues_correctly(arch):
    """prefill(prompt) + decode(next) == forward(prompt+next) at the end."""
    cfg = configs.get_smoke_config(arch).replace(kv_pq=False)
    b, s = 2, 24
    params = model_lib.init_lm(jax.random.PRNGKey(3), cfg)
    tokens = _tokens(cfg, b, s + 1, seed=1)
    full_logits, _ = model_lib.forward(params, tokens, cfg)

    logits_p, cache = model_lib.prefill(params, tokens[:, :s], cfg,
                                        max_seq=s + 4)
    scale = float(jnp.max(jnp.abs(full_logits))) + 1e-9
    # prefill last-position logits == forward at position s-1
    err_p = float(jnp.max(jnp.abs(logits_p - full_logits[:, s - 1])))
    assert err_p / scale < 5e-2, f"{arch}: prefill mismatch {err_p/scale}"
    # one decode step after the prompt == forward at position s
    logits_d, _ = model_lib.decode_step(params, cache, tokens[:, s],
                                        jnp.full((b,), s, jnp.int32), cfg)
    err_d = float(jnp.max(jnp.abs(logits_d - full_logits[:, s])))
    assert err_d / scale < 5e-2, f"{arch}: decode-after-prefill mismatch {err_d/scale}"
