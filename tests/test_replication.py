"""Replication tier: WAL shipping, warm standbys, fenced failover.

The contract under test (docs/persistence.md#replication): a standby that
follows the shipped-WAL stream is bit-identical to the primary over the
applied prefix; promotion drains, fences the old primary loudly
(``FencedError`` on its next append AND ship), and the promoted replica
equals a from-scratch rebuild over exactly the acked prefix — across
staged/fused query paths and both sharded drivers. Shipped-chain damage
(drops, duplicates, torn or bit-flipped frames, flaky transports past the
retry budget) is loud (``ReplicationError``), never a silently diverged
index. Delta snapshots and WAL group commit ride the same invariants.
"""
import json
import os
import threading
import time

import jax
import numpy as np
import pytest

import faults
from repro import persist
from repro.persist import io as pio
from repro.persist import wal as wal_mod
from repro.persist.snapshot import _manifest_crc
from repro.engine import EngineConfig, ShardedEngine
from repro.serving import NotPrimary, ServingLoop
from test_persist import (apply_ops, assert_same_results, mk_engine,
                          scripted_ops, _queries, D)


def _transport(kind, tmp_path):
    if kind == "dir":
        return persist.DirTransport(str(tmp_path / "ship"))
    return persist.PipeTransport()


def _pair(tmp_path, kind="pipe"):
    """(primary, shipper, standby, replica, transport) ready to stream."""
    pdir = str(tmp_path / "primary")
    primary = mk_engine()
    persist.ensure_attached(primary, pdir)
    transport = _transport(kind, tmp_path)
    shipper = persist.WALShipper(primary, pdir, transport)
    standby = mk_engine()
    replica = persist.StandbyReplica(standby, transport)
    return primary, shipper, standby, replica, transport


# ---------------------------------------------------------------------------
# ship -> replay bit-identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["dir", "pipe"])
def test_ship_replay_bit_identity(tmp_path, kind):
    primary, shipper, standby, replica, _ = _pair(tmp_path, kind)
    ops = scripted_ops(6)
    for i, op in enumerate(ops):
        apply_ops(primary, [op])
        shipper.ship_once()
        replica.poll_once()
        assert replica.applied_seq == i + 1
    # staged AND fused paths agree bit-for-bit with the primary
    assert_same_results(primary, standby, _queries())
    assert replica.records_replayed == len(ops)
    assert replica.lag() == persist.ReplicationLag(0, 0.0)


def test_standby_serves_reads_while_lagging(tmp_path):
    primary, shipper, standby, replica, _ = _pair(tmp_path)
    ops = scripted_ops(4)
    apply_ops(primary, ops[:2])
    shipper.ship_once()
    replica.poll_once()
    want = standby.search(_queries(), 8)  # the prefix the standby holds
    apply_ops(primary, ops[2:])
    shipper.ship_once()  # shipped but NOT yet polled: standby lags
    lag = replica.lag()
    assert lag.seqs == 2 and lag.seconds >= 0.0
    # reads keep serving the applied prefix exactly — never an error, never
    # a half-applied state
    r = standby.search(_queries(), 8)
    np.testing.assert_array_equal(np.asarray(r.ids), np.asarray(want.ids))
    replica.poll_once()
    assert replica.lag() == persist.ReplicationLag(0, 0.0)
    assert_same_results(primary, standby, _queries())


def test_duplicate_delivery_is_idempotent(tmp_path):
    primary, shipper, standby, replica, transport = _pair(tmp_path)
    apply_ops(primary, scripted_ops(4))
    shipper.ship_once()
    replica.poll_once()
    want = standby.search(_queries(), 8)
    # duplicated segments: forget both sides' dedup state so every segment
    # is re-published and re-fetched — replay must skip exactly
    shipper._published.clear()
    shipper.ship_once()
    replica._seen.clear()
    assert replica.poll_once() == 0  # all records <= applied_seq
    r = standby.search(_queries(), 8)
    np.testing.assert_array_equal(np.asarray(r.ids), np.asarray(want.ids))


def test_dropped_segment_is_loud(tmp_path):
    primary, shipper, _standby, _replica, transport = _pair(tmp_path, "dir")
    for op in scripted_ops(4):
        apply_ops(primary, [op])
        shipper.ship_once()  # one segment per op (each ship rotates)
    names = transport.list_segments()
    assert len(names) >= 3
    os.remove(os.path.join(transport.directory, "seg-" + names[1]))
    fresh = persist.StandbyReplica(mk_engine(), transport)
    with pytest.raises(persist.ReplicationError, match="gap"):
        fresh.poll_once()


def test_torn_and_flipped_frames_are_loud(tmp_path):
    primary, shipper, _s, _r, transport = _pair(tmp_path, "dir")
    apply_ops(primary, scripted_ops(2))
    shipper.ship_once()
    name = transport.list_segments()[0]
    seg_path = os.path.join(transport.directory, "seg-" + name)
    pristine = pio.read_bytes(seg_path)
    # torn frame (lost tail in flight)
    faults.truncate_file(seg_path, 0.6)
    with pytest.raises(persist.ReplicationError):
        persist.StandbyReplica(mk_engine(), transport).poll_once()
    # bit flip anywhere: frame header, payload, or an inner WAL record —
    # every layer is checksummed, so each lands on a typed error
    for seed in range(4):
        pio.write_bytes(seg_path, pristine)
        faults.flip_byte_in(seg_path, seed=seed)
        with pytest.raises((persist.ReplicationError,
                            persist.CorruptWALError)):
            persist.StandbyReplica(mk_engine(), transport).poll_once()


class _FlakyTransport:
    """Wraps a transport; fails the first ``n_fail`` publish/fetch calls."""

    def __init__(self, inner, n_fail):
        self.inner = inner
        self.fails_left = n_fail
        self.attempts = 0

    def _maybe_fail(self):
        self.attempts += 1
        if self.fails_left > 0:
            self.fails_left -= 1
            raise OSError("simulated transport outage")

    def publish(self, name, data, *, term):
        self._maybe_fail()
        self.inner.publish(name, data, term=term)

    def fetch(self, name):
        self._maybe_fail()
        return self.inner.fetch(name)

    def __getattr__(self, attr):
        return getattr(self.inner, attr)


def test_transport_retry_bounded_then_loud(tmp_path):
    pdir = str(tmp_path / "p")
    primary = mk_engine()
    persist.ensure_attached(primary, pdir)
    apply_ops(primary, scripted_ops(2))
    # transient outage inside the budget: retried to success
    flaky = _FlakyTransport(persist.PipeTransport(), n_fail=2)
    shipper = persist.WALShipper(primary, pdir, flaky, max_retries=3,
                                 backoff_s=0.001)
    assert shipper.ship_once() == 1
    replica = persist.StandbyReplica(mk_engine(), flaky)
    flaky.fails_left = 2
    assert replica.poll_once() == 2
    # outage past the budget: loud, and the segment is NOT marked shipped
    apply_ops(primary, scripted_ops(2, seed=29))
    flaky.fails_left = 99
    with pytest.raises(persist.ReplicationError, match="attempts"):
        shipper.ship_once()
    flaky.fails_left = 0
    assert shipper.ship_once() == 1  # healed transport catches up exactly
    assert replica.poll_once() == 2
    assert_same_results(primary, replica.engine, _queries())


# ---------------------------------------------------------------------------
# fenced failover
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["dir", "pipe"])
def test_fenced_failover_acked_prefix_exactly(tmp_path, kind):
    """The acceptance drill, in-process: primary dies mid-stream, standby
    promotes, and its answers equal a from-scratch rebuild over exactly
    the acked (shipped) prefix — staged and fused paths."""
    primary, shipper, standby, replica, transport = _pair(tmp_path, kind)
    primary._wal.guard = persist.make_fence_guard(transport, 0)
    ops = scripted_ops(6)
    acked = 4  # primary "dies" with 2 ops logged locally but never shipped
    apply_ops(primary, ops[:acked])
    shipper.ship_once()
    replica.poll_once()
    apply_ops(primary, ops[acked:])  # logged, never shipped: not acked
    new_term = replica.promote(str(tmp_path / "standby"))
    assert new_term == 1
    # the promoted replica == from-scratch rebuild over ops[:acked]
    rebuild = mk_engine()
    apply_ops(rebuild, ops[:acked])
    assert_same_results(rebuild, standby, _queries())
    # the old primary is fenced on its next ship AND its next append
    with pytest.raises(persist.FencedError):
        shipper.ship_once()
    with pytest.raises(persist.FencedError):
        primary.upsert(np.array([9000]), np.zeros((1, D), np.float32))
    # while the promoted primary is writable, durable, and re-recoverable
    standby.upsert(np.array([9001, 9002]),
                   np.ones((2, D), np.float32))
    rec, info = persist.open_engine(str(tmp_path / "standby"), attach=False)
    assert info.term == 1 and info.wal_seq == acked and info.replayed == 1
    assert_same_results(standby, rec, _queries())


def test_promotion_race_loses_loudly(tmp_path):
    primary, shipper, standby, replica, transport = _pair(tmp_path)
    apply_ops(primary, scripted_ops(2))
    shipper.ship_once()
    replica.poll_once()
    loser = persist.StandbyReplica(mk_engine(), transport)
    loser.poll_once()
    assert replica.promote(str(tmp_path / "win")) == 1
    with pytest.raises(persist.FencedError):
        loser.promote(str(tmp_path / "lose"), term=1)
    # the loser stayed a consistent follower: no WAL attached, no manifest
    assert getattr(loser.engine, "_wal", None) is None
    assert not os.path.exists(os.path.join(str(tmp_path / "lose"),
                                           persist.MANIFEST_NAME))


def test_ship_skips_live_file_under_concurrent_rotation(tmp_path):
    """A checkpoint-thread rotation can land between the shipper's own
    rotate and its directory listing, so a file that did not exist a
    moment ago is the LIVE file when the shipper walks the chain. It must
    never ship (or mark published) a live file — the segment is picked up
    whole once it closes, and the standby sees no gap and no torn tail."""
    pdir = str(tmp_path / "p")
    primary = mk_engine()
    persist.ensure_attached(primary, pdir)
    transport = persist.PipeTransport()
    shipper = persist.WALShipper(primary, pdir, transport)
    ops = scripted_ops(6)
    apply_ops(primary, ops[:2])
    orig_wal_files = wal_mod.wal_files
    raced = []

    def racy_wal_files(directory):
        if raced:
            return orig_wal_files(directory)
        # fires inside ship_once, after its rotate: a concurrent
        # checkpoint closes the file the shipper just opened and leaves a
        # NEWER live file mid-append in the listing it is about to walk
        raced.append(True)
        apply_ops(primary, ops[2:4])
        primary._wal.rotate(directory)
        apply_ops(primary, ops[4:5])  # seq 5: in the new live file
        return orig_wal_files(directory)

    wal_mod.wal_files = racy_wal_files
    try:
        shipper.ship_once()
    finally:
        wal_mod.wal_files = orig_wal_files
    apply_ops(primary, ops[5:])  # seq 6 lands in that same live file
    shipper.ship_once()          # rotation closes it; it ships complete
    replica = persist.StandbyReplica(mk_engine(), transport)
    assert replica.poll_once() == len(ops)  # no gap, nothing torn
    assert replica.applied_seq == len(ops)
    assert_same_results(primary, replica.engine, _queries())


def test_stale_term_records_ignored_via_term_chart(tmp_path):
    """The publish-side fence is check-then-act, so a deposed primary's
    in-flight publish can still LAND after a promotion. The term-scoped
    segment namespace means it can never collide with a new-term segment,
    and the term chart proves its records stale — followers skip them
    (``records_stale``) and keep following the live chain, including a
    fresh follower bootstrapping over the full multi-term history."""
    primary, shipper, standby, replica, transport = _pair(tmp_path)
    pdir = str(tmp_path / "primary")
    ops = scripted_ops(6)
    apply_ops(primary, ops[:4])
    shipper.ship_once()
    replica.poll_once()  # standby applied seqs 1-4
    new_term = replica.promote(str(tmp_path / "win"))  # chain starts at 5
    assert new_term == 1 and transport.term_chart() == [(1, 5)]
    # the deposed primary logs 2 more ops (seqs 5-6) and its publish slips
    # through the TOCTOU window: inject the term-0 segment directly
    apply_ops(primary, ops[4:])
    primary._wal.rotate(pdir)
    stale_path = dict(wal_mod.wal_files(pdir))[5]
    transport._segments[
        persist.ship_segment_name(0, os.path.basename(stale_path))] = (
            persist.encode_ship_frame(0, 5, pio.read_bytes(stale_path)))
    # the winner writes seq 5 under term 1 and ships it
    rng = np.random.default_rng(13)
    standby.upsert(np.arange(5000, 5010),
                   rng.normal(size=(10, D)).astype(np.float32))
    win_shipper = persist.WALShipper(standby, str(tmp_path / "win"),
                                     transport, term=1)
    assert win_shipper.ship_once() == 1
    # names are term-scoped: the stale segment sorts BEFORE the winner's
    names = transport.list_segments()
    assert [persist.parse_ship_name(n)[0] for n in names] == [0, 0, 1]
    # a fresh follower over the whole history: old term's acked prefix is
    # applied, the stale leftovers are skipped, the new chain continues
    follower = persist.StandbyReplica(mk_engine(), transport)
    assert follower.poll_once() == 5
    assert follower.records_stale == 2 and follower.applied_seq == 5
    assert_same_results(standby, follower.engine, _queries())


def test_sharded_standby_both_drivers_and_promotion(tmp_path):
    pdir = str(tmp_path / "p")
    primary = ShardedEngine(mk_engine(EngineConfig(nprobe=6, rerank_mult=2)),
                            2)
    persist.ensure_attached(primary, pdir)
    transport = persist.PipeTransport()
    shipper = persist.WALShipper(primary, pdir, transport)
    standby = ShardedEngine(mk_engine(EngineConfig(nprobe=6, rerank_mult=2)),
                            2)
    replica = persist.StandbyReplica(standby, transport)
    ops = scripted_ops(5)
    apply_ops(primary, ops)
    shipper.ship_once()
    replica.poll_once()
    q = _queries()
    assert_same_results(primary, standby, q, calls=("search",))  # vmap
    new_term = replica.promote(str(tmp_path / "s"))
    rec, info = persist.open_engine(str(tmp_path / "s"), attach=False)
    assert isinstance(rec, ShardedEngine) and info.term == new_term
    assert_same_results(standby, rec, q, calls=("search",))
    # shard_map driver: 1-shard pair on the device mesh
    p1 = ShardedEngine(mk_engine(EngineConfig(nprobe=6, rerank_mult=2)), 1)
    persist.ensure_attached(p1, str(tmp_path / "p1"))
    t1 = persist.PipeTransport()
    sh1 = persist.WALShipper(p1, str(tmp_path / "p1"), t1)
    s1 = ShardedEngine(mk_engine(EngineConfig(nprobe=6, rerank_mult=2)), 1)
    r1 = persist.StandbyReplica(s1, t1)
    apply_ops(p1, scripted_ops(3))
    sh1.ship_once()
    r1.poll_once()
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("shards",))
    ra, rb = p1.search(q, 8, mesh=mesh), s1.search(q, 8, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(ra.ids), np.asarray(rb.ids))
    np.testing.assert_array_equal(np.asarray(ra.dists), np.asarray(rb.dists))


# ---------------------------------------------------------------------------
# ServingLoop roles
# ---------------------------------------------------------------------------

def _wait_for(pred, timeout=10.0, every=0.01):
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        if pred():
            return True
        time.sleep(every)
    return False


def test_serving_loop_pair_follows_and_sheds_writes(tmp_path):
    transport = persist.PipeTransport()
    pl = ServingLoop(mk_engine(), snapshot_dir=str(tmp_path / "p"),
                     transport=transport, ship_every=0.01,
                     snapshot_every=60.0).start()
    sl = ServingLoop(mk_engine(), role="standby", transport=transport,
                     snapshot_dir=str(tmp_path / "s"),
                     poll_every=0.01).start()
    try:
        rng = np.random.default_rng(3)
        for op in scripted_ops(3):
            apply_ops(pl, [op])  # loop.upsert/delete/compact delegate
        with pytest.raises(NotPrimary):
            sl.upsert(np.array([1]), rng.normal(size=(1, D)).astype(np.float32))
        with pytest.raises(NotPrimary):
            sl.delete(np.array([1]))
        with pytest.raises(NotPrimary):
            sl.compact()
        assert _wait_for(lambda: sl.metrics().records_replayed == 3)
        q = np.asarray(_queries())
        ra = pl.submit(q[0], k=8).result(10)
        rb = sl.submit(q[0], k=8).result(10)
        np.testing.assert_array_equal(ra.ids, rb.ids)
        np.testing.assert_array_equal(ra.dists, rb.dists)
        mp, ms = pl.metrics(), sl.metrics()
        assert mp.role == "primary" and mp.segments_shipped >= 1
        assert ms.role == "standby" and ms.records_replayed == 3
        assert ms.replication_lag_seqs == 0
    finally:
        sl.close()
        pl.close()


def test_serving_loop_failover_detection_and_promote(tmp_path):
    transport = persist.PipeTransport()
    pl = ServingLoop(mk_engine(), snapshot_dir=str(tmp_path / "p"),
                     transport=transport, ship_every=0.01,
                     snapshot_every=60.0).start()
    promoted = []
    sl = ServingLoop(mk_engine(), role="standby", transport=transport,
                     snapshot_dir=str(tmp_path / "s"), poll_every=0.01,
                     heartbeat_timeout=0.25,
                     on_failover=lambda loop: promoted.append(
                         loop.promote())).start()
    try:
        rng = np.random.default_rng(5)
        pl.upsert(np.arange(2000, 2020),
                  rng.normal(size=(20, D)).astype(np.float32))
        assert _wait_for(lambda: sl.metrics().records_replayed == 1)
        q = np.asarray(_queries())
        want = sl.submit(q[0], k=8).result(10)
        pl.stop()  # primary goes silent: heartbeats cease (kill-9 analogue)
        assert _wait_for(lambda: bool(promoted)), "failover never fired"
        assert promoted == [1] and sl.role == "primary"
        # standby reads never errored through the transition, and the
        # promoted loop serves the same prefix then accepts writes
        got = sl.submit(q[0], k=8).result(10)
        np.testing.assert_array_equal(want.ids, got.ids)
        sl.upsert(np.arange(3000, 3010),
                  rng.normal(size=(10, D)).astype(np.float32))
        assert _wait_for(lambda: sl.metrics().segments_shipped >= 1)
        m = sl.metrics()
        assert m.term == 1
        # a promoted loop IS the primary: lag vs its OWN heartbeats (with
        # applied_seq frozen at the promotion point) must read 0, not grow
        assert (m.replication_lag_seqs, m.replication_lag_s) == (0, 0.0)
        assert sl.replication_lag() == persist.ReplicationLag(0, 0.0)
        # the deposed loop's writes are fenced
        with pytest.raises(persist.FencedError):
            pl.upsert(np.array([1]), rng.normal(size=(1, D)).astype(np.float32))
    finally:
        sl.close()
        pl.close()


def test_promote_lost_race_resumes_standby(tmp_path):
    """A promote() that loses the term race must leave the loop a REAL
    standby: the replay thread resumes and keeps following the winner's
    stream (not silently serving an ever-staler prefix)."""
    transport = persist.PipeTransport()
    pl = ServingLoop(mk_engine(), snapshot_dir=str(tmp_path / "p"),
                     transport=transport, ship_every=0.01,
                     snapshot_every=60.0).start()
    sl = ServingLoop(mk_engine(), role="standby", transport=transport,
                     snapshot_dir=str(tmp_path / "s"),
                     poll_every=0.01).start()
    try:
        rng = np.random.default_rng(11)
        pl.upsert(np.arange(4000, 4010),
                  rng.normal(size=(10, D)).astype(np.float32))
        assert _wait_for(lambda: sl.metrics().records_replayed == 1)

        def lose(directory, **kw):  # deterministic lost race
            raise persist.FencedError("a newer promotion won the race")

        orig_promote = sl._replica.promote
        sl._replica.promote = lose
        try:
            with pytest.raises(persist.FencedError):
                sl.promote()
        finally:
            sl._replica.promote = orig_promote
        assert sl.role == "standby"
        assert sl._replay_thread is not None and sl._replay_thread.is_alive()
        with pytest.raises(NotPrimary):
            sl.delete(np.array([1]))
        pl.upsert(np.arange(4100, 4110),
                  rng.normal(size=(10, D)).astype(np.float32))
        assert _wait_for(lambda: sl.metrics().records_replayed == 2)
    finally:
        sl.close()
        pl.close()


def test_failover_fires_without_any_primary_heartbeat():
    """A primary that dies before ever writing a heartbeat (or whose
    heartbeat file vanished) is still a failed primary: silence is
    measured from standby start, not only from an existing heartbeat."""
    transport = persist.PipeTransport()
    fired = []
    sl = ServingLoop(mk_engine(), role="standby", transport=transport,
                     poll_every=0.01, heartbeat_timeout=0.2,
                     on_failover=lambda loop: fired.append(
                         time.monotonic())).start()
    try:
        assert transport.read_heartbeat("primary") is None
        assert _wait_for(lambda: bool(fired)), \
            "failover never fired without a heartbeat file"
    finally:
        sl.close()


def test_loop_close_idempotent_joins_threads_and_flushes(tmp_path):
    """The historical close()-vs-checkpoint race: every background thread
    must be joined no matter how stop/close interleave, and the WAL's
    group-commit tail must hit disk."""
    eng = mk_engine()
    loop = ServingLoop(eng, snapshot_dir=str(tmp_path / "d"),
                       snapshot_every=0.01).start()
    # swap in a group-commit writer mid-flight to leave a pending fsync
    eng._wal.fsync_interval = 3600.0
    apply_ops(loop, scripted_ops(3))
    loop.close()
    loop.close()  # idempotent
    loop.stop()   # and in either order
    assert loop._thread is None and loop._ckpt_thread is None
    assert not [t for t in threading.enumerate()
                if t.name.startswith("repro-")]
    assert eng._wal._pending_fsync == 0  # flushed on close
    rec, info = persist.open_engine(str(tmp_path / "d"), attach=False)
    assert info.last_seq == 3
    assert_same_results(eng, rec, _queries())


# ---------------------------------------------------------------------------
# delta snapshots
# ---------------------------------------------------------------------------

def test_delta_snapshot_reuses_unchanged_segments(tmp_path):
    d = str(tmp_path / "d")
    eng = mk_engine()
    persist.ensure_attached(eng, d)  # snap 1: full (no parent)
    m1 = persist.read_manifest(d)
    assert m1["parent"] is None and m1["delta"]["segments_reused"] == 0
    eng.delete(np.arange(100, 120))  # delete-only interval
    m2 = persist.save_snapshot(eng, d)
    assert m2["parent"] == m1["snapshot"]
    # centroids/codebook/codes/base never changed: referenced, not rewritten
    assert m2["delta"]["segments_reused"] >= 3
    assert m2["delta"]["bytes_reused"] > m2["delta"]["bytes_written"]
    reused = [e["file"] for e in m2["segments"].values()
              if e["file"].startswith(m1["snapshot"])]
    assert reused, "no segment referenced from the parent snapshot"
    # the parent dir survives GC (reachable chain) and recovery is exact
    assert os.path.isdir(os.path.join(d, m1["snapshot"]))
    rec, _ = persist.open_engine(d, attach=False)
    assert_same_results(eng, rec, _queries())


def test_delta_gc_drops_unreachable_chain(tmp_path):
    d = str(tmp_path / "d")
    eng = mk_engine()
    persist.ensure_attached(eng, d)
    for i in range(3):  # three delete-only deltas onto the same parent
        eng.delete(np.arange(200 + 20 * i, 200 + 20 * i + 10))
        persist.save_snapshot(eng, d)
    snaps = sorted(n for n in os.listdir(d) if n.startswith("snap-"))
    manifest = persist.read_manifest(d)
    # intermediate delta-only snapshots are unreachable once superseded;
    # the full parent stays because current segments still point into it
    assert manifest["snapshot"] in snaps and "snap-000001" in snaps
    assert len(snaps) <= 3  # never the full 4-snapshot history
    assert "snap-000002" not in snaps and "snap-000003" not in snaps
    # a compact rewrites the list store (codes/ids/sizes) but the immutable
    # payloads (centroids/codebook/base) keep riding the original parent —
    # long-lived base segments are the POINT of delta snapshots
    eng.compact()
    m = persist.save_snapshot(eng, d)
    written = {k for k, e in m["segments"].items()
               if e["file"].startswith(m["snapshot"])}
    assert {"codes", "ids", "sizes"} <= written
    reused_from_parent = {k for k, e in m["segments"].items()
                          if e["file"].startswith("snap-000001")}
    assert {"centroids", "codebook", "base"} <= reused_from_parent
    assert os.path.isdir(os.path.join(d, "snap-000001"))
    rec, _ = persist.open_engine(d, attach=False)
    assert_same_results(eng, rec, _queries())


def test_schema1_manifest_migrates_gracefully(tmp_path):
    d = str(tmp_path / "d")
    eng = mk_engine()
    persist.ensure_attached(eng, d)
    apply_ops(eng, scripted_ops(2))
    persist.save_snapshot(eng, d)
    # rewrite the manifest as a pre-replication schema-1 file
    path = os.path.join(d, persist.MANIFEST_NAME)
    manifest = json.loads(pio.read_bytes(path).decode("utf-8"))
    for k in ("term", "parent", "delta"):
        manifest.pop(k, None)
    manifest["schema"] = 1
    del manifest["manifest_crc"]
    manifest["manifest_crc"] = _manifest_crc(manifest)
    pio.atomic_write_bytes(path, json.dumps(manifest).encode("utf-8"))
    back = persist.read_manifest(d)
    assert back["term"] == 0 and back["parent"] is None
    rec, info = persist.open_engine(d, attach=False)
    assert info.term == 0
    assert_same_results(eng, rec, _queries())


def test_snapshot_crash_sweep_with_delta_parent(tmp_path):
    """Crash at every write inside a DELTA checkpoint: the old manifest +
    WAL chain still recover the full pre-crash state (the delta machinery
    adds reads of the parent, never a window where the old chain is
    gone)."""
    eng0, d0 = mk_engine(), str(tmp_path / "count")
    persist.ensure_attached(eng0, d0)
    apply_ops(eng0, scripted_ops(2))
    persist.save_snapshot(eng0, d0)
    eng0.delete(np.arange(300, 320))
    with faults.FaultInjector() as counter:
        persist.save_snapshot(eng0, d0)
    q = _queries()
    want = eng0.search(q, 8)
    for n in range(1, counter.writes + 1):
        eng, d = mk_engine(), str(tmp_path / f"ck{n}")
        persist.ensure_attached(eng, d)
        apply_ops(eng, scripted_ops(2))
        persist.save_snapshot(eng, d)
        eng.delete(np.arange(300, 320))
        with faults.FaultInjector(crash_at_write=n):
            with pytest.raises(faults.SimulatedCrash):
                persist.save_snapshot(eng, d)
        rec, _ = persist.open_engine(d, attach=False)
        r = rec.search(q, 8)
        np.testing.assert_array_equal(np.asarray(r.dists),
                                      np.asarray(want.dists),
                                      err_msg=f"crash at write {n}")
        np.testing.assert_array_equal(np.asarray(r.ids),
                                      np.asarray(want.ids))


# ---------------------------------------------------------------------------
# WAL group commit
# ---------------------------------------------------------------------------

def test_group_commit_defers_fsyncs_and_flushes_on_rotate(tmp_path):
    fsyncs = []
    orig = pio.fsync_file
    pio.fsync_file = lambda f: (fsyncs.append(1), orig(f))[1]
    try:
        w = persist.WALWriter(str(tmp_path / persist.wal_name(1)), 1,
                              fsync_interval=3600.0)
        for i in range(5):
            w.log_delete(np.array([i]))
        assert not fsyncs and w._pending_fsync == 5
        w.flush()
        assert len(fsyncs) == 1 and w._pending_fsync == 0
        w.log_delete(np.array([9]))
        path1 = w.path
        w.rotate(str(tmp_path))  # closed segments are always fully durable
        assert len(fsyncs) == 2 and w._pending_fsync == 0
        recs, _valid, clean = wal_mod.scan_wal(path1)
        assert clean and [r.seq for r in recs] == [1, 2, 3, 4, 5, 6]
        w.log_delete(np.array([10]))
        w.close()  # close flushes too
        assert w._pending_fsync == 0
    finally:
        pio.fsync_file = orig
    assert [r.seq for r in persist.iter_wal(str(tmp_path))] == list(range(1, 8))


def test_group_commit_interval_elapses(tmp_path):
    w = persist.WALWriter(str(tmp_path / persist.wal_name(1)), 1,
                          fsync_interval=0.0)  # every append qualifies
    w.log_delete(np.array([1]))
    assert w._pending_fsync == 0  # interval 0 -> fsync each append
    w.close()


def test_group_commit_engine_recovery_after_flush(tmp_path):
    d = str(tmp_path / "d")
    eng = mk_engine()
    persist.ensure_attached(eng, d)
    # replace the attached writer with a group-commit one at the same seq
    eng._wal.close()
    eng.attach_wal(persist.WALWriter(eng._wal.path, eng._wal.last_seq + 1,
                                     fsync_interval=3600.0))
    ops = scripted_ops(4)
    apply_ops(eng, ops)
    eng._wal.flush()
    rec, info = persist.open_engine(d, attach=False)
    assert info.last_seq == len(ops)
    assert_same_results(eng, rec, _queries())


def test_group_commit_torn_tail_is_prefix(tmp_path):
    """A crash before the deferred fsync may lose the un-flushed suffix —
    but only the suffix, and recovery stays prefix-exact (writes happen in
    seq order through the same seam)."""
    d = str(tmp_path / "d")
    eng = mk_engine()
    persist.ensure_attached(eng, d)
    eng._wal.close()
    eng.attach_wal(persist.WALWriter(eng._wal.path, 1,
                                     fsync_interval=3600.0))
    ops = scripted_ops(4)
    apply_ops(eng, ops[:2])
    eng._wal.flush()  # acked through seq 2
    apply_ops(eng, ops[2:])  # in the page cache, not yet fsync'd
    # simulate the OS dropping the un-flushed tail at the crash point
    wal_path = eng._wal.path
    eng._wal.close()
    recs, valid_through_2, _ = wal_mod.scan_wal(wal_path)
    # keep only what was durable at the last flush: seqs 1-2
    flushed_end = (wal_mod.FILE_HEADER_SIZE
                   + sum(len(wal_mod.encode_record(r.seq, r.op, r.arrays))
                         for r in recs[:2]))
    with open(wal_path, "r+b") as f:
        f.truncate(flushed_end)
    ref = mk_engine()
    apply_ops(ref, ops[:2])
    rec, info = persist.open_engine(d, attach=False)
    assert info.last_seq == 2
    assert_same_results(ref, rec, _queries())


# ---------------------------------------------------------------------------
# WAL file headers / terms
# ---------------------------------------------------------------------------

def test_wal_file_header_terms_and_legacy(tmp_path):
    p = str(tmp_path / persist.wal_name(1))
    w = persist.WALWriter(p, 1, term=7)
    w.log_delete(np.array([1]))
    w.close()
    assert persist.wal_term(p) == 7
    recs, _v, clean = wal_mod.scan_wal(p)
    assert clean and recs[0].seq == 1
    # legacy headerless file (pre-replication format) still parses, term 0
    legacy = str(tmp_path / persist.wal_name(2))
    with open(legacy, "wb") as f:
        f.write(wal_mod.encode_record(2, "delete",
                                      {"ids": np.array([2], np.int64)}))
    assert persist.wal_term(legacy) == 0
    recs2, _v2, clean2 = wal_mod.scan_wal(legacy)
    assert clean2 and recs2[0].seq == 2
    assert [r.seq for r in persist.iter_wal(str(tmp_path))] == [1, 2]
    # a torn header (crash between header write and first append) is an
    # empty torn file, not corruption
    torn = str(tmp_path / persist.wal_name(3))
    with open(torn, "wb") as f:
        f.write(wal_mod.encode_file_header(1, 3)[:10])
    recs3, valid3, clean3 = wal_mod.scan_wal(torn)
    assert recs3 == [] and valid3 == 0 and not clean3
    # a COMPLETE header with a flipped byte is loud
    bad = str(tmp_path / "wal-000000000099.log")
    with open(bad, "wb") as f:
        f.write(wal_mod.encode_file_header(1, 99))
    faults.flip_byte_in(bad, offset=5)
    with pytest.raises(persist.CorruptWALError):
        wal_mod.scan_wal(bad)
