"""Filtered & namespaced search: bitmap helpers, oracle parity, isolation.

The contract under test is docs/filtering.md: a filtered search returns
exactly what an unfiltered search over only the passing rows would return —
the stream kernels' in-VMEM predicate masking must be bit-identical to the
gathered post-filter oracle at every selectivity; namespaces must confine a
query to its own lists end to end (single host, sharded, serving). Integer
ADC accumulation is exact, so scan comparisons are ``assert_array_equal``.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ivf
from repro.core.lists import (ListStore, build_lists, filter_from_attrs,
                              filter_pass_sizes, filter_words,
                              pack_filter_mask, partition_filter,
                              round_robin_perm, unpack_filter_mask)
from repro.core.pq import PQCodebook
from repro.core.topk import gather_ids, masked_topk
from repro.engine import EngineConfig, SearchEngine, ShardedEngine
from repro.engine.engine import fused_cache_size

SELECTIVITIES = (0.0, 0.01, 0.5, 1.0)


def _synth_index(nlist, cap, m, *, d=None, seed=0, occupancy="ragged"):
    """IVFIndex from raw random arrays (same shape contract as the build)."""
    d = d or 4 * m
    rng = np.random.default_rng(seed)
    if isinstance(occupancy, str):
        sizes = (np.full(nlist, cap) if occupancy == "full"
                 else rng.integers(0, cap + 1, nlist))
    else:
        sizes = np.asarray(occupancy)
    codes = np.zeros((nlist, cap, m // 2), np.uint8)
    ids = np.full((nlist, cap), -1, np.int32)
    nxt = 0
    for li in range(nlist):
        s = int(sizes[li])
        codes[li, :s] = rng.integers(0, 256, (s, m // 2), np.uint8)
        ids[li, :s] = np.arange(nxt, nxt + s, dtype=np.int32)
        nxt += s
    index = ivf.IVFIndex(
        centroids=jnp.asarray(rng.normal(size=(nlist, d)).astype(np.float32)),
        codebook=PQCodebook(jnp.asarray(
            rng.normal(size=(m, 16, d // m)).astype(np.float32))),
        lists=ListStore(codes=jnp.asarray(codes), ids=jnp.asarray(ids),
                        sizes=jnp.asarray(sizes.astype(np.int32))),
    )
    base = rng.normal(size=(max(nxt, 1), d)).astype(np.float32)
    return index, jnp.asarray(base)


def _queries(index, q, seed=1):
    rng = np.random.default_rng(seed)
    d = index.centroids.shape[1]
    return jnp.asarray(rng.normal(size=(q, d)).astype(np.float32))


def _random_mask(index, selectivity, seed=7):
    """(nlist, cap) bool predicate over occupied slots only."""
    rng = np.random.default_rng(seed)
    nlist, cap = index.lists.ids.shape
    mask = rng.random((nlist, cap)) < selectivity
    return mask & np.asarray(index.lists.ids >= 0)


def _oracle_select(index, q, probes, mask, keep):
    """Gathered scan -> post-filter -> masked top-keep: the reference."""
    dg, ig = ivf.scan_probes(index, q, probes, impl="ref")
    ok = jnp.asarray(mask)[jnp.maximum(probes, 0)] & (ig >= 0)
    dg = jnp.where(ok, dg, jnp.inf).reshape(q.shape[0], -1)
    ig = jnp.where(ok, ig, -1).reshape(q.shape[0], -1)
    vals, pos = masked_topk(dg, ig >= 0, keep)
    return vals, gather_ids(ig, pos)


# ---------------------------------------------------------------------------
# bitmap helpers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cap", [1, 7, 8, 9, 129, 512])
def test_pack_unpack_roundtrip(cap):
    rng = np.random.default_rng(cap)
    mask = jnp.asarray(rng.random((5, cap)) < 0.5)
    bits = pack_filter_mask(mask)
    assert bits.dtype == jnp.uint8
    assert bits.shape == (5, filter_words(cap))
    np.testing.assert_array_equal(np.asarray(unpack_filter_mask(bits, cap)),
                                  np.asarray(mask))


def test_bit_layout_lsb_first():
    # slot w*8 + j  <->  bit j of word w
    mask = np.zeros((1, 16), bool)
    mask[0, 0] = True   # word 0 bit 0
    mask[0, 9] = True   # word 1 bit 1
    bits = np.asarray(pack_filter_mask(jnp.asarray(mask)))
    assert bits[0, 0] == 1 and bits[0, 1] == 2


def test_padded_slot_bits_are_zero_via_filter_from_attrs():
    rng = np.random.default_rng(0)
    n, nlist, cap = 50, 4, 32
    assign = rng.integers(0, nlist, n)
    packed = rng.integers(0, 256, (n, 2), np.uint8)
    attrs = rng.integers(0, 100, n).astype(np.int32)
    store = build_lists(assign, packed, nlist=nlist, cap=cap, attrs=attrs)
    assert store.attrs is not None and store.attrs.shape == (nlist, cap)
    bits = filter_from_attrs(store, lambda a: a >= 0)  # passes every real row
    got = np.asarray(unpack_filter_mask(bits, cap))
    np.testing.assert_array_equal(got, np.asarray(store.ids >= 0))
    # a store built without attrs refuses loudly
    bare = build_lists(assign, packed, nlist=nlist, cap=cap)
    with pytest.raises(ValueError):
        filter_from_attrs(bare, lambda a: a >= 0)


def test_filter_pass_sizes_ignores_stale_bits_past_occupancy():
    index, _ = _synth_index(6, 40, 4, occupancy="ragged")
    all_ones = pack_filter_mask(jnp.ones_like(index.lists.ids, dtype=bool))
    np.testing.assert_array_equal(
        np.asarray(filter_pass_sizes(index.lists, all_ones)),
        np.asarray(index.lists.sizes))


def test_partition_filter_matches_round_robin_layout():
    nlist, cap, shards = 10, 24, 4  # non-divisible -> padded layout
    rng = np.random.default_rng(3)
    bits = pack_filter_mask(jnp.asarray(rng.random((nlist, cap)) < 0.5))
    sharded = np.asarray(partition_filter(bits, shards))
    l = -(-nlist // shards)
    assert sharded.shape == (shards, l, filter_words(cap))
    perm = round_robin_perm(nlist, shards)
    flat = sharded.reshape(shards * l, -1)
    for padded_pos, global_list in enumerate(perm):
        if global_list < nlist:
            np.testing.assert_array_equal(flat[padded_pos],
                                          np.asarray(bits)[global_list])
        else:
            assert not flat[padded_pos].any()  # padding passes nothing


# ---------------------------------------------------------------------------
# stream-kernel parity vs the post-filter oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("selectivity", SELECTIVITIES)
def test_stream_scan_filter_parity_vs_oracle(selectivity):
    index, _ = _synth_index(12, 96, 4, occupancy="full", seed=2)
    q = _queries(index, 4)
    probes = jnp.asarray(
        np.random.default_rng(5).integers(0, 12, (4, 5)).astype(np.int32))
    mask = _random_mask(index, selectivity)
    fb = pack_filter_mask(jnp.asarray(mask))
    keep = 20
    ds, ids_s = ivf.scan_probes_stream(index, q, probes, keep=keep, tile_n=32,
                                       filter_bits=fb)
    vals_s, pos_s = masked_topk(ds, ids_s >= 0, keep)
    got_ids = gather_ids(ids_s, pos_s)
    want_vals, want_ids = _oracle_select(index, q, probes, mask, keep)
    np.testing.assert_array_equal(np.asarray(got_ids), np.asarray(want_ids))
    np.testing.assert_array_equal(np.asarray(vals_s), np.asarray(want_vals))


def test_filter_with_ragged_occupancy_and_invalid_probes():
    # filters must compose with occupancy padding AND -1 probes
    index, _ = _synth_index(10, 64, 4, occupancy="ragged", seed=9)
    q = _queries(index, 3)
    probes = jnp.asarray(np.array([[0, 3, -1, 7], [9, -1, -1, 2],
                                   [-1, -1, -1, -1]], np.int32))
    mask = _random_mask(index, 0.5, seed=11)
    fb = pack_filter_mask(jnp.asarray(mask))
    keep = 12
    ds, ids_s = ivf.scan_probes_stream(index, q, probes, keep=keep, tile_n=16,
                                       filter_bits=fb)
    vals_s, pos_s = masked_topk(ds, ids_s >= 0, keep)
    got_ids = gather_ids(ids_s, pos_s)
    want_vals, want_ids = _oracle_select(index, q, probes, mask, keep)
    np.testing.assert_array_equal(np.asarray(got_ids), np.asarray(want_ids))
    np.testing.assert_array_equal(np.asarray(vals_s), np.asarray(want_vals))


def test_all_filtered_lists_return_only_sentinels():
    index, _ = _synth_index(8, 48, 4, occupancy="full", seed=4)
    q = _queries(index, 2)
    probes = jnp.asarray(np.array([[0, 1, 2], [3, 4, 5]], np.int32))
    fb = pack_filter_mask(jnp.zeros_like(index.lists.ids, dtype=bool))
    ds, ids_s = ivf.scan_probes_stream(index, q, probes, keep=10, tile_n=16,
                                       filter_bits=fb)
    assert np.all(np.asarray(ids_s) == -1)


# ---------------------------------------------------------------------------
# engine end to end: stream engine == gathered-oracle engine, jit == staged
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _engines():
    rng = np.random.default_rng(0)
    base = rng.standard_normal((1500, 32)).astype(np.float32)
    train = rng.standard_normal((1500, 32)).astype(np.float32)
    key = jax.random.PRNGKey(0)
    mk = lambda impl: SearchEngine.build(
        key, train, base, m=8, nlist=16,
        config=EngineConfig(nprobe=6, rerank_mult=4, scan_impl=impl))
    return mk("stream"), mk("ref"), base


@pytest.mark.parametrize("selectivity", SELECTIVITIES)
def test_engine_filtered_search_parity(selectivity):
    eng_s, eng_g, _ = _engines()
    q = _queries(eng_s.index, 5, seed=8)
    mask = _random_mask(eng_s.index, selectivity, seed=13)
    fb = pack_filter_mask(jnp.asarray(mask))
    rs = eng_s.search(q, 10, filter_bits=fb)
    rg = eng_g.search(q, 10, filter_bits=fb)
    np.testing.assert_array_equal(np.asarray(rs.ids), np.asarray(rg.ids))
    np.testing.assert_allclose(np.asarray(rs.dists), np.asarray(rg.dists),
                               rtol=1e-6)
    # every surfaced id passes the predicate
    passing = set()
    ids_np, mk = np.asarray(eng_s.index.lists.ids), mask
    for li in range(ids_np.shape[0]):
        for sl in range(ids_np.shape[1]):
            if ids_np[li, sl] >= 0 and mk[li, sl]:
                passing.add(int(ids_np[li, sl]))
    for gid in np.asarray(rs.ids).ravel():
        assert gid < 0 or gid in passing
    # rows_filtered counts the complement of the pass set over probed lists
    rf = np.asarray(rs.stats.rows_filtered)
    if selectivity == 1.0:
        np.testing.assert_array_equal(rf, 0)
    else:
        assert (rf > 0).all()


def test_all_ones_filter_bit_identical_to_unfiltered():
    eng_s, _, _ = _engines()
    q = _queries(eng_s.index, 4, seed=21)
    fb = pack_filter_mask(eng_s.index.lists.ids >= 0)
    r_f = eng_s.search(q, 10, filter_bits=fb)
    r_u = eng_s.search(q, 10)
    np.testing.assert_array_equal(np.asarray(r_f.ids), np.asarray(r_u.ids))
    np.testing.assert_array_equal(np.asarray(r_f.dists), np.asarray(r_u.dists))
    np.testing.assert_array_equal(np.asarray(r_f.stats.rows_filtered), 0)


def test_search_jit_filter_is_traced_not_static():
    eng_s, _, _ = _engines()
    q = _queries(eng_s.index, 3, seed=30)
    fb1 = pack_filter_mask(jnp.asarray(_random_mask(eng_s.index, 0.5, seed=1)))
    fb2 = pack_filter_mask(jnp.asarray(_random_mask(eng_s.index, 0.3, seed=2)))
    r1 = eng_s.search_jit(q, 10, filter_bits=fb1)
    n0 = fused_cache_size()
    r2 = eng_s.search_jit(q, 10, filter_bits=fb2)  # new VALUES, same shapes
    assert fused_cache_size() == n0, "filter values must not recompile"
    e1 = eng_s.search(q, 10, filter_bits=fb1)
    e2 = eng_s.search(q, 10, filter_bits=fb2)
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(e1.ids))
    np.testing.assert_array_equal(np.asarray(r2.ids), np.asarray(e2.ids))


def test_filter_shape_validation():
    eng_s, _, _ = _engines()
    q = _queries(eng_s.index, 1)
    with pytest.raises(ValueError, match="filter_bits"):
        eng_s.search(q, 5, filter_bits=jnp.zeros((3, 2), jnp.uint8))
    with pytest.raises(ValueError, match="namespace"):
        eng_s.search(q, 5, namespaces=jnp.zeros((1,), jnp.int32))


# ---------------------------------------------------------------------------
# namespaces: single host + ShardedEngine, both drivers
# ---------------------------------------------------------------------------

def _ns_setup():
    rng = np.random.default_rng(17)
    base = rng.standard_normal((1200, 32)).astype(np.float32)
    train = rng.standard_normal((1200, 32)).astype(np.float32)
    member = np.zeros((2, 12), bool)
    member[0, :6] = True
    member[1, 6:] = True
    eng = SearchEngine.build(
        jax.random.PRNGKey(1), train, base, m=8, nlist=12,
        config=EngineConfig(nprobe=4, rerank_mult=4, scan_impl="stream"),
        namespaces=jnp.asarray(member))
    ids_np = np.asarray(eng.index.lists.ids)
    owner = np.full(1200, -1)
    for li in range(12):
        for sl in range(ids_np.shape[1]):
            if ids_np[li, sl] >= 0:
                owner[ids_np[li, sl]] = 0 if li < 6 else 1
    q = _queries(eng.index, 5, seed=23)
    ns = jnp.asarray([0, 1, -1, 0, 1], jnp.int32)
    return eng, owner, q, ns


def _assert_isolated(ids, ns, owner):
    for qi, t in enumerate(np.asarray(ns)):
        for gid in np.asarray(ids)[qi]:
            if gid >= 0 and t >= 0:
                assert owner[gid] == t, f"namespace leak: q{qi} got {gid}"


def test_namespace_isolation_single_host():
    eng, owner, q, ns = _ns_setup()
    r = eng.search(q, 10, namespaces=ns)
    rj = eng.search_jit(q, 10, namespaces=ns)
    _assert_isolated(r.ids, ns, owner)
    np.testing.assert_array_equal(np.asarray(r.ids), np.asarray(rj.ids))
    # unrestricted query is bit-identical to a namespace-free search
    r_free = eng.search(q, 10)
    np.testing.assert_array_equal(np.asarray(r.ids[2]),
                                  np.asarray(r_free.ids[2]))


@pytest.mark.parametrize("num_shards", [1, 3])
def test_namespace_isolation_sharded_vmap(num_shards):
    eng, owner, q, ns = _ns_setup()
    sh = ShardedEngine(eng, num_shards)
    r = sh.search(q, 10, namespaces=ns)
    _assert_isolated(r.ids, ns, owner)
    # filter composes on top of namespaces in the sharded path too
    mask = _random_mask(eng.index, 0.5, seed=31)
    fb = pack_filter_mask(jnp.asarray(mask))
    rc = sh.search(q, 10, namespaces=ns, filter_bits=fb)
    _assert_isolated(rc.ids, ns, owner)
    assert (np.asarray(rc.stats.rows_filtered) > 0).all()


def test_namespace_isolation_sharded_shard_map():
    eng, owner, q, ns = _ns_setup()
    sh = ShardedEngine(eng, 1)  # one shard per device; CI has one device
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("shards",))
    mask = _random_mask(eng.index, 0.5, seed=37)
    fb = pack_filter_mask(jnp.asarray(mask))
    rm = sh.search(q, 10, namespaces=ns, filter_bits=fb, mesh=mesh)
    rv = sh.search(q, 10, namespaces=ns, filter_bits=fb)
    _assert_isolated(rm.ids, ns, owner)
    np.testing.assert_array_equal(np.asarray(rm.ids), np.asarray(rv.ids))
    np.testing.assert_array_equal(np.asarray(rm.stats.rows_filtered),
                                  np.asarray(rv.stats.rows_filtered))


def test_sharded_unfiltered_unchanged_by_namespace_support():
    # building a ShardedEngine from a namespace-capable engine and searching
    # without namespaces must match a namespace-free engine exactly
    eng, _, q, _ = _ns_setup()
    sh = ShardedEngine(eng, 3)
    bare = SearchEngine(eng.index, base=None if eng.base is None else eng.base,
                        config=eng.config)
    sh_bare = ShardedEngine(bare, 3)
    r1 = sh.search(q, 10)
    r2 = sh_bare.search(q, 10)
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    np.testing.assert_array_equal(np.asarray(r1.dists), np.asarray(r2.dists))


# ---------------------------------------------------------------------------
# serving: per-request namespaces + rows_filtered accounting
# ---------------------------------------------------------------------------

def test_serving_namespaces_and_filter_accounting():
    from repro.serving import ServingLoop

    eng, owner, _, _ = _ns_setup()
    mask = _random_mask(eng.index, 0.5, seed=41)
    fb = pack_filter_mask(jnp.asarray(mask))
    loop = ServingLoop(eng, buckets=(1, 4), filter_bits=fb)
    loop.start(warmup=True)
    try:
        compiles0 = loop.metrics().compiles
        rng = np.random.default_rng(43)
        futs = [loop.submit(rng.standard_normal(32).astype(np.float32), k=10,
                            tenant=f"t{i % 2}", namespace=i % 2)
                for i in range(6)]
        results = [f.result(timeout=60) for f in futs]
        assert loop.metrics().compiles == compiles0, \
            "filtered/namespaced steady-state traffic recompiled"
        for i, r in enumerate(results):
            assert r.rows_filtered > 0
            for gid in r.ids:
                if gid >= 0:
                    assert owner[gid] == i % 2
                    assert mask.ravel()[
                        np.flatnonzero(
                            np.asarray(eng.index.lists.ids).ravel() == gid)[0]]
        for t in ("t0", "t1"):
            st = loop.stats.get(t)
            assert st.queries == 3 and st.rows_filtered > 0
        with pytest.raises(ValueError, match="out of range"):
            loop.submit(np.zeros(32, np.float32), namespace=99)
    finally:
        loop.stop()
