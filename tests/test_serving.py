"""Serving-layer tests: bucket padding, fused-vs-staged bit-identity,
recompile discipline across mixed batch sizes, per-tenant accounting, and
construction-time config validation."""
import functools
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import vectors
from repro.engine import (EngineConfig, SearchEngine, fused_cache_size,
                          validate_config)
from repro.serving import (Batcher, ServingLoop, StatsRegistry, bucket_for,
                           pad_to_bucket)


@functools.lru_cache(maxsize=None)
def small_ds():
    return vectors.make_sift_like(n=5000, nt=2000, nq=32, d=32, ncl=32, seed=3)


@functools.lru_cache(maxsize=None)
def small_engine():
    ds = small_ds()
    return SearchEngine.build(jax.random.PRNGKey(0), ds.train, ds.base,
                              m=8, nlist=32, coarse_iters=6, pq_iters=6)


def make_loop(**kw):
    kw.setdefault("rerank_mult", 2)
    kw.setdefault("max_wait_s", 0.005)
    return ServingLoop(small_engine(), **kw)


# ---------------------------------------------------------------------------
# fused single-jit pipeline == staged pipeline, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("coarse", ["flat", "hnsw", "tree"])
def test_search_jit_bit_identical_to_staged(coarse):
    ds = small_ds()
    eng = SearchEngine(small_engine().index, base=ds.base, coarse=coarse,
                       hnsw_m=8, ef_construction=32)
    for r in (0, 3):
        staged = eng.search(ds.queries, 10, nprobe=6, rerank_mult=r)
        fused = eng.search_jit(ds.queries, 10, nprobe=6, rerank_mult=r)
        np.testing.assert_array_equal(np.asarray(staged.ids),
                                      np.asarray(fused.ids))
        np.testing.assert_array_equal(np.asarray(staged.dists),
                                      np.asarray(fused.dists))
        for s, f in zip(staged.stats, fused.stats):
            np.testing.assert_array_equal(np.asarray(s), np.asarray(f))


def test_search_jit_reuses_compile_across_engines_same_shapes():
    """The fused jit cache is process-wide: a second engine with identical
    static knobs and array shapes adds zero compiles."""
    ds = small_ds()
    eng1 = small_engine()
    eng1.search_jit(ds.queries, 10, nprobe=6)
    c0 = fused_cache_size()
    # same build key => identical array shapes (list cap depends on the
    # k-means assignment); a different key may change cap and legitimately
    # need its own compile
    eng2 = SearchEngine.build(jax.random.PRNGKey(0), ds.train, ds.base,
                              m=8, nlist=32, coarse_iters=6, pq_iters=6)
    eng2.search_jit(ds.queries, 10, nprobe=6)
    assert fused_cache_size() == c0


# ---------------------------------------------------------------------------
# bucket padding
# ---------------------------------------------------------------------------

def test_bucket_for_picks_smallest_fitting():
    assert bucket_for(1) == 1
    assert bucket_for(2) == 8
    assert bucket_for(8) == 8
    assert bucket_for(9) == 32
    assert bucket_for(128) == 128
    with pytest.raises(ValueError, match="exceeds"):
        bucket_for(129)


def test_pad_to_bucket_shapes_and_content():
    q = np.arange(6, dtype=np.float32).reshape(3, 2)
    out = pad_to_bucket(q, 8)
    assert out.shape == (8, 2) and out.dtype == np.float32
    np.testing.assert_array_equal(out[:3], q)
    np.testing.assert_array_equal(out[3:], 0)
    with pytest.raises(ValueError, match="fit"):
        pad_to_bucket(q, 2)


def test_padded_queries_never_leak_into_results():
    """3 requests -> bucket 8: results are exactly the 3 direct-search rows;
    the 5 zero-pad rows influence nothing and reach no caller."""
    ds, eng = small_ds(), small_engine()
    loop = make_loop()
    loop.start(warmup=True)
    try:
        futs = [loop.submit(ds.queries[i], k=10) for i in range(3)]
        got = [f.result(timeout=30) for f in futs]
    finally:
        loop.stop()
    direct = eng.search(ds.queries[:3], 10, rerank_mult=2)
    for i, r in enumerate(got):
        np.testing.assert_array_equal(r.ids, np.asarray(direct.ids)[i])
        np.testing.assert_array_equal(r.dists, np.asarray(direct.dists)[i])
    m = loop.metrics()
    assert m.rows_served == 3
    assert m.batches == 1 and m.bucket_counts == {8: 1}
    assert m.rows_padded == 5
    total_rows = sum(s.queries for s in loop.stats.snapshot().values())
    assert total_rows == 3  # accounting sees real rows only


def test_mixed_k_requests_never_share_a_batch():
    ds = small_ds()
    loop = make_loop()
    loop.start(warmup=True)
    try:
        f_a = loop.submit(ds.queries[0], k=10)
        f_b = loop.submit(ds.queries[1], k=5)
        ra, rb = f_a.result(timeout=30), f_b.result(timeout=30)
    finally:
        loop.stop()
    assert ra.ids.shape == (10,) and rb.ids.shape == (5,)
    assert loop.metrics().batches == 2


# ---------------------------------------------------------------------------
# recompile discipline
# ---------------------------------------------------------------------------

def test_mixed_sizes_compile_at_most_once_per_bucket():
    """A ragged stream (sizes 1..20 interleaved) through the batcher triggers
    at most one fused compile per shape bucket, asserted via the jit cache."""
    ds = small_ds()
    loop = make_loop(max_wait_s=0.02)
    loop.start()  # no warmup: we count the organic compiles
    c0 = fused_cache_size()
    try:
        futs = []
        for burst in (1, 7, 20, 2, 1, 15, 8):
            for i in range(burst):
                futs.append(loop.submit(ds.queries[i % 32], k=10))
            time.sleep(0.03)  # let each burst form its own batch
        for f in futs:
            f.result(timeout=60)
    finally:
        loop.stop()
    m = loop.metrics()
    buckets_used = set(m.bucket_counts)
    assert fused_cache_size() - c0 <= len(buckets_used)
    assert buckets_used <= set(loop.batcher.buckets)


def test_warmup_precompiles_all_buckets():
    loop = make_loop()
    c0 = fused_cache_size()
    loop.start(warmup=True)
    try:
        warm = fused_cache_size() - c0
        assert warm <= len(loop.batcher.buckets)
        ds = small_ds()
        futs = [loop.submit(ds.queries[i % 32], k=10) for i in range(40)]
        for f in futs:
            f.result(timeout=60)
        assert fused_cache_size() - c0 == warm  # steady state: no new compiles
    finally:
        loop.stop()


# ---------------------------------------------------------------------------
# batcher mechanics
# ---------------------------------------------------------------------------

def test_batcher_groups_fifo_and_caps_at_largest_bucket():
    b = Batcher(buckets=(1, 4), max_wait_s=0.0)
    for i in range(6):
        b.submit(np.zeros(3, np.float32) + i, k=10)
    first = b.next_batch(timeout=1)
    second = b.next_batch(timeout=1)
    assert [int(r.query[0]) for r in first] == [0, 1, 2, 3]
    assert [int(r.query[0]) for r in second] == [4, 5]
    assert b.next_batch(timeout=0.01) is None


def test_batcher_waits_for_coriders_until_deadline():
    b = Batcher(buckets=(1, 8), max_wait_s=0.2)
    b.submit(np.zeros(3, np.float32), k=10)

    def late_submit():
        time.sleep(0.05)
        b.submit(np.ones(3, np.float32), k=10)

    t = threading.Thread(target=late_submit)
    t.start()
    batch = b.next_batch(timeout=2)
    t.join()
    assert len(batch) == 2  # the late request caught the open window


def test_batcher_rejects_bad_input():
    b = Batcher()
    with pytest.raises(ValueError, match="single"):
        b.submit(np.zeros((2, 3), np.float32))
    with pytest.raises(ValueError, match="k must be"):
        b.submit(np.zeros(3, np.float32), k=0)
    with pytest.raises(ValueError, match="ascending"):
        Batcher(buckets=(8, 1))
    b.close()
    with pytest.raises(RuntimeError, match="closed"):
        b.submit(np.zeros(3, np.float32))


# ---------------------------------------------------------------------------
# per-tenant accounting
# ---------------------------------------------------------------------------

def test_tenant_stats_aggregate_per_caller():
    """Tenant aggregates must equal the per-query stats of a direct search
    over the same rows, bucketed by tenant."""
    ds, eng = small_ds(), small_engine()
    loop = make_loop()
    loop.start(warmup=True)
    tenants = [("alice", "bob")[i % 2] for i in range(10)]
    try:
        futs = [loop.submit(ds.queries[i], k=10, tenant=t)
                for i, t in enumerate(tenants)]
        for f in futs:
            f.result(timeout=30)
    finally:
        loop.stop()
    direct = eng.search(ds.queries[:10], 10, rerank_mult=2)
    lp = np.asarray(direct.stats.lists_probed)
    cs = np.asarray(direct.stats.codes_scanned)
    rr = np.asarray(direct.stats.reranked)
    snap = loop.stats.snapshot()
    for tenant in ("alice", "bob"):
        rows = [i for i, t in enumerate(tenants) if t == tenant]
        st = snap[tenant]
        assert st.queries == len(rows)
        assert st.lists_probed == int(lp[rows].sum())
        assert st.codes_scanned == int(cs[rows].sum())
        assert st.reranked == int(rr[rows].sum())
        assert st.latency_max_s >= st.mean_latency_s > 0


def test_stats_registry_thread_safety_and_snapshot_isolation():
    reg = StatsRegistry()
    one = np.ones(1, np.int32)

    def hammer(tenant):
        for _ in range(200):
            reg.record_batch([tenant], one, one, one, [0.001])

    threads = [threading.Thread(target=hammer, args=(f"t{i % 2}",))
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = reg.snapshot()
    assert snap["t0"].queries == snap["t1"].queries == 400
    snap["t0"].queries = -1  # mutating a snapshot must not touch the registry
    assert reg.get("t0").queries == 400


# ---------------------------------------------------------------------------
# construction-time config validation
# ---------------------------------------------------------------------------

def test_ef_with_non_hnsw_coarse_raises_at_construction():
    eng = small_engine()
    with pytest.raises(ValueError, match="ef"):
        SearchEngine(eng.index, config=EngineConfig(ef=128))  # flat coarse
    # same knob with hnsw coarse is fine
    SearchEngine(eng.index, coarse="hnsw", hnsw_m=8, ef_construction=32,
                 config=EngineConfig(ef=128))


def test_rerank_without_base_raises_at_build_not_first_search():
    ds = small_ds()
    with pytest.raises(ValueError, match="rerank_mult"):
        SearchEngine.build(jax.random.PRNGKey(0), ds.train, ds.base,
                           m=8, nlist=32, coarse_iters=2, pq_iters=2,
                           keep_base=False,
                           config=EngineConfig(rerank_mult=4))


@pytest.mark.parametrize("bad", [
    EngineConfig(nprobe=0),
    EngineConfig(rerank_mult=-1),
    EngineConfig(scan_impl="simd"),
    EngineConfig(ef=0),
])
def test_invalid_config_knobs_raise(bad):
    with pytest.raises(ValueError):
        validate_config(bad, coarse_kind="hnsw", has_base=True)


def test_serving_loop_rejects_rerank_without_base():
    eng = small_engine()
    bare = SearchEngine(eng.index, base=None)
    with pytest.raises(ValueError, match="base"):
        ServingLoop(bare, rerank_mult=2)


# ---------------------------------------------------------------------------
# loop robustness
# ---------------------------------------------------------------------------

def test_wrong_dim_submit_fails_alone_not_the_batch():
    """A wrong-D query is rejected at submit; co-riders are unaffected."""
    ds = small_ds()
    loop = make_loop()
    loop.start(warmup=True)
    try:
        good = loop.submit(ds.queries[0], k=10)
        with pytest.raises(ValueError, match="does not match engine dim"):
            loop.submit(np.zeros(7, np.float32), k=10)
        assert good.result(timeout=30).ids.shape == (10,)
    finally:
        loop.stop()


def test_loop_restart_after_stop_serves_again():
    ds = small_ds()
    loop = make_loop()
    loop.start(warmup=True)
    loop.submit(ds.queries[0], k=10).result(timeout=30)
    loop.stop()
    with pytest.raises(RuntimeError, match="not running"):
        loop.submit(ds.queries[0], k=10)
    loop.start()
    try:
        res = loop.submit(ds.queries[1], k=10).result(timeout=30)
        assert res.ids.shape == (10,)
        assert loop.metrics().rows_served == 2
    finally:
        loop.stop()


def test_loop_compiles_metric_ignores_other_engines():
    """Per-loop compile attribution: another engine compiling a new shape in
    the shared process-wide cache must not show up in this loop's metrics."""
    ds = small_ds()
    loop = make_loop()
    loop.start(warmup=True)
    try:
        c_loop = loop.metrics().compiles
        small_engine().search_jit(ds.queries[:5], 3, nprobe=2)  # foreign compile
        assert loop.metrics().compiles == c_loop
    finally:
        loop.stop()


def test_auto_scan_impl_warmup_absorbs_autotune_and_stays_flat():
    """The docs/serving.md contract for scan_impl='auto': warmup runs the
    kernel autotune micro-sweep per bucket signature, and steady-state
    traffic adds neither compiles nor autotune sweeps. Also: results through
    the loop are identical to the ref-impl engine's."""
    from repro.kernels import ops

    ds = small_ds()
    eng_auto = SearchEngine(small_engine().index, base=ds.base,
                            config=EngineConfig(scan_impl="auto"))
    ops.clear_autotune_cache()
    try:
        loop = ServingLoop(eng_auto, rerank_mult=2, buckets=(1, 4),
                           max_wait_s=0.005)
        loop.start(warmup=True)
        try:
            m0 = loop.metrics()
            assert m0.autotuned > 0  # warmup resolved each bucket's signature
            futs = [loop.submit(np.asarray(ds.queries[i]), k=10)
                    for i in range(6)]
            res = [f.result(timeout=60) for f in futs]
            m1 = loop.metrics()
            assert m1.compiles == m0.compiles
            assert m1.autotuned == m0.autotuned  # flat after warmup
        finally:
            loop.stop()
        want = small_engine().search(ds.queries[:6], 10, nprobe=8,
                                     rerank_mult=2)
        got_ids = np.stack([r.ids for r in res])
        np.testing.assert_array_equal(got_ids, np.asarray(want.ids))
    finally:
        ops.clear_autotune_cache()

# ---------------------------------------------------------------------------
# overload shedding, deadlines, drain, dispatch hardening (docs/serving.md)
# ---------------------------------------------------------------------------

def _fresh_engine():
    """Private engine instance so tests can wrap its methods without
    poisoning the lru-cached shared one."""
    ds = small_ds()
    return ds, SearchEngine(small_engine().index, base=ds.base)


def _gate_engine(eng):
    """Wrap search_jit so every dispatch blocks on a gate; returns the gate
    and the list of batch sizes the engine actually saw."""
    gate = threading.Event()
    calls = []
    real = eng.search_jit

    def gated(q, k, **kw):
        calls.append(int(q.shape[0]))
        gate.wait(60)
        return real(q, k, **kw)

    eng.search_jit = gated
    return gate, calls


def _wait_queue_drained(loop, timeout=10.0):
    t0 = time.monotonic()
    while len(loop.batcher._queue) and time.monotonic() - t0 < timeout:
        time.sleep(0.005)
    assert not len(loop.batcher._queue), "dispatch never picked up the head"


def test_bounded_queue_sheds_with_typed_error():
    from repro.serving import Overloaded

    ds, eng = _fresh_engine()
    gate, _calls = _gate_engine(eng)
    loop = ServingLoop(eng, rerank_mult=2, buckets=(1,), max_wait_s=0.0,
                       max_pending=2)
    loop.start()
    try:
        f0 = loop.submit(ds.queries[0], k=10, tenant="flood")
        _wait_queue_drained(loop)  # f0 now stalls inside the engine
        f1 = loop.submit(ds.queries[1], k=10, tenant="flood")
        f2 = loop.submit(ds.queries[2], k=10, tenant="flood")
        with pytest.raises(Overloaded):
            loop.submit(ds.queries[3], k=10, tenant="flood")
        assert loop.metrics().rejects == 1
        assert loop.stats.get("flood").rejects == 1
        # shed request never holds a future; accepted ones all complete
        gate.set()
        for f in (f0, f1, f2):
            assert f.result(timeout=120).ids.shape == (10,)
        assert loop.stats.get("flood").queries == 3
    finally:
        gate.set()
        loop.stop()


def test_expired_deadline_never_reaches_the_engine():
    from repro.serving import DeadlineExceeded

    ds, eng = _fresh_engine()
    gate, calls = _gate_engine(eng)
    loop = ServingLoop(eng, rerank_mult=2, buckets=(1,), max_wait_s=0.0)
    loop.start()
    try:
        f0 = loop.submit(ds.queries[0], k=10)
        _wait_queue_drained(loop)  # dispatch now stalls holding f0
        f_dead = loop.submit(ds.queries[1], k=10, deadline_s=0.01)
        time.sleep(0.05)  # expires while queued behind the stalled batch
        gate.set()
        with pytest.raises(DeadlineExceeded):
            f_dead.result(timeout=60)
        assert f0.result(timeout=120).ids.shape == (10,)
        f2 = loop.submit(ds.queries[2], k=10)
        assert f2.result(timeout=60).ids.shape == (10,)
        # engine saw exactly the two live requests, never the expired one
        assert calls == [1, 1]
        assert loop.metrics().deadline_misses == 1
    finally:
        gate.set()
        loop.stop()


def test_engine_exception_fails_its_batch_only():
    """A dispatch-time engine failure resolves that batch's futures with the
    error and the loop keeps serving — regression for the dispatch thread
    dying and wedging every later caller."""
    ds, eng = _fresh_engine()
    real = eng.search_jit
    armed = [True]

    def flaky(q, k, **kw):
        if armed[0]:
            armed[0] = False
            raise RuntimeError("injected engine failure")
        return real(q, k, **kw)

    eng.search_jit = flaky
    loop = ServingLoop(eng, rerank_mult=2, buckets=(1,), max_wait_s=0.0)
    loop.start()
    try:
        f_bad = loop.submit(ds.queries[0], k=10)
        with pytest.raises(RuntimeError, match="injected engine failure"):
            f_bad.result(timeout=60)
        f_ok = loop.submit(ds.queries[1], k=10)
        assert f_ok.result(timeout=60).ids.shape == (10,)
        assert loop.metrics().batches == 1  # only the good dispatch counted
    finally:
        loop.stop()


def test_close_drains_pending_futures_with_typed_error():
    from repro.serving import LoopClosed

    ds, eng = _fresh_engine()
    gate, _calls = _gate_engine(eng)
    loop = ServingLoop(eng, rerank_mult=2, buckets=(1,), max_wait_s=0.0)
    loop.start()
    f0 = loop.submit(ds.queries[0], k=10)
    _wait_queue_drained(loop)
    f1 = loop.submit(ds.queries[1], k=10)
    f2 = loop.submit(ds.queries[2], k=10)
    loop.close(timeout=0.2)  # dispatch is stalled: queued work must drain
    for f in (f1, f2):
        with pytest.raises(LoopClosed):
            f.result(timeout=10)
    with pytest.raises(RuntimeError, match="not running"):
        loop.submit(ds.queries[3], k=10)
    gate.set()  # the in-flight batch still completes for its caller
    assert f0.result(timeout=120).ids.shape == (10,)


def test_batcher_bounded_queue_and_deadline_purge():
    from repro.serving import DeadlineExceeded, LoopClosed, Overloaded

    b = Batcher(buckets=(1,), max_wait_s=0.0, max_pending=1)
    b.submit(np.zeros(3, np.float32), k=10)
    with pytest.raises(Overloaded):
        b.submit(np.ones(3, np.float32), k=10)
    assert b.rejects == 1
    assert b.next_batch(timeout=0.01) is not None  # head still dispatchable
    # expired requests are purged at next_batch, never returned
    f = b.submit(np.zeros(3, np.float32), k=10, deadline_s=0.005)
    time.sleep(0.02)
    assert b.next_batch(timeout=0.01) is None
    assert b.deadline_misses == 1
    with pytest.raises(DeadlineExceeded):
        f.result(timeout=1)
    with pytest.raises(ValueError, match="deadline_s"):
        b.submit(np.zeros(3, np.float32), k=10, deadline_s=0.0)
    b.close()
    with pytest.raises(LoopClosed):
        b.submit(np.zeros(3, np.float32), k=10)
