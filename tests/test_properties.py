"""Property suite for the filter bitmap helpers and top-k reductions.

Hypothesis drives random widths/selectivities/splits (skipped gracefully
when the package is absent — see conftest); each property also has a
deterministic seed-swept twin so the tier-1 container exercises the same
oracles without hypothesis installed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as hst

from repro.core.lists import (build_lists, filter_from_attrs,
                              filter_pass_sizes, filter_words,
                              pack_filter_mask, unpack_filter_mask)
from repro.core.topk import distributed_topk, gather_ids, masked_topk

_SETTINGS = dict(max_examples=25, deadline=None,
                 suppress_health_check=[HealthCheck.too_slow,
                                        HealthCheck.data_too_large])


# ---------------------------------------------------------------------------
# pack/unpack round-trip
# ---------------------------------------------------------------------------

def _roundtrip(mask: np.ndarray):
    bits = pack_filter_mask(jnp.asarray(mask))
    assert bits.shape == (*mask.shape[:-1], filter_words(mask.shape[-1]))
    assert bits.dtype == jnp.uint8
    back = unpack_filter_mask(bits, mask.shape[-1])
    np.testing.assert_array_equal(np.asarray(back), mask)


@given(rows=hst.integers(1, 7), cap=hst.integers(1, 300),
       selectivity=hst.floats(0.0, 1.0), seed=hst.integers(0, 2**31 - 1))
@settings(**_SETTINGS)
def test_pack_unpack_roundtrip_property(rows, cap, selectivity, seed):
    rng = np.random.default_rng(seed)
    _roundtrip(rng.random((rows, cap)) < selectivity)


@pytest.mark.parametrize("seed", range(6))
def test_pack_unpack_roundtrip_seeds(seed):
    rng = np.random.default_rng(seed)
    rows = int(rng.integers(1, 7))
    cap = int(rng.integers(1, 300))
    _roundtrip(rng.random((rows, cap)) < rng.random())
    # degenerate widths: all-true / all-false at non-multiple-of-8 caps
    _roundtrip(np.ones((2, 8 * seed + 1), bool))
    _roundtrip(np.zeros((2, 8 * seed + 3), bool))


# ---------------------------------------------------------------------------
# filter_from_attrs vs the numpy predicate oracle
# ---------------------------------------------------------------------------

def _attrs_store(nlist, cap, seed):
    rng = np.random.default_rng(seed)
    sizes = rng.integers(0, cap + 1, nlist)
    n = max(int(sizes.sum()), 1)
    assign = np.repeat(np.arange(nlist), sizes)[:n]
    packed = rng.integers(0, 256, (assign.size, 2), np.uint8)
    attrs = rng.integers(0, 50, assign.size).astype(np.int32)
    return build_lists(assign, packed, nlist=nlist, cap=cap,
                       attrs=attrs), attrs


def _check_filter_from_attrs(nlist, cap, thresh, seed):
    store, _ = _attrs_store(nlist, cap, seed)
    bits = filter_from_attrs(store, lambda a: a < thresh)
    got = np.asarray(unpack_filter_mask(bits, cap))
    ids = np.asarray(store.ids)
    want = (np.asarray(store.attrs) < thresh) & (ids >= 0)
    np.testing.assert_array_equal(got, want)
    # pass-size accounting agrees with popcount over occupied slots
    np.testing.assert_array_equal(np.asarray(filter_pass_sizes(store, bits)),
                                  want.sum(axis=1))


@given(nlist=hst.integers(1, 12), cap=hst.integers(1, 64),
       thresh=hst.integers(0, 50), seed=hst.integers(0, 2**31 - 1))
@settings(**_SETTINGS)
def test_filter_from_attrs_oracle_property(nlist, cap, thresh, seed):
    _check_filter_from_attrs(nlist, cap, thresh, seed)


@pytest.mark.parametrize("seed", range(6))
def test_filter_from_attrs_oracle_seeds(seed):
    rng = np.random.default_rng(100 + seed)
    _check_filter_from_attrs(int(rng.integers(1, 12)),
                             int(rng.integers(1, 64)),
                             int(rng.integers(0, 50)), seed)


# ---------------------------------------------------------------------------
# masked_topk vs a stable-argsort numpy oracle (tie-break included)
# ---------------------------------------------------------------------------

def _check_masked_topk(d, valid, k):
    vals, pos = masked_topk(jnp.asarray(d), jnp.asarray(valid), k)
    vals, pos = np.asarray(vals), np.asarray(pos)
    for qi in range(d.shape[0]):
        dd = np.where(valid[qi], d[qi], np.inf)
        # lax.top_k prefers the lowest index among equal keys — exactly a
        # stable sort's order, which is the tie-break the engine's layout
        # identity rests on
        order = np.argsort(dd, kind="stable")[:k]
        want_vals = dd[order]
        want_pos = np.where(np.isfinite(want_vals), order, -1)
        np.testing.assert_array_equal(vals[qi], want_vals)
        np.testing.assert_array_equal(pos[qi], want_pos)


@given(n=hst.integers(1, 200), k=hst.integers(1, 32),
       dup=hst.integers(1, 6), selectivity=hst.floats(0.0, 1.0),
       seed=hst.integers(0, 2**31 - 1))
@settings(**_SETTINGS)
def test_masked_topk_tiebreak_property(n, k, dup, selectivity, seed):
    rng = np.random.default_rng(seed)
    k = min(k, n)
    # draw from `dup` distinct values so exact ties are common
    d = rng.integers(0, dup, (3, n)).astype(np.float32)
    valid = rng.random((3, n)) < selectivity
    _check_masked_topk(d, valid, k)


@pytest.mark.parametrize("seed", range(6))
def test_masked_topk_tiebreak_seeds(seed):
    rng = np.random.default_rng(200 + seed)
    n = int(rng.integers(5, 200))
    k = min(int(rng.integers(1, 32)), n)
    d = rng.integers(0, 4, (3, n)).astype(np.float32)
    valid = rng.random((3, n)) < rng.random()
    _check_masked_topk(d, valid, k)


def test_masked_topk_all_invalid_row():
    vals, pos = masked_topk(jnp.ones((1, 8)), jnp.zeros((1, 8), bool), 4)
    assert np.isinf(np.asarray(vals)).all()
    assert (np.asarray(pos) == -1).all()
    # gather_ids preserves the sentinel through the id map
    ids = gather_ids(jnp.arange(8)[None, :].astype(jnp.int32), pos)
    assert (np.asarray(ids) == -1).all()


# ---------------------------------------------------------------------------
# distributed_topk merge parity under random shard splits
# ---------------------------------------------------------------------------

def _check_distributed_merge(q, n, shards, k, seed):
    """Random per-shard candidate pools: the distributed merge must equal a
    single global top-k over the union (dists exactly; ids tie-aware)."""
    rng = np.random.default_rng(seed)
    d = rng.integers(0, 10_000, (shards, q, n)).astype(np.float32)
    ids = rng.permutation(shards * n).astype(np.int32).reshape(shards, n)
    ids = np.broadcast_to(ids[:, None, :], (shards, q, n)).copy()

    merged = jax.vmap(
        lambda dd, ii: distributed_topk(dd, ii, k, "sh"),
        axis_name="sh")(jnp.asarray(d), jnp.asarray(ids))
    mvals, mids = np.asarray(merged[0][0]), np.asarray(merged[1][0])

    flat_d = d.transpose(1, 0, 2).reshape(q, -1)
    flat_i = ids.transpose(1, 0, 2).reshape(q, -1)
    for qi in range(q):
        order = np.argsort(flat_d[qi], kind="stable")[:k]
        np.testing.assert_array_equal(mvals[qi], flat_d[qi][order])
        # ids within an exact-tie group may legally permute across shards
        want = flat_i[qi][order]
        for v in np.unique(flat_d[qi][order]):
            grp = flat_d[qi][order] == v
            assert sorted(mids[qi][grp].tolist()) == sorted(want[grp].tolist())


@given(q=hst.integers(1, 4), n=hst.integers(1, 64),
       shards=hst.integers(1, 6), k=hst.integers(1, 16),
       seed=hst.integers(0, 2**31 - 1))
@settings(**_SETTINGS)
def test_distributed_topk_random_splits_property(q, n, shards, k, seed):
    _check_distributed_merge(q, n, shards, min(k, n), seed)


@pytest.mark.parametrize("seed", range(6))
def test_distributed_topk_random_splits_seeds(seed):
    rng = np.random.default_rng(300 + seed)
    n = int(rng.integers(1, 64))
    _check_distributed_merge(int(rng.integers(1, 4)), n,
                             int(rng.integers(1, 6)),
                             min(int(rng.integers(1, 16)), n), seed)
