"""Training substrate tests: optimizer, checkpoint/restart, compression, loop."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.train import checkpoint as ckpt
from repro.train import grad_compress as gc
from repro.train import optimizer as opt
from repro.train import train_loop


def test_adamw_reduces_quadratic():
    cfg = opt.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                          weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init_state(params)
    for _ in range(60):
        grads = jax.tree.map(lambda p: 2 * p, params)  # d/dp p^2
        params, state, m = opt.apply_updates(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5
    assert m["lr"] > 0


def test_adamw_clips_gradients():
    cfg = opt.AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(4)}
    state = opt.init_state(params)
    _, _, m = opt.apply_updates(params, {"w": jnp.full(4, 100.0)}, state, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_cosine_schedule_shape():
    cfg = opt.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(opt.schedule(cfg, jnp.int32(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0.1 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.1)


def test_checkpoint_save_restore_roundtrip(tmp_path):
    state = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
             "nested": {"b": jnp.ones((4,), jnp.int32)}}
    d = str(tmp_path / "ckpt")
    ckpt.save(d, 3, state)
    ckpt.save(d, 7, jax.tree.map(lambda x: x + 1, state))
    assert ckpt.latest_step(d) == 7
    step, restored = ckpt.restore(d, state)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(state["a"]) + 1)


def test_checkpoint_keep_k(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in range(6):
        ckpt.save(d, s, {"x": jnp.zeros(1)}, keep=2)
    dirs = sorted(p for p in os.listdir(d) if p.startswith("step_"))
    assert dirs == ["step_00000004", "step_00000005"]


def test_checkpoint_restart_bitwise_exact(tmp_path):
    """Interrupt -> restart -> final state matches an uninterrupted run."""
    cfg = configs.get_smoke_config("qwen3_1p7b")
    kw = dict(steps=6, global_batch=2, seq_len=32, ckpt_every=3,
              log=lambda s: None)
    full_state, _ = train_loop.train(cfg, ckpt_dir=str(tmp_path / "a"), **kw)
    # interrupted run: first 3 steps only
    kw_i = dict(kw, steps=3)
    train_loop.train(cfg, ckpt_dir=str(tmp_path / "b"), **kw_i)
    # resume to 6
    resumed_state, _ = train_loop.train(cfg, ckpt_dir=str(tmp_path / "b"), **kw)
    for a, b in zip(jax.tree.leaves(full_state.params),
                    jax.tree.leaves(resumed_state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_training_reduces_loss():
    cfg = configs.get_smoke_config("qwen3_1p7b")
    _, hist = train_loop.train(cfg, steps=20, global_batch=4, seq_len=64,
                               log=lambda s: None)
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert last < first, f"loss did not drop: {first} -> {last}"


def test_microbatch_accumulation_matches_full_batch():
    cfg = configs.get_smoke_config("qwen3_1p7b")
    ocfg = opt.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    params = __import__("repro.models.model", fromlist=["x"]).init_lm(
        jax.random.PRNGKey(0), cfg)
    state = train_loop.TrainState(params, opt.init_state(params))
    from repro.data import tokens as tok
    batch = dict(tok.batch_at_step(
        tok.TokenPipelineConfig(vocab=cfg.vocab, seq_len=32, global_batch=4), 0
    )._asdict())
    s1, m1 = train_loop.make_train_step(cfg, ocfg, microbatches=1)(state, batch)
    s2, m2 = train_loop.make_train_step(cfg, ocfg, microbatches=2)(state, batch)
    # losses match to accumulation tolerance
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_straggler_watchdog():
    events = []
    w = train_loop.StragglerWatchdog(factor=2.0, on_straggler=lambda *a: events.append(a))
    for s in range(5):
        w.observe(s, 1.0)
    assert not events
    assert w.observe(5, 5.0)  # 5x the EMA
    assert events and events[0][0] == 5
    # EMA not poisoned by the outlier
    assert w.ema == pytest.approx(1.0)


def test_grad_compression_error_feedback():
    key = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(key, (256, 8)),
             "b": jax.random.normal(jax.random.PRNGKey(1), (64,))}
    err = gc.init_error(grads)
    codec = gc.PQGradCodec(dsub=4)
    dec, new_err, stats = gc.ef_step(key, grads, err, codec)
    assert stats["ratio"] > 4.0, f"compression ratio too low: {stats['ratio']}"
    # error feedback invariant: decoded + error == original (+ old error)
    for name in ("w", "b"):
        np.testing.assert_allclose(
            np.asarray(dec[name], np.float32) + np.asarray(new_err[name]),
            np.asarray(grads[name], np.float32), atol=1e-5)
    # compression is lossy but bounded
    rel = float(jnp.linalg.norm(dec["w"] - grads["w"]) / jnp.linalg.norm(grads["w"]))
    assert rel < 0.9


def test_grad_compression_ef_converges_on_quadratic():
    """SGD + EF-compressed grads still converges (the EF guarantee)."""
    key = jax.random.PRNGKey(0)
    w = jnp.asarray(np.random.default_rng(0).normal(size=(32, 4)).astype(np.float32))
    err = gc.init_error({"w": w})
    codec = gc.PQGradCodec(dsub=4, sample=128)
    params = {"w": w}
    for i in range(40):
        grads = {"w": 2 * params["w"]}
        dec, err, _ = gc.ef_step(jax.random.fold_in(key, i), grads, err, codec)
        params = {"w": params["w"] - 0.1 * dec["w"]}
    assert float(jnp.linalg.norm(params["w"])) < 0.2 * float(jnp.linalg.norm(w))
