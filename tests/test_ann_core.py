"""Behaviour tests for the ANN core: PQ, fast-scan, IVF, HNSW, top-k, metrics."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import coarse, fastscan, hnsw, ivf, metrics, pq, topk
from repro.core.kmeans import kmeans, pairwise_sqdist
from repro.data import vectors


@functools.lru_cache(maxsize=None)
def small_ds():
    return vectors.make_sift_like(n=20_000, nt=5_000, nq=64, d=32, ncl=64, seed=3)


# ---------------------------------------------------------------------------
# kmeans / PQ
# ---------------------------------------------------------------------------

def test_kmeans_reduces_inertia():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2000, 16))
    r1 = kmeans(key, x, k=16, iters=1)
    r2 = kmeans(key, x, k=16, iters=20)
    assert float(r2.inertia) < float(r1.inertia)
    assert r2.centroids.shape == (16, 16)


def test_pq_encode_decode_reduces_error_with_m():
    ds = small_ds()
    key = jax.random.PRNGKey(1)
    errs = []
    for m in (2, 8, 16):
        cb = pq.train_pq(key, ds.train, m=m, k=16, iters=10)
        codes = pq.encode(cb, ds.base[:2000])
        rec = pq.decode(cb, codes)
        errs.append(float(jnp.mean(jnp.sum((rec - ds.base[:2000]) ** 2, -1))))
    assert errs[0] > errs[1] > errs[2]


def test_adc_matches_reconstructed_distance():
    """ADC(q, code) == ||q - decode(code)||^2 exactly (paper Eq. (3))."""
    ds = small_ds()
    cb = pq.train_pq(jax.random.PRNGKey(2), ds.train, m=8, k=16, iters=8)
    codes = pq.encode(cb, ds.base[:512])
    q = ds.queries[:8]
    t = pq.adc_table(cb, q)
    adc = pq.adc_lookup(t, codes)  # (8, 512)
    rec = pq.decode(cb, codes)
    exact = pairwise_sqdist(q, rec)
    np.testing.assert_allclose(np.asarray(adc), np.asarray(exact), rtol=2e-3, atol=2e-1)


# ---------------------------------------------------------------------------
# fast-scan: recall parity with naive PQ (the paper's Fig. 2 claim)
# ---------------------------------------------------------------------------

def test_fastscan_recall_parity_with_naive_pq():
    ds = small_ds()
    m = 16
    idx = fastscan.build_index(jax.random.PRNGKey(4), ds.train, ds.base, m=m, iters=10)
    _, ids_fast = fastscan.search(idx, ds.queries, topk=10, impl="mxu")
    _, ids_naive = pq.search(idx.codebook, pq.encode(idx.codebook, ds.base),
                             ds.queries, topk=10)
    r_fast = float(metrics.recall_at_r(ids_fast, ds.gt_ids, r=10))
    r_naive = float(metrics.recall_at_r(ids_naive, ds.gt_ids, r=10))
    # same codes, same codebook; the only difference is u8 LUT quantization
    assert abs(r_fast - r_naive) < 0.05
    assert r_fast > 0.5  # sanity: clustered data, M=16 should retrieve well


def test_fastscan_impls_agree():
    ds = small_ds()
    idx = fastscan.build_index(jax.random.PRNGKey(5), ds.train, ds.base[:4096],
                               m=8, iters=8)
    d_sel = fastscan.compute_distances(idx, ds.queries[:4], impl="select")
    d_mxu = fastscan.compute_distances(idx, ds.queries[:4], impl="mxu")
    d_ref = fastscan.compute_distances(idx, ds.queries[:4], impl="ref")
    np.testing.assert_array_equal(np.asarray(d_sel), np.asarray(d_mxu))
    np.testing.assert_array_equal(np.asarray(d_sel), np.asarray(d_ref))


# ---------------------------------------------------------------------------
# top-k
# ---------------------------------------------------------------------------

@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(n=st.integers(10, 3000), k=st.integers(1, 10), seed=st.integers(0, 10**6))
def test_property_tournament_topk_matches_sort(n, k, seed):
    rng = np.random.default_rng(seed)
    d = jnp.asarray(rng.normal(size=(3, n)).astype(np.float32))
    vals, ids = topk.tournament_topk(d, k, block=256)
    want = np.sort(np.asarray(d), axis=1)[:, :k]
    np.testing.assert_allclose(np.asarray(vals), want, rtol=1e-6)
    got_by_id = np.take_along_axis(np.asarray(d), np.asarray(ids), axis=1)
    np.testing.assert_allclose(got_by_id, want, rtol=1e-6)


def test_masked_topk_ignores_invalid():
    d = jnp.asarray([[1.0, 0.5, 2.0, 0.1]])
    valid = jnp.asarray([[True, False, True, False]])
    vals, ids = topk.masked_topk(d, valid, 2)
    np.testing.assert_allclose(np.asarray(vals[0]), [1.0, 2.0])
    np.testing.assert_array_equal(np.asarray(ids[0]), [0, 2])


def test_distributed_topk_equals_global():
    """vmap-with-axis-name merge over a fake 4-shard axis == global top-k."""
    rng = np.random.default_rng(0)
    shards, q, n_local, k = 4, 8, 64, 5
    d = jnp.asarray(rng.normal(size=(shards, q, n_local)).astype(np.float32))
    # global ids: shard s owns [s*n_local, (s+1)*n_local)
    ids = jnp.broadcast_to(
        (jnp.arange(shards)[:, None, None] * n_local
         + jnp.arange(n_local)[None, None, :]).astype(jnp.int32),
        (shards, q, n_local))

    merged = jax.vmap(
        lambda dd, ii: topk.distributed_topk(dd, ii, k, axis_name="shards"),
        axis_name="shards")
    mv, mi = merged(d, ids)  # replicated across shards: (shards, Q, k)
    np.testing.assert_allclose(np.asarray(mv[0]), np.asarray(mv[1]))

    flat = np.transpose(np.asarray(d), (1, 0, 2)).reshape(q, -1)
    order = np.argsort(flat, axis=1)[:, :k]
    np.testing.assert_allclose(np.asarray(mv[0]),
                               np.take_along_axis(flat, order, axis=1), rtol=1e-6)
    # global id == position in the shard-major flat layout, per construction
    got = np.take_along_axis(flat, np.asarray(mi[0]), axis=1)
    np.testing.assert_allclose(np.sort(got, axis=1),
                               np.take_along_axis(flat, order, axis=1), rtol=1e-6)


# ---------------------------------------------------------------------------
# IVF
# ---------------------------------------------------------------------------

def test_ivf_recall_improves_with_nprobe():
    ds = small_ds()
    index = ivf.build_ivf(jax.random.PRNGKey(6), ds.train, ds.base, m=16,
                          nlist=64, coarse_iters=10, pq_iters=8)
    recalls = []
    for nprobe in (1, 4, 16):
        _, ids = ivf.search_ivf(index, ds.queries, nprobe=nprobe, topk=10)
        recalls.append(float(metrics.recall_at_r(ids, ds.gt_ids, r=10)))
    assert recalls[0] <= recalls[1] <= recalls[2] + 1e-6
    assert recalls[2] > 0.6


def test_ivf_padding_never_returned_for_valid_k():
    ds = small_ds()
    index = ivf.build_ivf(jax.random.PRNGKey(7), ds.train, ds.base[:5000], m=8,
                          nlist=32, coarse_iters=8, pq_iters=6)
    _, ids = ivf.search_ivf(index, ds.queries, nprobe=8, topk=10)
    assert int((np.asarray(ids) >= 0).sum()) == ids.size  # enough candidates


# ---------------------------------------------------------------------------
# HNSW
# ---------------------------------------------------------------------------

def test_hnsw_beats_random_and_matches_brute_force_mostly():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(2000, 24)).astype(np.float32)
    g = hnsw.build_hnsw(x, m=12, ef_construction=48, seed=0)
    q = jnp.asarray(rng.normal(size=(32, 24)).astype(np.float32))
    d, ids = hnsw.search_hnsw(g, q, ef=48, topk=1)
    exact = np.argmin(np.asarray(pairwise_sqdist(q, jnp.asarray(x))), axis=1)
    recall = float(np.mean(np.asarray(ids[:, 0]) == exact))
    assert recall >= 0.9, f"HNSW recall@1 too low: {recall}"


def test_hnsw_as_coarse_quantizer_pipeline():
    """Paper Table 1 pipeline: HNSW coarse + IVF fast-scan fine."""
    ds = small_ds()
    index = ivf.build_ivf(jax.random.PRNGKey(8), ds.train, ds.base, m=16,
                          nlist=64, coarse_iters=10, pq_iters=8)
    hc = coarse.build_hnsw_coarse(index.centroids, m=8, ef_construction=32)
    _, probe_ids = hc.search(ds.queries, nprobe=8)
    _, ids = ivf.search_ivf_precomputed_probes(index, ds.queries, probe_ids,
                                               nprobe=8, topk=10)
    r = float(metrics.recall_at_r(ids, ds.gt_ids, r=10))
    # HNSW coarse should roughly match flat coarse at the same nprobe
    _, ids_flat = ivf.search_ivf(index, ds.queries, nprobe=8, topk=10)
    r_flat = float(metrics.recall_at_r(ids_flat, ds.gt_ids, r=10))
    assert r >= r_flat - 0.08


def test_tree_coarse_quantizer():
    ds = small_ds()
    res = kmeans(jax.random.PRNGKey(9), ds.train, k=64, iters=10)
    tc = coarse.build_tree(jax.random.PRNGKey(10), res.centroids)
    _, ids = tc.search(ds.queries, nprobe=4)
    flat = coarse.build_flat(res.centroids)
    _, ids_flat = flat.search(ds.queries, nprobe=4)
    # top-1 probe agreement should be high (tree explores 4 of 8 roots)
    agree = float(np.mean(np.asarray(ids[:, 0]) == np.asarray(ids_flat[:, 0])))
    assert agree > 0.7


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_recall_at_r():
    pred = jnp.asarray([[1, 2, 3], [4, 5, 6]])
    gt = jnp.asarray([2, 9])
    assert float(metrics.recall_at_r(pred, gt)) == 0.5
    assert float(metrics.recall_at_r(pred, gt, r=1)) == 0.0
