"""Per-kernel allclose (here: bit-exact) tests vs the pure-jnp oracle.

Integer ADC accumulation is exact, so every kernel variant must match ref.py
bit-for-bit across a sweep of shapes — including ragged N/Q that exercise the
padding paths in ops.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import fastscan
from repro.kernels import fastscan_kernel as fk
from repro.kernels import ops, ref


def _rand_case(seed, q, n, m):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.integers(0, 256, size=(q, m, 16), dtype=np.uint8))
    packed = jnp.asarray(rng.integers(0, 256, size=(n, m // 2), dtype=np.uint8))
    return table, packed


SHAPES = [
    (1, 32, 2),      # minimal
    (3, 100, 4),     # ragged N -> padding path
    (8, 1024, 8),    # exact tile
    (2, 1500, 16),   # ragged, > 1 tile
    (5, 2048, 64),   # multi-tile, wide M
]


@pytest.mark.parametrize("impl", ["select", "mxu"])
@pytest.mark.parametrize("q,n,m", SHAPES)
def test_kernel_matches_ref_bitexact(impl, q, n, m):
    table, packed = _rand_case(q * 1000 + n + m, q, n, m)
    want = ref.fastscan_distances_ref(table, packed)
    got = ops.fastscan_distances(table, packed, impl=impl)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_extreme_values():
    """All-255 tables with max M: accumulator must not overflow/clip."""
    q, n, m = 2, 64, 128
    table = jnp.full((q, m, 16), 255, jnp.uint8)
    packed = jnp.asarray(np.random.default_rng(0).integers(0, 256, (n, m // 2), np.uint8))
    want = ref.fastscan_distances_ref(table, packed)
    assert int(want.max()) == 255 * m  # sanity: 32640 < 2^31 and exact in f32
    for impl in ("select", "mxu"):
        got = ops.fastscan_distances(table, packed, impl=impl)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_blockmin_matches_ref():
    q, n, m, block = 3, 2048, 8, 1024
    table, packed = _rand_case(7, q, n, m)
    want_min, want_arg = ref.fastscan_block_min_ref(table, packed, block)
    got_min, got_arg = ops.fastscan_blockmin(table, packed, block=block)
    np.testing.assert_array_equal(np.asarray(got_min), np.asarray(want_min))
    # argmin ties may resolve differently; check the dists at argmins match
    full = np.asarray(ref.fastscan_distances_ref(table, packed))
    np.testing.assert_array_equal(
        np.take_along_axis(full, np.asarray(got_arg), axis=1), np.asarray(want_min))


def test_blockmin_ragged_padding_is_maskable():
    q, n, m, block = 2, 1500, 4, 1024
    table, packed = _rand_case(9, q, n, m)
    got_min, got_arg = ops.fastscan_blockmin(table, packed, block=block)
    assert got_min.shape == (q, 2)
    full = np.asarray(ref.fastscan_distances_ref(table, packed))
    arg = np.asarray(got_arg)
    # ids either point into the real range with matching dists, or to padding
    in_range = arg < n
    np.testing.assert_array_equal(
        np.take_along_axis(full, np.where(in_range, arg, 0), axis=1)[in_range],
        np.asarray(got_min)[in_range])


# ---------------------------------------------------------------------------
# grouped scan (the IVF hot path): ref / select / mxu parity + autotune
# ---------------------------------------------------------------------------

def _rand_grouped(seed, g, cap, mh):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.integers(0, 256, size=(g, 2 * mh, 16), dtype=np.uint8))
    codes = jnp.asarray(rng.integers(0, 256, size=(g, cap, mh), dtype=np.uint8))
    return table, codes


GROUPED_SHAPES = [
    (1, 64, 4),      # G=1 (single query x single probe)
    (3, 100, 4),     # cap not a multiple of any tile -> padding path
    (4, 129, 3),     # ragged cap AND odd M//2 (lane dim not 128-aligned)
    (2, 300, 1),     # minimal M (one packed byte per code)
    (5, 1024, 8),    # exact tile
]


@pytest.mark.parametrize("impl", ["select", "mxu"])
@pytest.mark.parametrize("g,cap,mh", GROUPED_SHAPES)
def test_grouped_kernel_matches_ref_bitexact(impl, g, cap, mh):
    table, codes = _rand_grouped(g * 777 + cap + mh, g, cap, mh)
    want = ref.fastscan_grouped_ref(table, codes)
    got = ops.fastscan_grouped(table, codes, impl=impl)
    assert got.dtype == jnp.int32 and got.shape == (g, cap)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("impl", ["select", "mxu"])
def test_grouped_kernel_multi_tile_grid(impl):
    """tile_n smaller than cap drives a >1-tile grid per group."""
    table, codes = _rand_grouped(11, 3, 200, 4)
    want = np.asarray(ref.fastscan_grouped_ref(table, codes))
    got = ops.fastscan_grouped(table, codes, impl=impl, tile_n=64)
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("impl", ["select", "mxu"])
def test_grouped_kernel_all_sentinel_rows(impl):
    """Fully-padded gathered lists (invalid probe -> all-zero codes) must
    still agree with ref: consumers mask by id, but the scan itself has to
    be well-defined on the padding it is handed."""
    g, cap, mh = 2, 64, 4
    table = jnp.asarray(
        np.random.default_rng(3).integers(0, 256, (g, 2 * mh, 16), np.uint8))
    codes = jnp.zeros((g, cap, mh), jnp.uint8)  # what ListStore.gather pads with
    want = ref.fastscan_grouped_ref(table, codes)
    got = ops.fastscan_grouped(table, codes, impl=impl)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # every row of a group collapses to the same all-zero-code sum
    assert np.unique(np.asarray(got), axis=1).shape[1] == 1


def test_grouped_kernel_extreme_values():
    """All-255 tables with max M through the grouped MXU path: the bf16
    one-hot GEMM's f32 accumulation must stay exact at the extreme."""
    g, cap, m = 2, 64, 128
    table = jnp.full((g, m, 16), 255, jnp.uint8)
    codes = jnp.asarray(
        np.random.default_rng(4).integers(0, 256, (g, cap, m // 2), np.uint8))
    want = ref.fastscan_grouped_ref(table, codes)
    assert int(jnp.max(want)) == 255 * m
    for impl in ("select", "mxu"):
        got = ops.fastscan_grouped(table, codes, impl=impl)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_grouped_unknown_impl_raises():
    table, codes = _rand_grouped(0, 1, 32, 2)
    with pytest.raises(ValueError, match="unknown grouped impl"):
        ops.fastscan_grouped(table, codes, impl="simd")


def test_impl_registries_are_one_source_of_truth():
    """engine.SCAN_IMPLS derives from ops.GROUPED_IMPLS; the flat scan
    supports the gathered subset (no probe indirection to stream through)."""
    from repro.engine import engine as engine_mod
    assert ops.IMPLS == ("ref", "select", "mxu")
    assert set(ops.IMPLS) < set(ops.GROUPED_IMPLS)
    assert "stream" in ops.GROUPED_IMPLS
    assert ops.SCAN_IMPLS == ops.GROUPED_IMPLS + ("auto",)
    assert engine_mod.SCAN_IMPLS is ops.SCAN_IMPLS


def test_auto_resolves_deterministically_and_caches():
    g, cap, mh = 3, 96, 4
    table, codes = _rand_grouped(21, g, cap, mh)
    ops.clear_autotune_cache()
    try:
        tuned1 = ops.resolve_grouped_impl(g, cap, 2 * mh)
        assert tuned1.impl in ops.GROUPED_IMPLS
        assert len(tuned1.timings_us) >= len(ops.GROUPED_IMPLS)
        size1 = ops.autotune_cache_size()
        assert size1 == 1
        # second resolve is a cache hit: identical verdict, no new entry,
        # and no re-timing (the cached object comes back as-is)
        tuned2 = ops.resolve_grouped_impl(g, cap, 2 * mh)
        assert tuned2 is tuned1
        assert ops.autotune_cache_size() == size1
        # 'auto' dispatch is bit-identical to ref and reuses the cache
        want = np.asarray(ref.fastscan_grouped_ref(table, codes))
        got = np.asarray(ops.fastscan_grouped(table, codes, impl="auto"))
        np.testing.assert_array_equal(got, want)
        assert ops.autotune_cache_size() == size1
        # scan keys carry the store size the stream candidate was timed
        # against; the gathered signature defaults to nlist=G (its own store)
        key = ("scan", jax.default_backend(), ops._default_interpret(),
               g, cap, 2 * mh, g, 1.0)
        assert ops.autotune_cache()[key] is tuned1
    finally:
        ops.clear_autotune_cache()


def test_auto_sweep_executes_under_ambient_jit_trace():
    """'auto' resolving at trace time (the production path: scan_probes and
    the fused pipeline are jit'd) must still EXECUTE its timing sweep rather
    than stage it into the caller's jaxpr. The sweep runs on a worker thread
    to escape the thread-local trace; _median_time_us raises loudly on any
    regression (a Tracer where a concrete result should be), which would
    surface here as a failed trace."""
    ops.clear_autotune_cache()
    try:
        g, cap, mh = 2, 64, 4
        table, codes = _rand_grouped(33, g, cap, mh)

        @jax.jit
        def run(t, c):
            return ops.fastscan_grouped(t, c, impl="auto")

        got = np.asarray(run(table, codes))
        want = np.asarray(ref.fastscan_grouped_ref(table, codes))
        np.testing.assert_array_equal(got, want)
        assert ops.autotune_cache_size() == 1
        (tuned,) = ops.autotune_cache().values()
        # real executions take real time; staged tracing of the ref gather
        # at this tiny shape would not register as a plausible runtime sweep
        assert all(us > 0 for _, us in tuned.timings_us)
    finally:
        ops.clear_autotune_cache()


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(
    g=st.integers(1, 6),
    cap=st.integers(1, 200),
    mh=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_grouped_kernels_bitexact(g, cap, mh, seed):
    """Property: for any grouped shape/content, select and mxu == oracle."""
    table, codes = _rand_grouped(seed, g, cap, mh)
    want = np.asarray(ref.fastscan_grouped_ref(table, codes))
    for impl in ("select", "mxu"):
        got = np.asarray(ops.fastscan_grouped(table, codes, impl=impl))
        np.testing.assert_array_equal(got, want)


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(
    q=st.integers(1, 9),
    n=st.integers(1, 300),
    mh=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_kernels_bitexact(q, n, mh, seed):
    """Property: for any shape/content, both kernels == oracle exactly."""
    table, packed = _rand_case(seed, q, n, 2 * mh)
    want = np.asarray(ref.fastscan_distances_ref(table, packed))
    for impl in ("select", "mxu"):
        got = np.asarray(ops.fastscan_distances(table, packed, impl=impl))
        np.testing.assert_array_equal(got, want)


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 64), mh=st.integers(1, 32), seed=st.integers(0, 2**31 - 1))
def test_property_pack_unpack_roundtrip(n, mh, seed):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 16, size=(n, 2 * mh), dtype=np.int32))
    packed = fastscan.pack_codes(codes)
    assert packed.shape == (n, mh) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(fastscan.unpack_codes(packed)), np.asarray(codes))
    np.testing.assert_array_equal(np.asarray(ref.unpack_nibbles(packed)), np.asarray(codes))


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(q=st.integers(1, 4), mh=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_property_lut_quantization_error_bound(q, mh, seed):
    """|dequant(acc) - float ADC| <= M * scale/2 (per-entry rounding error)."""
    m = 2 * mh
    rng = np.random.default_rng(seed)
    table_np = rng.uniform(0, 100, size=(q, m, 16)).astype(np.float32)
    codes_np = rng.integers(0, 16, size=(64, m))
    qlut = fastscan.quantize_lut(jnp.asarray(table_np))
    acc = ref.fastscan_distances_ref(qlut.table_q8,
                                     fastscan.pack_codes(jnp.asarray(codes_np)))
    approx = np.asarray(fastscan.dequantize_acc(qlut, acc))  # (q, 64)
    # exact float ADC, plain numpy: exact[qi, n] = sum_m table[qi, m, codes[n, m]]
    exact = np.stack([
        sum(table_np[qi, j, codes_np[:, j]] for j in range(m)) for qi in range(q)])
    bound = np.asarray(qlut.scale)[:, None] * (0.5 * m) + 1e-3 * np.abs(exact) + 1e-3
    assert np.all(np.abs(approx - exact) <= bound)
