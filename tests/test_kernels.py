"""Per-kernel allclose (here: bit-exact) tests vs the pure-jnp oracle.

Integer ADC accumulation is exact, so every kernel variant must match ref.py
bit-for-bit across a sweep of shapes — including ragged N/Q that exercise the
padding paths in ops.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import fastscan
from repro.kernels import fastscan_kernel as fk
from repro.kernels import ops, ref


def _rand_case(seed, q, n, m):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.integers(0, 256, size=(q, m, 16), dtype=np.uint8))
    packed = jnp.asarray(rng.integers(0, 256, size=(n, m // 2), dtype=np.uint8))
    return table, packed


SHAPES = [
    (1, 32, 2),      # minimal
    (3, 100, 4),     # ragged N -> padding path
    (8, 1024, 8),    # exact tile
    (2, 1500, 16),   # ragged, > 1 tile
    (5, 2048, 64),   # multi-tile, wide M
]


@pytest.mark.parametrize("impl", ["select", "mxu"])
@pytest.mark.parametrize("q,n,m", SHAPES)
def test_kernel_matches_ref_bitexact(impl, q, n, m):
    table, packed = _rand_case(q * 1000 + n + m, q, n, m)
    want = ref.fastscan_distances_ref(table, packed)
    got = ops.fastscan_distances(table, packed, impl=impl)
    assert got.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_extreme_values():
    """All-255 tables with max M: accumulator must not overflow/clip."""
    q, n, m = 2, 64, 128
    table = jnp.full((q, m, 16), 255, jnp.uint8)
    packed = jnp.asarray(np.random.default_rng(0).integers(0, 256, (n, m // 2), np.uint8))
    want = ref.fastscan_distances_ref(table, packed)
    assert int(want.max()) == 255 * m  # sanity: 32640 < 2^31 and exact in f32
    for impl in ("select", "mxu"):
        got = ops.fastscan_distances(table, packed, impl=impl)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_blockmin_matches_ref():
    q, n, m, block = 3, 2048, 8, 1024
    table, packed = _rand_case(7, q, n, m)
    want_min, want_arg = ref.fastscan_block_min_ref(table, packed, block)
    got_min, got_arg = ops.fastscan_blockmin(table, packed, block=block)
    np.testing.assert_array_equal(np.asarray(got_min), np.asarray(want_min))
    # argmin ties may resolve differently; check the dists at argmins match
    full = np.asarray(ref.fastscan_distances_ref(table, packed))
    np.testing.assert_array_equal(
        np.take_along_axis(full, np.asarray(got_arg), axis=1), np.asarray(want_min))


def test_blockmin_ragged_padding_is_maskable():
    q, n, m, block = 2, 1500, 4, 1024
    table, packed = _rand_case(9, q, n, m)
    got_min, got_arg = ops.fastscan_blockmin(table, packed, block=block)
    assert got_min.shape == (q, 2)
    full = np.asarray(ref.fastscan_distances_ref(table, packed))
    arg = np.asarray(got_arg)
    # ids either point into the real range with matching dists, or to padding
    in_range = arg < n
    np.testing.assert_array_equal(
        np.take_along_axis(full, np.where(in_range, arg, 0), axis=1)[in_range],
        np.asarray(got_min)[in_range])


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(
    q=st.integers(1, 9),
    n=st.integers(1, 300),
    mh=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_kernels_bitexact(q, n, mh, seed):
    """Property: for any shape/content, both kernels == oracle exactly."""
    table, packed = _rand_case(seed, q, n, 2 * mh)
    want = np.asarray(ref.fastscan_distances_ref(table, packed))
    for impl in ("select", "mxu"):
        got = np.asarray(ops.fastscan_distances(table, packed, impl=impl))
        np.testing.assert_array_equal(got, want)


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 64), mh=st.integers(1, 32), seed=st.integers(0, 2**31 - 1))
def test_property_pack_unpack_roundtrip(n, mh, seed):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, 16, size=(n, 2 * mh), dtype=np.int32))
    packed = fastscan.pack_codes(codes)
    assert packed.shape == (n, mh) and packed.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(fastscan.unpack_codes(packed)), np.asarray(codes))
    np.testing.assert_array_equal(np.asarray(ref.unpack_nibbles(packed)), np.asarray(codes))


@pytest.mark.slow
@settings(max_examples=20, deadline=None)
@given(q=st.integers(1, 4), mh=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_property_lut_quantization_error_bound(q, mh, seed):
    """|dequant(acc) - float ADC| <= M * scale/2 (per-entry rounding error)."""
    m = 2 * mh
    rng = np.random.default_rng(seed)
    table_np = rng.uniform(0, 100, size=(q, m, 16)).astype(np.float32)
    codes_np = rng.integers(0, 16, size=(64, m))
    qlut = fastscan.quantize_lut(jnp.asarray(table_np))
    acc = ref.fastscan_distances_ref(qlut.table_q8,
                                     fastscan.pack_codes(jnp.asarray(codes_np)))
    approx = np.asarray(fastscan.dequantize_acc(qlut, acc))  # (q, 64)
    # exact float ADC, plain numpy: exact[qi, n] = sum_m table[qi, m, codes[n, m]]
    exact = np.stack([
        sum(table_np[qi, j, codes_np[:, j]] for j in range(m)) for qi in range(q)])
    bound = np.asarray(qlut.scale)[:, None] * (0.5 * m) + 1e-3 * np.abs(exact) + 1e-3
    assert np.all(np.abs(approx - exact) <= bound)
