"""Subprocess body for test_multidevice.py — runs with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` set by the parent
BEFORE jax is imported (the flag is read at backend init, so it cannot be
flipped inside an already-running test process).

Asserts on a real 8-device host mesh:
  - the shard_map driver is bit-identical to the vmap driver (which needs
    no devices and is tested everywhere else), unfiltered and filtered;
  - mutation (delete/upsert/compact) threads through the multi-device
    path: both drivers agree after every epoch and tombstones never leak.
Exits 0 and prints OK on success; any assertion kills the process.
"""
import os
import sys

assert "--xla_force_host_platform_device_count=8" in \
    os.environ.get("XLA_FLAGS", ""), "harness must run with forced devices"

import jax  # noqa: E402  (import order is the point)
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

assert jax.device_count() >= 8, f"got {jax.device_count()} devices"

from repro.core.lists import filter_words  # noqa: E402
from repro.data import vectors  # noqa: E402
from repro.engine import EngineConfig, SearchEngine, ShardedEngine  # noqa: E402

S = 8
ds = vectors.make_sift_like(n=2400, nt=1200, nq=6, d=32, ncl=16, seed=3)
cfg = EngineConfig(nprobe=2, rerank_mult=4)
eng = SearchEngine.build(jax.random.PRNGKey(0), jnp.asarray(ds.train),
                         jnp.asarray(ds.base), m=8, nlist=16, config=cfg,
                         coarse_iters=4, pq_iters=4)
sh = ShardedEngine(eng, S)
mesh = jax.sharding.Mesh(np.array(jax.devices()[:S]), ("shards",))
q = jnp.asarray(ds.queries)


def drivers_agree(tag):
    rm = sh.search(q, 10, mesh=mesh)
    rv = sh.search(q, 10)
    np.testing.assert_array_equal(np.asarray(rm.dists), np.asarray(rv.dists),
                                  err_msg=tag)
    np.testing.assert_array_equal(np.asarray(rm.ids), np.asarray(rv.ids),
                                  err_msg=tag)
    for a, b in zip(rm.stats, rv.stats):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=tag)
    return rm


r0 = drivers_agree("pristine")
assert (np.asarray(r0.stats.rows_tombstoned) == 0).all()

# mutation over the 8-way mesh: delete / upsert / compact, re-checking the
# driver pair after every epoch
rng = np.random.default_rng(41)
dead = rng.choice(2400, size=160, replace=False)
assert sh.delete(dead) == 160
r1 = drivers_agree("post-delete")
assert (np.asarray(r1.stats.rows_tombstoned) > 0).all()
assert not np.isin(np.asarray(r1.ids), dead).any(), "tombstone leaked"

new_ids = np.arange(2400, 2480)
sh.upsert(new_ids, rng.normal(size=(80, 32)).astype(np.float32))
drivers_agree("post-upsert")

assert sh.compact() == 160
assert sh.n_tombstones == 0
r3 = drivers_agree("post-compact")
assert (np.asarray(r3.stats.rows_tombstoned) == 0).all()
assert not np.isin(np.asarray(r3.ids), dead).any()

# filtered path over the mesh: an arbitrary bitmap at the LIVE width (the
# upsert above grew cap, so a pristine-width bitmap would be refused)
fb = jnp.asarray(
    rng.integers(0, 256, (16, filter_words(sh.cap)), dtype=np.uint8))
rf_m = sh.search(q, 10, filter_bits=fb, mesh=mesh)
rf_v = sh.search(q, 10, filter_bits=fb)
np.testing.assert_array_equal(np.asarray(rf_m.ids), np.asarray(rf_v.ids))

# anytime path over the mesh (docs/anytime.md): margin policy + in-kernel
# early exit through the stream scan — drivers must agree on results AND the
# pruned/skipped counters, and tau=inf must match a fixed-policy engine
cfg_any = EngineConfig(nprobe=2, rerank_mult=4, scan_impl="stream",
                       probe_policy="margin", early_exit=True)
eng_any = SearchEngine.build(jax.random.PRNGKey(0), jnp.asarray(ds.train),
                             jnp.asarray(ds.base), m=8, nlist=16,
                             config=cfg_any, coarse_iters=4, pq_iters=4)
sh_any = ShardedEngine(eng_any, S)
for tau in (float("inf"), 0.2):
    ra_m = sh_any.search(q, 10, margin_tau=tau, mesh=mesh)
    ra_v = sh_any.search(q, 10, margin_tau=tau)
    np.testing.assert_array_equal(np.asarray(ra_m.ids), np.asarray(ra_v.ids),
                                  err_msg=f"anytime tau={tau}")
    np.testing.assert_array_equal(np.asarray(ra_m.dists),
                                  np.asarray(ra_v.dists),
                                  err_msg=f"anytime tau={tau}")
    for a, b in zip(ra_m.stats, ra_v.stats):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"anytime stats tau={tau}")
cfg_fix = EngineConfig(nprobe=2, rerank_mult=4, scan_impl="stream")
eng_fix = SearchEngine.build(jax.random.PRNGKey(0), jnp.asarray(ds.train),
                             jnp.asarray(ds.base), m=8, nlist=16,
                             config=cfg_fix, coarse_iters=4, pq_iters=4)
sh_fix = ShardedEngine(eng_fix, S)
r_fix = sh_fix.search(q, 10, mesh=mesh)
r_inf = sh_any.search(q, 10, margin_tau=float("inf"), mesh=mesh)
np.testing.assert_array_equal(np.asarray(r_inf.ids), np.asarray(r_fix.ids))
np.testing.assert_array_equal(np.asarray(r_inf.dists),
                              np.asarray(r_fix.dists))
assert (np.asarray(r_inf.stats.lists_pruned) == 0).all()
r_tight = sh_any.search(q, 10, margin_tau=0.0, mesh=mesh)
assert (np.asarray(r_tight.stats.lists_pruned) > 0).any(), \
    "tau=0 pruned nothing across 8 shards"

print("OK")
sys.exit(0)
