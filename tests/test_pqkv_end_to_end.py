"""End-to-end PQ-KV quality: on a briefly-trained model with codebooks
calibrated on real activations, PQ-cache decoding should track exact-cache
decoding closely (the serving-quality claim behind the paper-tech
integration)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import serve as serve_lib
from repro.models import model as model_lib
from repro.train import optimizer as opt_lib
from repro.train import train_loop


def test_pq_kv_decode_tracks_exact_on_trained_model():
    cfg = configs.get_smoke_config("qwen3_1p7b").replace(kv_pq=False)
    # brief training so K/V develop non-random structure
    ocfg = opt_lib.AdamWConfig(lr=2e-3, total_steps=30, warmup_steps=3)
    state, hist = train_loop.train(cfg, steps=30, global_batch=4, seq_len=64,
                                   ocfg=ocfg, log=lambda s: None)
    assert hist[-1]["loss"] < hist[0]["loss"]
    params = state.params

    rng = np.random.default_rng(0)
    b, prompt_len, gen = 2, 48, 8
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (b, prompt_len), np.int32))

    toks_exact = serve_lib.serve_batch(cfg, params, prompts, gen)
    pq_cfg = cfg.replace(kv_pq=True)
    toks_pq = serve_lib.serve_batch(pq_cfg, params, prompts, gen,
                                    key=jax.random.PRNGKey(1))
    agree = float(jnp.mean((toks_exact == toks_pq).astype(jnp.float32)))
    # trained model, calibrated codebooks: decoded streams should mostly agree
    assert agree >= 0.5, f"PQ-KV decode diverges from exact: agreement={agree}"

    # and the logits themselves should be close at the first decode step
    max_seq = prompt_len + gen
    _, cache_e = model_lib.prefill(params, prompts, cfg, max_seq=max_seq)
    pqc = serve_lib.calibrate_pq_cache(jax.random.PRNGKey(1), params, pq_cfg,
                                       b, max_seq)
    _, cache_p = model_lib.prefill(params, prompts, pq_cfg, max_seq=max_seq,
                                   pq_cache=pqc)
    tok = toks_exact[:, 0].astype(jnp.int32)
    pos = jnp.full((b,), prompt_len, jnp.int32)
    log_e, _ = model_lib.decode_step(params, cache_e, tok, pos, cfg)
    log_p, _ = model_lib.decode_step(params, cache_p, tok, pos, pq_cfg)
    # compare top-5 overlap
    top_e = np.asarray(jax.lax.top_k(log_e, 5)[1])
    top_p = np.asarray(jax.lax.top_k(log_p, 5)[1])
    overlap = np.mean([len(set(a) & set(bb)) / 5 for a, bb in zip(top_e, top_p)])
    assert overlap >= 0.4, f"top-5 overlap too low: {overlap}"
