"""shard_map driver on a real >1-device mesh.

``--xla_force_host_platform_device_count`` must be set before jax's backend
initializes, so the 8-device run happens in a subprocess executing
``tests/_multidevice_harness.py`` (which asserts vmap/shard_map bit-identity
through a full mutation program); this module just launches it and checks
the exit status. Marked slow: the child pays its own jax init + compiles.
"""
import os
import pathlib
import subprocess
import sys

import pytest

_HARNESS = pathlib.Path(__file__).with_name("_multidevice_harness.py")
_SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")


def _run_harness(extra_env):
    env = dict(os.environ)
    env.update(extra_env)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, str(_HARNESS)], env=env,
                          capture_output=True, text=True, timeout=900)


@pytest.mark.slow
def test_sharded_drivers_on_eight_device_mesh():
    proc = _run_harness(
        {"XLA_FLAGS": (os.environ.get("XLA_FLAGS", "")
                       + " --xla_force_host_platform_device_count=8").strip(),
         "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, (
        f"harness failed\nstdout:\n{proc.stdout}\nstderr:\n{proc.stderr}")
    assert "OK" in proc.stdout


def test_harness_refuses_to_run_without_forced_devices():
    """The guard that keeps the harness meaningful: without the flag it must
    die loudly instead of silently testing a 1-device mesh."""
    env = {k: v for k, v in os.environ.items() if k != "XLA_FLAGS"}
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, str(_HARNESS)], env=env,
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode != 0
    assert "forced devices" in (proc.stderr + proc.stdout)
