"""Live mutable index: epoch-versioned upserts/deletes under an oracle.

The contract under test is docs/mutability.md: after ANY interleaving of
upserts, deletes and compactions, ``search`` / ``search_jit`` return results
bit-identical to a from-scratch engine rebuilt over the surviving rows (same
centroids, codebook and cap) — across every scan/rerank impl, the filtered
and namespaced paths, and both ShardedEngine drivers. ADC accumulation is
integer-exact and the fixed-shape encoder makes codes batch-independent, so
every comparison here is ``assert_array_equal``, not allclose.

Plus: mutation primitives (watermark/tombstone/live-bits invariants),
epoch/stats accounting, selective autotune invalidation, serving entry
points, a hypothesis sweep over random mutation programs, and a threaded
stress test hammering the ServingLoop with queries during mutation.
"""
import functools
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as hst

from repro.core import ivf
from repro.core.lists import (ListStore, append_rows, build_lists,
                              compact_lists, filter_from_attrs, filter_words,
                              grow_cap, live_counts, live_filter_bits,
                              locate_rows, pack_filter_mask, tombstone_counts,
                              tombstone_rows)
from repro.data import vectors
from repro.engine import EngineConfig, SearchEngine, ShardedEngine
from repro.kernels import ops as ops_mod
from repro.serving.loop import ServingLoop

# ---------------------------------------------------------------------------
# shared build (immutable jax arrays: engines wrapping it never alias state)
# ---------------------------------------------------------------------------

NLIST = 16
D = 32
M = 8


@functools.lru_cache(maxsize=None)
def _built():
    ds = vectors.make_sift_like(n=3000, nt=1500, nq=8, d=D, ncl=16, seed=3)
    index = ivf.build_ivf(jax.random.PRNGKey(0), jnp.asarray(ds.train),
                          jnp.asarray(ds.base), m=M, nlist=NLIST,
                          coarse_iters=4, pq_iters=4)
    return ds, index


def _attr_of(gids):
    return (np.asarray(gids, np.int64) % 5).astype(np.int32)


def _with_attrs(store: ListStore) -> ListStore:
    """Attach a deterministic attrs column derived from each row's gid."""
    ids = np.asarray(store.ids)
    attrs = np.where(ids >= 0, _attr_of(np.maximum(ids, 0)), -1).astype(np.int32)
    return store._replace(attrs=jnp.asarray(attrs))


def mk_engine(cfg: EngineConfig, *, attrs=False, namespaces=None) -> SearchEngine:
    ds, index = _built()
    store = index.lists
    if attrs:
        store = _with_attrs(store)
    return SearchEngine(index._replace(lists=store), base=jnp.asarray(ds.base),
                        config=cfg, namespaces=namespaces)


class Model:
    """Host-side mirror of the live row set: gid -> vector."""

    def __init__(self, base: np.ndarray):
        self.rows = {g: np.asarray(base[g]) for g in range(base.shape[0])}

    def delete(self, gids):
        for g in np.asarray(gids).ravel():
            self.rows.pop(int(g), None)

    def upsert(self, gids, vecs):
        for g, v in zip(np.asarray(gids).ravel(), np.asarray(vecs)):
            self.rows[int(g)] = np.asarray(v, np.float32)

    def survivors(self):
        surv = np.array(sorted(self.rows), np.int64)
        vecs = (np.stack([self.rows[int(g)] for g in surv])
                if surv.size else np.zeros((0, D), np.float32))
        return surv, vecs


def rebuild_oracle(model: Model, cap: int, cfg: EngineConfig, *, attrs=False,
                   namespaces=None):
    """From-scratch engine over the surviving rows: the ground truth.

    Same centroids/codebook as the live engine, same cap (the layout knob a
    grow can change), rows encoded through the same fixed-shape encoder —
    so a correct mutable engine must match it bitwise. The oracle's ids are
    positions into the survivor array; ``surv`` maps them back to gids.
    """
    _, index = _built()
    surv, vecs = model.survivors()
    assign, packed = ivf.encode_rows(index.centroids, index.codebook,
                                     jnp.asarray(vecs))
    store = build_lists(np.asarray(assign), np.asarray(packed),
                        ids=np.arange(surv.size, dtype=np.int32),
                        nlist=NLIST, cap=cap,
                        attrs=_attr_of(surv) if attrs else None)
    eng = SearchEngine(index._replace(lists=store), config=cfg,
                       base=jnp.asarray(vecs) if surv.size else
                       jnp.zeros((1, D), jnp.float32),
                       namespaces=namespaces)
    return eng, surv


def _to_gids(ids, surv):
    ids = np.asarray(ids)
    return np.where(ids >= 0, surv[np.maximum(ids, 0)] if surv.size else -1, -1)


def assert_matches_oracle(eng, model, q, *, k=10, filter_fn=None,
                          namespaces=None, ns_table=None):
    """search AND search_jit of the live engine vs the rebuilt oracle."""
    cfg = eng.config
    cap = eng.index.lists.cap
    oracle, surv = rebuild_oracle(model, cap, cfg,
                                  attrs=filter_fn is not None,
                                  namespaces=ns_table)
    fb_live = fb_oracle = None
    if filter_fn is not None:
        # filters are derived from each engine's OWN live store — a grow
        # may have changed cap, so the caller can't share one bitmap
        fb_live = filter_from_attrs(eng.index.lists, filter_fn)
        fb_oracle = filter_from_attrs(oracle.index.lists, filter_fn)
    for call in ("search", "search_jit"):
        r_mut = getattr(eng, call)(q, k, filter_bits=fb_live,
                                   namespaces=namespaces)
        r_orc = getattr(oracle, call)(q, k, filter_bits=fb_oracle,
                                      namespaces=namespaces)
        np.testing.assert_array_equal(np.asarray(r_mut.dists),
                                      np.asarray(r_orc.dists), err_msg=call)
        np.testing.assert_array_equal(np.asarray(r_mut.ids),
                                      _to_gids(r_orc.ids, surv), err_msg=call)
        # live stats must partition: filtered counts only live rows, the
        # oracle (tombstone-free by construction) reports zero tombstoned
        assert (np.asarray(r_orc.stats.rows_tombstoned) == 0).all()
    return oracle, surv


def _mutate(eng, model, *, seed=7, n_delete=200, n_new=150, n_re=50,
            id_base=3000):
    """The canonical program: delete a slab, insert new ids, re-upsert."""
    rng = np.random.default_rng(seed)
    dead = rng.choice(3000, size=n_delete, replace=False)
    assert eng.delete(dead) == n_delete
    model.delete(dead)
    new_ids = np.arange(id_base, id_base + n_new)
    new_vecs = rng.normal(size=(n_new, D)).astype(np.float32)
    eng.upsert(new_ids, new_vecs)
    model.upsert(new_ids, new_vecs)
    re_ids = np.setdiff1d(np.arange(3000), dead)[:n_re]
    re_vecs = rng.normal(size=(n_re, D)).astype(np.float32)
    eng.upsert(re_ids, re_vecs)
    model.upsert(re_ids, re_vecs)


# ---------------------------------------------------------------------------
# mutation primitives (core.lists)
# ---------------------------------------------------------------------------

def _tiny_store(nlist=4, cap=8, m=4, rows_per_list=(3, 0, 5, 2), seed=0):
    rng = np.random.default_rng(seed)
    assign = np.repeat(np.arange(nlist), rows_per_list)
    packed = rng.integers(0, 256, (assign.size, m // 2), np.uint8)
    return build_lists(assign, packed, nlist=nlist, cap=cap)


def test_append_rows_slots_watermark_and_overflow():
    st = _tiny_store()
    packed = np.full((3, 2), 9, np.uint8)
    st2, slots = append_rows(st, np.array([0, 2, 0]), packed,
                             np.array([100, 101, 102], np.int32))
    # slot = list watermark + stable rank within the batch
    np.testing.assert_array_equal(slots, [3, 5, 4])
    assert int(st2.sizes[0]) == 5 and int(st2.sizes[2]) == 6
    np.testing.assert_array_equal(np.asarray(st2.ids[0, 3:5]), [100, 102])
    assert int(st2.ids[2, 5]) == 101
    # original store untouched (jax arrays are immutable)
    assert int(st.sizes[0]) == 3
    with pytest.raises(ValueError, match="spare capacity"):
        append_rows(st2, np.full(4, 2), np.zeros((4, 2), np.uint8),
                    np.arange(200, 204, dtype=np.int32))


def test_append_rows_attrs_contract():
    st = _tiny_store()
    with pytest.raises(ValueError, match="attrs"):
        append_rows(st, np.array([0]), np.zeros((1, 2), np.uint8),
                    np.array([7], np.int32), attrs=np.array([1], np.int32))
    st_a = _with_attrs(st)
    st2, slots = append_rows(st_a, np.array([1]), np.zeros((1, 2), np.uint8),
                             np.array([7], np.int32),
                             attrs=np.array([42], np.int32))
    assert int(st2.attrs[1, slots[0]]) == 42


def test_tombstone_marks_ids_attrs_and_live_counts():
    st = _with_attrs(_tiny_store())
    st2 = tombstone_rows(st, np.array([0, 2]), np.array([1, 4]))
    assert int(st2.ids[0, 1]) == -1 and int(st2.attrs[0, 1]) == -1
    assert int(st2.ids[2, 4]) == -1
    # watermark unchanged, live shrinks, tombstones appear
    np.testing.assert_array_equal(np.asarray(st2.sizes), np.asarray(st.sizes))
    np.testing.assert_array_equal(np.asarray(live_counts(st2)), [2, 0, 4, 2])
    np.testing.assert_array_equal(np.asarray(tombstone_counts(st2)),
                                  [1, 0, 1, 0])
    # live bitmap has exactly the live slots set
    bits = live_filter_bits(st2)
    from repro.core.lists import unpack_filter_mask
    np.testing.assert_array_equal(
        np.asarray(unpack_filter_mask(bits, st2.cap)),
        np.asarray(st2.ids >= 0))


def test_grow_cap_pads_and_refuses_shrink():
    st = _with_attrs(_tiny_store())
    g = grow_cap(st, 16)
    assert g.cap == 16 and g.codes.shape == (4, 16, 2)
    np.testing.assert_array_equal(np.asarray(g.ids[:, 8:]), -1)
    np.testing.assert_array_equal(np.asarray(g.attrs[:, 8:]), -1)
    np.testing.assert_array_equal(np.asarray(g.ids[:, :8]), np.asarray(st.ids))
    assert grow_cap(st, 8) is st
    with pytest.raises(ValueError):
        grow_cap(st, 4)


def test_compact_lists_preserves_survivor_order():
    st = _tiny_store()
    st2 = tombstone_rows(st, np.array([2, 2, 0]), np.array([0, 3, 1]))
    st3 = compact_lists(st2)
    np.testing.assert_array_equal(np.asarray(st3.sizes), [2, 0, 3, 2])
    # list 2 held gids 3..7; slots 0 and 3 died -> survivors 4, 5, 7 in order
    np.testing.assert_array_equal(np.asarray(st3.ids[2, :3]), [4, 5, 7])
    np.testing.assert_array_equal(
        np.asarray(st3.codes[2, :3]),
        np.asarray(st2.codes)[2][np.array([1, 2, 4])])
    # shrink below the largest live list refuses
    with pytest.raises(ValueError):
        compact_lists(st2, cap=2)
    small = compact_lists(st2, cap=4)
    assert small.cap == 4


def test_locate_rows_live_only():
    st = _tiny_store()
    st2 = tombstone_rows(st, np.array([0]), np.array([0]))
    loc = locate_rows(st2)
    assert 0 not in loc            # gid 0 was (list 0, slot 0)
    assert loc[1] == (0, 1)
    assert loc[3] == (2, 0)
    assert len(loc) == 9


# ---------------------------------------------------------------------------
# the headline: oracle bit-identity across impls
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("scan_impl", ["ref", "stream"])
@pytest.mark.parametrize("rerank_impl", ["gathered", "stream"])
def test_mutation_oracle_bit_identity(scan_impl, rerank_impl):
    ds, _ = _built()
    cfg = EngineConfig(nprobe=8, rerank_mult=4, scan_impl=scan_impl,
                       rerank_impl=rerank_impl)
    eng = mk_engine(cfg)
    model = Model(np.asarray(ds.base))
    _mutate(eng, model)
    assert_matches_oracle(eng, model, jnp.asarray(ds.queries))


def test_mutation_oracle_no_rerank():
    ds, _ = _built()
    cfg = EngineConfig(nprobe=8, rerank_mult=0)
    eng = mk_engine(cfg)
    model = Model(np.asarray(ds.base))
    _mutate(eng, model)
    assert_matches_oracle(eng, model, jnp.asarray(ds.queries))


@pytest.mark.parametrize("scan_impl", ["ref", "stream"])
def test_mutation_oracle_filtered(scan_impl):
    ds, _ = _built()
    cfg = EngineConfig(nprobe=8, rerank_mult=4, scan_impl=scan_impl)
    eng = mk_engine(cfg, attrs=True)
    model = Model(np.asarray(ds.base))
    rng = np.random.default_rng(11)
    dead = rng.choice(3000, size=150, replace=False)
    eng.delete(dead)
    model.delete(dead)
    new_ids = np.arange(3000, 3100)
    new_vecs = rng.normal(size=(100, D)).astype(np.float32)
    # upserting into an attrs-bearing store requires attrs for the rows
    eng.upsert(new_ids, new_vecs, attrs=_attr_of(new_ids))
    model.upsert(new_ids, new_vecs)
    assert_matches_oracle(eng, model, jnp.asarray(ds.queries),
                          filter_fn=lambda a: (a % 5) != 2)


def test_mutation_oracle_namespaced():
    ds, index = _built()
    member = np.zeros((2, NLIST), bool)
    member[0, :NLIST // 2] = True
    member[1, NLIST // 2:] = True
    ns_table = jnp.asarray(member)
    cfg = EngineConfig(nprobe=8, rerank_mult=4, scan_impl="stream")
    eng = mk_engine(cfg, namespaces=ns_table)
    model = Model(np.asarray(ds.base))
    _mutate(eng, model, seed=13)
    ns = jnp.asarray([0, 1, -1, 0, 1, -1, 0, 1], jnp.int32)
    _, surv = assert_matches_oracle(eng, model, jnp.asarray(ds.queries),
                                    namespaces=ns, ns_table=ns_table)
    # isolation survives mutation: a restricted query only sees its lists
    r = eng.search(jnp.asarray(ds.queries), 10, namespaces=ns)
    ids = np.asarray(r.ids)
    loc = {g: eng.locate(g) for row in ids for g in row if g >= 0}
    for qi, n in enumerate(np.asarray(ns)):
        if n < 0:
            continue
        for g in ids[qi]:
            if g >= 0:
                assert member[int(n), loc[int(g)][0]]


def test_post_compact_bit_identity_and_shrink():
    ds, _ = _built()
    cfg = EngineConfig(nprobe=8, rerank_mult=4, scan_impl="stream",
                       rerank_impl="stream")
    eng = mk_engine(cfg)
    model = Model(np.asarray(ds.base))
    _mutate(eng, model)
    n_tomb = eng.n_tombstones
    assert n_tomb > 0
    assert eng.compact() == n_tomb
    assert eng.n_tombstones == 0 and eng.live_bits is None
    assert_matches_oracle(eng, model, jnp.asarray(ds.queries))
    # compaction with an explicit smaller cap still matches its oracle
    max_live = int(np.asarray(live_counts(eng.index.lists)).max())
    tight = -(-max_live // 8) * 8
    if tight < eng.index.lists.cap:
        eng.compact(cap=tight)
        assert eng.index.lists.cap == tight
        assert_matches_oracle(eng, model, jnp.asarray(ds.queries))


def test_capacity_growth_keeps_oracle_parity():
    ds, _ = _built()
    cfg = EngineConfig(nprobe=8, rerank_mult=4)
    eng = mk_engine(cfg)
    model = Model(np.asarray(ds.base))
    cap0 = eng.index.lists.cap
    # slam one list with enough rows to overflow its spare slots
    target = int(np.argmax(np.asarray(eng.index.lists.sizes)))
    cvec = np.asarray(eng.index.centroids[target])
    n_new = int(cap0)  # guaranteed overflow for the fullest list
    new_ids = np.arange(4000, 4000 + n_new)
    new_vecs = (cvec[None, :]
                + 0.01 * np.random.default_rng(5).normal(size=(n_new, D))
                ).astype(np.float32)
    eng.upsert(new_ids, new_vecs)
    model.upsert(new_ids, new_vecs)
    assert eng.index.lists.cap > cap0
    assert eng.index.lists.cap % 8 == 0
    assert_matches_oracle(eng, model, jnp.asarray(ds.queries))


def test_upsert_replaces_vector_exactly():
    ds, _ = _built()
    eng = mk_engine(EngineConfig(nprobe=NLIST, rerank_mult=8))
    probe = np.asarray(ds.base[42]) * 0.0 + 7.5  # far from everything
    eng.upsert(np.array([42]), probe[None, :])
    r = eng.search(jnp.asarray(probe), 1)
    assert int(r.ids[0, 0]) == 42
    assert float(r.dists[0, 0]) == 0.0


def test_delete_everything_returns_sentinels():
    ds, _ = _built()
    eng = mk_engine(EngineConfig(nprobe=8, rerank_mult=4))
    assert eng.delete(np.arange(3000)) == 3000
    r = eng.search(jnp.asarray(ds.queries), 10)
    assert (np.asarray(r.ids) == -1).all()
    assert np.isinf(np.asarray(r.dists)).all()
    # and reinsertion brings rows back
    eng.upsert(np.array([7]), np.asarray(ds.base[7])[None, :])
    r2 = eng.search(jnp.asarray(ds.base[7]), 1)
    assert int(r2.ids[0, 0]) == 7


def test_epoch_counters_and_noop_mutations():
    ds, _ = _built()
    eng = mk_engine(EngineConfig(nprobe=8, rerank_mult=4))
    assert eng.epoch == 0 and eng.n_tombstones == 0 and eng.live_bits is None
    assert eng.delete([99999]) == 0      # unknown id: no-op, no epoch bump
    assert eng.epoch == 0
    assert eng.upsert(np.empty(0, np.int64), np.empty((0, D))).size == 0
    assert eng.epoch == 0
    assert eng.delete([5, 5, 6]) == 2    # duplicates collapse
    assert eng.epoch == 1 and eng.n_tombstones == 2
    assert eng.live_bits is not None
    assert eng.locate(5) is None and eng.locate(7) is not None
    eng.upsert(np.array([5]), np.asarray(ds.base[5])[None, :])
    assert eng.epoch == 2
    assert eng.locate(5) is not None


def test_upsert_validation():
    ds, _ = _built()
    eng = mk_engine(EngineConfig(nprobe=8, rerank_mult=4))
    with pytest.raises(ValueError):
        eng.upsert(np.array([1, 2]), np.zeros((3, D)))
    with pytest.raises(ValueError):
        eng.upsert(np.array([-1]), np.zeros((1, D)))
    with pytest.raises(ValueError):
        eng.upsert(np.array([1, 1]), np.zeros((2, D)))
    with pytest.raises(ValueError, match="attrs"):
        eng.upsert(np.array([1]), np.zeros((1, D)),
                   attrs=np.array([3], np.int32))


def test_stats_partition_filtered_vs_tombstoned():
    ds, _ = _built()
    eng = mk_engine(EngineConfig(nprobe=NLIST, rerank_mult=4), attrs=True)
    q = jnp.asarray(ds.queries)
    dead = np.arange(0, 600)
    eng.delete(dead)
    # all-pass filter: rows_filtered must be 0, tombstones all visible
    fb_all = filter_from_attrs(eng.index.lists, lambda a: a >= 0)
    r = eng.search(q, 10, filter_bits=fb_all)
    assert (np.asarray(r.stats.rows_filtered) == 0).all()
    assert (np.asarray(r.stats.rows_tombstoned) == 600).all()
    # restrictive filter drops only LIVE rows; the partition is disjoint
    fb = filter_from_attrs(eng.index.lists, lambda a: (a % 5) == 0)
    r2 = eng.search(q, 10, filter_bits=fb)
    live_total = 3000 - 600
    pass_total = int(np.asarray(
        jnp.sum((jnp.asarray(_attr_of(np.arange(3000))) % 5 == 0)
                & (jnp.arange(3000) >= 600))))
    assert (np.asarray(r2.stats.rows_filtered)
            == live_total - pass_total).all()
    assert (np.asarray(r2.stats.rows_tombstoned) == 600).all()
    # unfiltered search still reports zero filtered
    r3 = eng.search(q, 10)
    assert (np.asarray(r3.stats.rows_filtered) == 0).all()
    assert (np.asarray(r3.stats.rows_tombstoned) == 600).all()


def test_stale_filter_width_rejected_after_growth():
    ds, _ = _built()
    eng = mk_engine(EngineConfig(nprobe=8, rerank_mult=4), attrs=True)
    fb = filter_from_attrs(eng.index.lists, lambda a: a >= 0)
    cap0 = eng.index.lists.cap
    # force a cap grow, then the pre-grow bitmap must be refused loudly
    target = int(np.argmax(np.asarray(eng.index.lists.sizes)))
    cvec = np.asarray(eng.index.centroids[target])
    n_new = int(cap0)
    vecs = (cvec[None, :] + 0.01 * np.random.default_rng(6)
            .normal(size=(n_new, D))).astype(np.float32)
    eng.upsert(np.arange(5000, 5000 + n_new), vecs,
               attrs=_attr_of(np.arange(5000, 5000 + n_new)))
    assert eng.index.lists.cap > cap0
    if fb.shape[1] < filter_words(eng.index.lists.cap):
        with pytest.raises(ValueError, match="cap"):
            eng.search(jnp.asarray(ds.queries), 10, filter_bits=fb)


# ---------------------------------------------------------------------------
# sharded: mutation threads through both drivers
# ---------------------------------------------------------------------------

def _assert_sharded_matches_oracle(sh, model, q, cfg, num_shards, *,
                                   mesh=None):
    oracle, surv = rebuild_oracle(model, sh.cap, cfg)
    sh_oracle = ShardedEngine(oracle, num_shards)
    r_mut = sh.search(q, 10, mesh=mesh)
    r_orc = sh_oracle.search(q, 10, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(r_mut.dists),
                                  np.asarray(r_orc.dists))
    np.testing.assert_array_equal(np.asarray(r_mut.ids),
                                  _to_gids(r_orc.ids, surv))
    return r_mut


@pytest.mark.parametrize("num_shards", [2, 3])
def test_sharded_mutation_oracle_vmap(num_shards):
    ds, _ = _built()
    cfg = EngineConfig(nprobe=8, rerank_mult=4, scan_impl="stream",
                       rerank_impl="stream")
    eng = mk_engine(cfg)
    sh = ShardedEngine(eng, num_shards)
    model = Model(np.asarray(ds.base))
    rng = np.random.default_rng(21)
    dead = rng.choice(3000, size=200, replace=False)
    # routing and bookkeeping agree with the single-host engine exactly
    assert sh.delete(dead) == eng.delete(dead) == dead.size
    model.delete(dead)
    new_ids = np.arange(3000, 3150)
    new_vecs = rng.normal(size=(150, D)).astype(np.float32)
    a_s = sh.upsert(new_ids, new_vecs)
    a_e = eng.upsert(new_ids, new_vecs)
    np.testing.assert_array_equal(np.asarray(a_s), np.asarray(a_e))
    model.upsert(new_ids, new_vecs)
    assert sh.epoch == eng.epoch == 2
    assert sh.n_tombstones == eng.n_tombstones
    q = jnp.asarray(ds.queries)
    r = _assert_sharded_matches_oracle(sh, model, q, cfg, num_shards)
    assert (np.asarray(r.stats.rows_tombstoned) > 0).all()
    # compaction reclaims and stays on the oracle
    assert sh.compact() == dead.size
    assert sh.n_tombstones == 0 and sh.live_s is None
    _assert_sharded_matches_oracle(sh, model, q, cfg, num_shards)


def test_sharded_mutation_oracle_shard_map():
    ds, _ = _built()
    cfg = EngineConfig(nprobe=8, rerank_mult=4)
    eng = mk_engine(cfg)
    sh = ShardedEngine(eng, 1)  # one shard per device; CI has one device
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("shards",))
    model = Model(np.asarray(ds.base))
    rng = np.random.default_rng(23)
    dead = rng.choice(3000, size=120, replace=False)
    sh.delete(dead)
    model.delete(dead)
    new_ids = np.arange(3000, 3080)
    new_vecs = rng.normal(size=(80, D)).astype(np.float32)
    sh.upsert(new_ids, new_vecs)
    model.upsert(new_ids, new_vecs)
    q = jnp.asarray(ds.queries)
    rm = _assert_sharded_matches_oracle(sh, model, q, cfg, 1, mesh=mesh)
    # both drivers agree with each other too
    rv = sh.search(q, 10)
    np.testing.assert_array_equal(np.asarray(rm.ids), np.asarray(rv.ids))
    np.testing.assert_array_equal(np.asarray(rm.stats.rows_tombstoned),
                                  np.asarray(rv.stats.rows_tombstoned))


def test_sharded_locate_and_reupsert():
    ds, _ = _built()
    cfg = EngineConfig(nprobe=8, rerank_mult=4)
    sh = ShardedEngine(mk_engine(cfg), 3)
    loc = sh.locate(42)
    assert loc is not None
    sh.delete([42])
    assert sh.locate(42) is None
    v = np.asarray(ds.base[42])[None, :]
    sh.upsert(np.array([42]), v)
    j, l, s = sh.locate(42)
    # re-routed to the same global list -> same shard/local by round robin
    assert (j, l) == (loc[0], loc[1])


# ---------------------------------------------------------------------------
# autotune invalidation (docs/mutability.md: no stale verdicts)
# ---------------------------------------------------------------------------

def test_clear_autotune_cache_selective():
    saved = dict(ops_mod._AUTOTUNE_CACHE)
    try:
        ops_mod._AUTOTUNE_CACHE.clear()
        ops_mod._AUTOTUNE_CACHE.update({
            ("scan", "cpu", False, 8, 512, 8, 64, 1.0): "a",
            ("scan", "cpu", False, 8, 1024, 8, 64, 1.0): "b",
            ("scan", "cpu", False, 8, 512, 8, 128, 0.5): "c",
            ("rerank", "cpu", False, 8, 40, 32, 10, 3000): "d",
            ("rerank", "cpu", False, 8, 40, 32, 10, 4096): "e",
        })
        # cap matcher touches only scan keys with that cap
        assert ops_mod.clear_autotune_cache(cap=512) == 2
        assert ("scan", "cpu", False, 8, 1024, 8, 64, 1.0) in \
            ops_mod._AUTOTUNE_CACHE
        assert len(ops_mod._AUTOTUNE_CACHE) == 3
        # n matcher touches only rerank keys with that N
        assert ops_mod.clear_autotune_cache(n=3000) == 1
        assert ("rerank", "cpu", False, 8, 40, 32, 10, 4096) in \
            ops_mod._AUTOTUNE_CACHE
        # nlist matcher
        assert ops_mod.clear_autotune_cache(nlist=128) == 0  # cap dropped it
        assert ops_mod.clear_autotune_cache(nlist=64) == 1
        # kind + no dims clears that kind
        assert ops_mod.clear_autotune_cache(kind="rerank") == 1
        assert ops_mod.autotune_cache_size() == 0
    finally:
        ops_mod._AUTOTUNE_CACHE.clear()
        ops_mod._AUTOTUNE_CACHE.update(saved)


def test_compaction_cap_change_retriggers_autotune_sweep():
    """Regression: a post-compaction shape change must re-run the sweep —
    a stale verdict for the old (G, cap, M, nlist) signature must not
    survive to serve the new shape."""
    ds, _ = _built()
    cfg = EngineConfig(nprobe=8, rerank_mult=0, scan_impl="auto")
    eng = mk_engine(cfg)
    q = jnp.asarray(ds.queries)
    cap0 = eng.index.lists.cap
    eng.search(q, 10)  # resolves the (..., cap0, ...) scan signature
    sig_hit = [k for k in ops_mod.autotune_cache()
               if k[0] == "scan" and k[4] == cap0 and k[6] == NLIST]
    assert sig_hit, "expected the sweep to have resolved this shape"
    eng.delete(np.arange(500))
    max_live = int(np.asarray(live_counts(eng.index.lists)).max())
    tight = -(-max_live // 8) * 8
    assert tight < cap0, "test needs the compaction to actually shrink cap"
    eng.compact(cap=tight)
    snap = ops_mod.autotune_cache()
    for k in sig_hit:
        assert k not in snap, "stale verdict survived the cap change"
    a0 = ops_mod.autotune_cache_size()
    eng.search(q, 10)  # must re-sweep for the new cap
    assert ops_mod.autotune_cache_size() == a0 + 1
    new_key = [k for k in ops_mod.autotune_cache()
               if k[0] == "scan" and k[4] == tight and k[6] == NLIST]
    assert new_key


# ---------------------------------------------------------------------------
# serving: mutation entry points + threaded stress
# ---------------------------------------------------------------------------

def _serving_engine():
    ds, _ = _built()
    return ds, mk_engine(EngineConfig(nprobe=8, rerank_mult=2))


def test_serving_mutation_entry_points():
    ds, eng = _serving_engine()
    loop = ServingLoop(eng, buckets=(4,), max_wait_s=0.001)
    with loop:
        r0 = loop.submit(ds.queries[0], k=5, tenant="t").result(timeout=60)
        assert r0.rows_tombstoned == 0
        assert loop.metrics().epoch == 0
        assert loop.delete(np.arange(300)) == 300
        r1 = loop.submit(ds.queries[0], k=5, tenant="t").result(timeout=60)
        assert r1.rows_tombstoned > 0
        m = loop.metrics()
        assert m.epoch == 1
        assert m.rows_tombstoned == r1.rows_tombstoned
        assert loop.stats.get("t").rows_tombstoned == r1.rows_tombstoned
        loop.upsert(np.array([9000]), np.asarray(ds.base[0])[None, :])
        reclaimed = loop.compact()
        # the upsert may itself have compacted while growing a full list;
        # either way every tombstone is gone afterwards
        assert reclaimed >= 0 and eng.n_tombstones == 0
        r2 = loop.submit(ds.queries[0], k=5, tenant="t").result(timeout=60)
        assert r2.rows_tombstoned == 0
        assert loop.metrics().epoch == eng.epoch >= 3


def test_serving_stress_queries_during_mutation():
    """Hammer the loop with queries while a mutator thread upserts, deletes
    and compacts: zero failed futures, zero stale-epoch results (a gid
    deleted before the run never reappears), epochs advance."""
    ds, eng = _serving_engine()
    pre_dead = np.arange(0, 100)
    eng.delete(pre_dead)
    eng.compact()
    pre_dead_set = set(pre_dead.tolist())
    # mutator only touches this disjoint pool, so base/cap shapes stay
    # stable and queries never see a mid-run recompile storm
    pool = np.arange(100, 400)
    stop = threading.Event()
    mut_err = []

    def mutate():
        rng = np.random.default_rng(31)
        try:
            while not stop.is_set():
                sel = rng.choice(pool, size=40, replace=False)
                eng.delete(sel)
                vecs = rng.normal(size=(sel.size, D)).astype(np.float32)
                eng.upsert(np.sort(sel), vecs)
                eng.compact()
        except Exception as e:  # surface in the main thread
            mut_err.append(e)

    loop = ServingLoop(eng, buckets=(4,), max_wait_s=0.001)
    with loop:
        # compile the bucket before the clock starts
        loop.submit(ds.queries[0], k=5).result(timeout=120)
        epoch0 = loop.metrics().epoch
        t = threading.Thread(target=mutate, daemon=True)
        t.start()
        futures = []
        try:
            for i in range(120):
                q = np.asarray(ds.queries[i % ds.queries.shape[0]])
                futures.append(loop.submit(q, k=5, tenant=f"t{i % 3}"))
        finally:
            stop.set()
            t.join(timeout=30)
        results = [f.result(timeout=120) for f in futures]  # zero failures
    assert not mut_err, mut_err
    for r in results:
        for g in r.ids:
            g = int(g)
            assert g not in pre_dead_set, "stale-epoch result leaked"
            assert g == -1 or g < 3000
    assert loop.metrics().epoch > epoch0
    # quiesced index agrees with its oracle: the interleaving left no damage
    model = Model(np.asarray(ds.base))
    model.delete(pre_dead)
    live = np.asarray(eng.index.lists.ids)
    live_gids = set(int(g) for g in live[live >= 0])
    for g in list(model.rows):
        if g not in live_gids:
            del model.rows[g]
    # re-upserted vectors: read them back out of the engine's base
    locs = {g: eng.locate(g) for g in live_gids}
    base_np = np.asarray(eng.base)
    for g in live_gids:
        model.rows[g] = base_np[np.asarray(eng.index.lists.ids)[
            locs[g][0], locs[g][1]]]
    assert_matches_oracle(eng, model, jnp.asarray(ds.queries))


# ---------------------------------------------------------------------------
# hypothesis: random mutation programs vs the oracle (tie-aware on ids)
# ---------------------------------------------------------------------------

def _assert_tie_aware_equal(d_a, i_a, d_b, i_b):
    """Distances must match bitwise; ids must match except inside exact
    distance ties, where any permutation of the tied ids is legal (layout
    differences legitimately reorder equal keys in masked_topk)."""
    d_a, i_a = np.asarray(d_a), np.asarray(i_a)
    d_b, i_b = np.asarray(d_b), np.asarray(i_b)
    np.testing.assert_array_equal(d_a, d_b)
    for qi in range(d_a.shape[0]):
        row = d_a[qi]
        for v in np.unique(row):
            grp = row == v
            assert (sorted(i_a[qi][grp].tolist())
                    == sorted(i_b[qi][grp].tolist()))


_PROGRAM = hst.lists(
    hst.tuples(hst.integers(min_value=0, max_value=3),
               hst.integers(min_value=0, max_value=2**31 - 1)),
    min_size=1, max_size=6)


@pytest.mark.slow
@given(program=_PROGRAM)
@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.data_too_large,
                                 HealthCheck.filter_too_much])
def test_random_mutation_programs_match_oracle(program):
    ds, _ = _built()
    cfg = EngineConfig(nprobe=8, rerank_mult=4, scan_impl="stream")
    eng = mk_engine(cfg)
    model = Model(np.asarray(ds.base))
    next_gid = 3000
    for op, seed in program:
        rng = np.random.default_rng(seed)
        if op == 0:      # delete a random slab
            gids = list(model.rows)
            if gids:
                sel = rng.choice(gids, size=min(100, len(gids)),
                                 replace=False)
                assert eng.delete(sel) == np.unique(sel).size
                model.delete(sel)
        elif op == 1:    # insert brand-new ids
            n = int(rng.integers(1, 80))
            gids = np.arange(next_gid, next_gid + n)
            next_gid += n
            vecs = rng.normal(size=(n, D)).astype(np.float32)
            eng.upsert(gids, vecs)
            model.upsert(gids, vecs)
        elif op == 2:    # re-upsert existing ids with new vectors
            gids = sorted(model.rows)
            if gids:
                sel = np.unique(rng.choice(gids, size=min(50, len(gids))))
                vecs = rng.normal(size=(sel.size, D)).astype(np.float32)
                eng.upsert(sel, vecs)
                model.upsert(sel, vecs)
        else:            # compact
            eng.compact()
            assert eng.n_tombstones == 0
    oracle, surv = rebuild_oracle(model, eng.index.lists.cap, cfg)
    q = jnp.asarray(ds.queries)
    r_mut = eng.search(q, 10)
    r_orc = oracle.search(q, 10)
    _assert_tie_aware_equal(r_mut.dists, r_mut.ids,
                            r_orc.dists, _to_gids(r_orc.ids, surv))


# ---------------------------------------------------------------------------
# durability: background snapshots during the mutation storm
# ---------------------------------------------------------------------------

def test_serving_stress_with_background_snapshots(tmp_path):
    """Queries + upserts/deletes/compactions while the loop's checkpoint
    thread snapshots concurrently (docs/persistence.md): zero failed
    futures, zero checkpoint errors, and every durable state the run
    leaves behind — mid-run directory copies and the final directory —
    recovers bit-identical to a from-scratch engine replaying the same
    acknowledged mutation prefix (or fails loudly on a torn copy)."""
    import shutil

    from repro import persist
    from repro.persist import CorruptSnapshotError, CorruptWALError

    ds, eng = _serving_engine()
    d = str(tmp_path / "dur")
    pool = np.arange(100, 400)
    stop = threading.Event()
    mut_err = []
    applied = []  # ops in WAL-seq order (single mutator => issue order)

    def mutate():
        rng = np.random.default_rng(37)
        try:
            rounds = 0
            while not stop.is_set():
                sel = rng.choice(pool, size=40, replace=False)
                if eng.delete(sel):
                    applied.append(("delete", np.sort(np.asarray(sel))))
                vecs = rng.normal(size=(sel.size, D)).astype(np.float32)
                gids = np.sort(sel)
                eng.upsert(gids, vecs)
                applied.append(("upsert", gids, vecs))
                rounds += 1
                if rounds % 5 == 0:
                    eng.compact()
                    applied.append(("compact",))
        except Exception as e:  # surface in the main thread
            mut_err.append(e)

    loop = ServingLoop(eng, buckets=(4,), max_wait_s=0.001,
                       snapshot_dir=d, snapshot_every=0.05)
    frozen = []
    with loop:
        loop.submit(ds.queries[0], k=5).result(timeout=120)
        t = threading.Thread(target=mutate, daemon=True)
        t.start()
        futures = []
        try:
            for i in range(80):
                q = np.asarray(ds.queries[i % ds.queries.shape[0]])
                futures.append(loop.submit(q, k=5, tenant=f"t{i % 3}"))
                if i % 25 == 20:  # freeze a mid-run durable state
                    fz = str(tmp_path / f"frozen{i}")
                    shutil.copytree(d, fz)
                    frozen.append(fz)
        finally:
            stop.set()
            t.join(timeout=60)
        results = [f.result(timeout=120) for f in futures]  # zero failures
        # The state is dirty here, so the checkpoint thread is guaranteed to
        # fire; under load its 0.05 s cadence can lag the (fast) submit
        # window, so wait for the first snapshot rather than racing it.
        deadline = time.monotonic() + 120.0
        while (loop.metrics().checkpoints == 0
               and loop.checkpoint_error is None
               and time.monotonic() < deadline):
            time.sleep(0.02)
        assert loop.checkpoint_error is None
        ckpts = loop.metrics().checkpoints
    assert not mut_err, mut_err
    assert ckpts >= 1, "background checkpointing never fired"
    assert len(results) == 80

    def replay_reference(n_ops):
        ref = mk_engine(EngineConfig(nprobe=8, rerank_mult=2))
        for op in applied[:n_ops]:
            if op[0] == "delete":
                ref.delete(op[1])
            elif op[0] == "upsert":
                ref.upsert(op[1], op[2])
            else:
                ref.compact()
        return ref

    q = jnp.asarray(ds.queries)
    # final state: recovery == live engine == from-scratch replay of ALL ops
    rec, info = persist.open_engine(d, attach=False)
    assert info.last_seq == len(applied)
    ref = replay_reference(len(applied))
    for other in (eng, ref):
        ra, rb = rec.search(q, 10), other.search(q, 10)
        np.testing.assert_array_equal(np.asarray(ra.dists),
                                      np.asarray(rb.dists))
        np.testing.assert_array_equal(np.asarray(ra.ids),
                                      np.asarray(rb.ids))
    # mid-run copies: prefix-or-loud (a copy racing the checkpointer may
    # have caught a GC'd segment — loud is correct; silent damage is not)
    opened = 0
    for fz in frozen:
        try:
            rec_f, info_f = persist.open_engine(fz, attach=False)
        except (CorruptSnapshotError, CorruptWALError):
            continue
        opened += 1
        assert info_f.last_seq <= len(applied)
        ref_f = replay_reference(info_f.last_seq)
        ra, rb = rec_f.search(q, 10), ref_f.search(q, 10)
        np.testing.assert_array_equal(np.asarray(ra.dists),
                                      np.asarray(rb.dists))
        np.testing.assert_array_equal(np.asarray(ra.ids),
                                      np.asarray(rb.ids))
    assert opened >= 1, "every mid-run copy was torn; expected >=1 clean"
