"""Session shims: survive a missing ``hypothesis`` and gate ``tpu`` tests.

The container that runs tier-1 CI does not ship ``hypothesis``. Instead of
letting three modules die at collection (the seed-state failure mode), we
install a minimal stand-in: modules still import, plain tests in them still
run, and each ``@given`` property test individually reports as skipped.
With the real package installed (``pip install -r requirements-dev.txt``)
this shim is inert.
"""
from __future__ import annotations

import importlib.util
import pathlib
import sys
import types

import pytest

# `PYTHONPATH=src` is the documented invocation; make bare `pytest` work too.
_SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None

if not HAVE_HYPOTHESIS:
    class _AnyStrategy:
        """Absorbs any strategy-construction call chain."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

    def _given(*a, **k):
        def deco(fn):
            def skipper():
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            skipper.pytestmark = list(getattr(fn, "pytestmark", []))
            return skipper
        return deco

    def _settings(*a, **k):
        return lambda fn: fn

    _settings.register_profile = lambda *a, **k: None
    _settings.load_profile = lambda *a, **k: None

    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.__getattr__ = lambda name: _AnyStrategy()
    hyp.given = _given
    hyp.settings = _settings
    hyp.assume = lambda *a, **k: True
    hyp.note = lambda *a, **k: None
    hyp.HealthCheck = _AnyStrategy()
    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


def pytest_collection_modifyitems(config, items):
    """Skip ``tpu``-marked tests unless a real TPU backend is present."""
    import jax

    if jax.default_backend() == "tpu":
        return
    skip_tpu = pytest.mark.skip(reason="requires a TPU backend")
    for item in items:
        if "tpu" in item.keywords:
            item.add_marker(skip_tpu)
