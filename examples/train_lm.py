"""End-to-end LM training driver: train a ~small config for a few hundred
steps on CPU with checkpointing, then resume to show restart works.

    PYTHONPATH=src python examples/train_lm.py [--arch qwen3-1.7b] [--steps 200]

(All ten assigned architectures work via --arch; smoke-scale configs are
used so this runs on a laptop. The full configs are exercised by
`python -m repro.launch.dryrun --all`.)
"""
import argparse
import tempfile

from repro import configs
from repro.train import optimizer as opt_lib
from repro.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    print(f"== training {cfg.name} ({cfg.param_count()/1e6:.1f}M params) ==")
    ocfg = opt_lib.AdamWConfig(lr=1e-3, total_steps=args.steps,
                               warmup_steps=args.steps // 10)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        # phase 1: train to 60% with checkpoints
        state, hist = train_loop.train(
            cfg, steps=int(args.steps * 0.6), global_batch=args.global_batch,
            seq_len=args.seq_len, ocfg=ocfg, ckpt_dir=ckpt_dir,
            ckpt_every=max(10, args.steps // 10))
        print(f"-- simulated preemption at step {len(hist)} --")
        # phase 2: resume from the checkpoint and finish
        state, hist2 = train_loop.train(
            cfg, steps=args.steps, global_batch=args.global_batch,
            seq_len=args.seq_len, ocfg=ocfg, ckpt_dir=ckpt_dir,
            ckpt_every=max(10, args.steps // 10))
    first = hist[0]["loss"]
    last = hist2[-1]["loss"]
    print(f"loss: {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"(resumed across a restart)")
    assert last < first, "training failed to reduce loss"


if __name__ == "__main__":
    main()
