"""Quickstart: build a 4-bit fast-scan PQ index and search it (60 seconds).

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

from repro.core import fastscan, metrics, pq
from repro.data import vectors


def main():
    print("== 4-bit PQ fast-scan quickstart ==")
    ds = vectors.make_sift_like(n=50_000, nt=10_000, nq=128)
    print(f"dataset: base={ds.base.shape} queries={ds.queries.shape}")

    # build: PQ codebooks (K=16 -> 4-bit codes), nibble-packed layout
    t0 = time.time()
    index = fastscan.build_index(jax.random.PRNGKey(0), ds.train, ds.base,
                                 m=16, iters=15)
    print(f"built index in {time.time()-t0:.1f}s: "
          f"codes {index.packed_codes.shape} uint8 "
          f"({index.packed_codes.size / ds.base.size / 4 * 100:.1f}% of raw)")

    # search with both TPU formulations + the naive-PQ baseline
    for impl in ("mxu", "select"):
        t0 = time.time()
        dists, ids = fastscan.search(index, ds.queries, topk=10, impl=impl)
        jax.block_until_ready(ids)
        r1 = float(metrics.recall_at_r(ids, ds.gt_ids, r=1))
        r10 = float(metrics.recall_at_r(ids, ds.gt_ids, r=10))
        print(f"fast-scan[{impl}]: recall@1={r1:.3f} recall@10={r10:.3f} "
              f"({time.time()-t0:.2f}s incl. jit)")

    codes = pq.encode(index.codebook, ds.base)
    _, ids = pq.search(index.codebook, codes, ds.queries, topk=10)
    r1 = float(metrics.recall_at_r(ids, ds.gt_ids, r=1))
    print(f"naive PQ (float LUT): recall@1={r1:.3f}  <- same accuracy, "
          f"slower scan (the paper's Fig. 2 claim)")


if __name__ == "__main__":
    main()
