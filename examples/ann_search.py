"""End-to-end large-scale ANN pipeline (paper Table 1, scaled) through the
unified engine: HNSW coarse -> 4-bit fast-scan ADC -> exact re-rank -> top-k.

    PYTHONPATH=src python examples/ann_search.py [--n 200000] [--nprobe 4]
"""
import argparse
import math
import time

import jax

from repro.core import metrics
from repro.data import vectors
from repro.engine import SearchEngine, ShardedEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--nprobe", type=int, default=4)
    ap.add_argument("--queries", type=int, default=128)
    ap.add_argument("--rerank-mult", type=int, default=4,
                    help="refine rerank_mult*k candidates exactly (0 = off)")
    ap.add_argument("--shards", type=int, default=0,
                    help="also run the shard-parallel path with S shards")
    args = ap.parse_args()

    print("== unified engine: IVF + HNSW + 4-bit PQ + exact re-rank ==")
    ds = vectors.make_deep_like(n=args.n, nt=max(10_000, args.n // 10),
                                nq=args.queries)
    nlist = int(math.sqrt(args.n))  # the paper's sqrt(N) heuristic
    print(f"N={args.n}, nlist={nlist}, M={args.m}, K=16, nprobe={args.nprobe}")

    t0 = time.time()
    engine = SearchEngine.build(jax.random.PRNGKey(0), ds.train, ds.base,
                                m=args.m, nlist=nlist, coarse="hnsw")
    print(f"build: {time.time()-t0:.1f}s "
          f"(codes {engine.index.lists.codes.shape}, {4*args.m} bits/vector)")

    def timed_search(rr):
        jax.block_until_ready(  # warmup/jit at the SAME batch shape as timed
            engine.search(ds.queries, 10, nprobe=args.nprobe,
                          rerank_mult=rr).ids)
        t0 = time.time()
        res = engine.search(ds.queries, 10, nprobe=args.nprobe, rerank_mult=rr)
        jax.block_until_ready(res.ids)
        return res, time.time() - t0

    res, dt = timed_search(0)
    r1 = float(metrics.recall_at_r(res.ids, ds.gt_ids, r=1))
    print(f"fast-scan only:   recall@1={r1:.3f}, "
          f"{dt/args.queries*1e3:.3f} ms/query "
          f"(scanned ~{float(res.stats.codes_scanned.mean()):.0f} codes/query)")

    if args.rerank_mult:
        res_rr, dt_rr = timed_search(args.rerank_mult)
        r1_rr = float(metrics.recall_at_r(res_rr.ids, ds.gt_ids, r=1))
        print(f"+ exact re-rank:  recall@1={r1_rr:.3f}, "
              f"{dt_rr/args.queries*1e3:.3f} ms/query "
              f"(re-ranked {float(res_rr.stats.reranked.mean()):.0f}/query)")

    # flat coarse quantizer reference (exact probe selection)
    flat = SearchEngine(engine.index, base=ds.base, coarse="flat")
    res_flat = flat.search(ds.queries, 10, nprobe=args.nprobe, rerank_mult=0)
    r1f = float(metrics.recall_at_r(res_flat.ids, ds.gt_ids, r=1))
    print(f"flat-coarse reference: recall@1={r1f:.3f} "
          f"(HNSW coarse loses {max(0.0, r1f - r1):.3f})")

    if args.shards > 1:
        sh = ShardedEngine(engine, args.shards)
        res_s = sh.search(ds.queries, 10, nprobe=args.nprobe,
                          rerank_mult=args.rerank_mult)
        r1s = float(metrics.recall_at_r(res_s.ids, ds.gt_ids, r=1))
        print(f"sharded x{args.shards} (flat coarse per shard): "
              f"recall@1={r1s:.3f} "
              f"(probed {int(res_s.stats.lists_probed[0])} lists/query total)")


if __name__ == "__main__":
    main()
