"""End-to-end large-scale ANN pipeline (paper Table 1, scaled):
IVF inverted index + HNSW coarse quantizer + 4-bit PQ distance estimation.

    PYTHONPATH=src python examples/ann_search.py [--n 200000] [--nprobe 4]
"""
import argparse
import math
import time

import jax

from repro.core import coarse, ivf, metrics
from repro.data import vectors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=100_000)
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--nprobe", type=int, default=4)
    ap.add_argument("--queries", type=int, default=128)
    args = ap.parse_args()

    print("== IVF + HNSW + 4-bit PQ (Table 1 pipeline) ==")
    ds = vectors.make_deep_like(n=args.n, nt=max(10_000, args.n // 10),
                                nq=args.queries)
    nlist = int(math.sqrt(args.n))  # the paper's sqrt(N) heuristic
    print(f"N={args.n}, nlist={nlist}, M={args.m}, K=16, nprobe={args.nprobe}")

    t0 = time.time()
    index = ivf.build_ivf(jax.random.PRNGKey(0), ds.train, ds.base,
                          m=args.m, nlist=nlist)
    hc = coarse.build_hnsw_coarse(index.centroids, m=16, ef_construction=64)
    print(f"build: {time.time()-t0:.1f}s "
          f"(codes {index.list_codes.shape}, {4*args.m} bits/vector)")

    def pipeline(q):
        _, probes = hc.search(q, nprobe=args.nprobe)
        return ivf.search_ivf_precomputed_probes(index, q, probes,
                                                 nprobe=args.nprobe, topk=10)

    # warmup/jit, then timed
    jax.block_until_ready(pipeline(ds.queries[:8])[0])
    t0 = time.time()
    dists, ids = pipeline(ds.queries)
    jax.block_until_ready(ids)
    dt = time.time() - t0
    r1 = float(metrics.recall_at_r(ids, ds.gt_ids, r=1))
    print(f"search: recall@1={r1:.3f}, "
          f"{dt/args.queries*1e3:.3f} ms/query (batch of {args.queries})")

    # flat coarse quantizer reference (exact probe selection)
    _, ids_flat = ivf.search_ivf(index, ds.queries, nprobe=args.nprobe, topk=10)
    r1f = float(metrics.recall_at_r(ids_flat, ds.gt_ids, r=1))
    print(f"flat-coarse reference: recall@1={r1f:.3f} "
          f"(HNSW coarse loses {max(0.0, r1f - r1):.3f})")


if __name__ == "__main__":
    main()
