"""Serve a small LM with batched requests: exact KV cache vs the paper's
4-bit-PQ-compressed KV cache, comparing outputs and cache bytes.

    PYTHONPATH=src python examples/serve_lm.py [--arch qwen1.5-32b] [--tokens 12]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch import serve as serve_lib
from repro.models import model as model_lib


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-32b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    print(f"== serving {cfg.name}: {args.batch} requests, "
          f"{args.tokens} tokens each ==")
    params = model_lib.init_lm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len), np.int32))
    max_seq = args.prompt_len + args.tokens

    # exact cache
    exact_cfg = cfg.replace(kv_pq=False)
    toks_exact = serve_lib.serve_batch(exact_cfg, params, prompts, args.tokens)
    _, c_exact = model_lib.prefill(params, prompts, exact_cfg, max_seq=max_seq)

    if cfg.block_type != "attn":
        print("arch is attention-free/hybrid: PQ-KV applies to attention "
              "blocks only (see DESIGN.md §Arch-applicability)")
        print("generated:", np.asarray(toks_exact)[:, :8], "...")
        return

    # PQ cache (paper technique): calibrate codebooks, then serve
    pq_cfg = cfg.replace(kv_pq=True)
    toks_pq = serve_lib.serve_batch(pq_cfg, params, prompts, args.tokens,
                                    key=jax.random.PRNGKey(7))
    pqc = serve_lib.calibrate_pq_cache(jax.random.PRNGKey(7), params, pq_cfg,
                                       args.batch, max_seq)
    exact_b = cache_bytes(c_exact)
    pq_b = cache_bytes((pqc.k_codes, pqc.v_codes))
    agree = float(jnp.mean((toks_exact == toks_pq).astype(jnp.float32)))
    print(f"cache bytes: exact={exact_b/1e6:.2f}MB "
          f"pq={pq_b/1e6:.2f}MB ({exact_b/pq_b:.1f}x smaller)")
    print(f"token agreement exact-vs-pq: {agree:.2f} "
          f"(untrained weights; production codebooks are activation-calibrated)")
    print("exact:", np.asarray(toks_exact)[0, :10])
    print("pq:   ", np.asarray(toks_pq)[0, :10])


if __name__ == "__main__":
    main()
