"""Serve ANN queries through the dynamic micro-batching loop, end to end:

build an IVF + 4-bit-PQ engine, start ``repro.serving.ServingLoop`` (fused
single-jit pipeline underneath), fire a ragged multi-tenant request stream
at it, and print per-tenant accounting + loop metrics.

    PYTHONPATH=src python examples/serve_ann.py [--n 50000] [--requests 200]
"""
import argparse
import asyncio
import math
import time

import jax
import numpy as np

from repro.core import metrics
from repro.data import vectors
from repro.engine import SearchEngine
from repro.serving import ServingLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=50_000)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--rerank-mult", type=int, default=4)
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="batching window: how long a request waits for co-riders")
    args = ap.parse_args()

    print("== build engine ==")
    ds = vectors.make_sift_like(n=args.n, nt=max(5_000, args.n // 10), nq=256)
    engine = SearchEngine.build(jax.random.PRNGKey(0), ds.train, ds.base,
                                m=8, nlist=int(math.sqrt(args.n)),
                                coarse_iters=10, pq_iters=10)

    loop = ServingLoop(engine, rerank_mult=args.rerank_mult,
                       max_wait_s=args.max_wait_ms / 1e3)
    loop.start(warmup=True)  # pre-compile every shape bucket
    print(f"warmed up: {loop.metrics().compiles} compiles "
          f"(one per shape bucket {loop.batcher.buckets})")

    print(f"\n== serve {args.requests} requests from 3 tenants ==")
    rng = np.random.default_rng(0)
    queries = np.asarray(ds.queries, np.float32)
    t0 = time.monotonic()
    futs, rows = [], []
    for i in range(args.requests):
        qi = i % queries.shape[0]
        tenant = ("alice", "bob", "carol")[i % 3]
        futs.append(loop.submit(queries[qi], k=10, tenant=tenant))
        rows.append(qi)
        if rng.random() < 0.3:               # ragged arrivals: bursty stream
            time.sleep(float(rng.exponential(0.002)))
    results = [f.result(timeout=60) for f in futs]
    wall = time.monotonic() - t0

    got = np.stack([r.ids for r in results])
    r1 = float(metrics.recall_at_r(got, ds.gt_ids[np.asarray(rows)], r=1))
    m = loop.metrics()
    print(f"{args.requests} requests in {wall:.2f}s "
          f"({args.requests / wall:.0f} qps), recall@1={r1:.3f}")
    print(f"batches={m.batches}, occupancy={m.occupancy:.2f}, "
          f"buckets={m.bucket_counts}, compiles after warmup="
          f"{m.compiles - len(loop.batcher.buckets)}")

    print("\n== per-tenant accounting ==")
    for tenant, st in sorted(loop.stats.snapshot().items()):
        print(f"  {tenant:8s} queries={st.queries:4d} "
              f"codes_scanned={st.codes_scanned:8d} "
              f"reranked={st.reranked:6d} "
              f"mean_latency={st.mean_latency_s * 1e3:6.2f}ms "
              f"max={st.latency_max_s * 1e3:6.2f}ms")

    print("\n== asyncio entry point ==")

    async def one():
        res = await loop.asearch(queries[0], k=5, tenant="async")
        return res.ids

    print("await loop.asearch(...) ->", asyncio.run(one()))
    loop.stop()


if __name__ == "__main__":
    main()
