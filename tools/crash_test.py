"""Kill-9 crash-recovery driver for the durable index (docs/persistence.md).

The in-process fault harness (tests/faults.py) simulates crashes by raising
at an I/O step; this tool is the real thing: a CHILD process runs a seeded
scripted mutation workload against a durable directory, printing an ack
line after every mutation the WAL has fsync'd; the PARENT SIGKILLs it at a
chosen ack (no atexit, no flushing, no goodbye), reopens the directory via
``persist.open_engine``, and asserts the recovered engine's search results
are bit-identical to a from-scratch engine replaying exactly the
acknowledged prefix of the same workload.

The workload is pure-deterministic from ``--seed`` (same dataset build,
same mutation stream), so parent and child derive identical ops without
sharing anything but the directory under test.

Usage:
    python tools/crash_test.py [--kill-at 5] [--steps 12] [--seed 7] \
        [--dir /tmp/crashdir] [--sweep] [--replication]

``--kill-at N`` kills after the N-th ack (default: seeded random step).
``--sweep`` runs every kill point 1..steps sequentially. Exits non-zero on
any recovery mismatch.

``--replication`` runs the failover drill instead (docs/serving.md): the
child is a PRIMARY that ships every mutation's WAL segment through a
``DirTransport`` before acking; the parent SIGKILLs it mid-stream, replays
the shipped chain into a warm STANDBY, promotes it (term bump), and
asserts (a) the promoted replica is bit-identical to a from-scratch
rebuild over exactly the acked prefix, (b) the deposed primary's next
append and ship both raise ``FencedError``, and (c) standby reads serve
before, during, and after the transition.
"""
from __future__ import annotations

import argparse
import os
import pathlib
import shutil
import signal
import subprocess
import sys
import tempfile
import time

_SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

NLIST = 16
D = 32
M = 8
ACK = "ACK"


def build_engine():
    import jax
    import jax.numpy as jnp

    from repro.core import ivf
    from repro.data import vectors
    from repro.engine import EngineConfig, SearchEngine

    ds = vectors.make_sift_like(n=2000, nt=1000, nq=6, d=D, ncl=16, seed=5)
    index = ivf.build_ivf(jax.random.PRNGKey(0), jnp.asarray(ds.train),
                          jnp.asarray(ds.base), m=M, nlist=NLIST,
                          coarse_iters=4, pq_iters=4)
    eng = SearchEngine(index, base=jnp.asarray(ds.base),
                       config=EngineConfig(nprobe=6, rerank_mult=2))
    return ds, eng


def scripted_ops(steps: int, seed: int):
    """Deterministic mutation stream; every op logs exactly one WAL record
    (delete slabs are disjoint so each always finds live rows)."""
    import numpy as np

    rng = np.random.default_rng(seed)
    ops = []
    for i in range(steps):
        r = i % 4
        if r == 3:
            ops.append(("compact",))
        elif r == 1:
            ops.append(("delete", np.arange(60 * i, 60 * i + 40)))
        else:
            ids = np.arange(2000 + 50 * i, 2000 + 50 * i + 30)
            ops.append(("upsert", ids,
                        rng.normal(size=(30, D)).astype(np.float32)))
    return ops


def apply_op(eng, op):
    if op[0] == "upsert":
        eng.upsert(op[1], op[2])
    elif op[0] == "delete":
        eng.delete(op[1])
    else:
        eng.compact()


def child_main(directory: str, steps: int, seed: int) -> int:
    """Run the workload, printing one ack per durably-logged mutation."""
    from repro import persist

    _ds, eng = build_engine()
    persist.ensure_attached(eng, directory)
    print(f"{ACK} 0", flush=True)  # attached: snapshot + WAL live
    for i, op in enumerate(scripted_ops(steps, seed), start=1):
        apply_op(eng, op)
        # the WAL record was fsync'd before the in-memory swap, so this op
        # survives any crash from here on — THAT is what the ack promises
        print(f"{ACK} {i}", flush=True)
    return 0


def child_repl_main(directory: str, steps: int, seed: int) -> int:
    """Primary-side workload: every mutation is shipped before it is acked,
    so an ack promises the op is replayable on the standby side."""
    from repro import persist

    primary_dir = os.path.join(directory, "primary")
    ship_dir = os.path.join(directory, "ship")
    _ds, eng = build_engine()
    persist.ensure_attached(eng, primary_dir)
    transport = persist.DirTransport(ship_dir)
    shipper = persist.WALShipper(eng, primary_dir, transport, term=0)
    shipper.ship_once()
    print(f"{ACK} 0", flush=True)  # snapshot + WAL live, chain shipped
    for i, op in enumerate(scripted_ops(steps, seed), start=1):
        apply_op(eng, op)
        shipper.ship_once()
        print(f"{ACK} {i}", flush=True)
    return 0


def run_replication(kill_at: int, steps: int, seed: int,
                    directory: str) -> bool:
    """Kill a shipping primary mid-stream, promote a warm standby, and
    check the three failover guarantees (see module docstring)."""
    import numpy as np

    from repro import persist
    from repro.persist.errors import FencedError

    shutil.rmtree(directory, ignore_errors=True)
    os.makedirs(directory)
    proc = subprocess.Popen(
        [sys.executable, os.path.abspath(__file__), "--child",
         "--replication", "--dir", directory, "--steps", str(steps),
         "--seed", str(seed)],
        stdout=subprocess.PIPE, text=True,
        cwd=str(pathlib.Path(__file__).resolve().parent.parent))
    acked = -1
    try:
        for line in proc.stdout:
            if not line.startswith(ACK):
                continue
            acked = int(line.split()[1])
            if acked >= kill_at:
                os.kill(proc.pid, signal.SIGKILL)
                break
    finally:
        proc.stdout.close()
        proc.wait(timeout=60)
    if acked < kill_at:
        print(f"FAIL kill_at={kill_at}: primary finished after {acked} acks "
              "before the kill landed (raise --steps)")
        return False

    ship_dir = os.path.join(directory, "ship")
    standby_dir = os.path.join(directory, "standby")
    transport = persist.DirTransport(ship_dir)
    ds, standby = build_engine()
    replica = persist.StandbyReplica(standby, transport)
    q = np.asarray(ds.queries)

    def read_ok(eng, when):
        try:
            r = eng.search(q, 10)
            _ = np.asarray(r.ids)
            return True
        except Exception as exc:  # noqa: BLE001 — the drill reports, not raises
            print(f"FAIL kill_at={kill_at}: standby read errored {when}: "
                  f"{exc!r}")
            return False

    # (c) standby reads serve before, during, and after the transition
    if not read_ok(standby, "before replay"):
        return False
    replica.poll_once()
    if not read_ok(standby, "after replay, before promote"):
        return False
    if replica.applied_seq < acked:
        print(f"FAIL kill_at={kill_at}: primary acked {acked} shipped ops "
              f"but standby replayed only to seq {replica.applied_seq}")
        return False

    t0 = time.monotonic()
    new_term = replica.promote(standby_dir)
    dt = time.monotonic() - t0
    if not read_ok(standby, "after promote"):
        return False

    # (a) promoted replica == from-scratch rebuild of the acked prefix
    ops = scripted_ops(steps, seed)
    _ds2, ref = build_engine()
    for op in ops[:replica.applied_seq]:
        apply_op(ref, op)
    ra = standby.search(q, 10)
    rb = ref.search(q, 10)
    if (np.asarray(ra.ids) != np.asarray(rb.ids)).any() or \
       (np.asarray(ra.dists) != np.asarray(rb.dists)).any():
        print(f"FAIL kill_at={kill_at}: promoted standby (seq "
              f"{replica.applied_seq}) differs from the from-scratch "
              "replay of the same prefix")
        return False

    # (b) the deposed primary is fenced on its next append AND ship
    old = persist.open_engine(os.path.join(directory, "primary"))[0]
    old._wal.guard = persist.make_fence_guard(transport, 0)
    old_shipper = persist.WALShipper(old, os.path.join(directory, "primary"),
                                     transport, term=0)
    try:
        old_shipper.ship_once()
        print(f"FAIL kill_at={kill_at}: deposed primary shipped at term 0 "
              f"after promotion to term {new_term}")
        return False
    except FencedError:
        pass
    try:
        old.delete(np.arange(3))
        print(f"FAIL kill_at={kill_at}: deposed primary appended at term 0 "
              f"after promotion to term {new_term}")
        return False
    except FencedError:
        pass

    print(f"ok kill_at={kill_at}: acked>={acked}, standby replayed seq "
          f"{replica.applied_seq}, promoted to term {new_term} in {dt:.2f}s "
          "— bit-identical, deposed primary fenced")
    return True


def run_one(kill_at: int, steps: int, seed: int, directory: str) -> bool:
    """Spawn the child, SIGKILL it after ack ``kill_at``, verify recovery."""
    import numpy as np

    from repro import persist

    shutil.rmtree(directory, ignore_errors=True)
    proc = subprocess.Popen(
        [sys.executable, os.path.abspath(__file__), "--child",
         "--dir", directory, "--steps", str(steps), "--seed", str(seed)],
        stdout=subprocess.PIPE, text=True,
        cwd=str(pathlib.Path(__file__).resolve().parent.parent))
    acked = -1
    try:
        for line in proc.stdout:
            if not line.startswith(ACK):
                continue
            acked = int(line.split()[1])
            if acked >= kill_at:
                os.kill(proc.pid, signal.SIGKILL)
                break
    finally:
        proc.stdout.close()
        proc.wait(timeout=60)
    if acked < kill_at:
        print(f"FAIL kill_at={kill_at}: child finished after {acked} acks "
              "before the kill landed (raise --steps)")
        return False

    t0 = time.monotonic()
    rec, info = persist.open_engine(directory, attach=False)
    dt = time.monotonic() - t0
    # the kill may land after further unread acks: the WAL, not the pipe,
    # is the source of truth — recovery must cover at least every ack we
    # READ, and whatever suffix was durable beyond it
    if info.last_seq < acked:
        print(f"FAIL kill_at={kill_at}: child acked {acked} mutations but "
              f"recovery replayed only to seq {info.last_seq} — ack lost")
        return False
    ops = scripted_ops(steps, seed)
    ds, ref = build_engine()
    for op in ops[:info.last_seq]:
        apply_op(ref, op)
    q = np.asarray(ds.queries)
    ra = rec.search(q, 10)
    rb = ref.search(q, 10)
    if (np.asarray(ra.ids) != np.asarray(rb.ids)).any() or \
       (np.asarray(ra.dists) != np.asarray(rb.dists)).any():
        print(f"FAIL kill_at={kill_at}: recovered state (seq {info.last_seq})"
              " differs from the from-scratch replay of the same prefix")
        return False
    print(f"ok kill_at={kill_at}: acked>={acked}, recovered seq "
          f"{info.last_seq} (snapshot {info.snapshot!r}, replayed "
          f"{info.replayed}, wal tail truncated {info.truncated_bytes}B) "
          f"in {dt:.2f}s — bit-identical")
    return True


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--child", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--dir", default=None,
                    help="durable directory (default: fresh tempdir)")
    ap.add_argument("--steps", type=int, default=12,
                    help="mutations in the scripted workload")
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--kill-at", type=int, default=None,
                    help="SIGKILL after this ack (default: seeded random)")
    ap.add_argument("--sweep", action="store_true",
                    help="run every kill point 1..steps")
    ap.add_argument("--replication", action="store_true",
                    help="run the ship/promote failover drill instead")
    args = ap.parse_args()

    if args.child:
        if args.replication:
            return child_repl_main(args.dir, args.steps, args.seed)
        return child_main(args.dir, args.steps, args.seed)

    tmp = None
    directory = args.dir
    if directory is None:
        tmp = tempfile.mkdtemp(prefix="crash_test_")
        directory = tmp
    try:
        if args.sweep:
            points = list(range(1, args.steps + 1))
        else:
            import random
            kill_at = (args.kill_at if args.kill_at is not None
                       else random.Random(args.seed).randint(1, args.steps))
            points = [kill_at]
        run = run_replication if args.replication else run_one
        failures = sum(not run(p, args.steps, args.seed, directory)
                       for p in points)
        if failures:
            print(f"{failures}/{len(points)} kill points FAILED")
            return 1
        print(f"all {len(points)} kill point(s) recovered bit-identical")
        return 0
    finally:
        if tmp is not None:
            shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    sys.exit(main())
