"""Non-blocking line-coverage floor check for the CI coverage job.

Reads a Cobertura ``coverage.xml`` (pytest-cov's ``--cov-report=xml``) and
emits a GitHub Actions ``::warning`` annotation when line coverage over the
measured packages falls below the floor (default 85%). Always exits 0 —
coverage is a trend to watch, not a merge gate; the annotation puts a dip
in the job summary where a reviewer sees it.

Usage:
    python tools/check_coverage.py --xml coverage.xml [--floor 85]
"""
from __future__ import annotations

import argparse
import sys
import xml.etree.ElementTree as ET


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--xml", required=True, help="Cobertura coverage.xml")
    ap.add_argument("--floor", type=float, default=85.0,
                    help="line-coverage percentage that triggers a warning")
    args = ap.parse_args(argv)

    try:
        root = ET.parse(args.xml).getroot()
    except (OSError, ET.ParseError) as e:
        print(f"::notice::coverage check skipped: cannot read "
              f"{args.xml} ({e})")
        return 0

    covered = valid = 0
    # sum the raw line counts rather than trusting the pre-divided
    # line-rate attribute: per-package rounding must not move the verdict
    for cls in root.iter("class"):
        for line in cls.iter("line"):
            valid += 1
            if int(line.get("hits", "0")) > 0:
                covered += 1
    if not valid:
        print("::notice::coverage check: no measured lines in report")
        return 0

    pct = 100.0 * covered / valid
    per_pkg = []
    for pkg in root.iter("package"):
        rate = float(pkg.get("line-rate", "0"))
        per_pkg.append(f"{pkg.get('name')}={rate * 100:.1f}%")
    detail = ", ".join(per_pkg)
    if pct < args.floor:
        print(f"::warning::line coverage {pct:.1f}% is below the "
              f"{args.floor:.0f}% floor ({covered}/{valid} lines; {detail})")
    else:
        print(f"coverage ok: {pct:.1f}% >= {args.floor:.0f}% "
              f"({covered}/{valid} lines; {detail})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
